"""Declarative CI bench gates: one harness, one TOML, zero inline shell math.

Every perf/quality guarantee CI enforces used to live as an ad-hoc inline
python step in ``ci.yml`` — unreviewable, untestable, and copy-pasted per
check.  This module replaces them all: ``benchmarks/gates.toml`` declares
the *inputs* (bench JSON artifacts + committed baselines, each with a
schema whitelist) and the *gates* (threshold checks over dotted metric
paths), and CI calls

    python -m benchmarks.check_gates check --only <input> [name=path ...]

once per bench JSON.  The gate logic itself is tier-1 unit-tested
(``tests/test_check_gates.py``) — pass, fail, malformed input, and
unknown-schema refusal are all asserted, which no inline YAML step ever
was.

Gate kinds (see gates.toml for the live set):

``max_value`` / ``min_value``
    absolute bound on a metric.
``max_ratio`` (+ ``ref_input``/``ref_metric`` + optional ``slack``,
``ref_floor``)
    ``value <= max_ratio * max(ref, ref_floor) + slack`` — the committed-
    baseline regression checks and the churn-vs-control drift bound.
``require``
    the metric path must resolve (row/section present).
``contains``
    substring match on a string metric (e.g. the roofline row's
    ``dom=memory`` bandwidth-bound marker).

Metric paths are dot-separated; a list of ``{"name": ...}`` rows is
indexed by row name (names use ``/``, never ``.``), so
``rows.kernels/range_probe_xla.us_per_call`` addresses the bench row
directly.

Schema refusal: every input declares the schema versions it understands;
a baseline (or fresh artifact) with any other ``schema`` string fails the
run with exit code 2 *before* any gate is evaluated — a silent format
drift can never make gates vacuously pass.

``trajectory`` mode guards the bench *trend* instead of a single
baseline: ``benchmarks/run.py --smoke --json`` appends a timestamped
metrics row to ``BENCH_TRAJECTORY.jsonl`` on every run, and

    python -m benchmarks.check_gates trajectory BENCH_TRAJECTORY.jsonl

fails when a configured metric worsened monotonically across the last
``window`` rows by more than ``total_frac`` overall — the slow-creep
regression a 1.5x single-baseline gate never catches.

Exit codes: 0 = all gates pass, 1 = gate failure, 2 = malformed input /
unknown schema / bad config.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

try:
    import tomllib
except ImportError:                         # Python < 3.11
    import tomli as tomllib

GATES_TOML = os.path.join(os.path.dirname(__file__), "gates.toml")
CONFIG_SCHEMA = "bloomrf-gates/v1"
TRAJECTORY_SCHEMA = "bloomrf-trajectory/v1"


class GateError(Exception):
    """A gate failed (exit 1)."""


class InputError(Exception):
    """Malformed input, unknown schema, or bad config (exit 2)."""


def load_config(path: str = GATES_TOML) -> dict:
    try:
        with open(path, "rb") as f:
            cfg = tomllib.load(f)
    except (OSError, tomllib.TOMLDecodeError) as e:
        raise InputError(f"cannot read gates config {path}: {e}")
    if cfg.get("schema") != CONFIG_SCHEMA:
        raise InputError(f"{path}: unknown gates schema "
                         f"{cfg.get('schema')!r} (want {CONFIG_SCHEMA!r})")
    for field in ("inputs", "gates"):
        if field not in cfg:
            raise InputError(f"{path}: missing [{field}] section")
    return cfg


def load_input(name: str, spec: dict, overrides: dict) -> dict:
    """Load one bench JSON, enforcing the schema whitelist."""
    path = overrides.get(name, spec.get("path"))
    if not path:
        raise InputError(f"input {name!r}: no path configured")
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise InputError(f"input {name!r} ({path}): {e}")
    if not isinstance(data, dict):
        raise InputError(f"input {name!r} ({path}): not a JSON object")
    allowed = spec.get("schemas", [])
    if data.get("schema") not in allowed:
        raise InputError(
            f"input {name!r} ({path}): unknown schema "
            f"{data.get('schema')!r} — this harness understands {allowed}; "
            f"refusing to evaluate gates against an unrecognised format")
    # structural validation of the shared rows shape (when present)
    value_key = spec.get("value_key")
    if "rows" in data:
        if not data["rows"]:
            raise InputError(f"input {name!r} ({path}): empty rows")
        for r in data["rows"]:
            if not isinstance(r, dict) or not r.get("name"):
                raise InputError(f"input {name!r} ({path}): malformed row "
                                 f"{r!r}")
            if value_key is not None:
                try:
                    float(r[value_key])
                except (KeyError, TypeError, ValueError):
                    raise InputError(
                        f"input {name!r} ({path}): row {r.get('name')!r} "
                        f"lacks a numeric {value_key!r}")
    return data


def resolve(data, path: str):
    """Walk a dotted metric path; row lists are indexed by row name."""
    cur = data
    for part in path.split("."):
        if isinstance(cur, list):
            byname = {r.get("name"): r for r in cur if isinstance(r, dict)}
            if part not in byname:
                raise KeyError(f"no row named {part!r}")
            cur = byname[part]
        elif isinstance(cur, dict):
            if part not in cur:
                raise KeyError(f"no key {part!r}")
            cur = cur[part]
        else:
            raise KeyError(f"cannot index {type(cur).__name__} with {part!r}")
    return cur


def _fmt(gate: dict) -> str:
    return f"gate {gate.get('name', gate['metric'])!r}"


def check_gate(gate: dict, inputs: dict) -> str:
    """Evaluate one gate; returns a pass description or raises GateError."""
    data = inputs[gate["input"]]
    if gate.get("require"):
        try:
            resolve(data, gate["metric"])
        except KeyError as e:
            raise GateError(f"{_fmt(gate)}: required metric "
                            f"{gate['metric']!r} missing ({e})")
        return f"{_fmt(gate)}: present"
    try:
        value = resolve(data, gate["metric"])
    except KeyError as e:
        raise GateError(f"{_fmt(gate)}: metric {gate['metric']!r} "
                        f"unresolved ({e})")
    if "contains" in gate:
        if gate["contains"] not in str(value):
            raise GateError(f"{_fmt(gate)}: {gate['metric']} = {value!r} "
                            f"does not contain {gate['contains']!r}")
        return f"{_fmt(gate)}: contains {gate['contains']!r}"
    value = float(value)
    if "max_ratio" in gate:
        ref_data = inputs[gate.get("ref_input", gate["input"])]
        try:
            ref = float(resolve(ref_data, gate["ref_metric"]))
        except KeyError as e:
            raise GateError(f"{_fmt(gate)}: ref metric "
                            f"{gate['ref_metric']!r} unresolved ({e})")
        ref_eff = max(ref, gate.get("ref_floor", ref))
        bound = gate["max_ratio"] * ref_eff + gate.get("slack", 0.0)
        if value > bound:
            raise GateError(
                f"{_fmt(gate)}: {gate['metric']} = {value:.4f} > "
                f"{gate['max_ratio']}x ref {ref:.4f}"
                + (f" + {gate['slack']}" if gate.get("slack") else "")
                + f" (bound {bound:.4f}) — {gate.get('why', 'regression')}")
        return (f"{_fmt(gate)}: {value:.4f} <= {gate['max_ratio']}x "
                f"{ref:.4f} OK")
    if "max_value" in gate and value > gate["max_value"]:
        raise GateError(f"{_fmt(gate)}: {gate['metric']} = {value:.4f} > "
                        f"{gate['max_value']} — "
                        f"{gate.get('why', 'above bound')}")
    if "min_value" in gate and value < gate["min_value"]:
        raise GateError(f"{_fmt(gate)}: {gate['metric']} = {value:.4f} < "
                        f"{gate['min_value']} — "
                        f"{gate.get('why', 'below bound')}")
    if not any(k in gate for k in ("max_value", "min_value")):
        raise InputError(f"{_fmt(gate)}: no known gate kind "
                         f"(max_value/min_value/max_ratio/require/contains)")
    return f"{_fmt(gate)}: {value:.4f} within bounds OK"


def run_check(cfg: dict, only=None, overrides=None) -> list:
    """Evaluate the configured gates; returns pass messages, raises on the
    first failure.  ``only`` restricts to gates whose ``input`` is listed
    (reference inputs still load — with schema refusal — as needed)."""
    overrides = overrides or {}
    gates = [g for g in cfg["gates"]
             if only is None or g["input"] in only]
    if only is not None and not gates:
        raise InputError(f"no gates target inputs {sorted(only)}")
    needed = {g["input"] for g in gates}
    needed |= {g["ref_input"] for g in gates if "ref_input" in g}
    inputs = {}
    for name in sorted(needed):
        if name not in cfg["inputs"]:
            raise InputError(f"gate references undeclared input {name!r}")
        inputs[name] = load_input(name, cfg["inputs"][name], overrides)
    return [check_gate(g, inputs) for g in gates]


# ---------------------------------------------------------------------------
# trajectory mode
# ---------------------------------------------------------------------------

def load_trajectory(path: str) -> list:
    try:
        with open(path) as f:
            lines = [ln for ln in f if ln.strip()]
    except OSError as e:
        raise InputError(f"trajectory {path}: {e}")
    rows = []
    for i, ln in enumerate(lines):
        try:
            row = json.loads(ln)
        except json.JSONDecodeError as e:
            raise InputError(f"trajectory {path} line {i + 1}: {e}")
        if row.get("schema") != TRAJECTORY_SCHEMA:
            raise InputError(
                f"trajectory {path} line {i + 1}: unknown schema "
                f"{row.get('schema')!r} (want {TRAJECTORY_SCHEMA!r})")
        rows.append(row)
    return rows


def check_trajectory(cfg: dict, path: str, window=None) -> list:
    """Fail on monotone worsening of a configured metric across the last
    ``window`` trajectory rows (each step up AND total growth beyond
    ``total_frac`` — single noisy rows never trip it)."""
    tcfg = cfg.get("trajectory", {})
    window = window or int(tcfg.get("window", 4))
    total_frac = float(tcfg.get("total_frac", 0.25))
    rows = load_trajectory(path)
    msgs = []
    for metric in tcfg.get("metrics", []):
        series = []
        for row in rows:
            try:
                series.append(float(resolve(row.get("metrics", {}), metric)))
            except KeyError:
                continue            # metric not in this row (older schema)
        tail = series[-window:]
        if len(tail) < window:
            msgs.append(f"{metric}: only {len(tail)}/{window} rows, skipped")
            continue
        rising = all(b > a for a, b in zip(tail, tail[1:]))
        growth = tail[-1] / max(tail[0], 1e-12) - 1.0
        if rising and growth > total_frac:
            raise GateError(
                f"trajectory: {metric} rose monotonically over the last "
                f"{window} runs ({', '.join(f'{v:.3f}' for v in tail)}; "
                f"+{growth:.0%} > {total_frac:.0%}) — a slow-creep "
                f"regression the single-baseline gates cannot see")
        msgs.append(f"{metric}: last {window} rows "
                    f"{', '.join(f'{v:.3f}' for v in tail)} OK")
    return msgs


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", default=GATES_TOML)
    sub = ap.add_subparsers(dest="cmd", required=True)
    chk = sub.add_parser("check", help="evaluate the configured gates")
    chk.add_argument("--only", default=None,
                     help="comma-separated input names to gate")
    chk.add_argument("overrides", nargs="*", metavar="name=path",
                     help="override an input's path (e.g. store_ci=X.json)")
    trj = sub.add_parser("trajectory", help="check the bench trend file")
    trj.add_argument("path", help="BENCH_TRAJECTORY.jsonl")
    trj.add_argument("--window", type=int, default=None)
    args = ap.parse_args(argv)
    try:
        cfg = load_config(args.config)
        if args.cmd == "check":
            overrides = {}
            for ov in args.overrides:
                if "=" not in ov:
                    raise InputError(f"override {ov!r} is not name=path")
                k, _, v = ov.partition("=")
                overrides[k] = v
            only = set(args.only.split(",")) if args.only else None
            msgs = run_check(cfg, only=only, overrides=overrides)
        else:
            msgs = check_trajectory(cfg, args.path, window=args.window)
    except GateError as e:
        print(f"GATE FAILED: {e}", file=sys.stderr)
        return 1
    except InputError as e:
        print(f"BAD INPUT: {e}", file=sys.stderr)
        return 2
    for m in msgs:
        print(m)
    print(f"{len(msgs)} checks passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
