"""Fig. 10: FPR across space budgets (10..22 bits/key) for small / medium /
large ranges, plus point-query FPR vs a standard Bloom filter."""
import numpy as np

from repro.filters import BloomFilter, BloomRFAdapter, Rosetta, SuRFLite

from .common import (emit, gen_empty_ranges, gen_keys, measure_point,
                     measure_range)

N = 200_000
Q = 10_000
BPKS = (10, 14, 18, 22)


def run():
    rows = []
    rng = np.random.default_rng(10)
    keys = gen_keys(N, "uniform", rng)
    classes = {"small": 6, "medium": 14, "large": 22}
    for bpk in BPKS:
        for cls, rlog2 in classes.items():
            lo, hi, truth = gen_empty_ranges(keys, Q, 2 ** rlog2, "uniform",
                                             rng)
            for name, f in [
                ("bloomRF", BloomRFAdapter(bpk, R=2.0 ** rlog2, mode="auto")),
                ("rosetta", Rosetta(bpk, max_range_log2=min(rlog2, 14))),
                ("surf", SuRFLite.for_budget(bpk)),
            ]:
                f.build(keys)
                fpr, us = measure_range(f, keys, lo, hi, truth)
                rows.append(emit(f"fig10/{cls}/bpk={bpk}/{name}", us,
                                 f"{fpr:.4f}"))
        # point lookups
        pq = np.concatenate([keys[:Q // 2],
                             gen_keys(Q, "uniform", rng)])
        ptruth = np.isin(pq, keys)
        for name, f in [("bloomRF", BloomRFAdapter(bpk, mode="basic")),
                        ("BF", BloomFilter(bpk))]:
            f.build(keys)
            fpr, us = measure_point(f, keys, pq, ptruth)
            rows.append(emit(f"fig10/point/bpk={bpk}/{name}", us,
                             f"{fpr:.5f}"))
    return rows


if __name__ == "__main__":
    run()
