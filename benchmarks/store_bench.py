"""LSM run-store benchmark: YCSB-E-style scan-heavy workload over the
filter-pruned read path (the paper's RocksDB experiment, §9, standalone).

For each key distribution and filter backend the driver loads N keys
through the memtable (flushes + compactions build the run pyramid), then
runs a mixed phase of OPS operations — ``SCAN_FRAC`` short range scans
(YCSB-E's dominant op; scans batch through ``scan_many``, ONE fused
gather over all live runs' stacked filter state per batch) interleaved
with inserts.  The store is opened through the typed façade
(``repro.open_filter``), so the benchmark measures the production path —
including the codec layer.  Distributions: ``uniform`` / ``zipf``
(32-bit integer keys) and ``float`` (float32 keys through the f32 codec —
the paper's §8 floating-point support, end-to-end through ``Store.scan``).

Reported per setting:

* ``runs probed per scan``  — data-block reads a scan actually paid for
  (the paper's pruned-SSTable-reads axis); the ``none`` backend is the
  min/max-fence-only baseline every filter must beat,
* ``scan FP-read rate``     — touched runs that held nothing in range,
* ``bytes not read``        — data bytes the pruning saved,
* ``us/op``                 — for the device-capable backends (``bloomrf``
  / ``none``) the **device-resident probe-plane time per scan**: the scan
  bound stream is encoded to device arrays up front, every batch goes
  through ``Store.scan_probe_device`` (one fused megakernel / XLA gather
  per batch), and the per-op pruning counters accumulate as device
  scalars — no per-op host hops, so the row finally times the kernel
  instead of the Python materialisation loop.  The old host mixed-phase
  wall time survives as ``host_us_per_op`` in the per-setting metrics.
  Host-side baseline backends still report the host path.

Backends: ``bloomrf`` (stacked one-gather probes), ``none`` (fences
only), plus host-side baselines from ``repro.filters``; the ``float``
distribution runs bloomrf vs none only (the CI gate compares its pruning
against the committed uniform row).

The ``store/recovery/*`` rows measure the durability subsystem
(DESIGN.md §14): WAL-on vs WAL-off put-path us/op (the append-before-ack
tax, CI-gated ≤1.3x), checkpoint-reopen time through ``Store.open``, and
a degraded-scan drill that corrupts one run's filter block and requires
the quarantined (fence-only) scan results to match an uncorrupted
control exactly.

The ``store/tune/{static,adaptive}`` rows are the §16 self-tuning twin:
the identical zipf + correlated-near-miss scan stream through a static
store and a ``tuning="adaptive"`` one.  The adaptive store samples the
warm-phase scans, re-solves its layout at class-graduating compactions,
and must land at a strictly lower observed FPR on ground-truth-empty
ranges at equal bits per key (both CI-gated, plus retune-count >= 1).

The ``store/churn/*`` rows measure filters under deletion churn
(DESIGN.md §12): load, measure the absent-key FPR, run a 50/50
put/delete phase over the same seeded op stream, re-measure.
``fpr_drift`` (post/pre ratio) is gated in CI for the ``deletable``
mutability — its purge/promote compaction must keep drift bounded and
no worse than the ``insert_only`` control that keeps every dead key's
bits forever.

Run standalone (full sizes; the nightly row):
  PYTHONPATH=src python -m benchmarks.store_bench --json BENCH_STORE.json
or at CI sizes via ``--smoke`` / ``python -m benchmarks.run --smoke``.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.api import FilterSpec, open_filter
from repro.core import u32_to_float32

from .common import emit, gen_keys, write_json

SCHEMA = "bloomrf-store-bench/v2"   # v2: us_per_op = device probe plane
                                    # for bloomrf/none (host_us_per_op
                                    # keeps the old v1 measurement)
DEVICE_BACKENDS = ("bloomrf", "none")   # rows timed device-resident

# sizes (patched by benchmarks.run --smoke / --smoke here)
N = 200_000          # load-phase keys
OPS = 10_000         # mixed-phase operations
MEMTABLE = 8_192     # memtable flush threshold (capacity class 0)
LEVEL0 = 8           # level-0 run count triggering compaction
FANOUT = 4
BPK = 14.0           # filter bits per key
RSIZE = 1 << 8       # scan range width (short YCSB-E scans; code units)
SCAN_FRAC = 0.95     # YCSB-E: 95% scans / 5% inserts
SCAN_BATCH = 512     # scans per fused probe batch
NEAR_MISS = 0.2      # share of scans starting just past a stored key
DISTS = ("uniform", "zipf", "float")
BACKENDS = ("bloomrf", "none", "prefix_bloom", "rosetta")
FLOAT_BACKENDS = ("bloomrf", "none")
TUNE_KEYS = 60_000   # tuner-twin load-phase keys (zipf-clustered)
TUNE_SCANS = 2_048   # short scans the tuner observes before compacting
TUNE_FPR_PROBES = 4_000   # ground-truth-empty ranges for observed FPR
CHURN_OPS = 40_000   # churn-phase op count
CHURN_DELETE_FRAC = 0.6   # delete-heavy churn (the FPR-drift stressor)
CHURN_PURGE_DEAD = 0.15   # deletable: dead fraction forcing a purge rebuild
CHURN_MUTABILITIES = ("deletable", "insert_only")
RECOVERY_OPS = 30_000     # durable-load op count (WAL-on vs WAL-off rows)
RECOVERY_SCANS = 256      # degraded-scan drill batch


def _f32_keys(codes: np.ndarray, rng) -> np.ndarray:
    """Finite float32 keys whose φ codes are the given uint32 codes.

    The f32 codec is a bijection, so pushing the *uniform integer* code
    distribution through ``u32_to_float32`` yields a float workload whose
    filter behaviour is directly comparable to the ``uniform`` row (the
    CI gate compares exactly that).  Code bands that decode to NaN — or
    whose ``+RSIZE`` scan window would reach one — are resampled."""
    codes = codes.astype(np.uint32)
    win = np.uint32(max(RSIZE - 1, 0))
    for _ in range(64):
        bad = (np.isnan(u32_to_float32(codes))
               | np.isnan(u32_to_float32(codes + win)))
        if not bad.any():
            return u32_to_float32(codes)
        codes = np.where(
            bad, rng.integers(0, 1 << 31, len(codes),
                              dtype=np.uint64).astype(np.uint32), codes)
    raise RuntimeError("could not draw NaN-free float32 codes")


def _keys(n: int, dist: str, rng) -> np.ndarray:
    """Keys in the store's 32-bit domain (uint32 codes or float32 values).

    zipf keys are drawn directly in the small domain (cluster scaled to
    2^31 with a 2^22 jitter window) — truncating the 64-bit generator's
    output would drop the jitter bits and collapse the cluster onto a
    handful of duplicate keys."""
    if dist == "float":
        return _f32_keys(gen_keys(n, "uniform", rng) >> np.uint64(32), rng)
    if dist == "zipf":
        z = rng.zipf(1.2, n).astype(np.float64)
        z = z / (z.max() + 1.0)
        jitter = rng.integers(0, 1 << 22, n, dtype=np.uint64)
        return np.minimum((z * float(1 << 31)).astype(np.uint64) + jitter,
                          np.uint64((1 << 32) - 1))
    return gen_keys(n, dist, rng) >> np.uint64(32)


def _scan_starts(n: int, dist: str, data: np.ndarray, rng) -> np.ndarray:
    """Scan start keys: mostly-empty queries, the range-filter literature's
    evaluation regime (the paper measures FPR over empty ranges).

    ``1 - NEAR_MISS`` of the starts are uniform over the domain (empty
    wherever the data is sparse); ``NEAR_MISS`` are *correlated near
    misses* — a stored key plus a small gap, the adversarial case for
    prefix-based filters (cf. Rosetta/Proteus workloads)."""
    take_near = rng.random(n) < NEAR_MISS
    uni = rng.integers(0, 1 << 31, n, dtype=np.uint64)
    gap = rng.integers(RSIZE, 32 * RSIZE, n, dtype=np.uint64)
    if dist == "float":
        from repro.core import float32_to_u32

        anchor = float32_to_u32(
            data[rng.integers(0, len(data), n)]).astype(np.uint64)
        near = np.minimum(anchor + gap, np.uint64((1 << 32) - 1 - RSIZE))
        return _f32_keys(np.where(take_near, near, uni), rng)
    anchor = data[rng.integers(0, len(data), n)]
    near = np.minimum(anchor + gap, np.uint64((1 << 32) - 1))
    return np.where(take_near, near, uni)


def _scan_bounds(lo: np.ndarray, dist: str) -> np.ndarray:
    if dist == "float":
        from repro.core import float32_to_u32

        return u32_to_float32(float32_to_u32(lo)
                              + np.uint32(max(RSIZE - 1, 0)))
    return np.minimum(lo + np.uint64(max(RSIZE - 1, 0)),
                      np.uint64((1 << 32) - 1))


def run_one(backend: str, dist: str, seed: int = 0x57043) -> tuple:
    """(typed store handle, us_per_op) after load + mixed phase; same op
    stream for every backend (seeded), so pruning metrics are directly
    comparable."""
    import dataclasses

    rng = np.random.default_rng(seed)
    handle = open_filter(FilterSpec(
        dtype="f32" if dist == "float" else "u32", placement="store",
        memtable_limit=MEMTABLE, level0_runs=LEVEL0, fanout=FANOUT,
        bits_per_key=BPK, delta=6, store_backend=backend))
    data = _keys(N, dist, rng)
    as_key = float if dist == "float" else int
    for i, k in enumerate(data):
        handle.put(as_key(k), i)
    handle.flush()

    # whole batches only, so one compiled probe shape serves the phase
    n_scans = max(int(OPS * SCAN_FRAC) // SCAN_BATCH, 1) * SCAN_BATCH
    n_ins = max(OPS - n_scans, 0)
    lo = _scan_starts(n_scans, dist, data, rng)
    hi = _scan_bounds(lo, dist)
    ins = _keys(max(n_ins, 1), dist, rng)
    # warm up the fused probe (compile) outside the timed phase, then undo
    # the warm-up's counter contribution
    pre = dataclasses.replace(handle.stats)
    handle.scan_many(lo[:SCAN_BATCH], hi[:SCAN_BATCH])
    handle.store.stats = pre
    t0 = time.perf_counter()
    done_ins = 0
    for s in range(0, n_scans, SCAN_BATCH):
        handle.scan_many(lo[s:s + SCAN_BATCH], hi[s:s + SCAN_BATCH])
        # interleave the insert share owed by this slice of the stream
        owed = round(n_ins * min(s + SCAN_BATCH, n_scans) / n_scans)
        for k in ins[done_ins:owed]:
            handle.put(as_key(k), 0)
        done_ins = owed
    dt = time.perf_counter() - t0
    return handle, dt / max(n_scans + n_ins, 1) * 1e6, data


def run_device_one(handle, dist: str, data: np.ndarray,
                   seed: int = 0x57043) -> tuple:
    """Device-resident YCSB-E scan phase: ``(us_per_scan, device metrics)``.

    The whole scan-bound stream is encoded to device arrays before the
    clock starts; the timed loop slices device arrays, dispatches one
    fused pruning call per ``SCAN_BATCH`` (``Store.scan_probe_device`` —
    the megakernel on TPU, the jit'd StackedProbe fence+gather on CPU),
    and folds the per-op stats (runs touched, fence passes, data bytes a
    reader would fetch) into device scalar accumulators.  Nothing crosses
    back to the host until the final ``block_until_ready`` — the row
    measures device time, not Python dispatch."""
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(seed ^ 0xDE1CE)
    n_scans = max(int(OPS * SCAN_FRAC) // SCAN_BATCH, 1) * SCAN_BATCH
    lo = _scan_starts(n_scans, dist, data, rng)
    clo, chi = handle.encode_scan_bounds(lo, _scan_bounds(lo, dist))
    store = handle.store
    dbytes = jnp.asarray([r.data_bytes(store.cfg.value_bytes)
                          for r in store.live_runs()], jnp.int64)
    def step(acc, s):
        f, t = handle.scan_probe_device(clo[s:s + SCAN_BATCH],
                                        chi[s:s + SCAN_BATCH])
        return (acc[0] + t.sum(dtype=jnp.int64),
                acc[1] + f.sum(dtype=jnp.int64),
                acc[2] + (t.sum(axis=0, dtype=jnp.int64) * dbytes).sum())

    zero = (jnp.zeros((), jnp.int64),) * 3
    jax.block_until_ready(step(zero, 0))    # compile probe + accumulators
    acc = zero
    t0 = time.perf_counter()
    for s in range(0, n_scans, SCAN_BATCH):
        acc = step(acc, s)
    jax.block_until_ready(acc)
    dt = time.perf_counter() - t0
    touched, fenced, readable = acc
    return dt / n_scans * 1e6, {
        "scans": n_scans,
        "runs_probed_per_scan": float(touched) / n_scans,
        "fence_pass_per_scan": float(fenced) / n_scans,
        "bytes_touched_per_scan": float(readable) / n_scans,
    }


def metrics(handle, us_per_op: float) -> dict:
    s = handle.stats
    total_bytes = max(s.bytes_read + s.bytes_not_read, 1)
    return {
        "runs_probed_per_scan": s.runs_probed_per_scan,
        "scan_fp_read_rate": s.scan_fp_read_rate,
        "scan_filter_skips": s.scan_filter_skips,
        "scan_fence_skips": s.scan_fence_skips,
        "scans": s.scans,
        "runs_live": handle.n_runs,
        "compactions": s.compactions,
        "or_merges": s.or_merges,
        "rebuild_merges": s.rebuild_merges,
        "bytes_not_read_frac": s.bytes_not_read / total_bytes,
        "us_per_op": us_per_op,
    }


def _filter_positive_rate(store, keys: np.ndarray) -> float:
    """Fraction of absent keys the (fence AND filter) masks let through."""
    fence, filt = store.probe_runs(keys, keys, point=True)
    return float((fence & filt).any(axis=1).mean())


def run_churn_one(mutability: str, seed: int = 0x57043) -> tuple:
    """(typed store handle, churn metrics dict): load N keys, measure the
    absent-key FPR, run a 50/50 put/delete churn phase, re-measure.

    ``fpr_drift`` (post/pre FPR ratio) is the headline: the deletable
    store's purge/promote compaction washes dead keys' bits out, so its
    drift must stay bounded while the insert-only control accumulates
    every ever-inserted key forever.  The same seeded op stream drives
    both mutabilities, so the rows are directly comparable.
    """
    rng = np.random.default_rng(seed)
    handle = open_filter(FilterSpec(
        dtype="u32", placement="store", memtable_limit=MEMTABLE,
        level0_runs=LEVEL0, fanout=FANOUT, bits_per_key=BPK, delta=6,
        mutability=mutability, purge_dead_frac=CHURN_PURGE_DEAD))
    data = np.unique(_keys(N, "uniform", rng))
    live = {}
    for i, k in enumerate(data):
        handle.put(int(k), i)
        live[int(k)] = i
    handle.flush()
    absent = rng.integers(0, 1 << 31, 50_000, dtype=np.uint64)
    absent = absent[~np.isin(absent, data)]
    fpr0 = _filter_positive_rate(handle.store, absent)

    order = list(live)          # deletion order fixed by the seeded load
    drops = rng.random(CHURN_OPS) < CHURN_DELETE_FRAC
    t0 = time.perf_counter()
    deleted = 0
    for i in range(CHURN_OPS):
        if drops[i] and deleted < len(order):
            handle.delete(order[deleted])
            del live[order[deleted]]
            deleted += 1
        else:
            k = int(rng.integers(0, 1 << 31))
            handle.put(k, i)
            live[k] = i
    handle.flush()
    dt = time.perf_counter() - t0
    us = dt / max(CHURN_OPS, 1) * 1e6

    still_absent = absent[~np.isin(absent,
                                   np.fromiter(live, np.uint64, len(live)))]
    fpr1 = _filter_positive_rate(handle.store, still_absent)
    # post-churn scan pruning (the runs-probed-per-scan gate)
    lo = _scan_starts(SCAN_BATCH, "uniform", data, rng)
    handle.scan_many(lo, _scan_bounds(lo, "uniform"))
    s = handle.stats
    m = {
        "fpr_before": fpr0,
        "fpr_after": fpr1,
        "fpr_drift": fpr1 / max(fpr0, 1e-9),
        "runs_probed_per_scan": s.runs_probed_per_scan,
        "runs_live": handle.n_runs,
        "or_merges": s.or_merges,
        "rebuild_merges": s.rebuild_merges,
        "promote_merges": s.promote_merges,
        "purge_rebuilds": s.purge_rebuilds,
        "us_per_op": us,
    }
    return handle, m


def run_tune_one(tuning: str, seed: int = 0x57043) -> tuple:
    """(typed store handle, tune metrics): the §16 static-vs-adaptive twin.

    Both twins see the identical seeded op stream: load half the
    zipf-clustered keys, run the short-scan warm phase (zipf + correlated
    near misses — the workload the adaptive tuner samples), then load the
    rest so compactions graduate capacity classes (the retune point).
    ``observed_fpr`` re-probes ground-truth-empty ranges drawn from the
    *same* scan-start distribution through the run filters, so the
    adaptive row measures exactly what the tuner optimised for at equal
    bits per key; ``us_per_op`` times the post-retune scan phase."""
    rng = np.random.default_rng(seed ^ 0x7E4E)
    handle = open_filter(FilterSpec(
        dtype="u32", placement="store", memtable_limit=MEMTABLE,
        level0_runs=LEVEL0, fanout=FANOUT, bits_per_key=BPK, delta=6,
        tuning="adaptive" if tuning == "adaptive" else "auto"))
    data = _keys(TUNE_KEYS, "zipf", rng)
    half = len(data) // 2
    for i, k in enumerate(data[:half]):
        handle.put(int(k), i)
    handle.flush()
    # warm phase: the scans the tuner observes (and everyone answers)
    n_scans = max(TUNE_SCANS // SCAN_BATCH, 1) * SCAN_BATCH
    lo = _scan_starts(n_scans, "zipf", data[:half], rng)
    hi = _scan_bounds(lo, "zipf")
    for s in range(0, n_scans, SCAN_BATCH):
        handle.scan_many(lo[s:s + SCAN_BATCH], hi[s:s + SCAN_BATCH])
    # second load half: compactions fire -> class-graduating rebuilds
    # consult the solver and land in the tuned layout
    for i, k in enumerate(data[half:]):
        handle.put(int(k), half + i)
    handle.flush()
    # observed FPR on ground-truth-empty ranges from the same scan mix
    plo = _scan_starts(TUNE_FPR_PROBES, "zipf", data, rng)
    phi = _scan_bounds(plo, "zipf")
    srt = np.sort(data)
    idx = np.searchsorted(srt, plo)
    hit = (idx < len(srt)) & (srt[np.minimum(idx, len(srt) - 1)] <= phi)
    plo, phi = plo[~hit], phi[~hit]
    fence, filt = handle.store.probe_runs(plo, phi)
    observed_fpr = float((fence & filt).any(axis=1).mean())
    # timed post-retune scan phase (host path; same batching as warm).
    # One untimed batch first: the retuned stack's layouts are new to the
    # probe cache, and the static twin must not win on compile time alone.
    handle.scan_many(lo[:SCAN_BATCH], hi[:SCAN_BATCH])
    t0 = time.perf_counter()
    for s in range(0, n_scans, SCAN_BATCH):
        handle.scan_many(lo[s:s + SCAN_BATCH], hi[s:s + SCAN_BATCH])
    us = (time.perf_counter() - t0) / n_scans * 1e6
    s = handle.stats
    rep = handle.retune_report()
    m = {
        "observed_fpr": observed_fpr,
        "empty_probes": int(len(plo)),
        "retunes": int(rep.get("retunes", 0)),
        "retune_events": len(rep.get("events", [])),
        "workload_seen": int(rep.get("workload", {}).get("n_ranges", 0)),
        "runs_probed_per_scan": s.runs_probed_per_scan,
        "scan_fp_read_rate": s.scan_fp_read_rate,
        "runs_live": handle.n_runs,
        "filter_bits": handle.size_bits(),
        "us_per_op": us,
    }
    if tuning == "adaptive" and rep.get("cross_check"):
        cc = rep["cross_check"]
        if cc.get("calibration") is not None:
            m["calibration"] = float(cc["calibration"])
    return handle, m


def run_recovery(seed: int = 0x57043) -> dict:
    """``store/recovery`` metrics: the WAL write-path tax, reopen time,
    and the degraded-scan correctness drill (DESIGN.md §14).

    The same seeded put stream loads a WAL-off control store and a durable
    (``durability="wal"``) twin rooted in a temp dir; the us/op ratio is
    the append-before-ack tax the WAL charges every write (gated ≤1.3x in
    CI).  The durable twin then checkpoints, writes a post-checkpoint WAL
    tail, closes, and ``Store.open`` recovery is timed (snapshot restore
    + WAL replay).  The degraded drill snapshots the control, flips bits
    in one run's packed filter block, restores (checksum mismatch →
    quarantine, fence-only pruning for that row), and counts scan-result
    mismatches against the uncorrupted control — gated to exactly zero.
    """
    import copy
    import tempfile

    from repro.store import Store, StoreConfig
    from repro.store.faults import flip_filter_bits

    rng = np.random.default_rng(seed ^ 0x5EC0)
    keys = rng.integers(0, 1 << 31, RECOVERY_OPS, dtype=np.uint64)
    base = dict(d=32, memtable_limit=MEMTABLE, level0_runs=LEVEL0,
                fanout=FANOUT, bits_per_key=BPK, delta=6)

    def load(cfg):
        st = Store(cfg, _warn=False)
        t0 = time.perf_counter()
        for i, k in enumerate(keys):
            st.put(int(k), i)
        return st, (time.perf_counter() - t0) / len(keys) * 1e6

    # warm the flush/compaction jit cache so neither timed load pays compile
    warm = Store(StoreConfig(**base), _warn=False)
    for k in range(MEMTABLE + 1):
        warm.put(k, 0)

    ctrl, us_off = load(StoreConfig(**base))
    with tempfile.TemporaryDirectory() as wal_dir:
        st, us_on = load(StoreConfig(**base, durability="wal",
                                     wal_dir=wal_dir))
        st.checkpoint()
        tail = rng.integers(0, 1 << 31, max(RECOVERY_OPS // 20, 1),
                            dtype=np.uint64)
        for i, k in enumerate(tail):        # post-checkpoint WAL tail
            st.put(int(k), i)
        st.close()
        t0 = time.perf_counter()
        rec = Store.open(wal_dir)
        reopen_ms = (time.perf_counter() - t0) * 1e3
        replayed = rec.stats.wal_replayed
        rec.close()

    # degraded-scan drill: quarantined filter block must change nothing
    ctrl.flush()
    snap = ctrl.snapshot()
    hurt_snap = copy.deepcopy(snap)
    encs = [e for lvl in hurt_snap["levels"] for e in lvl if "filter" in e]
    victim = encs[int(rng.integers(0, len(encs)))]
    bad = flip_filter_bits(victim, rng, nbits=3)
    hurt_snap["levels"] = [[bad if e is victim else e for e in lvl]
                           for lvl in hurt_snap["levels"]]
    clean = Store.restore(snap)
    hurt = Store.restore(hurt_snap)
    lo = _scan_starts(RECOVERY_SCANS, "uniform", keys, rng)
    hi = _scan_bounds(lo, "uniform")
    mismatches = sum(a != b for a, b in zip(clean.scan_many(lo, hi),
                                            hurt.scan_many(lo, hi)))
    return {
        "wal_on_us_per_op": us_on,
        "wal_off_us_per_op": us_off,
        "wal_overhead": us_on / max(us_off, 1e-9),
        "reopen_ms": reopen_ms,
        "reopen_us_per_record": reopen_ms * 1e3 / max(replayed, 1),
        "wal_replayed": replayed,
        "quarantined_runs": len(hurt.quarantined_runs()),
        "degraded_probes": int(hurt.stats.degraded_probes),
        "degraded_scan_mismatches": int(mismatches),
    }


def run_obs(seed: int = 0x57043) -> tuple:
    """``store/obs`` rows: the zero-overhead gate + live FPR vs §6 model.

    ``(metrics, export_doc)``: one bloomrf/uniform store drives the
    device scan phase (``run_device_one``) with the obs plane off and on
    — min-of-3 each side, their ratio is the CI-gated overhead — then a
    host facade batch populates the latency histograms and the
    known-absent reservoir re-probe yields the live observed range FPR,
    gated against the §6 analytic model for the same run stack.  The
    export doc is the full ``bloomrf-metrics/v1`` snapshot
    (``--metrics PATH`` writes it; CI gates it via check_gates)."""
    from repro import obs
    from repro.core.model import basic_range_fpr

    rng = np.random.default_rng(seed ^ 0x0B5)
    handle, _, data = run_one("bloomrf", "uniform", seed)
    was_on = obs.enabled()
    obs.disable()
    us_off = min(run_device_one(handle, "uniform", data)[0]
                 for _ in range(3))
    obs.enable()
    try:
        handle.register_obs()
        us_on = min(run_device_one(handle, "uniform", data)[0]
                    for _ in range(3))
        overhead = us_on / max(us_off, 1e-9)
        # one host facade batch so the latency histograms have data
        lo = _scan_starts(SCAN_BATCH, "uniform", data, rng)
        handle.scan_many(lo, _scan_bounds(lo, "uniform"))
        # fresh known-absent reservoir at the scan width, ground-truth mode
        handle._fpr = None
        handle._fpr_sampler(range_len=RSIZE)
        fpr = handle.observed_fpr()
        # §6 model for the same stack: a scan passes when ANY live run's
        # filter fires, so the model FPR unions over the run pyramid
        cfg = handle.store.cfg
        miss = 1.0
        for r in handle.store.live_runs():
            miss *= 1.0 - basic_range_fpr(r.layout.d, len(r.keys),
                                          r.layout.total_bits, RSIZE,
                                          delta=cfg.delta)
        model = 1.0 - miss
        m = {
            "overhead_ratio": overhead,
            "us_per_op_obs_off": us_off,
            "us_per_op_obs_on": us_on,
            "observed_fpr": fpr.get("range_fpr", 0.0),
            "point_fpr": fpr.get("point_fpr", 0.0),
            "model_fpr": model,
            "range_candidates": fpr.get("range_candidates", 0),
            "runs_live": handle.n_runs,
        }
        doc = obs.export_snapshot(extra={
            "obs/overhead_ratio": overhead,
            "obs/fpr/observed": m["observed_fpr"],
            "obs/fpr/point": m["point_fpr"],
            "obs/fpr/model": model,
            "obs/fpr/range_candidates": m["range_candidates"],
        })
    finally:
        if not was_on:
            obs.disable()
    return m, doc


def run(section: dict | None = None, metrics_path: str | None = None):
    """Bench rows (+ per-setting metrics into ``section`` when given)."""
    rows = []
    for dist in DISTS:
        backends = FLOAT_BACKENDS if dist == "float" else BACKENDS
        for backend in backends:
            handle, host_us, data = run_one(backend, dist)
            m = metrics(handle, host_us)
            detail = (f"runs/scan={m['runs_probed_per_scan']:.3f};"
                      f"fp={m['scan_fp_read_rate']:.3f};"
                      f"runs={m['runs_live']};"
                      f"bytes_saved={m['bytes_not_read_frac']:.3f}")
            us = host_us
            if backend in DEVICE_BACKENDS:
                us, dm = run_device_one(handle, dist, data)
                m["us_per_op"] = us
                m["host_us_per_op"] = host_us
                m.update({f"device_{k}": v for k, v in dm.items()})
                detail += (f";host_us={host_us:.1f};"
                           f"dev_runs/scan={dm['runs_probed_per_scan']:.3f}")
            if section is not None:
                section[f"{dist}/{backend}"] = m
            rows.append(emit(f"store/{dist}/{backend}", us, detail))
    for tuning in ("static", "adaptive"):
        _, m = run_tune_one(tuning)
        if section is not None:
            section[f"tune/{tuning}"] = m
        rows.append(emit(
            f"store/tune/{tuning}", m["us_per_op"],
            f"fpr={m['observed_fpr']:.4f};"
            f"retunes={m['retunes']};"
            f"runs/scan={m['runs_probed_per_scan']:.3f};"
            f"bits={m['filter_bits']}"))
    for mutability in CHURN_MUTABILITIES:
        _, m = run_churn_one(mutability)
        if section is not None:
            section[f"churn/{mutability}"] = m
        rows.append(emit(
            f"store/churn/{mutability}", m["us_per_op"],
            f"fpr_drift={m['fpr_drift']:.3f};"
            f"fpr={m['fpr_after']:.4f};"
            f"runs/scan={m['runs_probed_per_scan']:.3f};"
            f"promote={m['promote_merges']};"
            f"purge={m['purge_rebuilds']}"))
    r = run_recovery()
    if section is not None:
        section["recovery"] = r
    rows.append(emit(
        "store/recovery/wal_on", r["wal_on_us_per_op"],
        f"overhead={r['wal_overhead']:.3f};"
        f"replayed={r['wal_replayed']}"))
    rows.append(emit(
        "store/recovery/wal_off", r["wal_off_us_per_op"],
        "wal-off control (same seeded put stream)"))
    rows.append(emit(
        "store/recovery/reopen", r["reopen_us_per_record"],
        f"reopen_ms={r['reopen_ms']:.1f};"
        f"quarantined={r['quarantined_runs']};"
        f"degraded_mismatches={r['degraded_scan_mismatches']}"))
    om, doc = run_obs()
    if section is not None:
        section["obs"] = om
    if metrics_path:
        import json
        with open(metrics_path, "w") as f:
            json.dump(doc, f, indent=1)
    rows.append(emit(
        "store/obs/overhead", om["us_per_op_obs_on"],
        f"ratio={om['overhead_ratio']:.3f};"
        f"off_us={om['us_per_op_obs_off']:.2f}"))
    rows.append(emit(
        "store/obs/observed_fpr", om["observed_fpr"],
        f"model={om['model_fpr']:.4f};"
        f"point={om['point_fpr']:.4f};"
        f"candidates={om['range_candidates']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes (benchmarks.run's smoke registry)")
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write the bloomrf-metrics/v1 observability "
                         "snapshot (registry + observed FPR + overhead "
                         "ratio) for check_gates --only obs_metrics")
    args = ap.parse_args()
    if args.smoke:
        from . import run as run_mod
        for attr, val in run_mod.SMOKE["store"].items():
            globals()[attr] = val
    section: dict = {}
    print("name,us_per_call,derived")
    rows = run(section, metrics_path=args.metrics)
    if args.json:
        write_json(args.json, SCHEMA, rows, value_key="us_per_op",
                   smoke=args.smoke, store=section,
                   config={"N": N, "OPS": OPS, "memtable": MEMTABLE,
                           "level0": LEVEL0, "fanout": FANOUT, "bpk": BPK,
                           "rsize": RSIZE, "scan_frac": SCAN_FRAC})


if __name__ == "__main__":
    main()
