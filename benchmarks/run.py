"""Benchmark harness: one module per paper table/figure (DESIGN.md §7).
Prints ``name,us_per_call,derived`` CSV rows.

Usage: PYTHONPATH=src python -m benchmarks.run [--only fig09,fig12]
           [--smoke] [--json PATH]

``--smoke`` shrinks key/query counts and sweep grids to CI-friendly sizes;
``--json`` writes every emitted row as machine-readable JSON (the CI
``bench-smoke`` job uploads it as the ``BENCH_CI.json`` artifact and fails
on malformed output).
"""
import argparse
import sys
import time

SCHEMA = "bloomrf-bench/v1"

# Per-module constant overrides applied by --smoke.  Only attributes the
# module actually defines are patched, so a rename fails loudly in CI
# (the run falls back to full size and blows the job timeout) rather than
# silently benchmarking the wrong thing.
SMOKE = {
    "fig08": {"N": 100_000},
    "fig09": {"N": 20_000, "Q": 2_000, "DISTS": ("uniform",),
              "RLOG2S": (2, 10)},
    "fig10": {"N": 20_000, "Q": 2_000, "BPKS": (10, 18)},
    "fig11": {"Q": 1_000, "NS": (10_000,), "DISTS": ("uniform",),
              "BPKS": (16,), "RLOG2S": (10,)},
    "fig12": {"N": 20_000, "Q": 2_000, "MIX_OPS": 4_000, "LOOKUPS": 10_000},
    "kernels": {"N": 100_000, "Q": 50_000},
    "store": {"N": 20_000, "OPS": 2_000, "MEMTABLE": 800, "SCAN_BATCH": 256,
              "BACKENDS": ("bloomrf", "none", "prefix_bloom"),
              "CHURN_OPS": 8_000, "RECOVERY_OPS": 6_000,
              "TUNE_KEYS": 16_000, "TUNE_SCANS": 512,
              "TUNE_FPR_PROBES": 2_000},
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module name filter")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI / quick local sanity runs")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write emitted rows as JSON to PATH")
    ap.add_argument("--trajectory", default="BENCH_TRAJECTORY.jsonl",
                    metavar="PATH",
                    help="bench-trend JSONL appended to on every --json "
                         "run ('' disables); check_gates.py trajectory "
                         "fails on monotone regression over the last rows")
    args = ap.parse_args()

    from . import (fig08_space, fig09_ranges, fig10_space_budget,
                   fig11_holistic, fig12_online_and_more, kernels_bench,
                   roofline_report, store_bench)
    modules = [
        ("fig08", fig08_space), ("fig09", fig09_ranges),
        ("fig10", fig10_space_budget), ("fig11", fig11_holistic),
        ("fig12", fig12_online_and_more), ("kernels", kernels_bench),
        ("store", store_bench), ("roofline", roofline_report),
    ]
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    rows = []
    t0 = time.time()
    for name, mod in modules:
        if only and name not in only:
            continue
        if args.smoke:
            for attr, val in SMOKE.get(name, {}).items():
                if hasattr(mod, attr):
                    setattr(mod, attr, val)
        print(f"# --- {name} ---", file=sys.stderr)
        rows.extend(mod.run() or [])
    elapsed = time.time() - t0
    print(f"# total {elapsed:.1f}s", file=sys.stderr)
    if args.json:
        from .common import append_trajectory, write_json
        write_json(args.json, SCHEMA, rows, smoke=args.smoke,
                   only=sorted(only) if only else None, elapsed_s=elapsed)
        if args.trajectory:
            append_trajectory(args.trajectory, rows, args.smoke)


if __name__ == "__main__":
    main()
