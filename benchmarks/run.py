"""Benchmark harness: one module per paper table/figure (DESIGN.md §7).
Prints ``name,us_per_call,derived`` CSV rows.

Usage: PYTHONPATH=src python -m benchmarks.run [--only fig09,fig12]
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module name filter")
    args = ap.parse_args()

    from . import (fig08_space, fig09_ranges, fig10_space_budget,
                   fig11_holistic, fig12_online_and_more, kernels_bench,
                   roofline_report)
    modules = [
        ("fig08", fig08_space), ("fig09", fig09_ranges),
        ("fig10", fig10_space_budget), ("fig11", fig11_holistic),
        ("fig12", fig12_online_and_more), ("kernels", kernels_bench),
        ("roofline", roofline_report),
    ]
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, mod in modules:
        if only and name not in only:
            continue
        print(f"# --- {name} ---", file=sys.stderr)
        mod.run()
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
