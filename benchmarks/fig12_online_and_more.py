"""Fig. 12 panels:
  A  — online behavior: mixed insert/lookup throughput at varying ratios;
  C  — filter construction (build + serialize) time;
  D  — floating-point keys (monotone codec) FPR across budgets;
  E  — point-query FPR vs BF / cuckoo fingerprint sizes;
  F  — dual-attribute filter vs two single-attribute filters;
  G  — probe cost breakdown (word accesses/query; point vs range).
"""
import io
import time

import numpy as np

from repro.core.codecs import (float64_to_u64, multiattr_insert_codes,
                               multiattr_range_for_a_eq_b_range)
from repro.filters import (BloomFilter, BloomRFAdapter, CuckooFilter,
                           Rosetta, SuRFLite)

from .common import (emit, gen_empty_ranges, gen_keys, measure_point,
                     measure_range)

N = 200_000
Q = 10_000
MIX_OPS = 20_000   # insert/lookup ops per fig12a ratio setting
LOOKUPS = 50_000


def fig12a_online(rows, rng):
    keys = gen_keys(N, "uniform", rng)
    f = BloomRFAdapter(16, mode="basic")
    f.build(keys[:1000])  # warm start
    lookups = gen_keys(LOOKUPS, "uniform", rng)
    for ratio in (0.0, 0.25, 0.5, 0.75):
        n_ins = int(MIX_OPS * ratio)
        n_look = MIX_OPS - n_ins
        t0 = time.perf_counter()
        if n_ins:
            f.insert_more(keys[1000:1000 + n_ins])
        if n_look:
            f.point(lookups[:n_look])
        dt = time.perf_counter() - t0
        rows.append(emit(f"fig12a/insert_ratio={ratio}/bloomRF",
                         dt / MIX_OPS * 1e6, f"{MIX_OPS / dt:.0f} ops/s"))


def fig12c_construction(rows, rng):
    keys = gen_keys(N, "uniform", rng)
    for name, f in [("bloomRF", BloomRFAdapter(18, mode="basic")),
                    ("rosetta", Rosetta(18, max_range_log2=10)),
                    ("surf", SuRFLite.for_budget(18)),
                    ("BF", BloomFilter(18))]:
        t0 = time.perf_counter()
        f.build(keys)
        build = time.perf_counter() - t0
        t0 = time.perf_counter()
        buf = io.BytesIO()  # serialization analogue of the SST filter block
        state = getattr(f, "state", None)
        np.save(buf, np.asarray(state) if state is not None
                else np.zeros(1))
        ser = time.perf_counter() - t0
        rows.append(emit(f"fig12c/{name}", build / N * 1e6,
                         f"build={build:.3f}s;serialize={ser:.4f}s"))


def fig12d_floats(rows, rng):
    # synthetic flux time series (NASA Kepler-like): values in [-1e3, 1e3]
    vals = rng.normal(0, 100, N).astype(np.float64)
    keys = float64_to_u64(vals)
    for bpk in (10, 16, 22):
        f = BloomRFAdapter(bpk, mode="tuned", R=2.0 ** 40)
        f.build(keys)
        qlo = rng.uniform(-500, 500, Q)
        lo = float64_to_u64(qlo)
        hi = float64_to_u64(qlo + 1e-3)
        ks = np.sort(keys)
        idx = np.searchsorted(ks, lo)
        truth = (idx < len(ks)) & (ks[np.minimum(idx, len(ks) - 1)] <= hi)
        fpr, us = measure_range(f, keys, lo, hi, truth)
        rows.append(emit(f"fig12d/floats/bpk={bpk}/bloomRF", us,
                         f"{fpr:.4f}"))


def fig12e_point(rows, rng):
    keys = gen_keys(N, "uniform", rng)
    pq = np.concatenate([keys[:Q // 2], gen_keys(Q, "uniform", rng)])
    truth = np.isin(pq, keys)
    for name, f in [("BF-10", BloomFilter(10)),
                    ("cuckoo-f8", CuckooFilter(8)),
                    ("cuckoo-f12", CuckooFilter(12)),
                    ("bloomRF-10", BloomRFAdapter(10, mode="basic")),
                    ("surf-hash", SuRFLite(suffix_bits=8, mode="hash"))]:
        f.build(keys)
        fpr, us = measure_point(f, keys, pq, truth)
        rows.append(emit(f"fig12e/{name}", us,
                         f"{fpr:.5f};bpk={f.size_bits()/N:.1f}"))


def fig12f_multiattr(rows, rng):
    # SDSS-like: Run (normal-ish, reduced precision) and ObjectID
    run_attr = np.abs(rng.normal(400, 150, N)).astype(np.uint64)
    obj_attr = rng.integers(0, 1 << 31, N, dtype=np.uint64)
    ab, ba = multiattr_insert_codes(obj_attr, run_attr)
    dual = BloomRFAdapter(16, mode="tuned", R=2.0 ** 32)
    dual.build(np.concatenate([ab, ba]))
    fa = BloomRFAdapter(16, mode="basic")
    fa.build(run_attr)
    fb = BloomRFAdapter(16, mode="basic")
    fb.build(obj_attr)
    qs = rng.integers(0, 1 << 31, Q, dtype=np.uint64)  # ObjectID = const
    # predicate: Run < 300 AND ObjectID = q  ->  range on <ObjectID, Run>
    lo, hi = multiattr_range_for_a_eq_b_range(qs, np.uint64(0),
                                              np.uint64(299))
    res_dual = dual.range(lo, hi)
    res_sep = fb.point(qs)  # Run<300 filter alone is ~always true
    ks = np.sort(ab)
    idx = np.searchsorted(ks, lo)
    truth = (idx < len(ks)) & (ks[np.minimum(idx, len(ks) - 1)] <= hi)
    for name, res in (("dual", res_dual), ("separate", res_sep)):
        assert not (truth & ~res).any()
        fpr = (res & ~truth).sum() / max((~truth).sum(), 1)
        rows.append(emit(f"fig12f/{name}", 0.0, f"{fpr:.4f}"))


def fig12g_cost(rows, rng):
    keys = gen_keys(N, "uniform", rng)
    f = BloomRFAdapter(22, mode="basic")
    f.build(keys)
    inner = f.filter
    rows.append(emit("fig12g/word_accesses/point", 0.0,
                     inner.word_accesses_per_point_query()))
    rows.append(emit("fig12g/word_accesses/range", 0.0,
                     inner.word_accesses_per_range_query()))
    lo, hi, truth = gen_empty_ranges(keys, Q, 2 ** 12, "uniform", rng)
    _, us_r = measure_range(f, keys, lo, hi, truth)
    pq = gen_keys(Q, "uniform", rng)
    _, us_p = measure_point(f, keys, pq, np.isin(pq, keys))
    rows.append(emit("fig12g/probe_us/point", us_p, "cpu-xla"))
    rows.append(emit("fig12g/probe_us/range", us_r, "cpu-xla"))


def run():
    rows = []
    rng = np.random.default_rng(12)
    fig12a_online(rows, rng)
    fig12c_construction(rows, rng)
    fig12d_floats(rows, rng)
    fig12e_point(rows, rng)
    fig12f_multiattr(rows, rng)
    fig12g_cost(rows, rng)
    return rows


if __name__ == "__main__":
    run()
