"""Fig. 8: space (bits/key) vs FPR — bloomRF model, Rosetta first-cut model,
and the theoretical lower bounds (Carter point / Goswami range)."""
import numpy as np

from repro.core.model import (basic_point_fpr, basic_space_for_fpr,
                              point_lower_bound_space,
                              range_lower_bound_space, rosetta_space_for_fpr)

from .common import emit

N = 10_000_000
D = 64


def run():
    rows = []
    # point queries (Fig. 8a)
    for eps in (0.1, 0.03, 0.01, 0.003, 0.001):
        lb = point_lower_bound_space(N, eps) / N
        rows.append(emit(f"fig08/point/eps={eps}/lower_bound", 0.0, f"{lb:.2f}"))
        # bloomRF point: invert eps = (1-p)^k via scan over m
        for bpk in np.arange(6, 30, 0.5):
            if basic_point_fpr(D, N, bpk * N) <= eps:
                rows.append(emit(f"fig08/point/eps={eps}/bloomRF", 0.0,
                                 f"{bpk:.2f}"))
                break
    # range queries (Fig. 8b), R = 16/32/64
    for R in (16, 32, 64):
        for eps in (0.1, 0.03, 0.01, 0.003):
            lb = range_lower_bound_space(N, eps, R, D) / N
            ros = rosetta_space_for_fpr(N, eps, R) / N
            brf = basic_space_for_fpr(D, N, eps, R) / N
            rows.append(emit(f"fig08/range/R={R}/eps={eps}/lower_bound", 0.0,
                             f"{lb:.2f}"))
            rows.append(emit(f"fig08/range/R={R}/eps={eps}/rosetta", 0.0,
                             f"{ros:.2f}"))
            rows.append(emit(f"fig08/range/R={R}/eps={eps}/bloomRF", 0.0,
                             f"{brf:.2f}"))
    return rows


if __name__ == "__main__":
    run()
