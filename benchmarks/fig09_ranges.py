"""Fig. 9: FPR + probe latency across query-range sizes and workload
distributions at a fixed 22 bits/key budget (the paper's favorable setting)."""
import numpy as np

from repro.filters import (BloomRFAdapter, FencePointers, PrefixBloomFilter,
                           Rosetta, SuRFLite)

from .common import emit, gen_empty_ranges, gen_keys, measure_range

N = 200_000
Q = 10_000
BPK = 22.0
DISTS = ("uniform", "normal", "zipf")
RLOG2S = (2, 6, 10, 14, 18, 24, 30)


def _filters(rlog2):
    return [
        ("bloomRF", BloomRFAdapter(BPK, R=2.0 ** rlog2, mode="auto")),
        ("rosetta", Rosetta(BPK, max_range_log2=min(rlog2, 16))),
        ("surf", SuRFLite.for_budget(BPK)),
        ("prefixBF", PrefixBloomFilter(BPK, prefix_level=max(rlog2 - 1, 1))),
        ("minmax", FencePointers(BPK)),
    ]


def run():
    rows = []
    rng = np.random.default_rng(9)
    keys = gen_keys(N, "uniform", rng)
    for wdist in DISTS:
        for rlog2 in RLOG2S:
            lo, hi, truth = gen_empty_ranges(keys, Q, 2 ** rlog2, wdist, rng)
            for name, f in _filters(rlog2):
                f.build(keys)
                fpr, us = measure_range(f, keys, lo, hi, truth)
                rows.append(emit(
                    f"fig09/{wdist}/R=2^{rlog2}/{name}", us, f"{fpr:.4f}"))
    return rows


if __name__ == "__main__":
    run()
