"""Benchmark package: make ``src/`` importable before any submodule pulls
in ``repro`` (so ``python -m benchmarks.run`` works without PYTHONPATH)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("JAX_ENABLE_X64", "1")
