"""Render the dry-run roofline table (results/dryrun.json) as CSV rows and
derive MODEL_FLOPS / usefulness ratios per cell (EXPERIMENTS.md §Roofline)."""
import json
import os

from repro.configs import get_config
from repro.models import SHAPES, get_model
from repro.models.params import count_params

from .common import emit

PEAK_FLOPS = 197e12

_RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun.json")


def model_flops(arch: str, shape_name: str) -> float:
    """6·N(_active)·D for train; 2·N_active·tokens for a decode step."""
    cfg = get_config(arch)
    model = get_model(cfg)
    n = count_params(model.table())
    if cfg.family == "moe":
        # active params: replace expert count with experts_per_token
        dense_share = n - (cfg.n_experts * 3 * cfg.d_model * cfg.d_ff *
                           cfg.n_layers)
        n = dense_share + (cfg.experts_per_token * 3 * cfg.d_model *
                           cfg.d_ff * cfg.n_layers)
    shape = SHAPES[shape_name]
    tokens = shape.batch * (shape.seq if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def run():
    rows = []
    if not os.path.exists(_RESULTS):
        rows.append(emit("roofline/missing", 0.0,
                         "run repro.launch.dryrun first"))
        return rows
    recs = json.load(open(_RESULTS))
    for r in recs:
        tag = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r["status"] != "ok":
            rows.append(emit(tag, 0.0, r["status"]))
            continue
        t = r["roofline_terms_s"]
        mf = model_flops(r["arch"], r["shape"])
        hlo_global = r["flops_per_device"] * r["chips"]
        useful = mf / max(hlo_global, 1.0)
        bound = max(t.values())
        frac = t["compute_s"] / max(bound, 1e-12)
        rows.append(emit(
            tag, bound * 1e6,
            f"dom={r['dominant'][:-2]};roofline_frac={frac:.3f};"
            f"useful_flops={useful:.2f};comp={t['compute_s']:.3e};"
            f"mem={t['memory_s']:.3e};coll={t['collective_s']:.3e}"))
    return rows


if __name__ == "__main__":
    run()
