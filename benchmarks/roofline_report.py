"""Render the dry-run roofline table (results/dryrun.json) as CSV rows and
derive MODEL_FLOPS / usefulness ratios per cell (EXPERIMENTS.md §Roofline).

Also emits the analytic ``roofline/store_scan/megakernel`` row (no dry-run
needed): the store-scan Pallas kernel's arithmetic intensity over a
representative LSM run pyramid, demonstrating the kernel is
bandwidth-bound — its per-batch filter-state DMA dominates its
compare/gather flops by orders of magnitude, so fusing the scan plane
into one kernel (PR 7) buys exactly what the roofline says it should:
the HBM streaming time, with the Python/dispatch time gone."""
import json
import os

from repro.configs import get_config
from repro.models import SHAPES, get_model
from repro.models.params import count_params

from .common import emit

PEAK_FLOPS = 197e12
PEAK_HBM_BPS = 1.2e12           # HBM bandwidth model constant (bytes/s)

_RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun.json")


def store_scan_entry():
    """Analytic roofline row for the store-scan megakernel.

    Models the fused kernel's per-batch traffic and flops over the LSM
    pyramid the YCSB-E bench builds (4 level-0 runs at class 0 plus one
    run each at the next two classes, ``SCAN_BATCH`` queries per batch):

    * bytes  — every run's padded filter row streams HBM->VMEM once per
      query tile (the flash-decoding grid), plus fences, bounds, and the
      two bool output planes;
    * flops  — each query gathers ``range_gather_width`` lanes per run
      and combines them with a handful of mask/compare ops per lane.

    The resulting intensity is a few flops per byte — far below any
    TPU's compute/bandwidth ridge — so the memory term dominates and the
    kernel is bandwidth-bound by construction."""
    from repro.core import basic_layout
    from repro.core.engine import ProbeEngine, _filter_for_layout

    from . import store_bench as sb

    classes = [0, 0, 0, 0, 1, 2]            # representative run pyramid
    layouts = [basic_layout(32, sb.MEMTABLE * sb.FANOUT ** c, sb.BPK,
                            delta=6) for c in classes]
    B, tile = sb.SCAN_BATCH, 256
    rowpad = max(lay.total_u32 for lay in layouts)
    R = len(layouts)
    q_tiles = max(B // tile, 1)
    bytes_moved = (q_tiles * R * rowpad * 4    # filter blocks, once/tile
                   + 2 * R * 4                 # kmin/kmax fences
                   + 2 * B * 4                 # lo/hi bounds
                   + 2 * B * R)                # fence+touch outputs
    lanes = sum(ProbeEngine(_filter_for_layout(lay)).range_gather_width
                for lay in layouts)
    flops = B * lanes * 6                      # shift/mask/cmp/or per lane
    t_mem = bytes_moved / PEAK_HBM_BPS
    t_comp = flops / PEAK_FLOPS
    bound = max(t_mem, t_comp)
    return emit(
        "roofline/store_scan/megakernel", bound * 1e6,
        f"dom={'memory' if t_mem >= t_comp else 'compute'};"
        f"intensity={flops / bytes_moved:.3f}flop/B;"
        f"mem={t_mem:.3e};comp={t_comp:.3e};"
        f"runs={R};rowpad_u32={rowpad};batch={B}")


def model_flops(arch: str, shape_name: str) -> float:
    """6·N(_active)·D for train; 2·N_active·tokens for a decode step."""
    cfg = get_config(arch)
    model = get_model(cfg)
    n = count_params(model.table())
    if cfg.family == "moe":
        # active params: replace expert count with experts_per_token
        dense_share = n - (cfg.n_experts * 3 * cfg.d_model * cfg.d_ff *
                           cfg.n_layers)
        n = dense_share + (cfg.experts_per_token * 3 * cfg.d_model *
                           cfg.d_ff * cfg.n_layers)
    shape = SHAPES[shape_name]
    tokens = shape.batch * (shape.seq if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def run():
    rows = [store_scan_entry()]
    if not os.path.exists(_RESULTS):
        rows.append(emit("roofline/missing", 0.0,
                         "run repro.launch.dryrun first"))
        return rows
    recs = json.load(open(_RESULTS))
    for r in recs:
        tag = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r["status"] != "ok":
            rows.append(emit(tag, 0.0, r["status"]))
            continue
        t = r["roofline_terms_s"]
        mf = model_flops(r["arch"], r["shape"])
        hlo_global = r["flops_per_device"] * r["chips"]
        useful = mf / max(hlo_global, 1.0)
        bound = max(t.values())
        frac = t["compute_s"] / max(bound, 1e-12)
        rows.append(emit(
            tag, bound * 1e6,
            f"dom={r['dominant'][:-2]};roofline_frac={frac:.3f};"
            f"useful_flops={useful:.2f};comp={t['compute_s']:.3e};"
            f"mem={t['memory_s']:.3e};coll={t['collective_s']:.3e}"))
    return rows


if __name__ == "__main__":
    run()
