"""Fig. 11: holistic best-filter map over (n keys x budget x range x data
distribution) — which PRF wins each cell (and by how much)."""
import numpy as np

from repro.filters import BloomRFAdapter, Rosetta, SuRFLite

from .common import emit, gen_empty_ranges, gen_keys, measure_range

Q = 4_000
NS = (10_000, 100_000, 1_000_000)
DISTS = ("uniform", "normal", "zipf")
BPKS = (10, 16, 22)
RLOG2S = (4, 10, 16)


def run():
    rows = []
    rng = np.random.default_rng(11)
    for n in NS:
        for dist in DISTS:
            keys = gen_keys(n, dist, rng)
            for bpk in BPKS:
                for rlog2 in RLOG2S:
                    lo, hi, truth = gen_empty_ranges(keys, Q, 2 ** rlog2,
                                                     dist, rng)
                    results = {}
                    for name, f in [
                        ("bloomRF", BloomRFAdapter(bpk, R=2.0 ** rlog2,
                                                   mode="auto")),
                        ("rosetta", Rosetta(bpk,
                                            max_range_log2=min(rlog2, 14))),
                        ("surf", SuRFLite.for_budget(bpk)),
                    ]:
                        f.build(keys)
                        fpr, _ = measure_range(f, keys, lo, hi, truth)
                        results[name] = fpr
                    best = min(results, key=results.get)
                    second = sorted(results.values())[1]
                    delta = second - results[best]
                    rows.append(emit(
                        f"fig11/n={n}/{dist}/bpk={bpk}/R=2^{rlog2}",
                        0.0, f"best={best};fpr={results[best]:.4f};"
                             f"margin={delta:.4f}"))
    return rows


if __name__ == "__main__":
    run()
