"""Generate the §Dry-run / §Roofline tables of EXPERIMENTS.md from
results/dryrun_final.json (static sections live in the template below)."""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config          # noqa: E402
from repro.models import SHAPES, get_model    # noqa: E402
from repro.models.params import count_params  # noqa: E402

PEAK = 197e12


def model_flops(arch, shape_name):
    cfg = get_config(arch)
    n = count_params(get_model(cfg).table())
    if cfg.family == "moe":
        dense_share = n - (cfg.n_experts * 3 * cfg.d_model * cfg.d_ff *
                           cfg.n_layers)
        n = dense_share + (cfg.experts_per_token * 3 * cfg.d_model *
                           cfg.d_ff * cfg.n_layers)
    shape = SHAPES[shape_name]
    tokens = shape.batch * (shape.seq if shape.kind != "decode" else 1)
    return (6.0 if shape.kind == "train" else 2.0) * n * tokens


def table(recs, mesh):
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | roofline frac | useful FLOPs |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"SKIPPED ({r.get('note','')[:40]}) | — | — |")
            continue
        t = r["roofline_terms_s"]
        bound = max(t.values())
        frac = t["compute_s"] / max(bound, 1e-12)
        mf = model_flops(r["arch"], r["shape"])
        useful = mf / max(r["flops_per_device"] * r["chips"], 1.0)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
            f"{r['dominant'][:-2]} | {frac:.3f} | {useful:.2f} |")
    return "\n".join(rows)


def main(path="results/dryrun_final.json"):
    recs = json.load(open(path))
    ok = [r for r in recs if r["status"] == "ok"]
    print(f"{len(ok)} ok / {len(recs)} cells")
    single = table(recs, "single")
    multi = table(recs, "multi")
    open("results/roofline_single.md", "w").write(single)
    open("results/roofline_multi.md", "w").write(multi)
    # compact per-cell dry-run facts
    lines = []
    for r in ok:
        mem = r.get("memory_analysis") or {}
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
            f"{r['flops_per_device']:.2e} | {r['bytes_per_device']:.2e} | "
            f"{r['collective_bytes_per_device']:.2e} | "
            f"{r['compile_s']:.0f}s |")
    open("results/dryrun_table.md", "w").write(
        "| arch | shape | mesh | chips | FLOPs/dev | bytes/dev | "
        "coll bytes/dev | compile |\n|---|---|---|---|---|---|---|---|\n" +
        "\n".join(lines))
    print("wrote results/roofline_single.md, roofline_multi.md, "
          "dryrun_table.md")


if __name__ == "__main__":
    main(*sys.argv[1:])
