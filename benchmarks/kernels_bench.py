"""Standalone filter-op throughput (the paper's probe-latency axis) on the
jitted XLA path, plus Pallas-kernel validation timing in interpret mode.

On this CPU container the XLA path is the performance-relevant number; the
Pallas kernels target TPU (validated bit-identical in interpret mode —
tests/test_kernels.py) and are benchmarked here only for dispatch overhead
sanity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import FilterSpec, open_filter

from .common import emit, gen_keys, timeit as _time

N = 1_000_000
Q = 200_000


def run():
    rows = []
    rng = np.random.default_rng(13)
    # the production path: the typed façade opens the same basic layout
    # the pre-façade driver hand-built (u32, 16 b/key, Δ=6, default seed)
    h = open_filter(FilterSpec(dtype="u32", n=N, bits_per_key=16.0,
                               delta=6, backend="xla"))
    keys = gen_keys(N, "uniform", rng).astype(np.uint32)
    h.insert(keys)
    f, state = h.filter, h.state

    qs = jnp.asarray(gen_keys(Q, "uniform", rng).astype(np.uint32))
    point = jax.jit(f.point)
    dt = _time(point, state, qs)
    rows.append(emit("kernels/point_probe_xla", dt / Q * 1e6,
                     f"{Q/dt/1e6:.2f} Mop/s"))

    lo = jnp.asarray(gen_keys(Q, "uniform", rng).astype(np.uint32))
    hi = lo + jnp.uint32(1 << 12)
    hi = jnp.maximum(lo, hi)
    rquery = jax.jit(f.range)
    dt = _time(rquery, state, lo, hi)
    rows.append(emit("kernels/range_probe_xla", dt / Q * 1e6,
                     f"{Q/dt/1e6:.2f} Mop/s"))

    ins = jax.jit(f.insert)
    dt = _time(ins, state, qs)
    rows.append(emit("kernels/bulk_insert_xla", dt / Q * 1e6,
                     f"{Q/dt/1e6:.2f} Mop/s"))
    return rows


if __name__ == "__main__":
    run()
