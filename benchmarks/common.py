"""Shared benchmark utilities: datasets, workloads, timing, FPR measurement,
and the machine-readable JSON emitters the CI gates consume.

Benchmarks mirror the paper's standalone methodology (§9): build a filter
over n keys, issue Q range- (or point-) queries of a fixed size per setting,
and report FPR over empty queries + mean probe latency.  Distributions:
uniform / normal / zipfian for both data and queries (Fig. 9/11).

``timeit`` and ``write_json`` are the single copies of the warm-up-once
timing loop and the ``{schema, rows: [{name, <value>, <detail>}]}`` JSON
shape previously duplicated across the bench drivers — every driver
(``run.py``, ``dist_bench.py``, ``store_bench.py``) routes through them so
the CI validators keep one contract.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("JAX_ENABLE_X64", "1")

U64MAX = np.uint64(0xFFFFFFFFFFFFFFFF)


def timeit(fn, *args, repeat: int = 3) -> float:
    """Seconds per call: warm up exactly once (compile + drain), then the
    mean of ``repeat`` timed calls (block_until_ready handles pytrees)."""
    import jax

    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(repeat):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeat


def write_json(path: str, schema: str, rows, value_key: str = "us_per_call",
               detail_key: str = "derived", **extra) -> None:
    """Write ``(name, value, detail)`` rows as the CI benchmark JSON shape."""
    payload = {
        "schema": schema,
        **extra,
        "rows": [{"name": n, value_key: float(u), detail_key: str(d)}
                 for n, u, d in rows],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)


def append_trajectory(path: str, rows, smoke: bool) -> None:
    """Append one timestamped metrics row to the bench-trend JSONL.

    Every ``run.py --json`` invocation adds ``{ts, schema, smoke,
    metrics: {row name: value}}``; ``check_gates.py trajectory`` diffs the
    last N rows and fails on monotone regression — the slow-creep drift a
    single committed baseline can never catch."""
    row = {
        "schema": "bloomrf-trajectory/v1",
        "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "smoke": bool(smoke),
        "metrics": {n: float(u) for n, u, _ in rows},
    }
    with open(path, "a") as f:
        f.write(json.dumps(row) + "\n")


def gen_keys(n: int, dist: str, rng: np.random.Generator) -> np.ndarray:
    if dist == "uniform":
        return rng.integers(0, 1 << 63, n, dtype=np.uint64)
    if dist == "normal":
        x = rng.normal(0.5, 0.1, n)
        return (np.clip(x, 0, 1) * float(1 << 62)).astype(np.uint64)
    if dist == "zipf":
        z = rng.zipf(1.2, n).astype(np.float64)
        z = z / (z.max() + 1.0)
        jitter = rng.integers(0, 1 << 32, n, dtype=np.uint64)
        return (z * float(1 << 62)).astype(np.uint64) + jitter
    raise ValueError(dist)


def gen_empty_ranges(keys: np.ndarray, q: int, rsize: int, dist: str,
                     rng: np.random.Generator):
    """Query ranges (mostly empty — the paper's worst case) + truth mask."""
    lo = gen_keys(q, dist, rng)
    hi = lo + np.uint64(max(rsize - 1, 0))
    hi = np.maximum(hi, lo)  # wrap guard
    ks = np.sort(keys)
    idx = np.searchsorted(ks, lo)
    truth = (idx < len(ks)) & (ks[np.minimum(idx, len(ks) - 1)] <= hi)
    return lo, hi, truth


def measure_range(f, keys, lo, hi, truth):
    t0 = time.perf_counter()
    res = f.range(lo, hi)
    dt = time.perf_counter() - t0
    fn = int((truth & ~res).sum())
    assert fn == 0, f"{type(f).__name__}: {fn} range false negatives"
    empties = max(int((~truth).sum()), 1)
    fpr = float((res & ~truth).sum()) / empties
    return fpr, dt / len(lo) * 1e6  # us/query


def measure_point(f, keys, qs, truth):
    t0 = time.perf_counter()
    res = f.point(qs)
    dt = time.perf_counter() - t0
    assert not (truth & ~res).any()
    empties = max(int((~truth).sum()), 1)
    fpr = float((res & ~truth).sum()) / empties
    return fpr, dt / len(qs) * 1e6


def emit(name: str, us_per_call, derived):
    print(f"{name},{us_per_call:.3f},{derived}")
    return (name, us_per_call, derived)
