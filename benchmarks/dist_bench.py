"""Sharded / multi-tenant filter-bank probe throughput vs the single-device
paths, plus the Bloofi-style meta-filter skip-rate measurement.

Compares, at fixed total key count and bits/key:
  * core       — one monolithic BloomRF (XLA, the ops.py fallback path)
  * kernel     — one monolithic filter through the Pallas resident kernels
  * bank       — FilterBank (range-partitioned, vmap on one device)
  * sharded    — ShardedFilterBank over every host device (shard_map)
  * tenant     — TenantFilterBank (vmapped multi-tenant reference)
  * tenant-sharded / tenant-replicated — shard_map variants, tenant rows on
    a data axis, optionally state replicated over a replica axis
and reports the meta-filter skip rate: the fraction of candidate
(probe, shard) pairs whose clipped sub-range the coarse per-shard filter
proves empty, together with the implied word-access saving per range probe.

Run with faked devices to see the scaling shape on CPU:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python benchmarks/dist_bench.py --shards 8 --queries 200000

Output: csv ``name,us_per_query,detail`` rows (benchmarks/common.py idiom);
``--json PATH`` additionally writes the rows and the meta-filter stats as
machine-readable JSON (consumed by the CI benchmark job).
"""
from __future__ import annotations

import argparse
from dataclasses import replace as dataclass_replace

import jax
import jax.numpy as jnp
import numpy as np
from common import emit, timeit as _time, write_json

from repro.api import FilterSpec, open_filter
from repro.dist.filter_bank import ShardedFilterBank
from repro.dist.tenant_bank import ShardedTenantFilterBank

SCHEMA = "bloomrf-dist-bench/v1"


def _tenant_meshes(n_tenants: int):
    """(label, mesh, data_axis, replica_axis) variants the host supports."""
    n_dev = len(jax.devices())
    data = n_dev
    while n_tenants % data:
        data -= 1
    out = [("tenant-sharded", jax.make_mesh((data,), ("data",)),
            "data", None)]
    if n_dev >= 2 and n_dev % 2 == 0:
        rdata = n_dev // 2
        while n_tenants % rdata:
            rdata -= 1
        out.append(("tenant-replicated",
                    jax.make_mesh((2, rdata), ("replica", "data")),
                    "data", "replica"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--keys", type=int, default=100_000)
    ap.add_argument("--queries", type=int, default=200_000)
    ap.add_argument("--shards", type=int, default=len(jax.devices()))
    ap.add_argument("--tenants", type=int, default=16)
    ap.add_argument("--tenant-shards", type=int, default=4)
    ap.add_argument("--bits-per-key", type=float, default=14.0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows + meta-filter stats as JSON")
    args = ap.parse_args()

    rows = []

    def rec(name, us, detail):
        rows.append(emit(name, us, detail))

    rng = np.random.default_rng(0xB100F)
    keys = rng.integers(0, 1 << 32, args.keys, dtype=np.uint64
                        ).astype(np.uint32)
    qs = rng.integers(0, 1 << 32, args.queries, dtype=np.uint64
                      ).astype(np.uint32)
    lo64 = rng.integers(0, 1 << 32, args.queries, dtype=np.uint64)
    hi = np.minimum(lo64 + (1 << 10), (1 << 32) - 1).astype(np.uint32)
    lo = lo64.astype(np.uint32)
    jq, jlo, jhi = jnp.asarray(qs), jnp.asarray(lo), jnp.asarray(hi)

    # every deployment shape opens through the typed façade (the
    # production front door); the handles expose their impls for the
    # shard_map variants and the raw-state timing loops below
    mono = FilterSpec(dtype="u32", n=args.keys,
                      bits_per_key=args.bits_per_key, delta=6)
    core = open_filter(dataclass_replace(mono, backend="xla")).filter
    st = core.build(jnp.asarray(keys))
    ops = open_filter(dataclass_replace(mono, backend="resident")).ops
    bank = open_filter(dataclass_replace(
        mono, placement="bank", shards=args.shards)).bank
    bst = bank.build(jnp.asarray(keys))
    # largest device count the shard rows divide over
    n_dev = len(jax.devices())
    while args.shards % n_dev:
        n_dev -= 1
    sb = ShardedFilterBank(bank, jax.make_mesh((n_dev,), ("data",)), "data")
    sst = sb.shard_state(bst)

    Q = args.queries
    dev_detail = f"devices={len(jax.devices())},shards={args.shards}"
    for name, pf, rf in [
        ("core", lambda: core.point(st, jq), lambda: core.range(st, jlo, jhi)),
        ("kernel", lambda: ops.point(st, jq), lambda: ops.range(st, jlo, jhi)),
        ("bank", lambda: bank.point(bst, jq), lambda: bank.range(bst, jlo, jhi)),
        ("sharded", lambda: sb.point(sst, jq), lambda: sb.range(sst, jlo, jhi)),
    ]:
        rec(f"{name}/point", _time(lambda *_: pf()) / Q * 1e6, dev_detail)
        rec(f"{name}/range", _time(lambda *_: rf()) / Q * 1e6, dev_detail)

    # -- multi-tenant bank -------------------------------------------------
    T, S = args.tenants, args.tenant_shards
    tb = open_filter(dataclass_replace(
        mono, placement="tenant", tenants=T, shards=S,
        n=max(args.keys // T, 1))).bank
    tenants = rng.integers(0, T, args.keys).astype(np.uint32)
    qt = jnp.asarray(rng.integers(0, T, Q).astype(np.uint32))
    jt, jk = jnp.asarray(tenants), jnp.asarray(keys)
    tstate, tmeta = tb.build(jt, jk)
    t_detail = f"devices={len(jax.devices())},tenants={T},shards={S}"
    rec("tenant/point", _time(lambda: tb.point(tstate, qt, jq)) / Q * 1e6,
        t_detail)
    rec("tenant/range", _time(lambda: tb.range(tstate, qt, jlo, jhi))
        / Q * 1e6, t_detail)
    rec("tenant/range+meta",
        _time(lambda: tb.range(tstate, qt, jlo, jhi, tmeta)) / Q * 1e6,
        t_detail)
    for label, mesh, daxis, raxis in _tenant_meshes(T):
        stb = ShardedTenantFilterBank(tb, mesh, daxis, raxis)
        s_state = stb.shard_state(tstate)
        s_meta = stb.shard_meta(tmeta)
        mesh_detail = f"{t_detail},mesh={dict(mesh.shape)}"
        rec(f"{label}/point",
            _time(lambda: stb.point(s_state, qt, jq)) / Q * 1e6, mesh_detail)
        rec(f"{label}/range+meta",
            _time(lambda: stb.range(s_state, qt, jlo, jhi, s_meta))
            / Q * 1e6, mesh_detail)

    # -- meta-filter skip rate + implied memory-access saving --------------
    cand, skip = tb.meta_skip_stats(tmeta, qt, jlo, jhi)
    cand, skip = int(cand), int(skip)
    skip_rate = skip / max(cand, 1)
    main_wa = tb.bank.filter.word_accesses_per_range_query()
    meta_wa = tb.meta.word_accesses_per_range_query()
    eff_wa = meta_wa + (1.0 - skip_rate) * main_wa
    rec("tenant/meta_skip_rate", 0.0,
        f"skipped={skip};candidates={cand};rate={skip_rate:.4f}")
    rec("tenant/meta_word_accesses", 0.0,
        f"main={main_wa};meta={meta_wa};effective={eff_wa:.2f}")

    if args.json:
        write_json(
            args.json, SCHEMA, rows,
            value_key="us_per_query", detail_key="detail",
            config={k: v for k, v in vars(args).items() if k != "json"},
            devices=len(jax.devices()),
            meta_filter={
                "candidates": cand, "skipped": skip,
                "skip_rate": skip_rate,
                "word_accesses_main": main_wa,
                "word_accesses_meta": meta_wa,
                "word_accesses_effective": eff_wa,
            })


if __name__ == "__main__":
    main()
