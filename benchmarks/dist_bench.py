"""Sharded filter-bank probe throughput vs the single-device paths.

Compares, at fixed total key count and bits/key:
  * core      — one monolithic BloomRF (XLA, the ops.py fallback path)
  * kernel    — one monolithic filter through the Pallas resident kernels
  * bank      — FilterBank (range-partitioned, vmap on one device)
  * sharded   — ShardedFilterBank over every host device (shard_map)

Run with faked devices to see the scaling shape on CPU:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python benchmarks/dist_bench.py --shards 8 --queries 200000

Output: csv ``name,us_per_query,detail`` rows (benchmarks/common.py idiom).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from common import emit  # noqa: F401  (path bootstrap side effect)

import jax
import jax.numpy as jnp

from repro.core import BloomRF, basic_layout
from repro.dist.filter_bank import FilterBank, ShardedFilterBank
from repro.kernels import FilterOps


def _time(fn, *args, repeat: int = 3):
    jax.block_until_ready(fn(*args))  # compile + drain the warm-up
    t0 = time.perf_counter()
    for _ in range(repeat):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeat


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--keys", type=int, default=100_000)
    ap.add_argument("--queries", type=int, default=200_000)
    ap.add_argument("--shards", type=int, default=len(jax.devices()))
    ap.add_argument("--bits-per-key", type=float, default=14.0)
    args = ap.parse_args()

    rng = np.random.default_rng(0xB100F)
    keys = rng.integers(0, 1 << 32, args.keys, dtype=np.uint64
                        ).astype(np.uint32)
    qs = rng.integers(0, 1 << 32, args.queries, dtype=np.uint64
                      ).astype(np.uint32)
    lo64 = rng.integers(0, 1 << 32, args.queries, dtype=np.uint64)
    hi = np.minimum(lo64 + (1 << 10), (1 << 32) - 1).astype(np.uint32)
    lo = lo64.astype(np.uint32)
    jq, jlo, jhi = jnp.asarray(qs), jnp.asarray(lo), jnp.asarray(hi)

    lay = basic_layout(32, args.keys, args.bits_per_key, delta=6)
    core = BloomRF(lay)
    st = core.build(jnp.asarray(keys))
    ops = FilterOps(lay)
    bank = FilterBank(32, args.shards, args.keys, args.bits_per_key, delta=6)
    bst = bank.build(jnp.asarray(keys))
    # largest device count the shard rows divide over
    n_dev = len(jax.devices())
    while args.shards % n_dev:
        n_dev -= 1
    sb = ShardedFilterBank(bank, jax.make_mesh((n_dev,), ("data",)), "data")
    sst = sb.shard_state(bst)

    Q = args.queries
    for name, pf, rf in [
        ("core", lambda: core.point(st, jq), lambda: core.range(st, jlo, jhi)),
        ("kernel", lambda: ops.point(st, jq), lambda: ops.range(st, jlo, jhi)),
        ("bank", lambda: bank.point(bst, jq), lambda: bank.range(bst, jlo, jhi)),
        ("sharded", lambda: sb.point(sst, jq), lambda: sb.range(sst, jlo, jhi)),
    ]:
        emit(f"{name}/point", _time(lambda *_: pf()) / Q * 1e6,
             f"devices={len(jax.devices())},shards={args.shards}")
        emit(f"{name}/range", _time(lambda *_: rf()) / Q * 1e6,
             f"devices={len(jax.devices())},shards={args.shards}")


if __name__ == "__main__":
    main()
