"""Sharded checkpointing with a bloomRF layer-range index per shard.

Layout on disk (one directory per step):
    step_000123/
      manifest.json         — leaf paths, shapes, dtypes, shard assignment,
                              bloomRF layout + per-shard filter state
      shard_00.npz ...      — stacked-layer leaves split by layer ranges
                              (non-layer leaves live in shard 0)

Every (layer, leaf) stored in a shard is keyed as ``ordinal << 7 | layer``
and inserted into that shard's bloomRF.  An elastic restart that only needs a
layer range (e.g. a pipeline stage re-shard, or a mesh-size change) issues a
*batched range query* per leaf ordinal — [ord<<7|lo, ord<<7|hi] — against
each shard's filter and downloads only matching shards: the paper's
range-filter pruning applied to checkpoint I/O, with narrow ranges where
bloomRF's FPR is lowest.  Filters have no false negatives, so restores are
always complete; a false positive merely fetches one extra shard.
"""
from __future__ import annotations

import json
import os
import re
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import BloomRF, basic_layout

__all__ = ["save_checkpoint", "restore_checkpoint", "restore_layer_range",
           "latest_step", "AsyncSaver"]

_SEP = "/"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = leaf
    return flat


def _is_layer_leaf(key: str, arr) -> bool:
    return "layers" in key and arr.ndim >= 1 and arr.shape[0] > 1


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:06d}")


def save_checkpoint(ckpt_dir: str, step: int, tree, n_shards: int = 4) -> str:
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    keys = sorted(flat)
    n_layers = max((flat[k].shape[0] for k in keys
                    if _is_layer_leaf(k, flat[k])), default=1)
    n_shards = max(1, min(n_shards, n_layers))
    bounds = np.linspace(0, n_layers, n_shards + 1).astype(int)

    sdir = _step_dir(ckpt_dir, step)
    os.makedirs(sdir + ".tmp", exist_ok=True)
    shard_files: dict = {s: {} for s in range(n_shards)}
    shard_keys: dict = {s: [] for s in range(n_shards)}  # filter keys
    manifest = {"step": step, "n_shards": n_shards, "n_layers": int(n_layers),
                "leaves": {}, "bounds": bounds.tolist()}

    for ordinal, k in enumerate(keys):
        arr = flat[k]
        manifest["leaves"][k] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype),
            "ordinal": ordinal, "layered": _is_layer_leaf(k, arr)}
        if _is_layer_leaf(k, arr):
            for s in range(n_shards):
                lo, hi = bounds[s], bounds[s + 1]
                if hi > lo:
                    shard_files[s][k] = arr[lo:hi]
                    shard_keys[s].extend(
                        (ordinal << 7) | int(ly) for ly in range(lo, hi))
        else:
            shard_files[0][k] = arr
            shard_keys[0].append(ordinal << 7)  # layer 0 pseudo-key

    # bloomRF per shard over (ordinal << 7 | layer) keys.  The filter domain
    # is sized to the actual key span (clustered keys in an oversized domain
    # saturate the upper dyadic levels — paper §7 'Memory Management').
    max_key = max((max(v) for v in shard_keys.values() if v), default=1)
    dom = max(8, int(max_key).bit_length() + 1)
    filt_meta = []
    for s in range(n_shards):
        nkeys = max(len(shard_keys[s]), 1)
        lay = basic_layout(dom, nkeys, bits_per_key=20.0, delta=3)
        f = BloomRF(lay, _warn=False)
        state = f.build(jnp.asarray(shard_keys[s] or [0], jnp.uint32))
        shard_files[s]["__bloomrf__"] = np.asarray(state)
        filt_meta.append({"n_keys": nkeys, "bits_per_key": 20.0, "delta": 3,
                          "domain_bits": dom})
    manifest["filters"] = filt_meta

    for s in range(n_shards):
        np.savez(os.path.join(sdir + ".tmp", f"shard_{s:02d}.npz"),
                 **shard_files[s])
    with open(os.path.join(sdir + ".tmp", "manifest.json"), "w") as fh:
        json.dump(manifest, fh)
    if os.path.exists(sdir):
        import shutil
        shutil.rmtree(sdir)
    os.rename(sdir + ".tmp", sdir)
    return sdir


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def _load_manifest(ckpt_dir: str, step: int):
    sdir = _step_dir(ckpt_dir, step)
    with open(os.path.join(sdir, "manifest.json")) as fh:
        return sdir, json.load(fh)


def _shard_filter(sdir, manifest, s):
    meta = manifest["filters"][s]
    lay = basic_layout(meta.get("domain_bits", 32), meta["n_keys"],
                       meta["bits_per_key"], delta=meta["delta"])
    data = np.load(os.path.join(sdir, f"shard_{s:02d}.npz"))
    return BloomRF(lay, _warn=False), jnp.asarray(data["__bloomrf__"]), data


def restore_checkpoint(ckpt_dir: str, step: int, like):
    """Full restore; reassembles layer shards. ``like`` provides the pytree
    structure (and device placement targets, if sharded)."""
    sdir, manifest = _load_manifest(ckpt_dir, step)
    shards = [np.load(os.path.join(sdir, f"shard_{s:02d}.npz"))
              for s in range(manifest["n_shards"])]
    out = {}
    for k, meta in manifest["leaves"].items():
        if meta["layered"]:
            parts = [sh[k] for sh in shards if k in sh.files]
            out[k] = np.concatenate(parts, axis=0)
        else:
            out[k] = shards[0][k]
    _, tdef = jax.tree.flatten(like)
    keys = _flatten_order_keys(like)
    assert sorted(keys) == sorted(out), "checkpoint/restore tree mismatch"
    return jax.tree.unflatten(tdef, [jnp.asarray(out[k]) for k in keys])


def _flatten_order_keys(tree):
    return [
        _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]


def restore_layer_range(ckpt_dir: str, step: int, lo_layer: int,
                        hi_layer: int):
    """Elastic partial restore: batched narrow range queries — one per
    layered leaf ordinal, [ord<<7|lo, ord<<7|hi] — against each shard's
    bloomRF; only matching shards are loaded.  Returns (flat dict of
    layer-sliced arrays, shards_probed, shards_loaded)."""
    sdir, manifest = _load_manifest(ckpt_dir, step)
    ordinals = [m["ordinal"] for m in manifest["leaves"].values()
                if m["layered"]]
    los = jnp.asarray([(o << 7) | lo_layer for o in ordinals], jnp.uint32)
    his = jnp.asarray([(o << 7) | hi_layer for o in ordinals], jnp.uint32)
    picked, probed = [], 0
    for s in range(manifest["n_shards"]):
        f, state, data = _shard_filter(sdir, manifest, s)
        probed += 1
        hit = bool(np.asarray(f.range(state, los, his)).any())
        if hit:
            picked.append((s, data))
    out = {}
    bounds = manifest["bounds"]
    for k, meta in manifest["leaves"].items():
        if not meta["layered"]:
            continue
        parts = []
        for s, data in picked:
            if k not in data.files:
                continue
            base = bounds[s]
            arr = data[k]
            a = max(lo_layer - base, 0)
            b = min(hi_layer + 1 - base, arr.shape[0])
            if b > a:
                parts.append(arr[a:b])
        if parts:
            out[k] = np.concatenate(parts, axis=0)
    return out, probed, len(picked)


class AsyncSaver:
    """Overlap checkpoint serialization with training (device->host copy on
    the caller thread, file I/O in the background)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None

    def save(self, ckpt_dir: str, step: int, tree, n_shards: int = 4):
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()
        self._thread = threading.Thread(
            target=save_checkpoint, args=(ckpt_dir, step, host_tree, n_shards),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
