"""Train step factory + the host-side Trainer driver.

``make_train_step`` builds a jit-able, fully-sharded step:
  (params, opt_state, [ef_error], batch) -> (params, opt_state, metrics)
with optional microbatch gradient accumulation (lax.scan over microbatches)
and optional int8 error-feedback gradient compression.

``Trainer`` is the host loop: data iterator, metrics JSONL, periodic +
async checkpointing, straggler detection hooks and crash/restart recovery
(see fault_tolerance.py for the supervisor).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.compression import ef_compress, ef_init
from .optimizer import OptConfig, adamw_init, adamw_update

__all__ = ["TrainConfig", "make_train_step", "Trainer"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 200
    microbatches: int = 1           # gradient accumulation
    grad_compression: bool = False  # int8 error-feedback
    checkpoint_every: int = 50
    log_every: int = 10
    straggler_zscore: float = 3.0
    seed: int = 0


def make_train_step(model, opt_cfg: OptConfig, train_cfg: TrainConfig):
    """Returns step(params, opt_state, ef_error, batch) -> (...)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def grads_of(params, batch):
        if train_cfg.microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        mb = train_cfg.microbatches

        def split(x):
            return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

        batches = jax.tree.map(
            lambda x: split(x) if x.ndim >= 1 and x.shape[0] % mb == 0 else
            jnp.broadcast_to(x, (mb,) + x.shape), batch)

        def body(carry, b):
            loss, g = jax.value_and_grad(loss_fn)(params, b)
            acc_l, acc_g = carry
            return (acc_l + loss / mb,
                    jax.tree.map(lambda a, x: a + x / mb, acc_g, g)), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), batches)
        return loss, grads

    def step(params, opt_state, ef_error, batch):
        loss, grads = grads_of(params, batch)
        if train_cfg.grad_compression:
            grads, ef_error = ef_compress(grads, ef_error)
        params, opt_state, om = adamw_update(opt_cfg, grads, opt_state, params)
        metrics = {"loss": loss, **om}
        return params, opt_state, ef_error, metrics

    return step


class Trainer:
    """Host-side training driver with fault-tolerance hooks."""

    def __init__(self, model, params, opt_cfg: OptConfig,
                 train_cfg: TrainConfig, data_iter,
                 ckpt_dir: Optional[str] = None,
                 step_fn: Optional[Callable] = None,
                 fail_at_step: Optional[int] = None):
        from .checkpoint import latest_step, restore_checkpoint

        self.model = model
        self.opt_cfg = opt_cfg
        self.cfg = train_cfg
        self.data_iter = data_iter
        self.ckpt_dir = ckpt_dir
        self.fail_at_step = fail_at_step  # failure injection (tests)
        self.metrics_log: list = []
        self.straggler_events: list = []

        self.step_fn = step_fn or jax.jit(
            make_train_step(model, opt_cfg, train_cfg))
        self.params = params
        self.opt_state = adamw_init(params)
        self.ef_error = (ef_init(params) if train_cfg.grad_compression
                         else jax.tree.map(lambda p: jnp.zeros((), jnp.float32),
                                           {}))
        self.start_step = 0
        if ckpt_dir and latest_step(ckpt_dir) is not None:
            st = latest_step(ckpt_dir)
            tree = restore_checkpoint(
                ckpt_dir, st,
                {"params": self.params, "opt": self.opt_state})
            self.params = tree["params"]
            self.opt_state = tree["opt"]
            self.start_step = st + 1

    # ------------------------------------------------------------------
    def _detect_straggler(self, times):
        if len(times) < 8:
            return None
        arr = np.asarray(times[-32:])
        mu, sd = arr[:-1].mean(), arr[:-1].std() + 1e-9
        z = (arr[-1] - mu) / sd
        if z > self.cfg.straggler_zscore:
            return {"step": len(times) - 1, "z": float(z),
                    "action": "flagged-for-rescheduling"}
        return None

    def run(self):
        from .checkpoint import save_checkpoint

        times = []
        step = self.start_step
        while step < self.cfg.steps:
            batch = next(self.data_iter)
            if self.fail_at_step is not None and step == self.fail_at_step:
                self.fail_at_step = None
                raise RuntimeError(f"injected failure at step {step}")
            t0 = time.perf_counter()
            self.params, self.opt_state, self.ef_error, metrics = \
                self.step_fn(self.params, self.opt_state, self.ef_error,
                             batch)
            jax.block_until_ready(metrics["loss"])
            times.append(time.perf_counter() - t0)
            ev = self._detect_straggler(times)
            if ev:
                self.straggler_events.append(ev)
            if step % self.cfg.log_every == 0 or step == self.cfg.steps - 1:
                rec = {"step": step,
                       **{k: float(v) for k, v in metrics.items()},
                       "step_time_s": times[-1]}
                self.metrics_log.append(rec)
            if self.ckpt_dir and (
                    (step + 1) % self.cfg.checkpoint_every == 0 or
                    step == self.cfg.steps - 1):
                save_checkpoint(self.ckpt_dir, step,
                                {"params": self.params,
                                 "opt": self.opt_state})
            step += 1
        return self.metrics_log
