"""Fault tolerance: crash/restart supervision and elastic re-sharding.

* :class:`Supervisor` — wraps a Trainer factory; on worker failure (any
  exception from the step loop) it recreates the trainer, which restores from
  the latest checkpoint, and resumes.  Bounded restarts; every incident is
  logged.  On a real cluster the factory re-acquires devices (possibly fewer
  — elastic), here it is exercised with injected failures (tests).
* :func:`elastic_restore` — restore a checkpoint onto a *different* mesh:
  arrays are loaded host-side and re-placed with the new shardings (GSPMD
  handles the re-partitioning on first use).
* Straggler mitigation lives in Trainer._detect_straggler (step-time z-score
  outliers flagged and surfaced for rescheduling).
"""
from __future__ import annotations

import time
from typing import Callable, List

import jax

from .checkpoint import restore_checkpoint

__all__ = ["Supervisor", "elastic_restore"]


class Supervisor:
    def __init__(self, trainer_factory: Callable, max_restarts: int = 3):
        self.factory = trainer_factory
        self.max_restarts = max_restarts
        self.incidents: List[dict] = []

    def run(self):
        restarts = 0
        while True:
            trainer = self.factory()
            try:
                metrics = trainer.run()
                return {"metrics": metrics, "restarts": restarts,
                        "incidents": self.incidents,
                        "stragglers": trainer.straggler_events}
            except Exception as e:  # noqa: BLE001 — any worker fault
                restarts += 1
                self.incidents.append({
                    "time": time.time(), "error": repr(e),
                    "resume_step": getattr(trainer, "start_step", 0)})
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts") from e


def elastic_restore(ckpt_dir: str, step: int, like, shardings=None):
    """Restore onto a (possibly different) mesh: load host-side, then place
    with the provided shardings pytree (or leave on default device)."""
    tree = restore_checkpoint(ckpt_dir, step, like)
    if shardings is None:
        return tree
    return jax.tree.map(jax.device_put, tree, shardings)
