"""Fault tolerance: crash/restart supervision and elastic re-sharding.

* :class:`Supervisor` — wraps a Trainer factory; on worker failure (any
  exception from the step loop) it recreates the trainer, which restores from
  the latest checkpoint, and resumes.  Bounded restarts with jittered
  exponential backoff between attempts (doubling base delay, capped, plus a
  seeded random jitter fraction so a fleet of supervisors never restarts in
  lockstep); every incident is logged with the delay it waited.  On a real
  cluster the factory re-acquires devices (possibly fewer — elastic), here it
  is exercised with injected failures (tests, reusing ``store/faults.py``).
* :func:`elastic_restore` — restore a checkpoint onto a *different* mesh:
  arrays are loaded host-side and re-placed with the new shardings (GSPMD
  handles the re-partitioning on first use).
* Straggler mitigation lives in Trainer._detect_straggler (step-time z-score
  outliers flagged and surfaced for rescheduling).
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional

import jax
import numpy as np

from .checkpoint import restore_checkpoint

__all__ = ["Supervisor", "elastic_restore"]


class Supervisor:
    """Bounded-restart trainer supervision with jittered exponential backoff.

    ``backoff_base`` seconds doubles per consecutive failure up to
    ``backoff_cap``, then a uniform jitter of up to ``jitter`` of the delay
    is added (seeded — deterministic in tests).  ``sleep`` is injectable so
    tests assert the schedule without waiting it out.  The restart budget
    counts *consecutive* failures within one :meth:`run` call; each call
    starts fresh, so a supervisor that recovered successfully can be reused
    with a full budget."""

    def __init__(self, trainer_factory: Callable, max_restarts: int = 3,
                 backoff_base: float = 0.5, backoff_cap: float = 30.0,
                 jitter: float = 0.25, seed: int = 0,
                 sleep: Optional[Callable[[float], None]] = None):
        if backoff_base < 0 or backoff_cap < 0 or not (0 <= jitter <= 1):
            raise ValueError("backoff_base/backoff_cap must be >= 0 and "
                             "jitter in [0, 1]")
        self.factory = trainer_factory
        self.max_restarts = max_restarts
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.jitter = jitter
        self._rng = np.random.default_rng(seed)
        self._sleep = sleep if sleep is not None else time.sleep
        self.incidents: List[dict] = []

    def _backoff(self, n_failures: int) -> float:
        """Delay before restart ``n_failures`` (1-based): capped doubling
        plus up to ``jitter`` fraction of uniform random spread."""
        base = min(self.backoff_base * (2.0 ** (n_failures - 1)),
                   self.backoff_cap)
        return base * (1.0 + self.jitter * float(self._rng.random()))

    def run(self):
        restarts = 0
        while True:
            trainer = self.factory()
            try:
                metrics = trainer.run()
                return {"metrics": metrics, "restarts": restarts,
                        "incidents": self.incidents,
                        "stragglers": trainer.straggler_events}
            except Exception as e:  # noqa: BLE001 — any worker fault
                restarts += 1
                delay = self._backoff(restarts)
                self.incidents.append({
                    "time": time.time(), "error": repr(e),
                    "backoff_s": delay,
                    "resume_step": getattr(trainer, "start_step", 0)})
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts") from e
                self._sleep(delay)


def elastic_restore(ckpt_dir: str, step: int, like, shardings=None):
    """Restore onto a (possibly different) mesh: load host-side, then place
    with the provided shardings pytree (or leave on default device)."""
    tree = restore_checkpoint(ckpt_dir, step, like)
    if shardings is None:
        return tree
    return jax.tree.map(jax.device_put, tree, shardings)
