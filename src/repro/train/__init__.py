"""Training substrate: optimizer, train step, checkpointing, fault tolerance."""
from .optimizer import OptConfig, adamw_init, adamw_update, cosine_lr
from .train_loop import TrainConfig, Trainer, make_train_step

__all__ = ["adamw_init", "adamw_update", "cosine_lr", "OptConfig",
           "make_train_step", "Trainer", "TrainConfig"]
