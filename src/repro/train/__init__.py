"""Training substrate: optimizer, train step, checkpointing, fault tolerance."""
from .optimizer import adamw_init, adamw_update, cosine_lr, OptConfig
from .train_loop import make_train_step, Trainer, TrainConfig

__all__ = ["adamw_init", "adamw_update", "cosine_lr", "OptConfig",
           "make_train_step", "Trainer", "TrainConfig"]
