"""AdamW + cosine schedule with warmup and global-norm clipping.

Pure-function optimizer (no optax dependency): state = (m, v, count), all
f32, sharded identically to the parameters (ZeRO-style — the sharding specs
are just the param specs applied twice).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "adamw_init", "adamw_update", "cosine_lr"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_lr(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * \
        (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: OptConfig, grads, opt_state, params):
    count = opt_state["count"] + 1
    cf = count.astype(jnp.float32)
    lr = cosine_lr(cfg, count)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    m = jax.tree.map(lambda m_, g: cfg.b1 * m_ + (1 - cfg.b1) * g,
                     opt_state["m"], grads)
    v = jax.tree.map(lambda v_, g: cfg.b2 * v_ + (1 - cfg.b2) * g * g,
                     opt_state["v"], grads)
    bc1 = 1 - cfg.b1 ** cf
    bc2 = 1 - cfg.b2 ** cf

    def upd(p, m_, v_):
        step = m_ / bc1 / (jnp.sqrt(v_ / bc2) + cfg.eps)
        return (p.astype(jnp.float32) -
                lr * (step + cfg.weight_decay * p.astype(jnp.float32))
                ).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "count": count}, \
        {"lr": lr, "grad_norm": gnorm}
