"""bloomRF reproduction, grown into a sharded jax/pallas filter system.

The public front door is the typed façade (DESIGN.md §11)::

    import repro

    f = repro.open_filter(repro.FilterSpec(dtype="f64", n=100_000))
    f.insert(keys)                 # typed keys — codecs applied inside
    f.range(lo, hi)                # one fused gather per probe batch

Subpackages (``repro.core``, ``repro.kernels``, ``repro.dist``,
``repro.store``, ``repro.serve``, ``repro.filters``) stay importable
directly; the pre-façade constructors they expose are deprecated shims
that warn with their ``FilterSpec`` equivalent.

Attribute access is lazy (PEP 562) so ``import repro`` stays cheap and
subpackage imports never cycle through the façade.
"""
from __future__ import annotations

__all__ = ["FilterSpec", "open_filter", "chunked_probe", "LegacyAPIWarning"]

_API = ("FilterSpec", "open_filter", "chunked_probe", "SingleFilter",
        "BankFilter", "TenantFilter", "TypedStore")


def __getattr__(name: str):
    if name in _API:
        from . import api

        return getattr(api, name)
    if name == "LegacyAPIWarning":
        from ._compat import LegacyAPIWarning

        return LegacyAPIWarning
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_API) | {"LegacyAPIWarning"})
