"""whisper-base [audio]: 6L(enc)+6L(dec) d=512 8H d_ff=2048 vocab=51865,
enc-dec with stubbed conv frontend (precomputed frame embeddings)
[arXiv:2212.04356]."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, enc_seq=1500, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, enc_seq=32, tie_embeddings=True,
    remat=False, dtype="float32",
)
