"""mamba2-130m [ssm]: 24L d=768 (attention-free) vocab=50280 ssm_state=128,
SSD (state-space duality)  [arXiv:2405.21060]."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, vocab=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, conv_kernel=4,
    ssm_chunk=128, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=64, vocab=256,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, conv_kernel=4,
    ssm_chunk=8, tie_embeddings=True,
    remat=False, dtype="float32",
)
