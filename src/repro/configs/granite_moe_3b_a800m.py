"""granite-moe-3b-a800m [moe]: 32L d=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40e top-8  [hf:ibm-granite].  40 experts do not divide the
16-way model axis, so EP shards the in-expert mlp dim instead (512/16=32)."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab=49155,
    n_experts=40, experts_per_token=8, moe_shard_dim="mlp",
)

SMOKE = ModelConfig(
    name="granite-moe-smoke", family="moe",
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab=128,
    n_experts=5, experts_per_token=2, moe_shard_dim="mlp",
    moe_capacity_factor=8.0,
    remat=False, dtype="float32",
)
