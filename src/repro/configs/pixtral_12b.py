"""pixtral-12b [vlm]: 40L d=5120 32H (GQA kv=8) d_ff=14336 vocab=131072,
pixtral-ViT frontend stubbed (precomputed patch embeddings) + mistral-nemo
decoder  [hf:mistralai/Pixtral-12B-2409]."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=131072, head_dim=128, rope_theta=1e6,
    n_patches=1024,
)

SMOKE = ModelConfig(
    name="pixtral-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, head_dim=16, n_patches=8,
    remat=False, dtype="float32",
)
