"""Config registry: one module per assigned architecture (+ smoke variants).

Each arch module defines ``FULL`` (the exact assigned config) and ``SMOKE``
(a reduced same-family config for CPU tests).  ``long_500k`` applicability
follows DESIGN.md §Arch-applicability (SSM/hybrid only).
"""
from __future__ import annotations

import importlib

from ..models.config import SHAPES, ModelConfig, Shape

ARCH_NAMES = [
    "moonshot-v1-16b-a3b",
    "granite-moe-3b-a800m",
    "qwen1.5-32b",
    "qwen3-1.7b",
    "granite-8b",
    "qwen2.5-3b",
    "whisper-base",
    "mamba2-130m",
    "pixtral-12b",
    "zamba2-2.7b",
]


def _module(name: str):
    mod = name.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name not in ARCH_NAMES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    m = _module(name)
    return m.SMOKE if smoke else m.FULL


def shape_applicable(cfg: ModelConfig, shape: Shape) -> bool:
    """long_500k needs sub-quadratic context state: SSM/hybrid only."""
    if shape.name == "long_500k":
        return cfg.supports_long_context
    return True


def cells(smoke: bool = False):
    """All (arch, shape) dry-run cells, with applicability flags."""
    out = []
    for name in ARCH_NAMES:
        cfg = get_config(name, smoke=smoke)
        for shape in SHAPES.values():
            out.append((name, cfg, shape, shape_applicable(cfg, shape)))
    return out
