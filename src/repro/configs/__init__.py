from .base import ARCH_NAMES, cells, get_config, shape_applicable

__all__ = ["ARCH_NAMES", "get_config", "cells", "shape_applicable"]
