"""zamba2-2.7b [hybrid]: 54L d=2560 32H (kv=32) d_ff=10240 vocab=32000
ssm_state=64 — Mamba2 backbone + 2 alternating shared attention blocks every
6 layers  [arXiv:2411.15242]."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, conv_kernel=4,
    ssm_chunk=128, attn_every=6, n_shared_blocks=2,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, conv_kernel=4,
    ssm_chunk=8, attn_every=2, n_shared_blocks=2,
    remat=False, dtype="float32",
)
