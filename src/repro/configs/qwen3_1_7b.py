"""qwen3-1.7b [dense]: 28L d=2048 16H (GQA kv=8) d_ff=6144 vocab=151936,
qk_norm + GQA  [hf:Qwen/Qwen3 family]."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=6144, vocab=151936, qk_norm=True, head_dim=128, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, qk_norm=True, head_dim=16,
    remat=False, dtype="float32",
)
