"""Paged KV cache (vLLM-style pages, JAX arrays + host-side allocator).

Pages are (L, n_pages, page_size, n_kv, hd) arrays; sequences own pages via a
host-side page table.  ``gather_cache`` materializes the contiguous
(L, B, S, kv, hd) view for the decode step (on TPU this is a cheap gather
along the page dim).  The allocator is a free list with reference counts so
frozen prefix segments (prefix_cache.py) can share pages copy-free.
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

__all__ = ["PagedKVCache"]


class PagedKVCache:
    def __init__(self, n_layers: int, n_pages: int, page_size: int,
                 n_kv: int, head_dim: int, dtype=jnp.bfloat16):
        shape = (n_layers, n_pages, page_size, n_kv, head_dim)
        self.k = jnp.zeros(shape, dtype)
        self.v = jnp.zeros(shape, dtype)
        self.page_size = page_size
        self.n_pages = n_pages
        self.free: List[int] = list(range(n_pages))
        self.refs = np.zeros(n_pages, np.int32)
        self.tables: Dict[int, List[int]] = {}

    # -- allocator -------------------------------------------------------
    def alloc_seq(self, seq_id: int, n_tokens: int) -> List[int]:
        need = (n_tokens + self.page_size - 1) // self.page_size
        if len(self.free) < need:
            raise MemoryError("KV pool exhausted")
        pages = [self.free.pop() for _ in range(need)]
        for p in pages:
            self.refs[p] += 1
        self.tables[seq_id] = pages
        return pages

    def extend_seq(self, seq_id: int, n_tokens_now: int) -> None:
        pages = self.tables[seq_id]
        need = (n_tokens_now + self.page_size - 1) // self.page_size
        while len(pages) < need:
            p = self.free.pop()
            self.refs[p] += 1
            pages.append(p)

    def share_pages(self, seq_id: int, pages: List[int]) -> None:
        """Adopt frozen prefix pages (copy-on-write not needed: read-only)."""
        for p in pages:
            self.refs[p] += 1
        self.tables[seq_id] = list(pages) + self.tables.get(seq_id, [])

    def free_seq(self, seq_id: int) -> None:
        for p in self.tables.pop(seq_id, []):
            self.refs[p] -= 1
            if self.refs[p] == 0:
                self.free.append(p)

    # -- device ops ------------------------------------------------------
    def write_prefill(self, seq_id: int, k_new, v_new) -> None:
        """k_new/v_new: (L, S, kv, hd) for one sequence."""
        S = k_new.shape[1]
        self.extend_seq(seq_id, S)
        pages = self.tables[seq_id]
        ps = self.page_size
        pad = (len(pages) * ps) - S
        kp = jnp.pad(k_new, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v_new, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp = kp.reshape(k_new.shape[0], len(pages), ps, *k_new.shape[2:])
        vp = vp.reshape(v_new.shape[0], len(pages), ps, *v_new.shape[2:])
        idx = jnp.asarray(pages, jnp.int32)
        self.k = self.k.at[:, idx].set(kp)
        self.v = self.v.at[:, idx].set(vp)

    def write_token(self, seq_id: int, pos: int, k_new, v_new) -> None:
        """k_new/v_new: (L, 1, kv, hd) single decoded token at ``pos``."""
        self.extend_seq(seq_id, pos + 1)
        page = self.tables[seq_id][pos // self.page_size]
        off = pos % self.page_size
        self.k = self.k.at[:, page, off].set(k_new[:, 0])
        self.v = self.v.at[:, page, off].set(v_new[:, 0])

    def gather_cache(self, seq_ids: List[int], max_pages: int):
        """(L, B, max_pages*page_size, kv, hd) contiguous view + lengths."""
        tables = []
        for sid in seq_ids:
            t = self.tables[sid][:max_pages]
            tables.append(t + [0] * (max_pages - len(t)))
        idx = jnp.asarray(tables, jnp.int32)                 # (B, max_pages)
        k = self.k[:, idx]                                    # (L,B,P,ps,kv,hd)
        v = self.v[:, idx]
        L, B = k.shape[0], k.shape[1]
        S = max_pages * self.page_size
        return (k.reshape(L, B, S, *k.shape[4:]),
                v.reshape(L, B, S, *v.shape[4:]))
