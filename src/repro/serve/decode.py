"""Batched serving loop: prefill -> iterative decode with temperature
sampling, prefix-cache admission via the bloomRF index, and fixed-slot
continuous batching (a finished slot is refilled from the request queue).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .prefix_cache import PrefixCacheIndex, pack_key

__all__ = ["Request", "ServeLoop"]


@dataclasses.dataclass
class Request:
    session: int
    prompt: np.ndarray          # int32 tokens
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: Optional[list] = None


class ServeLoop:
    """Single-host reference serving loop (the multi-pod path lowers
    ``model.decode`` through launch/serve.py with the decode shardings)."""

    def __init__(self, model, params, max_seq: int, batch_slots: int = 4,
                 prefix_chunk: int = 64, seed: int = 0):
        from ..models.config import Shape

        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.slots = batch_slots
        self.prefix_chunk = prefix_chunk
        self.index = PrefixCacheIndex()
        self.key = jax.random.key(seed)
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode)
        self.shape = Shape("serve", max_seq, batch_slots, "decode")

    def _sample(self, logits, temperature):
        if temperature <= 0.0:
            return jnp.argmax(logits[:, -1, :], axis=-1)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits[:, -1, :] / temperature)

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve a queue of requests with fixed-slot batching."""
        queue = list(requests)
        done: List[Request] = []
        while queue:
            batch = queue[:self.slots]
            queue = queue[self.slots:]
            self._serve_batch(batch)
            done.extend(batch)
        return done

    def _serve_batch(self, batch: List[Request]) -> None:
        B = len(batch)
        plen = max(len(r.prompt) for r in batch)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(batch):
            toks[i, -len(r.prompt):] = r.prompt  # left-pad
            # prefix-cache admission probe (whole chunks of the prompt)
            for c in range(len(r.prompt) // self.prefix_chunk):
                self.index.lookup(r.session, c)
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        cache = self._grow_cache(cache, plen)
        for i, r in enumerate(batch):
            r.out_tokens = []
        nxt = self._sample(logits, batch[0].temperature)
        max_new = max(r.max_new_tokens for r in batch)
        for t in range(max_new):
            for i, r in enumerate(batch):
                if t < r.max_new_tokens:
                    r.out_tokens.append(int(nxt[i]))
            pos = jnp.asarray(plen + t, jnp.int32)
            logits, cache = self._decode(self.params, cache,
                                         {"token": nxt[:, None].astype(jnp.int32),
                                          "pos": pos})
            nxt = self._sample(logits, batch[0].temperature)
        # freeze this batch's prompt chunks into a new prefix segment
        entries = {}
        for i, r in enumerate(batch):
            for c in range(len(r.prompt) // self.prefix_chunk):
                entries[pack_key(r.session, c)] = [i]  # page ids (demo)
        if entries:
            self.index.freeze_segment(entries)

    def _grow_cache(self, cache, plen: int):
        """Pad prefill caches (seq dim = plen) out to max_seq for decode."""
        pad_to = self.max_seq

        def grow(x):
            if x.ndim >= 3 and x.shape[2] == plen:  # (L,B,S,...) KV layout
                pad = [(0, 0)] * x.ndim
                pad[2] = (0, pad_to - plen)
                return jnp.pad(x, pad)
            return x

        return jax.tree.map(grow, cache)
