"""bloomRF-indexed prefix-KV-cache admission (the paper's LSM integration,
re-targeted at serving), backed by the multi-tenant filter bank.

Frozen cache *segments* are the analogue of SST files: immutable maps from
``(session, chunk_position)`` keys to lists of KV page ids.  Each segment
carries a :class:`~repro.dist.tenant_bank.TenantFilterBank` where every
**session namespace is a tenant**: the low ``log2(n_tenants)`` bits of the
session id pick the tenant row, and the remaining session bits with the
chunk position form the tenant-local key ``(session >> nt) << 16 | chunk``.
A batched lookup consults the cheap per-tenant filters before touching any
segment's (potentially cold) map:

* point query  — "is this exact (session, chunk) prefix cached?"
* range query  — "does this segment hold ANY chunk for session s?"
  (a session's chunks are one contiguous tenant-local range), and "any
  activity in a session-id window?" for range-based eviction sweeps — the
  window decomposes into one contiguous local range per tenant because
  sessions are striped over tenants by their low bits.

Segments also keep the bank's Bloofi-style meta-filter (built over the
session-prefix level, i.e. chunk bits dropped), so sweep-style range probes
are answered against ``main & meta`` — strictly fewer false positives.

Keys stay in a 32-bit domain (16-bit session, 16-bit chunk) so the filters
run without the x64 flag in serving processes.  Filters never produce false
negatives -> no cached prefix is ever missed; a false positive costs one
extra map probe (counted in stats).  All filter probes (point lookups,
session ranges, eviction sweeps, and the meta AND) route through the
plan->gather->combine engine (core/engine.py), so each segment consult is
a single fused gather over the tenant's filter row.

Optionally the index is backed by an LSM :class:`~repro.store.Store`
(``backing_store=``): frozen entries are mirrored into the store as the
cold tier, total-miss lookups fall through to ``store.get``, and
:meth:`evict_window` sweeps a session-id window — candidate segments found
through the range filters, evicted keys tombstoned in the store so the
cold tier masks them too (the store's own per-run filters keep the sweep's
read amplification bounded).
"""
from __future__ import annotations

import weakref
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core import codecs
from ..dist.tenant_bank import TenantFilterBank
from ..obs import metrics as _obs_metrics
from ..store import Store

__all__ = ["PrefixCacheIndex", "pack_key"]

_CHUNK_BITS = 16
_SES_BITS = 16


def pack_key(session: int, chunk: int) -> int:
    """(session, chunk) -> packed key via the order-preserving two-attribute
    codec (``core.codecs.pack2``): the multi-attribute concatenation of
    paper §8 with a 16-bit low field."""
    return int(codecs.pack2(session & 0xFFFF, chunk & 0xFFFF, _CHUNK_BITS))


class _Segment:
    def __init__(self, entries: Dict[int, List[int]], bank: TenantFilterBank,
                 tenants: np.ndarray, local_keys: np.ndarray, gen: int = 0):
        self.entries = entries
        self.bank = bank
        self.gen = gen               # generation the segment was frozen in
        self.state, self.meta = bank.build(jnp.asarray(tenants),
                                           jnp.asarray(local_keys))


class PrefixCacheIndex:
    def __init__(self, bits_per_key: float = 14.0, n_tenants: int = 16,
                 backing_store: Optional[Store] = None,
                 ttl_generations: Optional[int] = None):
        if n_tenants < 1 or n_tenants & (n_tenants - 1):
            raise ValueError(
                f"n_tenants must be a power of two, got {n_tenants}")
        if n_tenants > (1 << (_SES_BITS - 1)):
            # at least one session bit must remain for the tenant-local key
            # (the meta-filter level sits at the chunk/session boundary)
            raise ValueError(f"at most {1 << (_SES_BITS - 1)} tenants")
        if ttl_generations is not None and ttl_generations < 1:
            raise ValueError(
                f"ttl_generations must be >= 1, got {ttl_generations}")
        self.bits_per_key = bits_per_key
        self.n_tenants = n_tenants
        self.nt_bits = n_tenants.bit_length() - 1
        self.d_seg = (_SES_BITS - self.nt_bits) + _CHUNK_BITS
        self.segments: List[_Segment] = []
        self._banks: Dict[int, TenantFilterBank] = {}
        self.ttl_generations = ttl_generations
        self.generation = 0
        self.stats = {"filter_probes": 0, "filter_hits": 0,
                      "map_probes": 0, "map_hits": 0, "range_probes": 0,
                      "store_probes": 0, "store_hits": 0, "evicted": 0,
                      "expired": 0}
        self.store: Optional[Store] = None
        if backing_store is not None:
            self.attach_store(backing_store)
        if _obs_metrics.enabled():
            self.register_obs()

    def register_obs(self, family: str = "prefix_cache") -> str:
        """Publish the admission stats (+ live fp_rate) as a metric family.

        Registered through a weakref: a collected index's family reports
        ``None`` and is pruned at the next registry snapshot."""
        ref = weakref.ref(self)

        def _family():
            idx = ref()
            if idx is None:
                return None
            out = dict(idx.stats)
            out["fp_rate"] = idx.false_positive_rate()
            return out

        return _obs_metrics.registry().register_family(family, _family)

    def attach_store(self, store: Store, backfill: bool = True) -> None:
        """Use an LSM run-store as the cold tier behind the segments.

        Segments frozen before attachment are backfilled (``backfill=True``
        default), so the cold tier always mirrors every frozen entry —
        ``lookup``'s fallthrough and ``evict_window``'s cold sweep rely on
        that invariant.  Pass ``backfill=False`` when the store *already*
        holds the frozen entries, i.e. when re-attaching a recovered cold
        tier (:meth:`reopen_cold_tier`) whose durable state is the mirror."""
        if store.cfg.d < _SES_BITS + _CHUNK_BITS:
            raise ValueError(
                f"backing store needs a >= {_SES_BITS + _CHUNK_BITS}-bit "
                f"domain for packed keys, got d={store.cfg.d}")
        self.store = store
        if backfill:
            for seg in self.segments:
                for k, pages in seg.entries.items():
                    store.put(k, pages)

    def reopen_cold_tier(self, wal_dir: str, config=None) -> Store:
        """Recover a durable cold tier from ``wal_dir`` and attach it.

        Routes through ``Store.open`` — manifest + snapshot CRCs verified,
        torn WAL tail healed, acknowledged writes replayed — so a serving
        process restarted after a crash resumes with the cold tier it had
        acked, including eviction tombstones (a lost tombstone would
        resurrect an evicted prefix).  Runs whose filter block rotted come
        back quarantined: lookups stay exact, just less pruned.  The
        recovered store is attached without backfill (its durable state
        *is* the mirror) and returned."""
        store = Store.open(wal_dir, config=config)
        self.attach_store(store, backfill=False)
        return store

    # -- session-namespace routing (scalar ints and numpy arrays alike) --
    def _tenant(self, session):
        return session & (self.n_tenants - 1)

    def _local_key(self, session, chunk):
        local_ses = (session & 0xFFFF) >> self.nt_bits
        return codecs.pack2(local_ses, chunk, _CHUNK_BITS)

    def _bank_for(self, n_entries: int) -> TenantFilterBank:
        """Banks are cached per capacity class (power of two) so segments of
        similar size share one compiled filter program."""
        cap = max(16, 1 << (max(n_entries, 1) - 1).bit_length())
        if cap not in self._banks:
            self._banks[cap] = TenantFilterBank(
                self.d_seg, self.n_tenants, 1,
                n_keys_per_tenant=max(cap // self.n_tenants, 1),
                bits_per_key=self.bits_per_key, delta=6,
                meta_level=_CHUNK_BITS, _warn=False)
        return self._banks[cap]

    # ------------------------------------------------------------------
    def freeze_segment(self, entries: Dict[int, List[int]]) -> int:
        """Freeze a batch of (packed key -> page list) into a new segment."""
        entries = dict(entries)
        packed = list(entries) or [pack_key(0, 0)]
        sessions = np.asarray([k >> _CHUNK_BITS for k in packed], np.uint32)
        chunks = np.asarray([k & 0xFFFF for k in packed], np.uint32)
        tenants = self._tenant(sessions).astype(np.uint32)
        local = self._local_key(sessions, chunks).astype(np.uint32)
        self.segments.append(_Segment(entries, self._bank_for(len(packed)),
                                      tenants, local, gen=self.generation))
        if self.store is not None:           # mirror into the cold tier
            for k, pages in entries.items():
                self.store.put(k, pages)
        return len(self.segments) - 1

    def lookup(self, session: int, chunk: int) -> Optional[List[int]]:
        """Newest-first point lookup through the per-tenant filters."""
        key = pack_key(session, chunk)
        t = jnp.asarray([self._tenant(session)], jnp.uint32)
        q = jnp.asarray([self._local_key(session, chunk)], jnp.uint32)
        for seg in reversed(self.segments):
            self.stats["filter_probes"] += 1
            if bool(seg.bank.point(seg.state, t, q)[0]):
                self.stats["filter_hits"] += 1
                self.stats["map_probes"] += 1
                if key in seg.entries:
                    self.stats["map_hits"] += 1
                    return seg.entries[key]
        if self.store is not None:           # cold tier (evictions masked
            self.stats["store_probes"] += 1  # there by tombstones)
            pages = self.store.get(key)
            if pages is not None:
                self.stats["store_hits"] += 1
                return pages
        return None

    def session_segments(self, session: int) -> List[int]:
        """Range query: segments possibly holding ANY chunk of ``session``."""
        t = jnp.asarray([self._tenant(session)], jnp.uint32)
        lo = jnp.asarray([self._local_key(session, 0)], jnp.uint32)
        hi = jnp.asarray([self._local_key(session, (1 << _CHUNK_BITS) - 1)],
                         jnp.uint32)
        out = []
        for i, seg in enumerate(self.segments):
            self.stats["filter_probes"] += 1
            self.stats["range_probes"] += 1
            if bool(seg.bank.range(seg.state, t, lo, hi, seg.meta)[0]):
                out.append(i)
        return out

    def _window_probes(self, lo_session: int,
                       hi_session: int) -> Tuple[np.ndarray, ...]:
        """Decompose a session-id window into per-tenant local key ranges.

        Sessions stripe over tenants by their low bits, so the sessions of
        tenant ``t`` inside ``[lo_session, hi_session]`` are one contiguous
        local-session interval; each becomes one (tenant, lo, hi) probe."""
        T = self.n_tenants
        ts, los, his = [], [], []
        for t in range(T):
            lo_loc = (max(lo_session - t, 0) + T - 1) // T
            if hi_session < t:
                continue
            hi_loc = (hi_session - t) // T
            if hi_loc < lo_loc:
                continue
            ts.append(t)
            los.append(lo_loc << _CHUNK_BITS)
            his.append((hi_loc << _CHUNK_BITS) | ((1 << _CHUNK_BITS) - 1))
        return (np.asarray(ts, np.uint32), np.asarray(los, np.uint32),
                np.asarray(his, np.uint32))

    def eviction_candidates(self, lo_session: int, hi_session: int) -> List[int]:
        """Range sweep over a session-id window (e.g. expired id range)."""
        ts, los, his = self._window_probes(lo_session, hi_session)
        if not len(ts):
            return []
        t, lo, hi = jnp.asarray(ts), jnp.asarray(los), jnp.asarray(his)
        out = []
        for i, seg in enumerate(self.segments):
            self.stats["filter_probes"] += 1
            self.stats["range_probes"] += 1
            if bool(np.asarray(
                    seg.bank.range(seg.state, t, lo, hi, seg.meta)).any()):
                out.append(i)
        return out

    def evict_window(self, lo_session: int, hi_session: int) -> int:
        """Evict every cached prefix whose session id is in the window.

        The range filters narrow the sweep to candidate segments
        (:meth:`eviction_candidates`); matching entries are dropped from
        those segments' maps.  When a backing store is attached, the cold
        tier is swept too: a session window is one contiguous range of
        packed keys, so a single (filter-pruned) ``store.scan`` finds
        every cold entry in the window; the tombstones are written as ONE
        batched ``store.delete_many`` after the scan completes — a per-key
        delete loop could flush the memtable and cascade compactions
        mid-sweep, invalidating the pruning work of the scan it just ran.
        Segment filters are immutable (insert-only), so an evicted key
        degrades to one filter false positive until the segment is rebuilt
        or its generation retires; correctness never depends on clearing
        bits."""
        dropped = set()
        for i in self.eviction_candidates(lo_session, hi_session):
            seg = self.segments[i]
            drop = [k for k in seg.entries
                    if lo_session <= (k >> _CHUNK_BITS) <= hi_session]
            for k in drop:
                del seg.entries[k]
            dropped.update(drop)
        if self.store is not None:
            chunk_full = (1 << _CHUNK_BITS) - 1
            cold = [k for k, _ in self.store.scan(
                lo_session << _CHUNK_BITS,
                (hi_session << _CHUNK_BITS) | chunk_full)]
            self.store.delete_many(cold)
            dropped.update(cold)
        self.stats["evicted"] += len(dropped)
        return len(dropped)

    def advance_generation(self) -> int:
        """Close the current TTL window: segments frozen more than
        ``ttl_generations`` windows ago are retired wholesale — their
        entries, filter state, *and* filter bits disappear together, so
        expired keys stop costing false positives without any per-key
        sweep.  Retired entries are batch-tombstoned in the cold tier.
        Hot prefixes survive by being re-frozen into newer segments;
        expiry of anything older is the TTL contract, not a miss bug.
        Returns the number of entries expired."""
        if self.ttl_generations is None:
            raise ValueError(
                "PrefixCacheIndex was built without ttl_generations")
        self.generation += 1
        cutoff = self.generation - self.ttl_generations
        expired: List[int] = []
        kept: List[_Segment] = []
        for seg in self.segments:
            if seg.gen <= cutoff:
                expired.extend(seg.entries)
            else:
                kept.append(seg)
        self.segments = kept
        if self.store is not None and expired:
            self.store.delete_many(expired)
        self.stats["expired"] += len(expired)
        return len(expired)

    def false_positive_rate(self) -> float:
        fp = self.stats["map_probes"] - self.stats["map_hits"]
        return fp / max(self.stats["filter_hits"], 1)
