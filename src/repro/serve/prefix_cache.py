"""bloomRF-indexed prefix-KV-cache admission (the paper's LSM integration,
re-targeted at serving).

Frozen cache *segments* are the analogue of SST files: immutable maps from
``(session, chunk_position)`` keys to lists of KV page ids.  Each segment
carries a bloomRF built over its keys, so a batched lookup consults cheap
filters before touching any segment's (potentially cold) map:

* point query  — "is this exact (session, chunk) prefix cached?"
* range query  — "does this segment hold ANY chunk for session s?"
  (key space is session<<B | chunk, so a session's chunks are one range),
  and "any activity in a session-id window?" for range-based eviction sweeps.

Keys are packed into a 32-bit domain (16-bit session, 16-bit chunk) so the
filter runs without the x64 flag in serving processes; the 64-bit layout is a
constructor switch.  Filters never produce false negatives -> no cached
prefix is ever missed; a false positive costs one extra map probe (counted
in stats).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from ..core import BloomRF, basic_layout

__all__ = ["PrefixCacheIndex", "pack_key"]

_CHUNK_BITS = 16


def pack_key(session: int, chunk: int) -> int:
    return ((session & 0xFFFF) << _CHUNK_BITS) | (chunk & 0xFFFF)


class _Segment:
    def __init__(self, entries: Dict[int, List[int]], bits_per_key: float):
        self.entries = entries
        n = max(len(entries), 1)
        self.layout = basic_layout(32, n, bits_per_key, delta=6)
        self.filter = BloomRF(self.layout)
        keys = jnp.asarray(list(entries) or [0], jnp.uint32)
        self.state = self.filter.build(keys)


class PrefixCacheIndex:
    def __init__(self, bits_per_key: float = 14.0):
        self.bits_per_key = bits_per_key
        self.segments: List[_Segment] = []
        self.stats = {"filter_probes": 0, "filter_hits": 0,
                      "map_probes": 0, "map_hits": 0}

    # ------------------------------------------------------------------
    def freeze_segment(self, entries: Dict[int, List[int]]) -> int:
        """Freeze a batch of (packed key -> page list) into a new segment."""
        self.segments.append(_Segment(dict(entries), self.bits_per_key))
        return len(self.segments) - 1

    def lookup(self, session: int, chunk: int) -> Optional[List[int]]:
        """Newest-first point lookup through the segment filters."""
        key = pack_key(session, chunk)
        kq = jnp.uint32(key)
        for seg in reversed(self.segments):
            self.stats["filter_probes"] += 1
            if bool(seg.filter.point(seg.state, kq)):
                self.stats["filter_hits"] += 1
                self.stats["map_probes"] += 1
                if key in seg.entries:
                    self.stats["map_hits"] += 1
                    return seg.entries[key]
        return None

    def session_segments(self, session: int) -> List[int]:
        """Range query: segments possibly holding ANY chunk of ``session``."""
        lo = jnp.uint32(pack_key(session, 0))
        hi = jnp.uint32(pack_key(session, (1 << _CHUNK_BITS) - 1))
        out = []
        for i, seg in enumerate(self.segments):
            self.stats["filter_probes"] += 1
            if bool(seg.filter.range(seg.state, lo, hi)):
                out.append(i)
        return out

    def eviction_candidates(self, lo_session: int, hi_session: int) -> List[int]:
        """Range sweep over a session-id window (e.g. expired id range)."""
        lo = jnp.uint32(pack_key(lo_session, 0))
        hi = jnp.uint32(pack_key(hi_session, (1 << _CHUNK_BITS) - 1))
        return [i for i, seg in enumerate(self.segments)
                if bool(seg.filter.range(seg.state, lo, hi))]

    def false_positive_rate(self) -> float:
        fp = self.stats["map_probes"] - self.stats["map_hits"]
        return fp / max(self.stats["filter_hits"], 1)
