"""Serving substrate: paged KV cache, batched decode, bloomRF prefix index."""
from .kv_cache import PagedKVCache
from .prefix_cache import PrefixCacheIndex
from .decode import ServeLoop

__all__ = ["PagedKVCache", "PrefixCacheIndex", "ServeLoop"]
