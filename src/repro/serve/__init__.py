"""Serving substrate: paged KV cache, batched decode, bloomRF prefix index."""
from .decode import ServeLoop
from .kv_cache import PagedKVCache
from .prefix_cache import PrefixCacheIndex

__all__ = ["PagedKVCache", "PrefixCacheIndex", "ServeLoop"]
