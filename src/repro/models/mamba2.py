"""Mamba2 (state-space duality / SSD) backbone — attention-free.

Implements the chunked SSD scan (intra-chunk quadratic within ``ssm_chunk``
tokens + inter-chunk linear state recurrence) for train/prefill, and the O(1)
recurrent state update for decode.  Only ``ssm_groups == 1`` is supported
(all assigned SSM/hybrid archs use one B/C group).

Decode cache per layer: SSM state (B, H, N, P) + depthwise-conv tails for the
x/B/C streams — constant size in sequence length, which is why the ``ssm`` and
``hybrid`` families run the ``long_500k`` shape (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from .act import scan as _act_scan
from .config import ModelConfig, Shape
from .layers import rmsnorm
from .params import P
from .transformer import DenseModel

__all__ = ["MambaModel", "mamba_block_table", "mamba_block", "mamba_block_decode",
           "MambaCache", "init_mamba_cache_specs"]


def mamba_block_table(cfg: ModelConfig) -> dict:
    D, din = cfg.d_model, cfg.d_inner
    H, Pd, N, ck = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.conv_kernel
    assert cfg.ssm_groups == 1, "only ssm_groups=1 supported"
    return {
        "wz": P((D, din), ("embed", "ssm_inner")),
        "wx": P((D, din), ("embed", "ssm_inner")),
        "wb": P((D, N), ("embed", None)),
        "wc": P((D, N), ("embed", None)),
        "wdt": P((D, H), ("embed", None)),
        "dt_bias": P((H,), (None,), "dt_bias"),
        "a_log": P((H,), (None,), "a_log"),
        "d_skip": P((H,), (None,), "ones"),
        "conv_x": P((ck, din), (None, "ssm_inner")),
        "conv_b": P((ck, N), (None, None)),
        "conv_c": P((ck, N), (None, None)),
        "conv_bias_x": P((din,), ("ssm_inner",), "zeros"),
        "conv_bias_b": P((N,), (None,), "zeros"),
        "conv_bias_c": P((N,), (None,), "zeros"),
        "ln": P((D,), (None,), "ones"),
        "norm": P((din,), ("ssm_inner",), "ones"),
        "w_out": P((din, D), ("ssm_inner", "embed")),
    }


def _causal_depthwise_conv(x, w, b, tail=None):
    """x: (B, S, C); w: (ck, C); optional tail: (B, ck-1, C) from the cache.
    Returns (y, new_tail)."""
    ck = w.shape[0]
    pad = x if tail is not None else jnp.pad(x, ((0, 0), (ck - 1, 0), (0, 0)))
    if tail is not None:
        pad = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    y = sum(pad[:, i:i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
            for i in range(ck))
    y = y + b[None, None, :].astype(x.dtype)
    new_tail = pad[:, -(ck - 1):, :] if ck > 1 else None
    return jax.nn.silu(y), new_tail


def ssd_chunked(xs, dt, A, Bm, Cm, chunk: int, initial_state=None):
    """xs: (B,S,H,P); dt: (B,S,H) (post-softplus); A: (H,) negative;
    Bm, Cm: (B,S,N).  Returns (y: (B,S,H,P), final_state: (B,H,N,P))."""
    Bsz, S, H, Pd = xs.shape
    N = Bm.shape[-1]
    c = min(chunk, S)
    Sp = ((S + c - 1) // c) * c
    if Sp != S:
        # zero-pad to a chunk multiple: dt=0 -> decay 1 and zero contribution,
        # so the final state and real-position outputs are unaffected
        pad = Sp - S
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S_real, S = S, Sp
    nc = S // c
    dtype = xs.dtype

    x_ = xs.reshape(Bsz, nc, c, H, Pd)
    dt_ = dt.reshape(Bsz, nc, c, H).astype(jnp.float32)
    B_ = Bm.reshape(Bsz, nc, c, N)
    C_ = Cm.reshape(Bsz, nc, c, N)
    a = dt_ * A[None, None, None, :]                      # (B,nc,c,H) <= 0
    a_cs = jnp.cumsum(a, axis=2)

    # intra-chunk (quadratic within the chunk); labels: b=batch, c=chunk idx,
    # i/j=position within chunk, h=head, p=head dim, s=state dim.
    # The (B,nc,c,c,H) decay/weight tensors are the HBM hot spot of SSD —
    # keep them in the compute dtype end-to-end (exp(seg<=0) is in [0,1],
    # safe in bf16); only the cumulative-sum statistics stay f32
    # (§Perf iteration 1/3).
    from .act import legacy_f32
    seg = a_cs[:, :, :, None, :] - a_cs[:, :, None, :, :]  # (B,nc,c,c,H)
    causal = jnp.tril(jnp.ones((c, c), bool))
    if legacy_f32():
        CB = jnp.einsum("bcis,bcjs->bcij", C_, B_,
                        preferred_element_type=jnp.float32)
        decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
        w = (CB[..., None] * decay * dt_[:, :, None, :, :]).astype(dtype)
    else:
        CB = jnp.einsum("bcis,bcjs->bcij", C_, B_)        # compute dtype
        decay = jnp.where(causal[None, None, :, :, None],
                          jnp.exp(seg), 0.0).astype(dtype)
        w = CB[..., None] * decay * dt_[:, :, None, :, :].astype(dtype)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, x_)

    # chunk-local final states
    sdec = jnp.exp(a_cs[:, :, -1:, :] - a_cs)             # (B,nc,c,H)
    S_loc = jnp.einsum("bcjh,bcjs,bcjhp->bchsp",
                       (sdec * dt_).astype(dtype), B_, x_)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(a_cs[:, :, -1, :]).astype(jnp.float32)  # (B,nc,H)
    S0 = (jnp.zeros((Bsz, H, N, Pd), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(S_prev, inp):
        dec, Sl = inp
        S_new = dec[:, :, None, None] * S_prev + Sl.astype(jnp.float32)
        return S_new, S_prev

    S_fin, S_prevs = _act_scan(
        step, S0, (chunk_decay.transpose(1, 0, 2),
                   S_loc.transpose(1, 0, 2, 3, 4)))
    S_prevs = S_prevs.transpose(1, 0, 2, 3, 4)            # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcis,bcih,bchsp->bcihp",
                         C_, jnp.exp(a_cs).astype(dtype),
                         S_prevs.astype(dtype))
    y = (y_intra + y_inter).reshape(Bsz, S, H, Pd)
    return y[:, :S_real], S_fin


class MambaCache(dict):
    """Per-layer-stacked cache: ssm (L,B,H,N,P) + conv tails."""


def mamba_block(p, cfg: ModelConfig, x, cache=None):
    """x: (B,S,D). cache: None (train) or dict of conv tails + state (decode
    prefill capture).  Returns (x_out, new_cache_entries)."""
    from .act import constrain
    B, S, D = x.shape
    H, Pd, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    dt_ = x.dtype
    # pin the residual-stream sharding: nested scans (hybrid outer x inner)
    # otherwise let GSPMD drop the batch sharding of the loop carry,
    # replicating every SSD tensor across the data axis (§Perf iteration 4)
    x = constrain(x, ("batch", None, None))
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    z = jnp.einsum("bsd,de->bse", h, p["wz"].astype(dt_))
    xs = jnp.einsum("bsd,de->bse", h, p["wx"].astype(dt_))
    Bm = jnp.einsum("bsd,dn->bsn", h, p["wb"].astype(dt_))
    Cm = jnp.einsum("bsd,dn->bsn", h, p["wc"].astype(dt_))
    dtr = jnp.einsum("bsd,dh->bsh", h, p["wdt"].astype(dt_))

    xs, tail_x = _causal_depthwise_conv(xs, p["conv_x"], p["conv_bias_x"])
    Bm, tail_b = _causal_depthwise_conv(Bm, p["conv_b"], p["conv_bias_b"])
    Cm, tail_c = _causal_depthwise_conv(Cm, p["conv_c"], p["conv_bias_c"])

    dt = jax.nn.softplus(dtr.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, S_fin = ssd_chunked(xs.reshape(B, S, H, Pd), dt, A, Bm, Cm,
                           cfg.ssm_chunk)
    y = y + p["d_skip"].astype(dt_)[None, None, :, None] * \
        xs.reshape(B, S, H, Pd)
    y = y.reshape(B, S, cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = x + jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(dt_))
    new_cache = {"state": S_fin.astype(jnp.float32),
                 "tail_x": tail_x, "tail_b": tail_b, "tail_c": tail_c}
    return out, new_cache


def mamba_block_decode(p, cfg: ModelConfig, x, cache):
    """x: (B,1,D); cache entries per layer: state (B,H,N,P) f32 + conv tails."""
    B = x.shape[0]
    H, Pd, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    dt_ = x.dtype
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    z = jnp.einsum("bsd,de->bse", h, p["wz"].astype(dt_))
    xs = jnp.einsum("bsd,de->bse", h, p["wx"].astype(dt_))
    Bm = jnp.einsum("bsd,dn->bsn", h, p["wb"].astype(dt_))
    Cm = jnp.einsum("bsd,dn->bsn", h, p["wc"].astype(dt_))
    dtr = jnp.einsum("bsd,dh->bsh", h, p["wdt"].astype(dt_))

    xs, tail_x = _causal_depthwise_conv(xs, p["conv_x"], p["conv_bias_x"],
                                        tail=cache["tail_x"])
    Bm, tail_b = _causal_depthwise_conv(Bm, p["conv_b"], p["conv_bias_b"],
                                        tail=cache["tail_b"])
    Cm, tail_c = _causal_depthwise_conv(Cm, p["conv_c"], p["conv_bias_c"],
                                        tail=cache["tail_c"])

    dt = jax.nn.softplus(dtr.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))[:, 0]   # (B,H)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xs.reshape(B, H, Pd).astype(jnp.float32)
    Bv = Bm[:, 0].astype(jnp.float32)                              # (B,N)
    Cv = Cm[:, 0].astype(jnp.float32)
    S = cache["state"]
    decay = jnp.exp(dt * A[None, :])                               # (B,H)
    S_new = decay[:, :, None, None] * S + \
        jnp.einsum("bh,bn,bhp->bhnp", dt, Bv, xh)
    y = jnp.einsum("bn,bhnp->bhp", Cv, S_new)
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, 1, cfg.d_inner).astype(dt_)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = x + jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(dt_))
    return out, {"state": S_new, "tail_x": tail_x, "tail_b": tail_b,
                 "tail_c": tail_c}


def init_mamba_cache_specs(cfg: ModelConfig, n_layers: int, batch: int,
                           adtype):
    sds = jax.ShapeDtypeStruct
    H, Pd, N, ck = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.conv_kernel
    return {
        "state": sds((n_layers, batch, H, N, Pd), jnp.float32),
        "tail_x": sds((n_layers, batch, ck - 1, cfg.d_inner), adtype),
        "tail_b": sds((n_layers, batch, ck - 1, N), adtype),
        "tail_c": sds((n_layers, batch, ck - 1, N), adtype),
    }


class MambaModel(DenseModel):
    family = "ssm"

    def block_table(self) -> dict:
        return mamba_block_table(self.cfg)

    def apply_block(self, p, x, *, positions, q_offset=0):
        del positions, q_offset
        x, cache = mamba_block(p, self.cfg, x)
        return x, cache, jnp.zeros((), jnp.float32)

    def decode(self, params, cache, batch):
        cfg = self.cfg
        x = params["embed"].astype(self.adtype)[batch["token"]]

        def body(x, inp):
            lp, c = inp
            x, c2 = mamba_block_decode(lp, cfg, x, c)
            return x, c2

        x, new_cache = _act_scan(body, x, (params["layers"], cache))
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return self._logits(params, x), new_cache

    def cache_specs(self, shape: Shape):
        return init_mamba_cache_specs(self.cfg, self.cfg.n_layers,
                                      shape.batch, self.adtype)

    def cache_pspecs(self, shape: Shape, batch_axes, kv_axes):
        return {
            "state": PS(None, batch_axes, kv_axes, None, None),
            "tail_x": PS(None, batch_axes, None, kv_axes),
            "tail_b": PS(None, batch_axes, None, None),
            "tail_c": PS(None, batch_axes, None, None),
        }
