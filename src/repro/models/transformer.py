"""Dense decoder-only transformer (llama/qwen/granite family) and the
pixtral-style VLM variant (stub vision frontend: precomputed patch embeddings
prepended to the token sequence).

API (shared by all families, see registry.py):
  table()                      — parameter table (shapes + logical axes)
  init(key)                    — materialized params
  loss(params, batch)          — scalar train loss (batch: tokens/labels/...)
  prefill(params, batch)       — (last-token logits, kv cache)
  decode(params, cache, batch) — (logits, new cache)
  input_specs(shape)           — ShapeDtypeStructs for the dry-run
  batch_pspecs(shape)          — PartitionSpecs for inputs
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from .act import scan as _act_scan
from .config import ModelConfig, Shape
from .layers import (KVCache, dense_block, dense_block_decode, rmsnorm)
from .params import P, init_params, pspecs

__all__ = ["DenseModel"]


def stack_layers(table: dict, n: int) -> dict:
    """Prepend a stacked 'layers' dim to every leaf of a block table."""
    return jax.tree.map(
        lambda p: P((n,) + p.shape, ("layers",) + p.axes, p.init, p.scale),
        table, is_leaf=lambda x: isinstance(x, P))


def attn_table(cfg: ModelConfig) -> dict:
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    t = {
        "wq": P((D, H, hd), ("embed", "heads", None)),
        "wk": P((D, Hkv, hd), ("embed", "kv", None)),
        "wv": P((D, Hkv, hd), ("embed", "kv", None)),
        "wo": P((H, hd, D), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        t["bq"] = P((H, hd), ("heads", None), "zeros")
        t["bk"] = P((Hkv, hd), ("kv", None), "zeros")
        t["bv"] = P((Hkv, hd), ("kv", None), "zeros")
    if cfg.qk_norm:
        t["q_norm"] = P((hd,), (None,), "ones")
        t["k_norm"] = P((hd,), (None,), "ones")
    return t


def mlp_table(cfg: ModelConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "w_gate": P((D, F), ("embed", "mlp")),
        "w_up": P((D, F), ("embed", "mlp")),
        "w_down": P((F, D), ("mlp", "embed")),
    }


def block_table(cfg: ModelConfig) -> dict:
    return {
        "attn": attn_table(cfg),
        "mlp": mlp_table(cfg),
        "ln1": P((cfg.d_model,), (None,), "ones"),
        "ln2": P((cfg.d_model,), (None,), "ones"),
    }


def cross_entropy(logits, labels, mask=None):
    """Streamed CE: bf16 logits, fused f32 reductions (no f32 V-sized temp)."""
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(lf.max(axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


class DenseModel:
    family = "dense"

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.adtype = jnp.dtype(cfg.dtype)

    # ------------------------------------------------------------------
    # parameters
    # ------------------------------------------------------------------
    def table(self) -> dict:
        cfg = self.cfg
        t = {
            "embed": P((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                       scale=0.02),
            "layers": stack_layers(self.block_table(), cfg.n_layers),
            "ln_f": P((cfg.d_model,), (None,), "ones"),
        }
        if not cfg.tie_embeddings:
            t["lm_head"] = P((cfg.d_model, cfg.vocab), ("embed", "vocab"))
        return t

    def block_table(self) -> dict:
        return block_table(self.cfg)

    def init(self, key, dtype=jnp.float32):
        return init_params(self.table(), key, dtype)

    def param_pspecs(self, mesh_shape: dict, fsdp_axes=("data",)):
        return pspecs(self.table(), mesh_shape, fsdp_axes=fsdp_axes)

    # ------------------------------------------------------------------
    # blocks (overridden by MoE)
    # ------------------------------------------------------------------
    def apply_block(self, p, x, *, positions, q_offset=0):
        x, kv = dense_block(p, self.cfg, x, positions=positions,
                            q_offset=q_offset)
        return x, kv, jnp.zeros((), jnp.float32)  # (x, kv, aux_loss)

    def apply_block_decode(self, p, x, cache, pos):
        return dense_block_decode(p, self.cfg, x, cache, pos)

    # ------------------------------------------------------------------
    # forward passes
    # ------------------------------------------------------------------
    def _embed(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = params["embed"].astype(self.adtype)[tokens]
        if cfg.family == "vlm":
            x = jnp.concatenate(
                [batch["patch_embeds"].astype(self.adtype), x], axis=1)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        return x, positions

    def _backbone(self, params, x, positions, collect_cache: bool):
        cfg = self.cfg

        # NOTE: the scan carry is *only* the bf16 residual stream.  A mixed
        # (bf16, f32) carry makes XLA round-trip the full (L, B, S, D)
        # saved-residual stack through f32 every layer, defeating in-place
        # dynamic-update-slice (§Perf iteration 2) — aux losses travel
        # through the stacked per-layer outputs instead.
        def body(x, lp):
            x, kv, a = self.apply_block(lp, x, positions=positions)
            return x, ((kv, a) if collect_cache else a)

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, ys = _act_scan(body, x, params["layers"])
        if collect_cache:
            kvs, auxs = ys
        else:
            kvs, auxs = None, ys
        return (rmsnorm(x, params["ln_f"], cfg.norm_eps), jnp.sum(auxs),
                kvs)

    def _logits(self, params, x):
        cfg = self.cfg
        if cfg.tie_embeddings:
            w = params["embed"].astype(self.adtype).T
        else:
            w = params["lm_head"].astype(self.adtype)
        return jnp.einsum("bsd,dv->bsv", x, w)

    def loss(self, params, batch):
        cfg = self.cfg
        x, positions = self._embed(params, batch)
        x, aux, _ = self._backbone(params, x, positions, collect_cache=False)
        if cfg.family == "vlm":  # loss only on text positions
            x = x[:, cfg.n_patches:]
        logits = self._logits(params, x)
        return cross_entropy(logits, batch["labels"]) + 0.01 * aux

    def prefill(self, params, batch):
        x, positions = self._embed(params, batch)
        x, _, kvs = self._backbone(params, x, positions, collect_cache=True)
        logits = self._logits(params, x[:, -1:])
        return logits, kvs  # kvs: (k, v) stacked over layers

    def decode(self, params, cache, batch):
        """batch: {"token": (B,1) int32, "pos": scalar int32}."""
        cfg = self.cfg
        x = params["embed"].astype(self.adtype)[batch["token"]]
        pos = batch["pos"]

        def body(x, inp):
            lp, ck, cv = inp
            x, c2 = self.apply_block_decode(lp, x, KVCache(ck, cv), pos)
            return x, (c2.k, c2.v)

        x, new_cache = _act_scan(body, x,
                                    (params["layers"], cache[0], cache[1]))
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return self._logits(params, x), new_cache

    # ------------------------------------------------------------------
    # dry-run plumbing
    # ------------------------------------------------------------------
    def text_len(self, shape: Shape) -> int:
        if self.cfg.family == "vlm" and shape.kind == "train":
            return shape.seq - self.cfg.n_patches
        return shape.seq

    def input_specs(self, shape: Shape) -> dict:
        cfg = self.cfg
        B, S = shape.batch, shape.seq
        sds = jax.ShapeDtypeStruct
        if shape.kind == "train":
            spec = {"tokens": sds((B, self.text_len(shape)), jnp.int32),
                    "labels": sds((B, self.text_len(shape)), jnp.int32)}
            if cfg.family == "vlm":
                spec["patch_embeds"] = sds((B, cfg.n_patches, cfg.d_model),
                                           self.adtype)
            return spec
        if shape.kind == "prefill":
            spec = {"tokens": sds((B, self.text_len(shape)), jnp.int32)}
            if cfg.family == "vlm":
                spec["patch_embeds"] = sds((B, cfg.n_patches, cfg.d_model),
                                           self.adtype)
            return spec
        return {"token": sds((B, 1), jnp.int32),
                "pos": sds((), jnp.int32)}

    def batch_pspecs(self, shape: Shape, batch_axes) -> dict:
        spec = {}
        for k in self.input_specs(shape):
            if k == "pos":
                spec[k] = PS()
            elif k == "patch_embeds":
                spec[k] = PS(batch_axes, None, None)
            else:
                spec[k] = PS(batch_axes, None)
        return spec

    def cache_specs(self, shape: Shape) -> tuple:
        cfg = self.cfg
        B, S = shape.batch, shape.seq
        sds = jax.ShapeDtypeStruct
        shp = (cfg.n_layers, B, S, cfg.kv_cache_heads, cfg.hd)
        return (sds(shp, self.adtype), sds(shp, self.adtype))

    def cache_pspecs(self, shape: Shape, batch_axes, kv_axes) -> tuple:
        ps = PS(None, batch_axes, None, kv_axes, None)
        return (ps, ps)
