"""Model zoo: dense / MoE / SSM / hybrid / enc-dec / VLM backbones."""
from .config import ModelConfig, Shape, SHAPES
from .registry import get_model, MODEL_FAMILIES

__all__ = ["ModelConfig", "Shape", "SHAPES", "get_model", "MODEL_FAMILIES"]
