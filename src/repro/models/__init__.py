"""Model zoo: dense / MoE / SSM / hybrid / enc-dec / VLM backbones."""
from .config import SHAPES, ModelConfig, Shape
from .registry import MODEL_FAMILIES, get_model

__all__ = ["ModelConfig", "Shape", "SHAPES", "get_model", "MODEL_FAMILIES"]
