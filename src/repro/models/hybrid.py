"""Zamba2-style hybrid: Mamba2 backbone + *shared* full-attention blocks.

Every ``attn_every`` SSM layers, one of ``n_shared_blocks`` shared dense
transformer blocks is applied (parameters reused across applications,
alternating).  Each application keeps its own KV cache.  Zamba2's per-
application LoRA deltas on the shared block are omitted (DESIGN.md §5.3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from .act import scan as _act_scan
from .config import ModelConfig, Shape
from .layers import KVCache, dense_block, dense_block_decode, rmsnorm
from .mamba2 import (init_mamba_cache_specs, mamba_block, mamba_block_decode,
                     mamba_block_table)
from .params import P
from .transformer import DenseModel, block_table, stack_layers

__all__ = ["HybridModel"]


class HybridModel(DenseModel):
    family = "hybrid"

    def __init__(self, cfg: ModelConfig):
        super().__init__(cfg)
        assert cfg.attn_every > 0 and cfg.n_layers % cfg.attn_every == 0
        self.n_apps = cfg.n_layers // cfg.attn_every

    def table(self) -> dict:
        cfg = self.cfg
        t = {
            "embed": P((cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=0.02),
            "layers": stack_layers(mamba_block_table(cfg), cfg.n_layers),
            "shared": stack_layers(block_table(cfg), cfg.n_shared_blocks),
            "ln_f": P((cfg.d_model,), (None,), "ones"),
        }
        if not cfg.tie_embeddings:
            t["lm_head"] = P((cfg.d_model, cfg.vocab), ("embed", "vocab"))
        return t

    # ------------------------------------------------------------------
    def _group_params(self, params):
        """Reshape stacked mamba layers (L, ...) -> (n_apps, attn_every, ...)."""
        cfg = self.cfg
        return jax.tree.map(
            lambda a: a.reshape((self.n_apps, cfg.attn_every) + a.shape[1:]),
            params["layers"])

    def _backbone(self, params, x, positions, collect_cache: bool):
        cfg = self.cfg
        grouped = self._group_params(params)

        def outer(carry, inp):
            x = carry
            app_i, group_params = inp

            def inner(x, lp):
                x, c = mamba_block(lp, cfg, x)
                return x, (c if collect_cache else None)

            x, mcaches = _act_scan(inner, x, group_params)
            sp = jax.tree.map(lambda a: a[app_i % cfg.n_shared_blocks],
                              params["shared"])
            x, kv = dense_block(sp, cfg, x, positions=positions)
            return x, ((mcaches, kv) if collect_cache else None)

        if cfg.remat:
            outer = jax.checkpoint(
                outer, policy=jax.checkpoint_policies.nothing_saveable)
        x, caches = _act_scan(
            outer, x, (jnp.arange(self.n_apps), grouped))
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        aux = jnp.zeros((), jnp.float32)
        return x, aux, caches

    def prefill(self, params, batch):
        x, positions = self._embed(params, batch)
        x, _, caches = self._backbone(params, x, positions,
                                      collect_cache=True)
        mcaches, kvs = caches
        # mcaches leaves: (n_apps, attn_every, B, ...) -> flatten layer dims
        mcaches = jax.tree.map(
            lambda a: a.reshape((self.cfg.n_layers,) + a.shape[2:]), mcaches)
        logits = self._logits(params, x[:, -1:])
        return logits, {"ssm": mcaches, "kv_k": kvs[0], "kv_v": kvs[1]}

    def decode(self, params, cache, batch):
        cfg = self.cfg
        x = params["embed"].astype(self.adtype)[batch["token"]]
        pos = batch["pos"]
        grouped = self._group_params(params)
        ssm_grouped = jax.tree.map(
            lambda a: a.reshape((self.n_apps, cfg.attn_every) + a.shape[1:]),
            cache["ssm"])

        def outer(x, inp):
            app_i, gp, sc, ck, cv = inp

            def inner(x, lp_c):
                lp, c = lp_c
                x, c2 = mamba_block_decode(lp, cfg, x, c)
                return x, c2

            x, sc2 = _act_scan(inner, x, (gp, sc))
            sp = jax.tree.map(lambda a: a[app_i % cfg.n_shared_blocks],
                              params["shared"])
            x, kv2 = dense_block_decode(sp, cfg, x, KVCache(ck, cv), pos)
            return x, (sc2, kv2.k, kv2.v)

        x, (ssm2, k2, v2) = _act_scan(
            outer, x, (jnp.arange(self.n_apps), grouped, ssm_grouped,
                       cache["kv_k"], cache["kv_v"]))
        ssm2 = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), ssm2)
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return self._logits(params, x), {"ssm": ssm2, "kv_k": k2, "kv_v": v2}

    # ------------------------------------------------------------------
    def cache_specs(self, shape: Shape):
        cfg = self.cfg
        sds = jax.ShapeDtypeStruct
        kv = sds((self.n_apps, shape.batch, shape.seq, cfg.kv_cache_heads,
                  cfg.hd), self.adtype)
        return {
            "ssm": init_mamba_cache_specs(cfg, cfg.n_layers, shape.batch,
                                          self.adtype),
            "kv_k": kv,
            "kv_v": kv,
        }

    def cache_pspecs(self, shape: Shape, batch_axes, kv_axes):
        kv = PS(None, batch_axes, None, kv_axes, None)
        return {
            "ssm": {
                "state": PS(None, batch_axes, kv_axes, None, None),
                "tail_x": PS(None, batch_axes, None, kv_axes),
                "tail_b": PS(None, batch_axes, None, None),
                "tail_c": PS(None, batch_axes, None, None),
            },
            "kv_k": kv,
            "kv_v": kv,
        }
