"""Logical activation-sharding constraints (MaxText-style axis rules).

Model code annotates activations with *logical* axes ("batch", "model",
None); when a launcher installs an activation mesh (``activation_mesh``),
the annotations become ``with_sharding_constraint`` calls — including uneven
shardings (e.g. 40 heads over a 16-way model axis), which GSPMD pads.
Without an installed mesh the annotations are no-ops, so unit tests and
single-device paths are unaffected.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding, PartitionSpec as PS

__all__ = ["activation_mesh", "constrain", "unrolled_scans", "scan",
           "legacy_f32_internals", "legacy_f32"]

_CTX: contextvars.ContextVar = contextvars.ContextVar("act_mesh",
                                                      default=None)
_UNROLL: contextvars.ContextVar = contextvars.ContextVar("unroll_scans",
                                                         default=False)


@contextlib.contextmanager
def activation_mesh(mesh, batch_axes):
    """Install (mesh, batch_axes) for the duration of a trace/lowering."""
    token = _CTX.set((mesh, batch_axes))
    try:
        yield
    finally:
        _CTX.reset(token)


@contextlib.contextmanager
def unrolled_scans():
    """Fully unroll every model scan (layers / KV blocks / SSD chunks).

    Used by the dry-run so ``compiled.cost_analysis()`` and the collective-op
    parse see every repetition explicitly — XLA's cost analysis does not
    multiply while-loop bodies by their trip counts."""
    token = _UNROLL.set(True)
    try:
        yield
    finally:
        _UNROLL.reset(token)


def scan(f, init, xs, **kw):
    """lax.scan that honors the dry-run unroll context."""
    import jax

    if _UNROLL.get():
        kw = dict(kw, unroll=True)
    return jax.lax.scan(f, init, xs, **kw)


_LEGACY_F32: contextvars.ContextVar = contextvars.ContextVar(
    "legacy_f32", default=False)


@contextlib.contextmanager
def legacy_f32_internals():
    """Ablation toggle (§Perf iteration 1 baseline): full-f32 norm/rope/SSD
    internals — materializes f32 activation-sized temporaries."""
    token = _LEGACY_F32.set(True)
    try:
        yield
    finally:
        _LEGACY_F32.reset(token)


def legacy_f32() -> bool:
    return _LEGACY_F32.get()


def constrain(x, logical: tuple):
    """logical entries: "batch" | "model" | None per dim of ``x``."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, batch_axes = ctx
    spec = tuple(batch_axes if a == "batch" else
                 ("model" if a == "model" else None) for a in logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, PS(*spec)))


def current_mesh():
    """(mesh, batch_axes) when a launcher installed one, else None."""
    return _CTX.get()
