"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a stub per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, enc_seq, d_model).  Positions use sinusoidal
embeddings computed on the fly for both stacks (whisper's decoder uses a
learned table capped at 448; a computed table keeps the params independent of
the 32k decode shape — recorded in DESIGN.md §5.3).

Blocks follow whisper: pre-LayerNorm (with bias), biased attention
projections, GELU MLP; decoder adds cross-attention over encoder output
(cross KV computed once at prefill and cached).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from .act import scan as _act_scan
from .act import constrain
from .config import ModelConfig, Shape
from .layers import cast, flash_attention, gelu_mlp
from .params import P
from .transformer import DenseModel, cross_entropy, stack_layers

__all__ = ["EncDecModel"]


def layernorm(x, p, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y.astype(x.dtype) * p["w"].astype(x.dtype) +
            p["b"].astype(x.dtype))


def _ln_table(D):
    return {"w": P((D,), (None,), "ones"), "b": P((D,), (None,), "zeros")}


def _attn_table(cfg: ModelConfig):
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    return {
        "wq": P((D, H, hd), ("embed", "heads", None)),
        "wk": P((D, H, hd), ("embed", "heads", None)),
        "wv": P((D, H, hd), ("embed", "heads", None)),
        "wo": P((H, hd, D), ("heads", None, "embed")),
        "bq": P((H, hd), ("heads", None), "zeros"),
        "bv": P((H, hd), ("heads", None), "zeros"),
        "bo": P((D,), (None,), "zeros"),
    }


def _mlp_table(cfg: ModelConfig):
    D, F = cfg.d_model, cfg.d_ff
    return {
        "w_in": P((D, F), ("embed", "mlp")),
        "b_in": P((F,), ("mlp",), "zeros"),
        "w_out": P((F, D), ("mlp", "embed")),
        "b_out": P((D,), (None,), "zeros"),
    }


def sinusoid_positions(S, D, offset=0):
    pos = offset + jnp.arange(S, dtype=jnp.float32)
    half = D // 2
    freq = jnp.exp(-math.log(10000.0) *
                   jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _mha(p, cfg, xq, xkv=None, *, causal, q_offset=0, kv_len=None,
         kv_override=None):
    """Whisper-flavoured MHA (no rope, biased q/v/o projections)."""
    H, hd = cfg.n_heads, cfg.hd
    dt = xq.dtype
    B, Sq = xq.shape[:2]
    q = jnp.einsum("bsd,dhe->bshe", xq, cast(p["wq"], dt)) + cast(p["bq"], dt)
    if kv_override is not None:
        k, v = kv_override
    else:
        src = xq if xkv is None else xkv
        k = jnp.einsum("bsd,dhe->bshe", src, cast(p["wk"], dt))
        v = jnp.einsum("bsd,dhe->bshe", src, cast(p["wv"], dt)) + \
            cast(p["bv"], dt)
    qg = q.reshape(B, Sq, H, 1, hd)
    out = flash_attention(qg, k, v, causal=causal, q_offset=q_offset,
                          kv_len=kv_len)
    out = out.reshape(B, Sq, H, hd)
    y = jnp.einsum("bshe,hed->bsd", out, cast(p["wo"], dt)) + cast(p["bo"], dt)
    return y, (k, v)


class EncDecModel(DenseModel):
    family = "encdec"

    def table(self) -> dict:
        cfg = self.cfg
        enc_block = {
            "attn": _attn_table(cfg), "mlp": _mlp_table(cfg),
            "ln1": _ln_table(cfg.d_model), "ln2": _ln_table(cfg.d_model),
        }
        dec_block = {
            "attn": _attn_table(cfg), "xattn": _attn_table(cfg),
            "mlp": _mlp_table(cfg),
            "ln1": _ln_table(cfg.d_model), "lnx": _ln_table(cfg.d_model),
            "ln2": _ln_table(cfg.d_model),
        }
        return {
            "embed": P((cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=0.02),
            "enc_layers": stack_layers(enc_block, cfg.n_enc_layers),
            "dec_layers": stack_layers(dec_block, cfg.n_layers),
            "enc_ln_f": _ln_table(cfg.d_model),
            "dec_ln_f": _ln_table(cfg.d_model),
        }

    # ------------------------------------------------------------------
    def encode(self, params, frames):
        cfg = self.cfg
        x = frames.astype(self.adtype)
        x = x + sinusoid_positions(x.shape[1], cfg.d_model).astype(x.dtype)

        def body(x, p):
            x = constrain(x, ("batch", None, None))  # pin carry sharding
            h, _ = _mha(p["attn"], cfg, layernorm(x, p["ln1"], cfg.norm_eps),
                        causal=False)
            x = x + h
            x = x + gelu_mlp(p["mlp"], layernorm(x, p["ln2"], cfg.norm_eps))
            return x, None

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = _act_scan(body, x, params["enc_layers"])
        return layernorm(x, params["enc_ln_f"], cfg.norm_eps)

    def _decode_stack(self, params, x, enc_out, *, q_offset=0,
                      collect_cache=False):
        cfg = self.cfg

        def body(x, p):
            x = constrain(x, ("batch", None, None))  # pin carry sharding
            h, kv = _mha(p["attn"], cfg,
                         layernorm(x, p["ln1"], cfg.norm_eps),
                         causal=True, q_offset=q_offset)
            x = x + h
            h, xkv = _mha(p["xattn"], cfg,
                          layernorm(x, p["lnx"], cfg.norm_eps), enc_out,
                          causal=False)
            x = x + h
            x = x + gelu_mlp(p["mlp"], layernorm(x, p["ln2"], cfg.norm_eps))
            return x, ((kv, xkv) if collect_cache else None)

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, caches = _act_scan(body, x, params["dec_layers"])
        return layernorm(x, params["dec_ln_f"], cfg.norm_eps), caches

    def _embed_tokens(self, params, tokens, offset=0):
        x = params["embed"].astype(self.adtype)[tokens]
        return x + sinusoid_positions(x.shape[1], self.cfg.d_model,
                                      offset).astype(x.dtype)

    def loss(self, params, batch):
        enc_out = self.encode(params, batch["frames"])
        x = self._embed_tokens(params, batch["tokens"])
        x, _ = self._decode_stack(params, x, enc_out)
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embed"].astype(self.adtype))
        return cross_entropy(logits, batch["labels"])

    def prefill(self, params, batch):
        enc_out = self.encode(params, batch["frames"])
        x = self._embed_tokens(params, batch["tokens"])
        x, caches = self._decode_stack(params, x, enc_out,
                                       collect_cache=True)
        (k, v), (xk, xv) = caches
        logits = jnp.einsum("bsd,vd->bsv", x[:, -1:],
                            params["embed"].astype(self.adtype))
        return logits, {"k": k, "v": v, "xk": xk, "xv": xv}

    def decode(self, params, cache, batch):
        cfg = self.cfg
        pos = batch["pos"]
        x = self._embed_tokens(params, batch["token"], offset=pos)

        def body(x, inp):
            p, ck, cv, xk, xv = inp
            h = layernorm(x, p["ln1"], cfg.norm_eps)
            B = x.shape[0]
            dt = x.dtype
            q = jnp.einsum("bsd,dhe->bshe", h, cast(p["attn"]["wq"], dt)) + \
                cast(p["attn"]["bq"], dt)
            k_new = jnp.einsum("bsd,dhe->bshe", h, cast(p["attn"]["wk"], dt))
            v_new = jnp.einsum("bsd,dhe->bshe", h,
                               cast(p["attn"]["wv"], dt)) + \
                cast(p["attn"]["bv"], dt)
            pos32 = jnp.asarray(pos, jnp.int32)
            z = jnp.zeros((), jnp.int32)
            ck = jax.lax.dynamic_update_slice(ck, k_new.astype(ck.dtype),
                                              (z, pos32, z, z))
            cv = jax.lax.dynamic_update_slice(cv, v_new.astype(cv.dtype),
                                              (z, pos32, z, z))
            qg = q.reshape(B, 1, cfg.n_heads, 1, cfg.hd)
            o = flash_attention(qg, ck, cv, causal=False, kv_len=pos + 1)
            o = o.reshape(B, 1, cfg.n_heads, cfg.hd)
            x = x + jnp.einsum("bshe,hed->bsd", o,
                               cast(p["attn"]["wo"], dt)) + \
                cast(p["attn"]["bo"], dt)
            h, _ = _mha(p["xattn"], cfg, layernorm(x, p["lnx"], cfg.norm_eps),
                        causal=False, kv_override=(xk, xv))
            x = x + h
            x = x + gelu_mlp(p["mlp"], layernorm(x, p["ln2"], cfg.norm_eps))
            return x, (ck, cv)

        x, (k2, v2) = _act_scan(
            body, x, (params["dec_layers"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        x = layernorm(x, params["dec_ln_f"], cfg.norm_eps)
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embed"].astype(self.adtype))
        return logits, {"k": k2, "v": v2, "xk": cache["xk"],
                        "xv": cache["xv"]}

    # ------------------------------------------------------------------
    def input_specs(self, shape: Shape) -> dict:
        cfg = self.cfg
        B, S = shape.batch, shape.seq
        sds = jax.ShapeDtypeStruct
        frames = sds((B, cfg.enc_seq, cfg.d_model), self.adtype)
        if shape.kind == "train":
            return {"frames": frames,
                    "tokens": sds((B, S), jnp.int32),
                    "labels": sds((B, S), jnp.int32)}
        if shape.kind == "prefill":
            return {"frames": frames, "tokens": sds((B, S), jnp.int32)}
        return {"token": sds((B, 1), jnp.int32), "pos": sds((), jnp.int32)}

    def batch_pspecs(self, shape: Shape, batch_axes) -> dict:
        spec = {}
        for k in self.input_specs(shape):
            if k == "pos":
                spec[k] = PS()
            elif k == "frames":
                spec[k] = PS(batch_axes, None, None)
            else:
                spec[k] = PS(batch_axes, None)
        return spec

    def cache_specs(self, shape: Shape):
        cfg = self.cfg
        sds = jax.ShapeDtypeStruct
        L, B = cfg.n_layers, shape.batch
        return {
            "k": sds((L, B, shape.seq, cfg.n_heads, cfg.hd), self.adtype),
            "v": sds((L, B, shape.seq, cfg.n_heads, cfg.hd), self.adtype),
            "xk": sds((L, B, cfg.enc_seq, cfg.n_heads, cfg.hd), self.adtype),
            "xv": sds((L, B, cfg.enc_seq, cfg.n_heads, cfg.hd), self.adtype),
        }

    def cache_pspecs(self, shape: Shape, batch_axes, kv_axes):
        ps = PS(None, batch_axes, None, kv_axes, None)
        return {"k": ps, "v": ps, "xk": ps, "xv": ps}
