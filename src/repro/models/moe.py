"""Mixture-of-Experts decoder (moonlight/granite-moe family).

Dispatch: capacity-based, group-local (MaxText-style but scatter-add instead
of a materialized dispatch one-hot): tokens are grouped (group ~ one sequence
slice), each token's top-k experts are ranked by a group-local cumulative
count, and tokens are scattered into an (groups, experts*capacity, d) buffer.
Expert FFNs run as a batched einsum with experts sharded over the "model"
axis (EP); the combine gather is the returning all-to-all.  Aux
load-balancing loss (Switch-style) is accumulated through the layer scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from .act import constrain, current_mesh
from .config import ModelConfig
from .layers import attention, decode_attention, rmsnorm, swiglu
from .params import P
from .transformer import DenseModel, attn_table

__all__ = ["MoEModel"]

_GROUP = 512  # tokens per dispatch group


def moe_table(cfg: ModelConfig) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ax = ("expert", "embed", "mlp") if cfg.moe_shard_dim == "expert" else \
         (None, "embed", "mlp")
    t = {
        "router": P((D, E), ("embed", None)),
        "w_gate": P((E, D, F), ax),
        "w_up": P((E, D, F), ax),
        "w_down": P((E, F, D), (ax[0], ax[2], ax[1])),
    }
    if cfg.n_shared_experts:
        t["shared"] = {
            "w_gate": P((D, cfg.n_shared_experts * F), ("embed", "mlp")),
            "w_up": P((D, cfg.n_shared_experts * F), ("embed", "mlp")),
            "w_down": P((cfg.n_shared_experts * F, D), ("mlp", "embed")),
        }
    return t


def moe_mlp(p, cfg: ModelConfig, x):
    """x: (B, S, D) -> (y, aux_loss)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    Sg = min(_GROUP, S)
    G = (B * S) // Sg
    xg = x.reshape(G, Sg, D)

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, K)                  # (G,Sg,K)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e f_e * P_e
    f = jnp.zeros((G, E)).at[
        jnp.arange(G)[:, None, None], topi].add(1.0) / (Sg * K)
    aux = (E * (f * probs.mean(axis=1)).sum(-1)).mean()

    # group-local rank of each (token, k) within its expert
    onehot = jax.nn.one_hot(topi.reshape(G, Sg * K), E, dtype=jnp.int32)
    ranks = (jnp.cumsum(onehot, axis=1) - onehot)         # (G, Sg*K, E)
    rank = jnp.take_along_axis(
        ranks, topi.reshape(G, Sg * K, 1), axis=2)[..., 0].reshape(G, Sg, K)
    C = max(int(Sg * K * cfg.moe_capacity_factor / E), K)
    keep = rank < C
    slot = topi * C + jnp.minimum(rank, C - 1)            # (G,Sg,K) in [0,EC)

    dt = x.dtype
    wts = (topv * keep).astype(dt)                        # (G,Sg,K)

    ctx = current_mesh()
    if ctx is not None and cfg.moe_shard_dim == "expert" and \
            E % ctx[0].shape["model"] == 0:
        out = _expert_apply_ep(ctx, cfg, p, xg, slot, wts, C)
    else:
        # fallback (tests / mlp-sharded experts): local scatter + einsums
        xk = (xg[:, :, None, :] * keep[..., None].astype(dt))  # (G,Sg,K,D)
        buf = jnp.zeros((G, E * C, D), dt)
        gidx = jnp.arange(G)[:, None, None]
        buf = buf.at[gidx, slot].add(xk)
        buf = buf.reshape(G, E, C, D)
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf,
                                   p["w_gate"].astype(dt)))
        h = h * jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(dt))
        y = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))
        y = y.reshape(G, E * C, D)
        yk = y[gidx, slot]                                # (G,Sg,K,D) gather
        out = (yk * wts[..., None]).sum(axis=2)
    out = out.reshape(B, S, D)
    if cfg.n_shared_experts:
        out = out + swiglu(p["shared"], x)
    return out, aux.astype(jnp.float32)


def _expert_apply_ep(ctx, cfg: ModelConfig, p, xg, slot, wts, C: int):
    """Expert-parallel dispatch + compute + combine, entirely under
    shard_map (§Perf iterations 2a/2b).

    The pjit formulation let GSPMD replicate the (G,Sg,K,D) dispatch tensor
    and all-reduce the full expert buffer (~116 GB/device/layer on
    moonshot).  Here every model shard owns E/n contiguous experts: it
    scatters ONLY its own experts' tokens into a local (G_l, E_l*C, D)
    buffer (zero comm), runs its expert FFNs, gathers its tokens' outputs
    locally, and one activation-sized psum performs the combine — the
    returning all-to-all expressed as a masked partial sum."""
    mesh, batch_axes = ctx
    E = cfg.n_experts
    n_shards = mesh.shape["model"]
    E_l = E // n_shards
    ba = batch_axes if not isinstance(batch_axes, str) else (batch_axes,)
    ba_spec = tuple(ba) if len(ba) > 1 else ba[0]

    def local(xg_l, wg, wu, wd, slot_l, wts_l):
        # xg_l: (G_l, Sg, D); wg/wu: (E_l, D, F); wd: (E_l, F, D)
        dt = xg_l.dtype
        G_l, Sg, D = xg_l.shape
        idx = jax.lax.axis_index("model")
        lslot = slot_l - idx * (E_l * C)                  # (G_l,Sg,K)
        owned = (lslot >= 0) & (lslot < E_l * C)
        w_here = jnp.where(owned, wts_l, 0).astype(dt)
        xk = xg_l[:, :, None, :] * (owned[..., None]).astype(dt)
        g = jnp.arange(G_l)[:, None, None]
        buf = jnp.zeros((G_l, E_l * C, D), dt)
        buf = buf.at[g, jnp.clip(lslot, 0, E_l * C - 1)].add(xk)
        buf = buf.reshape(G_l, E_l, C, D)
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, wg.astype(dt)))
        h = h * jnp.einsum("gecd,edf->gecf", buf, wu.astype(dt))
        y = jnp.einsum("gecf,efd->gecd", h, wd.astype(dt))
        y = y.reshape(G_l, E_l * C, D)
        vals = y[g, jnp.clip(lslot, 0, E_l * C - 1)]      # (G_l,Sg,K,D)
        part = (vals * w_here[..., None]).sum(axis=2).astype(dt)
        return jax.lax.psum(part, "model")

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(PS(ba_spec, None, None), PS("model", None, None),
                  PS("model", None, None), PS("model", None, None),
                  PS(ba_spec, None, None), PS(ba_spec, None, None)),
        out_specs=PS(ba_spec, None, None),
        check_vma=False)
    return fn(xg, p["w_gate"], p["w_up"], p["w_down"], slot, wts)


class MoEModel(DenseModel):
    family = "moe"

    def block_table(self) -> dict:
        cfg = self.cfg
        return {
            "attn": attn_table(cfg),
            "moe": moe_table(cfg),
            "ln1": P((cfg.d_model,), (None,), "ones"),
            "ln2": P((cfg.d_model,), (None,), "ones"),
        }

    def apply_block(self, p, x, *, positions, q_offset=0):
        cfg = self.cfg
        x = constrain(x, ("batch", None, None))  # pin loop-carry sharding
        h, kv = attention(p["attn"], cfg, rmsnorm(x, p["ln1"], cfg.norm_eps),
                          positions=positions, q_offset=q_offset)
        x = x + h
        m, aux = moe_mlp(p["moe"], cfg, rmsnorm(x, p["ln2"], cfg.norm_eps))
        return x + m, kv, aux

    def apply_block_decode(self, p, x, cache, pos):
        cfg = self.cfg
        h, cache = decode_attention(p["attn"], cfg,
                                    rmsnorm(x, p["ln1"], cfg.norm_eps),
                                    cache, pos)
        x = x + h
        m, _ = moe_mlp(p["moe"], cfg, rmsnorm(x, p["ln2"], cfg.norm_eps))
        return x + m, cache
