"""Model and shape configuration dataclasses."""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ModelConfig", "Shape", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab: int = 0
    head_dim: Optional[int] = None
    qk_norm: bool = False
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_shard_dim: str = "expert"     # "expert" (EP) or "mlp" (TP-in-expert)
    n_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_kernel: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2)
    attn_every: int = 0               # shared attention block period
    n_shared_blocks: int = 1          # alternating shared blocks
    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 0                  # precomputed frame-embedding count
    # vlm (pixtral)
    n_patches: int = 0                # precomputed patch-embedding count
    # serving
    kv_cache_pad_heads: int = 0   # pad cached KV heads to a multiple of this
                                  # (0 = off) so the cache can shard over the
                                  # model axis when n_kv_heads doesn't divide
    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    remat: bool = True

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def kv_cache_heads(self) -> int:
        """Cached KV head count (>= n_kv_heads; padded when configured)."""
        p = self.kv_cache_pad_heads
        if p <= 0:
            return self.n_kv_heads
        return ((self.n_kv_heads + p - 1) // p) * p

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def supports_long_context(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has a decode path (whisper is enc-dec)


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq: int
    batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}
