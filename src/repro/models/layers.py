"""Shared neural-net layers: RMSNorm, RoPE, GQA attention (flash-style
blocked softmax in pure JAX), SwiGLU/GELU MLPs, and the dense decoder block.

Conventions:
* params are f32 pytrees; activations/compute default to bf16 with f32
  softmax/normalization internals;
* attention uses an online-softmax scan over KV blocks (memory O(S·block)
  instead of O(S^2)) — this is the pure-JAX flash pattern, needed so the 4k
  train and 32k prefill shapes fit HBM at compile time (dry-run requirement);
* GQA never materializes repeated KV heads (grouped einsum);
* every function is shard_map/pjit friendly: no data-dependent shapes.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .act import constrain, scan as _act_scan
from .config import ModelConfig

__all__ = [
    "rmsnorm", "rope", "flash_attention", "attention", "decode_attention",
    "swiglu", "gelu_mlp", "dense_block", "dense_block_decode", "KVCache",
]

DEFAULT_KV_BLOCK = 1024


def cast(x, dtype):
    return x.astype(dtype) if x.dtype != dtype else x


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x, w, eps: float = 1e-5):
    """RMSNorm with f32 statistics but compute-dtype activation tensors in
    BOTH directions: a plain autodiff rmsnorm leaks f32 (B,S,D) cotangents
    into the residual stream (through the f32 mean-of-squares), doubling
    the d_model all-reduce and save-restore traffic — the custom VJP keeps
    dx in x.dtype (§Perf iteration 2b)."""
    y, _ = _rmsnorm_fwd(x, w, eps)
    return y


def _rms_scale(x, eps):
    ss = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    return jax.lax.rsqrt(ss / x.shape[-1] + eps)           # f32 (..., )


def _rmsnorm_fwd(x, w, eps):
    scale = _rms_scale(x, eps)
    y = x * scale[..., None].astype(x.dtype) * w.astype(x.dtype)
    return y, (x, w, scale)


def _rmsnorm_bwd(eps, res, dy):
    x, w, scale = res
    D = x.shape[-1]
    wb = w.astype(x.dtype)
    # d/dx [x_i * s(x) * w_i] with s = rsqrt(mean(x^2)+eps):
    #   dx = s * w * dy  -  x * s^3/D * sum_j(dy_j * w_j * x_j)
    dyw = dy * wb
    inner = jnp.einsum("...d,...d->...", dyw, x,
                       preferred_element_type=jnp.float32)  # f32 stats only
    coef = inner * (scale ** 3) / D
    dx = (dyw * scale[..., None].astype(x.dtype) -
          x * coef[..., None].astype(x.dtype))
    # dw: reduce over all leading dims with f32 accumulation
    xs = x * scale[..., None].astype(x.dtype)
    red = tuple(range(x.ndim - 1))
    dw = jnp.sum((dy * xs).astype(jnp.float32), axis=red).astype(w.dtype)
    return dx, dw


rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def _rope_angles(positions, hd: int, theta: float):
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) *
                    jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S).

    The sin/cos tables are f32 (small: no head dim); the rotation itself
    runs in the compute dtype so no f32 activation-sized temps are
    materialized (§Perf iteration 1)."""
    from .act import legacy_f32
    hd = x.shape[-1]
    cos, sin = _rope_angles(positions, hd, theta)     # (..., S, half) f32
    if legacy_f32():
        cos = cos[..., None, :]
        sin = sin[..., None, :]
        x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
        return jnp.concatenate(
            [x1 * cos - x2 * sin, x1 * sin + x2 * cos],
            axis=-1).astype(x.dtype)
    cos = cos[..., None, :].astype(x.dtype)            # broadcast over heads
    sin = sin[..., None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)


# ---------------------------------------------------------------------------
# flash-style attention (pure JAX online softmax over KV blocks)
# ---------------------------------------------------------------------------

def _flash_scan(q, k, v, causal: bool, q_offset, kv_len, block: int):
    """Forward online-softmax scan. Returns (out, m, lse) with out already
    normalized; m/lse are the per-query statistics needed by the custom
    backward."""
    B, Sq, Hkv, G, hd = q.shape
    Skv = k.shape[1]
    blk = min(block, Skv)
    nblk = (Skv + blk - 1) // blk
    pad = nblk * blk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = 1.0 / math.sqrt(hd)
    q_pos = q_offset + jnp.arange(Sq)

    kb = k.reshape(B, nblk, blk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, blk, Hkv, hd).transpose(1, 0, 2, 3, 4)

    def step(carry, inp):
        m, lse, acc = carry
        bi, kblk, vblk = inp
        k_pos = bi * blk + jnp.arange(blk)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q, kblk,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((Sq, blk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        mask &= k_pos[None, :] < (Skv if kv_len is None else kv_len)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = lse * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, hd), jnp.float32)
    (m, lse, acc), _ = _act_scan(
        step, (m0, l0, a0), (jnp.arange(nblk), kb, vb))
    out = acc / jnp.maximum(lse[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (B,Sq,Hkv,G,hd)
    return out, m, jnp.maximum(lse, 1e-30)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_custom(q, k, v, causal: bool, q_offset: int, block: int):
    out, _, _ = _flash_scan(q, k, v, causal, q_offset, None, block)
    return out


def _flash_custom_fwd(q, k, v, causal, q_offset, block):
    out, m, lse = _flash_scan(q, k, v, causal, q_offset, None, block)
    return out, (q, k, v, out, m, lse)


def _flash_custom_bwd(causal, q_offset, block, res, dout):
    """Flash-attention backward: recompute scores per KV block instead of
    saving the per-block f32 (nblk, ...) statistics stacks jax autodiff
    creates for the forward scan (§Perf iteration 3) — residuals are just
    (q, k, v, out) plus the (B,Hkv,G,Sq) f32 softmax stats."""
    q, k, v, out, m, lse = res
    B, Sq, Hkv, G, hd = q.shape
    Skv = k.shape[1]
    blk = min(block, Skv)
    nblk = (Skv + blk - 1) // blk
    pad = nblk * blk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = 1.0 / math.sqrt(hd)
    q_pos = q_offset + jnp.arange(Sq)
    kb = k.reshape(B, nblk, blk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, blk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    do = dout.transpose(0, 2, 3, 1, 4)                 # (B,Hkv,G,Sq,hd)
    # delta_i = sum_d dout_i * out_i  (f32 stats, no big f32 tensors)
    delta = jnp.einsum("bqhgd,bqhgd->bhgq", dout.astype(jnp.float32),
                       out.astype(jnp.float32))

    def step(dq_acc, inp):
        bi, kblk, vblk = inp
        k_pos = bi * blk + jnp.arange(blk)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q, kblk,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((Sq, blk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        mask &= k_pos[None, :] < Skv
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        p = (jnp.exp(s - m[..., None]) / lse[..., None]).astype(q.dtype)
        dv_blk = jnp.einsum("bhgqk,bhgqd->bkhd", p, do.astype(q.dtype))
        dp = jnp.einsum("bhgqd,bkhd->bhgqk", do.astype(q.dtype), vblk)
        ds = (p * (dp - delta[..., None].astype(q.dtype)) *
              q.dtype.type(scale))
        dq_acc = dq_acc + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kblk,
                                     preferred_element_type=jnp.float32)
        dk_blk = jnp.einsum("bhgqk,bqhgd->bkhd", ds, q)
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((B, Sq, Hkv, G, hd), jnp.float32)
    dq, (dks, dvs) = _act_scan(step, dq0, (jnp.arange(nblk), kb, vb))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, nblk * blk, Hkv, hd)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, nblk * blk, Hkv, hd)
    return (dq.astype(q.dtype), dk[:, :Skv].astype(k.dtype),
            dv[:, :Skv].astype(v.dtype))


_flash_custom.defvjp(_flash_custom_fwd, _flash_custom_bwd)


def flash_attention(q, k, v, *, causal: bool, q_offset=0,
                    kv_len: Optional[jax.Array] = None,
                    block: int = DEFAULT_KV_BLOCK):
    """q: (B, Sq, Hkv, G, hd); k/v: (B, Skv, Hkv, hd).

    ``q_offset``: absolute position of q[0] (prefill continuation / decode).
    ``kv_len``: optional dynamic valid-KV length (decode against a cache).
    Train/prefill (static offset, no kv_len) uses the custom-VJP flash
    backward; the decode path keeps the plain scan (no grads needed)."""
    if kv_len is None and isinstance(q_offset, int):
        return _flash_custom(q, k, v, causal, q_offset,
                             min(block, k.shape[1]))
    out, _, _ = _flash_scan(q, k, v, causal, q_offset, kv_len, block)
    return out


# ---------------------------------------------------------------------------
# attention layers
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array  # (B, Smax, Hkv, hd)
    v: jax.Array


def _pad_heads(t, target: int):
    """Pad the head dim (axis -2) of (B, S, h, hd) up to ``target`` heads."""
    if t.shape[-2] >= target:
        return t
    pad = [(0, 0)] * t.ndim
    pad[-2] = (0, target - t.shape[-2])
    return jnp.pad(t, pad)


def _project_qkv(p, cfg: ModelConfig, x, positions):
    B, S, D = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = x.dtype
    q = jnp.einsum("bsd,dhe->bshe", x, cast(p["wq"], dt))
    k = jnp.einsum("bsd,dhe->bshe", x, cast(p["wk"], dt))
    v = jnp.einsum("bsd,dhe->bshe", x, cast(p["wv"], dt))
    # TP over heads for the attention activations (uneven counts padded by
    # GSPMD; see models/act.py) — breaks model-axis redundancy when the head
    # count does not divide the mesh axis.
    q = constrain(q, ("batch", None, "model", None))
    k = constrain(k, ("batch", None, "model", None))
    v = constrain(v, ("batch", None, "model", None))
    if cfg.qkv_bias:
        q = q + cast(p["bq"], dt)
        k = k + cast(p["bk"], dt)
        v = v + cast(p["bv"], dt)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if positions is not None:  # rope (None for whisper-style abs-pos models)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention(p, cfg: ModelConfig, x, *, positions, causal: bool = True,
              q_offset=0, kv_override=None):
    """Full-sequence attention (train / prefill / encoder / cross).

    Returns (out, (k, v)) — k/v for cache capture during prefill.
    ``kv_override``: (k, v) for cross-attention (keys from the encoder).
    """
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // Hkv
    if kv_override is None:
        q, k, v = _project_qkv(p, cfg, x, positions)
    else:
        dt = x.dtype
        q = jnp.einsum("bsd,dhe->bshe", x, cast(p["wq"], dt))
        if cfg.qkv_bias:
            q = q + cast(p["bq"], dt)
        k, v = kv_override
    B, S = x.shape[:2]
    qg = q.reshape(B, S, Hkv, G, hd)
    out = flash_attention(qg, k, v, causal=causal, q_offset=q_offset)
    out = out.reshape(B, S, H, hd)
    y = jnp.einsum("bshe,hed->bsd", out, cast(p["wo"], x.dtype))
    # the cache copy is padded to kv_cache_heads so it can shard evenly
    kvc = cfg.kv_cache_heads
    return y, (_pad_heads(k, kvc), _pad_heads(v, kvc))


def decode_attention(p, cfg: ModelConfig, x, cache: KVCache, pos):
    """Single-token decode against a KV cache. x: (B, 1, D); pos: scalar.

    Supports KV caches whose head dim is padded to ``cfg.kv_cache_heads``
    (for even model-axis sharding): padded q rows are zero and their outputs
    are sliced away."""
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // Hkv
    B = x.shape[0]
    kvc = cache.k.shape[-2]
    pos = jnp.asarray(pos, jnp.int32)
    z = jnp.zeros((), jnp.int32)
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    k = jax.lax.dynamic_update_slice(
        cache.k, _pad_heads(k_new, kvc).astype(cache.k.dtype),
        (z, pos, z, z))
    v = jax.lax.dynamic_update_slice(
        cache.v, _pad_heads(v_new, kvc).astype(cache.v.dtype),
        (z, pos, z, z))
    qg = q.reshape(B, 1, Hkv, G, hd)
    if kvc > Hkv:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, kvc - Hkv), (0, 0), (0, 0)))
    out = flash_attention(qg, k, v, causal=False, kv_len=pos + 1)
    out = out[:, :, :Hkv].reshape(B, 1, H, hd)
    y = jnp.einsum("bshe,hed->bsd", out, cast(p["wo"], x.dtype))
    return y, KVCache(k, v)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu(p, x):
    dt = x.dtype
    g = jnp.einsum("bsd,df->bsf", x, cast(p["w_gate"], dt))
    u = jnp.einsum("bsd,df->bsf", x, cast(p["w_up"], dt))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, cast(p["w_down"], dt))


def gelu_mlp(p, x):
    dt = x.dtype
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, cast(p["w_in"], dt)) +
                    cast(p["b_in"], dt))
    return jnp.einsum("bsf,fd->bsd", h, cast(p["w_out"], dt)) + cast(p["b_out"], dt)


# ---------------------------------------------------------------------------
# decoder blocks
# ---------------------------------------------------------------------------

def dense_block(p, cfg: ModelConfig, x, *, positions, causal=True,
                q_offset=0):
    x = constrain(x, ("batch", None, None))  # pin loop-carry sharding
    h, kv = attention(p["attn"], cfg, rmsnorm(x, p["ln1"], cfg.norm_eps),
                      positions=positions, causal=causal, q_offset=q_offset)
    x = x + h
    x = x + swiglu(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps))
    return x, kv


def dense_block_decode(p, cfg: ModelConfig, x, cache: KVCache, pos):
    h, cache = decode_attention(p["attn"], cfg,
                                rmsnorm(x, p["ln1"], cfg.norm_eps), cache, pos)
    x = x + h
    x = x + swiglu(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps))
    return x, cache
