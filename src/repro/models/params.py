"""Parameter tables: single source of truth for shapes, init and sharding.

Each model defines a nested dict of :class:`P` leaves.  ``init_params`` turns
the table into arrays; ``pspecs`` turns the *same* table into
``PartitionSpec``s via logical-axis rules — no drift between the two.

Logical axes used across the zoo:
  "embed"  — d_model dims            -> FSDP axes ("pod","data") by default
  "mlp"    — feed-forward wide dim   -> "model" (TP)
  "heads"  — attention head dim      -> "model" (TP) when divisible
  "kv"     — kv-head dim             -> "model" when divisible else replicated
  "vocab"  — embedding rows          -> "model"
  "expert" — MoE expert dim          -> "model" (EP) when divisible
  "layers" — stacked layer dim       -> never sharded (scan axis)
  None     — replicated
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

__all__ = ["P", "init_params", "pspecs", "count_params", "DEFAULT_RULES"]


@dataclasses.dataclass(frozen=True)
class P:
    """A parameter leaf: shape + logical axes + init spec."""
    shape: tuple
    axes: tuple                 # logical axis name per dim (or None)
    init: str = "normal"        # normal | zeros | ones | a_log | dt_bias
    scale: Optional[float] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


DEFAULT_RULES = {
    "embed": ("fsdp",),
    "mlp": ("model",),
    "heads": ("model",),
    "kv": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "ssm_inner": ("model",),
    "layers": (),
}


def _leaf_init(p: P, key, dtype):
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "a_log":  # mamba2 A in [-? ] log-uniform over [1, 16]
        u = jax.random.uniform(key, p.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if p.init == "dt_bias":  # softplus^-1 of dt ~ U[1e-3, 1e-1]
        u = jax.random.uniform(key, p.shape, jnp.float32, 1e-3, 1e-1)
        return jnp.log(jnp.expm1(u)).astype(dtype)
    # truncated-normal fan-in init
    fan_in = p.shape[-2] if len(p.shape) >= 2 else max(p.shape[-1], 1)
    scale = p.scale if p.scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, p.shape, jnp.float32)
            * scale).astype(dtype)


def init_params(table, key, dtype=jnp.float32):
    """Materialize a parameter table into arrays (deterministic per path)."""
    leaves, treedef = jax.tree.flatten(
        table, is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(key, len(leaves))
    arrs = [_leaf_init(p, k, dtype) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def _axis_ok(mesh_axes: tuple, dim: int, mesh_shape: dict) -> bool:
    """jit argument shardings must divide the dim exactly (XLA requirement);
    non-divisible dims are replicated here and re-sharded *internally* via
    with_sharding_constraint (models/act.py), which tolerates padding."""
    total = 1
    for a in mesh_axes:
        total *= mesh_shape.get(a, 1)
    return total > 0 and dim % total == 0


def pspecs(table, mesh_shape: dict, rules: dict | None = None,
           fsdp_axes: tuple = ("data",)):
    """Build a PartitionSpec pytree from the table.

    ``mesh_shape``: dict axis->size of the target mesh. ``fsdp_axes``: the
    physical axes backing the logical "fsdp" group (e.g. ("pod","data")).
    Shardings that do not divide a dim are dropped (replicated) unless a
    single-axis padded sharding is cheap (see ``_axis_ok``).
    """
    rules = dict(DEFAULT_RULES if rules is None else rules)

    def spec_for(p: P) -> PartitionSpec:
        used = set()
        out = []
        for dim, ax in zip(p.shape, p.axes):
            phys: tuple = ()
            if ax is not None and ax in rules:
                phys = tuple(rules[ax])
                phys = tuple(fsdp_axes if a == "fsdp" else (a,) for a in phys)
                phys = tuple(x for grp in phys for x in grp)
            phys = tuple(a for a in phys if a not in used)
            if phys and _axis_ok(phys, dim, mesh_shape):
                used.update(phys)
                out.append(phys if len(phys) > 1 else phys[0])
            else:
                out.append(None)
        return PartitionSpec(*out)

    return jax.tree.map(spec_for, table,
                        is_leaf=lambda x: isinstance(x, P))


def count_params(table) -> int:
    leaves = jax.tree.leaves(table, is_leaf=lambda x: isinstance(x, P))
    return int(sum(np.prod(p.shape) for p in leaves))
