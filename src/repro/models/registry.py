"""Family registry: config -> model instance."""
from __future__ import annotations

from .config import ModelConfig

MODEL_FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


def get_model(cfg: ModelConfig):
    from .transformer import DenseModel
    from .moe import MoEModel
    from .mamba2 import MambaModel
    from .hybrid import HybridModel
    from .encdec import EncDecModel

    fam = {
        "dense": DenseModel,
        "vlm": DenseModel,
        "moe": MoEModel,
        "ssm": MambaModel,
        "hybrid": HybridModel,
        "encdec": EncDecModel,
    }
    if cfg.family not in fam:
        raise KeyError(f"unknown family {cfg.family}")
    return fam[cfg.family](cfg)
