"""Checksums, the atomic manifest, and corruption containment.

Three jobs (DESIGN.md §14):

* **Per-run component CRCs** — :func:`run_checksums` covers each durable
  component of a run separately (filter state, keys, fences, values), so
  a v3 snapshot can tell *which* component rotted and react
  proportionately: a filter-block mismatch quarantines the run (the probe
  plane degrades that row to fence-only pruning — scans stay exact, a
  filter can never be allowed to produce a false negative from flipped
  bits), while a key/fence/value mismatch is real data corruption and
  raises.

* **Atomic file replacement** — :func:`atomic_write_bytes` writes a temp
  file in the destination directory and ``os.replace``-renames it over
  the target, so a crash at any byte offset leaves either the old file or
  the new one, never a torn hybrid.  Snapshots and the manifest both go
  through it.

* **The checksummed manifest** — a tiny self-checksummed JSON document
  (:func:`write_manifest` / :func:`read_manifest`) naming the current
  snapshot file and its whole-file CRC.  Recovery trusts nothing it
  cannot verify: manifest CRC first, then the snapshot CRC against the
  manifest's record, then every run's component CRCs.
"""
from __future__ import annotations

import json
import os
import pickle
import struct
import tempfile
import zlib
from typing import Optional

import numpy as np

__all__ = [
    "crc32_bytes", "state_crc32", "run_checksums", "verify_component",
    "atomic_write_bytes", "write_manifest", "read_manifest",
    "MANIFEST_FILENAME", "SNAPSHOT_SCHEMA_MANIFEST",
]

MANIFEST_FILENAME = "MANIFEST.json"
SNAPSHOT_SCHEMA_MANIFEST = "bloomrf-manifest/v1"


def crc32_bytes(data: bytes) -> int:
    return zlib.crc32(data) & 0xFFFFFFFF


def state_crc32(state) -> int:
    """CRC32 over a filter block's raw u32 lanes (device or host array)."""
    return crc32_bytes(np.ascontiguousarray(
        np.asarray(state, np.uint32)).tobytes())


def _keys_crc32(keys: np.ndarray) -> int:
    return crc32_bytes(np.ascontiguousarray(
        np.asarray(keys, np.uint64)).tobytes())


def _fence_crc32(kmin: int, kmax: int) -> int:
    return crc32_bytes(struct.pack("<QQ", kmin, kmax))


def _vals_crc32(vals, tombs) -> int:
    # tombstone slots carry a process-local sentinel; checksum them as None
    # (exactly the form Run.pack serialises)
    clean = [None if t else v for v, t in zip(vals, tombs)]
    return crc32_bytes(pickle.dumps(clean, protocol=pickle.HIGHEST_PROTOCOL))


def run_checksums(keys: np.ndarray, vals, tombs, kmin: int, kmax: int,
                  state=None) -> dict:
    """Component CRC dict for :meth:`Run.pack` (``filter`` key only when a
    bloomRF state block exists).

    The tombstone mask gets its own component: the vals CRC alone cannot
    see a tomb->live bit flip (both sides serialise the slot as ``None``),
    and a flipped mask silently turns a delete back into a live entry."""
    tombs_arr = np.asarray(tombs, bool)
    crc = {
        "keys": _keys_crc32(keys),
        "fences": _fence_crc32(kmin, kmax),
        "vals": _vals_crc32(vals, tombs_arr),
        "tombs": crc32_bytes(np.packbits(tombs_arr).tobytes()),
    }
    if state is not None:
        crc["filter"] = state_crc32(state)
    return crc


def verify_component(crcs: Optional[dict], name: str, actual: int) -> bool:
    """True when the recorded CRC matches (or none was recorded — v1/v2
    snapshots predate checksums and are accepted unverified)."""
    if not crcs or name not in crcs:
        return True
    return int(crcs[name]) == int(actual)


# ---------------------------------------------------------------------------
# atomic replace + the manifest
# ---------------------------------------------------------------------------

def atomic_write_bytes(path: str, data: bytes, *, fault=None,
                       fault_point: str = "") -> None:
    """Write ``data`` to ``path`` via temp-file + ``os.replace``.

    ``fault``/``fault_point`` thread the fault-injection harness through
    the commit point: a :class:`~repro.store.faults.FaultPlan` armed at
    ``fault_point`` crashes *after* the temp file is complete but
    *before* the rename — the crash the atomicity argument is about."""
    dirname = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=dirname, prefix=".tmp-",
                               suffix=os.path.basename(path))
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        if fault is not None and fault_point:
            fault.hit(fault_point)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def write_manifest(directory: str, payload: dict, *, fault=None) -> None:
    """Atomically publish a self-checksummed manifest.

    ``payload`` names the snapshot file and its CRC; the manifest wraps it
    with its own CRC over the canonical JSON encoding, so a torn or
    bit-flipped manifest is detected before anything it references is
    trusted."""
    payload = dict(payload, schema=SNAPSHOT_SCHEMA_MANIFEST)
    body = json.dumps(payload, sort_keys=True)
    doc = {"payload": payload, "crc": crc32_bytes(body.encode())}
    atomic_write_bytes(os.path.join(directory, MANIFEST_FILENAME),
                       json.dumps(doc).encode(),
                       fault=fault, fault_point="manifest.before_rename")


def read_manifest(directory: str) -> Optional[dict]:
    """Verified manifest payload, or None when no manifest exists.

    Raises ``ValueError`` on a corrupt manifest (bad JSON, missing
    fields, CRC mismatch, unknown schema) — recovery must not guess."""
    path = os.path.join(directory, MANIFEST_FILENAME)
    if not os.path.exists(path):
        return None
    try:
        with open(path, "rb") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise ValueError(f"corrupt store manifest {path!r}: {e}") from e
    if not isinstance(doc, dict) or "payload" not in doc or "crc" not in doc:
        raise ValueError(f"corrupt store manifest {path!r}: "
                         f"missing payload/crc envelope")
    payload = doc["payload"]
    body = json.dumps(payload, sort_keys=True)
    if crc32_bytes(body.encode()) != doc["crc"]:
        raise ValueError(f"corrupt store manifest {path!r}: CRC mismatch "
                         f"(torn write or bit rot — restore from backup)")
    if payload.get("schema") != SNAPSHOT_SCHEMA_MANIFEST:
        raise ValueError(f"unknown manifest schema "
                         f"{payload.get('schema')!r} in {path!r}")
    return payload
