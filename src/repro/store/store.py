"""The LSM run-store: memtable + leveled runs + one-gather filter probes.

Write path: ``put``/``delete`` land in the memtable; at
``memtable_limit`` entries the memtable flushes to an immutable level-0
:class:`~repro.store.run.Run` carrying a bloomRF filter block (layout
chosen from a capacity-class ladder) and min/max fences.  When level 0
exceeds ``level0_runs`` runs, leveled compaction merges them (plus the
next level's run) downward — same-class filter blocks merge with a single
``bitwise_or``, class-graduating merges re-insert through the kernels
insert path (``compaction.merge_filter_state``).

Read path: ``get``/``scan`` first consult the memtable, then probe **all**
live runs' filters at once — the per-run states are concatenated into one
flat lane vector and probed through ``core.engine.StackedProbe``, so a
scan over R runs costs exactly ONE fused gather over the stacked filter
state regardless of R or the mix of capacity classes (jaxpr-asserted in
the test suite).  Only runs whose fences overlap *and* whose filter says
"maybe" have their data blocks touched; :class:`StoreStats` counts what
the filters saved (skips, false-positive reads, bytes not read).

Filters are insert-only at write time: a delete writes a tombstone
*entry* whose key is inserted like any other, so newer tombstones are
discoverable through the filters and mask older runs at read time; no
filter bit is cleared outside compaction.  With
``mutability="deletable"`` compaction fights the resulting FPR drift:
class-graduating merges *promote* source filters in place (segment
tiling, ``core/dynamic.py``) instead of replaying keys, and when a
merge's dead-entry fraction exceeds ``purge_dead_frac`` the filter is
rebuilt from the surviving keys — purging every deleted key's bits at
the natural rebuild point (DESIGN.md §12).

``filter_backend`` swaps the per-run filter: ``"bloomrf"`` (stacked
one-gather probes), ``"none"`` (min/max fences only — the pruning
baseline), or any of the host-side baselines from ``repro.filters``
(``"bloom"``, ``"prefix_bloom"``, ``"rosetta"``, ``"surf"``) for
side-by-side comparisons in ``benchmarks/store_bench.py``.

Durability (DESIGN.md §14): with ``durability="wal"`` every
``put``/``delete``/``delete_many`` appends a CRC-framed record to a
write-ahead log (``store/wal.py``) *before* the memtable acks it, and
:meth:`Store.checkpoint` publishes a checksummed snapshot + manifest via
atomic renames (``store/integrity.py``) before resetting the log —
:meth:`Store.open` recovers the acknowledged state after a crash at any
point.  Runs whose filter block fails its checksum are *quarantined*:
the probe plane (XLA and megakernel alike) degrades them to fence-only
pruning so scans stay exact (``StoreStats.degraded_probes``), because a
corrupted filter is never allowed to produce a false negative.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import warnings
import weakref
from typing import ClassVar, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import basic_layout, key_dtype_for
from ..core.engine import _filter_for_layout, stacked_probe
from ..kernels import FilterOps, read_vmem_budget_u32
from ..kernels.store_scan import DEFAULT_TILE as STORE_SCAN_TILE
from ..kernels.store_scan import build_run_stack, store_scan_probe
from ..obs import metrics as _obs_metrics
from ..obs import trace as _obs_trace
from .compaction import merge_filter_state, merge_sorted_runs
from .faults import FaultPlan
from .integrity import (MANIFEST_FILENAME, atomic_write_bytes, crc32_bytes,
                        read_manifest, write_manifest)
from .memtable import TOMBSTONE, Memtable
from .run import Run
from .wal import WAL_FILENAME, Wal

__all__ = ["Store", "StoreConfig", "StoreStats"]


def _baseline_factory(name: str):
    from .. import filters as F

    return {
        "bloom": lambda bpk: F.BloomFilter(bits_per_key=bpk),
        "prefix_bloom": lambda bpk: F.PrefixBloomFilter(bits_per_key=bpk),
        "rosetta": lambda bpk: F.Rosetta(bits_per_key=bpk),
        "surf": lambda bpk: F.SuRFLite(),
    }[name]


@jax.jit
def _fence_touch_device(kmin, kmax, lo, hi):
    """Fence-only pruning plane (``filter_backend="none"``): every fenced
    run is touched."""
    lo = jnp.atleast_1d(lo)
    hi = jnp.atleast_1d(hi)
    fence = ((hi[:, None] >= kmin[None, :]) & (lo[:, None] <= kmax[None, :]))
    return fence, fence


@dataclasses.dataclass(frozen=True)
class StoreConfig:
    d: int = 32                     # key-domain bits
    memtable_limit: int = 4096      # entries per flush (= capacity class 0)
    bits_per_key: float = 14.0
    delta: int = 6
    fanout: int = 4                 # capacity-class / level size ratio
    level0_runs: int = 4            # level-0 run count that triggers compaction
    filter_backend: str = "bloomrf"  # "bloomrf" | "none" | repro.filters name
    scan_backend: str = "auto"      # scan-pruning plane: "auto" | "kernel"
                                    # | "xla" — "kernel" runs the fused
                                    # store-scan Pallas megakernel
                                    # (kernels/store_scan.py), "xla" the
                                    # StackedProbe.touch_all reference,
                                    # "auto" picks the kernel on TPU only
                                    # (interpret-mode Pallas is slow on CPU)
    use_insert_kernels: bool = False  # route rebuilds through FilterOps.insert
    value_bytes: int = 64           # per-entry data-block size for accounting
    seed: int = 0x0B100F11
    mutability: str = "insert_only"  # "insert_only" | "deletable"
    tuning: str = "static"          # "static" (capacity-class ladder only)
                                    # | "adaptive" — sample the live scan
                                    # workload and let compaction's
                                    # class-graduating rebuilds land in a
                                    # re-solved layout (repro.tune, §16)
    purge_dead_frac: float = 0.25   # deletable: dead fraction forcing a purge
    promote_max_hops: int = 1       # promote hops a filter survives before a
                                    # rebuild is forced (promotion keeps the
                                    # source class's resolution, so each hop
                                    # multiplies FPR by the source count)
    promote_density_slack: float = 1.5  # promote only when the OR-union's
                                    # per-layer density stays within this
                                    # factor of a rebuild's (compaction.py)
    durability: str = "none"        # "none" | "wal" — "wal" appends every
                                    # write to wal_dir/wal.log before acking
                                    # and enables checkpoint()/Store.open()
    wal_dir: Optional[str] = None   # durable root: WAL + snapshots + manifest
    wal_sync: str = "flush"         # "flush" (crash-safe) | "always" (fsync
                                    # per record — power-failure-safe, slow)

    def __post_init__(self):
        if not (1 <= self.d <= 64):
            raise ValueError(
                f"d must be in 1..64 (uint64 key domain), got {self.d}")
        if not self.bits_per_key > 0:
            raise ValueError(
                f"bits_per_key must be > 0, got {self.bits_per_key}")
        if self.memtable_limit < 1 or self.fanout < 2 or self.level0_runs < 1:
            raise ValueError("memtable_limit >= 1, fanout >= 2, "
                             "level0_runs >= 1 required")
        if self.mutability not in ("insert_only", "deletable"):
            raise ValueError(
                f"mutability must be 'insert_only' or 'deletable', "
                f"got {self.mutability!r}")
        if self.tuning not in ("static", "adaptive"):
            raise ValueError(f"tuning must be 'static' or 'adaptive', "
                             f"got {self.tuning!r}")
        if self.tuning == "adaptive" and self.filter_backend != "bloomrf":
            raise ValueError(
                f"tuning='adaptive' re-solves bloomRF layouts; it needs "
                f"filter_backend='bloomrf', not {self.filter_backend!r}")
        if not (0.0 < self.purge_dead_frac <= 1.0):
            raise ValueError(
                f"purge_dead_frac must be in (0, 1], got {self.purge_dead_frac}")
        if self.promote_max_hops < 0:
            raise ValueError(
                f"promote_max_hops must be >= 0, got {self.promote_max_hops}")
        if not self.promote_density_slack > 0:
            raise ValueError(f"promote_density_slack must be > 0, "
                             f"got {self.promote_density_slack}")
        if self.filter_backend not in ("bloomrf", "none"):
            try:
                _baseline_factory(self.filter_backend)
            except KeyError:
                raise ValueError(
                    f"unknown filter_backend {self.filter_backend!r}") from None
        if self.scan_backend not in ("auto", "kernel", "xla"):
            raise ValueError(f"scan_backend must be 'auto', 'kernel' or "
                             f"'xla', got {self.scan_backend!r}")
        if self.durability not in ("none", "wal"):
            raise ValueError(f"durability must be 'none' or 'wal', "
                             f"got {self.durability!r}")
        if self.durability == "wal" and not self.wal_dir:
            raise ValueError("durability='wal' requires wal_dir")
        if self.wal_sync not in ("flush", "always"):
            raise ValueError(f"wal_sync must be 'flush' or 'always', "
                             f"got {self.wal_sync!r}")


@dataclasses.dataclass
class StoreStats:
    """Counters for what the filter blocks saved on the read path.

    Field access stays plain attribute reads/writes; :meth:`snapshot`
    returns the same counters (plus derived rates) as a flat dict so the
    obs registry and the CI gates can address them by dotted path, and
    :meth:`reset` zeroes every field in place.  The :data:`DURABLE`
    subset travels inside ``Store.snapshot()`` and survives
    restore/checkpoint/recovery round-trips (DESIGN.md §15)."""

    # write-path history: durable — it describes the data the snapshot
    # carries, so it rides along (see DURABLE below)
    puts: int = 0
    deletes: int = 0
    gets: int = 0
    scans: int = 0
    flushes: int = 0
    compactions: int = 0
    or_merges: int = 0              # same-layout filter merges (bitwise OR)
    rebuild_merges: int = 0         # cross-layout merges (key re-insert)
    promote_merges: int = 0         # in-place segment-tiled class promotions
    purge_rebuilds: int = 0         # rebuilds forced by the dead-frac policy
    retunes: int = 0                # compaction rebuilds that landed in a
                                    # tuner-advised layout instead of the
                                    # capacity-class ladder's (§16)
    # point reads
    get_runs_considered: int = 0
    get_fence_skips: int = 0
    get_filter_skips: int = 0
    get_run_reads: int = 0
    get_fp_reads: int = 0           # run read, key absent
    # scans
    scan_runs_considered: int = 0
    scan_fence_skips: int = 0
    scan_filter_skips: int = 0
    scan_runs_touched: int = 0
    scan_fp_reads: int = 0          # run touched, empty slice
    # data-block bytes
    bytes_read: int = 0
    bytes_not_read: int = 0         # skipped runs' data bytes
    # durability / degradation
    wal_appends: int = 0            # records framed before acking a write
    wal_replayed: int = 0           # records recovered at the last open
    degraded_probes: int = 0        # (query, run) cells answered fence-only
                                    # because the run is quarantined
    kernel_fallbacks: int = 0       # scan batches retried through the XLA
                                    # plane after a pallas_call dispatch error

    # Counters that survive Store.snapshot()/restore(): the write-path
    # history that produced the snapshotted runs, plus kernel_fallbacks
    # (a degradation odometer that must not silently reset with the
    # process).  Read-path counters, wal_appends/wal_replayed and
    # degraded_probes describe THIS process's traffic and stay local.
    DURABLE: ClassVar[Tuple[str, ...]] = (
        "puts", "deletes", "flushes", "compactions", "or_merges",
        "rebuild_merges", "promote_merges", "purge_rebuilds", "retunes",
        "kernel_fallbacks")

    @property
    def runs_probed_per_scan(self) -> float:
        return self.scan_runs_touched / max(self.scans, 1)

    @property
    def scan_fp_read_rate(self) -> float:
        return self.scan_fp_reads / max(self.scan_runs_touched, 1)

    @property
    def get_fp_read_rate(self) -> float:
        return self.get_fp_reads / max(self.get_run_reads, 1)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["runs_probed_per_scan"] = self.runs_probed_per_scan
        d["scan_fp_read_rate"] = self.scan_fp_read_rate
        d["get_fp_read_rate"] = self.get_fp_read_rate
        return d

    def snapshot(self) -> dict:
        """Flat counters + derived rates (the registered-family view)."""
        return self.as_dict()

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)

    def durable_snapshot(self) -> dict:
        """The DURABLE subset, as carried inside ``Store.snapshot()``."""
        return {name: int(getattr(self, name)) for name in self.DURABLE}


class Store:
    """LSM key-value store with per-run bloomRF filter blocks."""

    def __init__(self, config: Optional[StoreConfig] = None, *,
                 faults: Optional[FaultPlan] = None,
                 _warn: bool = True, _open_wal: bool = True, **kw):
        if _warn:
            from .._compat import warn_legacy

            warn_legacy("Store(StoreConfig(...))",
                        "dtype=..., placement='store', ...")
        self.cfg = config if config is not None else StoreConfig(**kw)
        self.kdtype = key_dtype_for(self.cfg.d)
        self.mem = Memtable()
        self.levels: List[List[Run]] = [[]]   # levels[0] newest-first
        self.stats = StoreStats()
        self.faults = faults                  # fault-injection seams (tests)
        self._ops: dict = {}                  # FilterOps per layout
        self._runs: List[Run] = []
        self._flat = None                     # stacked filter lanes
        self._probe = None
        self._kmins = self._kmaxs = None      # per-run fences, np.uint64 (R,)
        self._quar = None                     # per-run quarantine mask (R,)
        self._quar_dev = None                 # lazy device copy of _quar
        self._kstate = None                   # lazy megakernel inputs
        self._fence_dev = None                # lazy device fences (kdtype)
        self._dirty = True
        self._wal: Optional[Wal] = None
        self._seq = 0                         # checkpoint sequence number
        self._tuner = None                    # workload-adaptive tuner (§16)
        if self.cfg.tuning == "adaptive":
            from ..tune import AdaptiveTuner

            self._tuner = AdaptiveTuner(self.cfg.d, seed=self.cfg.seed)
        if _obs_metrics.enabled():            # late joiners: register_obs()
            self.register_obs()
        if self.cfg.durability == "wal" and _open_wal:
            os.makedirs(self.cfg.wal_dir, exist_ok=True)
            wal_path = os.path.join(self.cfg.wal_dir, WAL_FILENAME)
            has_state = (
                os.path.exists(os.path.join(self.cfg.wal_dir,
                                            MANIFEST_FILENAME))
                or (os.path.exists(wal_path)
                    and os.path.getsize(wal_path) > 0))
            if has_state:
                raise ValueError(
                    f"{self.cfg.wal_dir!r} already holds store state; "
                    f"use Store.open({self.cfg.wal_dir!r}) to recover it")
            self._wal = Wal(wal_path, sync=self.cfg.wal_sync).open_for_append()

    def _fault(self, point: str) -> None:
        """Pass through a named fault-injection seam (no-op without a plan)."""
        if self.faults is not None:
            self.faults.hit(point)

    def register_obs(self, family: str = "store") -> str:
        """Join the obs registry as a metric family (DESIGN.md §15).

        The registry holds only a weak reference — a collected store
        drops out of the next ``snapshot()``.  Returns the assigned
        family name (auto-suffixed when taken).  Called automatically at
        construction when observability is already enabled."""
        sref = weakref.ref(self)
        return _obs_metrics.registry().register_family(
            family,
            lambda: (lambda s: None if s is None
                     else s.stats.snapshot())(sref()))

    # ------------------------------------------------------------------
    # capacity classes and filter construction
    # ------------------------------------------------------------------
    def class_capacity(self, cls: int) -> int:
        return self.cfg.memtable_limit * self.cfg.fanout ** cls

    def class_layout(self, n_keys: int):
        """Layout of the smallest capacity class that fits ``n_keys``."""
        cls = 0
        while self.class_capacity(cls) < n_keys:
            cls += 1
        return basic_layout(self.cfg.d, self.class_capacity(cls),
                            self.cfg.bits_per_key,
                            delta=min(self.cfg.delta, self.cfg.d),
                            seed=self.cfg.seed)

    def _build_filter(self, layout, keys: np.ndarray) -> jnp.ndarray:
        """Bulk filter build; the compaction rebuild path lands here too."""
        kj = jnp.asarray(keys, self.kdtype)
        if self.cfg.use_insert_kernels and layout.d <= 32:
            if layout not in self._ops:
                self._ops[layout] = FilterOps(layout, _warn=False)
            ops = self._ops[layout]
            return ops.insert(ops.init_state(), kj)
        return _filter_for_layout(layout).build(kj)

    def _make_run(self, keys: np.ndarray, vals: list, tombs: np.ndarray,
                  level: int) -> Run:
        layout = self.class_layout(len(keys))
        if self._tuner is not None:
            # flushes reuse the class's standing retune decision (no
            # re-solve here) so fresh runs join the tuned layout and
            # same-class compactions keep merging with a free OR
            layout = self._tuner.cached_layout(layout) or layout
        state = alt = None
        if self.cfg.filter_backend == "bloomrf":
            state = self._build_filter(layout, keys)
        elif self.cfg.filter_backend != "none":
            alt = _baseline_factory(self.cfg.filter_backend)(
                self.cfg.bits_per_key)
            alt.build(keys)
        return Run(keys, vals, tombs, level, layout, state, alt=alt)

    # ------------------------------------------------------------------
    # write path
    # ------------------------------------------------------------------
    def _check_key(self, key: int) -> int:
        key = int(key)
        if not (0 <= key < (1 << self.cfg.d)):
            raise ValueError(f"key {key} outside the {self.cfg.d}-bit domain")
        return key

    def _wal_append(self, op: str, key, value=None) -> None:
        """Frame a record before the memtable acks (durable stores only)."""
        if self._wal is None:
            return
        self._fault("wal.append")
        self._wal.append(op, key, value)
        self.stats.wal_appends += 1

    def put(self, key: int, value) -> None:
        key = self._check_key(key)
        self._wal_append("put", key, value)
        self.mem.put(key, value)
        self.stats.puts += 1
        if len(self.mem) >= self.cfg.memtable_limit:
            self.flush()

    def delete(self, key: int) -> None:
        key = self._check_key(key)
        self._wal_append("del", key)
        self.mem.delete(key)
        self.stats.deletes += 1
        if len(self.mem) >= self.cfg.memtable_limit:
            self.flush()

    def delete_many(self, keys) -> None:
        """Batched deletes: every tombstone lands in the memtable before the
        single flush decision, so a large eviction sweep triggers at most one
        flush (plus its own compaction cascade) instead of one per
        ``memtable_limit`` keys interleaved with the caller's scan.

        Durability-wise the batch is atomic: ONE ``"delm"`` WAL frame
        covers all keys, so replay applies the whole sweep or none of it
        (a torn frame was never acked)."""
        keys = [self._check_key(k) for k in keys]
        self._wal_append("delm", keys)
        for key in keys:
            self.mem.delete(key)
        self.stats.deletes += len(keys)
        if len(self.mem) >= self.cfg.memtable_limit:
            self.flush()

    def flush(self) -> None:
        """Freeze the memtable into a new level-0 run."""
        if len(self.mem) == 0:
            return
        with _obs_trace.span("store/flush", entries=len(self.mem)):
            keys, vals, tombs = self.mem.sorted_entries()
            run = self._make_run(keys, vals, tombs, 0)
            run.checksums()             # cache the build-time reference
            self._fault("flush.after_run")
            self.levels[0].insert(0, run)
            self.mem.clear()
            self.stats.flushes += 1
            self._dirty = True
        self._maybe_compact()

    # ------------------------------------------------------------------
    # compaction
    # ------------------------------------------------------------------
    def _maybe_compact(self) -> None:
        if len(self.levels[0]) > self.cfg.level0_runs:
            self.compact(0)
        lvl = 1
        while lvl < len(self.levels):
            runs = self.levels[lvl]
            if runs and len(runs[0]) > self.class_capacity(lvl):
                self.compact(lvl)
            lvl += 1

    def compact(self, level: int) -> None:
        """Merge every run at ``level`` (plus the next level's run) down.

        Crash-atomic: the merged run — keys, values, filter state, and its
        checksums — is fully built *before* the level lists are swapped,
        so a crash mid-compaction (the ``compact.before_swap`` fault seam)
        leaves every source run live and consistent."""
        if level >= len(self.levels) or not self.levels[level]:
            return
        with _obs_trace.span("store/compact", level=level):
            self._compact_inner(level)

    def _compact_inner(self, level: int) -> None:
        if level + 1 >= len(self.levels):
            self.levels.append([])
        sources = self.levels[level] + self.levels[level + 1]
        bottom = not any(self.levels[lv] for lv in
                         range(level + 2, len(self.levels)))
        keys, vals, tombs = merge_sorted_runs(sources,
                                              drop_tombstones=bottom)
        if len(keys) == 0:          # everything tombstoned away
            self._fault("compact.before_swap")
            self.levels[level] = []
            self.levels[level + 1] = []
            self.stats.compactions += 1
            self._dirty = True
            return
        target_layout = self.class_layout(len(keys))
        retuned = False
        if self._tuner is not None:
            # THE retune point (§16): a class-graduating merge is already
            # paying for a rebuild, so consult the solver and re-insert
            # into the tuned layout instead of the ladder's
            tuned = self._tuner.advise_layout(target_layout, len(keys))
            retuned = tuned != target_layout
            target_layout = tuned
        state = alt = None
        if self.cfg.filter_backend == "bloomrf":
            # fraction of merged entries that did not survive (shadowed
            # duplicates + dropped tombstones): the bits those entries set
            # are dead weight in an OR/promote-merged filter
            n_in = sum(len(r) for r in sources)
            dead_frac = 1.0 - len(keys) / n_in
            deletable = self.cfg.mutability == "deletable"
            # cap promotion depth: a promoted filter still answers at its
            # source class's resolution, so hop-on-hop promotion compounds
            # FPR; once any source has used its hops, rebuild fresh
            hops = max((r.promotions for r in sources), default=0)
            state, how = merge_filter_state(
                sources, target_layout, keys, self._build_filter,
                dead_frac=dead_frac,
                purge_dead_frac=(self.cfg.purge_dead_frac if deletable
                                 else None),
                allow_promote=deletable
                and hops < self.cfg.promote_max_hops,
                promote_density_slack=self.cfg.promote_density_slack)
            counter = {"or": "or_merges", "promote": "promote_merges",
                       "rebuild": "rebuild_merges", "purge": "purge_rebuilds"}
            setattr(self.stats, counter[how],
                    getattr(self.stats, counter[how]) + 1)
            if retuned and how in ("rebuild", "purge"):
                # only count retunes that actually re-inserted into the
                # tuned layout here; an OR over already-tuned sources
                # means an earlier compaction/flush did the work
                self.stats.retunes += 1
            promotions = {"or": hops, "promote": hops + 1}.get(how, 0)
        elif self.cfg.filter_backend != "none":
            alt = _baseline_factory(self.cfg.filter_backend)(
                self.cfg.bits_per_key)
            alt.build(keys)
            self.stats.rebuild_merges += 1
            promotions = 0
        else:
            promotions = 0
        new_run = Run(keys, vals, tombs, level + 1, target_layout, state,
                      alt=alt, promotions=promotions)
        new_run.checksums()             # checksummed before it goes live
        self._fault("compact.before_swap")
        self.levels[level] = []
        self.levels[level + 1] = [new_run]
        self.stats.compactions += 1
        self._dirty = True

    # ------------------------------------------------------------------
    # stacked filter probes (the one-gather read path)
    # ------------------------------------------------------------------
    def live_runs(self) -> List[Run]:
        """All runs, newest precedence first (L0 newest-first, then down)."""
        self._refresh()
        return self._runs

    def _refresh(self) -> None:
        if not self._dirty:
            return
        self._runs = [r for lvl in self.levels for r in lvl]
        self._flat = self._probe = None
        self._kstate = self._fence_dev = self._quar_dev = None
        self._kmins = np.asarray([r.kmin for r in self._runs], np.uint64)
        self._kmaxs = np.asarray([r.kmax for r in self._runs], np.uint64)
        self._quar = np.asarray([r.quarantined for r in self._runs], bool)
        if self._runs and self.cfg.filter_backend == "bloomrf":
            # a quarantined run may have no decodable state at all — stack
            # zero lanes in its place; the quarantine mask forces its
            # verdict to "maybe" so the zeros are never trusted
            states = [r.state if r.state is not None
                      else jnp.zeros(r.layout.total_u32, jnp.uint32)
                      for r in self._runs]
            self._flat = (states[0] if len(states) == 1
                          else jnp.concatenate(states))
            sizes = [r.layout.total_u32 for r in self._runs]
            bases = tuple(int(b) for b in
                          np.cumsum([0] + sizes[:-1], dtype=np.int64))
            self._probe = stacked_probe(
                tuple(r.layout for r in self._runs), bases)
        self._dirty = False

    def _quar_device(self):
        """Device quarantine mask, or None when no run is quarantined."""
        if not self._quar.any():
            return None
        if self._quar_dev is None:
            self._quar_dev = jnp.asarray(self._quar)
        return self._quar_dev

    def _fence_mask(self, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
        """(B, R) bool: query interval overlaps the run's [kmin, kmax]."""
        return ((hi[:, None] >= self._kmins[None, :])
                & (lo[:, None] <= self._kmaxs[None, :]))

    def _filter_mask(self, lo: np.ndarray, hi: np.ndarray,
                     point: bool) -> np.ndarray:
        """(B, R) bool filter verdicts (True = run may hold a match).

        Quarantined rows answer "maybe" unconditionally — their filter
        block failed its checksum, so its verdicts are untrusted."""
        if self.cfg.filter_backend == "none":
            return np.ones((len(lo), len(self._runs)), bool)
        if self.cfg.filter_backend == "bloomrf":
            if point:
                v = self._probe.point_all(self._flat,
                                          jnp.asarray(lo, self.kdtype))
            else:
                v = self._probe.range_all(self._flat,
                                          jnp.asarray(lo, self.kdtype),
                                          jnp.asarray(hi, self.kdtype))
            out = np.asarray(v)
        else:
            cols = [r.alt.point(lo) if point else r.alt.range(lo, hi)
                    for r in self._runs]
            out = np.stack(cols, axis=1)
        if self._quar.any():
            out = out | self._quar[None, :]
        return out

    def probe_runs(self, lo, hi, point: bool = False
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Batched pruning verdicts over all live runs.

        Returns ``(fence, filt)``, each (B, R) bool — the fence overlap
        mask and the filter verdicts.  A run is touched only where both
        are True.  One fused gather for the whole batch x run matrix when
        the backend is bloomRF."""
        self._refresh()
        lo = np.atleast_1d(np.asarray(lo, np.uint64))
        hi = lo if point else np.atleast_1d(np.asarray(hi, np.uint64))
        if not self._runs:
            z = np.zeros((len(lo), 0), bool)
            return z, z
        fence = self._fence_mask(lo, hi)
        # Filter probes run in the filter's d-bit dtype: clamp bounds into
        # the domain first, or an out-of-domain `hi` would wrap under the
        # dtype cast and the (min/max-normalised) probe would answer the
        # wrong interval — a false negative the fences don't catch.  The
        # clamped interval is exactly `query ∩ domain`; queries entirely
        # above the domain are already fenced off (kmax <= dmax < lo).
        dmax = np.uint64((1 << self.cfg.d) - 1)
        filt = self._filter_mask(np.minimum(lo, dmax), np.minimum(hi, dmax),
                                 point)
        if self._quar.any():
            self.stats.degraded_probes += int(
                (fence & self._quar[None, :]).sum())
        return fence, filt

    # ------------------------------------------------------------------
    # fused scan-pruning plane (fence ∧ filter in one device step)
    # ------------------------------------------------------------------
    def _scan_kernel_mode(self) -> str:
        """Resolve ``cfg.scan_backend`` for the current run stack.

        The megakernel handles bloomRF stacks in the uint32 key domain
        (the capacity-class ladder never emits exact-bitmap layouts, so
        d <= 32 is the only real constraint); everything else takes the
        XLA-exact path.  ``auto`` picks the kernel only on a real TPU —
        interpret-mode Pallas on CPU is for parity tests, not speed."""
        if (self.cfg.scan_backend == "xla"
                or self.cfg.filter_backend != "bloomrf"
                or self.cfg.d > 32 or not self._runs):
            return "xla"
        if self.cfg.scan_backend == "kernel":
            return "kernel"
        return "kernel" if jax.default_backend() == "tpu" else "xla"

    def _kernel_inputs(self):
        """Megakernel operands for the live stack, built once per refresh:
        the padded ``(R, rowpad)`` run stack, uint32 device fences, and a
        ``runs_per_block`` split sized so one filter block fits the VMEM
        budget (the Pallas grid pipeline streams blocks beyond it)."""
        if self._kstate is None:
            layouts = tuple(r.layout for r in self._runs)
            stack = build_run_stack([r.state for r in self._runs])
            rowpad, R = int(stack.shape[1]), len(self._runs)
            budget = read_vmem_budget_u32()
            rpb = R if rowpad * R <= budget else max(1, budget // rowpad)
            self._kstate = (layouts, stack,
                            jnp.asarray(self._kmins, jnp.uint32),
                            jnp.asarray(self._kmaxs, jnp.uint32), int(rpb))
        return self._kstate

    def _touch_masks(self, lo: np.ndarray,
                     hi: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Host scan pruning: ``(fence, touch)`` (B, R) bool.

        ``touch = fence & filter-maybe`` — the runs whose data blocks a
        scan must read.  Dispatches per ``_scan_kernel_mode``: one fused
        Pallas call, or the XLA fence+probe reference (bit-identical)."""
        self._refresh()
        if not self._runs:
            z = np.zeros((len(lo), 0), bool)
            return z, z
        if self._scan_kernel_mode() == "kernel":
            try:
                self._fault("kernel.dispatch")
                dmax = np.uint64((1 << self.cfg.d) - 1)
                layouts, stack, kmin_d, kmax_d, rpb = self._kernel_inputs()
                f, t = store_scan_probe(
                    layouts, stack, kmin_d, kmax_d,
                    jnp.asarray(np.minimum(lo, dmax), jnp.uint32),
                    jnp.asarray(np.minimum(hi, dmax), jnp.uint32),
                    STORE_SCAN_TILE, rpb, jax.default_backend() != "tpu",
                    self._quar_device())
                fence, touch = np.asarray(f), np.asarray(t)
            except Exception:
                # a dispatch-time pallas_call failure is survivable when
                # the caller asked for "auto": retry the batch through the
                # XLA probe plane (bit-identical verdicts) exactly once
                if self.cfg.scan_backend != "auto":
                    raise
                self.stats.kernel_fallbacks += 1
            else:
                # the uint32 clamp is exact for every in-domain `lo` (kmin,
                # kmax <= dmax); intervals entirely above the domain must be
                # fenced off on the host instead (kmax <= dmax < lo)
                dead = lo > dmax
                if dead.any():
                    fence, touch = fence.copy(), touch.copy()
                    fence[dead] = touch[dead] = False
                if self._quar.any():
                    self.stats.degraded_probes += int(
                        (fence & self._quar[None, :]).sum())
                return fence, touch
        fence, filt = self.probe_runs(lo, hi, point=False)
        return fence, fence & filt

    def scan_probe_device(self, lo, hi) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Device-resident scan pruning: ``(fence, touch)`` (B, R) bool
        jax arrays, no host round-trip — the YCSB device driver's probe
        plane.  Bounds must already lie inside the d-bit key domain
        (``scan_many`` handles out-of-domain clamping on the host).

        One fused megakernel call in ``kernel`` mode; the jit'd
        ``StackedProbe.touch_all`` (still one fused gather) in ``xla``
        mode; fence-only verdicts for ``filter_backend="none"``."""
        self._refresh()
        if _obs_metrics.enabled():
            # host-side batch odometer only: the dispatch stays async and
            # nothing syncs — the ≤1.05x obs-overhead gate times this path
            _obs_metrics.registry().counter(
                "store/scan_probe_batches").add(1)
        lo = jnp.atleast_1d(lo)
        if not self._runs:
            z = jnp.zeros((lo.shape[0], 0), bool)
            return z, z
        if self._scan_kernel_mode() == "kernel":
            try:
                self._fault("kernel.dispatch")
                layouts, stack, kmin_d, kmax_d, rpb = self._kernel_inputs()
                return store_scan_probe(layouts, stack, kmin_d, kmax_d,
                                        lo, hi, STORE_SCAN_TILE, rpb,
                                        jax.default_backend() != "tpu",
                                        self._quar_device())
            except Exception:
                if self.cfg.scan_backend != "auto":
                    raise
                self.stats.kernel_fallbacks += 1
        if self._fence_dev is None:
            self._fence_dev = (jnp.asarray(self._kmins, self.kdtype),
                               jnp.asarray(self._kmaxs, self.kdtype))
        kmin_d, kmax_d = self._fence_dev
        lo = jnp.asarray(lo, self.kdtype)
        hi = jnp.asarray(hi, self.kdtype)
        if self.cfg.filter_backend == "bloomrf":
            return self._probe.touch_all(self._flat, kmin_d, kmax_d, lo, hi,
                                         self._quar_device())
        if self.cfg.filter_backend == "none":
            fence, touch = _fence_touch_device(kmin_d, kmax_d, lo, hi)
            return fence, touch
        raise ValueError(
            f"device scan probing needs the 'bloomrf' or 'none' backend, "
            f"not {self.cfg.filter_backend!r} (host-side baseline)")

    # ------------------------------------------------------------------
    # read path
    # ------------------------------------------------------------------
    def get(self, key: int):
        """Point lookup; None when absent or deleted."""
        return self.get_many(np.asarray([self._check_key(key)], np.uint64))[0]

    def get_many(self, keys) -> list:
        """Batched point lookups: one fused filter gather for the batch."""
        keys = np.atleast_1d(np.asarray(keys, np.uint64))
        if self._tuner is not None:
            self._tuner.observe_points(len(keys))
        with _obs_trace.span("store/get", batch=len(keys)):
            return self._get_many_inner(keys)

    def _get_many_inner(self, keys: np.ndarray) -> list:
        st = self.stats
        st.gets += len(keys)
        fence, filt = self.probe_runs(keys, keys, point=True)
        dbytes = np.asarray([r.data_bytes(self.cfg.value_bytes)
                             for r in self._runs], np.int64)
        out = []
        for b, key in enumerate(keys):
            found, v = self.mem.get(int(key))
            if found:
                out.append(None if v is TOMBSTONE else v)
                continue
            result = None
            R = len(self._runs)
            st.get_runs_considered += R
            st.get_fence_skips += int((~fence[b]).sum())
            st.get_filter_skips += int((fence[b] & ~filt[b]).sum())
            # skipped runs save their data blocks on the point path too —
            # mirror of the _scan_one credit, so bytes_not_read covers
            # point-heavy workloads instead of understating savings
            st.bytes_not_read += int(dbytes[~(fence[b] & filt[b])].sum())
            for r_idx in np.flatnonzero(fence[b] & filt[b]):
                run = self._runs[r_idx]
                st.get_run_reads += 1
                st.bytes_read += run.data_bytes(self.cfg.value_bytes)
                hit, val, tomb = run.lookup(int(key))
                if hit:
                    result = None if tomb else val
                    break
                st.get_fp_reads += 1
            out.append(result)
        return out

    def scan(self, lo: int, hi: int) -> list:
        """All live (key, value) pairs with lo <= key <= hi, ascending."""
        return self.scan_many([lo], [hi])[0]

    def scan_many(self, los, his) -> list:
        """Batched scans: the whole pruning plane (fence + filter) in one
        device dispatch for the batch — a single megakernel call or one
        fused XLA gather, per ``StoreConfig.scan_backend``."""
        los = np.atleast_1d(np.asarray(los, np.uint64))
        his = np.atleast_1d(np.asarray(his, np.uint64))
        if self._tuner is not None:
            # host-side workload sampling (numpy histogram + reservoir);
            # the device probe dispatch below stays untouched
            self._tuner.observe_scan(los, his)
        with _obs_trace.span("store/scan", batch=len(los)):
            fence, touch = self._touch_masks(los, his)
            return [self._scan_one(int(lo), int(hi), fence[b], touch[b])
                    for b, (lo, hi) in enumerate(zip(los, his))]

    def _scan_one(self, lo: int, hi: int, fence: np.ndarray,
                  touch: np.ndarray) -> list:
        st = self.stats
        st.scans += 1
        seen = set()
        out = {}
        for k, v in self.mem.items():
            if lo <= k <= hi:
                seen.add(k)
                if v is not TOMBSTONE:
                    out[k] = v
        R = len(self._runs)
        st.scan_runs_considered += R
        st.scan_fence_skips += int((~fence).sum())
        st.scan_filter_skips += int((fence & ~touch).sum())
        for r_idx, run in enumerate(self._runs):
            if not touch[r_idx]:
                st.bytes_not_read += run.data_bytes(self.cfg.value_bytes)
                continue
            st.scan_runs_touched += 1
            st.bytes_read += run.data_bytes(self.cfg.value_bytes)
            ks, vs, tbs = run.slice(lo, hi)
            if len(ks) == 0:
                st.scan_fp_reads += 1
                continue
            for k, v, t in zip(ks, vs, tbs):
                k = int(k)
                if k in seen:
                    continue        # masked by a newer source
                seen.add(k)
                if not t:
                    out[k] = v
        return sorted(out.items())

    # ------------------------------------------------------------------
    # introspection / snapshots
    # ------------------------------------------------------------------
    @property
    def n_runs(self) -> int:
        return sum(len(lvl) for lvl in self.levels)

    def filter_bits(self) -> int:
        return sum(r.layout.total_bits for r in self.live_runs()
                   if r.state is not None)

    def quarantined_runs(self) -> List[Run]:
        """Live runs whose filter block failed its checksum."""
        return [r for r in self.live_runs() if r.quarantined]

    def snapshot(self, flush_first: bool = True) -> dict:
        """Compressed snapshot of the store's full state.

        The memtable is not serializable as such, so by default the store
        flushes it into a level-0 run first — a snapshot that silently
        dropped unflushed writes was this API's original sin.  Pass
        ``flush_first=False`` to snapshot only the frozen runs; without a
        WAL to re-cover the memtable that choice warns, because the
        unflushed entries exist nowhere else.

        v3 snapshots carry per-run component CRCs (``Run.pack``);
        ``restore`` accepts v1/v2 too (unverified).
        """
        if flush_first:
            self.flush()
        elif len(self.mem) and self._wal is None:
            warnings.warn(
                f"snapshot(flush_first=False) with {len(self.mem)} unflushed "
                f"memtable entries and no WAL: those writes are not in the "
                f"snapshot and will not survive a restore",
                RuntimeWarning, stacklevel=2)
        snap = {"schema": "bloomrf-store/v3",
                "config": dataclasses.asdict(self.cfg),
                "stats": self.stats.durable_snapshot(),
                "levels": [[r.pack() for r in lvl] for lvl in self.levels]}
        if self._tuner is not None:
            # the fitted workload model (bloomrf-workload/v1) rides along
            # so a reopened store resumes tuning from its sample
            snap["workload"] = self._tuner.to_dict()
        return snap

    @classmethod
    def restore(cls, snap: dict) -> "Store":
        """Validated inverse of :meth:`snapshot` (in-memory only: a durable
        config's WAL is NOT attached here — recover through
        :meth:`Store.open` instead).

        Malformed or corrupted input raises an actionable ``ValueError``
        (never a segfault or a silent mis-restore); a run whose *filter
        block* alone is corrupt restores quarantined (see ``Run.unpack``).
        """
        if not isinstance(snap, dict):
            raise ValueError(f"store snapshot must be a dict, "
                             f"got {type(snap).__name__}")
        if snap.get("schema") not in ("bloomrf-store/v1", "bloomrf-store/v2",
                                      "bloomrf-store/v3"):
            raise ValueError(f"not a store snapshot: {snap.get('schema')!r}")
        cfg_enc = snap.get("config")
        if not isinstance(cfg_enc, dict):
            raise ValueError("store snapshot: 'config' must be a dict")
        try:
            cfg = StoreConfig(**cfg_enc)
        except (TypeError, ValueError) as e:
            raise ValueError(f"store snapshot: bad config: {e}") from e
        store = cls(cfg, _warn=False, _open_wal=False)
        levels_enc = snap.get("levels")
        if (not isinstance(levels_enc, list)
                or not all(isinstance(lvl, list) for lvl in levels_enc)):
            raise ValueError("store snapshot: 'levels' must be a list of "
                             "run lists")
        store.levels = [[Run.unpack(enc) for enc in lvl]
                        for lvl in levels_enc]
        if not store.levels:
            store.levels = [[]]
        if store.cfg.filter_backend not in ("bloomrf", "none"):
            for lvl in store.levels:     # baselines don't snapshot: rebuild
                for r in lvl:
                    r.alt = _baseline_factory(store.cfg.filter_backend)(
                        store.cfg.bits_per_key)
                    r.alt.build(r.keys)
        stats_enc = snap.get("stats")    # optional: absent in v1/v2 or
        if stats_enc is not None:        # pre-§15 v3 snapshots
            if (not isinstance(stats_enc, dict)
                    or not set(stats_enc) <= set(StoreStats.DURABLE)
                    or not all(isinstance(v, int) and not isinstance(v, bool)
                               and v >= 0 for v in stats_enc.values())):
                raise ValueError(
                    "store snapshot: 'stats' must map durable counter "
                    "names to non-negative ints")
            for k, v in stats_enc.items():
                setattr(store.stats, k, v)
        wl_enc = snap.get("workload")    # optional: adaptive-tuned stores
        if wl_enc is not None:
            from ..tune import WorkloadModel

            try:
                model = WorkloadModel.from_dict(wl_enc)
            except ValueError as e:
                raise ValueError(
                    f"store snapshot: bad workload model: {e}") from e
            if store._tuner is not None:
                if model.d != store.cfg.d:
                    raise ValueError(
                        f"store snapshot: workload model d={model.d} does "
                        f"not match config d={store.cfg.d}")
                store._tuner.load(wl_enc)
        store._dirty = True
        return store

    # ------------------------------------------------------------------
    # durability: checkpoint / recovery / scrub (DESIGN.md §14)
    # ------------------------------------------------------------------
    def checkpoint(self) -> str:
        """Make the current state durable; returns the snapshot path.

        Protocol: flush the memtable, write ``snapshot-<seq>.bin``
        atomically (temp file + rename), publish the self-checksummed
        manifest naming it (also atomic), and only then reset the WAL and
        GC older snapshots.  A crash at any point leaves a recoverable
        directory: before the manifest rename the old checkpoint + full
        WAL still recover everything; after it, WAL replay is idempotent
        (last-write-wins), so replaying records the snapshot already
        holds changes nothing."""
        if self._wal is None:
            raise ValueError("checkpoint() requires durability='wal' "
                             "(open the store with a durable StoreConfig "
                             "or Store.open)")
        with _obs_trace.span("store/checkpoint"):
            self.flush()
            snap = self.snapshot(flush_first=False)
            blob = pickle.dumps(snap, protocol=pickle.HIGHEST_PROTOCOL)
            self._seq += 1
            name = f"snapshot-{self._seq:08d}.bin"
            path = os.path.join(self.cfg.wal_dir, name)
            atomic_write_bytes(path, blob, fault=self.faults,
                               fault_point="snapshot.before_rename")
            write_manifest(self.cfg.wal_dir,
                           {"snapshot": name, "crc32": crc32_bytes(blob),
                            "seq": self._seq},
                           fault=self.faults)
            self._wal.reset()
            self._gc_snapshots(keep=name)
            return path

    def _gc_snapshots(self, keep: str) -> None:
        """Drop superseded/orphaned snapshot files (best-effort)."""
        for fn in os.listdir(self.cfg.wal_dir):
            if (fn.startswith("snapshot-") and fn.endswith(".bin")
                    and fn != keep):
                try:
                    os.unlink(os.path.join(self.cfg.wal_dir, fn))
                except OSError:
                    pass

    @classmethod
    def open(cls, wal_dir: str, config: Optional[StoreConfig] = None, *,
             faults: Optional[FaultPlan] = None) -> "Store":
        """Open (or crash-recover) the durable store rooted at ``wal_dir``.

        Recovery trusts nothing unverified: the manifest's own CRC, then
        the snapshot file's CRC against the manifest's record, then every
        run's component CRCs (``Run.unpack``).  After the snapshot loads,
        the WAL is healed of any torn tail and its records replay into
        the memtable — replay is idempotent, so records the snapshot
        already holds are harmless.  ``config`` seeds a fresh store when
        no checkpoint exists yet (its ``durability``/``wal_dir`` are
        forced to this directory either way)."""
        manifest = read_manifest(wal_dir)    # ValueError on corruption
        if manifest is not None:
            name = manifest.get("snapshot")
            path = os.path.join(wal_dir, str(name))
            try:
                with open(path, "rb") as f:
                    blob = f.read()
            except OSError as e:
                raise ValueError(f"manifest names snapshot {name!r} but it "
                                 f"cannot be read: {e}") from e
            if crc32_bytes(blob) != int(manifest.get("crc32", -1)):
                raise ValueError(
                    f"snapshot {name!r} fails its manifest CRC — torn write "
                    f"or bit rot; restore from a previous checkpoint")
            try:
                snap = pickle.loads(blob)
            except Exception as e:
                raise ValueError(f"snapshot {name!r} passed its CRC but "
                                 f"does not unpickle: {e}") from e
            store = cls.restore(snap)
            store.cfg = dataclasses.replace(store.cfg, durability="wal",
                                            wal_dir=wal_dir)
            store._seq = int(manifest.get("seq", 0))
        else:
            cfg = config if config is not None else StoreConfig(
                durability="wal", wal_dir=wal_dir)
            cfg = dataclasses.replace(cfg, durability="wal", wal_dir=wal_dir)
            store = cls(cfg, _warn=False, _open_wal=False)
        store.faults = faults
        os.makedirs(wal_dir, exist_ok=True)
        store._wal = Wal(os.path.join(wal_dir, WAL_FILENAME),
                         sync=store.cfg.wal_sync).open_for_append()
        store._replay_wal()
        return store

    def _replay_wal(self) -> None:
        """Re-apply every intact WAL record through the memtable.

        Records go straight into the memtable (not through ``put`` — they
        must not re-append to the log they came from) with the normal
        flush trigger, so replaying more than ``memtable_limit`` records
        rebuilds runs exactly as the live path would have.

        Replayed records re-enter the durable ``puts``/``deletes``
        counters: the restored snapshot's stats stop at checkpoint time,
        so the post-checkpoint tail must be re-counted for the durable
        totals to equal every acked write (DESIGN.md §15)."""
        n = 0
        with _obs_trace.span("wal/replay"):
            for op, key, value in self._wal.replay():
                if op == "put":
                    self.mem.put(int(key), value)
                    self.stats.puts += 1
                elif op == "del":
                    self.mem.delete(int(key))
                    self.stats.deletes += 1
                else:                   # "delm": one frame, many tombstones
                    for k in key:
                        self.mem.delete(int(k))
                    self.stats.deletes += len(key)
                n += 1
                if len(self.mem) >= self.cfg.memtable_limit:
                    self.flush()
        self.stats.wal_replayed = n

    def close(self) -> None:
        """Release the WAL file handle (the store stays readable)."""
        if self._wal is not None:
            self._wal.close()

    def scrub(self, sample_keys: int = 64, seed: int = 0) -> dict:
        """Full integrity pass over every live run.

        Re-checks each run's component CRCs against its build-time
        reference: a keys/fences/values mismatch raises (data corruption
        has no graceful mode), a filter-block mismatch quarantines the
        run in place.  Then re-asserts the no-false-negative contract on
        up to ``sample_keys`` sampled live keys per run — each must probe
        "maybe" on its own row (a quarantined row trivially does).
        Returns a report dict."""
        with _obs_trace.span("store/scrub"):
            return self._scrub_inner(sample_keys, seed)

    def _scrub_inner(self, sample_keys: int, seed: int) -> dict:
        self._refresh()
        rng = np.random.default_rng(seed)
        newly = 0
        for r in self._runs:
            res = r.verify()
            bad = [c for c in ("keys", "fences", "vals", "tombs")
                   if not res.get(c, True)]
            if bad:
                raise ValueError(
                    f"scrub: level-{r.level} run failed {bad} checksum(s) — "
                    f"data corruption; restore from a checkpoint")
            if not res.get("filter", True) and not r.quarantined:
                r.quarantined = True
                newly += 1
                self._dirty = True
        if newly:
            self._refresh()
        report = {"runs": len(self._runs),
                  "quarantined": int(sum(r.quarantined for r in self._runs)),
                  "newly_quarantined": newly,
                  "fn_checked": 0}
        for idx, r in enumerate(self._runs):
            live = r.keys[~r.tombs]
            if len(live) == 0:
                continue
            pick = (live if len(live) <= sample_keys
                    else rng.choice(live, sample_keys, replace=False))
            fence, filt = self.probe_runs(pick, pick, point=True)
            report["fn_checked"] += len(pick)
            if not (fence[:, idx] & filt[:, idx]).all():
                raise ValueError(
                    f"scrub: filter false negative on level-{r.level} run "
                    f"{idx} — filter block corrupt beyond its checksum")
        return report
