"""The LSM run-store: memtable + leveled runs + one-gather filter probes.

Write path: ``put``/``delete`` land in the memtable; at
``memtable_limit`` entries the memtable flushes to an immutable level-0
:class:`~repro.store.run.Run` carrying a bloomRF filter block (layout
chosen from a capacity-class ladder) and min/max fences.  When level 0
exceeds ``level0_runs`` runs, leveled compaction merges them (plus the
next level's run) downward — same-class filter blocks merge with a single
``bitwise_or``, class-graduating merges re-insert through the kernels
insert path (``compaction.merge_filter_state``).

Read path: ``get``/``scan`` first consult the memtable, then probe **all**
live runs' filters at once — the per-run states are concatenated into one
flat lane vector and probed through ``core.engine.StackedProbe``, so a
scan over R runs costs exactly ONE fused gather over the stacked filter
state regardless of R or the mix of capacity classes (jaxpr-asserted in
the test suite).  Only runs whose fences overlap *and* whose filter says
"maybe" have their data blocks touched; :class:`StoreStats` counts what
the filters saved (skips, false-positive reads, bytes not read).

Filters are insert-only at write time: a delete writes a tombstone
*entry* whose key is inserted like any other, so newer tombstones are
discoverable through the filters and mask older runs at read time; no
filter bit is cleared outside compaction.  With
``mutability="deletable"`` compaction fights the resulting FPR drift:
class-graduating merges *promote* source filters in place (segment
tiling, ``core/dynamic.py``) instead of replaying keys, and when a
merge's dead-entry fraction exceeds ``purge_dead_frac`` the filter is
rebuilt from the surviving keys — purging every deleted key's bits at
the natural rebuild point (DESIGN.md §12).

``filter_backend`` swaps the per-run filter: ``"bloomrf"`` (stacked
one-gather probes), ``"none"`` (min/max fences only — the pruning
baseline), or any of the host-side baselines from ``repro.filters``
(``"bloom"``, ``"prefix_bloom"``, ``"rosetta"``, ``"surf"``) for
side-by-side comparisons in ``benchmarks/store_bench.py``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import basic_layout, key_dtype_for
from ..core.engine import _filter_for_layout, stacked_probe
from ..kernels import FilterOps, read_vmem_budget_u32
from ..kernels.store_scan import DEFAULT_TILE as STORE_SCAN_TILE
from ..kernels.store_scan import build_run_stack, store_scan_probe
from .compaction import merge_filter_state, merge_sorted_runs
from .memtable import TOMBSTONE, Memtable
from .run import Run

__all__ = ["Store", "StoreConfig", "StoreStats"]


def _baseline_factory(name: str):
    from .. import filters as F

    return {
        "bloom": lambda bpk: F.BloomFilter(bits_per_key=bpk),
        "prefix_bloom": lambda bpk: F.PrefixBloomFilter(bits_per_key=bpk),
        "rosetta": lambda bpk: F.Rosetta(bits_per_key=bpk),
        "surf": lambda bpk: F.SuRFLite(),
    }[name]


@jax.jit
def _fence_touch_device(kmin, kmax, lo, hi):
    """Fence-only pruning plane (``filter_backend="none"``): every fenced
    run is touched."""
    lo = jnp.atleast_1d(lo)
    hi = jnp.atleast_1d(hi)
    fence = ((hi[:, None] >= kmin[None, :]) & (lo[:, None] <= kmax[None, :]))
    return fence, fence


@dataclasses.dataclass(frozen=True)
class StoreConfig:
    d: int = 32                     # key-domain bits
    memtable_limit: int = 4096      # entries per flush (= capacity class 0)
    bits_per_key: float = 14.0
    delta: int = 6
    fanout: int = 4                 # capacity-class / level size ratio
    level0_runs: int = 4            # level-0 run count that triggers compaction
    filter_backend: str = "bloomrf"  # "bloomrf" | "none" | repro.filters name
    scan_backend: str = "auto"      # scan-pruning plane: "auto" | "kernel"
                                    # | "xla" — "kernel" runs the fused
                                    # store-scan Pallas megakernel
                                    # (kernels/store_scan.py), "xla" the
                                    # StackedProbe.touch_all reference,
                                    # "auto" picks the kernel on TPU only
                                    # (interpret-mode Pallas is slow on CPU)
    use_insert_kernels: bool = False  # route rebuilds through FilterOps.insert
    value_bytes: int = 64           # per-entry data-block size for accounting
    seed: int = 0x0B100F11
    mutability: str = "insert_only"  # "insert_only" | "deletable"
    purge_dead_frac: float = 0.25   # deletable: dead fraction forcing a purge
    promote_max_hops: int = 1       # promote hops a filter survives before a
                                    # rebuild is forced (promotion keeps the
                                    # source class's resolution, so each hop
                                    # multiplies FPR by the source count)
    promote_density_slack: float = 1.5  # promote only when the OR-union's
                                    # per-layer density stays within this
                                    # factor of a rebuild's (compaction.py)

    def __post_init__(self):
        if not (1 <= self.d <= 64):
            raise ValueError(
                f"d must be in 1..64 (uint64 key domain), got {self.d}")
        if not self.bits_per_key > 0:
            raise ValueError(
                f"bits_per_key must be > 0, got {self.bits_per_key}")
        if self.memtable_limit < 1 or self.fanout < 2 or self.level0_runs < 1:
            raise ValueError("memtable_limit >= 1, fanout >= 2, "
                             "level0_runs >= 1 required")
        if self.mutability not in ("insert_only", "deletable"):
            raise ValueError(
                f"mutability must be 'insert_only' or 'deletable', "
                f"got {self.mutability!r}")
        if not (0.0 < self.purge_dead_frac <= 1.0):
            raise ValueError(
                f"purge_dead_frac must be in (0, 1], got {self.purge_dead_frac}")
        if self.promote_max_hops < 0:
            raise ValueError(
                f"promote_max_hops must be >= 0, got {self.promote_max_hops}")
        if not self.promote_density_slack > 0:
            raise ValueError(f"promote_density_slack must be > 0, "
                             f"got {self.promote_density_slack}")
        if self.filter_backend not in ("bloomrf", "none"):
            _baseline_factory(self.filter_backend)  # raises on unknown name
        if self.scan_backend not in ("auto", "kernel", "xla"):
            raise ValueError(f"scan_backend must be 'auto', 'kernel' or "
                             f"'xla', got {self.scan_backend!r}")


@dataclasses.dataclass
class StoreStats:
    """Counters for what the filter blocks saved on the read path."""

    puts: int = 0
    deletes: int = 0
    gets: int = 0
    scans: int = 0
    flushes: int = 0
    compactions: int = 0
    or_merges: int = 0              # same-layout filter merges (bitwise OR)
    rebuild_merges: int = 0         # cross-layout merges (key re-insert)
    promote_merges: int = 0         # in-place segment-tiled class promotions
    purge_rebuilds: int = 0         # rebuilds forced by the dead-frac policy
    # point reads
    get_runs_considered: int = 0
    get_fence_skips: int = 0
    get_filter_skips: int = 0
    get_run_reads: int = 0
    get_fp_reads: int = 0           # run read, key absent
    # scans
    scan_runs_considered: int = 0
    scan_fence_skips: int = 0
    scan_filter_skips: int = 0
    scan_runs_touched: int = 0
    scan_fp_reads: int = 0          # run touched, empty slice
    # data-block bytes
    bytes_read: int = 0
    bytes_not_read: int = 0         # skipped runs' data bytes

    @property
    def runs_probed_per_scan(self) -> float:
        return self.scan_runs_touched / max(self.scans, 1)

    @property
    def scan_fp_read_rate(self) -> float:
        return self.scan_fp_reads / max(self.scan_runs_touched, 1)

    @property
    def get_fp_read_rate(self) -> float:
        return self.get_fp_reads / max(self.get_run_reads, 1)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["runs_probed_per_scan"] = self.runs_probed_per_scan
        d["scan_fp_read_rate"] = self.scan_fp_read_rate
        d["get_fp_read_rate"] = self.get_fp_read_rate
        return d


class Store:
    """LSM key-value store with per-run bloomRF filter blocks."""

    def __init__(self, config: Optional[StoreConfig] = None, *,
                 _warn: bool = True, **kw):
        if _warn:
            from .._compat import warn_legacy

            warn_legacy("Store(StoreConfig(...))",
                        "dtype=..., placement='store', ...")
        self.cfg = config if config is not None else StoreConfig(**kw)
        self.kdtype = key_dtype_for(self.cfg.d)
        self.mem = Memtable()
        self.levels: List[List[Run]] = [[]]   # levels[0] newest-first
        self.stats = StoreStats()
        self._ops: dict = {}                  # FilterOps per layout
        self._runs: List[Run] = []
        self._flat = None                     # stacked filter lanes
        self._probe = None
        self._kmins = self._kmaxs = None      # per-run fences, np.uint64 (R,)
        self._kstate = None                   # lazy megakernel inputs
        self._fence_dev = None                # lazy device fences (kdtype)
        self._dirty = True

    # ------------------------------------------------------------------
    # capacity classes and filter construction
    # ------------------------------------------------------------------
    def class_capacity(self, cls: int) -> int:
        return self.cfg.memtable_limit * self.cfg.fanout ** cls

    def class_layout(self, n_keys: int):
        """Layout of the smallest capacity class that fits ``n_keys``."""
        cls = 0
        while self.class_capacity(cls) < n_keys:
            cls += 1
        return basic_layout(self.cfg.d, self.class_capacity(cls),
                            self.cfg.bits_per_key,
                            delta=min(self.cfg.delta, self.cfg.d),
                            seed=self.cfg.seed)

    def _build_filter(self, layout, keys: np.ndarray) -> jnp.ndarray:
        """Bulk filter build; the compaction rebuild path lands here too."""
        kj = jnp.asarray(keys, self.kdtype)
        if self.cfg.use_insert_kernels and layout.d <= 32:
            if layout not in self._ops:
                self._ops[layout] = FilterOps(layout, _warn=False)
            ops = self._ops[layout]
            return ops.insert(ops.init_state(), kj)
        return _filter_for_layout(layout).build(kj)

    def _make_run(self, keys: np.ndarray, vals: list, tombs: np.ndarray,
                  level: int) -> Run:
        layout = self.class_layout(len(keys))
        state = alt = None
        if self.cfg.filter_backend == "bloomrf":
            state = self._build_filter(layout, keys)
        elif self.cfg.filter_backend != "none":
            alt = _baseline_factory(self.cfg.filter_backend)(
                self.cfg.bits_per_key)
            alt.build(keys)
        return Run(keys, vals, tombs, level, layout, state, alt=alt)

    # ------------------------------------------------------------------
    # write path
    # ------------------------------------------------------------------
    def _check_key(self, key: int) -> int:
        key = int(key)
        if not (0 <= key < (1 << self.cfg.d)):
            raise ValueError(f"key {key} outside the {self.cfg.d}-bit domain")
        return key

    def put(self, key: int, value) -> None:
        self.mem.put(self._check_key(key), value)
        self.stats.puts += 1
        if len(self.mem) >= self.cfg.memtable_limit:
            self.flush()

    def delete(self, key: int) -> None:
        self.mem.delete(self._check_key(key))
        self.stats.deletes += 1
        if len(self.mem) >= self.cfg.memtable_limit:
            self.flush()

    def delete_many(self, keys) -> None:
        """Batched deletes: every tombstone lands in the memtable before the
        single flush decision, so a large eviction sweep triggers at most one
        flush (plus its own compaction cascade) instead of one per
        ``memtable_limit`` keys interleaved with the caller's scan."""
        n = 0
        for key in keys:
            self.mem.delete(self._check_key(key))
            n += 1
        self.stats.deletes += n
        if len(self.mem) >= self.cfg.memtable_limit:
            self.flush()

    def flush(self) -> None:
        """Freeze the memtable into a new level-0 run."""
        if len(self.mem) == 0:
            return
        keys, vals, tombs = self.mem.sorted_entries()
        self.levels[0].insert(0, self._make_run(keys, vals, tombs, 0))
        self.mem.clear()
        self.stats.flushes += 1
        self._dirty = True
        self._maybe_compact()

    # ------------------------------------------------------------------
    # compaction
    # ------------------------------------------------------------------
    def _maybe_compact(self) -> None:
        if len(self.levels[0]) > self.cfg.level0_runs:
            self.compact(0)
        lvl = 1
        while lvl < len(self.levels):
            runs = self.levels[lvl]
            if runs and len(runs[0]) > self.class_capacity(lvl):
                self.compact(lvl)
            lvl += 1

    def compact(self, level: int) -> None:
        """Merge every run at ``level`` (plus the next level's run) down."""
        if level >= len(self.levels) or not self.levels[level]:
            return
        if level + 1 >= len(self.levels):
            self.levels.append([])
        sources = self.levels[level] + self.levels[level + 1]
        bottom = not any(self.levels[lv] for lv in
                         range(level + 2, len(self.levels)))
        keys, vals, tombs = merge_sorted_runs(sources,
                                              drop_tombstones=bottom)
        self.levels[level] = []
        if len(keys) == 0:          # everything tombstoned away
            self.levels[level + 1] = []
            self.stats.compactions += 1
            self._dirty = True
            return
        target_layout = self.class_layout(len(keys))
        state = alt = None
        if self.cfg.filter_backend == "bloomrf":
            # fraction of merged entries that did not survive (shadowed
            # duplicates + dropped tombstones): the bits those entries set
            # are dead weight in an OR/promote-merged filter
            n_in = sum(len(r) for r in sources)
            dead_frac = 1.0 - len(keys) / n_in
            deletable = self.cfg.mutability == "deletable"
            # cap promotion depth: a promoted filter still answers at its
            # source class's resolution, so hop-on-hop promotion compounds
            # FPR; once any source has used its hops, rebuild fresh
            hops = max((r.promotions for r in sources), default=0)
            state, how = merge_filter_state(
                sources, target_layout, keys, self._build_filter,
                dead_frac=dead_frac,
                purge_dead_frac=(self.cfg.purge_dead_frac if deletable
                                 else None),
                allow_promote=deletable
                and hops < self.cfg.promote_max_hops,
                promote_density_slack=self.cfg.promote_density_slack)
            counter = {"or": "or_merges", "promote": "promote_merges",
                       "rebuild": "rebuild_merges", "purge": "purge_rebuilds"}
            setattr(self.stats, counter[how],
                    getattr(self.stats, counter[how]) + 1)
            promotions = {"or": hops, "promote": hops + 1}.get(how, 0)
        elif self.cfg.filter_backend != "none":
            alt = _baseline_factory(self.cfg.filter_backend)(
                self.cfg.bits_per_key)
            alt.build(keys)
            self.stats.rebuild_merges += 1
            promotions = 0
        else:
            promotions = 0
        self.levels[level + 1] = [
            Run(keys, vals, tombs, level + 1, target_layout, state, alt=alt,
                promotions=promotions)]
        self.stats.compactions += 1
        self._dirty = True

    # ------------------------------------------------------------------
    # stacked filter probes (the one-gather read path)
    # ------------------------------------------------------------------
    def live_runs(self) -> List[Run]:
        """All runs, newest precedence first (L0 newest-first, then down)."""
        self._refresh()
        return self._runs

    def _refresh(self) -> None:
        if not self._dirty:
            return
        self._runs = [r for lvl in self.levels for r in lvl]
        self._flat = self._probe = None
        self._kstate = self._fence_dev = None
        self._kmins = np.asarray([r.kmin for r in self._runs], np.uint64)
        self._kmaxs = np.asarray([r.kmax for r in self._runs], np.uint64)
        if self._runs and self.cfg.filter_backend == "bloomrf":
            states = [r.state for r in self._runs]
            self._flat = (states[0] if len(states) == 1
                          else jnp.concatenate(states))
            sizes = [r.layout.total_u32 for r in self._runs]
            bases = tuple(int(b) for b in
                          np.cumsum([0] + sizes[:-1], dtype=np.int64))
            self._probe = stacked_probe(
                tuple(r.layout for r in self._runs), bases)
        self._dirty = False

    def _fence_mask(self, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
        """(B, R) bool: query interval overlaps the run's [kmin, kmax]."""
        return ((hi[:, None] >= self._kmins[None, :])
                & (lo[:, None] <= self._kmaxs[None, :]))

    def _filter_mask(self, lo: np.ndarray, hi: np.ndarray,
                     point: bool) -> np.ndarray:
        """(B, R) bool filter verdicts (True = run may hold a match)."""
        if self.cfg.filter_backend == "none":
            return np.ones((len(lo), len(self._runs)), bool)
        if self.cfg.filter_backend == "bloomrf":
            if point:
                v = self._probe.point_all(self._flat,
                                          jnp.asarray(lo, self.kdtype))
            else:
                v = self._probe.range_all(self._flat,
                                          jnp.asarray(lo, self.kdtype),
                                          jnp.asarray(hi, self.kdtype))
            return np.asarray(v)
        cols = [r.alt.point(lo) if point else r.alt.range(lo, hi)
                for r in self._runs]
        return np.stack(cols, axis=1)

    def probe_runs(self, lo, hi, point: bool = False
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Batched pruning verdicts over all live runs.

        Returns ``(fence, filt)``, each (B, R) bool — the fence overlap
        mask and the filter verdicts.  A run is touched only where both
        are True.  One fused gather for the whole batch x run matrix when
        the backend is bloomRF."""
        self._refresh()
        lo = np.atleast_1d(np.asarray(lo, np.uint64))
        hi = lo if point else np.atleast_1d(np.asarray(hi, np.uint64))
        if not self._runs:
            z = np.zeros((len(lo), 0), bool)
            return z, z
        fence = self._fence_mask(lo, hi)
        # Filter probes run in the filter's d-bit dtype: clamp bounds into
        # the domain first, or an out-of-domain `hi` would wrap under the
        # dtype cast and the (min/max-normalised) probe would answer the
        # wrong interval — a false negative the fences don't catch.  The
        # clamped interval is exactly `query ∩ domain`; queries entirely
        # above the domain are already fenced off (kmax <= dmax < lo).
        dmax = np.uint64((1 << self.cfg.d) - 1)
        filt = self._filter_mask(np.minimum(lo, dmax), np.minimum(hi, dmax),
                                 point)
        return fence, filt

    # ------------------------------------------------------------------
    # fused scan-pruning plane (fence ∧ filter in one device step)
    # ------------------------------------------------------------------
    def _scan_kernel_mode(self) -> str:
        """Resolve ``cfg.scan_backend`` for the current run stack.

        The megakernel handles bloomRF stacks in the uint32 key domain
        (the capacity-class ladder never emits exact-bitmap layouts, so
        d <= 32 is the only real constraint); everything else takes the
        XLA-exact path.  ``auto`` picks the kernel only on a real TPU —
        interpret-mode Pallas on CPU is for parity tests, not speed."""
        if (self.cfg.scan_backend == "xla"
                or self.cfg.filter_backend != "bloomrf"
                or self.cfg.d > 32 or not self._runs):
            return "xla"
        if self.cfg.scan_backend == "kernel":
            return "kernel"
        return "kernel" if jax.default_backend() == "tpu" else "xla"

    def _kernel_inputs(self):
        """Megakernel operands for the live stack, built once per refresh:
        the padded ``(R, rowpad)`` run stack, uint32 device fences, and a
        ``runs_per_block`` split sized so one filter block fits the VMEM
        budget (the Pallas grid pipeline streams blocks beyond it)."""
        if self._kstate is None:
            layouts = tuple(r.layout for r in self._runs)
            stack = build_run_stack([r.state for r in self._runs])
            rowpad, R = int(stack.shape[1]), len(self._runs)
            budget = read_vmem_budget_u32()
            rpb = R if rowpad * R <= budget else max(1, budget // rowpad)
            self._kstate = (layouts, stack,
                            jnp.asarray(self._kmins, jnp.uint32),
                            jnp.asarray(self._kmaxs, jnp.uint32), int(rpb))
        return self._kstate

    def _touch_masks(self, lo: np.ndarray,
                     hi: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Host scan pruning: ``(fence, touch)`` (B, R) bool.

        ``touch = fence & filter-maybe`` — the runs whose data blocks a
        scan must read.  Dispatches per ``_scan_kernel_mode``: one fused
        Pallas call, or the XLA fence+probe reference (bit-identical)."""
        self._refresh()
        if not self._runs:
            z = np.zeros((len(lo), 0), bool)
            return z, z
        if self._scan_kernel_mode() == "kernel":
            dmax = np.uint64((1 << self.cfg.d) - 1)
            layouts, stack, kmin_d, kmax_d, rpb = self._kernel_inputs()
            f, t = store_scan_probe(
                layouts, stack, kmin_d, kmax_d,
                jnp.asarray(np.minimum(lo, dmax), jnp.uint32),
                jnp.asarray(np.minimum(hi, dmax), jnp.uint32),
                STORE_SCAN_TILE, rpb, jax.default_backend() != "tpu")
            fence, touch = np.asarray(f), np.asarray(t)
            # the uint32 clamp is exact for every in-domain `lo` (kmin,
            # kmax <= dmax); intervals entirely above the domain must be
            # fenced off on the host instead (kmax <= dmax < lo)
            dead = lo > dmax
            if dead.any():
                fence, touch = fence.copy(), touch.copy()
                fence[dead] = touch[dead] = False
            return fence, touch
        fence, filt = self.probe_runs(lo, hi, point=False)
        return fence, fence & filt

    def scan_probe_device(self, lo, hi) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Device-resident scan pruning: ``(fence, touch)`` (B, R) bool
        jax arrays, no host round-trip — the YCSB device driver's probe
        plane.  Bounds must already lie inside the d-bit key domain
        (``scan_many`` handles out-of-domain clamping on the host).

        One fused megakernel call in ``kernel`` mode; the jit'd
        ``StackedProbe.touch_all`` (still one fused gather) in ``xla``
        mode; fence-only verdicts for ``filter_backend="none"``."""
        self._refresh()
        lo = jnp.atleast_1d(lo)
        if not self._runs:
            z = jnp.zeros((lo.shape[0], 0), bool)
            return z, z
        if self._scan_kernel_mode() == "kernel":
            layouts, stack, kmin_d, kmax_d, rpb = self._kernel_inputs()
            return store_scan_probe(layouts, stack, kmin_d, kmax_d, lo, hi,
                                    STORE_SCAN_TILE, rpb,
                                    jax.default_backend() != "tpu")
        if self._fence_dev is None:
            self._fence_dev = (jnp.asarray(self._kmins, self.kdtype),
                               jnp.asarray(self._kmaxs, self.kdtype))
        kmin_d, kmax_d = self._fence_dev
        lo = jnp.asarray(lo, self.kdtype)
        hi = jnp.asarray(hi, self.kdtype)
        if self.cfg.filter_backend == "bloomrf":
            return self._probe.touch_all(self._flat, kmin_d, kmax_d, lo, hi)
        if self.cfg.filter_backend == "none":
            fence, touch = _fence_touch_device(kmin_d, kmax_d, lo, hi)
            return fence, touch
        raise ValueError(
            f"device scan probing needs the 'bloomrf' or 'none' backend, "
            f"not {self.cfg.filter_backend!r} (host-side baseline)")

    # ------------------------------------------------------------------
    # read path
    # ------------------------------------------------------------------
    def get(self, key: int):
        """Point lookup; None when absent or deleted."""
        return self.get_many(np.asarray([self._check_key(key)], np.uint64))[0]

    def get_many(self, keys) -> list:
        """Batched point lookups: one fused filter gather for the batch."""
        keys = np.atleast_1d(np.asarray(keys, np.uint64))
        st = self.stats
        st.gets += len(keys)
        fence, filt = self.probe_runs(keys, keys, point=True)
        dbytes = np.asarray([r.data_bytes(self.cfg.value_bytes)
                             for r in self._runs], np.int64)
        out = []
        for b, key in enumerate(keys):
            found, v = self.mem.get(int(key))
            if found:
                out.append(None if v is TOMBSTONE else v)
                continue
            result = None
            R = len(self._runs)
            st.get_runs_considered += R
            st.get_fence_skips += int((~fence[b]).sum())
            st.get_filter_skips += int((fence[b] & ~filt[b]).sum())
            # skipped runs save their data blocks on the point path too —
            # mirror of the _scan_one credit, so bytes_not_read covers
            # point-heavy workloads instead of understating savings
            st.bytes_not_read += int(dbytes[~(fence[b] & filt[b])].sum())
            for r_idx in np.flatnonzero(fence[b] & filt[b]):
                run = self._runs[r_idx]
                st.get_run_reads += 1
                st.bytes_read += run.data_bytes(self.cfg.value_bytes)
                hit, val, tomb = run.lookup(int(key))
                if hit:
                    result = None if tomb else val
                    break
                st.get_fp_reads += 1
            out.append(result)
        return out

    def scan(self, lo: int, hi: int) -> list:
        """All live (key, value) pairs with lo <= key <= hi, ascending."""
        return self.scan_many([lo], [hi])[0]

    def scan_many(self, los, his) -> list:
        """Batched scans: the whole pruning plane (fence + filter) in one
        device dispatch for the batch — a single megakernel call or one
        fused XLA gather, per ``StoreConfig.scan_backend``."""
        los = np.atleast_1d(np.asarray(los, np.uint64))
        his = np.atleast_1d(np.asarray(his, np.uint64))
        fence, touch = self._touch_masks(los, his)
        return [self._scan_one(int(lo), int(hi), fence[b], touch[b])
                for b, (lo, hi) in enumerate(zip(los, his))]

    def _scan_one(self, lo: int, hi: int, fence: np.ndarray,
                  touch: np.ndarray) -> list:
        st = self.stats
        st.scans += 1
        seen = set()
        out = {}
        for k, v in self.mem.items():
            if lo <= k <= hi:
                seen.add(k)
                if v is not TOMBSTONE:
                    out[k] = v
        R = len(self._runs)
        st.scan_runs_considered += R
        st.scan_fence_skips += int((~fence).sum())
        st.scan_filter_skips += int((fence & ~touch).sum())
        for r_idx, run in enumerate(self._runs):
            if not touch[r_idx]:
                st.bytes_not_read += run.data_bytes(self.cfg.value_bytes)
                continue
            st.scan_runs_touched += 1
            st.bytes_read += run.data_bytes(self.cfg.value_bytes)
            ks, vs, tbs = run.slice(lo, hi)
            if len(ks) == 0:
                st.scan_fp_reads += 1
                continue
            for k, v, t in zip(ks, vs, tbs):
                k = int(k)
                if k in seen:
                    continue        # masked by a newer source
                seen.add(k)
                if not t:
                    out[k] = v
        return sorted(out.items())

    # ------------------------------------------------------------------
    # introspection / snapshots
    # ------------------------------------------------------------------
    @property
    def n_runs(self) -> int:
        return sum(len(lvl) for lvl in self.levels)

    def filter_bits(self) -> int:
        return sum(r.layout.total_bits for r in self.live_runs()
                   if r.state is not None)

    def snapshot(self) -> dict:
        """Compressed snapshot of every frozen run (memtable excluded —
        flush first for a full-state snapshot).

        v2 snapshots are byte-serializable (run ``vals`` hold ``None``
        placeholders for tombstones instead of the in-process sentinel) and
        carry the churn-policy config fields; ``restore`` accepts v1 too.
        """
        return {"schema": "bloomrf-store/v2",
                "config": dataclasses.asdict(self.cfg),
                "levels": [[r.pack() for r in lvl] for lvl in self.levels]}

    @classmethod
    def restore(cls, snap: dict) -> "Store":
        if snap.get("schema") not in ("bloomrf-store/v1", "bloomrf-store/v2"):
            raise ValueError(f"not a store snapshot: {snap.get('schema')!r}")
        store = cls(StoreConfig(**snap["config"]), _warn=False)
        store.levels = [[Run.unpack(enc) for enc in lvl]
                        for lvl in snap["levels"]]
        if not store.levels:
            store.levels = [[]]
        if store.cfg.filter_backend not in ("bloomrf", "none"):
            for lvl in store.levels:     # baselines don't snapshot: rebuild
                for r in lvl:
                    r.alt = _baseline_factory(store.cfg.filter_backend)(
                        store.cfg.bits_per_key)
                    r.alt.build(r.keys)
        store._dirty = True
        return store
