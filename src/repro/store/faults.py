"""Deterministic fault injection for the crash-recovery test harness.

A :class:`FaultPlan` arms *named seams* threaded through the store's
write/flush/compaction/checkpoint paths (``Store._fault(point)`` calls
:meth:`FaultPlan.hit`): when a seam's countdown reaches zero the plan
raises :class:`InjectedCrash`, simulating a process death at exactly that
point.  The test then throws the live ``Store`` object away and reopens
from disk — whatever bytes the crashed process had durably written are
the recovery input, which is precisely the crash model a WAL defends
against.

Seam names in the store (see DESIGN.md §14 for the full map):

* ``wal.append``            — before a WAL record is framed (write lost,
  but also never acked — the caller saw the exception);
* ``flush.after_run``       — after the memtable froze into a run but
  before anything durable changed (recovery replays the WAL);
* ``compact.before_swap``   — after the merged run + filter are fully
  built, before the level-list swap (crash-atomicity: the old runs must
  stay live, in memory *and* on disk);
* ``snapshot.before_rename`` / ``manifest.before_rename`` — between the
  temp file completing and the ``os.replace`` commit point.

Byte-level corruptions are separate helpers (they damage files, not
control flow): :func:`truncate_tail` tears the WAL's final bytes,
:func:`flip_filter_bits` flips bits inside a packed run's filter block
(the quarantine trigger), both driven by the plan's seeded RNG so a CI
failure replays exactly (``BLOOMRF_FAULT_SEED``).

``fail_pallas`` arms the kernel-dispatch seam (``kernel.dispatch``) with
a countdown of its own: the store-scan megakernel raises at dispatch and
``scan_backend="auto"`` must fall back to the XLA probe plane
(``StoreStats.kernel_fallbacks``) instead of failing the scan.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional

import numpy as np

__all__ = ["FaultPlan", "InjectedCrash", "truncate_tail",
           "flip_filter_bits", "fault_seed_from_env"]

FAULT_SEED_ENV = "BLOOMRF_FAULT_SEED"


class InjectedCrash(RuntimeError):
    """A simulated process death at a named seam (never caught by the
    store itself — it must unwind like a real crash would)."""

    def __init__(self, point: str):
        super().__init__(f"injected crash at {point!r}")
        self.point = point


def fault_seed_from_env(default: int = 0xFA17) -> int:
    """The CI-pinned fuzz seed (``BLOOMRF_FAULT_SEED``), else ``default``."""
    raw = os.environ.get(FAULT_SEED_ENV)
    if raw is None:
        return default
    try:
        return int(raw, 0)
    except ValueError as e:
        raise ValueError(f"{FAULT_SEED_ENV} must be an integer, "
                         f"got {raw!r}") from e


@dataclasses.dataclass
class FaultPlan:
    """Countdown-armed crash points + a seeded RNG for byte corruptions.

    ``crashes`` maps seam name -> hit countdown: ``{"wal.append": 3}``
    crashes on the third append.  ``fail_pallas`` is sugar for the
    ``kernel.dispatch`` seam, except it raises a plain ``RuntimeError``
    (a kernel dispatch failure is an *error the store must absorb*, not a
    process death — the auto backend falls back to XLA and keeps
    serving)."""

    seed: int = 0xFA17
    crashes: Dict[str, int] = dataclasses.field(default_factory=dict)
    fail_pallas: int = 0

    def __post_init__(self):
        for point, count in self.crashes.items():
            if count < 1:
                raise ValueError(f"crash countdown for {point!r} must be "
                                 f">= 1, got {count}")
        self._remaining = dict(self.crashes)
        self._pallas_left = int(self.fail_pallas)
        self.rng = np.random.default_rng(self.seed)
        self.fired: list = []           # seams that actually crashed

    def hit(self, point: str) -> None:
        """Count a pass through ``point``; raise when its countdown ends."""
        if point == "kernel.dispatch":
            if self._pallas_left > 0:
                self._pallas_left -= 1
                self.fired.append(point)
                raise RuntimeError(
                    "injected pallas_call dispatch failure (FaultPlan)")
            return
        left = self._remaining.get(point)
        if left is None:
            return
        if left <= 1:
            del self._remaining[point]
            self.fired.append(point)
            raise InjectedCrash(point)
        self._remaining[point] = left - 1

    def armed(self, point: str) -> bool:
        if point == "kernel.dispatch":
            return self._pallas_left > 0
        return point in self._remaining


# ---------------------------------------------------------------------------
# byte-level corruptions
# ---------------------------------------------------------------------------

def truncate_tail(path: str, rng: Optional[np.random.Generator] = None,
                  max_bytes: int = 64) -> int:
    """Tear 1..``max_bytes`` bytes off a file's end (a torn final write).

    Returns the number of bytes removed (0 for an empty/absent file)."""
    rng = rng if rng is not None else np.random.default_rng(0)
    size = os.path.getsize(path) if os.path.exists(path) else 0
    if size == 0:
        return 0
    cut = int(rng.integers(1, min(max_bytes, size) + 1))
    with open(path, "r+b") as f:
        f.truncate(size - cut)
    return cut


def flip_filter_bits(enc: dict, rng: Optional[np.random.Generator] = None,
                     nbits: int = 1) -> dict:
    """Flip ``nbits`` random bits inside a packed run's filter payload.

    ``enc`` is a :meth:`Run.pack` dict; the flip lands in the Elias-Fano
    ``low`` plane of the packed filter (dense raw bits, so any flip
    changes decoded state without breaking the EF structure).  Returns a
    deep-enough copy — the input dict is not modified.  The component CRC
    recorded at pack time no longer matches, which is exactly what
    ``Run.unpack`` quarantines on."""
    if "filter" not in enc:
        raise ValueError("run snapshot has no filter block to corrupt")
    rng = rng if rng is not None else np.random.default_rng(0)
    enc = dict(enc)
    ef = dict(enc["filter"])            # {"n", "u", "l", "low", "high"}
    target = "low" if np.size(ef.get("low")) else "high"
    flat = np.array(ef[target], np.uint8, copy=True)
    if flat.size == 0:
        raise ValueError("filter payload too small to corrupt")
    for _ in range(nbits):
        i = int(rng.integers(0, flat.size))
        flat[i] ^= np.uint8(1 << int(rng.integers(0, 8)))
    ef[target] = flat
    enc["filter"] = ef
    return enc
