"""Append-only, CRC-framed write-ahead log for the LSM store.

Durability contract (DESIGN.md §14): ``put``/``delete``/``delete_many``
append a framed record *before* the memtable acks the write, so a crash
between ack and the next checkpoint loses nothing — reopening the store
replays the log and reproduces every acknowledged write.  The log only
resets at :meth:`Store.checkpoint` time, after the snapshot + manifest
have been atomically renamed into place; replay is idempotent (the store
is last-write-wins), so a crash between the manifest rename and the WAL
reset merely replays records the snapshot already holds.

Frame format (little-endian)::

    u32 length | u32 crc32(payload) | payload bytes

The payload is a pickled ``(op, key, value)`` record with ``op`` one of
``"put"`` / ``"del"`` / ``"delm"`` (batched delete; ``key`` is a list).
Appends are buffered through one ``BufferedWriter`` and flushed to the OS
per record (``sync="always"`` additionally fsyncs — power-failure-proof
at a heavy per-op cost; the default ``"flush"`` survives process
crashes, the threat model of the fuzz harness).

**Truncated-tail tolerance**: a crash can tear the final frame (short
header, short payload, or a CRC mismatch from a partial write).
:meth:`Wal.replay` yields every intact record and stops at the first bad
frame; :meth:`Wal.open_for_append` then truncates the file back to the
last good frame boundary so later appends never sit behind an unreadable
gap.  Torn bytes can only belong to the record being written at crash
time — an un-acked write — so dropping them never loses acknowledged
data.
"""
from __future__ import annotations

import io
import os
import pickle
import struct
import zlib
from typing import Iterator, List, Optional, Tuple

from ..obs import metrics as _obs_metrics
from ..obs import trace as _obs_trace

__all__ = ["Wal", "WalRecord", "WAL_FILENAME"]

WAL_FILENAME = "wal.log"
_HEADER = struct.Struct("<II")          # (payload length, crc32)
_MAX_RECORD = 1 << 28                   # 256 MiB sanity cap per frame

WalRecord = Tuple[str, object, object]  # (op, key, value)
_OPS = ("put", "del", "delm")


class Wal:
    """One append-only log file with CRC-framed records."""

    def __init__(self, path: str, sync: str = "flush"):
        if sync not in ("flush", "always"):
            raise ValueError(f"sync must be 'flush' or 'always', got {sync!r}")
        self.path = path
        self.sync = sync
        self._f: Optional[io.BufferedWriter] = None
        #: records lost to a torn tail at the last open (un-acked writes)
        self.torn_bytes = 0

    # -- write side -------------------------------------------------------
    def open_for_append(self) -> "Wal":
        """Open (creating if absent), healing any torn tail first."""
        good = self.scan_valid_prefix()
        self._f = open(self.path, "r+b" if os.path.exists(self.path)
                       else "w+b")
        self._f.seek(0, os.SEEK_END)
        end = self._f.tell()
        if good < end:                  # tear off the unreadable tail
            self.torn_bytes = end - good
            self._f.truncate(good)
            self._f.seek(good)
        return self

    def append(self, op: str, key, value=None) -> None:
        if op not in _OPS:
            raise ValueError(f"unknown WAL op {op!r}")
        if self._f is None:
            self.open_for_append()
        with _obs_trace.span("wal/append"):
            payload = pickle.dumps((op, key, value),
                                   protocol=pickle.HIGHEST_PROTOCOL)
            self._f.write(_HEADER.pack(len(payload), zlib.crc32(payload)))
            self._f.write(payload)
            self._f.flush()
            if self.sync == "always":
                os.fsync(self._f.fileno())
        if _obs_metrics.enabled():
            _obs_metrics.registry().counter("wal/appends").add(1)

    def reset(self) -> None:
        """Drop every record (post-checkpoint): the snapshot now owns them."""
        if self._f is None:
            self._f = open(self.path, "w+b")
        self._f.seek(0)
        self._f.truncate(0)
        self._f.flush()
        if self.sync == "always":
            os.fsync(self._f.fileno())

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    # -- read side --------------------------------------------------------
    def scan_valid_prefix(self) -> int:
        """Byte offset of the last intact frame boundary (0 for no file)."""
        if not os.path.exists(self.path):
            return 0
        good = 0
        with open(self.path, "rb") as f:
            while True:
                header = f.read(_HEADER.size)
                if len(header) < _HEADER.size:
                    break
                length, crc = _HEADER.unpack(header)
                if length > _MAX_RECORD:
                    break               # garbage header — treat as torn
                payload = f.read(length)
                if len(payload) < length or zlib.crc32(payload) != crc:
                    break
                good = f.tell()
        return good

    def replay(self) -> Iterator[WalRecord]:
        """Yield every intact record; stop silently at the first bad frame
        (torn tail).  Never raises on a truncated or corrupted tail."""
        if not os.path.exists(self.path):
            return
        with open(self.path, "rb") as f:
            while True:
                header = f.read(_HEADER.size)
                if len(header) < _HEADER.size:
                    return
                length, crc = _HEADER.unpack(header)
                if length > _MAX_RECORD:
                    return
                payload = f.read(length)
                if len(payload) < length or zlib.crc32(payload) != crc:
                    return
                try:
                    op, key, value = pickle.loads(payload)
                except Exception:       # CRC passed but payload garbage:
                    return              # treat like a torn frame
                if op not in _OPS:
                    return
                yield op, key, value

    def records(self) -> List[WalRecord]:
        return list(self.replay())

    def __len__(self) -> int:
        return sum(1 for _ in self.replay())
