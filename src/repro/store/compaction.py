"""Leveled compaction: run merging and filter-state merging.

Two invariants carry the whole subsystem (DESIGN.md §10):

* **Entry merge** — runs are merged newest-first; for every key only the
  newest occurrence survives (last-write-wins), and tombstones survive as
  markers unless the merge target is the store's bottom level (nothing
  older left to mask — the marker is garbage-collected).

* **Filter merge** — bloomRF state is a union-closed bitmap: the filter of
  ``A ∪ B`` built under one layout is exactly ``state_A | state_B`` (insert
  only ever ORs bits, and every probe reads through the same position
  functions).  So same-layout merges are a single ``jnp.bitwise_or`` — no
  hashing, no key replay.  Cross-layout merges (the merged run graduates to
  a larger capacity class) either *promote* each source state in place
  (segment tiling, ``core/dynamic.py`` — zero key replay; opt-in via
  ``allow_promote``) or re-insert the surviving keys through the kernels
  insert path.  Either way the merged filter covers a *superset* of the
  surviving keys (shadowed duplicates and dropped tombstones stay set), so
  the no-false-negative guarantee is preserved by construction; the
  property suite checks this against a bulk rebuild over the union.

  OR and promote merges never clear bits, so the bits of deleted (dead)
  keys accumulate and FPR drifts upward under churn.  The ``purge``
  policy caps that drift Proteus-style, at the natural rebuild point:
  when the merge's dead-entry fraction exceeds ``purge_dead_frac`` the
  filter is rebuilt from the surviving keys regardless of layout
  compatibility, washing every dead contribution out.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core import FilterLayout, promote_state, promotion_factors
from ..obs import trace as _obs_trace
from .run import Run

__all__ = ["merge_sorted_runs", "merge_filter_state"]


def merge_sorted_runs(runs: List[Run], drop_tombstones: bool = False
                      ) -> Tuple[np.ndarray, list, np.ndarray]:
    """Merge runs given newest-first into (keys, vals, tombs), keys sorted.

    For duplicate keys the newest occurrence wins.  With
    ``drop_tombstones`` the surviving tombstone entries are removed
    entirely (bottom-level merges only)."""
    if not runs:
        raise ValueError("nothing to merge")
    with _obs_trace.span("compaction/merge_runs", runs=len(runs)):
        return _merge_sorted_runs(runs, drop_tombstones)


def _merge_sorted_runs(runs, drop_tombstones):
    all_keys = np.concatenate([r.keys for r in runs])
    prec = np.concatenate([np.full(len(r.keys), i, np.int64)
                           for i, r in enumerate(runs)])
    # stable pick of the newest occurrence per key: sort by (key, precedence)
    order = np.lexsort((prec, all_keys))
    ks = all_keys[order]
    first = np.concatenate([[True], ks[1:] != ks[:-1]])
    sel = order[first]
    keys = all_keys[sel]
    all_tombs = np.concatenate([r.tombs for r in runs])
    tombs = all_tombs[sel]
    flat_vals: list = []
    for r in runs:
        flat_vals.extend(r.vals)
    if drop_tombstones:
        keep = ~tombs
        keys, tombs, sel = keys[keep], tombs[keep], sel[keep]
    vals = [flat_vals[i] for i in sel]
    return keys, vals, tombs


def _state_density(run: Run) -> float:
    """Fraction of set bits in the run's filter block."""
    a = np.asarray(run.state)[..., : (run.layout.total_bits + 31) // 32]
    return float(np.unpackbits(a.view(np.uint8)).mean())


def _promotion_is_cheap(runs: List[Run], target_layout: FilterLayout,
                        n_keys: int, slack: float) -> bool:
    """Would promoting cost at most ``slack``x the per-layer density of a
    rebuild?

    A promoted segment answers queries at the *source* class's resolution
    (positions fold back mod the old segment size), so OR-ing promoted
    states unions their densities: ``1 - prod(1 - d_i)``.  A rebuild
    spreads the same keys over the full target space instead.  Estimate
    the rebuild's density from the sources' own set-bits-per-key and gate
    the promote on the ratio — promoting full filters (union density far
    above rebuild density) multiplies FPR per layer and is exactly what
    this guard rejects.
    """
    union_miss, set_bits_per_key = 1.0, []
    for r in runs:
        d = _state_density(r)
        if d >= 1.0:
            return False
        union_miss *= 1.0 - d
        set_bits_per_key.append(
            -r.layout.total_bits * np.log1p(-d) / max(len(r), 1))
    union_density = 1.0 - union_miss
    rebuild_density = 1.0 - np.exp(
        -n_keys * np.mean(set_bits_per_key) / target_layout.total_bits)
    return union_density <= slack * max(rebuild_density, 1e-9)


def merge_filter_state(runs: List[Run], target_layout: FilterLayout,
                       keys: np.ndarray,
                       build: Callable[[FilterLayout, np.ndarray], jnp.ndarray],
                       *,
                       dead_frac: float = 0.0,
                       purge_dead_frac: Optional[float] = None,
                       allow_promote: bool = False,
                       promote_density_slack: Optional[float] = None
                       ) -> Tuple[jnp.ndarray, str]:
    """Merged filter block for ``runs`` under ``target_layout``.

    Returns ``(state, how)`` with ``how`` one of:

    * ``"or"`` — every source already uses ``target_layout``: the union
      filter is the bitwise OR of the source states;
    * ``"promote"`` — every source is promotion-compatible with the target
      (``core.promotion_factors``): each state is segment-tiled in place
      and the results ORed — no key replay (``allow_promote`` only; with
      ``promote_density_slack`` set, also subject to the density guard —
      see :func:`_promotion_is_cheap`);
    * ``"rebuild"`` — surviving ``keys`` re-inserted through ``build`` (the
      kernels insert path);
    * ``"purge"`` — ``dead_frac`` exceeded ``purge_dead_frac``, forcing the
      rebuild path to wash dead keys' bits out of the filter even when an
      OR or promote merge was available.
    """
    with _obs_trace.span("compaction/merge_filters", runs=len(runs)):
        return _merge_filter_state(runs, target_layout, keys, build,
                                   dead_frac, purge_dead_frac, allow_promote,
                                   promote_density_slack)


def _merge_filter_state(runs, target_layout, keys, build, dead_frac,
                        purge_dead_frac, allow_promote,
                        promote_density_slack):
    purge = purge_dead_frac is not None and dead_frac > purge_dead_frac
    if purge:
        return build(target_layout, keys), "purge"
    if all(r.layout == target_layout and r.state is not None for r in runs):
        state = runs[0].state
        for r in runs[1:]:
            state = jnp.bitwise_or(state, r.state)
        return state, "or"
    if (allow_promote
            and all(r.state is not None for r in runs)
            and all(promotion_factors(r.layout, target_layout) is not None
                    for r in runs)
            and (promote_density_slack is None
                 or _promotion_is_cheap(runs, target_layout, len(keys),
                                        promote_density_slack))):
        state = promote_state(runs[0].state, runs[0].layout, target_layout)
        for r in runs[1:]:
            state = jnp.bitwise_or(
                state, promote_state(r.state, r.layout, target_layout))
        return state, "promote"
    return build(target_layout, keys), "rebuild"
