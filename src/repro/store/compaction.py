"""Leveled compaction: run merging and filter-state merging.

Two invariants carry the whole subsystem (DESIGN.md §10):

* **Entry merge** — runs are merged newest-first; for every key only the
  newest occurrence survives (last-write-wins), and tombstones survive as
  markers unless the merge target is the store's bottom level (nothing
  older left to mask — the marker is garbage-collected).

* **Filter merge** — bloomRF state is a union-closed bitmap: the filter of
  ``A ∪ B`` built under one layout is exactly ``state_A | state_B`` (insert
  only ever ORs bits, and every probe reads through the same position
  functions).  So same-layout merges are a single ``jnp.bitwise_or`` — no
  hashing, no key replay.  Cross-layout merges (the merged run graduates to
  a larger capacity class) re-insert the surviving keys through the kernels
  insert path.  Either way the merged filter covers a *superset* of the
  surviving keys (shadowed duplicates and dropped tombstones stay set), so
  the no-false-negative guarantee is preserved by construction; the
  property suite checks this against a bulk rebuild over the union.
"""
from __future__ import annotations

from typing import Callable, List, Tuple

import jax.numpy as jnp
import numpy as np

from ..core import FilterLayout
from .run import Run

__all__ = ["merge_sorted_runs", "merge_filter_state"]


def merge_sorted_runs(runs: List[Run], drop_tombstones: bool = False
                      ) -> Tuple[np.ndarray, list, np.ndarray]:
    """Merge runs given newest-first into (keys, vals, tombs), keys sorted.

    For duplicate keys the newest occurrence wins.  With
    ``drop_tombstones`` the surviving tombstone entries are removed
    entirely (bottom-level merges only)."""
    if not runs:
        raise ValueError("nothing to merge")
    all_keys = np.concatenate([r.keys for r in runs])
    prec = np.concatenate([np.full(len(r.keys), i, np.int64)
                           for i, r in enumerate(runs)])
    # stable pick of the newest occurrence per key: sort by (key, precedence)
    order = np.lexsort((prec, all_keys))
    ks = all_keys[order]
    first = np.concatenate([[True], ks[1:] != ks[:-1]])
    sel = order[first]
    keys = all_keys[sel]
    all_tombs = np.concatenate([r.tombs for r in runs])
    tombs = all_tombs[sel]
    flat_vals: list = []
    for r in runs:
        flat_vals.extend(r.vals)
    if drop_tombstones:
        keep = ~tombs
        keys, tombs, sel = keys[keep], tombs[keep], sel[keep]
    vals = [flat_vals[i] for i in sel]
    return keys, vals, tombs


def merge_filter_state(runs: List[Run], target_layout: FilterLayout,
                       keys: np.ndarray,
                       build: Callable[[FilterLayout, np.ndarray], jnp.ndarray]
                       ) -> Tuple[jnp.ndarray, bool]:
    """Merged filter block for ``runs`` under ``target_layout``.

    Returns ``(state, merged_via_or)``.  When every source run already uses
    ``target_layout`` (same capacity class, same seeds) the union filter is
    the bitwise OR of the source states; otherwise the surviving ``keys``
    are re-inserted through ``build`` (the kernels insert path)."""
    if all(r.layout == target_layout and r.state is not None for r in runs):
        state = runs[0].state
        for r in runs[1:]:
            state = jnp.bitwise_or(state, r.state)
        return state, True
    return build(target_layout, keys), False
