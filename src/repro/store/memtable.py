"""Mutable write buffer of the LSM store.

A plain insertion dict: ``put`` overwrites, ``delete`` writes the
:data:`TOMBSTONE` sentinel (deletes must flush as explicit markers so they
mask older runs — the filters are insert-only, so a key's *absence* can
never be encoded, only an entry saying "deleted here").  ``sorted_entries``
is the flush view: keys ascending, one entry per key (last write wins).
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

__all__ = ["Memtable", "TOMBSTONE"]


class _Tombstone:
    """Sentinel marking a deleted key (distinct from any stored value)."""

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return "<tombstone>"


TOMBSTONE = _Tombstone()


class Memtable:
    def __init__(self) -> None:
        self._map: dict = {}

    def __len__(self) -> int:
        return len(self._map)

    def put(self, key: int, value) -> None:
        self._map[int(key)] = value

    def delete(self, key: int) -> None:
        self._map[int(key)] = TOMBSTONE

    def get(self, key: int) -> Tuple[bool, object]:
        """(present-in-memtable, value-or-TOMBSTONE)."""
        k = int(key)
        if k in self._map:
            return True, self._map[k]
        return False, None

    def items(self) -> Iterator[Tuple[int, object]]:
        return iter(self._map.items())

    def sorted_entries(self) -> Tuple[np.ndarray, list, np.ndarray]:
        """Flush view: (sorted uint64 keys, values, tombstone mask)."""
        ks = sorted(self._map)
        keys = np.asarray(ks, np.uint64)
        vals = [self._map[k] for k in ks]
        tombs = np.asarray([v is TOMBSTONE for v in vals], bool)
        return keys, vals, tombs

    def clear(self) -> None:
        self._map.clear()
