"""Immutable sorted runs (the store's SSTables).

A run is the unit the filters prune: sorted unique keys, their values,
a tombstone mask (deletes flushed as markers), min/max key fences, and a
bloomRF filter block over *all* entry keys — tombstones included, so a
newer run's delete marker is discoverable through its filter and masks
older runs on the read path.

Runs snapshot via ``dist/compression.py``: both the key list and the
filter's set-bit positions are sorted integer lists, so the on-disk form
is two Elias-Fano posting lists (``n * (2 + log2(u/n))`` bits each)
instead of raw ``u32`` dumps — :meth:`Run.pack` / :meth:`Run.unpack`
round-trip bit-exactly.

v3 snapshots carry per-component CRC32s (``store/integrity.py``) over
the keys, fences, values, and decoded filter state.  :meth:`Run.unpack`
verifies them: a key/fence/value mismatch is unrecoverable data
corruption and raises an actionable ``ValueError``, while a filter-block
mismatch (or an undecodable filter payload) *quarantines* the run —
``quarantined=True`` makes the store's probe plane treat the row as
always-maybe (fence-only pruning), because a corrupted filter may
answer "no" for a stored key and a false negative is the one failure a
filter must never produce.  Scans through a quarantined run stay exact,
just less pruned (DESIGN.md §14).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import FilterLayout
from ..dist.compression import (elias_fano_decode, elias_fano_encode,
                                pack_filter_state, unpack_filter_state)
from .integrity import run_checksums, state_crc32, verify_component
from .memtable import TOMBSTONE

__all__ = ["Run"]

_SNAPSHOT_SCHEMA = "bloomrf-run/v3"
_ACCEPTED_SCHEMAS = ("bloomrf-run/v1", "bloomrf-run/v2", "bloomrf-run/v3")


class Run:
    """One immutable sorted run with its filter block and fences."""

    __slots__ = ("keys", "vals", "tombs", "level", "layout", "state", "alt",
                 "promotions", "quarantined", "_crcs")

    def __init__(self, keys: np.ndarray, vals: list, tombs: np.ndarray,
                 level: int, layout: FilterLayout,
                 state: Optional[jax.Array], alt=None,
                 promotions: int = 0, quarantined: bool = False):
        keys = np.asarray(keys, np.uint64)
        if keys.ndim != 1 or len(keys) == 0:
            raise ValueError("a run needs a non-empty 1-D key vector")
        if (keys[1:] <= keys[:-1]).any():
            raise ValueError("run keys must be strictly increasing")
        if len(vals) != len(keys) or len(tombs) != len(keys):
            raise ValueError("keys/vals/tombs length mismatch")
        self.keys = keys
        self.vals = vals
        self.tombs = np.asarray(tombs, bool)
        self.level = level
        self.layout = layout
        self.state = state            # uint32[layout.total_u32] filter block
        self.alt = alt                # optional baseline PointRangeFilter
        # promote hops this filter block has survived without a rebuild.
        # A promoted segment answers queries at the *source* class's
        # resolution (positions fold back mod the old size), so each hop
        # ORs states without adding resolution and multiplies FPR by the
        # source count — the store caps hops (promote_max_hops) to keep
        # that bounded.
        self.promotions = int(promotions)
        # a quarantined run's filter block failed its checksum: the probe
        # plane must treat the row as always-maybe (fence-only pruning)
        self.quarantined = bool(quarantined)
        self._crcs: Optional[dict] = None

    # -- fences ----------------------------------------------------------
    @property
    def kmin(self) -> int:
        return int(self.keys[0])

    @property
    def kmax(self) -> int:
        return int(self.keys[-1])

    def __len__(self) -> int:
        return len(self.keys)

    @property
    def n_live(self) -> int:
        return int((~self.tombs).sum())

    def data_bytes(self, value_bytes: int = 64) -> int:
        """Accounting size of the run's data blocks (not the filter)."""
        return len(self.keys) * (8 + value_bytes)

    # -- integrity -------------------------------------------------------
    def checksums(self) -> dict:
        """Per-component CRC32s, computed once and cached.

        The store computes these eagerly at run construction
        (flush/compaction), so the cached values are the *build-time*
        reference :meth:`verify` and ``Store.scrub`` compare against."""
        if self._crcs is None:
            self._crcs = run_checksums(self.keys, self.vals, self.tombs,
                                       self.kmin, self.kmax,
                                       state=self.state)
        return self._crcs

    def verify(self) -> dict:
        """Recompute every component CRC against the cached reference.

        Returns ``{component: bool}``; a missing reference (never
        checksummed) verifies vacuously true."""
        ref = self._crcs
        fresh = run_checksums(self.keys, self.vals, self.tombs,
                              self.kmin, self.kmax, state=self.state)
        if ref is None:
            return {k: True for k in fresh}
        return {k: verify_component(ref, k, v) for k, v in fresh.items()}

    # -- data-block reads (the part the filters try to avoid) ------------
    def lookup(self, key: int) -> Tuple[bool, object, bool]:
        """(found, value, is_tombstone) via binary search."""
        i = int(np.searchsorted(self.keys, np.uint64(key)))
        if i < len(self.keys) and self.keys[i] == np.uint64(key):
            return True, self.vals[i], bool(self.tombs[i])
        return False, None, False

    def slice(self, lo: int, hi: int) -> Tuple[np.ndarray, list, np.ndarray]:
        """Entries with lo <= key <= hi (inclusive bounds, like Store.scan)."""
        a, b = np.searchsorted(self.keys, [np.uint64(lo), np.uint64(hi)])
        if b < len(self.keys) and self.keys[b] == np.uint64(hi):
            b += 1
        return self.keys[a:b], self.vals[a:b], self.tombs[a:b]

    # -- snapshots (Elias-Fano, dist/compression.py) ---------------------
    def pack(self) -> dict:
        """Compressed snapshot: EF posting lists for keys + filter bits.

        Tombstoned slots store a ``None`` placeholder, not the in-process
        ``TOMBSTONE`` sentinel — the sentinel only round-trips by object
        identity and would make the snapshot unserializable to real bytes.
        ``unpack`` restores the canonical sentinel from the tombstone mask.

        v3 adds the per-component ``crc`` dict (build-time reference when
        the run was checksummed at construction, else computed now).
        """
        enc = {
            "schema": _SNAPSHOT_SCHEMA,
            "level": self.level,
            "layout": dataclasses.asdict(self.layout),
            "keys": elias_fano_encode(self.keys, universe=1 << 64),
            "vals": [None if t else v
                     for v, t in zip(self.vals, self.tombs)],
            "tombs": np.packbits(self.tombs),
            "n": len(self.keys),
            "promotions": self.promotions,
            "crc": dict(self.checksums()),
        }
        if self.quarantined:
            enc["quarantined"] = True
        if self.state is not None:
            enc["filter"] = pack_filter_state(np.asarray(self.state))
        return enc

    @classmethod
    def unpack(cls, enc: dict, alt=None) -> "Run":
        """Validated inverse of :meth:`pack`.

        Every malformed or corrupted input raises ``ValueError`` naming
        what failed (never a segfault, never a silent mis-restore); the
        one exception is a corrupt *filter block*, which degrades to a
        quarantined run instead — see the module docstring."""
        if not isinstance(enc, dict):
            raise ValueError(f"run snapshot must be a dict, "
                             f"got {type(enc).__name__}")
        if enc.get("schema") not in _ACCEPTED_SCHEMAS:
            raise ValueError(f"not a run snapshot: {enc.get('schema')!r}")
        # checksums are a v3 field: v1/v2 snapshots predate them and are
        # accepted unverified whatever stray keys they carry
        crcs = enc.get("crc") if enc.get("schema") == _SNAPSHOT_SCHEMA \
            else None
        if crcs is not None and not isinstance(crcs, dict):
            raise ValueError("run snapshot: 'crc' must be a dict")
        try:
            layout = FilterLayout(**enc["layout"])
        except Exception as e:
            raise ValueError(f"run snapshot: bad filter layout: {e}") from e
        try:
            n = int(enc["n"])
            level = int(enc["level"])
            promotions = int(enc.get("promotions", 0))
        except Exception as e:
            raise ValueError(f"run snapshot: bad scalar field: {e}") from e
        if n < 1:
            raise ValueError(f"run snapshot: n must be >= 1, got {n}")
        try:
            keys = elias_fano_decode(enc["keys"])
        except Exception as e:
            raise ValueError(f"run snapshot: undecodable key list: {e}") from e
        if len(keys) != n or keys.dtype != np.uint64:
            raise ValueError(f"run snapshot: decoded {len(keys)} keys, "
                             f"expected n={n}")
        if len(keys) > 1 and (keys[1:] <= keys[:-1]).any():
            raise ValueError("run snapshot: keys not strictly increasing "
                             "(corrupted key posting list)")
        kmin, kmax = int(keys[0]), int(keys[-1])
        try:
            tombs = np.unpackbits(np.asarray(enc["tombs"], np.uint8))[:n]
            tombs = tombs.astype(bool)
        except Exception as e:
            raise ValueError(f"run snapshot: bad tombstone mask: {e}") from e
        if len(tombs) != n:
            raise ValueError(f"run snapshot: tombstone mask covers "
                             f"{len(tombs)} entries, expected {n}")
        enc_vals = enc["vals"]
        if not isinstance(enc_vals, list) or len(enc_vals) != n:
            raise ValueError(f"run snapshot: expected {n} values, got "
                             f"{len(enc_vals) if isinstance(enc_vals, list) else type(enc_vals).__name__}")
        # content verification (v3): keys / fences / values / tombstones
        # are data — a mismatch is unrecoverable corruption and must not
        # restore.  The vals CRC is computed against the *decoded* mask
        # (live->tomb flips change the serialised form); tomb->live flips
        # are invisible to it and caught by the tombs component instead.
        fresh = run_checksums(keys, enc_vals, tombs, kmin, kmax)
        for comp in ("keys", "fences", "vals", "tombs"):
            if not verify_component(crcs, comp, fresh[comp]):
                raise ValueError(
                    f"run snapshot: {comp} CRC mismatch — the snapshot is "
                    f"corrupted; restore from a previous checkpoint")
        # filter block: corruption degrades (quarantine), never raises
        state = None
        quarantined = bool(enc.get("quarantined", False))
        if "filter" in enc:
            try:
                state_np = unpack_filter_state(enc["filter"],
                                               layout.total_u32)
                if not verify_component(crcs, "filter",
                                        state_crc32(state_np)):
                    quarantined = True
                state = jnp.asarray(state_np)
            except Exception:
                # undecodable filter payload: keep the run alive without a
                # usable filter block (the store substitutes zeros and the
                # quarantine mask keeps the row always-touch)
                state = None
                quarantined = True
        # the tombstone mask is authoritative (the memtable guarantees
        # vals[i] is the sentinel exactly where tombs[i]); restoring from it
        # also heals v1 snapshots whose vals hold stale sentinel objects
        vals = [TOMBSTONE if t else v for v, t in zip(enc_vals, tombs)]
        try:
            run = cls(keys, vals, tombs, level, layout,
                      state, alt=alt, promotions=promotions,
                      quarantined=quarantined)
        except Exception as e:
            raise ValueError(f"run snapshot: inconsistent run: {e}") from e
        if crcs is not None and not quarantined:
            run._crcs = dict(crcs)    # carry the build-time reference
        return run
