"""Immutable sorted runs (the store's SSTables).

A run is the unit the filters prune: sorted unique keys, their values,
a tombstone mask (deletes flushed as markers), min/max key fences, and a
bloomRF filter block over *all* entry keys — tombstones included, so a
newer run's delete marker is discoverable through its filter and masks
older runs on the read path.

Runs snapshot via ``dist/compression.py``: both the key list and the
filter's set-bit positions are sorted integer lists, so the on-disk form
is two Elias-Fano posting lists (``n * (2 + log2(u/n))`` bits each)
instead of raw ``u32`` dumps — :meth:`Run.pack` / :meth:`Run.unpack`
round-trip bit-exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import FilterLayout
from ..dist.compression import (elias_fano_decode, elias_fano_encode,
                                pack_filter_state, unpack_filter_state)
from .memtable import TOMBSTONE

__all__ = ["Run"]

_SNAPSHOT_SCHEMA = "bloomrf-run/v2"
_ACCEPTED_SCHEMAS = ("bloomrf-run/v1", "bloomrf-run/v2")


class Run:
    """One immutable sorted run with its filter block and fences."""

    __slots__ = ("keys", "vals", "tombs", "level", "layout", "state", "alt",
                 "promotions")

    def __init__(self, keys: np.ndarray, vals: list, tombs: np.ndarray,
                 level: int, layout: FilterLayout,
                 state: Optional[jax.Array], alt=None,
                 promotions: int = 0):
        keys = np.asarray(keys, np.uint64)
        if keys.ndim != 1 or len(keys) == 0:
            raise ValueError("a run needs a non-empty 1-D key vector")
        if (keys[1:] <= keys[:-1]).any():
            raise ValueError("run keys must be strictly increasing")
        if len(vals) != len(keys) or len(tombs) != len(keys):
            raise ValueError("keys/vals/tombs length mismatch")
        self.keys = keys
        self.vals = vals
        self.tombs = np.asarray(tombs, bool)
        self.level = level
        self.layout = layout
        self.state = state            # uint32[layout.total_u32] filter block
        self.alt = alt                # optional baseline PointRangeFilter
        # promote hops this filter block has survived without a rebuild.
        # A promoted segment answers queries at the *source* class's
        # resolution (positions fold back mod the old size), so each hop
        # ORs states without adding resolution and multiplies FPR by the
        # source count — the store caps hops (promote_max_hops) to keep
        # that bounded.
        self.promotions = int(promotions)

    # -- fences ----------------------------------------------------------
    @property
    def kmin(self) -> int:
        return int(self.keys[0])

    @property
    def kmax(self) -> int:
        return int(self.keys[-1])

    def __len__(self) -> int:
        return len(self.keys)

    @property
    def n_live(self) -> int:
        return int((~self.tombs).sum())

    def data_bytes(self, value_bytes: int = 64) -> int:
        """Accounting size of the run's data blocks (not the filter)."""
        return len(self.keys) * (8 + value_bytes)

    # -- data-block reads (the part the filters try to avoid) ------------
    def lookup(self, key: int) -> Tuple[bool, object, bool]:
        """(found, value, is_tombstone) via binary search."""
        i = int(np.searchsorted(self.keys, np.uint64(key)))
        if i < len(self.keys) and self.keys[i] == np.uint64(key):
            return True, self.vals[i], bool(self.tombs[i])
        return False, None, False

    def slice(self, lo: int, hi: int) -> Tuple[np.ndarray, list, np.ndarray]:
        """Entries with lo <= key <= hi (inclusive bounds, like Store.scan)."""
        a, b = np.searchsorted(self.keys, [np.uint64(lo), np.uint64(hi)])
        if b < len(self.keys) and self.keys[b] == np.uint64(hi):
            b += 1
        return self.keys[a:b], self.vals[a:b], self.tombs[a:b]

    # -- snapshots (Elias-Fano, dist/compression.py) ---------------------
    def pack(self) -> dict:
        """Compressed snapshot: EF posting lists for keys + filter bits.

        Tombstoned slots store a ``None`` placeholder, not the in-process
        ``TOMBSTONE`` sentinel — the sentinel only round-trips by object
        identity and would make the snapshot unserializable to real bytes.
        ``unpack`` restores the canonical sentinel from the tombstone mask.
        """
        enc = {
            "schema": _SNAPSHOT_SCHEMA,
            "level": self.level,
            "layout": dataclasses.asdict(self.layout),
            "keys": elias_fano_encode(self.keys, universe=1 << 64),
            "vals": [None if t else v
                     for v, t in zip(self.vals, self.tombs)],
            "tombs": np.packbits(self.tombs),
            "n": len(self.keys),
            "promotions": self.promotions,
        }
        if self.state is not None:
            enc["filter"] = pack_filter_state(np.asarray(self.state))
        return enc

    @classmethod
    def unpack(cls, enc: dict, alt=None) -> "Run":
        if enc.get("schema") not in _ACCEPTED_SCHEMAS:
            raise ValueError(f"not a run snapshot: {enc.get('schema')!r}")
        layout = FilterLayout(**enc["layout"])
        n = enc["n"]
        keys = elias_fano_decode(enc["keys"])
        tombs = np.unpackbits(enc["tombs"])[:n].astype(bool)
        state = None
        if "filter" in enc:
            state = jnp.asarray(
                unpack_filter_state(enc["filter"], layout.total_u32))
        # the tombstone mask is authoritative (the memtable guarantees
        # vals[i] is the sentinel exactly where tombs[i]); restoring from it
        # also heals v1 snapshots whose vals hold stale sentinel objects
        vals = [TOMBSTONE if t else v for v, t in zip(enc["vals"], tombs)]
        return cls(keys, vals, tombs, enc["level"], layout,
                   state, alt=alt, promotions=enc.get("promotions", 0))
