"""LSM run-store with per-run bloomRF filter blocks (DESIGN.md §10).

The paper's headline evaluation embeds bloomRF into RocksDB, where a
per-SSTable filter prunes point- and range-reads before any data-block I/O.
This package reproduces that workload standalone: a mutable
:class:`Memtable` flushes into immutable sorted :class:`Run`s, each carrying
a bloomRF filter block plus min/max fences; leveled compaction merges runs
and merges/rebuilds their filter state; and the read path batch-probes all
live runs' filters with ONE fused gather over the stacked state
(``core.engine.StackedProbe``) before touching any run's data.
"""
from .compaction import merge_filter_state, merge_sorted_runs
from .faults import FaultPlan, InjectedCrash, fault_seed_from_env
from .integrity import read_manifest, run_checksums, write_manifest
from .memtable import TOMBSTONE, Memtable
from .run import Run
from .store import Store, StoreConfig, StoreStats
from .wal import WAL_FILENAME, Wal

__all__ = [
    "Memtable",
    "TOMBSTONE",
    "Run",
    "Store",
    "StoreConfig",
    "StoreStats",
    "merge_sorted_runs",
    "merge_filter_state",
    "Wal",
    "WAL_FILENAME",
    "FaultPlan",
    "InjectedCrash",
    "fault_seed_from_env",
    "run_checksums",
    "read_manifest",
    "write_manifest",
]
