"""AdaptiveTuner: the observe → fit → solve → retune loop (§16).

One tuner serves one store (or tenant handle).  It owns:

* an ``obs.fpr.FprSampler`` fed from the host scan path (bounds
  reservoir + range-log2 histogram — cheap numpy, never on the device
  dispatch);
* a per-capacity-class **decision cache**: ``advise_layout`` is consulted
  by compaction exactly where a rebuild is already being paid for
  (class-graduating merges), re-solves at most every
  ``Hysteresis.cooldown`` consultations, and hands flushes the *cached*
  decision so new runs land directly in the tuned layout (keeping
  same-class merges on the free OR path);
* a retune **event log** (``events``) surfaced through
  ``TypedStore.retune_report()``.

Serialization rides the workload model (``bloomrf-workload/v1``): the
tuner snapshots its fitted sample and reloads it on restore, so a
reopened store resumes tuning from the observed workload instead of
cold-starting through the hysteresis gate again.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from ..core.layout import FilterLayout
from ..obs.fpr import FprSampler
from .cost import cross_check
from .solver import Hysteresis, RetuneDecision, solve
from .workload import WorkloadModel, fit_workload

__all__ = ["AdaptiveTuner"]


class AdaptiveTuner:
    """Closed-loop layout tuner for one store/handle."""

    def __init__(self, d: int, seed: int = 0x0B100F11,
                 hysteresis: Optional[Hysteresis] = None,
                 sampler: Optional[FprSampler] = None):
        if not 1 <= d <= 64:
            raise ValueError(f"d must be in 1..64, got {d}")
        self.d = d
        self.hysteresis = hysteresis or Hysteresis()
        self.sampler = sampler if sampler is not None \
            else FprSampler(d, seed=seed ^ 0x7E4E)
        self.points_seen = 0
        self.observed: dict = {}     # live FPR samples (cross-check input)
        self.events: list = []       # solver-accepted retunes, in order
        self.retunes = 0             # len(events), kept as a plain counter
        self._decisions: Dict[FilterLayout, RetuneDecision] = {}
        self._since_solve: Dict[FilterLayout, int] = {}

    # -- observation hooks (host path only; never syncs a device value) --

    def observe_scan(self, lo, hi) -> None:
        """Feed scan bounds into the workload sample (host numpy)."""
        self.sampler.observe_ranges(np.asarray(lo, np.uint64),
                                    np.asarray(hi, np.uint64))

    def observe_points(self, n: int) -> None:
        self.points_seen += int(n)

    def record_observed(self, sample: dict) -> None:
        """Fold a live ``observed_fpr()`` sample into the cross-check."""
        for k in ("point_fpr", "range_fpr"):
            if sample.get(k) is not None:
                self.observed[k] = float(sample[k])

    # -- model ------------------------------------------------------------

    def workload(self, stats=None, keys=None) -> WorkloadModel:
        return fit_workload(self.d, sampler=self.sampler, stats=stats,
                            keys=keys, observed=self.observed,
                            n_points=self.points_seen)

    def cross_check(self, layout: FilterLayout, n_keys: int) -> dict:
        return cross_check(layout, max(n_keys, 1), self.workload())

    # -- the retune point --------------------------------------------------

    def cached_layout(self, ladder_layout: FilterLayout
                      ) -> Optional[FilterLayout]:
        """The standing decision for a capacity class, without solving.

        The flush path uses this so fresh runs join the class's tuned
        layout (same-class compactions then merge with a free OR)."""
        dec = self._decisions.get(ladder_layout)
        return dec.layout if dec is not None and dec.changed else None

    def advise_layout(self, ladder_layout: FilterLayout,
                      n_keys: int) -> FilterLayout:
        """The layout a (re)build at this capacity class should use.

        Called by compaction when the rebuild is already being paid for.
        Re-solves at most every ``cooldown`` consultations per class;
        between solves the cached decision holds."""
        h = self.hysteresis
        if self.sampler.workload_seen < h.min_ranges:
            return ladder_layout
        n_since = self._since_solve.get(ladder_layout)
        if (n_since is not None and n_since < h.cooldown
                and ladder_layout in self._decisions):
            self._since_solve[ladder_layout] = n_since + 1
            return self._decisions[ladder_layout].layout
        prev = self._decisions.get(ladder_layout)
        dec = solve(self.workload(), max(n_keys, 1), ladder_layout, h)
        self._decisions[ladder_layout] = dec
        self._since_solve[ladder_layout] = 0
        if dec.changed and (prev is None or prev.layout != dec.layout):
            self.retunes += 1
            self.events.append({
                "class_deltas": list(ladder_layout.deltas),
                "tuned_deltas": list(dec.layout.deltas),
                "tuned_replicas": list(dec.layout.replicas),
                "n_keys": int(n_keys),
                "win": float(dec.win),
                "predicted_fpr_mix": float(dec.best.fpr_mix),
                "baseline_fpr_mix": float(dec.baseline.fpr_mix),
                "reason": dec.reason,
            })
        return dec.layout

    def report(self) -> dict:
        """Human-auditable state: decisions, events, fitted workload."""
        wl = self.workload()
        return {
            "retunes": self.retunes,
            "events": list(self.events),
            "workload": wl.to_dict(),
            "decisions": {
                str(lad.deltas): {
                    "tuned_deltas": list(dec.layout.deltas),
                    "changed": dec.changed,
                    "win": float(dec.win),
                    "reason": dec.reason,
                } for lad, dec in self._decisions.items()},
        }

    # -- serde (rides in Store.snapshot as "workload") --------------------

    def to_dict(self) -> dict:
        return self.workload().to_dict()

    def load(self, enc: dict) -> None:
        """Resume from a serialized workload model (snapshot restore);
        malformed input raises ``ValueError``."""
        model = WorkloadModel.from_dict(enc)
        if model.d != self.d:
            raise ValueError(f"workload model is for d={model.d}, "
                             f"tuner is d={self.d}")
        self.sampler.preload_workload(model.reservoir, model.n_ranges,
                                      model.range_log2)
        self.points_seen = model.n_points
        self.observed.update(model.observed)
