"""Layout re-solver: search the candidate space under the workload
objective, with hysteresis so retuning never thrashes.

The candidate space is the one ``core/tuning.py::advise`` sweeps —
Δ-vector shapes (uniform ladders δ=1..7, the paper's shrink-towards-the-
top vectors) and replica splits — restricted to **hashed single-segment
layouts at the current layout's bit budget**.  Two deliberate bounds:

* *equal bits per key*: every candidate gets the incumbent's ``m`` so a
  "win" is a better Δ geometry, never just more memory;
* *no exact-bitmap segments*: the store's probe planes (the stacked
  one-gather plan and the scan megakernel) only stack hashed layouts —
  an exact-level candidate would win the cost model and then be
  unprobeable (the same reason ``FilterSpec`` pins ``tuning='advised'``
  to the single placement).

Hysteresis (Memento's robustness argument, PAPERS.md): a retune must
beat the incumbent by ``min_win`` *predicted* relative objective, the
solver re-runs at most every ``cooldown`` consultations per capacity
class, and nothing is solved before ``min_ranges`` observed queries —
three knobs that together keep a borderline workload from flip-flopping
layouts at every compaction.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

from ..core.layout import FilterLayout, _round_up
from ..core.tuning import _delta_vector
from .cost import CostReport, score_layout
from .workload import WorkloadModel

__all__ = ["Hysteresis", "RetuneDecision", "candidate_layouts", "solve"]


@dataclasses.dataclass(frozen=True)
class Hysteresis:
    """Anti-thrash policy: when is a predicted win worth acting on?"""

    min_win: float = 0.10    # required relative objective improvement
    cooldown: int = 2        # consultations between re-solves (per class)
    min_ranges: int = 64     # observed ranges before solving at all

    def __post_init__(self):
        if not 0.0 <= self.min_win < 1.0:
            raise ValueError(f"min_win must be in [0, 1), "
                             f"got {self.min_win}")
        if self.cooldown < 0 or self.min_ranges < 0:
            raise ValueError("cooldown and min_ranges must be >= 0")


@dataclasses.dataclass(frozen=True)
class RetuneDecision:
    """One solver verdict for one capacity class."""

    layout: FilterLayout     # what to build (the incumbent when not won)
    changed: bool            # did a candidate clear the hysteresis bar?
    win: float               # best relative objective improvement found
    baseline: CostReport     # incumbent under the workload
    best: CostReport         # winning (or incumbent) report
    n_candidates: int
    reason: str


def _ladder_deltas(d: int, n_keys: int, delta: int) -> Tuple[int, ...]:
    """The uniform-δ ladder ``basic_layout`` would pick, clamped into d."""
    log2n = math.log2(max(n_keys, 2))
    k = max(1, math.ceil((d - log2n) / delta))
    k = min(k, max(1, math.ceil(d / delta)))
    deltas = [delta] * k
    while sum(deltas) > d:
        if deltas[-1] > 1:
            deltas[-1] -= 1
        else:
            deltas.pop()
    return tuple(deltas)


def _hashed(d: int, m_bits: int, deltas: Tuple[int, ...],
            replicas: Optional[Tuple[int, ...]] = None,
            seed: int = 0x0B100F11) -> Optional[FilterLayout]:
    """Hashed single-segment candidate at (>=) the given bit budget, or
    None when the geometry is infeasible."""
    if not deltas:
        return None
    k = len(deltas)
    min_bits = 2 * (1 << (max(deltas) - 1))  # >= 2 words per layer
    m = _round_up(max(int(m_bits), min_bits, 64), 64)
    try:
        return FilterLayout(d=d, deltas=tuple(deltas),
                            replicas=replicas or (1,) * k,
                            seg_of_layer=(0,) * k, seg_bits=(m,),
                            exact_seg=None, seed=seed)
    except ValueError:
        return None


def candidate_layouts(current: FilterLayout, n_keys: int,
                      seed: Optional[int] = None) -> List[FilterLayout]:
    """The search space around ``current`` at its own bit budget."""
    d = current.d
    m = current.seg_bits[0] if len(current.seg_bits) == 1 \
        else current.total_bits
    seed = current.seed if seed is None else seed
    shapes: dict = {}
    for delta in range(1, min(7, d) + 1):
        deltas = _ladder_deltas(d, n_keys, delta)
        shapes.setdefault((deltas, None), None)
        if delta <= 3 and len(deltas) > 1:
            # error-correction replica on the top hashed layer (§7)
            reps = (1,) * (len(deltas) - 1) + (2,)
            shapes.setdefault((deltas, reps), None)
    # paper-style shrink vectors: big words at the bottom, halving upward
    log2n = int(math.log2(max(n_keys, 2)))
    for target in {d, max(d - log2n, 1)}:
        shapes.setdefault((tuple(_delta_vector(target)), None), None)
    out = []
    for (deltas, reps) in shapes:
        lay = _hashed(d, m, deltas, reps, seed)
        if lay is not None and lay != current:
            out.append(lay)
    return out


def solve(workload: WorkloadModel, n_keys: int, current: FilterLayout,
          hysteresis: Hysteresis = Hysteresis(),
          seed: Optional[int] = None) -> RetuneDecision:
    """Re-solve the layout for ``workload``; hysteresis-gated.

    Returns the incumbent (``changed=False``) when too little workload
    has been observed or no candidate clears ``min_win`` — the caller
    can always act on ``decision.layout`` unconditionally."""
    baseline = score_layout(current, n_keys, workload)
    if workload.n_ranges < hysteresis.min_ranges:
        return RetuneDecision(
            layout=current, changed=False, win=0.0, baseline=baseline,
            best=baseline, n_candidates=0,
            reason=f"insufficient workload ({workload.n_ranges} ranges "
                   f"< {hysteresis.min_ranges})")
    cands = candidate_layouts(current, n_keys, seed)
    best_lay, best = current, baseline
    for lay in cands:
        rep = score_layout(lay, n_keys, workload)
        if rep.objective < best.objective:
            best_lay, best = lay, rep
    win = 1.0 - best.objective / max(baseline.objective, 1e-300)
    if best_lay is current or win < hysteresis.min_win:
        return RetuneDecision(
            layout=current, changed=False, win=max(win, 0.0),
            baseline=baseline, best=baseline, n_candidates=len(cands),
            reason=f"no candidate beat min_win={hysteresis.min_win} "
                   f"(best win {max(win, 0.0):.3f})")
    return RetuneDecision(
        layout=best_lay, changed=True, win=win, baseline=baseline,
        best=best, n_candidates=len(cands),
        reason=f"deltas {current.deltas} -> {best_lay.deltas}, predicted "
               f"mixed FPR {baseline.fpr_mix:.4f} -> {best.fpr_mix:.4f}")
