"""Workload-adaptive tuning: observe → cost-model → re-solve → retune.

The closed loop over the §6/§7 model (DESIGN.md §16):

* :mod:`~repro.tune.workload` — :class:`WorkloadModel` fitted from the
  obs plane's samples, serializable as ``bloomrf-workload/v1``;
* :mod:`~repro.tune.cost` — scores any ``FilterLayout`` against the
  fitted workload (FPR integrated over the range-length sample + the
  engine's probed-words accounting);
* :mod:`~repro.tune.solver` — re-solves the layout over the advisor's
  candidate space under the workload objective, hysteresis-gated;
* :mod:`~repro.tune.retune` — :class:`AdaptiveTuner`, the wiring the
  store's compaction path and the facade consult.

Opt in with ``FilterSpec(tuning="adaptive")`` (store/tenant placements)
or ``StoreConfig(tuning="adaptive")``.
"""
from .cost import CostReport, cross_check, score_layout
from .retune import AdaptiveTuner
from .solver import Hysteresis, RetuneDecision, candidate_layouts, solve
from .workload import SCHEMA, WorkloadModel, fit_workload

__all__ = [
    "AdaptiveTuner", "CostReport", "Hysteresis", "RetuneDecision",
    "SCHEMA", "WorkloadModel", "candidate_layouts", "cross_check",
    "fit_workload", "score_layout", "solve",
]
