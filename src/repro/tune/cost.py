"""Sample-driven cost model: score a layout against a fitted workload.

The static advisor (``core/tuning.py``) prices one worst-case R.  Here
the §7 per-level model is *integrated over the observed range-length
distribution* instead: a range of length ~``2^l`` is answered from dyadic
levels ``0..l``, and the paper prices its FPR as the max per-level FPR
over those levels (``core.model.range_fpr_max``), so

    fpr_range = sum_l  w[l] * max(fpr[0..l])

with ``w`` the workload's range-log2 weights.  Points are level 0; the
workload's point/range mix blends the two.  Probe *cost* (not just
accuracy) enters through the engine's own accounting —
``ProbeEngine.range_word_loads``, the number of 32-bit words a range
probe gathers — as a small multiplicative penalty, so two layouts with
equal predicted FPR tie-break toward the cheaper probe plane.

``cross_check`` compares the model's prediction for the *live* layout
against the live ``observed_fpr()`` sample and reports the calibration
ratio; the solver works on relative wins (calibration cancels), but the
report is how a human audits the model before trusting a retune.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.engine import _filter_for_layout
from ..core.layout import FilterLayout
from ..core.model import level_fprs
from .workload import N_RANGE_BUCKETS, WorkloadModel

__all__ = ["CostReport", "score_layout", "words_per_range_query",
           "cross_check", "WORD_COST"]

#: relative probe-cost weight: an extra gathered word costs this fraction
#: of the objective — a tie-breaker, never a trade against real FPR
WORD_COST = 1e-4


def words_per_range_query(layout: FilterLayout) -> float:
    """u32 words one range probe gathers, per the engine's own accounting
    (``ProbeEngine.range_word_loads``) — not a re-derivation."""
    return float(_filter_for_layout(layout).engine.range_word_loads)


@dataclasses.dataclass(frozen=True)
class CostReport:
    """Scored candidate: predicted FPRs under the workload + probe cost."""

    fpr_point: float        # level-0 FPR
    fpr_range: float        # FPR integrated over the range-length sample
    fpr_mix: float          # point/range blend per the observed query mix
    words_per_query: float  # gathered u32 words per range probe
    objective: float        # fpr_mix * (1 + WORD_COST * words)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def score_layout(layout: FilterLayout, n_keys: int,
                 workload: WorkloadModel, C: float = None,
                 word_cost: float = WORD_COST) -> CostReport:
    """Predict ``layout``'s cost on ``workload`` holding ``n_keys`` keys.

    ``C`` defaults to the workload's cluster-derived scatter factor."""
    if n_keys < 1:
        raise ValueError(f"n_keys must be >= 1, got {n_keys}")
    if C is None:
        C = workload.c_factor
    lm = level_fprs(layout, n_keys, C)
    # max per-level FPR over levels 0..l: the paper's fpr_m at R = 2^l
    cum_max = np.maximum.accumulate(lm.fpr)
    w = workload.range_weights()
    lv = np.minimum(np.arange(N_RANGE_BUCKETS), layout.d)
    fpr_range = float((w * cum_max[lv]).sum())
    fpr_point = float(lm.fpr[0])
    pf = workload.point_frac()
    fpr_mix = pf * fpr_point + (1.0 - pf) * fpr_range
    words = words_per_range_query(layout)
    return CostReport(fpr_point=fpr_point, fpr_range=fpr_range,
                      fpr_mix=fpr_mix, words_per_query=words,
                      objective=fpr_mix * (1.0 + word_cost * words))


def cross_check(layout: FilterLayout, n_keys: int,
                workload: WorkloadModel) -> dict:
    """Model-vs-live audit for the layout currently deployed.

    ``calibration`` is observed/predicted range FPR, clipped to [0.25, 4]
    (a reservoir of ~512 candidates is noisy); ~1 means the §7 model
    tracks the deployment, far from 1 means the filters degraded (churn,
    promotion hops) beyond what a fresh-build model can see."""
    rep = score_layout(layout, n_keys, workload)
    out = {"predicted_range_fpr": rep.fpr_range,
           "predicted_point_fpr": rep.fpr_point,
           "observed_range_fpr": workload.observed.get("range_fpr"),
           "observed_point_fpr": workload.observed.get("point_fpr"),
           "calibration": None}
    obs = out["observed_range_fpr"]
    if obs is not None and rep.fpr_range > 0:
        out["calibration"] = float(np.clip(obs / rep.fpr_range, 0.25, 4.0))
    return out
