"""Workload model: what the deployment actually asks the filter (§16).

The §6/§7 advisor designs for a *worst case* — one range budget R, a
uniform key space, a guessed point/range mix.  A :class:`WorkloadModel`
replaces those guesses with the live sample the obs plane already
collects (Proteus' central observation, PAPERS.md):

* the **range-length distribution** — the ``obs/fpr.FprSampler`` bounds
  reservoir plus its host ``range_log2`` histogram, bucketed by
  ``ceil(log2 len)`` so each bucket maps 1:1 onto the dyadic level the
  paper's per-level model prices;
* the **point/range query mix** — point probes stress only level 0, so a
  point-heavy workload wants different Δs than a scan-heavy one;
* **key-cluster density** over the ``2^d`` code domain — a mild PMHF
  scatter adjustment (the paper's C, Fig. 5) for heavily clustered key
  spaces;
* the **observed FPR** of the live layout — the cost model's predictions
  are cross-checked against what the deployment actually leaks
  (``cost.cross_check``).

The model serializes as ``bloomrf-workload/v1`` (reservoir included) so
it rides inside ``Store.snapshot()`` and the tuner resumes with its
sample after a reopen instead of restarting cold.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import numpy as np

SCHEMA = "bloomrf-workload/v1"

#: range-length buckets: index = ceil(log2 length), 0..64 (length 1 -> 0)
N_RANGE_BUCKETS = 65
#: key-density resolution: top log2(64)=6 bits of the code domain
N_DENSITY_BUCKETS = 64

__all__ = ["SCHEMA", "WorkloadModel", "fit_workload", "range_log2_bucket"]


def range_log2_bucket(lengths) -> np.ndarray:
    """Bucket index per range length: ``ceil(log2 len)`` clipped to 0..64."""
    lengths = np.maximum(np.asarray(lengths, np.float64), 1.0)
    return np.clip(np.ceil(np.log2(lengths)), 0,
                   N_RANGE_BUCKETS - 1).astype(np.int64)


@dataclasses.dataclass
class WorkloadModel:
    """Fitted workload sample; the cost model's only input besides n."""

    d: int                       # code-domain bits of the *observed* queries
    range_log2: np.ndarray       # (65,) counts per ceil(log2 len) bucket
    n_ranges: int                # total range queries observed
    n_points: int                # total point queries observed
    key_density: np.ndarray      # (64,) key-mass fraction per domain slice
    observed: dict               # live cross-check inputs (e.g. range_fpr)
    reservoir: Tuple[Tuple[int, int], ...]  # raw sampled (lo, hi) bounds

    # -- derived views ----------------------------------------------------

    def point_frac(self) -> float:
        """Fraction of queries that are point probes."""
        total = self.n_points + self.n_ranges
        return self.n_points / total if total else 0.0

    def range_weights(self, default_log2: int = 8) -> np.ndarray:
        """(65,) probability weights over range-length buckets.

        With no ranges observed yet the weight collapses onto
        ``default_log2`` (a spec-style R budget) so the cost model
        degrades to the static advisor's single-R objective."""
        w = np.asarray(self.range_log2, np.float64)
        total = float(w.sum())
        if total <= 0:
            w = np.zeros(N_RANGE_BUCKETS)
            w[min(max(default_log2, 0), N_RANGE_BUCKETS - 1)] = 1.0
            return w
        return w / total

    @property
    def c_factor(self) -> float:
        """PMHF scatter adjustment from key clustering (paper's C).

        Fig. 5 shows C ~= 1 for uniform/normal/zipfian keys, so this
        stays a *mild* correction: the normalized Herfindahl index of
        the key-density histogram (1 for uniform mass), fourth-rooted
        and capped at 1.5."""
        dens = np.asarray(self.key_density, np.float64)
        if dens.sum() <= 0:
            return 1.0
        dens = dens / dens.sum()
        hhi = float((dens ** 2).sum()) * N_DENSITY_BUCKETS
        return float(min(1.5, max(1.0, hhi ** 0.25)))

    def rescaled(self, shift_log2: int) -> "WorkloadModel":
        """The same workload with every range length scaled by
        ``2^shift_log2`` — e.g. ``shift_log2 = -log2(n_shards)`` prices a
        full-domain scan against a *shard-local* layout, where the scan's
        per-shard slice is ~``len / n_shards``."""
        if shift_log2 == 0:
            return self
        counts = np.zeros(N_RANGE_BUCKETS)
        idx = np.clip(np.arange(N_RANGE_BUCKETS) + shift_log2, 0,
                      N_RANGE_BUCKETS - 1)
        np.add.at(counts, idx, np.asarray(self.range_log2, np.float64))
        return dataclasses.replace(self, range_log2=counts)

    # -- serde (rides in Store.snapshot) ----------------------------------

    def to_dict(self) -> dict:
        return {
            "schema": SCHEMA,
            "d": int(self.d),
            "range_log2": [float(c) for c in self.range_log2],
            "n_ranges": int(self.n_ranges),
            "n_points": int(self.n_points),
            "key_density": [float(c) for c in self.key_density],
            "observed": {str(k): float(v)
                         for k, v in self.observed.items()},
            "reservoir": [[int(a), int(b)] for a, b in self.reservoir],
        }

    @classmethod
    def from_dict(cls, enc: dict) -> "WorkloadModel":
        """Validated inverse of :meth:`to_dict`; malformed input raises an
        actionable ``ValueError`` (snapshot-restore contract)."""
        if not isinstance(enc, dict):
            raise ValueError(
                f"workload model must be a dict, got {type(enc).__name__}")
        if enc.get("schema") != SCHEMA:
            raise ValueError(
                f"not a workload model: schema {enc.get('schema')!r} "
                f"(expected {SCHEMA!r})")
        d = enc.get("d")
        if not isinstance(d, int) or not 1 <= d <= 64:
            raise ValueError(f"workload model: d must be an int in 1..64, "
                             f"got {d!r}")

        def _vec(name, size):
            v = enc.get(name)
            if (not isinstance(v, (list, tuple)) or len(v) != size
                    or not all(isinstance(x, (int, float))
                               and not isinstance(x, bool)
                               and x >= 0 for x in v)):
                raise ValueError(f"workload model: {name!r} must be "
                                 f"{size} non-negative numbers")
            return np.asarray(v, np.float64)

        range_log2 = _vec("range_log2", N_RANGE_BUCKETS)
        key_density = _vec("key_density", N_DENSITY_BUCKETS)
        counts = {}
        for name in ("n_ranges", "n_points"):
            v = enc.get(name)
            if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                raise ValueError(f"workload model: {name!r} must be a "
                                 f"non-negative int, got {v!r}")
            counts[name] = v
        obs = enc.get("observed", {})
        if (not isinstance(obs, dict)
                or not all(isinstance(k, str)
                           and isinstance(v, (int, float))
                           and not isinstance(v, bool)
                           for k, v in obs.items())):
            raise ValueError("workload model: 'observed' must map names "
                             "to numbers")
        res = enc.get("reservoir", [])
        top = (1 << d) - 1 if d < 64 else 2 ** 64 - 1
        if (not isinstance(res, (list, tuple))
                or not all(isinstance(p, (list, tuple)) and len(p) == 2
                           and all(isinstance(x, int)
                                   and not isinstance(x, bool)
                                   and 0 <= x <= top for x in p)
                           and p[0] <= p[1] for p in res)):
            raise ValueError(
                "workload model: 'reservoir' must be [lo, hi] pairs with "
                f"0 <= lo <= hi < 2^{d}")
        return cls(d=d, range_log2=range_log2,
                   n_ranges=counts["n_ranges"], n_points=counts["n_points"],
                   key_density=key_density,
                   observed={str(k): float(v) for k, v in obs.items()},
                   reservoir=tuple((int(a), int(b)) for a, b in res))


def fit_workload(d: int, *, sampler=None, stats=None,
                 keys: Optional[Sequence] = None,
                 observed: Optional[dict] = None,
                 n_points: int = 0) -> WorkloadModel:
    """Fit a :class:`WorkloadModel` from the live observation hooks.

    ``sampler`` is an ``obs.fpr.FprSampler`` (range histogram + bounds
    reservoir); ``stats`` a ``store.StoreStats`` (point/range mix and FP
    read rates); ``keys`` a sample of live keys (cluster density); every
    input is optional — missing pieces fall back to neutral defaults so a
    cold tuner still produces a scoreable (if uninformative) model.
    """
    if not 1 <= d <= 64:
        raise ValueError(f"d must be in 1..64, got {d}")
    range_log2 = np.zeros(N_RANGE_BUCKETS)
    n_ranges = 0
    reservoir: Tuple[Tuple[int, int], ...] = ()
    if sampler is not None:
        range_log2 = np.asarray(sampler.range_log2_counts,
                                np.float64).copy()
        n_ranges = int(sampler.workload_seen)
        reservoir = tuple((int(a), int(b))
                          for a, b in sampler.workload_sample())
    obs = dict(observed or {})
    if stats is not None:
        n_points = int(getattr(stats, "gets", n_points))
        if getattr(stats, "scans", 0) and not n_ranges:
            n_ranges = int(stats.scans)
        if getattr(stats, "scan_runs_touched", 0):
            obs.setdefault("scan_fp_read_rate",
                           float(stats.scan_fp_read_rate))
    key_density = np.full(N_DENSITY_BUCKETS, 1.0 / N_DENSITY_BUCKETS)
    if keys is not None:
        ks = np.asarray(keys, np.uint64)
        if ks.size:
            shift = np.uint64(max(d - int(math.log2(N_DENSITY_BUCKETS)), 0))
            idx = np.minimum(ks >> shift, N_DENSITY_BUCKETS - 1)
            key_density = (np.bincount(idx.astype(np.int64),
                                       minlength=N_DENSITY_BUCKETS)
                           / ks.size)
    return WorkloadModel(d=d, range_log2=range_log2, n_ranges=n_ranges,
                         n_points=int(n_points), key_density=key_density,
                         observed=obs, reservoir=reservoir)
