"""Common point-range-filter API + shared host-side hashing."""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np

__all__ = ["PointRangeFilter", "mix64_np", "seeds_np"]

_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)


def mix64_np(x: np.ndarray, seed: int = 0) -> np.ndarray:
    """splitmix64 finalizer (vectorized numpy, wrapping uint64)."""
    with np.errstate(over="ignore"):
        x = np.asarray(x, np.uint64) ^ np.uint64(seed)
        x = (x ^ (x >> np.uint64(30))) * _M1
        x = (x ^ (x >> np.uint64(27))) * _M2
        return x ^ (x >> np.uint64(31))


def seeds_np(base: int, n: int) -> np.ndarray:
    """n derived seeds: splitmix64 over the golden-gamma sequence from
    ``base`` (vectorized; identical values to the historical scalar loop)."""
    with np.errstate(over="ignore"):
        steps = np.uint64(base) + (np.uint64(0x9E3779B97F4A7C15)
                                   * np.arange(1, n + 1, dtype=np.uint64))
    return mix64_np(steps)


@runtime_checkable
class PointRangeFilter(Protocol):
    """Build-once, query-many filter facade used by the benchmark harness."""

    def build(self, keys: np.ndarray) -> None: ...

    def point(self, qs: np.ndarray) -> np.ndarray: ...

    def range(self, lo: np.ndarray, hi: np.ndarray) -> np.ndarray: ...

    def size_bits(self) -> int: ...
