"""Baseline filters the paper evaluates against (§9), plus a common API.

All baselines are host-side numpy implementations (they model CPU data
structures); bloomRF itself is the JAX implementation in ``repro.core`` and is
adapted to the same API by :class:`BloomRFAdapter`.
"""
from .api import PointRangeFilter
from .bloom import BloomFilter
from .bloomrf_adapter import BloomRFAdapter
from .cuckoo import CuckooFilter
from .minmax import FencePointers
from .prefix_bloom import PrefixBloomFilter
from .rosetta import Rosetta
from .surf_lite import SuRFLite

__all__ = [
    "PointRangeFilter",
    "BloomFilter",
    "PrefixBloomFilter",
    "FencePointers",
    "Rosetta",
    "SuRFLite",
    "CuckooFilter",
    "BloomRFAdapter",
]
