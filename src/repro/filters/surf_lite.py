"""SuRF-lite: a truncated-trie point-range filter with SuRF's FPR behaviour.

SuRF (Zhang et al., SIGMOD 2018) stores the minimal distinguishing prefix of
every key in a fast succinct trie (LOUDS-DS) plus optional suffix bits
(SuRF-Real) or a key hash (SuRF-Hash).  We keep the *filtering semantics*
(truncated leaf intervals + suffixes) and replace the LOUDS encoding with
sorted interval arrays; reported size uses the SuRF paper's ~10 bits/key
structural cost plus suffix bits (see DESIGN.md §5.3).
"""
from __future__ import annotations

import numpy as np

from .api import mix64_np

__all__ = ["SuRFLite"]

_STRUCT_BPK = 10.0  # LOUDS-DS structural bits/key (SuRF paper, §6)


class SuRFLite:
    def __init__(self, suffix_bits: int = 4, mode: str = "real",
                 seed: int = 0x50F5):
        assert mode in ("real", "hash", "none")
        self.suffix_bits = suffix_bits if mode != "none" else 0
        self.mode = mode
        self.seed = seed

    @classmethod
    def for_budget(cls, bits_per_key: float, mode: str = "real") -> "SuRFLite":
        return cls(suffix_bits=max(0, int(round(bits_per_key - _STRUCT_BPK))),
                   mode=mode)

    def build(self, keys: np.ndarray) -> None:
        ks = np.unique(np.asarray(keys, np.uint64))
        self.n = len(ks)
        if self.n == 0:
            self.starts = np.zeros(0, np.uint64)
            self.ends = np.zeros(0, np.uint64)
            return
        # minimal distinguishing prefix length (bits from MSB)
        def lcp(a, b):
            x = a ^ b
            out = np.full(len(a), 64, np.int64)
            nz = x != 0
            # number of leading common bits = 64 - bit_length(xor)
            bl = np.zeros(len(a), np.int64)
            xv = x[nz]
            for shift in (32, 16, 8, 4, 2, 1):  # bit-length via binary steps
                big = xv >= (np.uint64(1) << np.uint64(shift))
                bl[np.nonzero(nz)[0][big]] += shift
                xv = np.where(big, xv >> np.uint64(shift), xv)
            out[nz] = 63 - bl[nz]
            return out

        left = np.full(self.n, 0, np.int64)
        right = np.full(self.n, 0, np.int64)
        if self.n > 1:
            lc = lcp(ks[1:], ks[:-1])
            left[1:] = lc
            right[:-1] = lc
        plen = np.minimum(np.maximum(left, right) + 1, 64)
        if self.mode == "real":
            plen = np.minimum(plen + self.suffix_bits, 64)
        rem = (64 - plen).astype(np.uint64)
        self.starts = np.where(plen == 64, ks, (ks >> rem) << rem)
        self.ends = np.where(
            plen == 64, ks,
            self.starts + ((np.uint64(1) << rem) - np.uint64(1)))
        self._plen_sum = int(plen.sum())
        if self.mode == "hash":
            mask = np.uint64((1 << self.suffix_bits) - 1)
            self.hashes = mix64_np(ks, self.seed) & mask

    # ------------------------------------------------------------------
    def _leaf_of(self, qs: np.ndarray) -> np.ndarray:
        i = np.searchsorted(self.starts, qs, side="right") - 1
        ok = i >= 0
        ok[ok] &= qs[ok] <= self.ends[np.maximum(i, 0)][ok]
        return np.where(ok, i, -1)

    def point(self, qs: np.ndarray) -> np.ndarray:
        qs = np.asarray(qs, np.uint64)
        leaf = self._leaf_of(qs)
        hit = leaf >= 0
        if self.mode == "hash" and self.suffix_bits > 0:
            mask = np.uint64((1 << self.suffix_bits) - 1)
            qh = mix64_np(qs, self.seed) & mask
            hit &= np.where(leaf >= 0,
                            self.hashes[np.maximum(leaf, 0)] == qh, False)
        return hit

    def range(self, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
        lo = np.asarray(lo, np.uint64)
        hi = np.asarray(hi, np.uint64)
        i = np.searchsorted(self.starts, lo, side="right") - 1
        ok = np.zeros(len(lo), bool)
        valid = i >= 0
        ok[valid] = self.ends[np.maximum(i, 0)][valid] >= lo[valid]
        j = np.minimum(i + 1, len(self.starts) - 1)
        more = (i + 1) < len(self.starts)
        ok |= more & (self.starts[j] <= hi)
        return ok

    def size_bits(self) -> int:
        return int(_STRUCT_BPK * self.n + self.suffix_bits * self.n)
