"""Cuckoo filter (Fan et al., CoNEXT 2014): partial-key cuckoo hashing,
4-slot buckets.  Point queries only (the paper compares it in Fig. 12.E)."""
from __future__ import annotations

import numpy as np

from .api import mix64_np

__all__ = ["CuckooFilter"]


class CuckooFilter:
    def __init__(self, fingerprint_bits: int = 12, occupancy: float = 0.95,
                 max_kicks: int = 500, seed: int = 0xC0C0):
        self.f = fingerprint_bits
        self.occupancy = occupancy
        self.max_kicks = max_kicks
        self.seed = seed

    def _fingerprint(self, keys: np.ndarray) -> np.ndarray:
        fp = mix64_np(keys, self.seed + 1) & np.uint64((1 << self.f) - 1)
        return np.where(fp == 0, np.uint64(1), fp)  # 0 marks empty slots

    def _i1(self, keys: np.ndarray) -> np.ndarray:
        return (mix64_np(keys, self.seed) & np.uint64(self.nb - 1)).astype(np.int64)

    def _alt(self, i: np.ndarray, fp: np.ndarray) -> np.ndarray:
        return (np.asarray(i, np.uint64) ^
                (mix64_np(fp, self.seed + 2) & np.uint64(self.nb - 1))
                ).astype(np.int64)

    def build(self, keys: np.ndarray) -> None:
        keys = np.asarray(keys, np.uint64)
        n = max(len(keys), 1)
        nb = 1
        while nb * 4 * self.occupancy < n:
            nb <<= 1
        self.nb = nb
        self.table = np.zeros((nb, 4), np.uint64)
        self.stash: list = []
        fps = self._fingerprint(keys)
        i1s = self._i1(keys)
        rng = np.random.default_rng(self.seed)
        for fp, i1 in zip(fps.tolist(), i1s.tolist()):
            fp = np.uint64(fp)
            placed = False
            for idx in (i1, int(self._alt(np.asarray([i1]), np.asarray([fp]))[0])):
                row = self.table[idx]
                free = np.nonzero(row == 0)[0]
                if len(free):
                    row[free[0]] = fp
                    placed = True
                    break
            if placed:
                continue
            idx = i1
            cur = fp
            for _ in range(self.max_kicks):
                slot = rng.integers(0, 4)
                cur, self.table[idx, slot] = self.table[idx, slot], cur
                idx = int(self._alt(np.asarray([idx]),
                                    np.asarray([cur], np.uint64))[0])
                row = self.table[idx]
                free = np.nonzero(row == 0)[0]
                if len(free):
                    row[free[0]] = cur
                    cur = None
                    break
            if cur is not None:
                self.stash.append(np.uint64(cur))

    def point(self, qs: np.ndarray) -> np.ndarray:
        qs = np.asarray(qs, np.uint64)
        fp = self._fingerprint(qs)
        i1 = self._i1(qs)
        i2 = self._alt(i1, fp)
        hit = (self.table[i1] == fp[:, None]).any(axis=1)
        hit |= (self.table[i2] == fp[:, None]).any(axis=1)
        if self.stash:
            hit |= np.isin(fp, np.asarray(self.stash, np.uint64))
        return hit

    def range(self, lo, hi):
        raise NotImplementedError("cuckoo filters cannot answer ranges")

    def size_bits(self) -> int:
        return int(self.nb * 4 * self.f + 64 * len(self.stash))
