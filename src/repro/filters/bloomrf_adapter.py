"""bloomRF adapted to the common host-side filter API used by benchmarks."""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from ..core import BloomRF, basic_layout
from ..core.tuning import advise

__all__ = ["BloomRFAdapter"]


class BloomRFAdapter:
    """``mode``:
    * ``"basic"`` — tuning-free basic bloomRF (paper §3–§5), good to R<=2^14;
    * ``"tuned"`` — advisor-selected layout for the given R (paper §7);
    * ``"auto"``  — basic when R <= 2^14 else tuned.
    """

    def __init__(self, bits_per_key: float = 16.0, d: int = 64,
                 R: float = 2 ** 14, mode: str = "auto", delta: int = 7,
                 point_weight: float = 1.0, seed: int = 0x0B100F11,
                 chunk: int = 1 << 18):
        assert mode in ("basic", "tuned", "auto")
        self.bits_per_key = bits_per_key
        self.d = d
        self.R = R
        self.mode = mode
        self.delta = delta
        self.point_weight = point_weight
        self.seed = seed
        self.chunk = chunk

    def build(self, keys: np.ndarray) -> None:
        keys = np.asarray(keys, np.uint64)
        n = max(len(keys), 1)
        mode = self.mode
        if mode == "auto":
            mode = "basic" if self.R <= 2 ** 14 else "tuned"
        if mode == "basic":
            self.layout = basic_layout(self.d, n, self.bits_per_key,
                                       delta=self.delta, seed=self.seed)
        else:
            self.layout = advise(self.d, n, int(n * self.bits_per_key),
                                 self.R, point_weight=self.point_weight,
                                 seed=self.seed).layout
        self.filter = BloomRF(self.layout)
        self.state = self.filter.build_np(keys)
        self._point = jax.jit(self.filter.point)
        self._range = jax.jit(self.filter.range)

    def _chunked(self, fn, *arrays):
        outs = []
        B = len(arrays[0])
        for s in range(0, B, self.chunk):
            args = [jnp.asarray(a[s:s + self.chunk], self.filter.kdtype)
                    for a in arrays]
            outs.append(np.asarray(fn(self.state, *args)))
        return np.concatenate(outs) if outs else np.zeros(0, bool)

    def point(self, qs: np.ndarray) -> np.ndarray:
        return self._chunked(self._point, np.asarray(qs, np.uint64))

    def range(self, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
        return self._chunked(self._range, np.asarray(lo, np.uint64),
                             np.asarray(hi, np.uint64))

    def insert_more(self, keys: np.ndarray) -> None:
        """Online insertion (the paper's Problem 2: bloomRF is online)."""
        self.state = self.filter.insert_online(
            self.state, jnp.asarray(keys, self.filter.kdtype))

    def size_bits(self) -> int:
        return self.layout.total_bits
