"""bloomRF adapted to the common host-side filter API used by benchmarks.

Since the typed façade landed (DESIGN.md §11) this adapter is a thin shim:
``build`` opens a :class:`repro.api.SingleFilter` from the equivalent
:class:`~repro.api.FilterSpec` and every probe rides the façade's shared
chunked probe path — the figure benchmarks therefore measure the
production façade, not a private side door.
"""
from __future__ import annotations

import math

import numpy as np

from ..api import FilterSpec, open_filter

__all__ = ["BloomRFAdapter"]


class BloomRFAdapter:
    """``mode``:
    * ``"basic"`` — tuning-free basic bloomRF (paper §3–§5), good to R<=2^14;
    * ``"tuned"`` — advisor-selected layout for the given R (paper §7);
    * ``"auto"``  — basic when R <= 2^14 else tuned.

    Maps onto ``FilterSpec.tuning`` = ``"basic"`` / ``"advised"`` /
    ``"auto"``.
    """

    _TUNING = {"basic": "basic", "tuned": "advised", "auto": "auto"}

    def __init__(self, bits_per_key: float = 16.0, d: int = 64,
                 R: float = 2 ** 14, mode: str = "auto", delta: int = 7,
                 point_weight: float = 1.0, seed: int = 0x0B100F11,
                 chunk: int = 1 << 18):
        assert mode in ("basic", "tuned", "auto")
        self.bits_per_key = bits_per_key
        self.d = d
        self.R = R
        self.mode = mode
        self.delta = delta
        self.point_weight = point_weight
        self.seed = seed
        self.chunk = chunk

    def build(self, keys: np.ndarray) -> None:
        keys = np.asarray(keys, np.uint64)
        range_log2 = max(int(math.ceil(math.log2(max(self.R, 2.0)))), 1)
        self.handle = open_filter(FilterSpec(
            dtype=f"u{self.d}", n=max(len(keys), 1),
            bits_per_key=self.bits_per_key,
            range_log2=min(range_log2, self.d),
            tuning=self._TUNING[self.mode], delta=self.delta,
            point_weight=self.point_weight, backend="xla",
            chunk=self.chunk, seed=self.seed))
        self.handle.insert(keys)
        self.layout = self.handle.layout
        self.filter = self.handle.filter

    @property
    def state(self):
        return self.handle.state

    def point(self, qs: np.ndarray) -> np.ndarray:
        return self.handle.point(np.asarray(qs, np.uint64))

    def range(self, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
        return self.handle.range(np.asarray(lo, np.uint64),
                                 np.asarray(hi, np.uint64))

    def insert_more(self, keys: np.ndarray) -> None:
        """Online insertion (the paper's Problem 2: bloomRF is online)."""
        self.handle.insert(np.asarray(keys, np.uint64))

    def size_bits(self) -> int:
        return self.handle.size_bits()
