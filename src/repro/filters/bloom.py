"""Standard Bloom filter (Bloom 1970) — the point-only baseline."""
from __future__ import annotations

import math

import numpy as np

from .api import mix64_np, seeds_np

__all__ = ["BloomFilter"]


class BloomFilter:
    def __init__(self, bits_per_key: float = 10.0, k: int | None = None,
                 seed: int = 0xB10F):
        self.bits_per_key = bits_per_key
        self._k_fixed = k
        self.seed = seed
        self.m = 0
        self.k = 0
        self.bits: np.ndarray | None = None

    def _positions(self, keys: np.ndarray) -> np.ndarray:
        hs = [mix64_np(keys, int(s)) % np.uint64(self.m) for s in self._seeds]
        return np.stack(hs, axis=-1).astype(np.int64)

    def build(self, keys: np.ndarray) -> None:
        n = max(len(keys), 1)
        self.m = max(64, int(n * self.bits_per_key) // 64 * 64)
        # optimal k = ln(2) m/n, floored like RocksDB
        self.k = self._k_fixed or max(1, int(math.log(2) * self.m / n))
        self._seeds = seeds_np(self.seed, self.k)
        self.bits = np.zeros(self.m // 32, np.uint32)
        pos = self._positions(np.asarray(keys, np.uint64)).reshape(-1)
        np.bitwise_or.at(self.bits, pos >> 5,
                         np.uint32(1) << (pos & 31).astype(np.uint32))

    def point(self, qs: np.ndarray) -> np.ndarray:
        pos = self._positions(np.asarray(qs, np.uint64))
        got = (self.bits[pos >> 5] >> (pos & 31).astype(np.uint32)) & 1
        return got.all(axis=-1)

    def range(self, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
        raise NotImplementedError("plain Bloom filters cannot answer ranges")

    def size_bits(self) -> int:
        return self.m
