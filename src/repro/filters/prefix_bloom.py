"""Prefix Bloom filter: a BF over fixed-level key prefixes.

Classic range-capable baseline (the paper's "Prefix-BF"): ranges are answered
by probing every covering prefix at the configured level; point lookups probe
the key's own prefix (hence elevated point FPR — all keys sharing a prefix are
indistinguishable)."""
from __future__ import annotations

import math

import numpy as np

from .api import mix64_np, seeds_np

__all__ = ["PrefixBloomFilter"]


class PrefixBloomFilter:
    def __init__(self, bits_per_key: float = 10.0, prefix_level: int = 12,
                 max_probe: int = 4096, seed: int = 0x9F1B):
        self.bits_per_key = bits_per_key
        self.level = prefix_level
        self.max_probe = max_probe
        self.seed = seed

    def build(self, keys: np.ndarray) -> None:
        keys = np.asarray(keys, np.uint64)
        prefixes = keys >> np.uint64(self.level)
        n = max(len(keys), 1)
        self.m = max(64, int(n * self.bits_per_key) // 64 * 64)
        self.k = max(1, int(math.log(2) * self.m / n))
        self._seeds = seeds_np(self.seed, self.k)
        self.bits = np.zeros(self.m // 32, np.uint32)
        pos = self._positions(prefixes).reshape(-1)
        np.bitwise_or.at(self.bits, pos >> 5,
                         np.uint32(1) << (pos & 31).astype(np.uint32))

    def _positions(self, prefixes: np.ndarray) -> np.ndarray:
        hs = [mix64_np(prefixes, int(s)) % np.uint64(self.m) for s in self._seeds]
        return np.stack(hs, axis=-1).astype(np.int64)

    def _probe(self, prefixes: np.ndarray) -> np.ndarray:
        pos = self._positions(prefixes)
        got = (self.bits[pos >> 5] >> (pos & 31).astype(np.uint32)) & 1
        return got.all(axis=-1)

    def point(self, qs: np.ndarray) -> np.ndarray:
        return self._probe(np.asarray(qs, np.uint64) >> np.uint64(self.level))

    def range(self, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
        lo = np.asarray(lo, np.uint64) >> np.uint64(self.level)
        hi = np.asarray(hi, np.uint64) >> np.uint64(self.level)
        span = (hi - lo + np.uint64(1)).astype(np.int64)
        out = np.zeros(len(lo), bool)
        over = span > self.max_probe
        out[over] = True  # conservatively positive beyond the probe budget
        todo = np.nonzero(~over)[0]
        # probe prefix-by-prefix, vectorized over queries still pending
        step = np.zeros(len(lo), np.uint64)
        pending = todo
        while len(pending):
            p = lo[pending] + step[pending]
            hit = self._probe(p)
            out[pending[hit]] = True
            step[pending] += np.uint64(1)
            keep = (~hit) & (lo[pending] + step[pending] <= hi[pending])
            pending = pending[keep]
        return out

    def size_bits(self) -> int:
        return self.m
