"""Rosetta (Luo et al., SIGMOD 2020) — hierarchical dyadic Bloom filters.

First-cut flavour (the paper's variant F): one BF per dyadic level 0..L; the
bottom level is sized for the target FPR, upper levels for FPR ~ 1/(2-eps)
(~1.44 bits/key, k=1).  Range queries use the standard dyadic decomposition
and *doubting*: every positive above level 0 is re-checked through its
children until a level-0 positive survives (worst case linear in R, as the
bloomRF paper notes).
"""
from __future__ import annotations

import math

import numpy as np

from .api import mix64_np, seeds_np

__all__ = ["Rosetta"]

_UPPER_BPK = 1.44  # bits/key per upper level (FPR ~ 0.5, k=1)


class Rosetta:
    def __init__(self, bits_per_key: float = 16.0, max_range_log2: int = 14,
                 decompose_cap: int = 4096, frontier_cap: int = 1 << 22,
                 seed: int = 0x4057A):
        self.bits_per_key = bits_per_key
        self.L = max_range_log2
        self.decompose_cap = decompose_cap
        self.frontier_cap = frontier_cap
        self.seed = seed

    # ------------------------------------------------------------------
    def build(self, keys: np.ndarray) -> None:
        keys = np.asarray(keys, np.uint64)
        n = max(len(keys), 1)
        total = int(n * self.bits_per_key)
        upper = int(math.ceil(_UPPER_BPK * n))
        L = self.L
        # shrink the hierarchy if the budget cannot afford all upper levels
        while L > 1 and total - L * upper < 2 * n:
            L -= 1
        self.L = L
        m_bottom = max(64, (total - L * upper) // 64 * 64)
        m_upper = max(64, upper // 64 * 64)
        self.m_lvl = [m_bottom] + [m_upper] * L
        self.k_lvl = [max(1, int(math.log(2) * m_bottom / n))] + [1] * L
        self.off = np.cumsum([0] + self.m_lvl[:-1]).astype(np.int64)
        self.total_m = int(sum(self.m_lvl))
        self._seeds = {
            lvl: seeds_np(self.seed + 101 * lvl, self.k_lvl[lvl])
            for lvl in range(L + 1)
        }
        self.bits = np.zeros(self.total_m // 32, np.uint32)
        for lvl in range(L + 1):
            pref = keys >> np.uint64(lvl)
            pos = self._positions(lvl, pref).reshape(-1)
            np.bitwise_or.at(self.bits, pos >> 5,
                             np.uint32(1) << (pos & 31).astype(np.uint32))

    def _positions(self, lvl: int, prefixes: np.ndarray) -> np.ndarray:
        m = np.uint64(self.m_lvl[lvl])
        hs = [(mix64_np(prefixes, int(s)) % m).astype(np.int64) + self.off[lvl]
              for s in self._seeds[lvl]]
        return np.stack(hs, axis=-1)

    def _probe(self, lvl_arr: np.ndarray, prefixes: np.ndarray) -> np.ndarray:
        out = np.zeros(len(prefixes), bool)
        for lvl in np.unique(lvl_arr):
            sel = lvl_arr == lvl
            pos = self._positions(int(lvl), prefixes[sel])
            got = (self.bits[pos >> 5] >> (pos & 31).astype(np.uint32)) & 1
            out[sel] = got.all(axis=-1)
        return out

    # ------------------------------------------------------------------
    def point(self, qs: np.ndarray) -> np.ndarray:
        qs = np.asarray(qs, np.uint64)
        return self._probe(np.zeros(len(qs), np.int64), qs)

    @staticmethod
    def _decompose(lo: int, hi: int, L: int, cap: int):
        """Standard dyadic decomposition into <= 2 DIs per level <= L."""
        out = []
        a, b = lo, hi + 1
        lvl = 0
        while a < b:
            if lvl >= L:
                if ((b - a) >> lvl) > cap:
                    return out, True
                out.extend((lvl, p) for p in range(a >> lvl, b >> lvl))
                return out, False
            if a & (1 << lvl):
                out.append((lvl, a >> lvl))
                a += 1 << lvl
            if b & (1 << lvl):
                b -= 1 << lvl
                out.append((lvl, b >> lvl))
            lvl += 1
        return out, False

    def range(self, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
        lo = np.asarray(lo, np.uint64)
        hi = np.asarray(hi, np.uint64)
        B = len(lo)
        out = np.zeros(B, bool)
        qid, lvl, pref = [], [], []
        for q in range(B):
            items, overflow = self._decompose(int(lo[q]), int(hi[q]), self.L,
                                              self.decompose_cap)
            if overflow:
                out[q] = True
                continue
            for (lv, p) in items:
                qid.append(q)
                lvl.append(lv)
                pref.append(p)
        qid = np.asarray(qid, np.int64)
        lvl = np.asarray(lvl, np.int64)
        pref = np.asarray(pref, np.uint64)
        # doubting BFS
        while len(qid):
            alive = self._probe(lvl, pref) & ~out[qid]
            hit0 = alive & (lvl == 0)
            out[qid[hit0]] = True
            expand = alive & (lvl > 0)
            qid, lvl, pref = qid[expand], lvl[expand], pref[expand]
            if len(qid) == 0:
                break
            qid = np.repeat(qid, 2)
            lvl = np.repeat(lvl, 2) - 1
            pref = np.repeat(pref << np.uint64(1), 2)
            pref[1::2] |= np.uint64(1)
            if len(qid) > self.frontier_cap:  # runaway doubting -> concede
                out[np.unique(qid)] = True
                break
            keep = ~out[qid]
            qid, lvl, pref = qid[keep], lvl[keep], pref[keep]
        return out

    def size_bits(self) -> int:
        return self.total_m
