"""Fence pointers / ZoneMap / BRIN-style min-max blocks.

Keys are sorted and chunked into blocks of B keys; each block stores
(min, max).  A range query is positive iff it overlaps any block interval;
point queries likewise (with block-level granularity).  128 bits per block
=> bits/key = 128 / B.
"""
from __future__ import annotations

import numpy as np

__all__ = ["FencePointers"]


class FencePointers:
    def __init__(self, bits_per_key: float = 10.0):
        self.bits_per_key = bits_per_key

    def build(self, keys: np.ndarray) -> None:
        ks = np.sort(np.asarray(keys, np.uint64))
        B = max(1, int(np.ceil(128.0 / self.bits_per_key)))
        nb = (len(ks) + B - 1) // B
        self.mins = ks[::B][:nb].copy()
        self.maxs = np.asarray(
            [ks[min((i + 1) * B, len(ks)) - 1] for i in range(nb)], np.uint64)
        self._nblocks = nb

    def range(self, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
        lo = np.asarray(lo, np.uint64)
        hi = np.asarray(hi, np.uint64)
        # overlap iff exists block with min <= hi and max >= lo
        i = np.searchsorted(self.mins, hi, side="right") - 1
        # the candidate block is the last with min <= hi; also check next-left
        ok = np.zeros(len(lo), bool)
        valid = i >= 0
        ok[valid] = self.maxs[np.maximum(i[valid], 0)] >= lo[valid]
        return ok

    def point(self, qs: np.ndarray) -> np.ndarray:
        qs = np.asarray(qs, np.uint64)
        return self.range(qs, qs)

    def size_bits(self) -> int:
        return int(self._nblocks * 128)
