import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ the dry-run (and ONLY the dry-run) fakes 512 host devices so
# jax.make_mesh can build the production meshes; must precede any jax import.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the jitted step (full train step incl. AdamW update, or
     prefill / decode) with production in/out shardings,
  2. ``.lower().compile()`` against ShapeDtypeStruct inputs (no allocation),
  3. records ``memory_analysis`` / ``cost_analysis`` and the collective-op
     byte totals parsed from the optimized HLO,
  4. derives the three roofline terms (compute / memory / collective) for
     TPU v5e constants (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).

Results stream to JSON (one record per cell) consumed by
``benchmarks/roofline_report.py`` and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun.json [--smoke]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_NAMES, get_config, shape_applicable
from ..dist.sharding import (batch_axes_for, make_shardings,
                             mesh_axis_sizes)
from ..models import SHAPES, get_model
from ..models.act import activation_mesh
from ..train.optimizer import OptConfig, adamw_update
from .hlo_cost import analyze_hlo
from .mesh import make_production_mesh

# TPU v5e roofline constants
PEAK_FLOPS = 197e12         # bf16 / chip
HBM_BW = 819e9              # bytes/s / chip
ICI_BW = 50e9               # bytes/s / link

# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def param_sds(model):
    from ..models.params import P as PLeaf
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
        model.table(), is_leaf=lambda x: isinstance(x, PLeaf))


def opt_sds(psds):
    zeros = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), psds)
    return {"m": zeros,
            "v": jax.tree.map(lambda s: s, zeros),
            "count": jax.ShapeDtypeStruct((), jnp.int32)}


def build_cell(model, mesh, shape):
    """Returns (fn, example_args_sds, in_shardings, out_shardings, donate)."""
    sh = make_shardings(model, mesh, shape)
    psds = param_sds(model)
    batch_sds = model.input_specs(shape)
    if shape.kind == "train":
        opt_cfg = OptConfig()

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            params, opt_state, om = adamw_update(opt_cfg, grads, opt_state,
                                                 params)
            return params, opt_state, {"loss": loss, **om}

        osds = opt_sds(psds)
        osh = {"m": sh.params, "v": jax.tree.map(lambda x: x, sh.params),
               "count": sh.out_scalar}
        metr = {"loss": sh.out_scalar, "lr": sh.out_scalar,
                "grad_norm": sh.out_scalar}
        return (train_step, (psds, osds, batch_sds),
                (sh.params, osh, sh.batch), (sh.params, osh, metr), (0, 1))
    from jax.sharding import NamedSharding, PartitionSpec as PS
    from ..dist.sharding import batch_axes_for, _axes_size
    msz = mesh_axis_sizes(mesh)
    ba = batch_axes_for(mesh)
    vocab_ax = "model" if model.cfg.vocab % msz.get("model", 1) == 0 else None
    if shape.kind == "prefill":
        logits_sh = NamedSharding(mesh, PS(ba, None, vocab_ax))
        return (model.prefill, (psds, batch_sds),
                (sh.params, sh.batch), (logits_sh, sh.cache), ())
    # decode
    if shape.batch < _axes_size(msz, ba):
        ba = None
    csds = model.cache_specs(shape)
    logits_sh = NamedSharding(mesh, PS(ba, None, vocab_ax))
    return (model.decode, (psds, csds, batch_sds),
            (sh.params, sh.cache, sh.batch), (logits_sh, sh.cache), (1,))


def run_cell(arch: str, shape_name: str, multi_pod: bool, smoke: bool) -> dict:
    cfg = get_config(arch, smoke=smoke)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "multi" if multi_pod else "single",
           "kind": shape.kind, "status": "skipped"}
    if not shape_applicable(cfg, shape):
        rec["note"] = "long_500k skipped for full-attention arch (DESIGN.md)"
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    model_axis = mesh_axis_sizes(mesh)["model"]
    if (shape.kind != "train" and cfg.n_kv_heads and
            cfg.n_kv_heads % model_axis != 0 and
            cfg.family in ("dense", "moe", "vlm", "hybrid")):
        # pad cached KV heads so the cache shards over the model axis
        cfg = dataclasses.replace(cfg, kv_cache_pad_heads=model_axis)
    model = get_model(cfg)
    t0 = time.time()
    fn, args, in_sh, out_sh, donate = build_cell(model, mesh, shape)
    jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                  donate_argnums=donate)
    with activation_mesh(mesh, batch_axes_for(mesh)):
        lowered = jfn.lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    # raw XLA cost analysis (NOT loop-trip-multiplied — kept for reference)
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                            None),
        }
    except Exception:
        mem_rec = None

    # loop-trip-aware accounting over the optimized HLO (launch/hlo_cost.py)
    hc = analyze_hlo(compiled.as_text())
    flops_dev = hc.flops
    bytes_dev = hc.bytes
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": hc.collective_bytes / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    rec.update({
        "status": "ok", "chips": chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": flops_dev, "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": hc.collective_bytes,
        "collective_breakdown": hc.collective_breakdown,
        "while_trips": hc.while_trips,
        "xla_cost_analysis": {"flops": float(ca.get("flops", 0.0)),
                              "bytes_accessed":
                              float(ca.get("bytes accessed", 0.0))},
        "memory_analysis": mem_rec,
        "roofline_terms_s": terms, "dominant": dominant,
    })
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs (CI)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = ARCH_NAMES if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    records = []
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                tag = f"{arch} x {shape_name} x {'multi' if multi else 'single'}"
                try:
                    rec = run_cell(arch, shape_name, multi, args.smoke)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "multi" if multi else "single",
                           "status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                records.append(rec)
                if rec["status"] == "ok":
                    t = rec["roofline_terms_s"]
                    print(f"[ok] {tag}: compile={rec['compile_s']:.1f}s "
                          f"compute={t['compute_s']:.3e}s "
                          f"mem={t['memory_s']:.3e}s "
                          f"coll={t['collective_s']:.3e}s "
                          f"dominant={rec['dominant']}", flush=True)
                else:
                    print(f"[{rec['status']}] {tag}"
                          f" {rec.get('error', rec.get('note', ''))}",
                          flush=True)
                if args.out:
                    with open(args.out, "w") as fh:
                        json.dump(records, fh, indent=1)
    n_ok = sum(r["status"] == "ok" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    print(f"dry-run: {n_ok} ok, {n_err} errors, "
          f"{len(records) - n_ok - n_err} skipped")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
