"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
touches no jax device state.  Mesh shapes (TPU v5e):

* single pod:  (data=16, model=16)          — 256 chips
* multi-pod:   (pod=2, data=16, model=16)   — 512 chips

Logical use: batch/FSDP over ("pod","data"); TP/EP/SP over "model"; the
"pod" axis can alternatively drive the pipeline utilities (dist/pipeline.py).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_smoke_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """A tiny mesh over however many devices the test process has."""
    n = len(jax.devices())
    if n >= 4:
        return jax.make_mesh((2, n // 2), ("data", "model"))
    return jax.make_mesh((1, n), ("data", "model"))
