"""Trip-count-aware cost accounting over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts each op once — while-loop bodies
(lax.scan over layers / KV blocks / SSD chunks) are NOT multiplied by their
trip counts, so scanned models look ~L× cheaper than they are.  Full
unrolling fixes that but is unaffordable to compile on one host core for 72
dry-run cells.  This module instead walks the HLO call graph:

* parse every computation and its ops (symbol table of result shapes);
* recover while-loop trip counts from the loop condition's comparison
  constant (scan lowers to ``compare(iter, const)``);
* propagate multipliers ENTRY -> called computations (while bodies get
  parent_mult × trips; call/fusion/cond bodies get parent_mult);
* FLOPs: dot ops count 2·numel(result)·contraction_size; elementwise math
  counts numel(result); everything scaled by the computation's multiplier.
* bytes: per *top-level* op (fusion bodies excluded — their traffic is the
  fusion's operands/results): operands + result, with slicing ops counted at
  slice size (matching XLA's optimistic bytes-accessed convention);
* collective bytes: operand bytes of all-reduce/all-gather/reduce-scatter/
  all-to-all/collective-permute × multiplier (async -start counted once).

Validated against ``cost_analysis`` on small unrolled modules
(tests/test_dryrun.py::test_hlo_cost_matches_unrolled).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["analyze_hlo", "HloCost"]

_DT_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "power",
    "select", "compare", "and", "or", "xor", "convert", "floor", "ceil",
    "sign", "cosine", "sine", "logistic", "expm1", "log1p", "clamp",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "remainder", "atan2", "reduce", "exponential-minus-one",
}

_SLICELIKE = {"dynamic-slice", "gather", "slice", "dynamic-update-slice",
              "scatter", "pad", "concatenate", "reshape", "transpose",
              "broadcast", "iota", "reverse"}

_FREE = {"parameter", "get-tuple-element", "tuple", "bitcast", "constant",
         "after-all", "custom-call", "partition-id", "replica-id",
         "rng-get-and-update-state", "get-dimension-size", "domain",
         "opt-barrier", "conditional", "while", "call", "fusion",
         "async-start", "async-update", "async-done"}


def _shape_numel_bytes(tstr: str):
    total_b = 0
    total_n = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", tstr):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DT_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_n += n
        total_b += n * _DT_BYTES[dt]
    return total_n, total_b


@dataclass
class _Op:
    name: str
    opcode: str
    tstr: str
    operands: list
    line: str


@dataclass
class _Computation:
    name: str
    ops: list = field(default_factory=list)


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_breakdown: dict = field(default_factory=dict)
    while_trips: dict = field(default_factory=dict)


_COMP_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"((?:\(.*?\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s+([\w\-]+)\((.*)$")


def _operand_names(rest: str) -> list:
    """Operand names of an op given everything after ``opcode(``.

    Operand references in optimized HLO carry full type annotations
    (``f32[8,64]{1,0} %name``) whose brackets contain commas, and tuple
    types contain parens — so the operand list must be cut at the
    depth-matching close paren and names taken as the ``%name`` tokens
    (attributes after the close paren, e.g. ``body=%region``, excluded).
    Sigil-less print styles (no ``%``) fall back to the last token of each
    top-level comma-separated operand."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                rest = rest[:i]
                break
    names = re.findall(r"%([\w\.\-]+)", rest)
    if names or not rest.strip():
        return names
    out = []
    depth = 0
    start = 0
    for i, ch in enumerate(rest):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            tok = rest[start:i].strip().split()
            if tok:
                out.append(tok[-1])
            start = i + 1
    tok = rest[start:].strip().split()
    if tok:
        out.append(tok[-1])
    return out


def _parse_module(hlo: str):
    comps: dict = {}
    cur = None
    entry = None
    for line in hlo.splitlines():
        mc = _COMP_RE.match(line.strip()) if "{" in line and "->" in line else None
        # header lines have no " = " assignment (note: /*index=N*/ comments
        # inside tuple types do contain bare '=')
        if mc and " = " not in line.split("{")[0]:
            cur = _Computation(mc.group(1))
            comps[cur.name] = cur
            if line.strip().startswith("ENTRY"):
                entry = cur.name
            continue
        mo = _OP_RE.match(line)
        if mo and cur is not None:
            name, tstr, opcode, rest = mo.groups()
            cur.ops.append(_Op(name, opcode, tstr, _operand_names(rest),
                               line))
    return comps, entry


def _attr(line: str, key: str):
    m = re.search(key + r"=%?([\w\.\-]+)", line)
    return m.group(1) if m else None


def _attr_list(line: str, key: str):
    m = re.search(key + r"=\{([^}]*)\}", line)
    if not m:
        return []
    return [x.strip() for x in m.group(1).split(",") if x.strip()]


def _trip_count(comps: dict, cond_name: str):
    """Trip count of a scan-style loop: the integer constant the iteration
    counter is compared against.  The compare may be fused, so we take the
    largest integer constant in the (tiny) condition computation."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", op.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _fusion_io_bytes(comps, body_name, operands, shape_of):
    """Traffic of a fusion op, looking inside the fused computation:
    a parameter consumed only by slice-like ops is charged at the slice
    result size (scan xs slicing!), and a root dynamic-update-slice charges
    the update size instead of the full (aliased) buffer."""
    body = comps.get(body_name)
    if body is None:
        return sum(shape_of.get(o, (0, 0))[1] for o in operands), None
    pname_by_idx = {}
    for bop in body.ops:
        if bop.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", bop.line)
            if m:
                pname_by_idx[int(m.group(1))] = bop.name
    param_partial = {}   # param name -> accumulated slice bytes
    param_full = set()
    pnames = set(pname_by_idx.values())
    # aliases: bitcast/reshape/transpose/copy/convert of a param read the
    # same logical bytes (TPU's ConvertMover folds the convert-around-DUS
    # pattern XLA:CPU leaves behind — model the folded form)
    _TRANSPARENT = ("bitcast", "reshape", "transpose", "copy", "convert")
    alias_of = {}
    for bop in body.ops:
        if bop.opcode in _TRANSPARENT and bop.operands:
            src = alias_of.get(bop.operands[0], bop.operands[0])
            if src in pnames:
                alias_of[bop.name] = src
    root = None
    ops_by_name = {bop.name: bop for bop in body.ops}
    for bop in body.ops:
        if bop.line.strip().startswith("ROOT"):
            root = bop
        if bop.opcode in _TRANSPARENT:
            continue
        for o in bop.operands:
            o = alias_of.get(o, o)
            if o not in pnames:
                continue
            if bop.opcode in ("dynamic-slice", "slice", "gather"):
                param_partial[o] = param_partial.get(o, 0) + \
                    shape_of.get(bop.name, (0, 0))[1]
            elif bop.opcode == "dynamic-update-slice" and \
                    bop.operands and alias_of.get(bop.operands[0],
                                                  bop.operands[0]) == o:
                pass  # aliased buffer passthrough; charged via the update
            else:
                param_full.add(o)
    # unwrap the root through transparent ops to find an in-place DUS
    while root is not None and root.opcode in _TRANSPARENT and root.operands:
        root = ops_by_name.get(root.operands[0])
    total_in = 0
    for i, o in enumerate(operands):
        pname = pname_by_idx.get(i)
        full = shape_of.get(o, (0, 0))[1]
        if pname is None:
            total_in += full
        elif pname in param_full:
            total_in += full
        else:
            total_in += min(param_partial.get(pname, full), full)
    out_override = None
    if root is not None and root.opcode == "dynamic-update-slice" and \
            len(root.operands) > 1:
        upd = root.operands[1]
        upd_b = shape_of.get(upd, None)
        if upd_b is None and upd in pname_by_idx.values():
            pass
        out_override = 2 * (shape_of.get(upd, (0, 0))[1] or 0)
        if out_override == 0:
            # update defined inside the fusion body
            out_override = 2 * shape_of.get(root.operands[1], (0, 0))[1]
    return total_in, out_override


def analyze_hlo(hlo: str) -> HloCost:
    comps, entry = _parse_module(hlo)
    if entry is None:
        # fall back: largest computation
        entry = max(comps, key=lambda c: len(comps[c].ops)) if comps else None
    cost = HloCost()
    if entry is None:
        return cost

    # compute multipliers and fused-body marking via BFS
    mult = {entry: 1.0}
    fused_body: set = set()
    order = [entry]
    seen = {entry}
    qi = 0
    while qi < len(order):
        cname = order[qi]
        qi += 1
        comp = comps[cname]
        for op in comp.ops:
            callees = []
            if op.opcode == "while":
                body = _attr(op.line, "body")
                cond = _attr(op.line, "condition")
                trips = _trip_count(comps, cond)
                cost.while_trips[op.name] = trips
                if body in comps:
                    callees.append((body, mult[cname] * trips, False))
                if cond in comps:
                    callees.append((cond, mult[cname], False))
            elif op.opcode == "fusion":
                body = _attr(op.line, "calls")
                if body in comps:
                    callees.append((body, mult[cname], True))
            elif op.opcode in ("call", "async-start"):
                body = _attr(op.line, "to_apply") or _attr(op.line, "calls")
                if body in comps:
                    callees.append((body, mult[cname], False))
            elif op.opcode == "conditional":
                for key in ("true_computation", "false_computation"):
                    body = _attr(op.line, key)
                    if body in comps:
                        callees.append((body, mult[cname], False))
                for body in _attr_list(op.line, "branch_computations"):
                    body = body.lstrip("%")
                    if body in comps:
                        callees.append((body, mult[cname], False))
            elif op.opcode in ("reduce", "scatter", "sort", "map",
                               "reduce-window", "select-and-scatter"):
                body = _attr(op.line, "to_apply")
                if body in comps:
                    callees.append((body, 0.0, True))  # tiny scalar lambdas
            for body, m, fused in callees:
                mult[body] = max(mult.get(body, 0.0), m)
                if fused:
                    fused_body.add(body)
                if body not in seen:
                    seen.add(body)
                    order.append(body)

    # symbol table (result bytes + type string per op name, module-wide)
    shape_of: dict = {}
    tstr_of: dict = {}
    for comp in comps.values():
        for op in comp.ops:
            shape_of[op.name] = _shape_numel_bytes(op.tstr)
            tstr_of[op.name] = op.tstr

    for cname in order:
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        comp = comps[cname]
        in_fused = cname in fused_body
        for op in comp.ops:
            numel, rbytes = shape_of.get(op.name, (0, 0))
            oc = op.opcode
            base = oc[:-6] if oc.endswith("-start") else oc
            # ---- flops (counted inside fusions too)
            if oc == "dot":
                cdims = _attr_list(op.line, "lhs_contracting_dims")
                lhs = op.operands[0] if op.operands else None
                k = 1
                if lhs is not None:
                    lm = re.search(r"\w+\[([\d,]*)\]", tstr_of.get(lhs, ""))
                    if lm and lm.group(1):
                        dims = [int(d) for d in lm.group(1).split(",")]
                        for c in cdims:
                            ci = int(c)
                            if ci < len(dims):
                                k *= dims[ci]
                cost.flops += m * 2.0 * numel * k
            elif oc == "convolution":
                cost.flops += m * 2.0 * numel * 32  # rare in this zoo
            elif base in _ELEMENTWISE:
                cost.flops += m * numel
            # ---- bytes (top-level ops only; fused bodies excluded)
            if not in_fused and oc not in _FREE:
                if oc in _SLICELIKE or base in _ELEMENTWISE or \
                        oc in ("dot", "convolution", "copy", "reduce",
                               "fusion") or base in _COLLECTIVES:
                    opnd = 0
                    if oc in ("dynamic-slice", "gather", "slice"):
                        opnd = rbytes            # reads slice-sized data
                    elif oc == "dynamic-update-slice":
                        upd = shape_of.get(op.operands[1], (0, 0))[1] \
                            if len(op.operands) > 1 else rbytes
                        opnd = 2 * upd           # read+write the update
                        rbytes = 0
                    else:
                        opnd = sum(shape_of.get(o, (0, 0))[1]
                                   for o in op.operands)
                    cost.bytes += m * (opnd + rbytes)
            # fusion op itself moves its (utilized) operands + result
            if not in_fused and oc == "fusion":
                opnd, out_override = _fusion_io_bytes(
                    comps, _attr(op.line, "calls"), op.operands, shape_of)
                cost.bytes += m * (opnd + (rbytes if out_override is None
                                           else out_override))
            # ---- collectives
            if base in _COLLECTIVES and not oc.endswith("-done"):
                b = sum(shape_of.get(o, (0, 0))[1] for o in op.operands)
                if b == 0:
                    b = rbytes
                cost.collective_bytes += m * b
                cost.collective_breakdown[base] = \
                    cost.collective_breakdown.get(base, 0.0) + m * b
    return cost
