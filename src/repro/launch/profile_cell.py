import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dump the top byte/flop-contributing HLO ops for one dry-run cell —
the 'profile' of the CPU-only perf loop (EXPERIMENTS.md §Perf).

Usage: PYTHONPATH=src python -m repro.launch.profile_cell --arch X \
    --shape train_4k [--multi] [--top 25]
"""
import argparse

import jax

from ..configs import get_config
from ..dist.sharding import batch_axes_for
from ..models import SHAPES, get_model
from ..models.act import activation_mesh
from . import dryrun as dr
from .hlo_cost import (_FREE, _attr, _parse_module, _shape_numel_bytes,
                       _trip_count)
from .mesh import make_production_mesh


def top_contributors(hlo: str, top: int = 25):
    comps, entry = _parse_module(hlo)
    mult = {entry: 1.0}
    fused = set()
    order = [entry]
    seen = {entry}
    qi = 0
    while qi < len(order):
        c = order[qi]
        qi += 1
        for op in comps[c].ops:
            cal = []
            if op.opcode == "while":
                b = _attr(op.line, "body")
                cd = _attr(op.line, "condition")
                t = _trip_count(comps, cd)
                if b in comps:
                    cal.append((b, mult[c] * t, False))
                if cd in comps:
                    cal.append((cd, mult[c], False))
            elif op.opcode == "fusion":
                b = _attr(op.line, "calls")
                if b in comps:
                    cal.append((b, mult[c], True))
            elif op.opcode in ("call", "async-start"):
                b = _attr(op.line, "to_apply") or _attr(op.line, "calls")
                if b in comps:
                    cal.append((b, mult[c], False))
            for b, m, f in cal:
                mult[b] = max(mult.get(b, 0), m)
                if f:
                    fused.add(b)
                if b not in seen:
                    seen.add(b)
                    order.append(b)
    shape_of = {}
    for comp in comps.values():
        for op in comp.ops:
            shape_of[op.name] = _shape_numel_bytes(op.tstr)
    rows = []
    for cname in order:
        m = mult.get(cname, 0)
        if m <= 0 or cname in fused:
            continue
        for op in comps[cname].ops:
            numel, rb = shape_of.get(op.name, (0, 0))
            oc = op.opcode
            if oc in _FREE and oc != "fusion":
                continue
            if oc in ("dynamic-slice", "gather", "slice"):
                b = rb
            elif oc == "dynamic-update-slice":
                upd = shape_of.get(op.operands[1], (0, 0))[1] \
                    if len(op.operands) > 1 else rb
                b = 2 * upd
            else:
                b = sum(shape_of.get(o, (0, 0))[1] for o in op.operands) + rb
            rows.append((m * b, m, oc, op.line.strip()[:150]))
    rows.sort(reverse=True)
    return rows[:top]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi)
    model = get_model(cfg)
    fn, fargs, in_sh, out_sh, donate = dr.build_cell(model, mesh, shape)
    jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                  donate_argnums=donate)
    with activation_mesh(mesh, batch_axes_for(mesh)):
        hlo = jfn.lower(*fargs).compile().as_text()
    total = 0.0
    rows = top_contributors(hlo, args.top)
    for b, m, oc, line in rows:
        print(f"{b/1e9:10.1f} GB x{m:6.0f} {oc:22s} {line}")


if __name__ == "__main__":
    main()
