"""Sharded bloomRF filter bank: range-partitioned state over a device mesh.

The global key domain (``d`` bits) is range-partitioned by its top
``log2(n_shards)`` bits; shard ``s`` owns the dyadic interval
``[s << d_local, (s+1) << d_local)`` and runs an independent bloomRF over
the low ``d_local = d - log2(n_shards)`` bits.  This is exactly the
deployment shape the TPU kernels assume (kernels/ref.py, DESIGN.md §3):
a 64-bit space becomes uint32 sub-domains per shard, all lane arithmetic
stays native uint32, and each shard's state is 1/n_shards of the total.

Routing is branch-free SPMD:
  * insert — every shard computes positions for the whole key batch but only
    ORs bits of keys it owns (a masked scatter), so no all-to-all is needed;
  * point  — shard-local verdict AND ownership mask, any-reduced;
  * range  — a global [lo, hi] is clipped to each shard's interval; shards
    with a non-empty intersection answer their clipped sub-range; verdicts
    are any-reduced.  Correctness: the dyadic partition means a key is in
    [lo, hi] iff it is in exactly one shard's clipped sub-range, so the bank
    is false-negative-free whenever the per-shard filters are.

``FilterBank`` is the single-device reference (vmap over shard rows);
``ShardedFilterBank`` runs the identical per-shard math under ``shard_map``
with the state sharded over a mesh axis and verdicts all-gathered via psum —
the two are bitwise-identical by construction, which the test suite checks
on 1e5-probe workloads.

Probes route through the plan->gather->combine engine (core/engine.py).
On the single-device bank they go one step further: the shard rows are a
stack over one flat lane vector, so ``point``/``range`` probe **all**
shards at once through the multi-filter stacked plan
(``core.engine.StackedProbe``) — ONE fused gather for the whole
(batch x shard) verdict matrix, with the per-shard clipped bounds passed
as per-row bounds.  The per-shard bodies survive for the ``shard_map``
variant (each device probes only its resident rows) and stay the bitwise
reference for both paths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from ..core import BloomRF, basic_layout, stacked_probe
from ..core.hashing import key_dtype_for

__all__ = ["FilterBank", "ShardedFilterBank"]


class FilterBank:
    """n_shards independent bloomRFs over a range-partitioned key domain."""

    def __init__(self, d: int, n_shards: int, n_keys: int,
                 bits_per_key: float = 16.0, delta: int = 6,
                 seed: int = 0x0B100F11, *, _warn: bool = True,
                 _layout=None):
        if _warn:
            from .._compat import warn_legacy

            warn_legacy("FilterBank(d, n_shards, ...)",
                        "dtype=..., n=..., placement='bank', shards=...")
        if n_shards < 1 or n_shards & (n_shards - 1):
            raise ValueError(f"n_shards must be a power of two, got {n_shards}")
        shard_bits = n_shards.bit_length() - 1
        if shard_bits >= d:
            raise ValueError(f"{n_shards} shards need more than d={d} bits")
        self.d = d
        self.n_shards = n_shards
        self.shard_bits = shard_bits
        self.d_local = d - shard_bits
        self.n_keys = n_keys
        self.bits_per_key = bits_per_key
        self.delta = delta
        self.seed = seed
        self.kdtype = key_dtype_for(d)
        if _layout is not None:           # in-place growth (core/dynamic.py)
            if _layout.d != self.d_local:
                raise ValueError(
                    f"_layout.d={_layout.d} != shard domain {self.d_local}")
            self.layout = _layout
        else:
            self.layout = basic_layout(self.d_local,
                                       max(n_keys // n_shards, 1),
                                       bits_per_key,
                                       delta=min(delta, self.d_local),
                                       seed=seed)
        self.filter = BloomRF(self.layout, _warn=False)
        # all shard rows probed at once: one fused gather (core/engine.py)
        self._stacked = stacked_probe(
            (self.layout,) * n_shards,
            tuple(s * self.layout.total_u32 for s in range(n_shards)))

    # -- key routing -----------------------------------------------------
    def _route(self, keys):
        """(local keys in the shard sub-domain, owning shard index)."""
        keys = jnp.asarray(keys, self.kdtype)
        if self.shard_bits == 0:  # shift by full key width is UB; shard 0 owns all
            return keys.astype(self.filter.kdtype), jnp.zeros(keys.shape,
                                                              jnp.uint32)
        shard = (keys >> self.d_local).astype(jnp.uint32)
        mask = (1 << self.d_local) - 1
        low = (keys & jnp.asarray(mask, self.kdtype)).astype(
            self.filter.kdtype)
        return low, shard

    # -- per-shard bodies (shared by vmap and shard_map paths) -----------
    def _insert_shard(self, state_row, low, owned):
        """Masked bulk insert: set positions only for owned keys."""
        f = self.filter
        pos = jax.vmap(f._positions_one)(low)                   # (B, P)
        vals = jnp.broadcast_to(owned[:, None], pos.shape).reshape(-1)
        return f.scatter_or(state_row, pos.reshape(-1), vals)

    def _point_shard(self, state_row, s_idx, low, shard):
        return self.filter.point(state_row, low) & (shard == s_idx)

    def _clip_to_shard(self, s_idx, lo_low, lo_shard, hi_low, hi_shard):
        """Clip a routed global range to shard ``s_idx``.

        Returns ``(nonempty, llo, lhi)``: whether the intersection with the
        shard's dyadic interval is non-empty, and the clipped local bounds.
        Single source of truth for the clip invariant — the tenant bank's
        meta-filter path and skip-rate accounting reuse it."""
        top = jnp.asarray((1 << self.d_local) - 1, self.filter.kdtype)
        nonempty = (s_idx >= lo_shard) & (s_idx <= hi_shard)
        llo = jnp.where(lo_shard == s_idx, lo_low, jnp.zeros_like(lo_low))
        lhi = jnp.where(hi_shard == s_idx, hi_low, top)
        return nonempty, llo, lhi

    def _range_shard(self, state_row, s_idx, lo_low, lo_shard, hi_low,
                     hi_shard):
        """Clip the global range to shard ``s_idx`` and probe the remainder."""
        nonempty, llo, lhi = self._clip_to_shard(s_idx, lo_low, lo_shard,
                                                 hi_low, hi_shard)
        return self.filter.range(state_row, llo, lhi) & nonempty

    # -- single-device reference API -------------------------------------
    def init_state(self) -> jax.Array:
        return jnp.zeros((self.n_shards, self.layout.total_u32), jnp.uint32)

    @functools.partial(jax.jit, static_argnums=0)
    def insert(self, state, keys):
        low, shard = self._route(keys)
        ids = jnp.arange(self.n_shards, dtype=jnp.uint32)
        return jax.vmap(lambda i, st: self._insert_shard(st, low, shard == i)
                        )(ids, state)

    def build(self, keys) -> jax.Array:
        return self.insert(self.init_state(), keys)

    @functools.partial(jax.jit, static_argnums=0)
    def point(self, state, qs):
        low, shard = self._route(qs)
        ids = jnp.arange(self.n_shards, dtype=jnp.uint32)
        hits = self._stacked.point_all(state.reshape(-1), low)  # (B, S)
        return (hits & (shard[:, None] == ids[None, :])).any(axis=1)

    @functools.partial(jax.jit, static_argnums=0)
    def range(self, state, lo, hi):
        lo_low, lo_shard = self._route(lo)
        hi_low, hi_shard = self._route(hi)
        ids = jnp.arange(self.n_shards, dtype=jnp.uint32)[:, None]  # (S, 1)
        nonempty, llo, lhi = self._clip_to_shard(ids, lo_low, lo_shard,
                                                 hi_low, hi_shard)  # (S, B)
        hits = self._stacked.range_all(state.reshape(-1), llo.T, lhi.T)
        return (hits & nonempty.T).any(axis=1)

    def size_bits(self) -> int:
        return self.n_shards * self.layout.total_bits


class ShardedFilterBank:
    """A :class:`FilterBank` with its shard rows laid out over a mesh axis.

    Each device owns ``n_shards / mesh.shape[axis]`` consecutive shard rows;
    probes run shard-local under ``shard_map`` and boolean verdicts are
    any-reduced with a psum all-gather.  Per-shard math is byte-for-byte the
    ``FilterBank`` body, so verdicts are bitwise identical to the
    single-device bank.
    """

    def __init__(self, bank: FilterBank, mesh: Mesh, axis: str = "data"):
        if axis not in mesh.shape:
            raise KeyError(f"mesh has no axis {axis!r}")
        n_dev = int(mesh.shape[axis])
        if bank.n_shards % n_dev:
            raise ValueError(f"{bank.n_shards} shards do not divide over "
                             f"{n_dev} devices on axis {axis!r}")
        self.bank = bank
        self.mesh = mesh
        self.axis = axis
        self.rows_per_dev = bank.n_shards // n_dev
        self.state_sharding = NamedSharding(mesh, PS(axis, None))
        spec_state = PS(axis, None)

        def local_ids():
            base = jax.lax.axis_index(axis) * self.rows_per_dev
            return (base + jnp.arange(self.rows_per_dev)).astype(jnp.uint32)

        def sm_insert(st, low, shard):
            ids = local_ids()
            return jax.vmap(lambda i, row: bank._insert_shard(
                row, low, shard == i))(ids, st)

        def sm_point(st, low, shard):
            ids = local_ids()
            hits = jax.vmap(lambda i, row: bank._point_shard(
                row, i, low, shard))(ids, st)
            local = hits.any(axis=0)
            return jax.lax.psum(local.astype(jnp.int32), axis) > 0

        def sm_range(st, lo_low, lo_shard, hi_low, hi_shard):
            ids = local_ids()
            hits = jax.vmap(lambda i, row: bank._range_shard(
                row, i, lo_low, lo_shard, hi_low, hi_shard))(ids, st)
            local = hits.any(axis=0)
            return jax.lax.psum(local.astype(jnp.int32), axis) > 0

        smap = functools.partial(shard_map, mesh=mesh, check_rep=False)
        self._insert = jax.jit(smap(
            sm_insert, in_specs=(spec_state, PS(), PS()),
            out_specs=spec_state))
        self._point = jax.jit(smap(
            sm_point, in_specs=(spec_state, PS(), PS()), out_specs=PS()))
        self._range = jax.jit(smap(
            sm_range, in_specs=(spec_state, PS(), PS(), PS(), PS()),
            out_specs=PS()))

    # -- public API (mirrors FilterBank) ---------------------------------
    def init_state(self) -> jax.Array:
        return jax.device_put(self.bank.init_state(), self.state_sharding)

    def shard_state(self, state) -> jax.Array:
        """Lay an existing (n_shards, total_u32) state out over the mesh."""
        return jax.device_put(state, self.state_sharding)

    def insert(self, state, keys):
        low, shard = self.bank._route(keys)
        return self._insert(state, low, shard)

    def build(self, keys) -> jax.Array:
        return self.insert(self.init_state(), keys)

    def point(self, state, qs):
        low, shard = self.bank._route(qs)
        return self._point(state, low, shard)

    def range(self, state, lo, hi):
        lo_low, lo_shard = self.bank._route(lo)
        hi_low, hi_shard = self.bank._route(hi)
        return self._range(state, lo_low, lo_shard, hi_low, hi_shard)
