"""Multi-tenant hierarchical bloomRF filter bank.

The production workload behind the ROADMAP north-star is many independent,
growing key sets (tenants: sessions, tables, SST levels ...) behind one
range-filter service.  This module stacks one range-partitioned bloomRF bank
per tenant along a new leading tenant dim:

    state: uint32[n_tenants, n_shards, total_u32]
    meta : uint32[n_tenants, n_shards, meta_total_u32]

Three layers compose on top of :class:`~repro.dist.filter_bank.FilterBank`:

* **Tenant stacking** — routing adds an explicit tenant id next to each key;
  ownership masks become ``(shard == s) & (tenant == t)``.  Probes against a
  tenant that never inserted hit an all-zero filter row, so tenants are
  perfectly isolated (no cross-tenant false positives from an empty tenant,
  and never any false negatives).

* **Bloofi-style meta-filter** (Crainiceanu & Lemire 2015, adapted to
  bloomRF's dyadic prefixes) — per (tenant, shard) a *coarse* bloomRF built
  over the dyadic prefixes ``key >> meta_level`` of the shard's resident
  keys (``core.dyadic_prefixes``).  A range probe clips ``[lo, hi]`` to the
  shard and asks the meta-filter about the prefix range
  ``[llo >> meta_level, lhi >> meta_level]``; a negative *proves* the
  clipped sub-range empty (prefix filters are false-negative-free), so the
  shard's main filter need not be touched.  Verdicts with meta enabled are
  ``main & meta`` — identical or strictly fewer false positives — and
  :meth:`TenantFilterBank.meta_skip_stats` reports how many shard-probes the
  meta level proved empty (the memory-access saving measured by
  ``benchmarks/dist_bench.py``).

* **Read replication** — :class:`ShardedTenantFilterBank` lays tenant rows
  over a ``data`` mesh axis (like ``ShardedFilterBank``) and optionally
  replicates the whole filter state ``r``-way over a ``replica`` axis.
  Probe batches are round-robined over the replicas (``PartitionSpec`` on
  the batch dim), so read throughput scales linearly with ``r``; inserts
  are computed per replica on its sub-batch and broadcast-combined with an
  all-gather + bitwise-OR over the replica axis (the OR is the psum of the
  bit domain), leaving every replica with the identical full state.

Both classes share the per-(tenant, shard) bodies, so the shard_map variant
is bitwise-identical to the vmapped single-device reference by construction
— asserted on >= 1e5 mixed point/range probes across an 8-device
(replica x data) mesh in ``tests/test_tenant_bank.py``.

Main-filter and meta-filter probes route through the multi-filter stacked
plan (``core.engine.StackedProbe``): the single-device reference probes
every (tenant, shard) row — and, for ``range(..., meta)``, every coarse
meta row too — with ONE fused gather over the flattened row stack, the
per-shard clipped bounds (and their dyadic-prefix images for the meta
rows) riding along as per-row bounds.  The per-(tenant, shard) bodies
survive for the ``shard_map`` variants, which stay bitwise-identical to
the stacked reference.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from ..core import (BloomRF, Generations, basic_layout, dyadic_prefixes,
                    promote_layout, promote_state, stacked_probe)
from .filter_bank import FilterBank

__all__ = ["TenantFilterBank", "ShardedTenantFilterBank", "AgingTenantBank"]

_NO_TENANT = 0xFFFFFFFF  # padding sentinel tenant id: owned by nobody


class TenantFilterBank:
    """n_tenants independent :class:`FilterBank`s stacked on a leading dim."""

    def __init__(self, d: int, n_tenants: int, n_shards: int,
                 n_keys_per_tenant: int, bits_per_key: float = 16.0,
                 delta: int = 6, meta_level: Optional[int] = None,
                 meta_bits_per_prefix: float = 8.0, seed: int = 0x0B100F11,
                 *, _warn: bool = True, _layout=None, _meta_layout=None):
        if _warn:
            from .._compat import warn_legacy

            warn_legacy("TenantFilterBank(d, n_tenants, ...)",
                        "dtype=..., n=..., placement='tenant', tenants=..., "
                        "shards=...")
        if n_tenants < 1:
            raise ValueError(f"need >= 1 tenant, got {n_tenants}")
        self.bank = FilterBank(d, n_shards, n_keys_per_tenant, bits_per_key,
                               delta=delta, seed=seed, _warn=False,
                               _layout=_layout)
        self.d = d
        self.n_tenants = n_tenants
        self.n_shards = n_shards
        self.n_keys_per_tenant = n_keys_per_tenant
        self.bits_per_key = bits_per_key
        self.delta = delta
        self.meta_bits_per_prefix = meta_bits_per_prefix
        self.seed = seed
        d_local = self.bank.d_local
        if meta_level is None:
            # coarse default: a ~12-bit prefix domain per shard.  On >32-bit
            # shard domains the prefix domain must stay in the same key
            # dtype as the main rows (the meta rows join the main rows'
            # stacked one-gather plan), so it widens to 33 bits there.
            target = 12 if d_local <= 32 else 33
            meta_level = d_local - min(target, max(d_local - 1, 1))
        if not (0 < meta_level < d_local):
            raise ValueError(
                f"meta_level must be in (0, {d_local}), got {meta_level}")
        self.meta_level = meta_level
        d_meta = d_local - meta_level
        from ..core.hashing import key_dtype_for

        if key_dtype_for(d_meta) != key_dtype_for(d_local):
            raise ValueError(
                f"meta_level={meta_level} puts the {d_meta}-bit prefix "
                f"domain in a different key dtype than the {d_local}-bit "
                f"shard domain; the stacked main+meta plan needs one dtype "
                f"(keep d_meta on the same side of 32 bits as d_local)")
        if _meta_layout is not None:      # in-place growth (core/dynamic.py)
            if _meta_layout.d != d_meta:
                raise ValueError(
                    f"_meta_layout.d={_meta_layout.d} != prefix domain "
                    f"{d_meta}")
            self.meta_layout = _meta_layout
        else:
            n_prefixes = max(min(n_keys_per_tenant // n_shards,
                                 1 << min(d_meta, 24)), 1)
            self.meta_layout = basic_layout(
                d_meta, n_prefixes, meta_bits_per_prefix,
                delta=min(delta, max(d_meta, 1)), seed=seed ^ 0xB100F1)
        self.meta = BloomRF(self.meta_layout, _warn=False)
        # stacked one-gather probes over all (tenant, shard) rows; the
        # meta variant appends the coarse rows to the same flat stack
        R = n_tenants * n_shards
        U = self.bank.layout.total_u32
        Um = self.meta_layout.total_u32
        bases_main = tuple(r * U for r in range(R))
        self._stacked = stacked_probe((self.bank.layout,) * R, bases_main)
        self._stacked_meta = stacked_probe(
            (self.bank.layout,) * R + (self.meta_layout,) * R,
            bases_main + tuple(R * U + r * Um for r in range(R)))
        self._row_tenant = jnp.asarray(
            np.repeat(np.arange(n_tenants), n_shards), jnp.uint32)

    # -- per-(tenant, shard) bodies (shared with the shard_map variant) ----
    def _meta_insert_shard(self, meta_row, plow, owned):
        """Masked bulk insert of dyadic prefixes into one meta-filter row."""
        m = self.meta
        pos = jax.vmap(m._positions_one)(plow)                  # (B, P)
        vals = jnp.broadcast_to(owned[:, None], pos.shape).reshape(-1)
        return m.scatter_or(meta_row, pos.reshape(-1), vals)

    def _meta_range_shard(self, meta_row, s_idx, lo_low, lo_shard, hi_low,
                          hi_shard):
        """Coarse verdict: could shard ``s_idx`` hold any key of the clipped
        range?  A False here *proves* the clipped sub-range empty."""
        bank = self.bank
        nonempty, llo, lhi = bank._clip_to_shard(s_idx, lo_low, lo_shard,
                                                 hi_low, hi_shard)
        plo = dyadic_prefixes(llo, self.meta_level, bank.d_local)
        phi = dyadic_prefixes(lhi, self.meta_level, bank.d_local)
        return self.meta.range(meta_row, plo, phi) & nonempty

    # -- layout ----------------------------------------------------------
    def init_state(self) -> jax.Array:
        return jnp.zeros((self.n_tenants, self.n_shards,
                          self.bank.layout.total_u32), jnp.uint32)

    def init_meta(self) -> jax.Array:
        return jnp.zeros((self.n_tenants, self.n_shards,
                          self.meta_layout.total_u32), jnp.uint32)

    def _ids(self):
        return (jnp.arange(self.n_tenants, dtype=jnp.uint32),
                jnp.arange(self.n_shards, dtype=jnp.uint32))

    # -- single-device reference API --------------------------------------
    @functools.partial(jax.jit, static_argnums=0)
    def insert(self, state, tenants, keys):
        tenants = jnp.asarray(tenants, jnp.uint32)
        low, shard = self.bank._route(keys)
        t_ids, s_ids = self._ids()

        def per_tenant(t, rows):
            return jax.vmap(lambda s, row: self.bank._insert_shard(
                row, low, (shard == s) & (tenants == t)))(s_ids, rows)

        return jax.vmap(per_tenant)(t_ids, state)

    @functools.partial(jax.jit, static_argnums=0)
    def insert_meta(self, meta, tenants, keys):
        tenants = jnp.asarray(tenants, jnp.uint32)
        low, shard = self.bank._route(keys)
        plow = dyadic_prefixes(low, self.meta_level, self.bank.d_local)
        t_ids, s_ids = self._ids()

        def per_tenant(t, rows):
            return jax.vmap(lambda s, row: self._meta_insert_shard(
                row, plow, (shard == s) & (tenants == t)))(s_ids, rows)

        return jax.vmap(per_tenant)(t_ids, meta)

    def build(self, tenants, keys) -> Tuple[jax.Array, jax.Array]:
        return (self.insert(self.init_state(), tenants, keys),
                self.insert_meta(self.init_meta(), tenants, keys))

    def _tile_rows(self, x):
        """(S, B) per-shard values -> (B, T*S) per-row values (row t*S+s
        carries shard s), matching the stacked probes' row order."""
        return jnp.tile(x.T, (1, self.n_tenants))

    @functools.partial(jax.jit, static_argnums=0)
    def point(self, state, tenants, qs):
        tenants = jnp.asarray(tenants, jnp.uint32)
        low, shard = self.bank._route(qs)
        s_row = jnp.tile(jnp.arange(self.n_shards, dtype=jnp.uint32),
                         self.n_tenants)
        own = ((shard[:, None] == s_row[None, :]) &
               (tenants[:, None] == self._row_tenant[None, :]))
        hits = self._stacked.point_all(state.reshape(-1), low)  # (B, T*S)
        return (hits & own).any(axis=1)

    @functools.partial(jax.jit, static_argnums=0)
    def range(self, state, tenants, lo, hi, meta=None):
        tenants = jnp.asarray(tenants, jnp.uint32)
        lo_low, lo_shard = self.bank._route(lo)
        hi_low, hi_shard = self.bank._route(hi)
        s_ids = jnp.arange(self.n_shards, dtype=jnp.uint32)[:, None]
        nonempty, llo, lhi = self.bank._clip_to_shard(
            s_ids, lo_low, lo_shard, hi_low, hi_shard)          # (S, B)
        own = (self._tile_rows(nonempty) &
               (tenants[:, None] == self._row_tenant[None, :]))
        if meta is None:
            hits = self._stacked.range_all(
                state.reshape(-1), self._tile_rows(llo), self._tile_rows(lhi))
            return (hits & own).any(axis=1)
        # meta rows join the same stack: main & meta in ONE fused gather
        plo = dyadic_prefixes(llo, self.meta_level, self.bank.d_local)
        phi = dyadic_prefixes(lhi, self.meta_level, self.bank.d_local)
        flat = jnp.concatenate([state.reshape(-1), meta.reshape(-1)])
        lo_all = jnp.concatenate(
            [self._tile_rows(llo), self._tile_rows(plo)], axis=1)
        hi_all = jnp.concatenate(
            [self._tile_rows(lhi), self._tile_rows(phi)], axis=1)
        hits = self._stacked_meta.range_all(flat, lo_all, hi_all)
        R = self.n_tenants * self.n_shards
        return (hits[:, :R] & hits[:, R:] & own).any(axis=1)

    @functools.partial(jax.jit, static_argnums=0)
    def meta_skip_stats(self, meta, tenants, lo, hi):
        """(candidate shard-probes, meta-skipped shard-probes) over a range
        batch.  A candidate is a (probe, shard) pair whose clipped interval
        is non-empty; it is skipped when the meta-filter proves it empty —
        each skip saves the shard's main-filter word accesses."""
        tenants = jnp.asarray(tenants, jnp.uint32)
        lo_low, lo_shard = self.bank._route(lo)
        hi_low, hi_shard = self.bank._route(hi)
        t_ids, s_ids = self._ids()

        def per_tenant(t, mrows):
            def per_shard(s, mrow):
                nonempty, _, _ = self.bank._clip_to_shard(
                    s, lo_low, lo_shard, hi_low, hi_shard)
                hit = self._meta_range_shard(mrow, s, lo_low, lo_shard,
                                             hi_low, hi_shard)
                cand = nonempty & (tenants == t)
                return cand, cand & ~hit

            return jax.vmap(per_shard)(s_ids, mrows)

        cand, skip = jax.vmap(per_tenant)(t_ids, meta)
        return cand.sum(), skip.sum()

    def record_meta_skips(self, meta, tenants, lo, hi) -> None:
        """Accumulate :meth:`meta_skip_stats` into the obs registry.

        Host helper: the jitted stats kernel is untouched; the device
        scalars it returns are handed to the ``tenant_bank/*`` counters
        without a host sync (they settle at ``snapshot()``)."""
        from ..obs import metrics as _obs_metrics

        cand, skip = self.meta_skip_stats(meta, tenants, lo, hi)
        reg = _obs_metrics.registry()
        reg.counter("tenant_bank/meta_candidates").add(cand)
        reg.counter("tenant_bank/meta_skipped").add(skip)

    def size_bits(self) -> int:
        return self.n_tenants * self.n_shards * (
            self.bank.layout.total_bits + self.meta_layout.total_bits)

    # -- in-place capacity growth (core/dynamic.py) ------------------------
    def grown(self, factor: int = 4) -> "TenantFilterBank":
        """A bank sized for ``factor`` more keys per tenant whose layouts
        are the segment-tiled promotions of this bank's — existing state
        carries over via :meth:`promote` with no key re-hashing."""
        return TenantFilterBank(
            self.d, self.n_tenants, self.n_shards,
            n_keys_per_tenant=self.n_keys_per_tenant * factor,
            bits_per_key=self.bits_per_key, delta=self.delta,
            meta_level=self.meta_level,
            meta_bits_per_prefix=self.meta_bits_per_prefix, seed=self.seed,
            _warn=False,
            _layout=promote_layout(self.bank.layout, factor),
            _meta_layout=promote_layout(self.meta_layout, factor))

    def advise_promotion(self, workload, n_current: Optional[int] = None,
                         n_target: Optional[int] = None,
                         factors: Tuple[int, ...] = (2, 4, 8)):
        """Workload-advised promotion factor (per-tenant retune, §16).

        Prices each candidate factor ``f``'s promoted layout under the
        sampled workload (``repro.tune.cost``).  Promotion tiles set bits
        ``f`` times, so a promoted segment's density equals a fresh build
        over ``f * n_current`` keys; filling the headroom to ``n_target``
        adds the difference on top — that effective key count is what the
        §7 model is scored at.  The workload's range lengths are rescaled
        to the shard-local domain (a scan's per-shard slice is
        ~``len / n_shards``).  The smallest factor with enough headroom
        wins unless a larger one at least halves the predicted mixed FPR
        (memory is ``f``-proportional; doubling it must buy a real win).

        Returns ``(factor, {factor: CostReport})``.
        """
        from ..core.dynamic import promote_layout
        from ..tune.cost import score_layout

        n_current = self.n_keys_per_tenant if n_current is None \
            else int(n_current)
        n_target = 2 * n_current if n_target is None else int(n_target)
        if n_current < 1 or n_target < n_current:
            raise ValueError(
                f"need 1 <= n_current <= n_target, got "
                f"n_current={n_current} n_target={n_target}")
        wl = workload.rescaled(
            -int(round(math.log2(self.n_shards)))) if self.n_shards > 1 \
            else workload
        reports, best = {}, None
        for f in sorted(set(int(f) for f in factors)):
            if f < 2 or self.n_keys_per_tenant * f < n_target:
                continue        # not enough headroom for the target
            try:
                lay = promote_layout(self.bank.layout, f)
            except ValueError:
                continue
            n_eff = f * n_current + (n_target - n_current)
            reports[f] = score_layout(lay, n_eff, wl)
            if best is None or \
                    reports[f].fpr_mix < 0.5 * reports[best].fpr_mix:
                best = f
        if best is None:
            raise ValueError(
                f"no promotion factor in {factors} reaches "
                f"n_target={n_target} from {self.n_keys_per_tenant} "
                f"keys/tenant")
        return best, reports

    def promote(self, state, meta, factor: int = 4
                ) -> Tuple["TenantFilterBank", jax.Array, jax.Array]:
        """Grow in place: ``(new_bank, new_state, new_meta)`` with every
        inserted key still probing positive under the new (``factor``-times
        larger) layouts — zero false negatives, no access to the original
        keys (the promotion theorem in ``core/dynamic.py``)."""
        nb = self.grown(factor)
        return (nb,
                promote_state(state, self.bank.layout, nb.bank.layout),
                promote_state(meta, self.meta_layout, nb.meta_layout))


class AgingTenantBank:
    """TTL wrapper over :class:`TenantFilterBank`: sweep-free expiry via
    generation lanes (``core.Generations``).

    Inserts land in the current generation's ``(state, meta)`` pair; every
    probe reads the OR-collapse of all generations (sound because bloomRF
    state is union-closed).  :meth:`advance` closes the TTL window — keys
    whose last insert fell out of the retained window stop costing false
    positives, with no per-key sweep and no FPR drift floor.  Reporting a
    retired key absent is the TTL contract, not a false negative; hot keys
    stay live by being re-inserted each window.
    """

    def __init__(self, bank: TenantFilterBank, n_generations: int = 4):
        self.bank = bank
        self.gens = Generations(
            lambda: (bank.init_state(), bank.init_meta()), n_generations)

    @property
    def n_generations(self) -> int:
        return self.gens.n_generations

    def insert(self, tenants, keys) -> None:
        self.gens.insert(
            lambda sm, t, k: (self.bank.insert(sm[0], t, k),
                              self.bank.insert_meta(sm[1], t, k)),
            tenants, keys)

    def point(self, tenants, qs):
        state, _ = self.gens.collapsed
        return self.bank.point(state, tenants, qs)

    def range(self, tenants, lo, hi, use_meta: bool = True):
        state, meta = self.gens.collapsed
        return self.bank.range(state, tenants, lo, hi,
                               meta if use_meta else None)

    def advance(self) -> None:
        """Retire the oldest generation's contributions."""
        self.gens.advance()

    def promoted(self, factor: int = 4) -> "AgingTenantBank":
        """Grow every generation in place to ``factor`` larger layouts."""
        nb = self.bank.grown(factor)
        ol, nl = self.bank.bank.layout, nb.bank.layout
        oml, nml = self.bank.meta_layout, nb.meta_layout
        out = AgingTenantBank.__new__(AgingTenantBank)
        out.bank = nb
        out.gens = self.gens.map(
            lambda sm: (promote_state(sm[0], ol, nl),
                        promote_state(sm[1], oml, nml)),
            zero_fn=lambda: (nb.init_state(), nb.init_meta()))
        return out

    def size_bits(self) -> int:
        return self.bank.size_bits() * self.n_generations


class ShardedTenantFilterBank:
    """A :class:`TenantFilterBank` laid out over a device mesh.

    Tenant rows are sharded over ``data_axis`` (each device owns
    ``n_tenants / mesh.shape[data_axis]`` consecutive tenants); when
    ``replica_axis`` is given, the state is additionally replicated over it
    and probe batches are split round-robin across replicas for linear read
    scaling.  Per-(tenant, shard) math is byte-for-byte the
    ``TenantFilterBank`` body, so verdicts are bitwise identical to the
    single-device bank.
    """

    def __init__(self, tbank: TenantFilterBank, mesh: Mesh,
                 data_axis: str = "data",
                 replica_axis: Optional[str] = None):
        if data_axis not in mesh.shape:
            raise KeyError(f"mesh has no axis {data_axis!r}")
        if replica_axis is not None and replica_axis not in mesh.shape:
            raise KeyError(f"mesh has no axis {replica_axis!r}")
        n_data = int(mesh.shape[data_axis])
        if tbank.n_tenants % n_data:
            raise ValueError(f"{tbank.n_tenants} tenants do not divide over "
                             f"{n_data} devices on axis {data_axis!r}")
        self.tbank = tbank
        self.mesh = mesh
        self.data_axis = data_axis
        self.replica_axis = replica_axis
        self.n_replicas = int(mesh.shape[replica_axis]) if replica_axis else 1
        self.tenants_per_dev = tbank.n_tenants // n_data
        self.state_sharding = NamedSharding(mesh, PS(data_axis, None, None))

        bank = tbank.bank
        tpd = self.tenants_per_dev
        r = self.n_replicas
        s_ids = jnp.arange(tbank.n_shards, dtype=jnp.uint32)
        spec_state = PS(data_axis, None, None)
        bspec = PS(replica_axis) if replica_axis is not None else PS()

        def local_tids():
            base = jax.lax.axis_index(data_axis) * tpd
            return (base + jnp.arange(tpd)).astype(jnp.uint32)

        def replica_or(new):
            """Broadcast-combine per-replica insert results: all-gather over
            the replica axis and bitwise-OR (the psum of the bit domain)."""
            if replica_axis is None:
                return new
            g = jax.lax.all_gather(new, replica_axis)
            out = g[0]
            for i in range(1, r):
                out = out | g[i]
            return out

        def sm_insert(st, low, shard, tenants):
            t_ids = local_tids()

            def per_tenant(t, rows):
                return jax.vmap(lambda s, row: bank._insert_shard(
                    row, low, (shard == s) & (tenants == t)))(s_ids, rows)

            return replica_or(jax.vmap(per_tenant)(t_ids, st))

        def sm_insert_meta(mst, plow, shard, tenants):
            t_ids = local_tids()

            def per_tenant(t, rows):
                return jax.vmap(lambda s, row: tbank._meta_insert_shard(
                    row, plow, (shard == s) & (tenants == t)))(s_ids, rows)

            return replica_or(jax.vmap(per_tenant)(t_ids, mst))

        def sm_point(st, low, shard, tenants):
            t_ids = local_tids()

            def per_tenant(t, rows):
                hits = jax.vmap(lambda s, row: bank._point_shard(
                    row, s, low, shard))(s_ids, rows)
                return hits & (tenants == t)

            local = jax.vmap(per_tenant)(t_ids, st).any(axis=(0, 1))
            return jax.lax.psum(local.astype(jnp.int32), data_axis) > 0

        def sm_range(st, lo_low, lo_shard, hi_low, hi_shard, tenants):
            t_ids = local_tids()

            def per_tenant(t, rows):
                hits = jax.vmap(lambda s, row: bank._range_shard(
                    row, s, lo_low, lo_shard, hi_low, hi_shard))(s_ids, rows)
                return hits & (tenants == t)

            local = jax.vmap(per_tenant)(t_ids, st).any(axis=(0, 1))
            return jax.lax.psum(local.astype(jnp.int32), data_axis) > 0

        def sm_range_meta(st, mst, lo_low, lo_shard, hi_low, hi_shard,
                          tenants):
            t_ids = local_tids()

            def per_tenant(t, rows, mrows):
                hits = jax.vmap(lambda s, row, mrow: bank._range_shard(
                    row, s, lo_low, lo_shard, hi_low, hi_shard)
                    & tbank._meta_range_shard(
                        mrow, s, lo_low, lo_shard, hi_low, hi_shard)
                    )(s_ids, rows, mrows)
                return hits & (tenants == t)

            local = jax.vmap(per_tenant)(t_ids, st, mst).any(axis=(0, 1))
            return jax.lax.psum(local.astype(jnp.int32), data_axis) > 0

        smap = functools.partial(shard_map, mesh=mesh, check_rep=False)
        self._insert = jax.jit(smap(
            sm_insert, in_specs=(spec_state, bspec, bspec, bspec),
            out_specs=spec_state))
        self._insert_meta = jax.jit(smap(
            sm_insert_meta, in_specs=(spec_state, bspec, bspec, bspec),
            out_specs=spec_state))
        self._point = jax.jit(smap(
            sm_point, in_specs=(spec_state, bspec, bspec, bspec),
            out_specs=bspec))
        self._range = jax.jit(smap(
            sm_range, in_specs=(spec_state,) + (bspec,) * 5,
            out_specs=bspec))
        self._range_meta = jax.jit(smap(
            sm_range_meta, in_specs=(spec_state, spec_state) + (bspec,) * 5,
            out_specs=bspec))

    # -- state placement --------------------------------------------------
    def init_state(self) -> jax.Array:
        return jax.device_put(self.tbank.init_state(), self.state_sharding)

    def init_meta(self) -> jax.Array:
        return jax.device_put(self.tbank.init_meta(), self.state_sharding)

    def shard_state(self, state) -> jax.Array:
        return jax.device_put(state, self.state_sharding)

    shard_meta = shard_state

    # -- batch round-robin over replicas ----------------------------------
    def _pad(self, tenants, arrs):
        """Pad the batch to a multiple of the replica count.  Padded slots
        carry the no-tenant sentinel, so they match no ownership mask and
        are no-ops for insert / all-False for probes."""
        n = int(tenants.shape[0])
        pad = (-n) % self.n_replicas
        if pad:
            tenants = jnp.concatenate(
                [tenants, jnp.full((pad,), _NO_TENANT, jnp.uint32)])
            arrs = [jnp.concatenate([a, jnp.zeros((pad,), a.dtype)])
                    for a in arrs]
        return tenants, arrs, n

    # -- public API (mirrors TenantFilterBank) -----------------------------
    def insert(self, state, tenants, keys):
        tenants = jnp.asarray(tenants, jnp.uint32)
        low, shard = self.tbank.bank._route(keys)
        tenants, (low, shard), _ = self._pad(tenants, [low, shard])
        return self._insert(state, low, shard, tenants)

    def insert_meta(self, meta, tenants, keys):
        tenants = jnp.asarray(tenants, jnp.uint32)
        low, shard = self.tbank.bank._route(keys)
        plow = dyadic_prefixes(low, self.tbank.meta_level,
                               self.tbank.bank.d_local)
        tenants, (plow, shard), _ = self._pad(tenants, [plow, shard])
        return self._insert_meta(meta, plow, shard, tenants)

    def build(self, tenants, keys) -> Tuple[jax.Array, jax.Array]:
        return (self.insert(self.init_state(), tenants, keys),
                self.insert_meta(self.init_meta(), tenants, keys))

    def point(self, state, tenants, qs):
        tenants = jnp.asarray(tenants, jnp.uint32)
        low, shard = self.tbank.bank._route(qs)
        tenants, (low, shard), n = self._pad(tenants, [low, shard])
        return self._point(state, low, shard, tenants)[:n]

    def range(self, state, tenants, lo, hi, meta=None):
        tenants = jnp.asarray(tenants, jnp.uint32)
        lo_low, lo_shard = self.tbank.bank._route(lo)
        hi_low, hi_shard = self.tbank.bank._route(hi)
        tenants, routed, n = self._pad(
            tenants, [lo_low, lo_shard, hi_low, hi_shard])
        if meta is None:
            return self._range(state, *routed, tenants)[:n]
        return self._range_meta(state, meta, *routed, tenants)[:n]
