"""Microbatched pipeline parallelism over one mesh axis.

``pipeline_apply`` runs a GPipe-style schedule under ``shard_map``: stage
parameters are sharded over ``axis`` (leading dim = number of stages), the
input batch is split into microbatches, and activations flow stage-to-stage
through ``lax.ppermute`` ring shifts.  The schedule is unrolled at trace time
(n_microbatches + n_stages - 1 ticks), so the compiled program is a straight
line of compute/permute pairs XLA can overlap.

The stage function must be shape-preserving: ``stage_fn(stage_params, x) ->
y`` with ``y.shape == x.shape`` (the residual-stream contract every model in
the zoo satisfies).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as PS

__all__ = ["pipeline_apply"]


def pipeline_apply(stage_fn, params, x, mesh, axis: str,
                   n_microbatches: int):
    """Apply ``n_stages`` chained stages to ``x`` with pipeline parallelism.

    Args:
      stage_fn: ``(stage_params, microbatch) -> microbatch`` (shape-preserving).
      params: pytree whose leaves all have leading dim ``mesh.shape[axis]``;
        leaf ``[s]`` holds stage ``s``'s parameters.
      x: batched input; ``x.shape[0]`` must divide by ``n_microbatches``.
      mesh: the device mesh; ``axis``: the pipeline axis name.
    Returns:
      The sequential composition ``stage_{S-1}(... stage_0(x))``, replicated.
    """
    if axis not in mesh.shape:
        raise KeyError(f"mesh has no axis {axis!r}; axes: {mesh.axis_names}")
    n_stages = int(mesh.shape[axis])
    B = x.shape[0]
    if B % n_microbatches:
        raise ValueError(f"batch {B} not divisible by {n_microbatches} "
                         "microbatches")
    for leaf in jax.tree.leaves(params):
        if leaf.shape[0] != n_stages:
            raise ValueError(
                f"param leading dim {leaf.shape[0]} != n_stages {n_stages}")
    mb_shape = (n_microbatches, B // n_microbatches) + x.shape[1:]
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def run(p, xr):
        p = jax.tree.map(lambda a: a[0], p)   # drop the sharded stage dim
        idx = jax.lax.axis_index(axis)
        is_first = idx == 0
        is_last = idx == n_stages - 1
        mbs = xr.reshape(mb_shape)
        out_buf = jnp.zeros(mb_shape, xr.dtype)
        carry = jnp.zeros(mb_shape[1:], xr.dtype)
        for t in range(n_microbatches + n_stages - 1):
            feed = mbs[min(t, n_microbatches - 1)]
            inp = jnp.where(is_first, feed, carry)
            out = stage_fn(p, inp).astype(xr.dtype)
            o = t - (n_stages - 1)
            if o >= 0:  # drain: the last stage owns microbatch ``o`` now
                out_buf = jnp.where(is_last, out_buf.at[o].set(out), out_buf)
            carry = jax.lax.ppermute(out, axis, perm)
        # only the last stage holds real outputs; mask + psum replicates them
        res = jnp.where(is_last, out_buf, jnp.zeros_like(out_buf))
        return jax.lax.psum(res, axis)

    fn = shard_map(run, mesh=mesh, in_specs=(PS(axis), PS()), out_specs=PS(),
                   check_rep=False)
    return fn(params, x).reshape(x.shape)
