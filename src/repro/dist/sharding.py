"""NamedSharding trees for every registered model config.

``make_shardings(model, mesh, shape)`` is the single entry point used by the
dry-run, the profiler and the launchers: it turns a model's *logical* pspecs
(``param_pspecs`` / ``batch_pspecs`` / ``cache_pspecs``) into physical
``NamedSharding``s on ``mesh``, dropping any axis that does not divide its
dim exactly (jit argument shardings must divide; uneven activation shardings
are handled separately via ``models/act.py`` constraints, which GSPMD pads).

Mesh conventions (launch/mesh.py): batch/FSDP over ("pod","data"); TP/EP
over "model"; "pod" alternatively drives the pipeline (dist/pipeline.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

__all__ = ["Shardings", "batch_axes_for", "make_shardings",
           "mesh_axis_sizes"]


def mesh_axis_sizes(mesh: Mesh) -> dict:
    """{axis name: size} of a mesh."""
    return dict(mesh.shape)


def batch_axes_for(mesh: Mesh):
    """Physical axes backing the logical batch/FSDP dim, as one PS entry."""
    if "pod" in mesh.axis_names:
        return ("pod", "data")
    return ("data",)


def _axes_size(mesh_shape: dict, axes) -> int:
    """Total device count of a PartitionSpec entry (None/str/tuple).
    0 when any named axis is absent from the mesh (-> replicate)."""
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    total = 1
    for a in axes:
        if a not in mesh_shape:
            return 0
        total *= mesh_shape[a]
    return total


def _sanitize(mesh: Mesh, specs, sds_tree):
    """Replicate every spec entry whose axes do not divide the dim exactly."""
    msz = mesh_axis_sizes(mesh)
    def is_ps(x):
        return isinstance(x, PS)

    def fix(ps: PS, s) -> PS:
        entries = tuple(ps) + (None,) * (len(s.shape) - len(tuple(ps)))
        out = []
        for dim, entry in zip(s.shape, entries):
            sz = _axes_size(msz, entry)
            out.append(entry if entry is not None and sz > 0 and
                       dim % sz == 0 else None)
        return PS(*out)

    spec_leaves, treedef = jax.tree.flatten(specs, is_leaf=is_ps)
    sds_leaves = jax.tree.leaves(sds_tree)
    assert len(spec_leaves) == len(sds_leaves), (specs, sds_tree)
    return jax.tree.unflatten(
        treedef, [fix(p, s) for p, s in zip(spec_leaves, sds_leaves)])


def _drop_missing_axes(mesh: Mesh, specs):
    """Replicate spec entries naming axes this mesh does not have (the
    logical rules in models/params.py mention "model"/"pod" unconditionally)."""
    names = set(mesh.axis_names)

    def fix(ps: PS) -> PS:
        out = []
        for entry in tuple(ps):
            axes = () if entry is None else (
                (entry,) if isinstance(entry, str) else tuple(entry))
            out.append(entry if all(a in names for a in axes) else None)
        return PS(*out)

    return jax.tree.map(fix, specs, is_leaf=lambda x: isinstance(x, PS))


def _named(mesh: Mesh, specs):
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps), specs,
                        is_leaf=lambda x: isinstance(x, PS))


@dataclasses.dataclass(frozen=True)
class Shardings:
    """Physical shardings for one (model, mesh, shape) cell."""
    params: Any          # NamedSharding tree matching model.table()
    batch: Any           # NamedSharding tree matching model.input_specs(shape)
    cache: Any           # NamedSharding tree matching model.cache_specs(shape)
    out_scalar: Any      # replicated scalar (losses / metrics)
    mesh: Mesh


def make_shardings(model, mesh: Mesh, shape) -> Shardings:
    msz = mesh_axis_sizes(mesh)
    ba = batch_axes_for(mesh)

    param_specs = _drop_missing_axes(mesh,
                                     model.param_pspecs(msz, fsdp_axes=ba))

    batch_specs = _sanitize(mesh, model.batch_pspecs(shape, ba),
                            model.input_specs(shape))

    # KV/state head dims shard over "model" when divisible; _sanitize drops
    # the axis per-leaf otherwise (e.g. whisper's 8 heads on a 16-way axis).
    cache_specs = _sanitize(mesh, model.cache_pspecs(shape, ba, "model"),
                            model.cache_specs(shape))

    return Shardings(
        params=_named(mesh, param_specs),
        batch=_named(mesh, batch_specs),
        cache=_named(mesh, cache_specs),
        out_scalar=NamedSharding(mesh, PS()),
        mesh=mesh,
    )
