"""Compression for the training substrate and filter-state snapshots.

Two codecs live here:

* **int8 error-feedback** (``ef_init`` / ``ef_compress``) — per-leaf symmetric
  int8 quantization of gradients with an error-feedback accumulator (Seide et
  al. / Karimireddy et al.): the quantization residual is carried into the
  next step, so compressed SGD retains the uncompressed fixed points.  Pure
  jnp, jit-safe, used by ``train/train_loop.py`` when
  ``TrainConfig.grad_compression`` is set.

* **Elias-Fano** (``elias_fano_encode`` / ``elias_fano_decode``) — the classic quasi-succinct
  encoding of a sorted integer list over a universe ``u``: low ``l =
  floor(log2(u/n))`` bits stored verbatim, high bits unary-coded in a bitmap
  of ``n + (u >> l)`` bits — ``n * (2 + log2(u/n))`` bits total.  Host-side
  numpy; used for compact bloomRF state snapshots (``pack_filter_state``:
  the set-bit positions of a filter are exactly a sorted posting list over
  ``total_bits``) and for shipping posting lists between shards.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ef_init", "ef_compress", "elias_fano_encode", "elias_fano_decode",
           "elias_fano_size_bits", "pack_filter_state", "unpack_filter_state"]


# ---------------------------------------------------------------------------
# int8 error-feedback gradient compression
# ---------------------------------------------------------------------------

def ef_init(params):
    """Zero error-feedback accumulators, one f32 leaf per parameter leaf."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize_leaf(g, e):
    t = g.astype(jnp.float32) + e
    scale = jnp.maximum(jnp.max(jnp.abs(t)) / 127.0, 1e-30)
    q = jnp.clip(jnp.round(t / scale), -127.0, 127.0).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, t - deq


def ef_compress(grads, error):
    """Quantize ``grads + error`` to int8 per leaf; return (dequantized
    gradients, new error).  8.25 bits/value on the wire (int8 + one f32
    scale per leaf); the dequantized form keeps the train step's math dtype-
    stable."""
    g_leaves, treedef = jax.tree.flatten(grads)
    e_leaves = jax.tree.leaves(error)
    assert len(g_leaves) == len(e_leaves), "grads/error tree mismatch"
    outs = [_quantize_leaf(g, e) for g, e in zip(g_leaves, e_leaves)]
    return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
            jax.tree.unflatten(treedef, [o[1] for o in outs]))


# ---------------------------------------------------------------------------
# Elias-Fano posting lists
# ---------------------------------------------------------------------------

def _low_bits(u: int, n: int) -> int:
    if n <= 0 or u <= n:
        return 0
    return max(int(math.floor(math.log2(u / n))), 0)


def elias_fano_encode(values, universe: Optional[int] = None) -> dict:
    """Encode a sorted (non-decreasing) uint64 list over ``[0, universe)``."""
    v = np.asarray(values, np.uint64)
    if v.ndim != 1:
        raise ValueError("elias_fano_encode takes a 1-D sorted list")
    n = len(v)
    if n and (v[1:] < v[:-1]).any():
        raise ValueError("elias_fano_encode requires a sorted list")
    u = int(universe) if universe is not None else (int(v[-1]) + 1 if n else 1)
    if n and int(v[-1]) >= u:
        raise ValueError(f"value {int(v[-1])} outside universe {u}")
    if n == 0:  # decode never reads the buffers; don't size them by u
        return {"n": 0, "u": u, "l": 0, "low": np.zeros(0, np.uint8),
                "high": np.zeros(0, np.uint8)}
    lbits = _low_bits(u, n)
    # low halves: n * lbits bits, packed little-endian-by-value
    if lbits:
        shifts = np.arange(lbits, dtype=np.uint64)
        low_bits = ((v[:, None] >> shifts[None, :]) & np.uint64(1)
                    ).astype(np.uint8).reshape(-1)
        low = np.packbits(low_bits)
    else:
        low = np.zeros(0, np.uint8)
    # high halves: unary gaps -> bit i+high[i] set, i = 0..n-1
    hi_len = n + (u >> lbits) + 1
    hi_bits = np.zeros(hi_len, np.uint8)
    if n:
        hi_bits[(v >> np.uint64(lbits)).astype(np.int64) + np.arange(n)] = 1
    return {"n": n, "u": u, "l": lbits, "low": low,
            "high": np.packbits(hi_bits)}


def elias_fano_decode(enc: dict) -> np.ndarray:
    """Inverse of :func:`elias_fano_encode`; returns the sorted uint64 list."""
    n, u, lbits = enc["n"], enc["u"], enc["l"]
    if n == 0:
        return np.zeros(0, np.uint64)
    hi_bits = np.unpackbits(enc["high"])
    ones = np.flatnonzero(hi_bits)[:n]
    high = (ones - np.arange(n)).astype(np.uint64)
    if lbits:
        low_bits = np.unpackbits(enc["low"])[: n * lbits].reshape(n, lbits)
        shifts = np.arange(lbits, dtype=np.uint64)
        low = (low_bits.astype(np.uint64) << shifts[None, :]).sum(
            axis=1, dtype=np.uint64)
    else:
        low = np.zeros(n, np.uint64)
    return (high << np.uint64(lbits)) | low


def elias_fano_size_bits(enc: dict) -> int:
    """Encoded size (payload bits, excluding the 3-int header)."""
    return 8 * (len(enc["low"]) + len(enc["high"]))


# ---------------------------------------------------------------------------
# filter-state snapshots
# ---------------------------------------------------------------------------

def pack_filter_state(state_u32) -> dict:
    """EF-encode the set-bit positions of a packed uint32 filter state.

    bloomRF states are sparse early in their fill curve (bits_per_key * n set
    bits out of total_bits), so the posting list beats the raw bitmap until
    the filter approaches half full."""
    lanes = np.asarray(state_u32, np.uint32)
    if lanes.ndim != 1:
        raise ValueError("expected a flat uint32 lane vector")
    shifts = np.arange(32, dtype=np.uint32)
    bits = ((lanes[:, None] >> shifts[None, :]) & np.uint32(1)).astype(bool)
    positions = np.flatnonzero(bits.reshape(-1)).astype(np.uint64)
    return elias_fano_encode(positions, universe=32 * len(lanes))


def unpack_filter_state(enc: dict, total_u32: int) -> np.ndarray:
    """Inverse of :func:`pack_filter_state` -> uint32[total_u32]."""
    pos = elias_fano_decode(enc)
    buf = np.zeros(total_u32, np.uint32)
    np.bitwise_or.at(buf, (pos >> np.uint64(5)).astype(np.int64),
                     np.uint32(1) << (pos & np.uint64(31)).astype(np.uint32))
    return buf
