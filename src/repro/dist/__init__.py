"""Distribution layer: sharding specs, pipeline parallelism, gradient/state
compression and the sharded bloomRF filter bank.

Modules:
  sharding     — NamedSharding trees for params/batch/cache of every model
  pipeline     — microbatched pipeline parallelism over a mesh axis
  compression  — int8 error-feedback gradient compression + Elias-Fano
                 encoding of sorted posting lists / filter-state snapshots
  filter_bank  — BloomRF filter bank range-partitioned across a device mesh
  tenant_bank  — multi-tenant bank stack with Bloofi-style meta-filters and
                 r-way read replication over a replica mesh axis
"""
from .compression import (ef_compress, ef_init, elias_fano_decode,
                          elias_fano_encode, elias_fano_size_bits,
                          pack_filter_state, unpack_filter_state)
from .filter_bank import FilterBank, ShardedFilterBank
from .pipeline import pipeline_apply
from .sharding import Shardings, batch_axes_for, make_shardings, mesh_axis_sizes
from .tenant_bank import (AgingTenantBank, ShardedTenantFilterBank,
                          TenantFilterBank)

__all__ = [
    "Shardings", "batch_axes_for", "make_shardings", "mesh_axis_sizes",
    "pipeline_apply",
    "ef_init", "ef_compress", "elias_fano_encode", "elias_fano_decode",
    "elias_fano_size_bits",
    "pack_filter_state", "unpack_filter_state",
    "FilterBank", "ShardedFilterBank",
    "TenantFilterBank", "ShardedTenantFilterBank", "AgingTenantBank",
]
