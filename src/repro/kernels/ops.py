"""Jit'd dispatch wrappers over the Pallas kernels with XLA fallbacks.

``interpret`` defaults to True off-TPU (kernel bodies execute in Python on
CPU for validation); on a real TPU backend pass ``interpret=False``.

The resident/partitioned dispatch threshold is a config knob (DESIGN.md
§3): filters of up to ``vmem_budget_u32`` lanes take the VMEM-resident
kernels, larger ones the block-partitioned kernels.  The default comes
from the ``BLOOMRF_VMEM_BUDGET_U32`` environment variable (validated every
time it is read: non-integer or <= 0 raises a ``ValueError`` naming the
variable) and falls back to 2^22 lanes = 16 MiB — a comfortable resident
footprint on a v5e core.  Deployments with other VMEM sizes, or tests
that want to force the partitioned path, set the env var or pass
``vmem_budget_u32`` explicitly.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from ..core import BloomRF, FilterLayout
from ..core.engine import stacked_probe
from ..obs import metrics as _obs_metrics
from ..obs import trace as _obs_trace
from . import insert as _insert
from . import probe as _probe
from . import rangeprobe as _rangeprobe
from .ref import check_kernel_layout


def _tick(tier: str) -> None:
    """Count one kernel dispatch on its tier (host int — never a tracer)."""
    if _obs_metrics.enabled():
        _obs_metrics.registry().counter(f"kernel/dispatch/{tier}").add(1)

__all__ = ["FilterOps", "DEFAULT_VMEM_BUDGET_U32", "read_vmem_budget_u32"]

#: fallback resident/partitioned threshold in uint32 lanes (16 MiB of lanes)
DEFAULT_VMEM_BUDGET_U32 = 1 << 22


def read_vmem_budget_u32() -> int:
    """The resident/partitioned threshold in uint32 lanes.

    Reads ``BLOOMRF_VMEM_BUDGET_U32`` on every call (so tests and
    deployments can flip it without re-importing) and validates it at read
    time: a value that does not parse as an integer, or is <= 0, raises a
    ``ValueError`` that names the variable."""
    raw = os.environ.get("BLOOMRF_VMEM_BUDGET_U32")
    if raw is None:
        return DEFAULT_VMEM_BUDGET_U32
    try:
        val = int(raw)
    except ValueError:
        raise ValueError(
            f"BLOOMRF_VMEM_BUDGET_U32 must be an integer lane count, "
            f"got {raw!r}") from None
    if val <= 0:
        raise ValueError(
            f"BLOOMRF_VMEM_BUDGET_U32 must be > 0 lanes, got {val}")
    return val


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


class FilterOps:
    """Layout-bound kernel dispatcher.

    * small filters (<= ``vmem_budget_u32`` lanes) -> VMEM-resident kernels;
    * large filters -> block-partitioned point AND range probe kernels
      (HBM-scale filters no longer fall back to XLA for range queries);
    * exact-layer layouts (range) -> XLA engine path (dynamic bounded scan);
    * same-layout run *stacks* (``point_stacked``/``range_stacked``) ->
      the stacked-resident kernel while the whole (R, total_u32) stack fits
      the VMEM budget, else the XLA stacked-probe path — either way one
      fused gather per query tile across every run row.
    """

    def __init__(self, layout: FilterLayout, interpret: bool | None = None,
                 vmem_budget_u32: int | None = None, *, _warn: bool = True):
        if _warn:
            from .._compat import warn_legacy

            warn_legacy("FilterOps(layout)",
                        "dtype=..., n=..., placement='single', "
                        "backend='resident'|'partitioned'")
        check_kernel_layout(layout)
        self.layout = layout
        self.filter = BloomRF(layout, _warn=False)
        self.interpret = (not _on_tpu()) if interpret is None else interpret
        self.vmem_budget_u32 = (read_vmem_budget_u32()
                                if vmem_budget_u32 is None else vmem_budget_u32)
        self.resident = layout.total_u32 <= self.vmem_budget_u32

    # -- build ----------------------------------------------------------
    def init_state(self):
        return self.filter.init_state()

    def insert(self, state, keys):
        with _obs_trace.span("kernel/insert"):
            if self.resident:
                _tick("resident")
                return _insert.insert_resident(self.layout, state, keys,
                                               interpret=self.interpret)
            _tick("xla")
            return self.filter.insert(state, keys)  # XLA fallback

    # -- probes ----------------------------------------------------------
    def point(self, state, keys):
        with _obs_trace.span("kernel/point"):
            if self.resident:
                _tick("resident")
                return _probe.point_probe_resident(
                    self.layout, state, keys, interpret=self.interpret)
            _tick("partitioned")
            return _probe.point_probe_partitioned(
                self.layout, state, keys, interpret=self.interpret)

    def range(self, state, lo, hi):
        with _obs_trace.span("kernel/range"):
            if self.layout.has_exact:  # bounded dynamic scan: XLA engine
                _tick("xla")
                return self.filter.range(state,
                                         jnp.asarray(lo, self.filter.kdtype),
                                         jnp.asarray(hi, self.filter.kdtype))
            if self.resident:
                _tick("resident")
                return _rangeprobe.range_probe_resident(
                    self.layout, state, lo, hi, interpret=self.interpret)
            _tick("partitioned")
            return _rangeprobe.range_probe_partitioned(
                self.layout, state, lo, hi, interpret=self.interpret)

    # -- stacked-run probes (R same-layout rows, one gather per tile) ----
    def _stacked(self, n_rows: int):
        u = self.layout.total_u32
        return stacked_probe((self.layout,) * n_rows,
                             tuple(r * u for r in range(n_rows)))

    def range_stacked(self, stack, lo, hi):
        """(B, R) range verdicts over a ``uint32[R, total_u32]`` run stack."""
        if self.layout.has_exact:
            lo = jnp.asarray(lo, self.filter.kdtype)
            hi = jnp.asarray(hi, self.filter.kdtype)
            return jax.vmap(lambda row: self.filter.range(row, lo, hi),
                            out_axes=1)(stack)
        R = stack.shape[0]
        if R * self.layout.total_u32 <= self.vmem_budget_u32:
            _tick("resident")
            return _rangeprobe.range_probe_stacked_resident(
                self.layout, stack, lo, hi, interpret=self.interpret)
        _tick("xla")
        return self._stacked(R).range_all(stack.reshape(-1), lo, hi)

    def point_stacked(self, stack, keys):
        """(B, R) point verdicts over a ``uint32[R, total_u32]`` run stack."""
        if self.layout.has_exact:
            keys = jnp.asarray(keys, self.filter.kdtype)
            return jax.vmap(lambda row: self.filter.point(row, keys),
                            out_axes=1)(stack)
        R = stack.shape[0]
        if R * self.layout.total_u32 <= self.vmem_budget_u32:
            _tick("resident")
            return _probe.point_probe_stacked_resident(
                self.layout, stack, keys, interpret=self.interpret)
        _tick("xla")
        return self._stacked(R).point_all(stack.reshape(-1), keys)
