"""Jit'd dispatch wrappers over the Pallas kernels with XLA fallbacks.

``interpret`` defaults to True off-TPU (kernel bodies execute in Python on
CPU for validation); on a real TPU backend pass ``interpret=False``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import BloomRF, FilterLayout
from . import probe as _probe
from . import insert as _insert
from . import rangeprobe as _rangeprobe
from .ref import check_kernel_layout

__all__ = ["FilterOps"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


class FilterOps:
    """Layout-bound kernel dispatcher.

    * small filters (<= ``vmem_budget_u32`` lanes) -> VMEM-resident kernels;
    * large filters -> block-partitioned point AND range probe kernels
      (HBM-scale filters no longer fall back to XLA for range queries);
    * exact-layer layouts (range) -> XLA engine path (dynamic bounded scan).
    """

    def __init__(self, layout: FilterLayout, interpret: bool | None = None,
                 vmem_budget_u32: int = 1 << 22):  # 16 MiB of lanes
        check_kernel_layout(layout)
        self.layout = layout
        self.filter = BloomRF(layout)
        self.interpret = (not _on_tpu()) if interpret is None else interpret
        self.resident = layout.total_u32 <= vmem_budget_u32

    # -- build ----------------------------------------------------------
    def init_state(self):
        return self.filter.init_state()

    def insert(self, state, keys):
        if self.resident:
            return _insert.insert_resident(self.layout, state, keys,
                                           interpret=self.interpret)
        return self.filter.insert(state, keys)  # XLA fallback

    # -- probes ----------------------------------------------------------
    def point(self, state, keys):
        if self.resident:
            return _probe.point_probe_resident(self.layout, state, keys,
                                               interpret=self.interpret)
        return _probe.point_probe_partitioned(self.layout, state, keys,
                                              interpret=self.interpret)

    def range(self, state, lo, hi):
        if self.layout.has_exact:  # bounded dynamic scan: XLA engine path
            return self.filter.range(state,
                                     jnp.asarray(lo, self.filter.kdtype),
                                     jnp.asarray(hi, self.filter.kdtype))
        if self.resident:
            return _rangeprobe.range_probe_resident(self.layout, state, lo,
                                                    hi,
                                                    interpret=self.interpret)
        return _rangeprobe.range_probe_partitioned(self.layout, state, lo,
                                                   hi,
                                                   interpret=self.interpret)
