"""Pure-jnp oracles for the Pallas kernels.

The oracles are pinned to the *pre-engine reference path*
(``BloomRF.point_reference`` / ``range_reference`` — per-key scalar probes
under ``vmap``), NOT the plan->gather->combine engine the kernels now trace.
That makes kernel parity a genuine cross-implementation check: engine-based
kernels must match the legacy scalar math bit-for-bit, not just match
themselves.  Kernels operate on 32-bit sub-domains (d <= 32): the
distributed deployment range-partitions a 64-bit key space by its top bits
across shards, keeping all TPU lane arithmetic native uint32 (DESIGN.md §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import BloomRF, FilterLayout


def check_kernel_layout(layout: FilterLayout) -> None:
    if layout.d > 32:
        raise ValueError(
            "TPU kernels operate on 32-bit sub-domains; range-partition the "
            "64-bit key space across shards first (DESIGN.md §3)")


def point_ref(layout: FilterLayout, state: jax.Array, keys: jax.Array):
    check_kernel_layout(layout)
    return BloomRF(layout, _warn=False).point_reference(state, keys)


def range_ref(layout: FilterLayout, state: jax.Array, lo: jax.Array,
              hi: jax.Array):
    check_kernel_layout(layout)
    return BloomRF(layout, _warn=False).range_reference(state, lo, hi)


def insert_ref(layout: FilterLayout, state: jax.Array, keys: jax.Array):
    check_kernel_layout(layout)
    return BloomRF(layout, _warn=False).insert(state, keys)


def positions_ref(layout: FilterLayout, keys: jax.Array):
    """(B, P) bit positions probed/set per key (kernel-probe decomposition)."""
    check_kernel_layout(layout)
    f = BloomRF(layout, _warn=False)
    return jax.vmap(f._positions_one)(jnp.asarray(keys, f.kdtype))
