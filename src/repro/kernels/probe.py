"""Pallas TPU kernels: batched bloomRF point probes.

Two variants (DESIGN.md §3 — HBM->VMEM adaptation of the paper's
cache-line-word design):

* ``point_probe_resident`` — the whole filter is pinned in VMEM (BlockSpec
  maps the full state to every grid step); the grid tiles the query batch.
  This is the fast path for per-SST/per-segment filters (a 2M-key, 16 bit/key
  filter is 4 MiB — fits v5e VMEM comfortably).

* ``point_probe_partitioned`` — HBM-scale filters: probes are pre-bucketed by
  filter *block* (XLA argsort), padded to tile multiples, and the kernel walks
  (tile, block) pairs with the block DMA'd into VMEM via a scalar-prefetched
  index map.  This is the Putze-style cache partitioning re-targeted at the
  TPU memory hierarchy.

All kernel arithmetic is uint32 (d <= 32 sub-domains).  The per-key probe
math is the *core* implementation itself, traced inside the kernel — the
kernels add memory orchestration, not new math.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core import BloomRF, FilterLayout
from ..core.engine import stacked_probe
from .ref import check_kernel_layout

__all__ = [
    "point_probe_resident",
    "point_probe_partitioned",
    "point_probe_stacked_resident",
    "DEFAULT_TILE",
    "DEFAULT_BLOCK_U32",
]

DEFAULT_TILE = 512           # queries per grid step
DEFAULT_BLOCK_U32 = 16384    # 64 KiB filter blocks for the partitioned path


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _bucket_probes(lane: jax.Array, tile: int, block_u32: int, nblocks: int):
    """Bucket flat lane probes by filter block for the partitioned kernels.

    Sorts probes by owning block and pads each block's probe list to a tile
    multiple so no kernel tile spans two blocks.  Returns ``(order, slot,
    lane_b, tile_block, capr)``: the sort permutation, each sorted probe's
    destination slot, the padded lane table (-1 = padding), the per-tile
    block id (scalar prefetch input), and the padded length.  Callers
    scatter their per-probe payloads with ``.at[slot].set(payload[order])``.
    Shared by the point and range partitioned kernels — the padding
    invariants live here once."""
    nprobe = lane.shape[0]
    blk = lane // block_u32
    order = jnp.argsort(blk)
    lane_s, blk_s = lane[order], blk[order]
    counts = jnp.bincount(blk_s, length=nblocks)
    padded_counts = ((counts + tile - 1) // tile) * tile
    starts = jnp.concatenate([jnp.zeros(1, padded_counts.dtype),
                              jnp.cumsum(padded_counts)])[:-1]
    rank = jnp.arange(nprobe) - jnp.cumsum(
        jnp.concatenate([jnp.zeros(1, counts.dtype), counts]))[:-1][blk_s]
    slot = starts[blk_s] + rank
    capr = _round_up(nprobe + nblocks * tile, tile)  # worst-case padding
    lane_b = jnp.full(capr, -1, jnp.int32).at[slot].set(lane_s)
    tile_block = jnp.where(lane_b[::tile] < 0, 0,
                           lane_b[::tile] // block_u32).astype(jnp.int32)
    return order, slot, lane_b, tile_block, capr


# ---------------------------------------------------------------------------
# resident variant
# ---------------------------------------------------------------------------

def _resident_kernel(keys_ref, state_ref, out_ref, *, filt: BloomRF):
    # plan->gather->combine engine traced over the tile: one fused gather
    out_ref[...] = filt.engine.point_batched(state_ref[...], keys_ref[...])


@functools.partial(jax.jit, static_argnums=(0, 3, 4))
def point_probe_resident(layout: FilterLayout, state: jax.Array, keys,
                         tile: int = DEFAULT_TILE, interpret: bool = True):
    """Batched point probe with the filter resident in VMEM."""
    check_kernel_layout(layout)
    filt = BloomRF(layout, _warn=False)
    keys = jnp.asarray(keys, jnp.uint32)
    B = keys.shape[0]
    Bp = _round_up(max(B, 1), tile)
    keys_p = jnp.pad(keys, (0, Bp - B))
    grid = (Bp // tile,)
    out = pl.pallas_call(
        functools.partial(_resident_kernel, filt=filt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((layout.total_u32,), lambda t: (0,)),  # pinned
        ],
        out_specs=pl.BlockSpec((tile,), lambda t: (t,)),
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.bool_),
        interpret=interpret,
    )(keys_p, state)
    return out[:B]


# ---------------------------------------------------------------------------
# stacked-run variant (LSM run stacks: R same-layout filter rows in VMEM)
# ---------------------------------------------------------------------------

def _stacked_kernel(keys_ref, state_ref, out_ref, *, probe):
    out_ref[...] = probe._point_all(state_ref[...].reshape(-1), keys_ref[...])


@functools.partial(jax.jit, static_argnums=(0, 3, 4))
def point_probe_stacked_resident(layout: FilterLayout, stack: jax.Array,
                                 keys, tile: int = DEFAULT_TILE,
                                 interpret: bool = True):
    """Batched point probe over a ``uint32[R, total_u32]`` run stack.

    Each grid step answers one query tile against all R rows at once via
    the multi-filter stacked plan (``core.engine.StackedProbe`` — one
    fused gather per tile).  Returns ``bool[B, R]``."""
    check_kernel_layout(layout)
    if layout.has_exact:
        raise ValueError("exact-layer layouts use the XLA path (ops.py)")
    R = stack.shape[0]
    probe = stacked_probe((layout,) * R,
                          tuple(r * layout.total_u32 for r in range(R)))
    keys = jnp.asarray(keys, jnp.uint32)
    B = keys.shape[0]
    Bp = _round_up(max(B, 1), tile)
    keys_p = jnp.pad(keys, (0, Bp - B))
    out = pl.pallas_call(
        functools.partial(_stacked_kernel, probe=probe),
        grid=(Bp // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((R, layout.total_u32), lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, R), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, R), jnp.bool_),
        interpret=interpret,
    )(keys_p, stack)
    return out[:B]


# ---------------------------------------------------------------------------
# partitioned variant (HBM-scale filters)
# ---------------------------------------------------------------------------

def _partitioned_kernel(tile_block, lane_ref, sh_ref, block_ref, out_ref, *,
                        block_u32: int):
    del tile_block  # consumed by the index maps
    lane = lane_ref[...]                      # global lane ids, -1 = padding
    sh = sh_ref[...]
    local = jnp.where(lane < 0, 0, lane % block_u32).astype(jnp.int32)
    word = block_ref[...][local]
    bit = (word >> sh.astype(jnp.uint32)) & jnp.uint32(1)
    out_ref[...] = jnp.where(lane < 0, jnp.uint32(1), bit)  # pad -> neutral


@functools.partial(jax.jit, static_argnums=(0, 3, 4, 5))
def point_probe_partitioned(layout: FilterLayout, state: jax.Array, keys,
                            tile: int = DEFAULT_TILE,
                            block_u32: int = DEFAULT_BLOCK_U32,
                            interpret: bool = True):
    """Batched point probe for filters too large for VMEM.

    XLA side: expand keys to probes, sort probes by filter block, pad each
    block's probe list to a tile multiple.  Pallas side: walk tiles with the
    owning block scalar-prefetch-mapped into VMEM.  Probe bits are then
    AND-reduced per key (segment reduction) back in XLA.
    """
    check_kernel_layout(layout)
    filt = BloomRF(layout, _warn=False)
    keys = jnp.asarray(keys, jnp.uint32)
    B = keys.shape[0]
    U = layout.total_u32
    nblocks = _round_up(U, block_u32) // block_u32
    state_p = jnp.pad(state, (0, nblocks * block_u32 - U))

    plan = filt.engine.plan_point(keys)                 # lanes/sh (B, P)
    P = plan.lanes.shape[1]
    lane = plan.lanes.reshape(-1)                       # (B*P,)
    sh = plan.sh.astype(jnp.int32).reshape(-1)
    keyid = jnp.repeat(jnp.arange(B, dtype=jnp.int32), P)

    order, slot, lane_b, tile_block, capr = _bucket_probes(
        lane, tile, block_u32, nblocks)
    sh_b = jnp.zeros(capr, jnp.int32).at[slot].set(sh[order])
    key_b = jnp.full(capr, B, jnp.int32).at[slot].set(keyid[order])  # B=scrap

    ntiles = capr // tile
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(ntiles,),
        in_specs=[
            pl.BlockSpec((tile,), lambda t, tb: (t,)),
            pl.BlockSpec((tile,), lambda t, tb: (t,)),
            pl.BlockSpec((block_u32,), lambda t, tb: (tb[t],)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda t, tb: (t,)),
    )
    bits = pl.pallas_call(
        functools.partial(_partitioned_kernel, block_u32=block_u32),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((capr,), jnp.uint32),
        interpret=interpret,
    )(tile_block, lane_b, sh_b, state_p)

    # AND-reduce per key: min of bits (1 = set) over each key's probes
    acc = jnp.ones(B + 1, jnp.uint32).at[key_b].min(bits)
    return acc[:B] == 1
