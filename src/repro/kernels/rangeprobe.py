"""Pallas TPU kernels: batched bloomRF range probes.

Both variants trace the plan->gather->combine engine (core/engine.py,
DESIGN.md §9) instead of vmapping the scalar reference path: the per-tile
word table is one fused ``state[lanes]`` gather of shape ``(tile, A)`` with
covering-bit loads deduped against the child-word loads (4 word loads per
layer per replica), and the combine phase is pure vector work on registers.

* ``range_probe_resident`` — the whole filter is pinned in VMEM (BlockSpec
  maps the full state to every grid step); the grid tiles the query batch.

* ``range_probe_partitioned`` — HBM-scale filters, mirroring
  ``point_probe_partitioned``: the engine's *plan* runs in XLA and flattens
  to ``B * A`` lane probes, which are pre-bucketed by filter block
  (argsort), padded so no tile spans two blocks, and walked by a kernel
  with the owning block scalar-prefetch-DMA'd into VMEM.  Gathered lane
  values are scattered back into the ``(B, A)`` word matrix and the
  engine's *combine* finishes in XLA — verdicts are bit-identical to the
  resident kernel and the XLA path by construction (same plan, same words,
  same combine).

Layout restrictions for both kernel paths: no exact segment (its bounded
lane scan is a dynamic while_loop — fine for XLA, not for a TPU kernel);
everything else (variable Δ, replicas, multi-segment) is supported.
Exact-layer layouts fall back to the XLA path in ``ops.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core import BloomRF, FilterLayout
from ..core.engine import stacked_probe
from .probe import DEFAULT_BLOCK_U32, _bucket_probes
from .ref import check_kernel_layout

__all__ = ["range_probe_resident", "range_probe_partitioned",
           "range_probe_stacked_resident"]

DEFAULT_TILE = 512


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _check_range_kernel_layout(layout: FilterLayout) -> None:
    check_kernel_layout(layout)
    if layout.has_exact:
        raise ValueError("exact-layer layouts use the XLA path (ops.py)")


# ---------------------------------------------------------------------------
# resident variant
# ---------------------------------------------------------------------------

def _range_kernel(lo_ref, hi_ref, state_ref, out_ref, *, filt: BloomRF):
    out_ref[...] = filt.engine.range_batched(state_ref[...], lo_ref[...],
                                             hi_ref[...])


@functools.partial(jax.jit, static_argnums=(0, 4, 5))
def range_probe_resident(layout: FilterLayout, state: jax.Array, lo, hi,
                         tile: int = DEFAULT_TILE, interpret: bool = True):
    """Batched range probe with the filter resident in VMEM."""
    _check_range_kernel_layout(layout)
    filt = BloomRF(layout, _warn=False)
    lo = jnp.asarray(lo, jnp.uint32)
    hi = jnp.asarray(hi, jnp.uint32)
    B = lo.shape[0]
    Bp = _round_up(max(B, 1), tile)
    lo_p = jnp.pad(lo, (0, Bp - B))
    hi_p = jnp.pad(hi, (0, Bp - B))
    grid = (Bp // tile,)
    out = pl.pallas_call(
        functools.partial(_range_kernel, filt=filt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((layout.total_u32,), lambda t: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda t: (t,)),
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.bool_),
        interpret=interpret,
    )(lo_p, hi_p, state)
    return out[:B]


# ---------------------------------------------------------------------------
# stacked-run variant (LSM run stacks: R same-layout filter rows in VMEM)
# ---------------------------------------------------------------------------

def _range_stacked_kernel(lo_ref, hi_ref, state_ref, out_ref, *, probe):
    # the StackedProbe's one fused gather, traced over the query tile:
    # verdicts for every run row of the tile in a single (tile, R*A) load
    out_ref[...] = probe._range_all(state_ref[...].reshape(-1),
                                    lo_ref[...], hi_ref[...])


@functools.partial(jax.jit, static_argnums=(0, 4, 5))
def range_probe_stacked_resident(layout: FilterLayout, stack: jax.Array,
                                 lo, hi, tile: int = DEFAULT_TILE,
                                 interpret: bool = True):
    """Batched range probe over a stack of R same-layout filter rows.

    ``stack`` is ``uint32[R, total_u32]`` (one row per LSM run / tenant);
    the whole stack is pinned in VMEM and each grid step answers one query
    tile against **all** rows at once through the multi-filter stacked plan
    (``core.engine.StackedProbe`` — one fused gather per tile).  Returns
    ``bool[B, R]``."""
    _check_range_kernel_layout(layout)
    R = stack.shape[0]
    probe = stacked_probe((layout,) * R,
                          tuple(r * layout.total_u32 for r in range(R)))
    lo = jnp.asarray(lo, jnp.uint32)
    hi = jnp.asarray(hi, jnp.uint32)
    B = lo.shape[0]
    Bp = _round_up(max(B, 1), tile)
    lo_p = jnp.pad(lo, (0, Bp - B))
    hi_p = jnp.pad(hi, (0, Bp - B))
    out = pl.pallas_call(
        functools.partial(_range_stacked_kernel, probe=probe),
        grid=(Bp // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((R, layout.total_u32), lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, R), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, R), jnp.bool_),
        interpret=interpret,
    )(lo_p, hi_p, stack)
    return out[:B]


# ---------------------------------------------------------------------------
# partitioned variant (HBM-scale filters)
# ---------------------------------------------------------------------------

def _gather_block_kernel(tile_block, lane_ref, block_ref, out_ref, *,
                         block_u32: int):
    del tile_block  # consumed by the index maps
    lane = lane_ref[...]                      # global lane ids, -1 = padding
    local = jnp.where(lane < 0, 0, lane % block_u32).astype(jnp.int32)
    word = block_ref[...][local]
    out_ref[...] = jnp.where(lane < 0, jnp.uint32(0), word)


@functools.partial(jax.jit, static_argnums=(0, 4, 5, 6))
def range_probe_partitioned(layout: FilterLayout, state: jax.Array, lo, hi,
                            tile: int = DEFAULT_TILE,
                            block_u32: int = DEFAULT_BLOCK_U32,
                            interpret: bool = True):
    """Batched range probe for filters too large for VMEM.

    XLA side: run the engine's plan (pure arithmetic -> the (B, A) lane
    table), flatten to lane probes, sort probes by filter block, pad each
    block's probe list to a tile multiple.  Pallas side: walk tiles with the
    owning block scalar-prefetch-mapped into VMEM, emitting the gathered
    lane *values*.  XLA side again: scatter values back to the (B, A) word
    matrix and run the engine's combine.
    """
    _check_range_kernel_layout(layout)
    filt = BloomRF(layout, _warn=False)
    eng = filt.engine
    lo = jnp.asarray(lo, jnp.uint32)
    hi = jnp.asarray(hi, jnp.uint32)
    B = lo.shape[0]
    U = layout.total_u32
    nblocks = _round_up(U, block_u32) // block_u32
    state_p = jnp.pad(state, (0, nblocks * block_u32 - U))

    plan = eng.plan_range(lo, hi)
    A = plan.lanes.shape[-1]
    nprobe = B * A
    lane = plan.lanes.reshape(-1)                       # (B*A,)
    flat = jnp.arange(nprobe, dtype=jnp.int32)          # original matrix slot

    order, slot, lane_b, tile_block, capr = _bucket_probes(
        lane, tile, block_u32, nblocks)
    flat_b = jnp.full(capr, nprobe, jnp.int32).at[slot].set(flat[order])

    ntiles = capr // tile
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(ntiles,),
        in_specs=[
            pl.BlockSpec((tile,), lambda t, tb: (t,)),
            pl.BlockSpec((block_u32,), lambda t, tb: (tb[t],)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda t, tb: (t,)),
    )
    vals = pl.pallas_call(
        functools.partial(_gather_block_kernel, block_u32=block_u32),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((capr,), jnp.uint32),
        interpret=interpret,
    )(tile_block, lane_b, state_p)

    # scatter gathered words back into the (B, A) matrix; padding -> scrap
    g = jnp.zeros(nprobe + 1, jnp.uint32).at[flat_b].set(vals)
    g = g[:-1].reshape(B, A)
    return eng.combine_range(g, plan)
