"""Pallas TPU kernel: batched bloomRF range probes.

The two-path dyadic range lookup (core ``_range_one``) is traced *inside* the
kernel over a query tile, with the filter resident in VMEM.  The core math is
branch-free (live/dead masks instead of early exits), so the kernel is pure
vector work over the tile: per layer, <= 4 word loads + 2 covering bits per
query, exactly the paper's access bound.

Layout restrictions for the kernel path: no exact segment (its bounded lane
scan is a dynamic while_loop — fine for XLA, not for a TPU kernel); everything
else (variable Δ, replicas, multi-segment) is supported.  Exact-layer layouts
fall back to the XLA path in ``ops.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import BloomRF, FilterLayout
from .ref import check_kernel_layout

__all__ = ["range_probe_resident"]

DEFAULT_TILE = 512


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _range_kernel(lo_ref, hi_ref, state_ref, out_ref, *, filt: BloomRF):
    lo = lo_ref[...]
    hi = hi_ref[...]
    state = state_ref[...]
    out_ref[...] = jax.vmap(functools.partial(filt._range_one, state))(lo, hi)


@functools.partial(jax.jit, static_argnums=(0, 4, 5))
def range_probe_resident(layout: FilterLayout, state: jax.Array, lo, hi,
                         tile: int = DEFAULT_TILE, interpret: bool = True):
    check_kernel_layout(layout)
    if layout.has_exact:
        raise ValueError("exact-layer layouts use the XLA path (ops.py)")
    filt = BloomRF(layout)
    lo = jnp.asarray(lo, jnp.uint32)
    hi = jnp.asarray(hi, jnp.uint32)
    B = lo.shape[0]
    Bp = _round_up(max(B, 1), tile)
    lo_p = jnp.pad(lo, (0, Bp - B))
    hi_p = jnp.pad(hi, (0, Bp - B))
    grid = (Bp // tile,)
    out = pl.pallas_call(
        functools.partial(_range_kernel, filt=filt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((layout.total_u32,), lambda t: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda t: (t,)),
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.bool_),
        interpret=interpret,
    )(lo_p, hi_p, state)
    return out[:B]
