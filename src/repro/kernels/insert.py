"""Pallas TPU kernel: bloomRF bulk insert (filter build).

The filter accumulates in VMEM across the whole grid pass via
``input_output_aliases`` (TPU grid steps on a core are sequential, so
read-modify-write OR needs no atomics — DESIGN.md §3).  Each grid step
consumes one tile of keys and ORs its probe bits into the resident filter.
The number of valid keys is a trace-time constant (shapes are static), so
padding lanes are masked with a zero OR — they touch lane 0 harmlessly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import BloomRF, FilterLayout
from .ref import check_kernel_layout

__all__ = ["insert_resident"]

DEFAULT_TILE = 512


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _insert_kernel(keys_ref, state_in_ref, state_ref, *, filt: BloomRF,
                   tile: int, B: int):
    del state_in_ref  # aliased with state_ref
    t = pl.program_id(0)
    keys = keys_ref[...]
    pos = jax.vmap(filt._positions_one)(keys)          # (tile, P)
    lane = (pos >> 5).astype(jnp.int32)
    mask = jnp.uint32(1) << (pos & 31).astype(jnp.uint32)
    P = pos.shape[1]

    def body(j, _):
        valid = (t * tile + j // P) < B
        ln = jnp.where(valid, lane[j // P, j % P], 0)
        m = jnp.where(valid, mask[j // P, j % P], jnp.uint32(0))
        state_ref[ln] = state_ref[ln] | m
        return 0

    jax.lax.fori_loop(0, tile * P, body, 0)


@functools.partial(jax.jit, static_argnums=(0, 3, 4))
def insert_resident(layout: FilterLayout, state: jax.Array, keys,
                    tile: int = DEFAULT_TILE, interpret: bool = True):
    """OR-accumulating bulk insert with the filter resident in VMEM."""
    check_kernel_layout(layout)
    filt = BloomRF(layout, _warn=False)
    keys = jnp.asarray(keys, jnp.uint32)
    B = keys.shape[0]
    Bp = _round_up(max(B, 1), tile)
    keys_p = jnp.pad(keys, (0, Bp - B))
    grid = (Bp // tile,)
    return pl.pallas_call(
        functools.partial(_insert_kernel, filt=filt, tile=tile, B=B),
        grid=grid,
        in_specs=[pl.BlockSpec((tile,), lambda t: (t,)),
                  pl.BlockSpec((layout.total_u32,), lambda t: (0,))],
        out_specs=pl.BlockSpec((layout.total_u32,), lambda t: (0,)),
        out_shape=jax.ShapeDtypeStruct((layout.total_u32,), jnp.uint32),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(keys_p, state)
