"""Pallas TPU megakernel: the LSM store scan-pruning plane in ONE kernel.

``Store.scan_many`` used to round-trip host Python between four device
steps: the StackedProbe plan, the one fused gather over all live runs'
filter blocks, the combine/mask algebra, and the min/max fence masking
(computed separately in numpy).  This kernel fuses the whole plane —
fence compare, plan, gather, combine, touch masking — into a single
``pallas_call`` per scan batch with a flash-decoding-style grid:

* the **query axis** is tiled as usual (``tile`` queries per step);
* the **run axis** is split into *blocks* of ``runs_per_block`` stacked
  filter rows, the way flash decoding splits KV into chunks — each
  ``(query_tile, run_block)`` grid step answers one tile against one
  block of runs and writes a disjoint output sub-matrix, so no
  cross-block combine is needed.  The per-block filter state is DMA'd
  HBM -> VMEM by the BlockSpec pipeline, which double-buffers the next
  block's transfer behind the current block's compute (the standard
  Pallas grid pipeline); a store whose whole run stack exceeds the VMEM
  budget still scans with every filter block streamed exactly once per
  query tile.

Mixed capacity classes are the normal LSM case (level-0 runs share the
smallest class, each lower level is one fanout bigger), so run rows have
*different* layouts.  Rows are padded to one uniform ``rowpad`` lane
width and the kernel body selects the right combine algebra per block
through a **scalar-prefetched block-type table**: ``btype[rb]`` (SMEM)
indexes a ``lax.switch`` over the distinct per-block layout tuples, each
branch tracing that block's :class:`~repro.core.engine.StackedProbe`
(one fused gather per tile per block).  Uniform stacks skip the switch.

Fences ride along as per-run ``uint32`` key bounds; padding rows carry
the empty fence ``(kmin, kmax) = (2^32-1, 0)`` so they can never be
touched.  Verdicts are bit-identical to
``StackedProbe.touch_all`` (the XLA-exact fallback) by construction:
same plan, same gather lanes (shifted by the padded row bases), same
combine, same fence compare — asserted per layout class in
``tests/test_store_scan_kernel.py``.

Layout restrictions: all rows share one key domain ``d <= 32`` and no
exact segment (the store's capacity-class ladder satisfies both by
construction); other stacks use the XLA path (``Store`` dispatches).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.engine import stacked_probe
from .rangeprobe import _check_range_kernel_layout

__all__ = ["store_scan_probe", "build_run_stack", "DEFAULT_TILE"]

DEFAULT_TILE = 256           # scan queries per grid step


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def build_run_stack(states) -> jax.Array:
    """Pad per-run filter states to one uniform ``(R, rowpad)`` stack.

    Zero-padding is safe: padded lanes sit past every row's addressable
    lane range, so no planned gather ever lands in them."""
    rowpad = max(int(s.shape[0]) for s in states)
    return jnp.stack([jnp.pad(s, (0, rowpad - s.shape[0])) for s in states])


def _block_probes(layouts, rpb: int, rowpad: int):
    """Per-run-block StackedProbe branches + the block-type table.

    Blocks are consecutive ``rpb``-row slices of the run stack; a block
    whose tail crosses ``R`` is padded by repeating its last layout (the
    padding rows' empty fences keep their verdicts unreachable).  Returns
    ``(probes, btype)`` where ``probes[btype[rb]]`` combines block
    ``rb``'s rows at the padded row bases ``(0, rowpad, 2*rowpad, ...)``.
    """
    nblocks = _round_up(len(layouts), rpb) // rpb
    bases = tuple(i * rowpad for i in range(rpb))
    kinds, btype = {}, []
    for b in range(nblocks):
        lays = list(layouts[b * rpb:(b + 1) * rpb])
        lays += [lays[-1]] * (rpb - len(lays))
        key = tuple(lays)
        if key not in kinds:
            kinds[key] = len(kinds)
        btype.append(kinds[key])
    probes = [stacked_probe(key, bases) for key in kinds]
    return probes, btype


def _store_scan_kernel(btype_ref, quar_ref, lo_ref, hi_ref, kmin_ref,
                       kmax_ref, stack_ref, fence_ref, touch_ref, *,
                       probes, rpb):
    lo = lo_ref[...]
    hi = hi_ref[...]
    kmin = kmin_ref[...]
    kmax = kmax_ref[...]
    # min/max fence masking fused with the probe: a run is touched only
    # where the query interval overlaps its key range AND its filter says
    # "maybe"
    fence = (hi[:, None] >= kmin[None, :]) & (lo[:, None] <= kmax[None, :])
    state = stack_ref[...].reshape(-1)
    rb = pl.program_id(1)
    if len(probes) == 1:
        filt = probes[0]._range_all(state, lo, hi)
    else:
        # scalar-prefetched block-type table: pick this run block's
        # combine algebra (distinct layout mixes trace distinct branches)
        filt = jax.lax.switch(btype_ref[rb],
                              [p._range_all for p in probes], state, lo, hi)
    # scalar-prefetched quarantine mask (SMEM): rows whose filter block
    # failed its checksum take the always-touch branch — the corrupted
    # filter's verdict is discarded and the row degrades to fence-only
    # pruning (a flipped bit must never skip a run: no false negatives)
    quar = jnp.stack([quar_ref[rb * rpb + i] != 0 for i in range(rpb)])
    fence_ref[...] = fence
    touch_ref[...] = fence & (filt | quar[None, :])


@functools.partial(jax.jit, static_argnums=(0, 6, 7, 8))
def store_scan_probe(layouts, stack: jax.Array, kmin, kmax, lo, hi,
                     tile: int = DEFAULT_TILE, runs_per_block: int = 0,
                     interpret: bool = True, quarantine=None):
    """Fused store-scan pruning: ``(fence, touch)`` in one kernel call.

    ``layouts`` is the static per-run layout tuple, ``stack`` the
    ``uint32[R, rowpad]`` padded filter stack (:func:`build_run_stack`),
    ``kmin``/``kmax`` the per-run key fences, ``lo``/``hi`` the scan
    bounds (clamped into the ``d``-bit domain by the caller).  Returns
    ``(fence, touch)``, both ``bool[B, R]`` — exactly what
    ``StackedProbe.touch_all`` returns, from a single ``pallas_call``
    whatever the run mix (jaxpr-asserted in the test suite).

    ``runs_per_block`` splits the run axis into VMEM-sized filter blocks
    (0 = whole stack resident); the grid is ``(B/tile, R/runs_per_block)``
    and the Pallas pipeline double-buffers each block's HBM DMA behind
    the previous block's compute.

    ``quarantine`` (optional ``(R,)`` bool/int mask) rides along as a
    second scalar-prefetch operand: a True row's filter verdict is forced
    to "maybe" inside the kernel, degrading it to fence-only pruning —
    bit-identical to ``touch_all``'s quarantine handling.
    """
    R = len(layouts)
    if R == 0:
        raise ValueError("need at least one run row")
    d = layouts[0].d
    rowpad = int(stack.shape[1])
    for lay in layouts:
        _check_range_kernel_layout(lay)
        if lay.d != d:
            raise ValueError("store-scan rows must share one key domain")
        if lay.total_u32 > rowpad:
            raise ValueError(f"stack rowpad {rowpad} < layout lanes "
                             f"{lay.total_u32}")
    rpb = min(runs_per_block, R) if runs_per_block > 0 else R
    nblocks = _round_up(R, rpb) // rpb
    Rp = nblocks * rpb
    probes, btype = _block_probes(layouts, rpb, rowpad)

    lo = jnp.atleast_1d(jnp.asarray(lo, jnp.uint32))
    hi = jnp.atleast_1d(jnp.asarray(hi, jnp.uint32))
    B = lo.shape[0]
    tile = min(tile, _round_up(max(B, 1), 8))
    Bp = _round_up(max(B, 1), tile)
    lo_p = jnp.pad(lo, (0, Bp - B))
    hi_p = jnp.pad(hi, (0, Bp - B))
    stack_p = jnp.pad(jnp.asarray(stack, jnp.uint32), ((0, Rp - R), (0, 0)))
    # padding rows get the empty fence: kmin > kmax rejects every query
    kmin_p = jnp.pad(jnp.asarray(kmin, jnp.uint32), (0, Rp - R),
                     constant_values=jnp.uint32(0xFFFFFFFF))
    kmax_p = jnp.pad(jnp.asarray(kmax, jnp.uint32), (0, Rp - R))
    btype_arr = jnp.asarray(btype, jnp.int32)
    # the quarantine mask is the second scalar-prefetch operand (SMEM);
    # padding rows get 0 — their empty fence already rejects every query
    if quarantine is None:
        quar_arr = jnp.zeros((Rp,), jnp.int32)
    else:
        quar_arr = jnp.pad(
            jnp.asarray(quarantine).astype(jnp.int32), (0, Rp - R))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Bp // tile, nblocks),
        in_specs=[
            pl.BlockSpec((tile,), lambda t, rb, bt, q: (t,)),
            pl.BlockSpec((tile,), lambda t, rb, bt, q: (t,)),
            pl.BlockSpec((rpb,), lambda t, rb, bt, q: (rb,)),
            pl.BlockSpec((rpb,), lambda t, rb, bt, q: (rb,)),
            pl.BlockSpec((rpb, rowpad), lambda t, rb, bt, q: (rb, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, rpb), lambda t, rb, bt, q: (t, rb)),
            pl.BlockSpec((tile, rpb), lambda t, rb, bt, q: (t, rb)),
        ],
    )
    # named_scope: device-trace annotation only — no jaxpr equations, so
    # the one-pallas_call invariant is asserted with the scope in place
    with jax.named_scope("bloomrf/store_scan/pallas_call"):
        fence, touch = pl.pallas_call(
            functools.partial(_store_scan_kernel, probes=probes, rpb=rpb),
            grid_spec=grid_spec,
            out_shape=[jax.ShapeDtypeStruct((Bp, Rp), jnp.bool_),
                       jax.ShapeDtypeStruct((Bp, Rp), jnp.bool_)],
            interpret=interpret,
        )(btype_arr, quar_arr, lo_p, hi_p, kmin_p, kmax_p, stack_p)
    return fence[:B, :R], touch[:B, :R]
