"""Pallas TPU kernels for bloomRF hot spots (probe / range-probe / insert).

Each kernel has a pure-jnp oracle in ``ref.py``; ``ops.py`` is the jit'd
dispatch wrapper.  Kernels are validated in interpret mode on CPU and target
TPU VMEM tiling (see DESIGN.md §3 for the hardware adaptation).
"""
from .insert import insert_resident
from .ops import (DEFAULT_VMEM_BUDGET_U32, FilterOps,
                  read_vmem_budget_u32)
from .probe import (point_probe_partitioned, point_probe_resident,
                    point_probe_stacked_resident)
from .rangeprobe import (range_probe_partitioned, range_probe_resident,
                         range_probe_stacked_resident)
from .store_scan import build_run_stack, store_scan_probe

__all__ = [
    "store_scan_probe",
    "build_run_stack",
    "FilterOps",
    "DEFAULT_VMEM_BUDGET_U32",
    "read_vmem_budget_u32",
    "point_probe_resident",
    "point_probe_partitioned",
    "point_probe_stacked_resident",
    "insert_resident",
    "range_probe_resident",
    "range_probe_partitioned",
    "range_probe_stacked_resident",
]
