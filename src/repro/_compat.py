"""Deprecation machinery for the pre-façade entry points (DESIGN.md §11).

Since the typed façade (``repro.open_filter``/``repro.FilterSpec``) became
the public front door, the five historical constructors — ``BloomRF``,
``FilterOps``, ``FilterBank``, ``TenantFilterBank``, ``Store`` — are
legacy shims: they still work, but constructing one directly emits a
:class:`LegacyAPIWarning` pointing at the ``FilterSpec`` equivalent.

In-tree code must never go through a shim: every internal construction
site passes the private ``_warn=False`` keyword, and the test suite turns
``LegacyAPIWarning`` raised *from a repro module* into an error
(``filterwarnings`` in pyproject.toml), so an accidental in-tree use of a
deprecated entry point fails tier-1 CI.  Warnings are attributed to the
*caller* of the constructor (``stacklevel``), which is what makes the
module-scoped filter work: user/test code sees a plain warning, repro
code sees an error.
"""
from __future__ import annotations

import warnings

__all__ = ["LegacyAPIWarning", "warn_legacy"]


class LegacyAPIWarning(DeprecationWarning):
    """A pre-façade constructor was used directly (see DESIGN.md §11)."""


def warn_legacy(old: str, spec_hint: str) -> None:
    """Warn that ``old`` is a legacy entry point.

    ``spec_hint`` is the ``FilterSpec(...)`` argument list that opens the
    equivalent filter through the façade.  Called from a legacy
    constructor's ``__init__``; ``stacklevel=3`` attributes the warning to
    whoever invoked that constructor.
    """
    warnings.warn(
        f"{old} is a deprecated public entry point; open it through the "
        f"typed façade instead: repro.open_filter(repro.FilterSpec("
        f"{spec_hint})). See DESIGN.md §11 for the full old→new map.",
        LegacyAPIWarning, stacklevel=3)
