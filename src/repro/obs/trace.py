"""Span tracing: host-side timing contexts around production paths (§15).

``span(name)`` returns a context manager.  With observability disabled
it is a shared do-nothing singleton — one boolean check, zero
allocation.  Enabled, a span:

* wraps the body in ``jax.profiler.TraceAnnotation`` so the op shows up
  in a device trace when a profiler session is active (and costs ~nothing
  when one is not),
* feeds the wall-clock duration into the per-op-class latency histogram
  ``obs/latency/{name}`` in the global registry (p50/p99 come from
  there), and
* appends a structured ``bloomrf-trace/v1`` JSONL record when a trace
  sink has been set via :func:`set_trace_sink`.

Spans are HOST-side only.  Inside jitted functions the engine and the
store-scan kernel use ``jax.named_scope`` instead — a trace-time
annotation that adds no jaxpr equations, so the one-fused-gather and
one-``pallas_call`` invariants hold bit-for-bit with observability on
or off (pinned by ``tests/test_obs.py``).
"""
from __future__ import annotations

import json
import time

from . import metrics as _metrics

TRACE_SCHEMA = "bloomrf-trace/v1"

_sink_path: str | None = None
_sink_file = None
_TraceAnnotation = None     # resolved on first enabled span (lazy jax)


def set_trace_sink(path: str | None) -> None:
    """Append JSONL span records to ``path`` (``None`` closes the sink)."""
    global _sink_path, _sink_file
    if _sink_file is not None:
        _sink_file.close()
    _sink_path, _sink_file = None, None
    if path:
        _sink_path = str(path)
        _sink_file = open(path, "a", encoding="utf-8")


def trace_sink() -> str | None:
    return _sink_path


class _NullSpan:
    """Disabled-mode span: a do-nothing context-manager singleton."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class Span:
    __slots__ = ("name", "attrs", "_t0", "_prof")

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs
        self._t0 = 0.0
        self._prof = None

    def __enter__(self):
        global _TraceAnnotation
        if _TraceAnnotation is None:
            from jax.profiler import TraceAnnotation
            _TraceAnnotation = TraceAnnotation
        self._prof = _TraceAnnotation(f"bloomrf/{self.name}")
        self._prof.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur_us = (time.perf_counter() - self._t0) * 1e6
        self._prof.__exit__(*exc)
        _metrics.registry().histogram(
            f"obs/latency/{self.name}").observe(dur_us)
        if _sink_file is not None:
            rec = {"schema": TRACE_SCHEMA, "span": self.name,
                   "ts": time.time(), "dur_us": dur_us}
            if self.attrs:
                rec["attrs"] = self.attrs
            _sink_file.write(json.dumps(rec) + "\n")
            _sink_file.flush()
        return False


def span(name: str, **attrs):
    """Span context for op-class ``name``; a no-op singleton when off."""
    if not _metrics.enabled():
        return NULL_SPAN
    return Span(name, attrs)
