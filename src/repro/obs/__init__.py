"""Unified observability plane (DESIGN.md §15).

Three layers, one registry:

* :mod:`repro.obs.metrics` — counters / gauges / fixed-bucket histograms
  with device-scalar accumulation (no host sync until ``snapshot()``),
  plus registered *families* absorbing the pre-existing ad-hoc counters
  (``StoreStats``, prefix-cache hits, WAL/recovery stats) without
  breaking their field access.
* :mod:`repro.obs.trace` — host-side spans around facade / store / WAL /
  compaction ops: ``jax.profiler`` annotations when profiling,
  ``bloomrf-trace/v1`` JSONL when a sink is set, p50/p99 latency
  histograms always.
* :mod:`repro.obs.fpr` — known-absent reservoirs whose periodic re-probe
  yields *live* observed FPR and the query range-length distribution
  (the Proteus-tuner workload sample).

Everything is off by default (``BLOOMRF_OBS=1`` or :func:`enable`); with
it off every instrumentation site is one boolean check and the jaxpr
invariants (one gather / one ``pallas_call``) are bit-for-bit unchanged.
``export_snapshot()`` emits the ``bloomrf-metrics/v1`` document the CI
gates consume (``benchmarks/check_gates.py``).
"""
from .fpr import FprSampler
from .metrics import (DEFAULT_LATENCY_BUCKETS_US, Counter, Gauge, Histogram,
                      MetricsRegistry, disable, enable, enabled, registry)
from .trace import TRACE_SCHEMA, set_trace_sink, span, trace_sink

METRICS_SCHEMA = "bloomrf-metrics/v1"


def export_snapshot(extra: dict | None = None) -> dict:
    """Materialise the registry once → a ``bloomrf-metrics/v1`` dict."""
    snap = registry().snapshot()
    if extra:
        snap.update(extra)
    return {"schema": METRICS_SCHEMA, "metrics": snap}


__all__ = [
    "DEFAULT_LATENCY_BUCKETS_US", "Counter", "FprSampler", "Gauge",
    "Histogram", "METRICS_SCHEMA", "MetricsRegistry", "TRACE_SCHEMA",
    "disable", "enable", "enabled", "export_snapshot", "registry",
    "set_trace_sink", "span", "trace_sink",
]
