"""Live FPR telemetry: known-absent reservoirs re-probed on demand (§15).

A :class:`FprSampler` holds a fixed reservoir of *candidate* absent
point keys and ranges drawn uniformly over the filter's ``2^d`` code
domain.  Candidates are invalidated as the workload proves them present,
through either of two modes:

* **insert-stream tracking** (filter handles): ``observe_insert`` buffers
  every inserted code and lazily kills candidates the stream hits —
  amortised, never on the probe path;
* **ground truth** (the LSM store): ``mark_present`` *recomputes*
  liveness from the full live-key set at sample time — zero per-put
  overhead, exact by construction.

``sample()`` re-probes the surviving candidates through caller-supplied
probe closures; any positive is a certain false positive, so the hit
rate IS the live observed FPR.  ``observe_ranges`` additionally feeds
the query range-length distribution (``obs/workload/range_log2``
histogram + an Algorithm-R reservoir of raw bounds) — the workload
sample the Proteus-style tuner open item needs (ROADMAP, PAPERS.md).
"""
from __future__ import annotations

import numpy as np

from . import metrics as _metrics

# log2(range length) upper edges, 0..64: the whole dyadic ladder
LOG2_BUCKETS = tuple(float(b) for b in range(65))

#: the pinned ``sample()`` dict schema: always the first three, the two
#: FPR fields only when the matching probe ran.  ``bloomrf-workload/v1``
#: (repro.tune.workload) consumes these by name — additions are fine,
#: renames/removals are a schema break.
SAMPLE_FIELDS = ("point_candidates", "range_candidates", "workload_seen",
                 "point_fpr", "range_fpr")

_SETTLE_AT = 1 << 16        # pending inserted codes before a lazy settle


class FprSampler:
    """Reservoir of known-absent keys/ranges over a ``2^d`` code domain."""

    def __init__(self, d: int, n_keys: int = 512, n_ranges: int = 512,
                 range_len: int = 256, seed: int = 0xB10F,
                 reservoir_cap: int = 1024,
                 workload_hist: str = "obs/workload/range_log2"):
        if not 1 <= d <= 64:
            raise ValueError("d must be in [1, 64]")
        self.d = d
        self._rng = np.random.default_rng(seed)
        top = np.uint64((1 << d) - 1) if d < 64 else np.uint64(2**64 - 1)
        self.keys = self._rng.integers(0, 1 << d, n_keys, dtype=np.uint64)
        lo = self._rng.integers(0, 1 << d, n_ranges, dtype=np.uint64)
        with np.errstate(over="ignore"):
            hi = lo + np.uint64(max(range_len - 1, 0))
        self.lo = lo
        self.hi = np.where(hi < lo, top, np.minimum(hi, top))
        self.key_live = np.ones(n_keys, dtype=bool)
        self.range_live = np.ones(n_ranges, dtype=bool)
        self._pending: list[np.ndarray] = []
        self._pending_n = 0
        # workload reservoir (Algorithm R over (lo, hi) pairs)
        self._reservoir: list[tuple[int, int]] = []
        self._cap = reservoir_cap
        self._seen = 0
        self._hist = workload_hist
        # host copy of the range-length histogram: the tuner's workload
        # fit must not depend on the (off-by-default) metrics registry
        self.range_log2_counts = np.zeros(len(LOG2_BUCKETS))

    # -- candidate invalidation ------------------------------------------

    def observe_insert(self, codes) -> None:
        """Buffer inserted codes; candidates they hit die lazily."""
        codes = np.atleast_1d(np.asarray(codes, dtype=np.uint64))
        if codes.size == 0:
            return
        self._pending.append(codes)
        self._pending_n += codes.size
        if self._pending_n >= _SETTLE_AT:
            self._settle()

    def _settle(self) -> None:
        if not self._pending:
            return
        ins = np.unique(np.concatenate(self._pending))
        self._pending, self._pending_n = [], 0
        self.key_live &= ~np.isin(self.keys, ins)
        idx = np.searchsorted(ins, self.lo)
        at = np.minimum(idx, max(ins.size - 1, 0))
        nonempty = (idx < ins.size) & (ins[at] <= self.hi)
        self.range_live &= ~nonempty

    def mark_present(self, present) -> None:
        """Recompute liveness from the FULL present-key set (ground
        truth); replaces — not merges with — insert-stream state."""
        present = np.unique(np.asarray(present, dtype=np.uint64))
        self._pending, self._pending_n = [], 0
        if present.size == 0:
            self.key_live[:] = True
            self.range_live[:] = True
            return
        self.key_live = ~np.isin(self.keys, present)
        idx = np.searchsorted(present, self.lo)
        at = np.minimum(idx, present.size - 1)
        self.range_live = ~((idx < present.size) & (present[at] <= self.hi))

    # -- workload sampling -----------------------------------------------

    def observe_ranges(self, lo, hi) -> None:
        """Feed the range-length histogram + the bounds reservoir."""
        lo = np.atleast_1d(np.asarray(lo, dtype=np.uint64))
        hi = np.atleast_1d(np.asarray(hi, dtype=np.uint64))
        if lo.size == 0:
            return
        lengths = (hi - lo).astype(np.float64) + 1.0
        log_len = np.log2(np.maximum(lengths, 1.0))
        _metrics.registry().histogram(self._hist, LOG2_BUCKETS).observe_many(
            log_len)
        idx = np.clip(np.ceil(log_len), 0, len(LOG2_BUCKETS) - 1)
        self.range_log2_counts += np.bincount(
            idx.astype(np.int64), minlength=len(LOG2_BUCKETS))
        free = self._cap - len(self._reservoir)
        if free > 0:
            take = min(free, lo.size)
            self._reservoir.extend(
                zip(lo[:take].tolist(), hi[:take].tolist()))
            self._seen += take
            lo, hi = lo[take:], hi[take:]
        if lo.size:
            # exact Algorithm R, vectorized: the i-th item of the batch is
            # the (seen_i)-th of the stream and replaces a uniform slot of
            # [0, seen_i) when that slot lands inside the reservoir.  The
            # draws are independent across items, so batch processing is
            # distribution-identical to the one-at-a-time loop — each
            # candidate survives with probability cap/seen, exactly.
            counts = self._seen + np.arange(1, lo.size + 1)
            slots = (self._rng.random(lo.size) * counts).astype(np.int64)
            self._seen += lo.size
            hit = slots < self._cap
            for j, a, b in zip(slots[hit].tolist(), lo[hit].tolist(),
                               hi[hit].tolist()):
                self._reservoir[j] = (a, b)

    def workload_sample(self) -> list[tuple[int, int]]:
        """The reservoir of raw (lo, hi) query bounds (tuner input)."""
        return list(self._reservoir)

    @property
    def workload_seen(self) -> int:
        return self._seen

    def preload_workload(self, bounds, seen: int, log2_counts=None) -> None:
        """Re-seed the workload sample from a serialized snapshot
        (``bloomrf-workload/v1`` restore): the reservoir resumes with its
        prior candidates and stream position, so a reopened tuner does not
        cold-start through its hysteresis gate again."""
        bounds = [(int(a), int(b)) for a, b in bounds][: self._cap]
        if any(a > b for a, b in bounds):
            raise ValueError("preload_workload: lo > hi in bounds")
        self._reservoir = bounds
        self._seen = max(int(seen), len(bounds))
        if log2_counts is not None:
            counts = np.asarray(log2_counts, np.float64)
            if counts.shape != (len(LOG2_BUCKETS),) or (counts < 0).any():
                raise ValueError(
                    f"preload_workload: log2_counts must be "
                    f"{len(LOG2_BUCKETS)} non-negative counts")
            self.range_log2_counts = counts.copy()

    # -- re-probe ---------------------------------------------------------

    def live_points(self) -> np.ndarray:
        self._settle()
        return self.keys[self.key_live]

    def live_ranges(self) -> tuple[np.ndarray, np.ndarray]:
        self._settle()
        return self.lo[self.range_live], self.hi[self.range_live]

    def sample(self, point_probe=None, range_probe=None) -> dict:
        """Re-probe surviving candidates → live observed FPR.

        ``point_probe(keys)`` / ``range_probe(lo, hi)`` return a boolean
        verdict per query; every positive is a certain false positive.
        """
        out = {
            "point_candidates": int(self.live_points().size),
            "range_candidates": int(self.live_ranges()[0].size),
            "workload_seen": self.workload_seen,
        }
        if point_probe is not None and out["point_candidates"]:
            pos = np.asarray(point_probe(self.live_points()))
            out["point_fpr"] = float(pos.astype(bool).ravel().mean())
        if range_probe is not None and out["range_candidates"]:
            lo, hi = self.live_ranges()
            pos = np.asarray(range_probe(lo, hi))
            out["range_fpr"] = float(pos.astype(bool).ravel().mean())
        return out
