"""Metrics registry: counters, gauges, fixed-bucket histograms (§15).

Three rules keep the registry safe to wire through hot paths:

* **Device-scalar accumulation.**  ``Counter.add`` / ``Gauge.set`` accept
  jax device scalars and only *stash* them — nothing blocks, nothing is
  transferred.  ``snapshot()`` materialises every pending scalar once, at
  the read point, so jitted / timed loops never pay a host sync for
  telemetry.
* **Disabled by default.**  The global flag (``BLOOMRF_OBS`` env var, or
  :func:`enable`/:func:`disable`) gates every instrumentation *site*;
  with it off the production paths do one boolean check and move on.
  The registry itself always works — the flag guards the call sites,
  not the data structures.
* **Families, not forks.**  Pre-existing ad-hoc counters (``StoreStats``,
  the prefix-cache hit dict, WAL/recovery stats) keep their native field
  access; they join the registry as *registered families* — zero-arg
  callables returning a plain dict, weakly referenced by the caller so a
  dead owner just drops out of the next snapshot.

Metric names use ``/`` separators (``store/puts``, ``obs/fpr/observed``)
so a whole name is ONE segment of ``check_gates.py``'s dotted paths:
``metrics.obs/fpr/observed`` resolves without escaping.
"""
from __future__ import annotations

import math
import os
from typing import Callable, Iterable

# default latency ladder (microseconds): ~log-spaced 1us..1s
DEFAULT_LATENCY_BUCKETS_US = (
    1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0,
    1e3, 2e3, 5e3, 1e4, 2e4, 5e4, 1e5, 2e5, 5e5, 1e6,
)

_ENABLED = os.environ.get("BLOOMRF_OBS", "").lower() in ("1", "true", "yes", "on")


def enabled() -> bool:
    """Is the observability plane on?  Call sites gate on this."""
    return _ENABLED


def enable() -> None:
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False


def _is_host_number(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


class Counter:
    """Monotone counter; ``add`` never syncs a device value."""

    kind = "counter"
    __slots__ = ("name", "_host", "_pending")

    def __init__(self, name: str):
        self.name = name
        self._host = 0.0
        self._pending: list = []

    def add(self, v=1) -> None:
        if _is_host_number(v):
            self._host += v
        else:                       # jax/numpy scalar: settle lazily
            self._pending.append(v)

    def _settle(self) -> None:
        if self._pending:
            total = self._pending[0]
            for x in self._pending[1:]:
                total = total + x   # device-side adds, one transfer below
            self._pending = []
            self._host += float(total)

    def value(self):
        self._settle()
        v = self._host
        return int(v) if float(v).is_integer() else v

    def reset(self) -> None:
        self._host, self._pending = 0.0, []

    def snapshot_value(self):
        return self.value()


class Gauge:
    """Last-write-wins value; device scalars settle at snapshot time."""

    kind = "gauge"
    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0

    def set(self, v) -> None:
        self._value = v             # device scalar kept as-is (no sync)

    def value(self) -> float:
        if not _is_host_number(self._value):
            self._value = float(self._value)
        return self._value

    def reset(self) -> None:
        self._value = 0.0

    def snapshot_value(self):
        return self.value()


class Histogram:
    """Fixed-bucket histogram (host-side observations).

    ``buckets`` are ascending upper edges; one implicit overflow bucket
    catches everything above the last edge.  Percentiles report the upper
    edge of the covering bucket (overflow clamps to the last edge), which
    is conservative and cheap — good enough for p50/p99 latency gates.
    """

    kind = "histogram"
    __slots__ = ("name", "buckets", "counts", "total", "count")

    def __init__(self, name: str,
                 buckets: tuple = DEFAULT_LATENCY_BUCKETS_US):
        if list(buckets) != sorted(buckets) or not buckets:
            raise ValueError("histogram buckets must be ascending and non-empty")
        self.name = name
        self.buckets = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.buckets) + 1)
        self.total = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        i = 0
        for edge in self.buckets:
            if v <= edge:
                break
            i += 1
        self.counts[i] += 1
        self.total += v
        self.count += 1

    def observe_many(self, values: Iterable[float]) -> None:
        import numpy as np

        arr = np.asarray(list(values) if not hasattr(values, "__len__")
                         else values, dtype=np.float64).ravel()
        if arr.size == 0:
            return
        idx = np.searchsorted(np.asarray(self.buckets), arr, side="left")
        for i, c in zip(*np.unique(idx, return_counts=True)):
            self.counts[int(i)] += int(c)
        self.total += float(arr.sum())
        self.count += int(arr.size)

    def percentile(self, q: float) -> float:
        if self.count == 0:
            return 0.0
        need = math.ceil(q * self.count)
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= need:
                return self.buckets[min(i, len(self.buckets) - 1)]
        return self.buckets[-1]

    def reset(self) -> None:
        self.counts = [0] * (len(self.buckets) + 1)
        self.total, self.count = 0.0, 0

    def snapshot_value(self) -> dict:
        mean = self.total / self.count if self.count else 0.0
        return {"count": self.count, "mean": mean,
                "p50": self.percentile(0.50), "p99": self.percentile(0.99)}


class MetricsRegistry:
    """Name → metric map plus registered families.

    A *family* is a zero-arg callable returning a flat dict (or ``None``
    once its owner is gone — dead families are pruned at snapshot time).
    Family keys flatten into the snapshot as ``{family}/{key}``.
    """

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._families: dict[str, Callable[[], dict | None]] = {}

    def _get(self, name: str, cls, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, **kw)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is a {m.kind}, not a "
                            f"{cls.kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, buckets: tuple | None = None) -> Histogram:
        if buckets is None:
            return self._get(name, Histogram)
        return self._get(name, Histogram, buckets=buckets)

    def register_family(self, name: str,
                        fn: Callable[[], dict | None]) -> str:
        """Register ``fn`` under ``name`` (suffixed ``#2``, ``#3``… if
        taken); returns the assigned name."""
        assigned, i = name, 1
        while assigned in self._families:
            i += 1
            assigned = f"{name}#{i}"
        self._families[assigned] = fn
        return assigned

    def unregister_family(self, name: str) -> None:
        self._families.pop(name, None)

    def snapshot(self) -> dict:
        """Flat dict of every metric value; the ONE host-sync point."""
        out = {}
        for name in sorted(self._metrics):
            out[name] = self._metrics[name].snapshot_value()
        for fam in sorted(self._families):
            vals = self._families[fam]()
            if vals is None:                  # owner collected: prune
                del self._families[fam]
                continue
            for k, v in vals.items():
                out[f"{fam}/{k}"] = v
        return out

    def reset(self) -> None:
        """Zero every metric; registered families are left alone."""
        for m in self._metrics.values():
            m.reset()


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-global registry every instrumentation site feeds."""
    return _REGISTRY
