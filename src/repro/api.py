"""The typed filter façade: one ``FilterSpec -> open_filter()`` front door
(DESIGN.md §11).

The paper sells bloomRF as a *unified* point-range filter that "supports
floating-points, and can serve as a multi-attribute filter" (§8).  This
module is that claim as an API: a declarative :class:`FilterSpec` names the
key dtype, sizing, tuning budget, placement, and probe backend, and
:func:`open_filter` returns a handle that

* routes every insert/point/range (and store put/get/scan) through the
  order-preserving codecs in ``core/codecs.py`` — typed keys (floats,
  strings, attribute pairs) never leak past the façade into the integer
  filter machinery;
* chooses the filter layout through ``core/tuning.py`` (the paper's §7
  advisor) or the tuning-free ``basic_layout``, instead of requiring a
  hand-built :class:`~repro.core.FilterLayout`;
* dispatches probes to the existing engine / kernels / StackedProbe
  machinery, preserving the one-fused-gather jaxpr invariant behind the
  new surface (asserted in ``tests/test_facade.py``).

Placements map onto the subsystems grown in PRs 1–4:

====================  ====================================================
``single``            one :class:`~repro.core.BloomRF` (XLA engine) or
                      :class:`~repro.kernels.FilterOps` (Pallas kernels)
``bank``              :class:`~repro.dist.filter_bank.FilterBank` —
                      range-partitioned shard rows, stacked one-gather
``tenant``            :class:`~repro.dist.tenant_bank.TenantFilterBank`
                      (+ Bloofi-style meta rows in the same gather)
``store``             the LSM :class:`~repro.store.Store` with per-run
                      filter blocks, wrapped for typed put/get/scan
====================  ====================================================

The pre-façade constructors survive as deprecated shims (``repro._compat``)
pointing at their ``FilterSpec`` equivalents.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .core import codecs as _cd
from .core.layout import basic_layout, require_x64
from .obs import metrics as _obs_metrics
from .obs import trace as _obs_trace

__all__ = ["FilterSpec", "open_filter", "chunked_probe",
           "SingleFilter", "BankFilter", "TenantFilter", "TypedStore"]

_DTYPES = ("u8", "u16", "u32", "u64", "f32", "f64", "str", "multiattr")
_PLACEMENTS = ("single", "bank", "tenant", "store")
_BACKENDS = ("auto", "xla", "resident", "partitioned", "stacked")
_TUNINGS = ("auto", "basic", "advised", "adaptive")
_MUTABILITIES = ("insert_only", "deletable", "ttl")

#: range budget (log2) up to which the tuning-free basic layout is advised
_BASIC_RANGE_LOG2 = 14


# ---------------------------------------------------------------------------
# key codecs: typed keys <-> the integer filter domain
# ---------------------------------------------------------------------------

class _Codec:
    """Order-preserving map from a typed key space into a d-bit uint domain.

    ``encode_point``/``encode_bounds`` are FN-free by construction: every
    key in a typed interval has its code inside the encoded code interval.
    ``codes_per_key`` > 1 means one inserted key sets several codes (the
    multi-attribute dual concatenation).
    """

    name: str
    d: int
    codes_per_key = 1
    exact = True            # encode is injective (store data keys allowed)

    def encode_insert(self, keys) -> np.ndarray:
        return self.encode_point(keys)

    def encode_point(self, qs) -> np.ndarray:
        raise NotImplementedError

    def encode_bounds(self, lo, hi) -> tuple:
        return self.encode_point(lo), self.encode_point(hi)

    def decode(self, code):
        raise NotImplementedError(f"{self.name} codes do not decode")


class _UIntCodec(_Codec):
    def __init__(self, bits: int):
        self.name = f"u{bits}"
        self.d = bits

    def encode_point(self, qs) -> np.ndarray:
        arr = np.atleast_1d(np.asarray(qs, np.uint64))
        if self.d < 64 and (arr >> np.uint64(self.d)).any():
            raise ValueError(
                f"{self.name} keys must fit the {self.d}-bit domain")
        return arr

    def decode(self, code):
        return np.asarray(code, np.uint64)


class _Float64Codec(_Codec):
    name = "f64"
    d = 64

    def encode_point(self, qs) -> np.ndarray:
        arr = np.atleast_1d(np.asarray(qs, np.float64))
        if np.isnan(arr).any():
            raise ValueError("f64 keys must not be NaN (the order-preserving "
                             "φ map has no total order for NaN)")
        return _cd.float64_to_u64(arr)

    def decode(self, code):
        return _cd.u64_to_float64(np.asarray(code, np.uint64))


class _Float32Codec(_Codec):
    name = "f32"
    d = 32

    def encode_point(self, qs) -> np.ndarray:
        arr = np.atleast_1d(np.asarray(qs, np.float32))
        if np.isnan(arr).any():
            raise ValueError("f32 keys must not be NaN (the order-preserving "
                             "φ map has no total order for NaN)")
        return _cd.float32_to_u32(arr).astype(np.uint64)

    def decode(self, code):
        return _cd.u32_to_float32(np.asarray(code, np.uint32))


class _StrCodec(_Codec):
    """SuRF-Hash-style string codes (7-byte prefix + tail hash, paper §8).

    Codes are *not* injective — two strings may share a code — so string
    stores keep per-code buckets (:class:`TypedStore`) and range probes are
    FN-free supersets over the 7-byte prefix order."""

    name = "str"
    d = 64
    exact = False

    def encode_point(self, qs) -> np.ndarray:
        if isinstance(qs, (str, bytes)):
            qs = [qs]
        return np.asarray([_cd.string_point_code(s) for s in qs], np.uint64)

    def encode_bounds(self, lo, hi) -> tuple:
        if isinstance(lo, (str, bytes)):
            lo, hi = [lo], [hi]
        pairs = [_cd.string_range_bounds(a, b) for a, b in zip(lo, hi)]
        return (np.asarray([p[0] for p in pairs], np.uint64),
                np.asarray([p[1] for p in pairs], np.uint64))


class _MultiAttrCodec(_Codec):
    """Two reduced-precision 32-bit attributes, concatenated in both orders
    (paper §8).  Keys and query bounds are ``(a, b)`` pairs; inserts set
    both the <A,B> and <B,A> codes so conjunctive predicates on either
    attribute map to one range probe."""

    name = "multiattr"
    d = 64
    codes_per_key = 2

    @staticmethod
    def _pair(key) -> tuple:
        """Normalise multiattr keys to (a, b) uint64 vectors.

        Accepts a scalar pair ``(a, b)``, the column form
        ``(a_vector, b_vector)``, or a sequence of ``(a, b)`` rows.  The
        ambiguous 2x2 case reads as the column form — pass columns when
        batching."""
        arr = np.asarray(key, np.uint64)
        if arr.ndim == 1 and arr.shape[0] == 2:        # one (a, b) pair
            a, b = arr[0:1], arr[1:2]
        elif arr.ndim == 2 and arr.shape[0] == 2:      # (a_vec, b_vec)
            a, b = arr[0], arr[1]
        elif arr.ndim == 2 and arr.shape[1] == 2:      # rows of (a, b)
            a, b = arr[:, 0], arr[:, 1]
        else:
            raise ValueError(
                f"multiattr keys are (a, b) pairs, column vectors, or "
                f"(N, 2) rows; got array of shape {arr.shape}")
        if (a >> np.uint64(32)).any() or (b >> np.uint64(32)).any():
            raise ValueError("multiattr attributes must fit 32 bits")
        return a, b

    def encode_insert(self, keys) -> np.ndarray:
        ab, ba = _cd.multiattr_insert_codes(*self._pair(keys))
        return np.concatenate([ab, ba])

    def encode_point(self, qs) -> np.ndarray:
        return _cd.pack2x32(*self._pair(qs))

    def encode_bounds(self, lo, hi) -> tuple:
        return self.encode_point(lo), self.encode_point(hi)

    def mirrored_bounds(self, b_const, a_lo, a_hi) -> tuple:
        """<B,A> code bounds for ``B == b_const AND A in [a_lo, a_hi]``."""
        return _cd.multiattr_range_for_a_eq_b_range(
            np.atleast_1d(np.asarray(b_const, np.uint64)),
            np.asarray(a_lo, np.uint64), np.asarray(a_hi, np.uint64))

    def decode(self, code):
        return _cd.unpack2x32(np.asarray(code, np.uint64))


def _codec_for(dtype: str) -> _Codec:
    if dtype in ("u8", "u16", "u32", "u64"):
        return _UIntCodec(int(dtype[1:]))
    return {"f32": _Float32Codec, "f64": _Float64Codec,
            "str": _StrCodec, "multiattr": _MultiAttrCodec}[dtype]()


# ---------------------------------------------------------------------------
# the spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FilterSpec:
    """Declarative description of a point-range filter deployment.

    Exactly one of ``bits_per_key`` / ``target_fpr`` sizes the filter
    (neither -> 16 bits/key).  ``range_log2`` is the log2 of the largest
    range the filter is tuned for (the paper's R); ``tuning='auto'`` picks
    the tuning-free basic layout up to R = 2^14 and the §7 advisor above.
    """

    dtype: str = "u64"                      # u8|u16|u32|u64|f32|f64|str|multiattr
    n: int = 1 << 16                        # expected keys (per tenant if tenant)
    bits_per_key: Optional[float] = None
    target_fpr: Optional[float] = None      # range-FPR target at R=2^range_log2
    range_log2: int = _BASIC_RANGE_LOG2     # log2 of the range budget R
    placement: str = "single"               # single|bank|tenant|store
    backend: str = "auto"                   # auto|xla|resident|partitioned|stacked
    tuning: str = "auto"                    # auto|basic|advised
    shards: int = 1                         # bank/tenant: power-of-two shard rows
    tenants: int = 1                        # tenant: tenant rows
    delta: Optional[int] = None             # layer distance override (1..7)
    point_weight: float = 1.0               # advisor's point-vs-range weight
    chunk: int = 1 << 18                    # host-side probe chunking
    seed: int = 0x0B100F11
    # churn model (core/dynamic.py): how inserted keys may leave again
    mutability: str = "insert_only"         # insert_only|deletable|ttl
    generations: int = 4                    # ttl: retained TTL windows (>= 2)
    # store placement knobs (StoreConfig)
    store_backend: str = "bloomrf"
    memtable_limit: int = 4096
    fanout: int = 4
    level0_runs: int = 4
    purge_dead_frac: float = 0.25           # deletable store: dead fraction
                                            # forcing a purge rebuild
    durability: str = "none"                # store: "none" | "wal" (crash-safe
                                            # WAL + checkpoint/recovery)
    wal_dir: Optional[str] = None           # store durable root (WAL +
                                            # snapshots + manifest)

    def __post_init__(self):
        def bad(msg):
            raise ValueError(f"FilterSpec: {msg}")

        if self.dtype not in _DTYPES:
            bad(f"dtype must be one of {_DTYPES}, got {self.dtype!r}")
        if self.placement not in _PLACEMENTS:
            bad(f"placement must be one of {_PLACEMENTS}, "
                f"got {self.placement!r}")
        if self.backend not in _BACKENDS:
            bad(f"backend must be one of {_BACKENDS}, got {self.backend!r}")
        if self.tuning not in _TUNINGS:
            bad(f"tuning must be one of {_TUNINGS}, got {self.tuning!r}")
        if self.n < 1:
            bad(f"n must be >= 1, got {self.n}")
        if self.bits_per_key is not None and self.target_fpr is not None:
            bad("give bits_per_key OR target_fpr, not both")
        if self.bits_per_key is not None and self.bits_per_key <= 0:
            bad(f"bits_per_key must be > 0, got {self.bits_per_key}")
        if self.target_fpr is not None and not (0 < self.target_fpr < 1):
            bad(f"target_fpr must be in (0, 1), got {self.target_fpr}")
        d = _codec_for(self.dtype).d
        if not (0 <= self.range_log2 <= d):
            bad(f"range_log2 must be in [0, {d}] for {self.dtype} keys, "
                f"got {self.range_log2}")
        if self.delta is not None and not (1 <= self.delta <= 7):
            bad(f"delta must be in 1..7, got {self.delta}")
        if self.shards < 1 or self.shards & (self.shards - 1):
            bad(f"shards must be a power of two, got {self.shards}")
        if self.tenants < 1:
            bad(f"tenants must be >= 1, got {self.tenants}")
        if self.chunk < 1:
            bad(f"chunk must be >= 1, got {self.chunk}")
        if self.backend in ("resident", "partitioned") \
                and self.placement != "single":
            bad(f"backend={self.backend!r} is a single-filter kernel "
                f"dispatch; {self.placement!r} placements always probe "
                f"through the stacked engine")
        if self.backend == "stacked" and self.placement == "single":
            bad("backend='stacked' needs a multi-row placement "
                "(bank/tenant/store)")
        if self.tuning == "advised" and self.placement != "single":
            bad("tuning='advised' builds exact-bitmap layouts, which only "
                "the single placement's XLA path can probe (the stacked "
                "plan and the kernels are hashed-layout only)")
        if self.tuning == "adaptive" \
                and self.placement not in ("store", "tenant"):
            bad("tuning='adaptive' fits a workload model from live scans; "
                "only the store placement (retune at compaction) and the "
                "tenant placement (retune on promote) sample one")
        if self.mutability not in _MUTABILITIES:
            bad(f"mutability must be one of {_MUTABILITIES}, "
                f"got {self.mutability!r}")
        if self.mutability == "deletable" \
                and self.placement not in ("single", "store"):
            bad("mutability='deletable' needs counting lanes (single) or "
                "compaction purges (store); bank/tenant placements age out "
                "keys with mutability='ttl' instead")
        if self.mutability == "ttl" \
                and self.placement not in ("single", "tenant"):
            bad("mutability='ttl' keeps generation lanes on the resident "
                "state (single/tenant); the store expires via tombstones "
                "plus mutability='deletable' compaction purges")
        if self.generations < 2:
            bad(f"generations must be >= 2 (current + retiring), "
                f"got {self.generations}")
        if not (0.0 < self.purge_dead_frac <= 1.0):
            bad(f"purge_dead_frac must be in (0, 1], "
                f"got {self.purge_dead_frac}")
        if self.durability not in ("none", "wal"):
            bad(f"durability must be 'none' or 'wal', "
                f"got {self.durability!r}")
        if self.durability == "wal" and self.placement != "store":
            bad("durability='wal' is a store placement feature (resident "
                "filters rebuild from their source of truth instead)")
        if self.durability == "wal" and not self.wal_dir:
            bad("durability='wal' requires wal_dir")

    # -- derived sizing ---------------------------------------------------
    def resolved_bits_per_key(self) -> float:
        """bits/key from the explicit knob or the §6 model's FPR target."""
        if self.bits_per_key is not None:
            return float(self.bits_per_key)
        if self.target_fpr is None:
            return 16.0
        from .core.model import basic_range_fpr

        d = _codec_for(self.dtype).d
        n = self.n * _codec_for(self.dtype).codes_per_key
        R = 2.0 ** self.range_log2
        delta = self.delta if self.delta is not None else 7
        for bpk in range(6, 41):
            if basic_range_fpr(d, n, bpk * n, R, delta=delta) \
                    <= self.target_fpr:
                return float(bpk)
        raise ValueError(
            f"FilterSpec: no bits_per_key <= 40 reaches target_fpr="
            f"{self.target_fpr} at R=2^{self.range_log2}; relax the target "
            f"or size with bits_per_key explicitly")

    def describe(self) -> str:
        bpk = self.resolved_bits_per_key()
        return (f"FilterSpec({self.dtype}, n={self.n}, {bpk:g} b/key, "
                f"R=2^{self.range_log2}, {self.placement}/{self.backend}"
                + (f", shards={self.shards}" if self.shards > 1 else "")
                + (f", tenants={self.tenants}" if self.tenants > 1 else "")
                + ")")


# ---------------------------------------------------------------------------
# shared probe plumbing
# ---------------------------------------------------------------------------

def chunked_probe(fn, state, arrays, kdtype, chunk: int) -> np.ndarray:
    """Drive a jitted probe over host arrays in fixed-size chunks.

    The single copy of the chunking loop used by every façade handle and
    by :class:`~repro.filters.BloomRFAdapter` — one compiled shape serves
    arbitrarily large query batches."""
    import jax.numpy as jnp

    outs = []
    B = len(arrays[0])
    for s in range(0, B, chunk):
        args = [jnp.asarray(a[s:s + chunk], kdtype) for a in arrays]
        outs.append(np.asarray(fn(state, *args)))
    return np.concatenate(outs) if outs else np.zeros(0, bool)


def _plan_layout(spec: FilterSpec, codec: _Codec):
    """Layout selection: tuning-free basic vs the §7 advisor."""
    from .core.tuning import advise

    n_codes = max(spec.n * codec.codes_per_key, 1)
    bpk = spec.resolved_bits_per_key()
    tuning = spec.tuning
    if tuning == "auto":
        tuning = "basic" if spec.range_log2 <= _BASIC_RANGE_LOG2 \
            else "advised"
    if tuning == "basic":
        delta = spec.delta if spec.delta is not None else min(7, codec.d)
        return basic_layout(codec.d, n_codes, bpk, delta=delta,
                            seed=spec.seed), "basic"
    return advise(codec.d, n_codes, int(n_codes * bpk),
                  R=2.0 ** spec.range_log2, point_weight=spec.point_weight,
                  seed=spec.seed).layout, "advised"


class _Handle:
    """Common surface of every façade handle."""

    def __init__(self, spec: FilterSpec, codec: _Codec):
        self.spec = spec
        self.codec = codec
        self._fpr = None        # lazy known-absent reservoir (obs/fpr.py)

    def describe(self) -> str:
        return self.spec.describe()

    def size_bits(self) -> int:
        raise NotImplementedError

    # -- observability (DESIGN.md §15) -----------------------------------
    def _fpr_sampler(self, **kw):
        """This handle's known-absent reservoir, built on first use."""
        if self._fpr is None:
            from .obs.fpr import FprSampler

            self._fpr = FprSampler(self.codec.d,
                                   seed=self.spec.seed ^ 0xB10F, **kw)
        return self._fpr

    def _observe_ranges(self, clo, chi) -> None:
        """Feed the workload sampler (range-length distribution) when the
        obs plane is on; one boolean check otherwise."""
        if _obs_metrics.enabled():
            self._fpr_sampler().observe_ranges(clo, chi)

    def _record_fpr(self, out: dict) -> dict:
        """Publish an ``observed_fpr()`` result to the registry gauges."""
        reg = _obs_metrics.registry()
        if "range_fpr" in out:
            reg.gauge("obs/fpr/observed").set(out["range_fpr"])
        if "point_fpr" in out:
            reg.gauge("obs/fpr/point").set(out["point_fpr"])
        reg.gauge("obs/fpr/range_candidates").set(
            out.get("range_candidates", 0))
        return out

    # multi-attribute sugar shared by the filter handles -----------------
    def _require_multiattr(self):
        if self.codec.name != "multiattr":
            raise TypeError(
                f"conjunctive-predicate probes need dtype='multiattr', "
                f"this filter holds {self.codec.name} keys")


class SingleFilter(_Handle):
    """One bloomRF: XLA engine path or the Pallas kernel dispatcher.

    ``backend='auto'`` uses the kernels wherever they apply (hashed layout,
    d <= 32) and the XLA engine otherwise; ``resident``/``partitioned``
    force a kernel dispatch tier; ``xla`` forces the engine.
    """

    def __init__(self, spec: FilterSpec, codec: _Codec):
        import jax

        super().__init__(spec, codec)
        require_x64(codec.d)
        layout, self.tuning = _plan_layout(spec, codec)
        backend = spec.backend
        if backend == "auto":
            # kernels only apply to hashed 32-bit layouts, and off-TPU they
            # run in interpret mode (validation, not speed): auto keeps the
            # XLA engine there and dispatches to the kernels on real TPUs
            on_tpu = jax.default_backend() == "tpu"
            backend = "kernels" if (on_tpu and codec.d <= 32
                                    and not layout.has_exact) else "xla"
        self.backend = backend
        self._bind_layout(layout)
        self.counts = None      # deletable: per-bit reference counters
        self.gens = None        # ttl: generation lanes
        self._state = self.filter.init_state()
        if spec.mutability == "deletable":
            from .core.dynamic import CountingLanes

            self.counts = CountingLanes(layout.total_bits)
        elif spec.mutability == "ttl":
            from .core.dynamic import Generations

            self.gens = Generations(self.filter.init_state, spec.generations)

    def _bind_layout(self, layout) -> None:
        """(Re)build the filter, kernel ops, and jitted entry points for
        ``layout`` — shared by ``__init__`` and :meth:`grow`."""
        import jax

        from .core.bloomrf import BloomRF
        from .kernels.ops import FilterOps

        self.layout = layout
        self.filter = BloomRF(layout, _warn=False)
        self.ops = None
        if self.backend in ("kernels", "resident", "partitioned"):
            budget = None
            if self.backend == "resident":
                budget = max(layout.total_u32, 1)
            elif self.backend == "partitioned":
                budget = 0
            self.ops = FilterOps(layout, vmem_budget_u32=budget,
                                 _warn=False)
        if self.ops is not None:
            self._point = self.ops.point
            self._range = self.ops.range
            self._insert = self.ops.insert
        else:
            self._point = jax.jit(self.filter.point)
            self._range = jax.jit(self.filter.range)
            self._insert = jax.jit(self.filter.insert)
        self._posf = jax.jit(jax.vmap(self.filter._positions_one))

    # -- state (TTL filters probe the OR-collapse of their generations) ---
    @property
    def state(self):
        return self.gens.collapsed if self.gens is not None else self._state

    @state.setter
    def state(self, value):
        if self.gens is not None:
            raise AttributeError(
                "a TTL filter's state is generation-managed; insert through "
                "insert() and age through advance_generation()")
        self._state = value

    # -- mutation ---------------------------------------------------------
    def insert(self, keys) -> None:
        codes = self.codec.encode_insert(keys)
        if _obs_metrics.enabled():
            self._fpr_sampler().observe_insert(codes)
        import jax.numpy as jnp

        kd = self.filter.kdtype
        with _obs_trace.span("facade/insert", n=len(codes)):
            for s in range(0, len(codes), self.spec.chunk):
                cj = jnp.asarray(codes[s:s + self.spec.chunk], kd)
                if self.gens is not None:
                    self.gens.insert(self._insert, cj)
                else:
                    self._state = self._insert(self._state, cj)
                if self.counts is not None:
                    self.counts.add(np.asarray(self._posf(cj)))

    def delete(self, keys) -> None:
        """Remove previously inserted keys (``mutability='deletable'``).

        Decrements the counting lanes; bits whose counters drain to zero
        are cleared, so deleted keys stop costing false positives (up to
        counter saturation).  Deleting keys never inserted is a contract
        violation, as with any counting Bloom."""
        if self.counts is None:
            raise ValueError(
                "delete() needs FilterSpec(mutability='deletable')")
        from .core.dynamic import clear_bits

        import jax.numpy as jnp

        codes = self.codec.encode_insert(keys)
        kd = self.filter.kdtype
        for s in range(0, len(codes), self.spec.chunk):
            cj = jnp.asarray(codes[s:s + self.spec.chunk], kd)
            zeroed = self.counts.remove(np.asarray(self._posf(cj)))
            self._state = clear_bits(self._state, zeroed)

    def advance_generation(self) -> None:
        """Close the current TTL window (``mutability='ttl'``): the oldest
        generation's keys stop costing false positives; keys not
        re-inserted within ``spec.generations`` windows expire."""
        if self.gens is None:
            raise ValueError(
                "advance_generation() needs FilterSpec(mutability='ttl')")
        self.gens.advance()

    def grow(self, factor: int = 4) -> None:
        """In-place capacity promotion (``core/dynamic.py``): segment-tile
        the state onto a ``factor``-times larger layout with no key
        re-hashing — every inserted key keeps probing positive."""
        from .core.dynamic import promote_layout, promote_state

        old = self.layout
        new = promote_layout(old, factor)
        self._bind_layout(new)
        if self.gens is not None:
            self.gens = self.gens.map(
                lambda st: promote_state(st, old, new),
                zero_fn=self.filter.init_state)
        else:
            self._state = promote_state(self._state, old, new)
        if self.counts is not None:
            self.counts = self.counts.promoted(old, new)
        self.spec = dataclasses.replace(self.spec, n=self.spec.n * factor)

    # -- probes -----------------------------------------------------------
    def point(self, qs) -> np.ndarray:
        codes = self.codec.encode_point(qs)
        with _obs_trace.span("facade/point", n=len(codes)):
            return chunked_probe(self._point, self.state, [codes],
                                 self.filter.kdtype, self.spec.chunk)

    def range(self, lo, hi) -> np.ndarray:
        clo, chi = self.codec.encode_bounds(lo, hi)
        self._observe_ranges(clo, chi)
        with _obs_trace.span("facade/range", n=len(clo)):
            return chunked_probe(self._range, self.state, [clo, chi],
                                 self.filter.kdtype, self.spec.chunk)

    def observed_fpr(self) -> dict:
        """Re-probe the known-absent reservoir → live observed FPR (§15).

        Candidates are invalidated from the insert stream observed while
        observability was enabled, so enable obs before the first insert
        for an exact reservoir.  Publishes ``obs/fpr/*`` gauges."""
        s = self._fpr_sampler()
        kd = self.filter.kdtype
        return self._record_fpr(s.sample(
            point_probe=lambda ks: chunked_probe(
                self._point, self.state, [ks], kd, self.spec.chunk),
            range_probe=lambda lo, hi: chunked_probe(
                self._range, self.state, [lo, hi], kd, self.spec.chunk)))

    def range_where_b(self, b_const, a_lo, a_hi) -> np.ndarray:
        """Multiattr: ``B == b_const AND A in [a_lo, a_hi]`` via <B,A> codes."""
        self._require_multiattr()
        clo, chi = self.codec.mirrored_bounds(b_const, a_lo, a_hi)
        return chunked_probe(self._range, self.state, [clo, chi],
                             self.filter.kdtype, self.spec.chunk)

    def size_bits(self) -> int:
        return self.layout.total_bits


class BankFilter(_Handle):
    """Range-partitioned shard rows, all probed in one stacked gather."""

    def __init__(self, spec: FilterSpec, codec: _Codec):
        from .dist.filter_bank import FilterBank

        super().__init__(spec, codec)
        require_x64(codec.d)
        delta = spec.delta if spec.delta is not None else 6
        self.bank = FilterBank(codec.d, spec.shards,
                               max(spec.n * codec.codes_per_key, 1),
                               spec.resolved_bits_per_key(), delta=delta,
                               seed=spec.seed, _warn=False)
        self.state = self.bank.init_state()

    def insert(self, keys) -> None:
        codes = self.codec.encode_insert(keys)
        if _obs_metrics.enabled():
            self._fpr_sampler().observe_insert(codes)
        import jax.numpy as jnp

        with _obs_trace.span("facade/insert", n=len(codes)):
            for s in range(0, len(codes), self.spec.chunk):
                self.state = self.bank.insert(
                    self.state, jnp.asarray(codes[s:s + self.spec.chunk],
                                            self.bank.kdtype))

    def point(self, qs) -> np.ndarray:
        codes = self.codec.encode_point(qs)
        with _obs_trace.span("facade/point", n=len(codes)):
            return chunked_probe(self.bank.point, self.state, [codes],
                                 self.bank.kdtype, self.spec.chunk)

    def range(self, lo, hi) -> np.ndarray:
        clo, chi = self.codec.encode_bounds(lo, hi)
        self._observe_ranges(clo, chi)
        with _obs_trace.span("facade/range", n=len(clo)):
            return chunked_probe(self.bank.range, self.state, [clo, chi],
                                 self.bank.kdtype, self.spec.chunk)

    def observed_fpr(self) -> dict:
        """Live observed FPR over the whole bank (see
        :meth:`SingleFilter.observed_fpr`)."""
        s = self._fpr_sampler()
        kd = self.bank.kdtype
        return self._record_fpr(s.sample(
            point_probe=lambda ks: chunked_probe(
                self.bank.point, self.state, [ks], kd, self.spec.chunk),
            range_probe=lambda lo, hi: chunked_probe(
                self.bank.range, self.state, [lo, hi], kd,
                self.spec.chunk)))

    def range_where_b(self, b_const, a_lo, a_hi) -> np.ndarray:
        self._require_multiattr()
        clo, chi = self.codec.mirrored_bounds(b_const, a_lo, a_hi)
        return chunked_probe(self.bank.range, self.state, [clo, chi],
                             self.bank.kdtype, self.spec.chunk)

    def size_bits(self) -> int:
        return self.bank.size_bits()


class TenantFilter(_Handle):
    """Per-tenant banks + the Bloofi-style meta rows, one stacked gather.

    Every probe takes a ``tenants`` vector next to the typed keys; range
    probes AND the meta verdict in by default (strictly fewer false
    positives, never a false negative)."""

    def __init__(self, spec: FilterSpec, codec: _Codec):
        from .dist.tenant_bank import TenantFilterBank

        super().__init__(spec, codec)
        require_x64(codec.d)
        delta = spec.delta if spec.delta is not None else 6
        self.bank = TenantFilterBank(
            codec.d, spec.tenants, spec.shards,
            max(spec.n * codec.codes_per_key, 1),
            spec.resolved_bits_per_key(), delta=delta, seed=spec.seed,
            _warn=False)
        self.gens = None        # ttl: generation lanes over (state, meta)
        self._fpr_tenants: dict = {}    # per-tenant reservoirs (first <= 8)
        self._wl_sampler = None         # adaptive: live scan-bounds sample
        self._promote_events: list = []  # adaptive: advised promotions
        if spec.tuning == "adaptive":
            from .obs.fpr import FprSampler

            self._wl_sampler = FprSampler(codec.d, seed=spec.seed ^ 0xAD47)
        self._state = self.bank.init_state()
        self._meta = self.bank.init_meta()
        if spec.mutability == "ttl":
            from .core.dynamic import Generations

            self.gens = Generations(
                lambda: (self.bank.init_state(), self.bank.init_meta()),
                spec.generations)

    # -- state (TTL filters probe the OR-collapse of their generations) ---
    @property
    def state(self):
        return self.gens.collapsed[0] if self.gens is not None \
            else self._state

    @property
    def meta(self):
        return self.gens.collapsed[1] if self.gens is not None \
            else self._meta

    def _tiled_tenants(self, tenants, n_codes: int):
        """Tenant ids aligned 1:1 with the encoded codes: a scalar tenant
        broadcasts over the batch, and multiattr's dual codes repeat the
        whole vector (codes are [ab..., ba...])."""
        t = np.atleast_1d(np.asarray(tenants, np.uint32))
        reps = n_codes // max(len(t), 1)
        t = np.tile(t, reps) if reps > 1 else t
        if len(t) != n_codes:
            raise ValueError(
                f"tenants ({len(t)} after broadcast) do not align with "
                f"{n_codes} encoded keys")
        return t

    #: tenants tracked with their own known-absent reservoir; per-tenant
    #: FPR telemetry over every tenant would cost O(tenants) probes per
    #: sample, so only the first few observed tenants are followed
    _MAX_FPR_TENANTS = 8

    def _tenant_sampler(self, tid: int):
        s = self._fpr_tenants.get(tid)
        if s is None and len(self._fpr_tenants) < self._MAX_FPR_TENANTS:
            from .obs.fpr import FprSampler

            s = self._fpr_tenants[tid] = FprSampler(
                self.codec.d, seed=(self.spec.seed ^ 0xB10F) + tid)
        return s

    def insert(self, tenants, keys) -> None:
        import jax.numpy as jnp

        codes = self.codec.encode_insert(keys)
        t = self._tiled_tenants(tenants, len(codes))
        if _obs_metrics.enabled():
            for tid in np.unique(t):
                s = self._tenant_sampler(int(tid))
                if s is not None:
                    s.observe_insert(codes[t == tid])
        for s in range(0, len(codes), self.spec.chunk):
            cj = jnp.asarray(codes[s:s + self.spec.chunk], self.bank.bank.kdtype)
            tj = jnp.asarray(t[s:s + self.spec.chunk])
            if self.gens is not None:
                self.gens.insert(
                    lambda sm, tt, cc: (self.bank.insert(sm[0], tt, cc),
                                        self.bank.insert_meta(sm[1], tt, cc)),
                    tj, cj)
            else:
                self._state = self.bank.insert(self._state, tj, cj)
                self._meta = self.bank.insert_meta(self._meta, tj, cj)

    def advance_generation(self) -> None:
        """Close the current TTL window (``mutability='ttl'``): tenants'
        cold keys expire after ``spec.generations`` windows without a
        re-insert and stop costing false positives — no sweeps."""
        if self.gens is None:
            raise ValueError(
                "advance_generation() needs FilterSpec(mutability='ttl')")
        self.gens.advance()

    def grow(self, factor: Optional[int] = None) -> None:
        """In-place capacity promotion of every tenant row (and the meta
        rows, and every TTL generation): segment tiling, no key re-hash.

        With ``FilterSpec(tuning='adaptive')`` and ``factor=None`` the
        promotion factor is *advised* from the sampled workload
        (``TenantFilterBank.advise_promotion``): the cost model prices
        each candidate factor's promoted layout under the observed
        range-length mix and the smallest factor that isn't clearly
        beaten wins.  Without a workload sample (or with static tuning)
        the default factor is 4."""
        from .core.dynamic import promote_state

        if factor is None:
            factor = 4
            if (self._wl_sampler is not None
                    and self._wl_sampler.workload_seen):
                from .tune import fit_workload

                wl = fit_workload(self.codec.d, sampler=self._wl_sampler)
                factor, reports = self.bank.advise_promotion(wl)
                self._promote_events.append({
                    "factor": factor,
                    "workload_seen": self._wl_sampler.workload_seen,
                    "reports": {f: r.as_dict()
                                for f, r in reports.items()},
                })
        old = self.bank
        if self.gens is not None:
            nb = old.grown(factor)
            ol, nl = old.bank.layout, nb.bank.layout
            oml, nml = old.meta_layout, nb.meta_layout
            self.gens = self.gens.map(
                lambda sm: (promote_state(sm[0], ol, nl),
                            promote_state(sm[1], oml, nml)),
                zero_fn=lambda: (nb.init_state(), nb.init_meta()))
            self.bank = nb
        else:
            self.bank, self._state, self._meta = old.promote(
                self._state, self._meta, factor)
        self.spec = dataclasses.replace(self.spec, n=self.spec.n * factor)

    def point(self, tenants, qs) -> np.ndarray:
        import jax.numpy as jnp

        codes = self.codec.encode_point(qs)
        t = self._tiled_tenants(tenants, len(codes))
        out = []
        with _obs_trace.span("facade/point", n=len(codes)):
            for s in range(0, len(codes), self.spec.chunk):
                out.append(np.asarray(self.bank.point(
                    self.state, jnp.asarray(t[s:s + self.spec.chunk]),
                    jnp.asarray(codes[s:s + self.spec.chunk],
                                self.bank.bank.kdtype))))
        return np.concatenate(out) if out else np.zeros(0, bool)

    def range(self, tenants, lo, hi, use_meta: bool = True) -> np.ndarray:
        import jax.numpy as jnp

        clo, chi = self.codec.encode_bounds(lo, hi)
        t = self._tiled_tenants(tenants, len(clo))
        self._observe_ranges(clo, chi)
        if self._wl_sampler is not None:
            # adaptive tuning samples regardless of the obs-plane flag
            self._wl_sampler.observe_ranges(clo, chi)
        record_skips = _obs_metrics.enabled() and use_meta
        out = []
        with _obs_trace.span("facade/range", n=len(clo)):
            for s in range(0, len(clo), self.spec.chunk):
                tj = jnp.asarray(t[s:s + self.spec.chunk])
                lj = jnp.asarray(clo[s:s + self.spec.chunk],
                                 self.bank.bank.kdtype)
                hj = jnp.asarray(chi[s:s + self.spec.chunk],
                                 self.bank.bank.kdtype)
                out.append(np.asarray(self.bank.range(
                    self.state, tj, lj, hj,
                    self.meta if use_meta else None)))
                if record_skips:
                    # device-scalar meta-pruning telemetry; settles at
                    # registry snapshot time, never here
                    self.bank.record_meta_skips(self.meta, tj, lj, hj)
        return np.concatenate(out) if out else np.zeros(0, bool)

    def observed_fpr(self) -> dict:
        """Per-tenant live observed FPR for every tracked tenant (§15).

        Tenants join the tracked set on their first ``insert`` while
        observability is enabled (bounded by ``_MAX_FPR_TENANTS``).
        Returns ``{tenant_id: sample_dict}`` and publishes
        ``obs/fpr/tenant/<id>`` gauges."""
        import jax.numpy as jnp

        kd = self.bank.bank.kdtype
        reg = _obs_metrics.registry()
        out = {}
        for tid, s in sorted(self._fpr_tenants.items()):
            r = s.sample(
                point_probe=lambda ks, tid=tid: np.asarray(self.bank.point(
                    self.state, jnp.full(len(ks), tid, jnp.uint32),
                    jnp.asarray(ks, kd))),
                range_probe=lambda lo, hi, tid=tid: np.asarray(
                    self.bank.range(
                        self.state, jnp.full(len(lo), tid, jnp.uint32),
                        jnp.asarray(lo, kd), jnp.asarray(hi, kd),
                        self.meta)))
            out[tid] = r
            if "range_fpr" in r:
                reg.gauge(f"obs/fpr/tenant/{tid}").set(r["range_fpr"])
        return out

    def retune_report(self) -> dict:
        """Workload sample + advised promotions (``tuning='adaptive'``)."""
        if self._wl_sampler is None:
            return {"tuning": self.spec.tuning, "promotions": []}
        from .tune import fit_workload

        return {"tuning": "adaptive",
                "workload_seen": self._wl_sampler.workload_seen,
                "promotions": list(self._promote_events),
                "workload": fit_workload(
                    self.codec.d, sampler=self._wl_sampler).to_dict()}

    def size_bits(self) -> int:
        return self.bank.size_bits()


class TypedStore(_Handle):
    """The LSM run-store behind the codec boundary: typed put/get/scan.

    Integer and float keys are bijective codes — scans decode back to the
    caller's key type exactly.  String codes are lossy (7-byte prefix +
    tail hash), so the store keeps a per-code *bucket* ``{key: value}``
    and post-filters scans by true string order: collisions cost one
    bucket, never a lost key, and scans return exactly the in-range
    entries.  Multi-attribute keys are <A,B> concatenations; ``scan``
    takes ``(a, b)`` pair bounds (a lexicographic code range), so the
    conjunctive ``A == a AND B in [b_lo, b_hi]`` predicate is
    ``scan((a, b_lo), (a, b_hi))``.
    """

    def __init__(self, spec: FilterSpec, codec: _Codec):
        from .store.store import Store, StoreConfig

        super().__init__(spec, codec)
        require_x64(codec.d)
        delta = spec.delta if spec.delta is not None else 6
        self.store = Store(StoreConfig(
            d=codec.d, memtable_limit=spec.memtable_limit,
            bits_per_key=spec.resolved_bits_per_key(),
            delta=min(delta, codec.d), fanout=spec.fanout,
            level0_runs=spec.level0_runs,
            filter_backend=spec.store_backend,
            # spec.backend='xla' pins the StackedProbe scan plane; any
            # other backend lets the store pick the fused scan megakernel
            # on TPU (kernels/store_scan.py)
            scan_backend="xla" if spec.backend == "xla" else "auto",
            seed=spec.seed,
            mutability=spec.mutability,
            purge_dead_frac=spec.purge_dead_frac,
            durability=spec.durability,
            wal_dir=spec.wal_dir,
            tuning="adaptive" if spec.tuning == "adaptive" else "static"),
            _warn=False)
        self._buckets = self.codec.name == "str"

    # -- write path -------------------------------------------------------
    def _code1(self, key) -> int:
        return int(self.codec.encode_point(key)[0])

    def put(self, key, value) -> None:
        code = self._code1(key)
        with _obs_trace.span("facade/put"):
            if self._buckets:
                bucket = dict(self.store.get(code) or {})
                bucket[key] = value
                self.store.put(code, bucket)
            else:
                self.store.put(code, value)

    def delete(self, key) -> None:
        code = self._code1(key)
        if self._buckets:
            bucket = dict(self.store.get(code) or {})
            bucket.pop(key, None)
            if bucket:
                self.store.put(code, bucket)
            else:
                self.store.delete(code)
        else:
            self.store.delete(code)

    def delete_many(self, keys) -> None:
        """Batched deletes: one memtable-flush decision for the whole
        batch (``Store.delete_many``), so eviction sweeps never cascade
        flushes/compactions mid-batch."""
        if self._buckets:
            for k in keys:      # buckets need per-key read-modify-write
                self.delete(k)
            return
        self.store.delete_many(self.codec.encode_point(keys))

    def flush(self) -> None:
        self.store.flush()

    # -- durability (FilterSpec(durability='wal', wal_dir=...)) -----------
    def checkpoint(self) -> str:
        """Publish a durable checkpoint (snapshot + manifest, WAL reset);
        see ``Store.checkpoint``.  Returns the snapshot path."""
        return self.store.checkpoint()

    def scrub(self, sample_keys: int = 64, seed: int = 0) -> dict:
        """Integrity pass over every live run (``Store.scrub``)."""
        return self.store.scrub(sample_keys=sample_keys, seed=seed)

    def close(self) -> None:
        """Release the WAL file handle (the store stays readable)."""
        self.store.close()

    # -- read path --------------------------------------------------------
    def get(self, key):
        code = self._code1(key)
        with _obs_trace.span("facade/get"):
            if self._buckets:
                bucket = self.store.get(code)
                return None if bucket is None else bucket.get(key)
            return self.store.get(code)

    def get_many(self, keys) -> list:
        if self._buckets:
            return [self.get(k) for k in keys]
        codes = self.codec.encode_point(keys)
        with _obs_trace.span("facade/get", batch=len(codes)):
            return self.store.get_many(codes)

    def scan(self, lo, hi) -> list:
        return self.scan_many([lo], [hi])[0]

    def scan_many(self, los, his) -> list:
        """Batched typed scans: one fused filter gather for the batch."""
        if self._buckets:
            clo, chi = self.codec.encode_bounds(los, his)
            self._observe_ranges(clo, chi)
            with _obs_trace.span("facade/scan", batch=len(clo)):
                raw = self.store.scan_many(clo, chi)
            # typed bounds ride along: buckets post-filter by string order
            return [self._decode_scan(rows, lo, hi)
                    for rows, lo, hi in zip(raw, los, his)]
        clo, chi = self.codec.encode_bounds(np.asarray(los), np.asarray(his))
        self._observe_ranges(clo, chi)
        # iterate the encoded per-query bounds, NOT the caller's container —
        # multiattr column-form bounds are a (2, B) array whose first axis
        # is (a, b), so zipping the raw input would truncate the batch to 2
        with _obs_trace.span("facade/scan", batch=len(clo)):
            raw = self.store.scan_many(clo, chi)
        return [self._decode_scan(rows, None, None) for rows in raw]

    def _decode_scan(self, rows: list, lo, hi) -> list:
        if self._buckets:
            out = []
            for _, bucket in rows:
                out.extend((k, v) for k, v in bucket.items() if lo <= k <= hi)
            return sorted(out)
        if self.codec.name == "multiattr":
            return [((int(a), int(b)), v) for (a, b), v in
                    ((self.codec.decode(np.uint64(c)), v) for c, v in rows)]
        if self.codec.name in ("f32", "f64"):
            return [(float(self.codec.decode(np.uint64(c))), v)
                    for c, v in rows]
        return rows

    # -- device-resident probe plane (YCSB device driver) -----------------
    def encode_scan_bounds(self, los, his):
        """Typed scan bounds -> device code arrays in the store's key dtype
        (the operand format :meth:`scan_probe_device` takes)."""
        import jax.numpy as jnp

        clo, chi = self.codec.encode_bounds(np.asarray(los), np.asarray(his))
        kd = self.store.kdtype
        return jnp.asarray(clo, kd), jnp.asarray(chi, kd)

    def scan_probe_device(self, clo, chi):
        """Device-resident scan pruning over encoded bounds: ``(fence,
        touch)`` (B, R) bool jax arrays, no host round-trip.  Verdicts are
        at code level (for lossy string codes a touched run may still
        post-filter to empty)."""
        return self.store.scan_probe_device(clo, chi)

    # -- introspection ----------------------------------------------------
    @property
    def stats(self):
        return self.store.stats

    @property
    def n_runs(self) -> int:
        return self.store.n_runs

    def size_bits(self) -> int:
        return self.store.filter_bits()

    def retune_report(self) -> dict:
        """What the adaptive tuner has seen and done (DESIGN.md §16).

        For ``tuning='adaptive'``: the retune counter (compaction
        rebuilds that landed in a tuner-advised layout), the solver event
        log, the fitted ``bloomrf-workload/v1`` model, per-class standing
        decisions, and a model-vs-live cross-check for the largest live
        run's layout.  Static stores report ``{'tuning': 'static', ...}``
        so callers can branch without try/except."""
        tuner = self.store._tuner
        if tuner is None:
            return {"tuning": self.spec.tuning, "retunes": 0, "events": []}
        out = {"tuning": "adaptive",
               "retunes": int(self.store.stats.retunes)}
        out.update(tuner.report())
        runs = self.store.live_runs()
        if runs:
            big = max(runs, key=len)
            out["cross_check"] = tuner.cross_check(big.layout, len(big))
        return out

    # -- observability (DESIGN.md §15) ------------------------------------
    def register_obs(self, family: str = "store") -> str:
        """Register the store's :class:`StoreStats` as a metric family."""
        return self.store.register_obs(family)

    def observed_fpr(self) -> dict:
        """Live observed FPR from ground truth (§15).

        Reservoir candidates still present in the store are eliminated at
        sample time against the live key set — zero per-put overhead —
        and the survivors re-probe through the run filters; any
        ``fence & filter`` positive is a certain false positive.  Returns
        aggregate point/range FPR plus per-run range rates, and publishes
        the ``obs/fpr/*`` gauges."""
        from .store.memtable import TOMBSTONE

        store = self.store
        s = self._fpr_sampler()
        present = [np.asarray([k for k, v in store.mem.items()
                               if v is not TOMBSTONE], np.uint64)]
        present += [r.keys[~r.tombs] for r in store.live_runs()]
        s.mark_present(np.concatenate(present))
        klive = s.live_points()
        rlo, rhi = s.live_ranges()
        out = {"point_candidates": int(klive.size),
               "range_candidates": int(rlo.size),
               "workload_seen": s.workload_seen}
        if klive.size:
            fence, filt = store.probe_runs(klive, klive, point=True)
            out["point_fpr"] = float((fence & filt).any(axis=1).mean())
        if rlo.size:
            fence, filt = store.probe_runs(rlo, rhi)
            pos = fence & filt
            out["range_fpr"] = float(pos.any(axis=1).mean())
            out["range_fpr_per_run"] = [float(x) for x in pos.mean(axis=0)]
        if store._tuner is not None:
            # close the loop: the live sample is the cost model's
            # cross-check input (tune/cost.cross_check)
            store._tuner.record_observed(out)
        return self._record_fpr(out)


# ---------------------------------------------------------------------------
# the front door
# ---------------------------------------------------------------------------

_PLACEMENT_HANDLES = {"single": SingleFilter, "bank": BankFilter,
                      "tenant": TenantFilter, "store": TypedStore}


def open_filter(spec: FilterSpec):
    """Build the filter deployment described by ``spec``.

    Returns a :class:`SingleFilter`, :class:`BankFilter`,
    :class:`TenantFilter`, or :class:`TypedStore` according to
    ``spec.placement``; every probe surface of the returned handle encodes
    typed keys through ``core/codecs.py`` and dispatches to the
    one-fused-gather probe machinery.
    """
    if not isinstance(spec, FilterSpec):
        raise TypeError(f"open_filter takes a FilterSpec, got {type(spec)}")
    return _PLACEMENT_HANDLES[spec.placement](spec, _codec_for(spec.dtype))
