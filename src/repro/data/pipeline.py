"""Streaming training-data pipeline with bloomRF integration.

The paper's Problem 2 — existing point-range filters are *offline* — is
exactly the constraint of a streaming ingestion loop: documents arrive while
dedup/admission queries are served.  bloomRF is online, so:

* :class:`StreamDeduper` — an online dedup filter over 32-bit document-hash
  sub-domains (the 64-bit hash is range-partitioned by its top 32 bits across
  ingestion shards, matching the kernel deployment in DESIGN.md §3).  A false
  positive drops a unique document (harmless); false negatives are impossible,
  so no duplicate is ever *guaranteed* unseen.
* :class:`ShardRangeIndex` — ZoneMap-style shard admission: each corpus shard
  carries a bloomRF over document timestamps; a freshness window query
  ("any docs in [t0, t1]?") skips cold shards without reading them.
* :func:`batch_iterator` — packs deduped documents into (B, S) token batches
  with next-token labels.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional

import jax.numpy as jnp
import numpy as np

from ..core import BloomRF, basic_layout
from ..filters.api import mix64_np

__all__ = ["SyntheticCorpus", "StreamDeduper", "ShardRangeIndex",
           "batch_iterator"]


class SyntheticCorpus:
    """Deterministic zipf-token document stream with duplicates."""

    def __init__(self, vocab: int, seed: int = 0, dup_rate: float = 0.2,
                 mean_len: int = 256, n_shards: int = 8,
                 docs_per_shard: int = 128):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        self.dup_rate = dup_rate
        self.mean_len = mean_len
        self.n_shards = n_shards
        self.docs_per_shard = docs_per_shard

    def shards(self) -> Iterator[dict]:
        seen_docs: List[np.ndarray] = []
        t = 0
        for s in range(self.n_shards):
            docs, ids, stamps = [], [], []
            for _ in range(self.docs_per_shard):
                t += int(self.rng.integers(1, 20))
                if seen_docs and self.rng.random() < self.dup_rate:
                    tokens = seen_docs[self.rng.integers(len(seen_docs))]
                else:
                    n = max(8, int(self.rng.normal(self.mean_len, 32)))
                    tokens = self.rng.zipf(1.3, n).astype(np.int64) % self.vocab
                    tokens = tokens.astype(np.int32)
                    seen_docs.append(tokens)
                docs.append(tokens)
                ids.append(int(mix64_np(
                    np.asarray([hash(tokens.tobytes()) & ((1 << 63) - 1)],
                               np.uint64))[0]))
                stamps.append(t)
            yield {"shard": s, "docs": docs,
                   "doc_ids": np.asarray(ids, np.uint64),
                   "timestamps": np.asarray(stamps, np.uint64)}


class StreamDeduper:
    """Online dedup: point-query then insert (bloomRF insert_online path)."""

    def __init__(self, expected_docs: int, bits_per_key: float = 14.0):
        self.layout = basic_layout(32, expected_docs, bits_per_key, delta=6)
        self.filter = BloomRF(self.layout, _warn=False)
        self.state = self.filter.init_state()
        self.stats = {"seen": 0, "dropped": 0}

    def admit(self, doc_ids: np.ndarray) -> np.ndarray:
        """Returns a keep-mask; inserts the kept ids (online)."""
        keys = jnp.asarray(doc_ids >> np.uint64(32), jnp.uint32) ^ \
            jnp.asarray(doc_ids & np.uint64(0xFFFFFFFF), jnp.uint32)
        dup = np.asarray(self.filter.point(self.state, keys))
        keep = ~dup
        if keep.any():
            self.state = self.filter.insert_online(self.state, keys[keep])
        self.stats["seen"] += len(doc_ids)
        self.stats["dropped"] += int(dup.sum())
        return keep


class ShardRangeIndex:
    """Per-shard bloomRF over timestamps: freshness-window admission."""

    def __init__(self, bits_per_key: float = 12.0):
        self.bits_per_key = bits_per_key
        self.shards: Dict[int, tuple] = {}

    def add_shard(self, shard_id: int, timestamps: np.ndarray) -> None:
        lay = basic_layout(32, max(len(timestamps), 1), self.bits_per_key,
                           delta=6)
        f = BloomRF(lay, _warn=False)
        st = f.build(jnp.asarray(timestamps, jnp.uint32))
        self.shards[shard_id] = (f, st)

    def shards_in_window(self, t0: int, t1: int) -> List[int]:
        out = []
        for sid, (f, st) in self.shards.items():
            if bool(f.range(st, jnp.uint32(t0), jnp.uint32(t1))):
                out.append(sid)
        return out


def batch_iterator(corpus: SyntheticCorpus, batch: int, seq: int,
                   deduper: Optional[StreamDeduper] = None,
                   window: Optional[tuple] = None) -> Iterator[dict]:
    """Pack admitted documents into (B, S) token/label batches, forever."""
    index = ShardRangeIndex()
    shard_list = list(corpus.shards())
    for sh in shard_list:
        index.add_shard(sh["shard"], sh["timestamps"])
    admitted = (set(index.shards_in_window(*window)) if window is not None
                else {sh["shard"] for sh in shard_list})
    stream: List[np.ndarray] = []
    while True:
        for sh in shard_list:
            if sh["shard"] not in admitted:
                continue
            keep = (deduper.admit(sh["doc_ids"]) if deduper is not None
                    else np.ones(len(sh["docs"]), bool))
            for d, k in zip(sh["docs"], keep):
                if k:
                    stream.append(d)
            while sum(len(d) for d in stream) >= batch * (seq + 1):
                flat = np.concatenate(stream)
                take = batch * (seq + 1)
                chunk = flat[:take].reshape(batch, seq + 1)
                rest = flat[take:]
                stream = [rest] if len(rest) else []
                yield {"tokens": jnp.asarray(chunk[:, :-1]),
                       "labels": jnp.asarray(chunk[:, 1:])}
