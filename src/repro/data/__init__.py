"""Data pipeline: synthetic corpus, online bloomRF dedup + shard range
admission."""
from .pipeline import (ShardRangeIndex, StreamDeduper, SyntheticCorpus,
                        batch_iterator)

__all__ = ["SyntheticCorpus", "StreamDeduper", "ShardRangeIndex",
           "batch_iterator"]
