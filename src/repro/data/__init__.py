"""Data pipeline: synthetic corpus, online bloomRF dedup + shard range
admission."""
from .pipeline import (SyntheticCorpus, StreamDeduper, ShardRangeIndex,
                       batch_iterator)

__all__ = ["SyntheticCorpus", "StreamDeduper", "ShardRangeIndex",
           "batch_iterator"]
