"""Theoretical FPR / space model for bloomRF (paper §5–§7) plus the
comparison curves of Fig. 8 (Rosetta first-cut model and the Goswami et al.
range-emptiness lower bound, and the Carter et al. point lower bound).

Everything here is host-side float math (numpy), used by the tuning advisor,
the benchmarks, and the tests that validate our implementation against the
paper's own worked example (§7: n=3, d=16, Δ=4, m=32 -> p≈0.683,
point FPR ≈ 1%, top-level FPR ≈ 0.95).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from .layout import FilterLayout

__all__ = [
    "p_zero",
    "basic_point_fpr",
    "basic_range_fpr",
    "basic_space_for_fpr",
    "level_fprs",
    "range_fpr_max",
    "point_fpr",
    "rosetta_space_for_fpr",
    "point_lower_bound_space",
    "range_lower_bound_space",
]


# ---------------------------------------------------------------------------
# basic model (§5)
# ---------------------------------------------------------------------------

def p_zero(m_bits: float, n: int, k_inserts: float, C: float = 1.0) -> float:
    """Probability a given bit is still zero after inserting n keys that each
    set ``k_inserts`` bits in an ``m_bits`` segment.  C models distribution
    effects on PMHF scatter (C=1 for uniform/normal/zipfian, Fig. 5)."""
    if m_bits <= 0:
        return 0.0
    return float((1.0 - C / m_bits) ** (n * k_inserts))


def basic_point_fpr(d: int, n: int, m_bits: float, delta: int = 7,
                    C: float = 1.0) -> float:
    k = max(1, math.ceil((d - math.log2(max(n, 2))) / delta))
    p = p_zero(m_bits, n, k, C)
    return (1.0 - p) ** k


def basic_range_fpr(d: int, n: int, m_bits: float, R: float,
                    delta: int = 7, C: float = 1.0) -> float:
    """Eq. (6): eps <= 2 (1 - e^{-kn/m})^(k - log2(R)/delta)."""
    k = max(1, math.ceil((d - math.log2(max(n, 2))) / delta))
    p = p_zero(m_bits, n, k, C)
    expo = k - math.log2(max(R, 1.0)) / delta
    if expo <= 0:
        return 1.0
    return min(1.0, 2.0 * (1.0 - p) ** expo)


def basic_space_for_fpr(d: int, n: int, eps: float, R: float,
                        delta: int = 7) -> float:
    """Solve eq. (6) for m (bits) given a target range FPR ``eps``."""
    k = max(1, math.ceil((d - math.log2(max(n, 2))) / delta))
    expo = k - math.log2(max(R, 1.0)) / delta
    if expo <= 0:
        return float("inf")
    base = (eps / 2.0) ** (1.0 / expo)
    if base >= 1.0:
        return 0.0
    return k * n / (-math.log(1.0 - base))


# ---------------------------------------------------------------------------
# extended model (§7) — per-level FPR for arbitrary layouts
# ---------------------------------------------------------------------------

@dataclass
class LevelModel:
    fpr: np.ndarray    # (d+1,) per-level FPR
    tp: np.ndarray     # expected non-empty DIs per level
    fp: np.ndarray
    tn: np.ndarray
    p_seg: np.ndarray  # zero-bit probability per segment


def _expected_tp(d: int, n: int) -> np.ndarray:
    """Expected non-empty DIs per level, uniform keys.

    The paper's text suggests the shorthand ``min(n, 2^{d-l})`` but its worked
    example (§7: fpr_15 = 0.95 with n=3) uses the exact expectation
    ``slots * (1 - (1 - 1/slots)^n)``; we use the expectation.
    """
    lv = np.arange(d + 1, dtype=np.float64)
    slots = np.exp2(d - lv)
    out = np.empty_like(slots)
    multi = slots > 1
    with np.errstate(over="ignore"):
        out[multi] = slots[multi] * -np.expm1(
            n * np.log1p(-1.0 / slots[multi]))
    out[~multi] = 1.0  # a single slot is non-empty as soon as n >= 1
    return out


def level_fprs(layout: FilterLayout, n: int, C: float = 1.0) -> LevelModel:
    """Paper §7 'Extended Model': recursive per-level (fp, tn) estimation."""
    d = layout.d
    k = layout.k
    levels = layout.levels
    tp = _expected_tp(d, n)
    fp = np.zeros(d + 1)
    tn = np.zeros(d + 1)

    # per-segment zero-bit probability
    nseg = len(layout.seg_bits)
    p_seg = np.ones(nseg)
    for s in range(nseg):
        if layout.exact_seg is not None and s == layout.exact_seg:
            continue
        k_seg = sum(layout.replicas[i] for i in range(k)
                    if layout.seg_of_layer[i] == s)
        p_seg[s] = p_zero(layout.seg_alloc_bits[s], n, k_seg, C)

    top = layout.top_level
    # levels at/above the top covering level
    for lv in range(d, top - 1, -1):
        slots = float(2.0 ** (d - lv))
        if layout.has_exact and lv == top:
            fp[lv] = 0.0
            tn[lv] = max(slots - tp[lv], 0.0)
        elif lv == top and not layout.has_exact:
            # unstored top boundary: everything tests positive
            fp[lv] = max(slots - tp[lv], 0.0)
            tn[lv] = 0.0
        else:
            # saturated / omitted levels above the boundary
            fp[lv] = max(slots - tp[lv], 0.0)
            tn[lv] = 0.0

    for i in reversed(range(k)):
        li, li1 = levels[i], levels[i + 1]
        p = p_seg[layout.seg_of_layer[i]]
        r = layout.replicas[i]
        q_prefix = (1.0 - p) ** r  # P(single prefix passes its r probes)
        for lv in range(li1 - 1, li - 1, -1):
            span = 2.0 ** (li1 - lv)
            fp_pot = span * (fp[li1] + tp[li1]) - tp[lv]
            fp_pot = max(fp_pot, 0.0)
            run = 2.0 ** (lv - li)   # bits probed for a level-lv DI
            p_pos = 1.0 - (1.0 - q_prefix) ** run
            fp[lv] = p_pos * fp_pot
            tn[lv] = span * tn[li1] + (1.0 - p_pos) * fp_pot

    denom = fp + tn
    # 0/0 (every DI a true positive) reports FPR 0, matching the paper
    fpr = np.divide(fp, denom, out=np.zeros(d + 1), where=denom > 0)
    return LevelModel(fpr=fpr, tp=tp, fp=fp, tn=tn, p_seg=p_seg)


def range_fpr_max(layout: FilterLayout, n: int, R: float,
                  C: float = 1.0) -> float:
    """Advisor objective fpr_m: max FPR over DI levels used by ranges <= R."""
    lm = level_fprs(layout, n, C)
    top_lv = min(int(math.floor(math.log2(max(R, 1.0)))), layout.d)
    return float(np.max(lm.fpr[: top_lv + 1]))


def point_fpr(layout: FilterLayout, n: int, C: float = 1.0) -> float:
    return float(level_fprs(layout, n, C).fpr[0])


# ---------------------------------------------------------------------------
# comparison curves (Fig. 8)
# ---------------------------------------------------------------------------

def rosetta_space_for_fpr(n: int, eps: float, R: float) -> float:
    """Rosetta first-cut (F): m ≈ log2(e) * n * log2(R/eps)."""
    return math.log2(math.e) * n * math.log2(max(R, 2.0) / eps)


def point_lower_bound_space(n: int, eps: float) -> float:
    """Carter et al. [7]: m >= n log2(1/eps)."""
    return n * math.log2(1.0 / eps)


def range_lower_bound_space(n: int, eps: float, R: float, d: int = 64) -> float:
    """Goswami et al. [20] family over gamma > 1; pointwise max."""
    best = 0.0
    for g in np.geomspace(1.0 + 1e-6, 1e6, 4096):
        if g * eps >= 1.0:
            continue
        t1 = n * math.log2(R ** (1.0 - g * eps) / eps)
        inner = (1.0 - 4.0 * n * R / 2.0 ** d) * (1.0 - 1.0 / g) / math.e
        if inner <= 0:
            continue
        t2 = n * math.log2(inner)
        best = max(best, t1 + t2)
    return best
