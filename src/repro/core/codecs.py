"""Datatype support (paper §8): order-preserving codecs into integer domains.

* Floating point: the monotone map φ — flip all bits for negatives, set the
  sign bit for positives — makes the uint order match the float order.
* Variable-length strings: 7 most-significant bytes carry the first 7 chars;
  the least-significant byte carries an 8-bit hash of the full string
  (including its length).  Point queries use the full code; range bounds use
  0x00 / 0xFF tails.
* Multi-attribute: two reduced-precision (32-bit) attributes concatenated in
  both orders; conjunctive point/range predicates map to range queries over
  one of the two concatenations.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "float64_to_u64",
    "u64_to_float64",
    "float32_to_u32",
    "u32_to_float32",
    "string_point_code",
    "string_range_bounds",
    "pack2",
    "unpack2",
    "pack2x32",
    "unpack2x32",
    "multiattr_insert_codes",
    "multiattr_range_for_a_eq_b_range",
]


def float64_to_u64(x) -> np.ndarray:
    """Monotone coding: x < y  <=>  code(x) < code(y) (paper's φ)."""
    b = np.asarray(x, np.float64).view(np.uint64)
    sign = (b >> np.uint64(63)) != 0
    return np.where(sign, ~b, b | np.uint64(1 << 63))


def u64_to_float64(c) -> np.ndarray:
    c = np.asarray(c, np.uint64)
    sign = (c >> np.uint64(63)) == 0
    return np.where(sign, ~c, c & ~np.uint64(1 << 63)).view(np.float64)


def float32_to_u32(x) -> np.ndarray:
    b = np.asarray(x, np.float32).view(np.uint32)
    sign = (b >> np.uint32(31)) != 0
    return np.where(sign, ~b, b | np.uint32(1 << 31))


def u32_to_float32(c) -> np.ndarray:
    c = np.asarray(c, np.uint32)
    sign = (c >> np.uint32(31)) == 0
    return np.where(sign, ~c, c & ~np.uint32(1 << 31)).view(np.float32)


def _str_tail_hash(s: bytes) -> int:
    h = 0x9E
    for ch in s + bytes([len(s) & 0xFF]):
        h = ((h * 131) ^ ch) & 0xFF
    return h


def string_point_code(s: str | bytes) -> int:
    """SuRF-Hash-style: 7-byte prefix + 1-byte tail hash (paper §8)."""
    b = s.encode() if isinstance(s, str) else s
    prefix = b[:7].ljust(7, b"\x00")
    code = int.from_bytes(prefix, "big") << 8
    return code | _str_tail_hash(b)


def string_range_bounds(lo: str | bytes, hi: str | bytes) -> tuple:
    """Range endpoints on the 7-byte prefix: tail 0x00 below, 0xFF above."""
    bl = (lo.encode() if isinstance(lo, str) else lo)[:7].ljust(7, b"\x00")
    bh = (hi.encode() if isinstance(hi, str) else hi)[:7].ljust(7, b"\x00")
    return (int.from_bytes(bl, "big") << 8,
            (int.from_bytes(bh, "big") << 8) | 0xFF)


def pack2(a, b, b_bits: int) -> np.ndarray:
    """Order-preserving concatenation ``<A,B>`` with a ``b_bits``-wide low
    field: ``(a, b) < (a', b')`` lexicographically  <=>  code < code'.
    Generalises :func:`pack2x32`; the serve layer packs (session, chunk)
    keys through this with ``b_bits=16``."""
    a = np.asarray(a, np.uint64)
    b = np.asarray(b, np.uint64)
    return (a << np.uint64(b_bits)) | (b & np.uint64((1 << b_bits) - 1))


def unpack2(code, b_bits: int) -> tuple:
    code = np.asarray(code, np.uint64)
    return code >> np.uint64(b_bits), code & np.uint64((1 << b_bits) - 1)


def pack2x32(a, b) -> np.ndarray:
    """Concatenate two (reduced-precision) 32-bit attributes into a u64 key."""
    return pack2(a, b, 32)


def unpack2x32(code) -> tuple:
    """Split a :func:`pack2x32` code back into its (a, b) attributes."""
    return unpack2(code, 32)


def multiattr_insert_codes(a, b) -> tuple:
    """Insert both <A,B> and <B,A> (paper §8)."""
    return pack2x32(a, b), pack2x32(b, a)


def multiattr_range_for_a_eq_b_range(a_const, b_lo, b_hi) -> tuple:
    """Range [lo,hi] answering ``A == a_const AND B in [b_lo, b_hi]`` against
    the <A,B> concatenation (use the <B,A> codes for the mirrored predicate)."""
    return (pack2x32(a_const, b_lo), pack2x32(a_const, b_hi))
