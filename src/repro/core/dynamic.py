"""Dynamic-filter machinery: deletion, aging, and in-place capacity growth.

bloomRF as published is insert-only.  This module layers three orthogonal,
composable mechanisms on the flat ``uint32`` lane state without touching the
probe path (the one-fused-gather invariant of the engine is preserved — the
probed bitmap stays a plain ``uint32[total_u32]`` vector in every case):

* **Counting lanes** (:class:`CountingLanes`, :class:`DeletableBloomRF`) —
  a host-side ``uint8`` reference counter per bit beside the probed bitmap.
  Deleting a previously-inserted key decrements its positions; a counter that
  reaches zero clears its bit (:func:`clear_bits`).  Counters saturate at 255
  and *freeze*: a frozen bit is never cleared, trading a little FPR for
  unconditional false-negative freedom.

* **Generation lanes** (:class:`Generations`) — TTL/aging as ``G``
  OR-composable copies of the filter state.  Inserts land in the current
  generation; probes see the OR-collapse of all generations (valid because
  bloomRF state is union-closed: ``filter(A ∪ B) == filter(A) | filter(B)``
  under one layout).  ``advance()`` retires the oldest generation by zeroing
  it, so expired keys stop costing false positives.  A key whose generation
  retired and that was not re-inserted is *expired by contract* — reporting
  it absent is correct, not a false negative.

* **In-place capacity promotion** (:func:`promotion_factors`,
  :func:`promote_layout`, :func:`promote_state`) — grow a filter to a larger
  layout by tiling each hashed segment an integer number of times, with no
  access to the original keys.  Correctness rests on the position function
  (``core/bloomrf.py``): a layer's word index is ``h % nwords``, and for any
  integer factor ``f``, ``(h mod f*N) mod N == h mod N`` — so every bit set
  in the old segment lands (among its ``f`` tiled copies) exactly where the
  new layout would have hashed it.  Old keys keep probing positive (zero
  false negatives); the extra copies are junk bits that only add FPR, which
  the next key-rebuilding compaction washes out.  Promotion distributes over
  OR, so the store's same-class ``bitwise_or`` union invariant extends to
  promoted runs: ``promote(a | b) == promote(a) | promote(b)``.
"""
from __future__ import annotations

from functools import reduce
from typing import Callable, List, Optional

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .hashing import key_dtype_for
from .layout import FilterLayout

__all__ = [
    "promotion_factors",
    "promote_layout",
    "promote_state",
    "promote_counts",
    "clear_bits",
    "CountingLanes",
    "Generations",
    "DeletableBloomRF",
]


# ---------------------------------------------------------------------------
# in-place capacity promotion
# ---------------------------------------------------------------------------

def promotion_factors(old: FilterLayout,
                      new: FilterLayout) -> Optional[tuple]:
    """Per-segment tiling factors promoting ``old`` state to ``new``, or
    ``None`` when the pair is not promotion-compatible.

    Compatibility demands that every position the old layout computes stays
    valid (modulo segment tiling) under the new one:

    * same domain and hash seed (seeds are a deterministic stream, so equal
      seeds + equal replica width give prefix-equal seed tables);
    * ``new`` keeps a *prefix* of the old layers (``new.k <= old.k`` with
      equal deltas/replicas/segment assignment on the prefix) — larger
      capacity classes legitimately drop saturated top layers, whose old
      bits become harmless junk;
    * segment-for-segment, the new allocation is an integer multiple of the
      old one (an exact-bitmap segment is identity-mapped and must match
      exactly, factor 1).
    """
    if old.d != new.d or old.seed != new.seed:
        return None
    if new.k > old.k:
        return None
    if new.deltas != old.deltas[:new.k]:
        return None
    if new.replicas != old.replicas[:new.k]:
        return None
    if new.seg_of_layer != old.seg_of_layer[:new.k]:
        return None
    if max(old.replicas) != max(new.replicas):
        # seed tables reshape to (k, rmax); different rmax scrambles rows
        return None
    if len(new.seg_bits) != len(old.seg_bits):
        return None
    if old.exact_seg != new.exact_seg:
        return None
    factors = []
    for s in range(len(old.seg_bits)):
        ob = old.seg_alloc_bits[s]
        nb = new.seg_alloc_bits[s]
        if nb % ob != 0:
            return None
        f = nb // ob
        if old.exact_seg is not None and s == old.exact_seg:
            # identity-mapped bitmap: sizes (hence top levels) must agree
            if f != 1 or old.top_level != new.top_level:
                return None
        factors.append(f)
    return tuple(factors)


def promote_layout(layout: FilterLayout, factor: int = 4) -> FilterLayout:
    """The canonical always-promotable growth target: same layers, every
    hashed segment scaled by ``factor``.

    Scales the *allocated* (alignment-rounded) sizes so the new allocation is
    exactly ``factor`` times the old one — ``promotion_factors`` on the pair
    returns ``(factor, ...)`` by construction.  Exact-bitmap segments keep
    their identity size.
    """
    if factor < 1:
        raise ValueError(f"promotion factor must be >= 1, got {factor}")
    seg_bits = []
    for s, alloc in enumerate(layout.seg_alloc_bits):
        if layout.exact_seg is not None and s == layout.exact_seg:
            seg_bits.append(layout.seg_bits[s])
        else:
            seg_bits.append(alloc * factor)
    return dataclasses.replace(layout, seg_bits=tuple(seg_bits))


def promote_state(state: jax.Array, old: FilterLayout,
                  new: FilterLayout) -> jax.Array:
    """Map ``uint32`` filter state from ``old`` to ``new`` by segment tiling.

    Supports leading batch dims (tenant banks carry ``[T, S, U]`` states).
    Raises ``ValueError`` for incompatible pairs — callers that want a
    fallback should check :func:`promotion_factors` first.
    """
    factors = promotion_factors(old, new)
    if factors is None:
        raise ValueError("layouts are not promotion-compatible")
    state = jnp.asarray(state)
    if state.shape[-1] != old.total_u32:
        raise ValueError(
            f"state has {state.shape[-1]} lanes, old layout {old.total_u32}")
    out = jnp.zeros(state.shape[:-1] + (new.total_u32,), jnp.uint32)
    for s, f in enumerate(factors):
        o_lo, o_n = old.seg_off_bits[s] // 32, old.seg_alloc_bits[s] // 32
        n_lo, n_n = new.seg_off_bits[s] // 32, new.seg_alloc_bits[s] // 32
        reps = (1,) * (state.ndim - 1) + (f,)
        tiled = jnp.tile(state[..., o_lo:o_lo + o_n], reps)
        out = out.at[..., n_lo:n_lo + n_n].set(tiled)
    return out


def promote_counts(counts: np.ndarray, old: FilterLayout,
                   new: FilterLayout) -> np.ndarray:
    """Tile counting lanes alongside :func:`promote_state`.

    Each tiled copy inherits the full counter: after promotion a key's
    position resolves to exactly one copy, whose counter still covers its
    contribution; the other copies decay into the same junk bits the state
    tiling produces (cleared only if their counters drain, never causing a
    false negative).
    """
    factors = promotion_factors(old, new)
    if factors is None:
        raise ValueError("layouts are not promotion-compatible")
    out = np.zeros(new.total_bits, np.uint8)
    for s, f in enumerate(factors):
        o_lo, o_n = old.seg_off_bits[s], old.seg_alloc_bits[s]
        n_lo = new.seg_off_bits[s]
        out[n_lo:n_lo + o_n * f] = np.tile(counts[o_lo:o_lo + o_n], f)
    return out


# ---------------------------------------------------------------------------
# bit clearing + counting lanes (deletable filters)
# ---------------------------------------------------------------------------

def clear_bits(state: jax.Array, pos) -> jax.Array:
    """Clear the given bit positions in packed ``uint32`` lane state."""
    pos = np.asarray(pos, np.int64).reshape(-1)
    if pos.size == 0:
        return state
    state = jnp.asarray(state)
    mask = np.zeros(state.shape[-1], np.uint32)
    np.bitwise_or.at(mask, pos >> 5,
                     np.uint32(1) << (pos & 31).astype(np.uint32))
    return state & jnp.asarray(~mask)


class CountingLanes:
    """Host-side ``uint8`` reference counters, one per filter bit.

    The probed bitmap stays untouched — counters live beside it and only
    decide *when* a bit may be cleared.  Counters saturate at
    :attr:`SATURATE` and freeze there: a saturated counter is never
    decremented and its bit is never cleared (conservative positives, never
    false negatives).
    """

    SATURATE = 255

    __slots__ = ("counts",)

    def __init__(self, total_bits: int, counts: Optional[np.ndarray] = None):
        if counts is not None:
            counts = np.asarray(counts, np.uint8)
            if counts.shape != (total_bits,):
                raise ValueError("counter/total_bits shape mismatch")
            self.counts = counts.copy()
        else:
            self.counts = np.zeros(total_bits, np.uint8)

    def add(self, pos) -> None:
        """Count one contribution per occurrence in ``pos`` (duplicates from
        colliding keys in one batch each count)."""
        pos = np.asarray(pos, np.int64).reshape(-1)
        if pos.size == 0:
            return
        upos, cnt = np.unique(pos, return_counts=True)
        cur = self.counts[upos].astype(np.int64)
        self.counts[upos] = np.minimum(cur + cnt, self.SATURATE).astype(np.uint8)

    def remove(self, pos) -> np.ndarray:
        """Decrement contributions; return the positions that drained to
        zero (whose bits the caller may now clear).  Saturated counters are
        frozen and never drain."""
        pos = np.asarray(pos, np.int64).reshape(-1)
        if pos.size == 0:
            return pos
        upos, cnt = np.unique(pos, return_counts=True)
        cur = self.counts[upos].astype(np.int64)
        frozen = cur >= self.SATURATE
        new = np.maximum(cur - cnt, 0)
        new[frozen] = self.SATURATE
        self.counts[upos] = new.astype(np.uint8)
        return upos[(new == 0) & (cur > 0)]

    def promoted(self, old: FilterLayout, new: FilterLayout) -> "CountingLanes":
        return CountingLanes(new.total_bits,
                             counts=promote_counts(self.counts, old, new))


# ---------------------------------------------------------------------------
# generation lanes (TTL / aging)
# ---------------------------------------------------------------------------

class Generations:
    """``G`` OR-composable copies of arbitrary filter state (any pytree of
    ``uint32`` arrays) giving sweep-free TTL semantics.

    Inserts go to the current generation; probes read :attr:`collapsed`
    (the element-wise OR of all generations — sound because bloomRF state is
    union-closed).  :meth:`advance` rotates to the next slot and zeroes it,
    retiring whatever the oldest generation still held: a key inserted into
    the current generation is dropped by the ``n_generations``-th subsequent
    advance (sooner if its slot comes up earlier in the rotation), after
    which it stops costing false positives.  Expiry is the contract — a retired key probing absent
    is correct behaviour, not a false negative.
    """

    __slots__ = ("zero_fn", "gens", "current", "_collapsed", "advances")

    def __init__(self, zero_fn: Callable[[], object], n_generations: int = 4):
        if n_generations < 2:
            raise ValueError(
                f"need >= 2 generations for aging, got {n_generations}")
        self.zero_fn = zero_fn
        self.gens: List[object] = [zero_fn() for _ in range(n_generations)]
        self.current = 0
        self.advances = 0
        self._collapsed = None

    @property
    def n_generations(self) -> int:
        return len(self.gens)

    def insert(self, fn: Callable, *args) -> None:
        """Apply ``fn(current_state, *args) -> new_state`` to the current
        generation."""
        self.gens[self.current] = fn(self.gens[self.current], *args)
        self._collapsed = None

    @property
    def collapsed(self):
        """OR of all generations — the state every probe should read."""
        if self._collapsed is None:
            self._collapsed = reduce(
                lambda a, b: jax.tree_util.tree_map(jnp.bitwise_or, a, b),
                self.gens)
        return self._collapsed

    def advance(self) -> None:
        """Retire the oldest generation (zero it) and make it current."""
        self.current = (self.current + 1) % len(self.gens)
        self.gens[self.current] = self.zero_fn()
        self.advances += 1
        self._collapsed = None

    def map(self, fn: Callable,
            zero_fn: Optional[Callable] = None) -> "Generations":
        """Rebuild with ``fn`` applied to every generation (e.g. promotion
        to a larger layout).  Pass the new shape's ``zero_fn`` whenever
        ``fn`` changes the state shape."""
        out = Generations.__new__(Generations)
        out.zero_fn = zero_fn if zero_fn is not None else self.zero_fn
        out.gens = [fn(g) for g in self.gens]
        out.current = self.current
        out.advances = self.advances
        out._collapsed = None
        return out


# ---------------------------------------------------------------------------
# deletable filter facade over BloomRF
# ---------------------------------------------------------------------------

class DeletableBloomRF:
    """BloomRF plus counting lanes: supports ``delete`` of previously
    inserted keys.

    The probed state is the same flat ``uint32`` vector as plain BloomRF —
    ``point``/``range`` delegate unchanged, so the engine's one-fused-gather
    property and all kernels keep working.  Deleting a key that was never
    inserted (or inserted fewer times than deleted) is a contract violation
    and may corrupt the filter, exactly as with classic counting Blooms.
    """

    def __init__(self, layout: FilterLayout):
        from .bloomrf import BloomRF

        self.layout = layout
        self.filter = BloomRF(layout, _warn=False)
        self.counts = CountingLanes(layout.total_bits)
        self.kdtype = key_dtype_for(layout.d)
        self._posf = jax.jit(jax.vmap(self.filter._positions_one))

    def init_state(self) -> jax.Array:
        return self.filter.init_state()

    def _positions(self, keys) -> np.ndarray:
        keys = jnp.atleast_1d(jnp.asarray(keys, self.kdtype))
        return np.asarray(self._posf(keys)).reshape(-1)

    def insert(self, state: jax.Array, keys) -> jax.Array:
        pos = self._positions(keys)
        self.counts.add(pos)
        return self.filter.scatter_or(
            state, jnp.asarray(pos, self.filter.pos_dtype))

    def delete(self, state: jax.Array, keys) -> jax.Array:
        zeroed = self.counts.remove(self._positions(keys))
        return clear_bits(state, zeroed)

    def point(self, state: jax.Array, ys) -> jax.Array:
        return self.filter.point(state, ys)

    def range(self, state: jax.Array, lo, hi) -> jax.Array:
        return self.filter.range(state, lo, hi)

    def promoted(self, new_layout: FilterLayout,
                 state: jax.Array) -> tuple:
        """(new DeletableBloomRF, promoted state) under ``new_layout``."""
        out = DeletableBloomRF(new_layout)
        out.counts = self.counts.promoted(self.layout, new_layout)
        return out, promote_state(state, self.layout, new_layout)
