"""Tuning advisor (paper §7).

Given standard parameters — number of keys ``n``, memory budget ``m`` (bits),
an (approximate max) query-range size ``R`` and the domain width ``d`` — the
advisor computes a full bloomRF configuration: the distance vector Δ, replica
counts r_i, segment assignment j_i and the three segment sizes (m1, m2, m3),
minimizing the weighted norm ``fpr_w^2 = fpr_m^2 + C^2 * fpr_p^2``.

Heuristics follow the paper:
* exact level candidates: smallest l with 2^(d-l) < 0.6 m, and that +1;
* bottom layers use Δ=7 (64-bit words), distances shrink towards the exact
  level (e.g. target 36 -> Δ = (7,7,7,7,4,2,2) bottom-first);
* one replica everywhere except the topmost hashed layer (2);
* m1 = exact bitmap, m2 = mid layers (Δ<7), m3 = bottom layers; m2 is swept.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import numpy as np

from .layout import FilterLayout
from .model import level_fprs

__all__ = ["advise", "AdvisorResult"]


def _delta_vector(target: int) -> list:
    """Bottom-first Δ vector summing to ``target``; big words at the bottom,
    halving distances towards the top (paper's example: 36 -> 7,7,7,7,4,2,2)."""
    deltas = []
    rem = target
    while rem >= 14:
        deltas.append(7)
        rem -= 7
    if rem == 7:
        deltas.append(7)
        rem = 0
    while rem > 0:
        step = rem if rem <= 2 else min(7, max(2, rem // 2))
        deltas.append(step)
        rem -= step
    if not deltas:
        deltas = [1]
    return deltas


@dataclass
class AdvisorResult:
    layout: FilterLayout
    fpr_point: float
    fpr_range_max: float
    fpr_w: float
    exact_level: int


def _build_candidate(d: int, n: int, m_bits: int, exact_level: int,
                     m2_frac: float, seed: int) -> Optional[FilterLayout]:
    m1 = 1 << (d - exact_level)
    if m1 >= m_bits:
        return None
    rest = m_bits - m1
    deltas = _delta_vector(exact_level)
    k = len(deltas)
    # segment assignment: bottom (Δ==7) -> seg 2 (m3); mid -> seg 1 (m2)
    seg_of_layer = tuple(2 if dl == 7 else 1 for dl in deltas)
    if all(s == 2 for s in seg_of_layer):
        seg_of_layer = tuple([2] * (k - 1) + [1])  # topmost layer -> mid seg
    replicas = [1] * k
    replicas[-1] = 2  # topmost hashed layer gets error-correction replica
    m2 = int(rest * m2_frac)
    m3 = rest - m2
    if m2 < 256 or m3 < 256:
        return None
    try:
        return FilterLayout(
            d=d,
            deltas=tuple(deltas),
            replicas=tuple(replicas),
            seg_of_layer=seg_of_layer,
            seg_bits=(m1, m2, m3),
            exact_seg=0,
            seed=seed,
        )
    except ValueError:
        return None


def advise(d: int, n: int, m_bits: int, R: float,
           point_weight: float = 1.0, C: float = 1.0,
           seed: int = 0x0B100F11) -> AdvisorResult:
    """Select a bloomRF configuration for ranges up to ``R`` within ``m_bits``.

    Raises ``ValueError`` for out-of-range inputs (d outside 1..64,
    non-positive n or m_bits, R < 1) and when no feasible configuration
    exists within the memory budget — never a silent bad layout or a
    deep assertion failure."""
    if not 1 <= d <= 64:
        raise ValueError(f"d must be in 1..64 (uint64 key domain), got {d}")
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    if m_bits < 1:
        raise ValueError(f"m_bits must be >= 1, got {m_bits}")
    if not R >= 1:
        raise ValueError(f"R must be >= 1, got {R}")
    # exact level heuristic: smallest level whose bitmap is < 60% of budget
    l_e = next((lv for lv in range(d + 1) if 2.0 ** (d - lv) < 0.6 * m_bits),
               None)
    if l_e is None:
        raise ValueError(
            f"advisor found no feasible exact level for d={d} within "
            f"m_bits={m_bits}; increase the memory budget")
    l_e = max(1, l_e)
    top_range_lv = min(int(math.ceil(math.log2(max(R, 2.0)))), d)

    best: Optional[AdvisorResult] = None
    for cand in {l_e, min(l_e + 1, d)}:
        for frac in np.linspace(0.15, 0.75, 9):
            lay = _build_candidate(d, n, m_bits, cand, float(frac), seed)
            if lay is None:
                continue
            lm = level_fprs(lay, n, C)
            fpr_p = float(lm.fpr[0])
            fpr_m = float(np.max(lm.fpr[: top_range_lv + 1]))
            fpr_w = math.hypot(fpr_m, point_weight * fpr_p)
            if best is None or fpr_w < best.fpr_w:
                best = AdvisorResult(lay, fpr_p, fpr_m, fpr_w, cand)
    if best is None:
        raise ValueError(
            f"advisor found no feasible configuration for d={d} n={n} "
            f"m={m_bits} R={R}; increase the memory budget"
        )
    return best
