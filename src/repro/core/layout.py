"""Static layout of a bloomRF filter.

Everything here is host-side python/numpy computed once at construction time;
under ``jax.jit`` all layout quantities are trace-time constants, so the
compiled filter kernels contain no data-dependent shapes or control flow.

Terminology follows the paper (Table 1):

* ``d``            — domain bits (UINT8..UINT64 domains).
* layer ``i``      — index of a (PMHF) hash function, bottom-first ``0..k-1``.
* ``deltas[i]``    — distance :math:`\\Delta_i` between level ``l_i`` and
                     ``l_{i+1}``; bottom-first (paper writes the vector
                     top-first: ``(2,2,4,7,7,7,7)`` == deltas ``(7,7,7,7,4,2,2)``).
* ``levels[i]``    — dyadic level handled by layer ``i``; ``levels[k]`` is the
                     *top covering level* (either ``d``, the saturation cut, or
                     the exact-bitmap level).
* word ``W_i``     — :math:`2^{\\Delta_i-1}` bits; the unit PMHF read/write.
                     Represented as 1–2 uint32 lanes (W in {1,2,4,8,16,32,64}).
* replicas ``r_i`` — replicated hash functions per layer (error correction).
* segments        — the bit-array is split into segments ``m_1..m_S``; each
                     hashed layer is assigned one segment; at most one segment
                     is an *exact* (identity-mapped) bitmap of level
                     ``levels[k]``.
"""
from __future__ import annotations

import dataclasses
import math
from functools import cached_property
from typing import Optional

import numpy as np

from .hashing import derive_seeds

__all__ = ["FilterLayout", "basic_layout", "require_x64"]

_LANE = 32  # storage lane width (uint32)


def require_x64(d: int) -> None:
    """Raise a helpful error when 64-bit keys are used without the x64 flag."""
    if d > 32:
        import jax

        if not jax.config.jax_enable_x64:
            raise RuntimeError(
                f"bloomRF with a {d}-bit domain needs uint64 keys: enable x64 "
                "(jax.config.update('jax_enable_x64', True)) before tracing, "
                "or use a domain of <= 32 bits."
            )


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class FilterLayout:
    """Frozen bloomRF configuration + derived addressing tables."""

    d: int                                 # domain bits
    deltas: tuple                          # bottom-first Δ_i, len k
    replicas: tuple                        # r_i per layer, len k
    seg_of_layer: tuple                    # segment index per hashed layer
    seg_bits: tuple                        # requested bits per segment
    exact_seg: Optional[int] = None        # which segment is the exact bitmap
    seed: int = 0x0B100F11  # "bloomRF"
    max_exact_scan_lanes: int = 1 << 14    # range-scan cap on the exact bitmap

    # ------------------------------------------------------------------
    # validation
    # ------------------------------------------------------------------
    def __post_init__(self):
        k = len(self.deltas)
        if k == 0:
            raise ValueError("need at least one layer")
        if len(self.replicas) != k or len(self.seg_of_layer) != k:
            raise ValueError("deltas/replicas/seg_of_layer length mismatch")
        for dl in self.deltas:
            if not (1 <= dl <= 7):
                raise ValueError(f"delta must be in 1..7 (word <= 64 bits), got {dl}")
        for r in self.replicas:
            if r < 1:
                raise ValueError("replicas must be >= 1")
        if sum(self.deltas) > self.d:
            raise ValueError(
                f"levels overflow domain: sum(deltas)={sum(self.deltas)} > d={self.d}"
            )
        nseg = len(self.seg_bits)
        for s in self.seg_of_layer:
            if not (0 <= s < nseg):
                raise ValueError("seg_of_layer out of range")
            if self.exact_seg is not None and s == self.exact_seg:
                raise ValueError("hashed layers cannot live in the exact segment")
        if self.exact_seg is not None:
            if not (0 <= self.exact_seg < nseg):
                raise ValueError("exact_seg out of range")
            need = 1 << (self.d - self.top_level)
            if self.seg_bits[self.exact_seg] < need:
                raise ValueError(
                    f"exact segment needs 2^(d-l_e) = {need} bits, "
                    f"got {self.seg_bits[self.exact_seg]}"
                )
        # every hashed segment must fit at least 2 words of each resident layer
        for i in range(k):
            if self.nwords(i) < 2:
                raise ValueError(f"segment of layer {i} too small for its word size")

    # ------------------------------------------------------------------
    # derived quantities (all python ints / numpy — trace-time constants)
    # ------------------------------------------------------------------
    @property
    def k(self) -> int:
        return len(self.deltas)

    @cached_property
    def levels(self) -> tuple:
        """levels[i] for i in 0..k; levels[k] is the top covering level."""
        lv = [0]
        for dl in self.deltas:
            lv.append(lv[-1] + dl)
        return tuple(lv)

    @property
    def top_level(self) -> int:
        return self.levels[self.k]

    @property
    def has_exact(self) -> bool:
        return self.exact_seg is not None

    @property
    def exact_level(self) -> Optional[int]:
        return self.top_level if self.has_exact else None

    def word_bits(self, i: int) -> int:
        return 1 << (self.deltas[i] - 1)

    @cached_property
    def _seg_alloc(self) -> tuple:
        """(aligned_bits, offset_bits) per segment.

        A segment hosting 64-bit words must start and size-align to 64 bits
        (so W=64 words begin on even lanes); everything else aligns to 32.
        """
        aligns = []
        for s in range(len(self.seg_bits)):
            a = 32
            for i in range(len(self.deltas)):
                if self.seg_of_layer[i] == s and self.word_bits(i) == 64:
                    a = 64
            aligns.append(a)
        offs, sizes = [], []
        cur = 0
        for s, bits in enumerate(self.seg_bits):
            if self.exact_seg is not None and s == self.exact_seg:
                bits = 1 << (self.d - self.top_level)  # exact size, no rounding
            aligned = _round_up(max(bits, aligns[s]), aligns[s])
            cur = _round_up(cur, aligns[s])
            offs.append(cur)
            sizes.append(aligned)
            cur += aligned
        return tuple(sizes), tuple(offs)

    @property
    def seg_alloc_bits(self) -> tuple:
        return self._seg_alloc[0]

    @property
    def seg_off_bits(self) -> tuple:
        return self._seg_alloc[1]

    @property
    def total_bits(self) -> int:
        sizes, offs = self._seg_alloc
        return _round_up(offs[-1] + sizes[-1], 32)

    @property
    def total_u32(self) -> int:
        return self.total_bits // _LANE

    def nwords(self, i: int) -> int:
        """Number of PMHF words of layer i in its segment."""
        s = self.seg_of_layer[i]
        return self.seg_alloc_bits[s] // self.word_bits(i)

    @property
    def exact_off_bits(self) -> int:
        assert self.exact_seg is not None
        return self.seg_off_bits[self.exact_seg]

    @property
    def exact_nbits(self) -> int:
        assert self.exact_seg is not None
        return 1 << (self.d - self.top_level)

    @cached_property
    def seeds(self) -> np.ndarray:
        """uint64 seeds, shape (k, max_replicas)."""
        rmax = max(self.replicas)
        flat = derive_seeds(self.seed, self.k * rmax)
        return flat.reshape(self.k, rmax)

    @property
    def bits_per_key(self) -> float:
        """Bits set per inserted key (hashed replicas + exact bit)."""
        return sum(self.replicas) + (1 if self.has_exact else 0)

    def describe(self) -> str:
        rows = [
            f"bloomRF layout: d={self.d} k={self.k} total_bits={self.total_bits}"
            f" (~{self.total_bits/1024:.1f} Kbit) exact_level="
            f"{self.exact_level} top_level={self.top_level}"
        ]
        for i in reversed(range(self.k)):
            rows.append(
                f"  layer {i}: levels [{self.levels[i]},{self.levels[i+1]}) "
                f"delta={self.deltas[i]} word={self.word_bits(i)}b "
                f"r={self.replicas[i]} seg={self.seg_of_layer[i]} "
                f"nwords={self.nwords(i)}"
            )
        for s, (bits, off) in enumerate(zip(self.seg_alloc_bits, self.seg_off_bits)):
            kind = "exact" if s == self.exact_seg else "hashed"
            rows.append(f"  segment {s}: {bits} bits @ {off} ({kind})")
        return "\n".join(rows)


# ---------------------------------------------------------------------------
# constructors
# ---------------------------------------------------------------------------

def basic_layout(
    d: int,
    n_keys: int,
    bits_per_key: float = 16.0,
    delta: int = 7,
    seed: int = 0x0B100F11,
) -> FilterLayout:
    """Basic (tuning-free) bloomRF of the paper (§3–§5).

    Equidistant levels ``l_i = i*delta``; ``k = ceil((d - log2 n)/delta)``
    hash functions (saturated top levels omitted); a single shared segment of
    ``n * bits_per_key`` bits; one hash function per layer; no exact layer.
    """
    if n_keys < 1:
        raise ValueError("n_keys must be >= 1")
    log2n = math.log2(max(n_keys, 2))
    k = max(1, math.ceil((d - log2n) / delta))
    k = min(k, max(1, math.ceil(d / delta)))
    # clamp levels into the domain: shrink top distances if sum overflows d
    deltas = [delta] * k
    while sum(deltas) > d:
        if deltas[-1] > 1:
            deltas[-1] -= 1
        else:
            deltas.pop()
    k = len(deltas)
    # every resident layer needs >= 2 words in its segment
    min_bits = 2 * (1 << (max(deltas) - 1))
    m = _round_up(max(int(n_keys * bits_per_key), min_bits, 64), 64)
    return FilterLayout(
        d=d,
        deltas=tuple(deltas),
        replicas=(1,) * k,
        seg_of_layer=(0,) * k,
        seg_bits=(m,),
        exact_seg=None,
        seed=seed,
    )
