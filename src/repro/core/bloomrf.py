"""bloomRF: point-range filter with prefix hashing and piecewise-monotone
hash functions (PMHF), in pure JAX.

Design notes (see DESIGN.md §5):

* The filter state is a flat ``uint32[total_u32]`` vector ("lanes").  A PMHF
  *word* of ``W = 2^{Δ-1}`` bits is 1–2 lanes (W in {1,2,4,8,16,32,64}); words
  never straddle lanes because W | 32 or W == 64 with even-lane alignment.
* All control flow is branch-free: the two-path range lookup evaluates every
  layer with live/dead path masks (the paper's early-stop becomes a mask AND —
  identical results, SIMD/TPU friendly).  The k-layer loop is unrolled at
  trace time; every shape is static.
* Insert / point / range are pure functions of ``(state, keys)`` and are
  jit/vmap-compatible.  64-bit domains require the x64 flag (see
  ``layout.require_x64``).
* ``point``/``range`` route through the plan->gather->combine probe engine
  (``core/engine.py``, DESIGN.md §9): one fused ``state[lanes]`` gather per
  batch, covering-bit loads deduped against child-word loads.  The scalar
  pre-engine path survives as ``point_reference``/``range_reference`` — the
  bit-identity oracle for the engine and the Pallas kernels.

False-negative freedom: insert and every probe share the single pair of
position functions ``_load_word`` / ``_bit_probe``; property tests exercise
this exhaustively on small domains and randomly on 64-bit domains.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .hashing import key_dtype_for, mix
from .layout import FilterLayout, require_x64

__all__ = ["BloomRF"]

_FULL = 0xFFFFFFFF


def _mask_u32(a, b):
    """uint32 mask with bits [a..b] set; empty when b < a. a,b int32 (clamped)."""
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    a_c = jnp.clip(a, 0, 32)
    b_c = jnp.clip(b + 1, 0, 32)
    width = jnp.maximum(b_c - a_c, 0)
    sh_w = jnp.minimum(width, 31).astype(jnp.uint32)
    base = jnp.where(
        width >= 32, jnp.uint32(_FULL), (jnp.uint32(1) << sh_w) - jnp.uint32(1)
    )
    sh_a = jnp.minimum(a_c, 31).astype(jnp.uint32)
    return jnp.where(width > 0, base << sh_a, jnp.uint32(0))


class BloomRF:
    """Unified point-range filter (paper §3–§7).

    Direct construction is a legacy entry point: the typed façade
    (``repro.open_filter``) builds filters from a :class:`~repro.api.FilterSpec`
    and threads key codecs and tuning for you.  In-tree call sites pass
    ``_warn=False`` (see ``repro._compat``).
    """

    def __init__(self, layout: FilterLayout, *, _warn: bool = True):
        if _warn:
            from .._compat import warn_legacy

            warn_legacy("BloomRF(layout)",
                        "dtype=..., n=..., placement='single', backend='xla'")
        require_x64(layout.d)
        self.layout = layout
        self.kdtype = key_dtype_for(layout.d)
        self.pos_dtype = jnp.int64 if layout.d > 32 else jnp.int32
        # trace-time constant tables
        self._seeds = layout.seeds  # np.uint64 (k, rmax)
        self._probes_per_key = sum(layout.replicas) + (1 if layout.has_exact else 0)
        self._engine = None

    @property
    def engine(self):
        """The plan->gather->combine probe engine (core/engine.py), lazily
        built; ``point``/``range`` route through it.  The legacy scalar path
        stays available as ``point_reference``/``range_reference``."""
        if self._engine is None:
            from .engine import ProbeEngine

            self._engine = ProbeEngine(self)
        return self._engine

    # -- helpers ---------------------------------------------------------
    def _kd(self, v):
        return jnp.asarray(v, self.kdtype)

    def _shr(self, x, s: int):
        """x >> s with the static s == d case (full shift-out) well-defined."""
        if s >= self.layout.d and s >= (32 if self.layout.d <= 32 else 64):
            return jnp.zeros_like(x)
        return x >> s

    def init_state(self) -> jax.Array:
        return jnp.zeros(self.layout.total_u32, jnp.uint32)

    # ------------------------------------------------------------------
    # position computation (shared by insert and probes)
    # ------------------------------------------------------------------
    def _positions_one(self, x):
        """All bit positions set/probed for key ``x`` (static count)."""
        lay = self.layout
        x = self._kd(x)
        out = []
        for i in range(lay.k):
            li = lay.levels[i]
            delta = lay.deltas[i]
            W = lay.word_bits(i)
            nw = lay.nwords(i)
            offbits = lay.seg_off_bits[lay.seg_of_layer[i]]
            off = (x >> li) & self._kd(W - 1)
            wkey = x >> (li + delta - 1)
            for rep in range(lay.replicas[i]):
                h = mix(wkey, self._seeds[i, rep], lay.d)
                widx = (h % np.asarray(nw, h.dtype)).astype(self.kdtype)
                bitpos = self._kd(offbits) + widx * self._kd(W) + off
                out.append(bitpos.astype(self.pos_dtype))
        if lay.has_exact:
            bitpos = self._kd(lay.exact_off_bits) + self._shr(x, lay.top_level)
            out.append(bitpos.astype(self.pos_dtype))
        return jnp.stack(out)

    # ------------------------------------------------------------------
    # insertion
    # ------------------------------------------------------------------
    def scatter_or(self, state: jax.Array, pos: jax.Array,
                   vals: Optional[jax.Array] = None,
                   bitmap: bool = False) -> jax.Array:
        """OR bit positions into the packed state.  ``vals`` (optional, same
        shape as ``pos``) masks which positions take effect — the sharded
        filter bank uses it to drop keys owned by other shards while keeping
        this lane-packing convention in one place.

        Default path: lane-packed scatter-add.  Positions are sorted,
        duplicates masked to a scrap lane, and each surviving position adds
        its single bit to its lane — distinct bits in a lane sum to their OR,
        so the transient is O(n log n) sort work + one uint32[total_u32 + 1]
        buffer instead of the O(total_bits) bool bitmap (a 2M-key build no
        longer materialises a 32M-element temp).  ``bitmap=True`` keeps the
        legacy bit-expanded path for exactness tests."""
        if bitmap:
            temp = jnp.zeros(self.layout.total_bits, jnp.bool_)
            temp = (temp.at[pos].set(True) if vals is None
                    else temp.at[pos].max(vals))
            lanes = temp.reshape(-1, 32).astype(jnp.uint32)
            packed = jnp.sum(
                lanes << jnp.arange(32, dtype=jnp.uint32)[None, :],
                axis=1, dtype=jnp.uint32)
            return state | packed
        pos = jnp.asarray(pos, self.pos_dtype)
        if pos.shape[0] == 0:
            return state
        scrap = jnp.asarray(self.layout.total_bits, self.pos_dtype)
        if vals is not None:
            pos = jnp.where(vals, pos, scrap)
        ps = jnp.sort(pos)
        keep = jnp.concatenate(
            [jnp.ones((1,), jnp.bool_), ps[1:] != ps[:-1]]) & (ps < scrap)
        lane = jnp.where(keep, (ps >> 5).astype(jnp.int32),
                         self.layout.total_u32)
        bit = jnp.where(keep, jnp.uint32(1) << (ps & 31).astype(jnp.uint32),
                        jnp.uint32(0))
        packed = jnp.zeros(self.layout.total_u32 + 1,
                           jnp.uint32).at[lane].add(bit)
        return state | packed[:-1]

    def insert(self, state: jax.Array, keys) -> jax.Array:
        """Bulk insert via the lane-packed ``scatter_or`` (sort + dedup +
        scatter-add; no O(total_bits) transient).  Exact w.r.t. duplicate
        positions."""
        keys = jnp.atleast_1d(jnp.asarray(keys, self.kdtype))
        pos = jax.vmap(self._positions_one)(keys).reshape(-1)
        return self.scatter_or(state, pos)

    def insert_online(self, state: jax.Array, keys) -> jax.Array:
        """Streaming insert (no O(m) temp): sequential read-modify-write OR.
        Suited to small online batches; bulk builds should use ``insert``."""
        keys = jnp.atleast_1d(jnp.asarray(keys, self.kdtype))
        pos = jax.vmap(self._positions_one)(keys)  # (B, P)
        lane = (pos >> 5).astype(jnp.int32)
        mask = jnp.uint32(1) << (pos & 31).astype(jnp.uint32)

        def body(j, st):
            for t in range(self._probes_per_key):
                ln = lane[j, t]
                st = st.at[ln].set(st[ln] | mask[j, t])
            return st

        return jax.lax.fori_loop(0, keys.shape[0], body, state)

    def build(self, keys) -> jax.Array:
        return self.insert(self.init_state(), keys)

    def build_np(self, keys_np: np.ndarray, chunk: int = 1 << 20) -> jax.Array:
        """Host-side chunked bulk build for very large key sets (numpy
        OR-scatter); bounds peak memory to one position chunk when the key
        set itself dwarfs device memory."""
        buf = np.zeros(self.layout.total_u32, np.uint32)
        posf = jax.jit(jax.vmap(self._positions_one))
        for s in range(0, len(keys_np), chunk):
            pos = np.asarray(posf(jnp.asarray(keys_np[s:s + chunk], self.kdtype)))
            pos = pos.reshape(-1)
            np.bitwise_or.at(buf, pos >> 5, np.uint32(1) << (pos & 31).astype(np.uint32))
        return jnp.asarray(buf)

    # ------------------------------------------------------------------
    # point lookup
    # ------------------------------------------------------------------
    def point(self, state: jax.Array, ys) -> jax.Array:
        """Batched point lookup via the probe engine (one fused gather)."""
        ys = jnp.asarray(ys, self.kdtype)
        scalar = ys.ndim == 0
        ys = jnp.atleast_1d(ys)
        res = self.engine.point_batched(state, ys)
        return res[0] if scalar else res

    def point_reference(self, state: jax.Array, ys) -> jax.Array:
        """Pre-engine point path (per-key gather); the bit-identity oracle
        for the engine and the Pallas kernels (kernels/ref.py)."""
        ys = jnp.asarray(ys, self.kdtype)
        scalar = ys.ndim == 0
        ys = jnp.atleast_1d(ys)
        pos = jax.vmap(self._positions_one)(ys)  # (B, P)
        lane = (pos >> 5).astype(jnp.int32)
        sh = (pos & 31).astype(jnp.uint32)
        bits = (state[lane] >> sh) & jnp.uint32(1)
        res = jnp.all(bits == 1, axis=1)
        return res[0] if scalar else res

    # ------------------------------------------------------------------
    # word-level probes (range machinery)
    # ------------------------------------------------------------------
    def _load_word(self, state, i: int, wordkey):
        """Load the layer-i word addressed by ``wordkey`` (= prefix >> (Δ-1)),
        AND-combined across replicas.  Returns (lo, hi) uint32 lanes; hi == 0
        for W <= 32."""
        lay = self.layout
        W = lay.word_bits(i)
        nw = lay.nwords(i)
        offbits = lay.seg_off_bits[lay.seg_of_layer[i]]
        lo = jnp.uint32(_FULL)
        hi = jnp.uint32(_FULL) if W == 64 else jnp.uint32(0)
        for rep in range(lay.replicas[i]):
            h = mix(wordkey, self._seeds[i, rep], lay.d)
            widx = (h % np.asarray(nw, h.dtype)).astype(self.kdtype)
            bitoff = self._kd(offbits) + widx * self._kd(W)
            lane = (bitoff >> 5).astype(jnp.int32)
            v = state[lane]
            if W == 64:
                lo = lo & v
                hi = hi & state[lane + 1]
            elif W == 32:
                lo = lo & v
            else:
                sh = (bitoff & 31).astype(jnp.uint32)
                lo = lo & ((v >> sh) & jnp.uint32((1 << W) - 1))
        return lo, hi

    def _bit_probe(self, state, i: int, x):
        """Single covering-bit probe at layer i for key x (replica-ANDed)."""
        lay = self.layout
        li = lay.levels[i]
        delta = lay.deltas[i]
        W = lay.word_bits(i)
        off = ((x >> li) & self._kd(W - 1)).astype(jnp.uint32)
        lo, hi = self._load_word(state, i, x >> (li + delta - 1))
        bit_lo = (lo >> jnp.minimum(off, 31)) & jnp.uint32(1)
        if W == 64:
            bit_hi = (hi >> (jnp.maximum(off, 32) - 32)) & jnp.uint32(1)
            bit = jnp.where(off < 32, bit_lo, bit_hi)
        else:
            bit = bit_lo
        return bit != 0

    def _mask_pair(self, a, b, W: int):
        """(lo, hi) uint32 masks for bit range [a..b] in a W-bit word."""
        if W <= 32:
            return _mask_u32(a, b), jnp.uint32(0)
        return _mask_u32(a, jnp.minimum(b, 31)), _mask_u32(a - 32, b - 32)

    def _children_any(self, state, i: int, parent, qlo, qhi, nonempty):
        """Test whether any prefix in [qlo, qhi] (children of ``parent`` at
        layer i) has its bit set.  <= 2 word loads (the paper's PMHF payoff)."""
        lay = self.layout
        delta = lay.deltas[i]
        W = lay.word_bits(i)
        base = parent << delta
        last = base | self._kd((1 << delta) - 1)
        qlo_c = jnp.clip(qlo, base, last)
        qhi_c = jnp.clip(qhi, base, last)
        o_lo = (qlo_c - base).astype(jnp.int32)  # 0..2W-1
        o_hi = (qhi_c - base).astype(jnp.int32)
        # a parent always has 2^delta = 2W children -> exactly two words
        wkA = parent << 1
        wkB = (parent << 1) | self._kd(1)
        loA, hiA = self._load_word(state, i, wkA)
        mAlo, mAhi = self._mask_pair(o_lo, jnp.minimum(o_hi, W - 1), W)
        acc = (loA & mAlo) | (hiA & mAhi)
        loB, hiB = self._load_word(state, i, wkB)
        # empty automatically when o_hi < W (negative b -> zero mask)
        mBlo, mBhi = self._mask_pair(jnp.maximum(o_lo - W, 0), o_hi - W, W)
        acc = acc | (loB & mBlo) | (hiB & mBhi)
        return nonempty & (acc != 0)

    # ------------------------------------------------------------------
    # exact-bitmap probes
    # ------------------------------------------------------------------
    def _exact_bit(self, state, prefix):
        lay = self.layout
        pos = (self._kd(lay.exact_off_bits) + prefix).astype(self.pos_dtype)
        lane = (pos >> 5).astype(jnp.int32)
        sh = (pos & 31).astype(jnp.uint32)
        return ((state[lane] >> sh) & jnp.uint32(1)) != 0

    def _exact_range_any(self, state, qlo, qhi, nonempty):
        """Any exact-bitmap bit set in prefix range [qlo, qhi]?  Bounded lane
        scan (cap -> conservative True: the paper's R-bound)."""
        lay = self.layout
        nbits = lay.exact_nbits
        qlo_c = jnp.clip(qlo, self._kd(0), self._kd(nbits - 1))
        qhi_c = jnp.clip(qhi, self._kd(0), self._kd(nbits - 1))
        p0 = (self._kd(lay.exact_off_bits) + qlo_c).astype(self.pos_dtype)
        p1 = (self._kd(lay.exact_off_bits) + qhi_c).astype(self.pos_dtype)
        lane0 = (p0 >> 5).astype(jnp.int32)
        lane1 = (p1 >> 5).astype(jnp.int32)
        b0 = (p0 & 31).astype(jnp.int32)
        b1 = (p1 & 31).astype(jnp.int32)
        over_cap = (lane1 - lane0 + 1) > lay.max_exact_scan_lanes
        # scan at most the cap; over-cap queries answer conservatively True
        lane_end = jnp.minimum(lane1, lane0 + lay.max_exact_scan_lanes - 1)

        def cond(c):
            ln, found = c
            return jnp.logical_and(~found, ln <= lane_end)

        def body(c):
            ln, found = c
            m = _mask_u32(jnp.where(ln == lane0, b0, 0),
                          jnp.where(ln == lane1, b1, 31))
            return ln + 1, found | ((state[ln] & m) != 0)

        _, any_hit = jax.lax.while_loop(cond, body, (lane0, jnp.asarray(False)))
        return nonempty & (over_cap | any_hit)

    # ------------------------------------------------------------------
    # range lookup: two-path dyadic decomposition (paper §4, Algorithm 1)
    # ------------------------------------------------------------------
    def _range_one(self, state, L, R):
        lay = self.layout
        L = self._kd(L)
        R = self._kd(R)
        L, R = jnp.minimum(L, R), jnp.maximum(L, R)
        top = lay.top_level
        false = jnp.asarray(False)

        if top >= lay.d:
            # levels cover the whole domain: single covering path from the top
            result = false
            split = false
            left_alive = jnp.asarray(True)
            right_alive = false
        else:
            lt = self._shr(L, top)
            rt = self._shr(R, top)
            split = lt != rt
            if lay.has_exact:
                covL = self._exact_bit(state, lt)
                covR = self._exact_bit(state, rt)
                mid_nonempty = (rt - lt) >= self._kd(2)
                one = self._kd(1)
                result = self._exact_range_any(state, lt + one, rt - one,
                                               mid_nonempty)
                left_alive = covL
                right_alive = covR & split
            else:
                # saturated top levels omitted: a middle gap of >= 1 full
                # top-level DI is untestable -> conservative positive
                result = (rt - lt) >= self._kd(2)
                left_alive = jnp.asarray(True)
                right_alive = split

        for i in reversed(range(lay.k)):
            li = lay.levels[i]
            li1 = lay.levels[i + 1]
            delta = lay.deltas[i]
            bottom = i == 0
            Lp = self._shr(L, li)
            Rp = self._shr(R, li)
            Lpar = self._shr(L, li1)
            Rpar = self._shr(R, li1)
            one = self._kd(1)
            edge = self._kd(0) if bottom else one

            # --- left path (doubles as the single pre-split path)
            l_end = (Lpar << delta) | self._kd((1 << delta) - 1)
            l_qlo = Lp + edge
            l_qhi = jnp.where(split, l_end, Rp - edge)
            if bottom:
                l_nonempty_pre = jnp.asarray(True)
                l_nonempty_post = jnp.asarray(True)
            else:
                l_nonempty_pre = (Rp - Lp) >= self._kd(2)
                l_nonempty_post = Lp != l_end
            l_nonempty = jnp.where(split, l_nonempty_post, l_nonempty_pre)
            hit_l = self._children_any(state, i, Lpar, l_qlo, l_qhi,
                                       l_nonempty & left_alive)
            result = result | hit_l

            # --- right path (only live after the split)
            r_start = Rpar << delta
            r_qhi = Rp - edge
            r_nonempty = jnp.asarray(True) if bottom else (Rp != r_start)
            hit_r = self._children_any(state, i, Rpar, r_start, r_qhi,
                                       r_nonempty & right_alive)
            result = result | hit_r

            # --- covering continuation (early-stop as mask AND)
            if not bottom:
                covL = self._bit_probe(state, i, L)
                covR = self._bit_probe(state, i, R)
                new_split = split | (Lp != Rp)
                nxt_left = left_alive & covL
                nxt_right = jnp.where(split, right_alive, left_alive & new_split)
                nxt_right = nxt_right & covR
                left_alive, right_alive, split = nxt_left, nxt_right, new_split

        return result

    def range(self, state: jax.Array, lo, hi) -> jax.Array:
        """Batched range lookup via the probe engine: one fused gather of
        the deduped word table, then register-only combine (DESIGN.md §9)."""
        lo = jnp.asarray(lo, self.kdtype)
        hi = jnp.asarray(hi, self.kdtype)
        scalar = lo.ndim == 0
        lo = jnp.atleast_1d(lo)
        hi = jnp.atleast_1d(hi)
        res = self.engine.range_batched(state, lo, hi)
        return res[0] if scalar else res

    def range_reference(self, state: jax.Array, lo, hi) -> jax.Array:
        """Pre-engine range path (vmapped scalar ``_range_one``); the
        bit-identity oracle for the engine and the Pallas kernels."""
        lo = jnp.asarray(lo, self.kdtype)
        hi = jnp.asarray(hi, self.kdtype)
        scalar = lo.ndim == 0
        lo = jnp.atleast_1d(lo)
        hi = jnp.atleast_1d(hi)
        res = jax.vmap(partial(self._range_one, state))(lo, hi)
        return res[0] if scalar else res

    # ------------------------------------------------------------------
    # cost accounting (fig. 12g)
    # ------------------------------------------------------------------
    def word_accesses_per_range_query(self) -> int:
        """Static word loads per range query under the deduped engine plan
        (paper: <= 4/layer, times replicas).

        Each layer costs exactly the two child-word pairs of the left and
        right parents (2 paths x 2 words x replicas); the two covering-bit
        probes are served from those same words — the covering word of ``x``
        at layer i is child word A or B of ``x``'s parent — so they add
        nothing.  Exact layouts add the two exact covering bits plus one
        amortized lane for the bounded middle scan.  The engine's static
        plan matches this count (``ProbeEngine.range_word_loads``); a test
        asserts the correspondence including the gather width ``A``."""
        lay = self.layout
        total = sum(4 * lay.replicas[i] for i in range(lay.k))
        if lay.has_exact and lay.top_level < lay.d:
            total += 3  # two covering bits + (amortized) mid scan
        return total

    def word_accesses_per_point_query(self) -> int:
        lay = self.layout
        return sum(lay.replicas) + (1 if lay.has_exact else 0)
