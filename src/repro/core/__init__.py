"""bloomRF core: the paper's contribution as a composable JAX module."""
from .bloomrf import BloomRF
from .engine import (PointPlan, ProbeEngine, RangePlan, StackedProbe,
                     stacked_probe)
from .hashing import dyadic_prefixes, key_dtype_for
from .layout import FilterLayout, basic_layout, require_x64

__all__ = [
    "FilterLayout",
    "basic_layout",
    "require_x64",
    "BloomRF",
    "ProbeEngine",
    "RangePlan",
    "PointPlan",
    "StackedProbe",
    "stacked_probe",
    "dyadic_prefixes",
    "key_dtype_for",
]
