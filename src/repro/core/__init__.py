"""bloomRF core: the paper's contribution as a composable JAX module."""
from .bloomrf import BloomRF
from .codecs import (float32_to_u32, float64_to_u64, multiattr_insert_codes,
                     multiattr_range_for_a_eq_b_range, pack2, pack2x32,
                     string_point_code, string_range_bounds, u32_to_float32,
                     u64_to_float64, unpack2, unpack2x32)
from .dynamic import (CountingLanes, DeletableBloomRF, Generations,
                      clear_bits, promote_counts, promote_layout,
                      promote_state, promotion_factors)
from .engine import (PointPlan, ProbeEngine, RangePlan, StackedProbe,
                     stacked_probe)
from .hashing import dyadic_prefixes, key_dtype_for
from .layout import FilterLayout, basic_layout, require_x64

__all__ = [
    "FilterLayout",
    "basic_layout",
    "require_x64",
    "BloomRF",
    "ProbeEngine",
    "RangePlan",
    "PointPlan",
    "StackedProbe",
    "stacked_probe",
    "dyadic_prefixes",
    "key_dtype_for",
    # dynamic-filter machinery: deletion, aging, in-place growth
    "CountingLanes",
    "DeletableBloomRF",
    "Generations",
    "clear_bits",
    "promote_counts",
    "promote_layout",
    "promote_state",
    "promotion_factors",
    # order-preserving codecs (paper §8) — the typed façade's key layer
    "float64_to_u64",
    "u64_to_float64",
    "float32_to_u32",
    "u32_to_float32",
    "string_point_code",
    "string_range_bounds",
    "pack2",
    "unpack2",
    "pack2x32",
    "unpack2x32",
    "multiattr_insert_codes",
    "multiattr_range_for_a_eq_b_range",
]
