"""bloomRF core: the paper's contribution as a composable JAX module."""
from .layout import FilterLayout, basic_layout, require_x64
from .bloomrf import BloomRF
from .hashing import key_dtype_for

__all__ = [
    "FilterLayout",
    "basic_layout",
    "require_x64",
    "BloomRF",
    "key_dtype_for",
]
