"""Hash functions for bloomRF.

The paper uses ``h_i(x) = (a_i + b_i * x) mod m`` (multiply-add mod prime).
On TPU VPUs integer multiplies are cheap but division/mod by non-constants is
not, so we use the splitmix64 / murmur3-finalizer mixing family (Dietzfelbinger
multiply-shift style): full-width wrapping multiplies + xor-shifts, which give
avalanche behaviour at least as good as the paper's multiplicative hashing.
The FPR model (core/model.py) is hash-agnostic; tests verify the empirical FPR
matches the model, which is the property the paper relies on.

All functions are pure jnp and work both inside and outside jit.  Key dtype is
uint32 for domains d <= 32 bits and uint64 for d <= 64 (requires the x64 flag;
see repro.core.layout.require_x64).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "splitmix64_np",
    "derive_seeds",
    "dyadic_prefixes",
    "mix64",
    "mix32",
    "mix",
    "key_dtype_for",
]

_U64 = np.uint64

# ---------------------------------------------------------------------------
# Host-side (numpy) seed derivation
# ---------------------------------------------------------------------------

def splitmix64_np(state: int) -> tuple[int, int]:
    """One splitmix64 step on python ints. Returns (new_state, output)."""
    mask = (1 << 64) - 1
    state = (state + 0x9E3779B97F4A7C15) & mask
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & mask
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & mask
    z = z ^ (z >> 31)
    return state, z


def derive_seeds(base_seed: int, n: int) -> np.ndarray:
    """Derive ``n`` decorrelated 64-bit seeds from a base seed (host side)."""
    out = np.empty(n, dtype=_U64)
    s = base_seed & ((1 << 64) - 1)
    for i in range(n):
        s, z = splitmix64_np(s)
        out[i] = _U64(z)
    return out


# ---------------------------------------------------------------------------
# Device-side mixing
# ---------------------------------------------------------------------------

def mix64(x):
    """splitmix64 finalizer on uint64 arrays (wrapping arithmetic)."""
    x = jnp.asarray(x, jnp.uint64)
    x = (x ^ (x >> jnp.uint64(30))) * jnp.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> jnp.uint64(27))) * jnp.uint64(0x94D049BB133111EB)
    x = x ^ (x >> jnp.uint64(31))
    return x


def mix32(x):
    """murmur3-style 32-bit finalizer on uint32 arrays."""
    x = jnp.asarray(x, jnp.uint32)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


def mix(x, seed, key_bits: int):
    """Seeded finalizer in the key dtype. ``seed`` is a python/numpy uint64."""
    if key_bits > 32:
        return mix64(jnp.asarray(x, jnp.uint64) ^ jnp.uint64(seed))
    return mix32(jnp.asarray(x, jnp.uint32) ^ jnp.uint32(int(seed) & 0xFFFFFFFF))


def key_dtype_for(d: int):
    """Key dtype for a d-bit domain."""
    if d <= 32:
        return jnp.uint32
    if d <= 64:
        return jnp.uint64
    raise ValueError(f"domain bits must be <= 64, got {d}")


def dyadic_prefixes(keys, level: int, d: int):
    """Dyadic prefixes of ``keys`` at ``level``: the keys with their low
    ``level`` bits dropped, living in the ``d - level``-bit prefix domain.

    A key ``k`` is in ``[lo, hi]`` only if ``k >> level`` is in
    ``[lo >> level, hi >> level]``, so a filter built over these prefixes
    answers coarse range queries with no false negatives — the building
    block for Bloofi-style meta-filters over shard summaries
    (``dist/tenant_bank.py``).
    """
    if not (0 <= level < d):
        raise ValueError(f"need 0 <= level < d, got level={level}, d={d}")
    keys = jnp.asarray(keys, key_dtype_for(d))
    return (keys >> level).astype(key_dtype_for(d - level))
