"""Plan -> gather -> combine probe engine (DESIGN.md §9).

The reference range lookup (``BloomRF._range_one``) interleaves address
computation with state reads: per layer it issues two ``_children_any``
word-pair loads and two ``_bit_probe`` covering loads, each an independent
one-element dynamic gather that ``vmap`` turns into a separate batched
gather op — ~6 word loads per layer per query, serialised behind a long
chain of gathers.  This module refactors the probe path into three phases
with *one* fused gather per query batch:

1. **plan** — a trace-time pass over the static layout emits, per query,
   the full table of uint32 *lane* addresses needed by the two-path dyadic
   decomposition.  Two dedup facts shrink the table:

   * the covering-bit word of ``x`` at layer ``i`` is addressed by
     ``x >> (l_i + Δ_i - 1) == (parent << 1) | b`` — i.e. it is always one
     of the two child words ``parent << 1`` / ``(parent << 1) | 1`` that
     ``_children_any`` fetches for the same layer, so covering probes cost
     **zero** extra loads (6/layer -> 4/layer, times replicas);
   * replicas are flattened into the same table instead of looping loads.

   The plan also carries the query-dependent extraction metadata (intra-lane
   shifts for sub-lane words, the clipped child-offset masks' inputs) that
   the combine phase needs — all pure arithmetic, no state access.

2. **gather** — a single batched ``state[lanes]`` of shape ``(B, A)``
   fetches every word for the whole query tile at once.  ``A`` is the
   static *gather width* (``ProbeEngine.range_gather_width``); the jaxpr of
   the batched range probe contains exactly one gather over the filter
   state (asserted in ``tests/test_engine.py``).

3. **combine** — the reference live/dead path algebra evaluated purely on
   registers: child-range masks, covering-bit selects (choose child word A
   or B by the parent-side bit), and the alive-mask recurrence.  Combine is
   bit-identical to ``_range_one`` by construction — same hash formulas,
   same mask algebra, same clip/select order.

Exact-bitmap layouts: the two exact covering bits join the fused gather;
the bounded middle lane scan stays a dynamic ``while_loop`` outside the
static plan (it is the one data-dependent part of the lookup), so exact
layouts gain the dedup on every hashed layer but keep their scan.

Everything here is batched natively on ``(B,)`` query vectors — no
``vmap`` — which is what lets the Pallas kernels trace the engine directly
over a tile and what the sharded banks route through.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .bloomrf import _FULL, BloomRF
from .hashing import mix

__all__ = ["ProbeEngine", "RangePlan", "PointPlan", "StackedProbe",
           "stacked_probe"]


class _Slot(NamedTuple):
    """One planned word load: column(s) in the lane table + extraction info."""

    col: int                       # first column in the (B, A) lane table
    sh: Optional[jax.Array]        # (B,) intra-lane bit shift (W < 32 only)


class RangePlan(NamedTuple):
    """Static-width address table + metadata for one range-query batch."""

    lanes: jax.Array               # (B, A) int32 — every state lane touched
    layers: tuple                  # per layer: {LA,LB,RA,RB: (slots...)}
    exact: Optional[tuple]         # ((col, sh) for L, (col, sh) for R)
    L: jax.Array                   # (B,) normalised query bounds
    R: jax.Array


class PointPlan(NamedTuple):
    lanes: jax.Array               # (B, P) int32
    sh: jax.Array                  # (B, P) uint32


class ProbeEngine:
    """Layout-bound plan/gather/combine evaluator for a :class:`BloomRF`.

    Construct via ``BloomRF.engine`` (lazily cached); the engine shares the
    filter's seeds and addressing formulas, so its verdicts are bit-identical
    to the reference scalar path (``point_reference`` / ``range_reference``).
    """

    def __init__(self, filt: BloomRF):
        self.filt = filt
        self.lay = filt.layout
        self._seeds = filt.layout.seeds
        # static plan accounting (word loads vs gathered lanes)
        loads = 0
        width = 0
        for i in range(self.lay.k):
            per_word_lanes = 2 if self.lay.word_bits(i) == 64 else 1
            loads += 4 * self.lay.replicas[i]
            width += 4 * self.lay.replicas[i] * per_word_lanes
        if self.lay.has_exact and self.lay.top_level < self.lay.d:
            loads += 2
            width += 2
        #: word loads in the static range plan (4/layer/replica + exact bits)
        self.range_word_loads = loads
        #: columns of the fused (B, A) gather — lanes, not words (W=64 -> 2)
        self.range_gather_width = width

    # ------------------------------------------------------------------
    # plan
    # ------------------------------------------------------------------
    def _word_slots(self, i: int, wordkey, cols: list) -> Tuple[_Slot, ...]:
        """Plan the replica loads of the layer-``i`` word at ``wordkey``.

        Address math mirrors ``BloomRF._load_word`` exactly (same hash, same
        modulo, same lane split) so the gathered values are the same lanes
        the reference implementation reads."""
        f, lay = self.filt, self.lay
        W = lay.word_bits(i)
        nw = lay.nwords(i)
        offbits = lay.seg_off_bits[lay.seg_of_layer[i]]
        slots = []
        for rep in range(lay.replicas[i]):
            h = mix(wordkey, self._seeds[i, rep], lay.d)
            widx = (h % np.asarray(nw, h.dtype)).astype(f.kdtype)
            bitoff = f._kd(offbits) + widx * f._kd(W)
            lane = (bitoff >> 5).astype(jnp.int32)
            col = len(cols)
            cols.append(lane)
            if W == 64:
                cols.append(lane + 1)
                slots.append(_Slot(col, None))
            elif W == 32:
                slots.append(_Slot(col, None))
            else:
                slots.append(_Slot(col, (bitoff & f._kd(31)).astype(jnp.uint32)))
        return tuple(slots)

    def _exact_slot(self, prefix, cols: list):
        f, lay = self.filt, self.lay
        pos = (f._kd(lay.exact_off_bits) + prefix).astype(f.pos_dtype)
        lane = (pos >> 5).astype(jnp.int32)
        col = len(cols)
        cols.append(lane)
        return col, (pos & 31).astype(jnp.uint32)

    def plan_range(self, lo, hi) -> RangePlan:
        """Emit the per-query lane table for the two-path decomposition.

        Per layer the plan holds exactly four words x replicas — the child
        word pairs of the left and right parents; covering bits are served
        from the same words (see module docstring), so no covering loads
        appear in the table."""
        f, lay = self.filt, self.lay
        L = f._kd(lo)
        R = f._kd(hi)
        L, R = jnp.minimum(L, R), jnp.maximum(L, R)
        cols: list = []
        layers = []
        for i in range(lay.k):
            li1 = lay.levels[i + 1]
            Lpar = f._shr(L, li1)
            Rpar = f._shr(R, li1)
            one = f._kd(1)
            layers.append({
                "LA": self._word_slots(i, Lpar << 1, cols),
                "LB": self._word_slots(i, (Lpar << 1) | one, cols),
                "RA": self._word_slots(i, Rpar << 1, cols),
                "RB": self._word_slots(i, (Rpar << 1) | one, cols),
            })
        exact = None
        if lay.has_exact and lay.top_level < lay.d:
            exact = (self._exact_slot(f._shr(L, lay.top_level), cols),
                     self._exact_slot(f._shr(R, lay.top_level), cols))
        lanes = jnp.stack(cols, axis=-1)
        return RangePlan(lanes, tuple(layers), exact, L, R)

    def plan_point(self, ys) -> PointPlan:
        pos = jax.vmap(self.filt._positions_one)(ys)        # (B, P)
        return PointPlan((pos >> 5).astype(jnp.int32),
                         (pos & 31).astype(jnp.uint32))

    # ------------------------------------------------------------------
    # gather
    # ------------------------------------------------------------------
    def gather(self, state: jax.Array, lanes: jax.Array) -> jax.Array:
        """The one fused load: every word for the batch in a single gather."""
        return state[lanes]

    # ------------------------------------------------------------------
    # combine
    # ------------------------------------------------------------------
    def _word(self, g, i: int, slots):
        """Replica-ANDed (lo, hi) lanes of one planned word (cf. _load_word)."""
        W = self.lay.word_bits(i)
        lo = jnp.uint32(_FULL)
        hi = jnp.uint32(_FULL) if W == 64 else jnp.uint32(0)
        for s in slots:
            v = g[..., s.col]
            if W == 64:
                lo = lo & v
                hi = hi & g[..., s.col + 1]
            elif W == 32:
                lo = lo & v
            else:
                lo = lo & ((v >> s.sh) & jnp.uint32((1 << W) - 1))
        return lo, hi

    def _children_any(self, i: int, parent, qlo, qhi, nonempty, wa, wb):
        """``BloomRF._children_any`` on pre-gathered word pairs (wa, wb)."""
        f, lay = self.filt, self.lay
        delta = lay.deltas[i]
        W = lay.word_bits(i)
        base = parent << delta
        last = base | f._kd((1 << delta) - 1)
        qlo_c = jnp.clip(qlo, base, last)
        qhi_c = jnp.clip(qhi, base, last)
        o_lo = (qlo_c - base).astype(jnp.int32)
        o_hi = (qhi_c - base).astype(jnp.int32)
        mAlo, mAhi = f._mask_pair(o_lo, jnp.minimum(o_hi, W - 1), W)
        acc = (wa[0] & mAlo) | (wa[1] & mAhi)
        mBlo, mBhi = f._mask_pair(jnp.maximum(o_lo - W, 0), o_hi - W, W)
        acc = acc | (wb[0] & mBlo) | (wb[1] & mBhi)
        return nonempty & (acc != 0)

    def _cov_bit(self, i: int, x, wa, wb):
        """Covering-bit probe served from the deduped child words: the word
        of ``x >> (l_i + Δ_i - 1)`` *is* child word A or B of ``x``'s parent,
        selected by the low parent-side bit — no extra load."""
        f, lay = self.filt, self.lay
        li = lay.levels[i]
        delta = lay.deltas[i]
        W = lay.word_bits(i)
        off = ((x >> li) & f._kd(W - 1)).astype(jnp.uint32)
        b = ((x >> (li + delta - 1)) & f._kd(1)) != 0
        lo = jnp.where(b, wb[0], wa[0])
        bit_lo = (lo >> jnp.minimum(off, 31)) & jnp.uint32(1)
        if W == 64:
            hi = jnp.where(b, wb[1], wa[1])
            bit_hi = (hi >> (jnp.maximum(off, 32) - 32)) & jnp.uint32(1)
            bit = jnp.where(off < 32, bit_lo, bit_hi)
        else:
            bit = bit_lo
        return bit != 0

    def combine_range(self, g: jax.Array, plan: RangePlan,
                      state: Optional[jax.Array] = None) -> jax.Array:
        """Branch-free verdicts from the gathered word matrix.

        ``state`` is only consulted for exact-bitmap layouts (the bounded
        middle scan is dynamic); hashed-only layouts combine on registers.
        """
        f, lay = self.filt, self.lay
        L, R = plan.L, plan.R
        top = lay.top_level
        false = jnp.asarray(False)

        if top >= lay.d:
            result = false
            split = false
            left_alive = jnp.asarray(True)
            right_alive = false
        else:
            lt = f._shr(L, top)
            rt = f._shr(R, top)
            split = lt != rt
            if lay.has_exact:
                if state is None:
                    raise ValueError(
                        "exact-bitmap layouts need `state` for the bounded "
                        "middle scan (combine_range(..., state=state))")
                (colL, shL), (colR, shR) = plan.exact
                covL = ((g[..., colL] >> shL) & jnp.uint32(1)) != 0
                covR = ((g[..., colR] >> shR) & jnp.uint32(1)) != 0
                mid_nonempty = (rt - lt) >= f._kd(2)
                one = f._kd(1)
                result = jax.vmap(
                    lambda a, b, ne: f._exact_range_any(state, a, b, ne)
                )(lt + one, rt - one, mid_nonempty)
                left_alive = covL
                right_alive = covR & split
            else:
                result = (rt - lt) >= f._kd(2)
                left_alive = jnp.asarray(True)
                right_alive = split

        for i in reversed(range(lay.k)):
            li = lay.levels[i]
            delta = lay.deltas[i]
            bottom = i == 0
            Lp = f._shr(L, li)
            Rp = f._shr(R, li)
            Lpar = f._shr(L, lay.levels[i + 1])
            Rpar = f._shr(R, lay.levels[i + 1])
            one = f._kd(1)
            edge = f._kd(0) if bottom else one
            wLA = self._word(g, i, plan.layers[i]["LA"])
            wLB = self._word(g, i, plan.layers[i]["LB"])
            wRA = self._word(g, i, plan.layers[i]["RA"])
            wRB = self._word(g, i, plan.layers[i]["RB"])

            # --- left path (doubles as the single pre-split path)
            l_end = (Lpar << delta) | f._kd((1 << delta) - 1)
            l_qlo = Lp + edge
            l_qhi = jnp.where(split, l_end, Rp - edge)
            if bottom:
                l_nonempty = jnp.asarray(True)
            else:
                l_nonempty = jnp.where(split, Lp != l_end,
                                       (Rp - Lp) >= f._kd(2))
            hit_l = self._children_any(i, Lpar, l_qlo, l_qhi,
                                       l_nonempty & left_alive, wLA, wLB)
            result = result | hit_l

            # --- right path (only live after the split)
            r_start = Rpar << delta
            r_qhi = Rp - edge
            r_nonempty = jnp.asarray(True) if bottom else (Rp != r_start)
            hit_r = self._children_any(i, Rpar, r_start, r_qhi,
                                       r_nonempty & right_alive, wRA, wRB)
            result = result | hit_r

            # --- covering continuation (early-stop as mask AND), bits pulled
            #     from the already-gathered child words
            if not bottom:
                covL = self._cov_bit(i, L, wLA, wLB)
                covR = self._cov_bit(i, R, wRA, wRB)
                new_split = split | (Lp != Rp)
                nxt_left = left_alive & covL
                nxt_right = jnp.where(split, right_alive, left_alive & new_split)
                nxt_right = nxt_right & covR
                left_alive, right_alive, split = nxt_left, nxt_right, new_split

        return result

    def combine_point(self, g: jax.Array, plan: PointPlan) -> jax.Array:
        bits = (g >> plan.sh) & jnp.uint32(1)
        return jnp.all(bits == 1, axis=-1)

    # ------------------------------------------------------------------
    # fused entry points
    # ------------------------------------------------------------------
    # jax.named_scope below is a trace-time annotation only: it adds NO
    # jaxpr equations, so the fused-probe invariants (and the jaxpr text
    # itself) are identical with observability on or off (tests/test_obs.py)
    def range_batched(self, state: jax.Array, lo, hi) -> jax.Array:
        with jax.named_scope("bloomrf/plan"):
            plan = self.plan_range(lo, hi)
        with jax.named_scope("bloomrf/gather"):
            g = self.gather(state, plan.lanes)
        with jax.named_scope("bloomrf/combine"):
            return self.combine_range(
                g, plan, state=state if self.lay.has_exact else None)

    def point_batched(self, state: jax.Array, ys) -> jax.Array:
        with jax.named_scope("bloomrf/plan"):
            plan = self.plan_point(ys)
        with jax.named_scope("bloomrf/gather"):
            g = self.gather(state, plan.lanes)
        with jax.named_scope("bloomrf/combine"):
            return self.combine_point(g, plan)


# ---------------------------------------------------------------------------
# multi-filter stacked plan: R filter rows, ONE fused gather
# ---------------------------------------------------------------------------

class StackedProbe:
    """Probe ``R`` stacked filter rows with one fused gather per query batch.

    The rows live in a single flat ``uint32`` state vector; row ``r`` starts
    at the static lane offset ``bases[r]`` and is addressed by
    ``engines[r]`` (rows may use different layouts — an LSM store stacks
    runs of several capacity classes, the tenant bank stacks main + meta
    rows).  The plan phase emits every row's lane table with the row base
    folded in, concatenates them along the lane axis, and issues a single
    ``flat_state[lanes]`` gather of shape ``(B, sum_r A_r)``; each row's
    verdict is then combined on registers exactly as
    :meth:`ProbeEngine.combine_range` would for that row alone — verdicts
    are bit-identical to probing each row separately.

    Rows are processed as maximal *spans* of consecutive rows sharing a
    layout, so bounds are selected with slices and verdicts re-assembled
    with concatenation: the jaxpr of ``range_all``/``point_all`` contains
    exactly one gather over the filter state, whatever the row mix
    (asserted in the test suite).  Query bounds are either shared across
    rows (shape ``(B,)``) or per-row (shape ``(B, R)`` — e.g. per-shard
    clipped ranges).  Exact-bitmap layouts are rejected: their bounded
    middle scan is a dynamic loop that cannot join the static plan.
    """

    def __init__(self, engines: Tuple[ProbeEngine, ...], bases: Tuple[int, ...]):
        if not engines:
            raise ValueError("need at least one stacked row")
        if len(engines) != len(bases):
            raise ValueError(
                f"{len(engines)} engines vs {len(bases)} row bases")
        kdtype = engines[0].filt.kdtype
        for e in engines:
            if e.lay.has_exact:
                raise ValueError(
                    "exact-bitmap layouts cannot be stacked (their bounded "
                    "middle scan is dynamic); use per-row engine probes")
            if e.filt.kdtype != kdtype:
                raise ValueError("stacked rows must share one key dtype")
        self.engines = tuple(engines)
        self.bases = tuple(int(b) for b in bases)
        self.R = len(engines)
        # maximal consecutive spans sharing a layout: (engine, row0, row1)
        spans = []
        for r, e in enumerate(self.engines):
            if spans and spans[-1][0].filt.layout == e.filt.layout:
                spans[-1] = (spans[-1][0], spans[-1][1], r + 1)
            else:
                spans.append((e, r, r + 1))
        self.spans = tuple(spans)
        #: columns of the one fused (B, A) range gather, summed over rows
        self.range_gather_width = sum(
            (r1 - r0) * e.range_gather_width for e, r0, r1 in self.spans)
        self._range_jit = jax.jit(self._range_all)
        self._point_jit = jax.jit(self._point_all)
        self._touch_jit = jax.jit(self._touch_all)

    # -- bounds handling --------------------------------------------------
    def _bounds(self, a, B: int, r0: int, r1: int):
        """Span slice of shared ``(B,)`` or per-row ``(B, R)`` bounds."""
        a = jnp.asarray(a)
        if a.ndim == 1:
            return jnp.broadcast_to(a[:, None], (B, r1 - r0))
        if a.ndim != 2 or a.shape[1] != self.R:
            raise ValueError(f"bounds must be (B,) or (B, {self.R}), "
                             f"got {a.shape}")
        return a[:, r0:r1]

    # -- fused probes ------------------------------------------------------
    def _range_all(self, flat_state: jax.Array, lo, hi) -> jax.Array:
        lo = jnp.atleast_1d(jnp.asarray(lo))
        hi = jnp.atleast_1d(jnp.asarray(hi))
        B = lo.shape[0]
        # named_scope: trace-time annotation only, zero jaxpr equations —
        # the one-gather invariant is asserted with these scopes in place
        with jax.named_scope("bloomrf/plan"):
            parts, plans = [], []
            for e, r0, r1 in self.spans:
                plan = e.plan_range(self._bounds(lo, B, r0, r1),
                                    self._bounds(hi, B, r0, r1))
                # row bases fold in as python-int adds (no captured constant
                # arrays — the Pallas stacked kernels trace this function)
                shifted = jnp.stack(
                    [plan.lanes[:, i, :] + self.bases[r0 + i]
                     for i in range(r1 - r0)], axis=1)
                parts.append(shifted.reshape(B, -1))
                plans.append(plan)
        with jax.named_scope("bloomrf/gather"):
            g = flat_state[jnp.concatenate(parts, axis=-1)]  # the one gather
        with jax.named_scope("bloomrf/combine"):
            out, off = [], 0
            for (e, r0, r1), plan in zip(self.spans, plans):
                G, A = r1 - r0, e.range_gather_width
                gg = g[:, off:off + G * A].reshape(B, G, A)
                off += G * A
                out.append(e.combine_range(gg, plan))
            return jnp.concatenate(out, axis=-1)          # (B, R)

    def _point_all(self, flat_state: jax.Array, ys) -> jax.Array:
        ys = jnp.atleast_1d(jnp.asarray(ys))
        B = ys.shape[0]
        with jax.named_scope("bloomrf/plan"):
            parts, plans = [], []
            for e, r0, r1 in self.spans:
                plan = e.plan_point(ys)                   # lanes/sh (B, P)
                shifted = jnp.stack(
                    [plan.lanes + self.bases[r] for r in range(r0, r1)],
                    axis=1)
                parts.append(shifted.reshape(B, -1))
                plans.append(plan)
        with jax.named_scope("bloomrf/gather"):
            g = flat_state[jnp.concatenate(parts, axis=-1)]  # the one gather
        with jax.named_scope("bloomrf/combine"):
            out, off = [], 0
            for (e, r0, r1), plan in zip(self.spans, plans):
                G, P = r1 - r0, plan.lanes.shape[-1]
                gg = g[:, off:off + G * P].reshape(B, G, P)
                off += G * P
                bits = (gg >> plan.sh[:, None, :]) & jnp.uint32(1)
                out.append(jnp.all(bits == 1, axis=-1))
            return jnp.concatenate(out, axis=-1)          # (B, R)

    def _touch_all(self, flat_state: jax.Array, kmin, kmax, lo, hi,
                   quarantine=None):
        """Fence-fused range probe: the full store scan-pruning plane.

        ``kmin``/``kmax`` are per-row key fences (shape ``(R,)``, key
        dtype).  Returns ``(fence, touch)``, both ``(B, R)`` bool:
        ``fence`` is interval overlap with the row's key range and
        ``touch = fence & filter_verdict`` — the data blocks a scan must
        actually read.  ``quarantine`` (optional ``(R,)`` bool) marks rows
        whose filter block failed its checksum (DESIGN.md §14): their
        filter verdict is forced to "maybe", degrading that row to
        fence-only pruning — a corrupted filter must never skip a run it
        might cover (that would be a false negative).  This is the
        XLA-exact reference the store-scan Pallas megakernel
        (``kernels/store_scan.py``) is bit-identical to; everything
        (fence compare, the one fused gather, combine, masking) stays on
        device in one jit.  Bounds must already be clamped into the
        filters' key domain (the store dispatch clamps and zeroes rows
        whose query lies entirely above the domain)."""
        lo = jnp.atleast_1d(jnp.asarray(lo))
        hi = jnp.atleast_1d(jnp.asarray(hi))
        kmin = jnp.asarray(kmin, lo.dtype)
        kmax = jnp.asarray(kmax, lo.dtype)
        fence = ((hi[:, None] >= kmin[None, :])
                 & (lo[:, None] <= kmax[None, :]))
        filt = self._range_all(flat_state, lo, hi)
        if quarantine is not None:
            filt = filt | jnp.asarray(quarantine, bool)[None, :]
        return fence, fence & filt

    def range_all(self, flat_state: jax.Array, lo, hi) -> jax.Array:
        """(B, R) bool: per-row range verdicts from one fused gather."""
        return self._range_jit(flat_state, lo, hi)

    def touch_all(self, flat_state: jax.Array, kmin, kmax, lo, hi,
                  quarantine=None):
        """(fence, touch) ``(B, R)`` bool pair — see :meth:`_touch_all`."""
        return self._touch_jit(flat_state, kmin, kmax, lo, hi, quarantine)

    def point_all(self, flat_state: jax.Array, ys) -> jax.Array:
        """(B, R) bool: per-row point verdicts from one fused gather."""
        return self._point_jit(flat_state, ys)


@functools.lru_cache(maxsize=None)
def _filter_for_layout(layout) -> BloomRF:
    return BloomRF(layout, _warn=False)


@functools.lru_cache(maxsize=None)
def stacked_probe(layouts: tuple, bases: tuple) -> StackedProbe:
    """Cached :class:`StackedProbe` for a row stack described by layouts.

    Layouts are hashable frozen dataclasses, so call sites that re-stack the
    same row mix (an LSM store after every flush/compaction, a bank per
    construction) share one probe instance — and with it the jit cache of
    the fused probe functions."""
    engines = tuple(_filter_for_layout(lay).engine for lay in layouts)
    return StackedProbe(engines, bases)
