"""Dynamic-filter machinery (core/dynamic.py, DESIGN.md §12): in-place
capacity promotion, counting-lane deletes, and generation-based TTL aging.

The promotion invariant under test: because ``(h mod f*N) mod N == h mod N``,
tiling each hashed segment ``f`` times maps every old bit into the position
the new layout would probe — so a promoted state admits **zero** false
negatives without re-hashing a single key, and promotion distributes over
OR (the property compaction's promote merge relies on).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BloomRF, CountingLanes, DeletableBloomRF, Generations,
                        basic_layout, clear_bits, promote_layout,
                        promote_state, promotion_factors)
from repro.store import Store, StoreConfig


def _keys(rng, d, n):
    return rng.integers(0, (1 << d) - 1, n, dtype=np.uint64)


# ---------------------------------------------------------------------------
# promotion: layout compatibility
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,delta", [(24, 6), (32, 6), (32, 4), (20, 7)])
def test_promote_layout_factors(d, delta):
    old = basic_layout(d, 512, 14.0, delta=delta)
    new = promote_layout(old, factor=4)
    fac = promotion_factors(old, new)
    assert fac is not None
    # hashed segments scale by exactly the factor; exact segments stay 1
    for s, f in enumerate(fac):
        assert f == (1 if s == old.exact_seg else 4)
    assert promotion_factors(old, old) is not None      # identity promotes
    assert promotion_factors(new, old) is None          # no demotion


def test_store_ladder_classes_are_promotion_compatible():
    """Consecutive capacity classes of the store's layout ladder promote."""
    st = Store(StoreConfig(d=32, memtable_limit=4096, bits_per_key=14.0))
    prev = st.class_layout(1)
    for cls in range(1, 4):
        cur = st.class_layout(st.class_capacity(cls))
        fac = promotion_factors(prev, cur)
        assert fac is not None and max(fac) > 1
        prev = cur


def test_promotion_rejects_incompatible_layouts():
    old = basic_layout(32, 512, 14.0, delta=6, seed=1)
    assert promotion_factors(old, basic_layout(32, 2048, 14.0, delta=6,
                                               seed=2)) is None   # seed
    assert promotion_factors(old, basic_layout(24, 2048, 14.0,
                                               delta=6, seed=1)) is None  # d
    assert promotion_factors(old, basic_layout(32, 2048, 14.0, delta=4,
                                               seed=1)) is None   # deltas
    with pytest.raises(ValueError):
        promote_state(BloomRF(old).init_state(), old,
                      basic_layout(32, 2048, 14.0, delta=6, seed=2))


# ---------------------------------------------------------------------------
# promotion: zero false negatives + OR distribution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,factor", [(24, 4), (32, 4), (32, 16)])
def test_promoted_state_has_zero_false_negatives(rng, d, factor):
    old = basic_layout(d, 512, 14.0, delta=6)
    new = promote_layout(old, factor=factor)
    keys = np.unique(_keys(rng, d, 2000))
    fo, fn_ = BloomRF(old), BloomRF(new)
    state = fo.build(jnp.asarray(keys, fo.kdtype))
    promoted = promote_state(state, old, new)
    kj = jnp.asarray(keys, fn_.kdtype)
    assert np.asarray(fn_.point(promoted, kj)).all()
    lo = np.maximum(keys, 2) - 2
    hi = np.minimum(keys + 3, (1 << d) - 1)
    assert np.asarray(fn_.range(promoted, jnp.asarray(lo, fn_.kdtype),
                                jnp.asarray(hi, fn_.kdtype))).all()


def test_promotion_distributes_over_or(rng):
    old = basic_layout(32, 512, 14.0, delta=6)
    new = promote_layout(old, factor=4)
    f = BloomRF(old)
    a = f.build(jnp.asarray(_keys(rng, 32, 700), f.kdtype))
    b = f.build(jnp.asarray(_keys(rng, 32, 700), f.kdtype))
    lhs = promote_state(jnp.bitwise_or(a, b), old, new)
    rhs = jnp.bitwise_or(promote_state(a, old, new),
                         promote_state(b, old, new))
    np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))


def test_promoted_state_keeps_fpr_reasonable(rng):
    """Promotion leaves junk bits from dropped top layers but must not
    saturate the new layout: absent-key FPR stays well under 50%."""
    old = basic_layout(32, 512, 14.0, delta=6)
    new = promote_layout(old, factor=4)
    keys = _keys(rng, 32, 512)
    fo, fnew = BloomRF(old), BloomRF(new)
    promoted = promote_state(fo.build(jnp.asarray(keys, fo.kdtype)), old, new)
    absent = _keys(rng, 32, 20_000)
    fpr = float(np.asarray(fnew.point(promoted,
                                      jnp.asarray(absent,
                                                  fnew.kdtype))).mean())
    assert fpr < 0.25


# ---------------------------------------------------------------------------
# counting lanes + deletable filter
# ---------------------------------------------------------------------------

def test_counting_lanes_add_remove_and_saturation():
    lanes = CountingLanes(64)
    lanes.add(np.array([3, 3, 7]))
    assert lanes.counts[3] == 2 and lanes.counts[7] == 1
    assert list(lanes.remove(np.array([3]))) == []      # still one holder
    assert list(lanes.remove(np.array([3, 7]))) == [3, 7]
    # saturated counters freeze: they never drain back to zero
    lanes.add(np.repeat(5, CountingLanes.SATURATE + 10))
    assert lanes.counts[5] == CountingLanes.SATURATE
    for _ in range(CountingLanes.SATURATE + 10):
        assert list(lanes.remove(np.array([5]))) == []
    assert lanes.counts[5] == CountingLanes.SATURATE


def test_clear_bits_only_touches_given_positions(rng):
    state = jnp.asarray(rng.integers(0, 1 << 32, 8, dtype=np.uint32))
    pos = np.array([0, 33, 255])
    out = np.asarray(clear_bits(state, pos))
    ref = np.asarray(state).copy()
    for p in pos:
        ref[p >> 5] &= ~np.uint32(1 << (p & 31))
    np.testing.assert_array_equal(out, ref)


def test_deletable_filter_delete_then_no_false_negative(rng):
    layout = basic_layout(32, 2048, 14.0, delta=6)
    df = DeletableBloomRF(layout)
    keys = np.unique(_keys(rng, 32, 2000))
    gone, kept = keys[: len(keys) // 2], keys[len(keys) // 2:]
    state = df.insert(df.init_state(), keys)
    state = df.delete(state, gone)
    kj = jnp.asarray(kept, df.kdtype)
    assert np.asarray(df.point(state, kj)).all()
    # deletes actually reclaim bits: most deleted keys stop probing positive
    gj = jnp.asarray(gone, df.kdtype)
    assert np.asarray(df.point(state, gj)).mean() < 0.05


def test_deletable_filter_promotes_with_counts(rng):
    layout = basic_layout(32, 512, 14.0, delta=6)
    df = DeletableBloomRF(layout)
    keys = np.unique(_keys(rng, 32, 900))
    state = df.insert(df.init_state(), keys)
    big, state = df.promoted(promote_layout(layout, 4), state)
    assert np.asarray(big.point(state, jnp.asarray(keys, big.kdtype))).all()
    # counters moved with the bits: deletes still work post-promotion
    state = big.delete(state, keys[:100])
    assert np.asarray(big.point(
        state, jnp.asarray(keys[100:], big.kdtype))).all()


# ---------------------------------------------------------------------------
# generations (TTL aging)
# ---------------------------------------------------------------------------

def test_generations_expiry_contract(rng):
    layout = basic_layout(32, 1024, 14.0, delta=6)
    f = BloomRF(layout)
    gens = Generations(f.init_state, n_generations=3)
    keys = jnp.asarray(_keys(rng, 32, 400), f.kdtype)
    gens.insert(f.insert, keys)
    assert np.asarray(f.point(gens.collapsed, keys)).all()
    # survives n_generations - 1 advances ...
    for _ in range(2):
        gens.advance()
        assert np.asarray(f.point(gens.collapsed, keys)).all()
    # ... and is fully dropped by the n_generations-th
    gens.advance()
    assert not np.asarray(gens.collapsed).any()


def test_generations_map_promotes_every_generation(rng):
    old = basic_layout(32, 512, 14.0, delta=6)
    new = promote_layout(old, 4)
    fo, fnew = BloomRF(old), BloomRF(new)
    gens = Generations(fo.init_state, n_generations=3)
    k1 = jnp.asarray(_keys(rng, 32, 200), fo.kdtype)
    k2 = jnp.asarray(_keys(rng, 32, 200), fo.kdtype)
    gens.insert(fo.insert, k1)
    gens.advance()
    gens.insert(fo.insert, k2)
    gens = gens.map(lambda st: promote_state(st, old, new),
                    zero_fn=fnew.init_state)
    assert np.asarray(fnew.point(gens.collapsed, k1)).all()
    assert np.asarray(fnew.point(gens.collapsed, k2)).all()
    gens.advance()                      # k1's generation retires first
    assert np.asarray(fnew.point(gens.collapsed, k2)).all()


# ---------------------------------------------------------------------------
# facade growth (api.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mutability", ["insert_only", "deletable", "ttl"])
def test_facade_grow_keeps_keys(rng, mutability):
    from repro.api import FilterSpec, open_filter

    f = open_filter(FilterSpec(dtype="u32", n=1024, mutability=mutability))
    keys = _keys(rng, 32, 1000)
    f.insert(keys)
    before = f.size_bits()
    f.grow(4)
    assert f.size_bits() > before
    assert f.spec.n == 4096
    assert np.asarray(f.point(keys)).all()
    assert np.asarray(f.range(np.maximum(keys, 2) - 2, keys)).all()


def test_facade_tenant_grow_and_ttl(rng):
    from repro.api import FilterSpec, open_filter

    f = open_filter(FilterSpec(dtype="u32", n=1024, placement="tenant",
                               tenants=4, shards=2, mutability="ttl",
                               generations=2))
    tenants = rng.integers(0, 4, 600).astype(np.uint32)
    keys = _keys(rng, 32, 600)
    f.insert(tenants, keys)
    f.grow(4)
    assert np.asarray(f.point(tenants, keys)).all()
    assert np.asarray(f.range(tenants, keys, keys)).all()
    f.advance_generation()
    f.advance_generation()
    assert not np.asarray(f.point(tenants, keys)).any() or \
        np.asarray(f.point(tenants, keys)).mean() < 0.05


def test_facade_mutability_validation():
    from repro.api import FilterSpec

    with pytest.raises(ValueError):
        FilterSpec(dtype="u32", mutability="frozen")
    with pytest.raises(ValueError):
        FilterSpec(dtype="u32", placement="tenant", tenants=2,
                   mutability="deletable")
    with pytest.raises(ValueError):
        FilterSpec(dtype="u32", placement="store", mutability="ttl")
    with pytest.raises(ValueError):
        FilterSpec(dtype="u32", mutability="ttl", generations=1)
