"""Store-scan megakernel vs the XLA StackedProbe reference.

``kernels/store_scan.py`` promises verdicts bit-identical to
``StackedProbe.touch_all`` whatever the run mix.  This suite pins that
contract per layout class (mixed deltas, multi-segment, replicas,
promoted/tiled state, capacity-class ladders, TTL generation lanes),
asserts the fused plane really is ONE ``pallas_call`` per scan batch,
and fuzzes a kernel-backed :class:`Store` against an XLA-backed twin
through a deletable-churn op stream — same results, same stats.

Everything runs in interpret mode on CPU (the CI pallas lane)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FilterLayout, basic_layout
from repro.core.dynamic import Generations, promote_layout, promote_state
from repro.core.engine import _filter_for_layout, stacked_probe
from repro.kernels.store_scan import build_run_stack, store_scan_probe
from repro.store import Store, StoreConfig

D = 32
DMAX = (1 << D) - 1


# ---------------------------------------------------------------------------
# layout-class row builders: (layouts, states, kmin, kmax)
# ---------------------------------------------------------------------------

def _filled_rows(layouts, rng, n_per=400):
    """One populated run row per layout + its true key fences."""
    states, kmins, kmaxs = [], [], []
    for lay in layouts:
        f = _filter_for_layout(lay)
        keys = rng.integers(0, DMAX, n_per, dtype=np.uint64)
        states.append(f.insert(f.init_state(), jnp.asarray(keys, jnp.uint32)))
        kmins.append(int(keys.min()))
        kmaxs.append(int(keys.max()))
    return (tuple(layouts), states,
            np.asarray(kmins, np.uint32), np.asarray(kmaxs, np.uint32))


def _mixed_delta(rng):
    return _filled_rows([basic_layout(D, 500, 12.0, delta=dl)
                         for dl in (4, 6, 7)], rng)


def _multi_segment(rng):
    seg = FilterLayout(d=D, deltas=(6, 5, 4), replicas=(1, 1, 1),
                       seg_of_layer=(0, 1, 0), seg_bits=(8192, 4096))
    return _filled_rows([seg, basic_layout(D, 400, 12.0, delta=6), seg], rng)


def _replicas(rng):
    rep = FilterLayout(d=D, deltas=(7, 7), replicas=(1, 2),
                       seg_of_layer=(0, 0), seg_bits=(16384,))
    return _filled_rows([rep, rep, basic_layout(D, 300, 14.0, delta=7)], rng)


def _promoted(rng):
    """A promote-merged run (tiled state) next to rebuilt neighbours."""
    base = basic_layout(D, 400, 12.0, delta=6)
    big = promote_layout(base, 4)
    f = _filter_for_layout(base)
    keys = rng.integers(0, DMAX, 800, dtype=np.uint64)
    small = f.insert(f.init_state(), jnp.asarray(keys, jnp.uint32))
    layouts = (big, basic_layout(D, 1600, 12.0, delta=6))
    _, states, kmins, kmaxs = _filled_rows(layouts[1:], rng)
    return (layouts, [promote_state(small, base, big)] + states,
            np.concatenate([[keys.min()], kmins]).astype(np.uint32),
            np.concatenate([[keys.max()], kmaxs]).astype(np.uint32))


def _capacity_ladder(rng):
    """The store's normal stack: two level-0 rows + two lower levels."""
    c0 = basic_layout(D, 400, 14.0, delta=6)
    return _filled_rows([c0, c0, basic_layout(D, 1600, 14.0, delta=6),
                         basic_layout(D, 6400, 14.0, delta=6)], rng)


def _ttl_lanes(rng):
    """A Generations-collapsed (TTL) state as one of the run rows."""
    lay = basic_layout(D, 600, 12.0, delta=6)
    f = _filter_for_layout(lay)
    gens = Generations(f.init_state, n_generations=3)
    keys = rng.integers(0, DMAX, 600, dtype=np.uint64)
    for part in np.array_split(keys, 4):
        gens.insert(f.insert, jnp.asarray(part, jnp.uint32))
        gens.advance()                  # retire a slot; OR stays union-sound
    layouts = (lay, basic_layout(D, 500, 12.0, delta=5))
    _, states, kmins, kmaxs = _filled_rows(layouts[1:], rng)
    return (layouts, [gens.collapsed] + states,
            np.concatenate([[keys.min()], kmins]).astype(np.uint32),
            np.concatenate([[keys.max()], kmaxs]).astype(np.uint32))


CLASSES = {
    "mixed_delta": _mixed_delta,
    "multi_segment": _multi_segment,
    "replicas": _replicas,
    "promoted": _promoted,
    "capacity_ladder": _capacity_ladder,
    "ttl_lanes": _ttl_lanes,
}


def _queries(rng, b=200):
    """Scan bounds: short/long ranges plus fully-off-fence probes."""
    lo = rng.integers(0, DMAX, b, dtype=np.uint64)
    width = rng.integers(0, 1 << 20, b, dtype=np.uint64)
    hi = np.minimum(lo + width, DMAX)
    lo[:8] = hi[:8] = 0                # below every fence
    lo[8:16] = hi[8:16] = DMAX         # above most fences
    return jnp.asarray(lo, jnp.uint32), jnp.asarray(hi, jnp.uint32)


def _reference(layouts, states, kmin, kmax, lo, hi):
    """StackedProbe.touch_all over the unpadded concatenated stack."""
    bases = tuple(int(b) for b in
                  np.cumsum([0] + [s.shape[0] for s in states[:-1]]))
    probe = stacked_probe(tuple(layouts), bases)
    return probe.touch_all(jnp.concatenate(states),
                           jnp.asarray(kmin, jnp.uint32),
                           jnp.asarray(kmax, jnp.uint32), lo, hi)


# ---------------------------------------------------------------------------
# per-layout-class parity (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cls", sorted(CLASSES))
@pytest.mark.parametrize("rpb", [0, 2])
def test_kernel_matches_stacked_probe(rng, cls, rpb):
    layouts, states, kmin, kmax = CLASSES[cls](rng)
    lo, hi = _queries(rng)
    f_ref, t_ref = _reference(layouts, states, kmin, kmax, lo, hi)
    stack = build_run_stack(states)
    f_k, t_k = store_scan_probe(layouts, stack,
                                jnp.asarray(kmin), jnp.asarray(kmax),
                                lo, hi, 64, rpb, True)
    assert np.array_equal(np.asarray(f_k), np.asarray(f_ref)), cls
    assert np.array_equal(np.asarray(t_k), np.asarray(t_ref)), cls


def test_kernel_odd_batch_and_tiny_tile(rng):
    """B not a multiple of the tile; rpb that doesn't divide R."""
    layouts, states, kmin, kmax = _capacity_ladder(rng)   # R = 4
    lo, hi = _queries(rng, b=77)
    f_ref, t_ref = _reference(layouts, states, kmin, kmax, lo, hi)
    stack = build_run_stack(states)
    for rpb in (1, 3):                 # 4 and 2 blocks, tail-padded
        f_k, t_k = store_scan_probe(layouts, stack,
                                    jnp.asarray(kmin), jnp.asarray(kmax),
                                    lo, hi, 32, rpb, True)
        assert np.array_equal(np.asarray(f_k), np.asarray(f_ref)), rpb
        assert np.array_equal(np.asarray(t_k), np.asarray(t_ref)), rpb


def test_kernel_rejects_bad_stacks(rng):
    layouts, states, kmin, kmax = _mixed_delta(rng)
    stack = build_run_stack(states)
    with pytest.raises(ValueError, match="one key domain"):
        store_scan_probe((layouts[0], basic_layout(24, 400, 12.0, delta=6)),
                         stack[:2], jnp.asarray(kmin[:2]),
                         jnp.asarray(kmax[:2]),
                         jnp.zeros(8, jnp.uint32), jnp.ones(8, jnp.uint32))
    with pytest.raises(ValueError, match="rowpad"):
        store_scan_probe(layouts, stack[:, :8], jnp.asarray(kmin),
                         jnp.asarray(kmax),
                         jnp.zeros(8, jnp.uint32), jnp.ones(8, jnp.uint32))


# ---------------------------------------------------------------------------
# dispatch shape: the whole scan plane is ONE kernel call per batch
# ---------------------------------------------------------------------------

def _count_prim(jaxpr, name) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):
                n += _count_prim(v.jaxpr, name)
            elif isinstance(v, (list, tuple)):
                n += sum(_count_prim(it.jaxpr, name) for it in v
                         if hasattr(it, "jaxpr"))
    return n


def test_fused_scan_is_one_pallas_call(rng):
    layouts, states, kmin, kmax = _mixed_delta(rng)
    stack = build_run_stack(states)
    lo, hi = _queries(rng, b=64)
    for rpb in (0, 1):                 # whole-stack AND multi-block grids
        jaxpr = jax.make_jaxpr(
            lambda s, a, b: store_scan_probe(
                layouts, s, jnp.asarray(kmin), jnp.asarray(kmax),
                a, b, 64, rpb, True))(stack, lo, hi)
        assert _count_prim(jaxpr.jaxpr, "pallas_call") == 1, (
            rpb, jaxpr.pretty_print())


def test_store_kernel_path_is_one_pallas_call(rng):
    """Through the Store dispatch, a scan batch is still one kernel."""
    st = Store(StoreConfig(d=D, memtable_limit=300, level0_runs=3,
                           scan_backend="kernel"))
    for k in rng.integers(0, DMAX, 2000, dtype=np.uint64):
        st.put(int(k), 0)
    st.flush()
    st._refresh()
    layouts, stack, kmin_d, kmax_d, rpb = st._kernel_inputs()
    lo = jnp.zeros(64, jnp.uint32)
    hi = jnp.full(64, 1 << 20, jnp.uint32)
    jaxpr = jax.make_jaxpr(
        lambda s, a, b: store_scan_probe(layouts, s, kmin_d, kmax_d,
                                         a, b, 256, rpb, True))(stack, lo, hi)
    assert _count_prim(jaxpr.jaxpr, "pallas_call") == 1


# ---------------------------------------------------------------------------
# kernel-backed store vs XLA-backed store: same ops, same answers
# ---------------------------------------------------------------------------

def _fuzz_kernel_vs_xla(n_ops: int, seed: int):
    rng = np.random.default_rng(seed)
    def mk(backend):
        return Store(StoreConfig(
            d=D, memtable_limit=800, level0_runs=3, fanout=4,
            mutability="deletable", scan_backend=backend))
    st_k, st_x = mk("kernel"), mk("xla")
    chunk, scan_b = 2_000, 64
    for c0 in range(0, n_ops, chunk):
        ops = rng.random(chunk)
        ks = rng.integers(0, 1 << 32, chunk, dtype=np.uint64)
        for op, k in zip(ops, ks):
            k = int(k)
            if op < 0.85:
                st_k.put(k, k ^ 0x5CA7)
                st_x.put(k, k ^ 0x5CA7)
            else:
                dk = int(ks[rng.integers(0, chunk)])
                st_k.delete(dk)
                st_x.delete(dk)
        lo = rng.integers(0, (1 << 32) - (1 << 16), scan_b, dtype=np.uint64)
        hi = lo + rng.integers(1, 1 << 16, scan_b, dtype=np.uint64)
        hi[-4:] = np.uint64((1 << 32) + 5)     # exercise the domain clamp
        assert st_k.scan_many(lo, hi) == st_x.scan_many(lo, hi), c0
    # bit-identical verdicts leave bit-identical pruning stats behind
    assert st_k.stats.scan_filter_skips == st_x.stats.scan_filter_skips
    assert st_k.stats.scan_runs_touched == st_x.stats.scan_runs_touched
    assert st_k.stats.scans == st_x.stats.scans
    return st_k


def test_fuzz_kernel_vs_xla_store_deletable(rng):
    st = _fuzz_kernel_vs_xla(20_000, 0xC0FE)
    assert st.stats.flushes > 5        # the mix actually churned


@pytest.mark.slow
def test_fuzz_kernel_vs_xla_store_100k_ops():
    st = _fuzz_kernel_vs_xla(100_000, 0xC0FE)
    assert st.stats.compactions > 0
