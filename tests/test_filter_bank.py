"""Sharded filter bank: shard-vs-single-device equivalence, false-negative
freedom under sharding, cross-shard range routing.  Multi-device checks run
as subprocesses (device count must be fixed before jax initializes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import brute_force_range_truth
from test_dist_and_dryrun import _run

from repro.dist.filter_bank import FilterBank, ShardedFilterBank


def test_bank_no_false_negatives(rng):
    bank = FilterBank(d=32, n_shards=8, n_keys=5000, bits_per_key=14.0)
    keys = rng.integers(0, 1 << 32, 5000, dtype=np.uint64).astype(np.uint32)
    state = bank.build(jnp.asarray(keys))
    assert np.asarray(bank.point(state, jnp.asarray(keys))).all()
    lo = np.maximum(keys.astype(np.int64) - 7, 0).astype(np.uint32)
    hi = np.minimum(keys.astype(np.int64) + 7, (1 << 32) - 1).astype(np.uint32)
    assert np.asarray(bank.range(state, jnp.asarray(lo),
                                 jnp.asarray(hi))).all()


def test_bank_matches_ground_truth_fpr(rng):
    bank = FilterBank(d=32, n_shards=4, n_keys=4000, bits_per_key=16.0)
    keys = rng.integers(0, 1 << 32, 4000, dtype=np.uint64).astype(np.uint32)
    state = bank.build(jnp.asarray(keys))
    lo = rng.integers(0, 1 << 32, 4000, dtype=np.uint64)
    hi = np.minimum(lo + (1 << 8), (1 << 32) - 1)
    truth = brute_force_range_truth(keys, lo, hi)
    got = np.asarray(bank.range(state, jnp.asarray(lo.astype(np.uint32)),
                                jnp.asarray(hi.astype(np.uint32))))
    assert not (truth & ~got).any()          # no false negatives
    empties = max(int((~truth).sum()), 1)
    fpr = float((got & ~truth).sum()) / empties
    assert fpr < 0.2, fpr                    # sane positive rate


def test_bank_cross_shard_ranges(rng):
    """Ranges spanning shard boundaries hit keys in interior shards."""
    bank = FilterBank(d=16, n_shards=4, n_keys=64, bits_per_key=16.0)
    # one key in shard 1 and one in shard 2 (d_local = 14)
    keys = np.asarray([(1 << 14) + 5, (2 << 14) + 123], np.uint32)
    state = bank.build(jnp.asarray(keys))
    # range living in shard 0 ... shard 3: straddles both keys
    assert bool(bank.range(state, jnp.asarray([100], np.uint32),
                           jnp.asarray([(3 << 14) + 1], np.uint32))[0])
    # range covering only shard 2's key, entered from shard 1
    assert bool(bank.range(state, jnp.asarray([(2 << 14)], np.uint32),
                           jnp.asarray([(2 << 14) + 200], np.uint32))[0])


def test_sharded_bank_single_process_equivalence(rng):
    """shard_map path == vmap path even on a 1-device mesh (8 rows/device)."""
    bank = FilterBank(d=32, n_shards=8, n_keys=2000, bits_per_key=14.0)
    keys = rng.integers(0, 1 << 32, 2000, dtype=np.uint64).astype(np.uint32)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    sb = ShardedFilterBank(bank, mesh, "data")
    state = bank.build(jnp.asarray(keys))
    sstate = sb.build(jnp.asarray(keys))
    assert np.array_equal(np.asarray(state), np.asarray(sstate))
    qs = rng.integers(0, 1 << 32, 3000, dtype=np.uint64).astype(np.uint32)
    lo = rng.integers(0, 1 << 32, 3000, dtype=np.uint64)
    hi = np.minimum(lo + (1 << 10), (1 << 32) - 1).astype(np.uint32)
    lo = lo.astype(np.uint32)
    assert np.array_equal(np.asarray(bank.point(state, jnp.asarray(qs))),
                          np.asarray(sb.point(sstate, jnp.asarray(qs))))
    assert np.array_equal(
        np.asarray(bank.range(state, jnp.asarray(lo), jnp.asarray(hi))),
        np.asarray(sb.range(sstate, jnp.asarray(lo), jnp.asarray(hi))))


def test_bank_rejects_bad_shard_counts():
    with pytest.raises(ValueError):
        FilterBank(d=32, n_shards=6, n_keys=100)
    bank = FilterBank(d=32, n_shards=2, n_keys=100)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    if len(jax.devices()) > 2:
        with pytest.raises(ValueError):
            ShardedFilterBank(bank, mesh, "data")


def test_sharded_bank_device_parallel_equivalence():
    """Acceptance: bitwise-identical verdicts single-device vs 8-device mesh
    on >= 1e5 random point and range probes; zero false negatives."""
    r = _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.dist.filter_bank import FilterBank, ShardedFilterBank
rng = np.random.default_rng(7)
bank = FilterBank(d=32, n_shards=8, n_keys=20000, bits_per_key=14.0)
keys = rng.integers(0, 1 << 32, 20000, dtype=np.uint64).astype(np.uint32)
state = bank.build(jnp.asarray(keys))
mesh = jax.make_mesh((8,), ("data",))
sb = ShardedFilterBank(bank, mesh, "data")
sstate = sb.shard_state(state)
assert np.array_equal(np.asarray(state),
                      np.asarray(sb.build(jnp.asarray(keys))))
Q = 100_000
qs = rng.integers(0, 1 << 32, Q, dtype=np.uint64).astype(np.uint32)
lo64 = rng.integers(0, 1 << 32, Q, dtype=np.uint64)
hi = np.minimum(lo64 + rng.integers(0, 1 << 12, Q).astype(np.uint64),
                (1 << 32) - 1).astype(np.uint32)
lo = lo64.astype(np.uint32)
p1 = np.asarray(bank.point(state, jnp.asarray(qs)))
p2 = np.asarray(sb.point(sstate, jnp.asarray(qs)))
assert np.array_equal(p1, p2), "point verdicts differ"
r1 = np.asarray(bank.range(state, jnp.asarray(lo), jnp.asarray(hi)))
r2 = np.asarray(sb.range(sstate, jnp.asarray(lo), jnp.asarray(hi)))
assert np.array_equal(r1, r2), "range verdicts differ"
# inserted keys never lost by either path
pk = np.asarray(sb.point(sstate, jnp.asarray(keys)))
assert pk.all(), "sharding introduced point false negatives"
slo = np.maximum(keys.astype(np.int64) - 5, 0).astype(np.uint32)
shi = np.minimum(keys.astype(np.int64) + 5, (1 << 32) - 1).astype(np.uint32)
sr = np.asarray(sb.range(sstate, jnp.asarray(slo), jnp.asarray(shi)))
assert sr.all(), "sharding introduced range false negatives"
print("SHARDED-BANK-OK", int(p1.sum()), int(r1.sum()))
""", devices=8)
    assert "SHARDED-BANK-OK" in r.stdout, r.stdout + r.stderr
