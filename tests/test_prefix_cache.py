"""Prefix-cache index on the tenant bank: session-namespace routing,
range-based eviction sweeps, and the false-positive stats counters."""
from repro.serve.prefix_cache import PrefixCacheIndex, pack_key


def _freeze_sessions(idx, sessions, chunks=range(4)):
    return idx.freeze_segment({pack_key(s, c): [s * 100 + c]
                               for s in sessions for c in chunks})


def test_namespace_routing_no_collisions():
    """Sessions sharing a tenant (same low bits) stay distinguishable."""
    idx = PrefixCacheIndex(bits_per_key=16, n_tenants=8)
    _freeze_sessions(idx, [1, 9, 17])  # all tenant 1 under 8 tenants
    for s in (1, 9, 17):
        for c in range(4):
            assert idx.lookup(s, c) == [s * 100 + c]
    assert idx.lookup(25, 0) is None  # tenant 1, never inserted
    assert idx.lookup(2, 0) is None   # different tenant, never inserted


def test_eviction_sweep_windows():
    idx = PrefixCacheIndex(bits_per_key=16, n_tenants=8)
    s0 = _freeze_sessions(idx, range(10, 20))
    s1 = _freeze_sessions(idx, range(100, 120))
    # windows overlapping exactly one segment must report it (no FN)
    assert s0 in idx.eviction_candidates(0, 50)
    assert s1 in idx.eviction_candidates(90, 130)
    # a window covering everything reports both
    both = idx.eviction_candidates(0, 200)
    assert s0 in both and s1 in both
    # empty window decomposes to no probes at all
    assert idx.eviction_candidates(60, 50) == []


def test_eviction_sweep_window_decomposition():
    """The per-tenant window decomposition covers exactly the sessions in
    [lo, hi]: every covered session id appears in exactly one tenant's
    contiguous local range."""
    idx = PrefixCacheIndex(n_tenants=8)
    lo_s, hi_s = 13, 61
    ts, los, his = idx._window_probes(lo_s, hi_s)
    covered = set()
    for t, lo, hi in zip(ts.tolist(), los.tolist(), his.tolist()):
        for local_ses in range(lo >> 16, (hi >> 16) + 1):
            ses = (local_ses << idx.nt_bits) | t
            assert ses not in covered, "session covered twice"
            covered.add(ses)
    assert covered == set(range(lo_s, hi_s + 1))


def test_session_segments_across_segments():
    idx = PrefixCacheIndex(bits_per_key=16, n_tenants=8)
    a = idx.freeze_segment({pack_key(5, 0): [1], pack_key(5, 1): [2]})
    b = idx.freeze_segment({pack_key(5, 2): [3], pack_key(6, 0): [4]})
    segs = idx.session_segments(5)
    assert a in segs and b in segs


def test_fp_stats_counters():
    idx = PrefixCacheIndex(bits_per_key=16, n_tenants=8)
    _freeze_sessions(idx, [1, 2, 3])
    # hits: filter and map agree
    assert idx.lookup(2, 1) == [201]
    st = idx.stats
    assert st["filter_probes"] == 1 and st["filter_hits"] == 1
    assert st["map_probes"] == 1 and st["map_hits"] == 1
    assert idx.false_positive_rate() == 0.0
    # misses never outnumber probes, and the fpr formula holds
    for s in range(40, 80):
        assert idx.lookup(s, 0) is None
    st = idx.stats
    assert st["filter_probes"] == 41
    assert st["map_hits"] == 1
    fp = st["map_probes"] - st["map_hits"]
    assert fp >= 0
    assert idx.false_positive_rate() == fp / max(st["filter_hits"], 1)
    # range sweeps tick their own counter
    before = st["range_probes"]
    idx.session_segments(1)
    idx.eviction_candidates(0, 10)
    assert idx.stats["range_probes"] == before + 2


def test_store_backed_cold_tier_and_eviction():
    """LSM-store backing: frozen entries mirror into the cold tier,
    total-miss lookups fall through to it, and evict_window drops
    segment entries while tombstoning the store."""
    import pytest

    from repro.store import Store, StoreConfig

    store = Store(StoreConfig(d=32, memtable_limit=32, level0_runs=2))
    idx = PrefixCacheIndex(bits_per_key=16, n_tenants=8,
                           backing_store=store)
    _freeze_sessions(idx, list(range(24)))
    assert idx.lookup(3, 2) == [302]
    # hot-tier eviction without store loss is impossible: drop from the
    # segment map only, the cold tier still serves it
    del idx.segments[0].entries[pack_key(3, 2)]
    assert idx.lookup(3, 2) == [302]
    assert idx.stats["store_hits"] == 1
    # window eviction: segments narrowed by range filters, the cold tier
    # swept with one store range-scan (hot-dropped keys included)
    n = idx.evict_window(0, 7)
    assert n == 8 * 4                # session 3 chunk 2 only in the store
    for s in range(8):
        for c in range(4):
            assert idx.lookup(s, c) is None
    assert idx.lookup(9, 1) == [901]
    # a too-small store domain is rejected
    with pytest.raises(ValueError, match="domain"):
        PrefixCacheIndex(n_tenants=8,
                         backing_store=Store(StoreConfig(d=16)))
    # late attachment backfills already-frozen segments into the cold tier
    late = PrefixCacheIndex(bits_per_key=16, n_tenants=8)
    _freeze_sessions(late, [5])
    late.attach_store(Store(StoreConfig(d=32, memtable_limit=32)))
    del late.segments[0].entries[pack_key(5, 1)]
    assert late.lookup(5, 1) == [501]


def test_evict_window_batches_store_tombstones():
    """A window sweep writes its cold-tier tombstones as ONE batched
    delete_many: at most one flush, never a per-key flush cascade that
    would compact mid-sweep (regression for the old delete-per-key loop)."""
    from repro.store import Store, StoreConfig

    store = Store(StoreConfig(d=32, memtable_limit=32, level0_runs=4))
    idx = PrefixCacheIndex(bits_per_key=16, n_tenants=8,
                           backing_store=store)
    _freeze_sessions(idx, list(range(40)))     # 160 cold entries
    store.flush()
    f0 = store.stats.flushes
    n = idx.evict_window(0, 39)                # sweeps all 160 >> memtable
    assert n == 40 * 4
    assert store.stats.flushes - f0 <= 1, \
        "evict_window flushed more than once mid-sweep"
    for s in range(40):
        assert idx.lookup(s, 0) is None


def test_ttl_generations_expire_segments():
    """advance_generation retires whole segments past the TTL window —
    entries, filter bits and cold-tier copies all expire together."""
    from repro.store import Store, StoreConfig

    store = Store(StoreConfig(d=32, memtable_limit=64, level0_runs=4))
    idx = PrefixCacheIndex(bits_per_key=16, n_tenants=8,
                           backing_store=store, ttl_generations=2)
    _freeze_sessions(idx, [1, 2])              # generation 0
    idx.advance_generation()
    _freeze_sessions(idx, [3])                 # generation 1
    assert idx.lookup(1, 0) == [100]           # still inside the window
    assert idx.lookup(3, 0) == [300]
    n = idx.advance_generation()               # gen-0 segments hit the cutoff
    assert n == 2 * 4
    assert idx.stats["expired"] == 8
    assert idx.lookup(1, 0) is None            # expired (hot AND cold tiers)
    assert idx.lookup(2, 3) is None
    assert idx.lookup(3, 0) == [300]           # younger generation survives
    # without ttl_generations the API is an explicit error
    bare = PrefixCacheIndex(n_tenants=8)
    import pytest
    with pytest.raises(ValueError, match="ttl_generations"):
        bare.advance_generation()
