"""CI gate harness (benchmarks/check_gates.py): the threshold logic that
used to live as inline ci.yml python steps, now unit-tested — pass, fail,
malformed input, unknown-schema refusal, and the trajectory trend check."""
import json

import pytest

from benchmarks import check_gates as cg

TOML = """
schema = "bloomrf-gates/v1"

[inputs.bench]
path = "%(bench)s"
schemas = ["bloomrf-bench/v1"]
value_key = "us_per_call"

[inputs.base]
path = "%(base)s"
schemas = ["bloomrf-bench/v1"]
value_key = "us_per_call"

[[gates]]
name = "abs bound"
input = "bench"
metric = "rows.kernels/probe.us_per_call"
max_value = 10.0

[[gates]]
name = "vs baseline"
input = "bench"
metric = "rows.kernels/probe.us_per_call"
max_ratio = 1.5
ref_input = "base"
ref_metric = "rows.kernels/probe.us_per_call"

[[gates]]
name = "row present"
input = "bench"
metric = "rows.kernels/probe"
require = true

[[gates]]
name = "marker"
input = "bench"
metric = "rows.roofline/x.derived"
contains = "dom=memory"

[[gates]]
name = "floor"
input = "bench"
metric = "meta.skip_rate"
min_value = 0.1

[trajectory]
window = 3
total_frac = 0.25
metrics = ["kernels/probe"]
"""


def _bench(us=5.0, derived="dom=memory;i=0.1", skip=0.5,
           schema="bloomrf-bench/v1"):
    return {"schema": schema, "meta": {"skip_rate": skip},
            "rows": [{"name": "kernels/probe", "us_per_call": us,
                      "derived": "x"},
                     {"name": "roofline/x", "us_per_call": 1.0,
                      "derived": derived}]}


@pytest.fixture
def setup(tmp_path):
    """Write config + two bench JSONs; returns (config_path, paths, rewrite)."""
    paths = {"bench": tmp_path / "bench.json", "base": tmp_path / "base.json"}

    def write(name, payload):
        paths[name].write_text(json.dumps(payload))

    write("bench", _bench())
    write("base", _bench(us=4.0))
    cfg = tmp_path / "gates.toml"
    cfg.write_text(TOML % {k: str(v) for k, v in paths.items()})
    return cfg, paths, write


def _run(cfg, *argv):
    return cg.main(["--config", str(cfg), *argv])


def test_all_gates_pass(setup, capsys):
    cfg, _, _ = setup
    assert _run(cfg, "check") == 0
    assert "5 checks passed" in capsys.readouterr().out


def test_max_value_fail(setup, capsys):
    cfg, _, write = setup
    write("bench", _bench(us=11.0))
    assert _run(cfg, "check") == 1
    assert "abs bound" in capsys.readouterr().err


def test_max_ratio_fail_and_slack(setup):
    cfg, _, write = setup
    write("bench", _bench(us=6.5))          # > 1.5 * 4.0
    assert _run(cfg, "check") == 1
    write("base", _bench(us=5.0))           # 6.5 <= 1.5 * 5.0
    assert _run(cfg, "check") == 0


def test_require_fail(setup):
    cfg, _, write = setup
    payload = _bench()
    payload["rows"][0]["name"] = "kernels/renamed"
    write("bench", payload)
    assert _run(cfg, "check") == 1


def test_contains_fail(setup, capsys):
    cfg, _, write = setup
    write("bench", _bench(derived="dom=compute;i=9"))
    assert _run(cfg, "check") == 1
    assert "dom=memory" in capsys.readouterr().err


def test_min_value_fail(setup):
    cfg, _, write = setup
    write("bench", _bench(skip=0.0))
    assert _run(cfg, "check") == 1


def test_unknown_schema_refused(setup, capsys):
    """A format drift must exit 2 before any gate can vacuously pass."""
    cfg, _, write = setup
    write("bench", _bench(schema="bloomrf-bench/v99"))
    assert _run(cfg, "check") == 2
    assert "refusing" in capsys.readouterr().err


def test_malformed_inputs(setup):
    cfg, paths, write = setup
    paths["bench"].write_text("{not json")
    assert _run(cfg, "check") == 2
    write("bench", {"schema": "bloomrf-bench/v1", "rows": []})
    assert _run(cfg, "check") == 2          # empty rows
    write("bench", {"schema": "bloomrf-bench/v1",
                    "rows": [{"name": "kernels/probe",
                              "us_per_call": "fast"}]})
    assert _run(cfg, "check") == 2          # non-numeric value_key
    paths["bench"].unlink()
    assert _run(cfg, "check") == 2          # missing file


def test_only_filter_and_override(setup, tmp_path):
    cfg, _, _ = setup
    other = tmp_path / "other.json"
    other.write_text(json.dumps(_bench(us=11.0)))
    # bad values in an overridden artifact fail, --only scopes the gate set
    assert _run(cfg, "check", "--only", "bench", f"bench={other}") == 1
    assert _run(cfg, "check", "--only", "nosuch") == 2


def test_bad_gates_config(tmp_path):
    cfg = tmp_path / "gates.toml"
    cfg.write_text('schema = "bloomrf-gates/v99"\n')
    assert _run(cfg, "check") == 2
    cfg.write_text('schema = "bloomrf-gates/v1"\n[inputs.x]\npath = "x"\n')
    assert _run(cfg, "check") == 2          # missing [[gates]]


def _traj_file(tmp_path, values, schema="bloomrf-trajectory/v1"):
    p = tmp_path / "traj.jsonl"
    p.write_text("".join(
        json.dumps({"schema": schema, "ts": f"t{i}", "smoke": True,
                    "metrics": {"kernels/probe": v}}) + "\n"
        for i, v in enumerate(values)))
    return p


def test_trajectory_pass_noise_and_short(setup, tmp_path, capsys):
    cfg, _, _ = setup
    # non-monotone wiggle: never fails, whatever the growth
    p = _traj_file(tmp_path, [5.0, 9.0, 4.0, 9.5])
    assert _run(cfg, "trajectory", str(p)) == 0
    # monotone but under total_frac: noise guard holds
    p = _traj_file(tmp_path, [5.0, 5.1, 5.2])
    assert _run(cfg, "trajectory", str(p)) == 0
    # fewer rows than the window: skipped, not failed
    p = _traj_file(tmp_path, [5.0])
    assert _run(cfg, "trajectory", str(p)) == 0
    assert "skipped" in capsys.readouterr().out


def test_trajectory_monotone_regression_fails(setup, tmp_path, capsys):
    cfg, _, _ = setup
    p = _traj_file(tmp_path, [2.0, 5.0, 6.0, 7.5])   # window=3 tail rises 50%
    assert _run(cfg, "trajectory", str(p)) == 1
    assert "monotonically" in capsys.readouterr().err


def test_trajectory_unknown_schema(setup, tmp_path):
    cfg, _, _ = setup
    p = _traj_file(tmp_path, [1.0], schema="bloomrf-trajectory/v9")
    assert _run(cfg, "trajectory", str(p)) == 2


def test_live_gates_toml_loads():
    """The committed gates.toml parses and every gate references a
    declared input and a known gate kind."""
    cfg = cg.load_config()
    kinds = ("max_value", "min_value", "max_ratio", "require", "contains")
    for g in cfg["gates"]:
        assert g["input"] in cfg["inputs"], g
        assert any(k in g for k in kinds), g
        if "ref_input" in g:
            assert g["ref_input"] in cfg["inputs"], g
