"""Multi-tenant filter bank: tenant isolation, false-negative freedom,
Bloofi-style meta-filter skipping, and sharded/replicated equivalence.
Multi-device checks run as subprocesses (device count must be fixed before
jax initializes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from test_dist_and_dryrun import _run

from repro.dist.tenant_bank import ShardedTenantFilterBank, TenantFilterBank


def _workload(rng, n_tenants, n, span=7):
    keys = rng.integers(0, 1 << 32, n, dtype=np.uint64).astype(np.uint32)
    tenants = rng.integers(0, n_tenants, n).astype(np.uint32)
    lo = np.maximum(keys.astype(np.int64) - span, 0).astype(np.uint32)
    hi = np.minimum(keys.astype(np.int64) + span,
                    (1 << 32) - 1).astype(np.uint32)
    return keys, tenants, lo, hi


def test_tenant_isolation(rng):
    """A tenant that never inserted has an all-zero row: deterministically
    negative, no matter what other tenants stored."""
    tb = TenantFilterBank(d=32, n_tenants=4, n_shards=2,
                          n_keys_per_tenant=1000, bits_per_key=14.0)
    keys, _, lo, hi = _workload(rng, 4, 2000)
    zeros = np.zeros(2000, np.uint32)
    state, meta = tb.build(jnp.asarray(zeros), jnp.asarray(keys))
    assert np.asarray(tb.point(state, jnp.asarray(zeros),
                               jnp.asarray(keys))).all()
    ones = np.ones(2000, np.uint32)
    assert not np.asarray(tb.point(state, jnp.asarray(ones),
                                   jnp.asarray(keys))).any()
    assert not np.asarray(tb.range(state, jnp.asarray(ones), jnp.asarray(lo),
                                   jnp.asarray(hi), meta)).any()


def test_tenant_no_false_negatives_with_meta(rng):
    """Inserted keys are found by point and by meta-gated range probes: the
    meta-filter AND may only remove false positives, never true hits."""
    tb = TenantFilterBank(d=32, n_tenants=8, n_shards=4,
                          n_keys_per_tenant=1000, bits_per_key=14.0)
    keys, tenants, lo, hi = _workload(rng, 8, 6000)
    state, meta = tb.build(jnp.asarray(tenants), jnp.asarray(keys))
    assert np.asarray(tb.point(state, jnp.asarray(tenants),
                               jnp.asarray(keys))).all()
    plain = np.asarray(tb.range(state, jnp.asarray(tenants), jnp.asarray(lo),
                                jnp.asarray(hi)))
    gated = np.asarray(tb.range(state, jnp.asarray(tenants), jnp.asarray(lo),
                                jnp.asarray(hi), meta))
    assert plain.all() and gated.all()
    assert not (gated & ~plain).any()  # meta only ever narrows


def test_meta_skip_rate_positive_on_sparse_ranges(rng):
    """On a mostly-empty range workload the meta level proves a measurable
    fraction of candidate shard-probes empty."""
    tb = TenantFilterBank(d=32, n_tenants=8, n_shards=4,
                          n_keys_per_tenant=500, bits_per_key=14.0)
    keys, tenants, _, _ = _workload(rng, 8, 4000)
    _, meta = tb.build(jnp.asarray(tenants), jnp.asarray(keys))
    q = 20000
    qlo64 = rng.integers(0, 1 << 32, q, dtype=np.uint64)
    qhi = np.minimum(qlo64 + (1 << 10), (1 << 32) - 1).astype(np.uint32)
    qt = rng.integers(0, 8, q).astype(np.uint32)
    cand, skip = tb.meta_skip_stats(meta, jnp.asarray(qt),
                                    jnp.asarray(qlo64.astype(np.uint32)),
                                    jnp.asarray(qhi))
    cand, skip = int(cand), int(skip)
    assert cand >= q  # every probe clips into at least one shard
    assert 0 < skip <= cand


def test_sharded_tenant_bank_validates_mesh():
    tb = TenantFilterBank(d=32, n_tenants=4, n_shards=2,
                          n_keys_per_tenant=100)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    with pytest.raises(KeyError):
        ShardedTenantFilterBank(tb, mesh, "nope")
    with pytest.raises(KeyError):
        ShardedTenantFilterBank(tb, mesh, "data", "replica")
    if len(jax.devices()) > 4:
        with pytest.raises(ValueError):
            ShardedTenantFilterBank(tb, mesh, "data")


def test_sharded_tenant_single_process_equivalence(rng):
    """shard_map path == vmap path on the host mesh, odd batch included."""
    tb = TenantFilterBank(d=32, n_tenants=8, n_shards=2,
                          n_keys_per_tenant=500, bits_per_key=14.0)
    keys, tenants, lo, hi = _workload(rng, 8, 3001)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    sb = ShardedTenantFilterBank(tb, mesh, "data")
    state, meta = tb.build(jnp.asarray(tenants), jnp.asarray(keys))
    sstate, smeta = sb.build(jnp.asarray(tenants), jnp.asarray(keys))
    assert np.array_equal(np.asarray(state), np.asarray(sstate))
    assert np.array_equal(np.asarray(meta), np.asarray(smeta))
    p1 = np.asarray(tb.point(state, jnp.asarray(tenants), jnp.asarray(keys)))
    p2 = np.asarray(sb.point(sstate, jnp.asarray(tenants), jnp.asarray(keys)))
    assert np.array_equal(p1, p2)
    r1 = np.asarray(tb.range(state, jnp.asarray(tenants), jnp.asarray(lo),
                             jnp.asarray(hi), meta))
    r2 = np.asarray(sb.range(sstate, jnp.asarray(tenants), jnp.asarray(lo),
                             jnp.asarray(hi), smeta))
    assert np.array_equal(r1, r2)


def test_sharded_tenant_8dev_replicated_equivalence():
    """Acceptance: bitwise-identical verdicts, vmapped single-device
    reference vs an 8-device (2 replica x 4 data) mesh, on > 1e5 mixed
    point/range probes; zero false negatives; meta skip rate > 0."""
    r = _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.dist.tenant_bank import TenantFilterBank, ShardedTenantFilterBank
rng = np.random.default_rng(11)
T, S, N = 8, 4, 20000
tb = TenantFilterBank(d=32, n_tenants=T, n_shards=S,
                      n_keys_per_tenant=N // T, bits_per_key=14.0)
keys = rng.integers(0, 1 << 32, N, dtype=np.uint64).astype(np.uint32)
tenants = rng.integers(0, T, N).astype(np.uint32)
jt, jk = jnp.asarray(tenants), jnp.asarray(keys)
state, meta = tb.build(jt, jk)
mesh = jax.make_mesh((2, 4), ("replica", "data"))
sb = ShardedTenantFilterBank(tb, mesh, "data", "replica")
sstate, smeta = sb.build(jt, jk)
assert np.array_equal(np.asarray(state), np.asarray(sstate)), "insert"
assert np.array_equal(np.asarray(meta), np.asarray(smeta)), "meta insert"
Qp, Qr = 50001, 50000   # odd point batch exercises the replica padding
qs = rng.integers(0, 1 << 32, Qp, dtype=np.uint64).astype(np.uint32)
qpt = rng.integers(0, T, Qp).astype(np.uint32)
p1 = np.asarray(tb.point(state, jnp.asarray(qpt), jnp.asarray(qs)))
p2 = np.asarray(sb.point(sstate, jnp.asarray(qpt), jnp.asarray(qs)))
assert np.array_equal(p1, p2), "point verdicts differ"
lo64 = rng.integers(0, 1 << 32, Qr, dtype=np.uint64)
hi = np.minimum(lo64 + rng.integers(0, 1 << 12, Qr).astype(np.uint64),
                (1 << 32) - 1).astype(np.uint32)
lo = lo64.astype(np.uint32)
qrt = rng.integers(0, T, Qr).astype(np.uint32)
args = (jnp.asarray(qrt), jnp.asarray(lo), jnp.asarray(hi))
r1 = np.asarray(tb.range(state, *args))
r2 = np.asarray(sb.range(sstate, *args))
assert np.array_equal(r1, r2), "range verdicts differ"
m1 = np.asarray(tb.range(state, *args, meta))
m2 = np.asarray(sb.range(sstate, *args, smeta))
assert np.array_equal(m1, m2), "meta-gated range verdicts differ"
assert not (m1 & ~r1).any(), "meta widened a verdict"
# inserted keys never lost by either path
pk = np.asarray(sb.point(sstate, jt, jk))
assert pk.all(), "replication introduced point false negatives"
slo = np.maximum(keys.astype(np.int64) - 5, 0).astype(np.uint32)
shi = np.minimum(keys.astype(np.int64) + 5, (1 << 32) - 1).astype(np.uint32)
sr = np.asarray(sb.range(sstate, jt, jnp.asarray(slo), jnp.asarray(shi),
                         smeta))
assert sr.all(), "replication introduced range false negatives"
cand, skip = tb.meta_skip_stats(meta, *args)
assert int(skip) > 0, "meta filter skipped nothing"
print("TENANT-BANK-OK", int(p1.sum()), int(r1.sum()), int(m1.sum()),
      int(skip), int(cand))
""", devices=8)
    assert "TENANT-BANK-OK" in r.stdout, r.stdout + r.stderr
