"""The typed filter façade (repro/api.py, DESIGN.md §11).

Covers: FilterSpec validation, codec threading on every probe surface
(single / bank / tenant / store), the preserved one-fused-gather jaxpr
invariant behind the façade, the legacy-constructor deprecation map, the
validated BLOOMRF_VMEM_BUDGET_U32 knob, and the vectorized seeds_np.
"""
import warnings

import jax
import numpy as np
import pytest

import repro
from repro.api import FilterSpec, open_filter
from test_engine import _count_gathers


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(dtype="u128"),
    dict(placement="cluster"),
    dict(backend="gpu"),
    dict(tuning="magic"),
    dict(n=0),
    dict(bits_per_key=12.0, target_fpr=0.01),
    dict(bits_per_key=-1.0),
    dict(target_fpr=1.5),
    dict(dtype="u32", range_log2=40),
    dict(delta=9),
    dict(shards=3),
    dict(tenants=0),
    dict(chunk=0),
    dict(chunk=-1),
    dict(backend="resident", placement="bank"),
    dict(backend="stacked", placement="single"),
    dict(tuning="advised", placement="store"),
])
def test_spec_rejects_bad_fields(kw):
    with pytest.raises(ValueError, match="FilterSpec"):
        FilterSpec(**kw)


def test_spec_target_fpr_sizing():
    spec = FilterSpec(dtype="u32", n=10_000, target_fpr=0.05, range_log2=10)
    bpk = spec.resolved_bits_per_key()
    assert 6 <= bpk <= 40
    from repro.core.model import basic_range_fpr

    assert basic_range_fpr(32, 10_000, bpk * 10_000, 2.0 ** 10,
                           delta=7) <= 0.05
    # default sizing without either knob
    assert FilterSpec().resolved_bits_per_key() == 16.0
    assert "b/key" in spec.describe()


def test_open_filter_requires_spec():
    with pytest.raises(TypeError):
        open_filter({"dtype": "u64"})


# ---------------------------------------------------------------------------
# deprecation map: every legacy constructor warns, the façade never does
# ---------------------------------------------------------------------------

def _legacy_constructors():
    from repro.core import BloomRF, basic_layout
    from repro.dist.filter_bank import FilterBank
    from repro.dist.tenant_bank import TenantFilterBank
    from repro.kernels import FilterOps
    from repro.store import Store

    lay = basic_layout(32, 1000, 12.0, delta=6)
    return [
        ("BloomRF", lambda: BloomRF(lay)),
        ("FilterOps", lambda: FilterOps(lay)),
        ("FilterBank", lambda: FilterBank(32, 4, 1000)),
        ("TenantFilterBank", lambda: TenantFilterBank(32, 2, 2, 500)),
        ("Store", lambda: Store(d=32)),
    ]


@pytest.mark.parametrize("name,ctor",
                         _legacy_constructors(),
                         ids=[n for n, _ in _legacy_constructors()])
def test_legacy_constructor_warns_with_spec_equivalent(name, ctor):
    with pytest.warns(repro.LegacyAPIWarning, match="FilterSpec"):
        ctor()


@pytest.mark.parametrize("spec", [
    FilterSpec(dtype="u32", n=1000),
    FilterSpec(dtype="u32", n=1000, backend="xla"),
    FilterSpec(dtype="f64", n=1000, placement="bank", shards=2),
    FilterSpec(dtype="u32", n=500, placement="tenant", tenants=2, shards=2),
    FilterSpec(dtype="f32", placement="store", memtable_limit=64),
], ids=["single", "single-xla", "bank", "tenant", "store"])
def test_facade_emits_no_deprecation_warnings(spec):
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        open_filter(spec)


# ---------------------------------------------------------------------------
# one fused gather behind the façade (single, bank, store placements)
# ---------------------------------------------------------------------------

def test_single_placement_one_gather_jaxpr():
    h = open_filter(FilterSpec(dtype="u32", n=5_000, backend="xla"))
    h.insert(np.arange(100, dtype=np.uint64))
    lo = np.arange(16, dtype=np.uint64)
    hi = lo + 7
    import jax.numpy as jnp

    kd = h.filter.kdtype
    jx = jax.make_jaxpr(h.filter.range)(h.state, jnp.asarray(lo, kd),
                                        jnp.asarray(hi, kd))
    assert _count_gathers(jx.jaxpr) == 1, jx.pretty_print()
    jp = jax.make_jaxpr(h.filter.point)(h.state, jnp.asarray(lo, kd))
    assert _count_gathers(jp.jaxpr) == 1


def test_bank_placement_one_gather_jaxpr():
    h = open_filter(FilterSpec(dtype="u32", n=5_000, placement="bank",
                               shards=4))
    import jax.numpy as jnp

    kd = h.bank.kdtype
    lo = jnp.asarray(np.arange(16, dtype=np.uint64), kd)
    hi = lo + 7
    jx = jax.make_jaxpr(h.bank.range)(h.state, lo, hi)
    assert _count_gathers(jx.jaxpr) == 1, jx.pretty_print()
    jp = jax.make_jaxpr(h.bank.point)(h.state, lo)
    assert _count_gathers(jp.jaxpr) == 1


def test_store_placement_one_gather_jaxpr():
    h = open_filter(FilterSpec(dtype="f32", placement="store",
                               memtable_limit=128, level0_runs=8))
    rng = np.random.default_rng(5)
    for v in rng.normal(0, 100, 700).astype(np.float32):
        h.put(float(v), 0)
    h.flush()
    store = h.store
    assert store.n_runs >= 2          # a real multi-run stack
    store._refresh()
    import jax.numpy as jnp

    lo = jnp.zeros(16, store.kdtype)
    hi = lo + 77
    jx = jax.make_jaxpr(store._probe._range_all)(store._flat, lo, hi)
    assert _count_gathers(jx.jaxpr) == 1, jx.pretty_print()
    jp = jax.make_jaxpr(store._probe._point_all)(store._flat, lo)
    assert _count_gathers(jp.jaxpr) == 1


# ---------------------------------------------------------------------------
# typed round-trips: f64 / str / multiattr, façade probes + Store.scan
# (together > 1e5 fuzz ops, zero false negatives everywhere)
# ---------------------------------------------------------------------------

def test_float64_roundtrip_filter_bank_store(rng):
    n, q = 20_000, 20_000
    keys = rng.normal(0.0, 1e6, n)
    single = open_filter(FilterSpec(dtype="f64", n=n, backend="xla"))
    single.insert(keys)
    assert single.point(keys).all()                       # n point ops
    lo = keys - rng.uniform(0.0, 10.0, n)
    hi = keys + rng.uniform(0.0, 10.0, n)
    assert single.range(lo, hi).all()                     # n range ops

    bank = open_filter(FilterSpec(dtype="f64", n=n, placement="bank",
                                  shards=4))
    bank.insert(keys)
    assert bank.point(keys[:q]).all()
    assert bank.range(lo[:q], hi[:q]).all()

    # LSM store: float put -> scan windows must return every stored key
    ts = open_filter(FilterSpec(dtype="f64", placement="store",
                                memtable_limit=512))
    stored = keys[:3_000]
    for i, v in enumerate(stored):
        ts.put(float(v), i)
    ts.flush()
    got = ts.get(float(stored[7]))
    assert got is not None
    centers = stored[rng.integers(0, len(stored), 2_000)]
    res = ts.scan_many(centers - 1.0, centers + 1.0)
    su = np.sort(np.unique(stored))
    for c, rows in zip(centers, res):
        found = {k for k, _ in rows}
        i0, i1 = np.searchsorted(su, [c - 1.0, c + 1.0])
        expect = set(su[i0:i1].tolist()) | ({float(c)} if c in su else set())
        missing = {e for e in expect if c - 1.0 <= e <= c + 1.0} - found
        assert not missing, f"store scan missed float keys: {missing}"


def test_string_roundtrip_filter_and_store(rng):
    import random

    pr = random.Random(17)
    words = list({"".join(pr.choices("abcdefgxyz", k=pr.randint(0, 12)))
                  for _ in range(3_000)})
    single = open_filter(FilterSpec(dtype="str", n=len(words),
                                    backend="xla"))
    single.insert(words)
    assert single.point(words).all()
    # ranges straddling each inserted string (string order)
    assert single.range(words, words).all()
    assert single.range([w[:-1] if w else "" for w in words],
                        [w + "~" for w in words]).all()

    ss = open_filter(FilterSpec(dtype="str", placement="store",
                                memtable_limit=256))
    stored = sorted(words[:1_000])
    for i, w in enumerate(stored):
        ss.put(w, i)
    ss.flush()
    assert ss.get(stored[3]) is not None
    for _ in range(300):
        i = pr.randrange(len(stored))
        j = min(i + pr.randrange(20), len(stored) - 1)
        lo, hi = stored[i], stored[j]
        got = [k for k, _ in ss.scan(lo, hi)]
        assert got == stored[i:j + 1], (lo, hi)   # exact: no FN, no FP


def test_multiattr_roundtrip_filter_and_store(rng):
    n = 10_000
    a = rng.integers(0, 1 << 16, n, dtype=np.uint64)
    b = rng.integers(0, 1 << 31, n, dtype=np.uint64)
    single = open_filter(FilterSpec(dtype="multiattr", n=n, backend="xla"))
    single.insert((a, b))
    assert single.point((a, b)).all()
    # A == a AND B in [b-δ, b+δ] through the <A,B> codes
    blo = np.maximum(b, 5) - 5
    bhi = np.minimum(b + 5, np.uint64((1 << 32) - 1))
    assert single.range((a, blo), (a, bhi)).all()
    # mirrored predicate through the <B,A> codes
    alo = np.maximum(a, 3) - 3
    ahi = np.minimum(a + 3, np.uint64((1 << 32) - 1))
    assert single.range_where_b(b, alo, ahi).all()

    ms = open_filter(FilterSpec(dtype="multiattr", placement="store",
                                memtable_limit=256))
    for i in range(2_000):
        ms.put((int(a[i]), int(b[i])), i)
    ms.flush()
    assert ms.get((int(a[0]), int(b[0]))) == 0
    # conjunctive scans vs brute force
    pairs = sorted(zip(a[:2_000].tolist(), b[:2_000].tolist()))
    for i in range(0, 1_000, 7):
        qa = int(a[i])
        qlo, qhi = int(blo[i]), int(bhi[i])
        got = {k for k, _ in ms.scan((qa, qlo), (qa, qhi))}
        expect = {(x, y) for x, y in pairs if x == qa and qlo <= y <= qhi}
        assert expect <= got        # FN-free; equality holds too (exact keys)
        assert got == expect


def test_multiattr_scan_many_column_bounds_full_batch():
    """Batched multiattr scans with column-form (a_vec, b_vec) bounds must
    return one result list per query, not truncate to the 2 column rows."""
    ms = open_filter(FilterSpec(dtype="multiattr", placement="store",
                                memtable_limit=64))
    for i in range(100):
        ms.put((i % 10, i), i)
    ms.flush()
    a = np.arange(5, dtype=np.uint64)
    res = ms.scan_many((a, np.zeros(5, np.uint64)),
                       (a, np.full(5, 99, np.uint64)))
    assert len(res) == 5
    for ai, rows in zip(a, res):
        assert rows and all(k[0] == int(ai) for k, _ in rows)


def test_tenant_scalar_tenant_broadcasts_across_chunks():
    """A scalar tenant id must broadcast over probe batches longer than one
    chunk (same semantics as the insert path)."""
    h = open_filter(FilterSpec(dtype="u32", n=64, placement="tenant",
                               tenants=2, shards=2, chunk=8))
    keys = np.arange(20, dtype=np.uint64) * 7 + 3
    h.insert(1, keys)
    assert h.point(1, keys).all()                  # 20 queries, chunk=8
    assert h.range(1, keys, keys + 1).all()
    assert not h.point(0, keys).any()              # isolation intact
    with pytest.raises(ValueError, match="align"):
        h.point(np.zeros(3, np.uint32), keys)      # 3 does not align to 20


# ---------------------------------------------------------------------------
# BLOOMRF_VMEM_BUDGET_U32: validated at read time, both knob paths
# ---------------------------------------------------------------------------

def _kernel_layout():
    from repro.core import basic_layout

    return basic_layout(32, 200_000, 16.0, delta=6)


@pytest.mark.parametrize("bad", ["banana", "", "1.5", "-3", "0"])
def test_vmem_budget_env_validated_at_read_time(monkeypatch, bad):
    from repro.kernels.ops import FilterOps, read_vmem_budget_u32

    monkeypatch.setenv("BLOOMRF_VMEM_BUDGET_U32", bad)
    with pytest.raises(ValueError, match="BLOOMRF_VMEM_BUDGET_U32"):
        read_vmem_budget_u32()
    with pytest.raises(ValueError, match="BLOOMRF_VMEM_BUDGET_U32"):
        FilterOps(_kernel_layout(), _warn=False)


def test_vmem_budget_env_and_override_paths(monkeypatch):
    from repro.kernels.ops import DEFAULT_VMEM_BUDGET_U32, FilterOps

    lay = _kernel_layout()
    monkeypatch.delenv("BLOOMRF_VMEM_BUDGET_U32", raising=False)
    assert FilterOps(lay, _warn=False).vmem_budget_u32 \
        == DEFAULT_VMEM_BUDGET_U32
    # env knob: small budget flips the dispatch to partitioned
    monkeypatch.setenv("BLOOMRF_VMEM_BUDGET_U32", "64")
    ops = FilterOps(lay, _warn=False)
    assert ops.vmem_budget_u32 == 64 and not ops.resident
    # per-instance override beats the env
    ops = FilterOps(lay, vmem_budget_u32=1 << 22, _warn=False)
    assert ops.resident
    # the façade's backend knob rides the same override
    h = open_filter(FilterSpec(dtype="u32", n=200_000,
                               backend="partitioned"))
    assert h.ops is not None and not h.ops.resident
    h = open_filter(FilterSpec(dtype="u32", n=200_000, backend="resident"))
    assert h.ops is not None and h.ops.resident


# ---------------------------------------------------------------------------
# seeds_np vectorization + codec exports
# ---------------------------------------------------------------------------

def test_seeds_np_vectorized_matches_scalar_loop():
    from repro.filters.api import mix64_np, seeds_np

    def reference(base, n):
        out = np.empty(n, np.uint64)
        s = np.uint64(base)
        for i in range(n):
            with np.errstate(over="ignore"):
                s = s + np.uint64(0x9E3779B97F4A7C15)
            out[i] = mix64_np(np.asarray([s]))[0]
        return out

    for base in (0, 1, 0xDEADBEEF, 2 ** 63, 2 ** 64 - 1):
        assert np.array_equal(seeds_np(base, 13), reference(base, 13))
    assert seeds_np(7, 0).shape == (0,)


def test_codec_helpers_exported_from_core():
    import repro.core as core

    for name in ("float64_to_u64", "u64_to_float64", "float32_to_u32",
                 "u32_to_float32", "string_point_code",
                 "string_range_bounds", "pack2", "unpack2", "pack2x32",
                 "unpack2x32", "multiattr_insert_codes",
                 "multiattr_range_for_a_eq_b_range"):
        assert name in core.__all__
        assert callable(getattr(core, name))
