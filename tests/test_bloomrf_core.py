"""Core bloomRF correctness: the no-false-negative invariant (exhaustive on
small domains, randomized on 64-bit), FPR agreement with the paper's model,
and the paper's §7 worked example."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import brute_force_range_truth
from repro.core import BloomRF, FilterLayout, basic_layout
from repro.core.model import basic_range_fpr, level_fprs
from repro.core.tuning import advise


def _build(layout, keys):
    f = BloomRF(layout)
    return f, f.build(jnp.asarray(keys, f.kdtype))


@pytest.mark.parametrize("delta", [1, 2, 3, 4, 5, 6, 7])
def test_exhaustive_no_false_negatives_small_domain(rng, delta):
    d = 8
    keys = np.unique(rng.integers(0, (1 << d) - 1, 12, dtype=np.uint64))
    lay = basic_layout(d, len(keys), bits_per_key=14.0, delta=delta)
    f, state = _build(lay, keys)
    los, his = np.meshgrid(np.arange(1 << d, dtype=np.uint64),
                           np.arange(1 << d, dtype=np.uint64))
    mask = los.ravel() <= his.ravel()
    lo = los.ravel()[mask]
    hi = his.ravel()[mask]
    res = np.asarray(f.range(state, jnp.asarray(lo, f.kdtype),
                             jnp.asarray(hi, f.kdtype)))
    truth = brute_force_range_truth(keys, lo, hi)
    assert not (truth & ~res).any(), "range false negative"
    pq = np.arange(1 << d, dtype=np.uint64)
    pres = np.asarray(f.point(state, jnp.asarray(pq, f.kdtype)))
    assert pres[np.isin(pq, keys)].all(), "point false negative"


@pytest.mark.parametrize("d,delta,n", [(16, 4, 200), (32, 6, 500),
                                       (64, 7, 2000)])
def test_no_false_negatives_random(rng, d, delta, n):
    hi_dom = (1 << d) - 1 if d < 64 else np.uint64(0xFFFFFFFFFFFFFFFF)
    keys = rng.integers(0, hi_dom, n, dtype=np.uint64)
    lay = basic_layout(d, n, bits_per_key=16.0, delta=delta)
    f, state = _build(lay, keys)
    lo = rng.integers(0, hi_dom, 4000, dtype=np.uint64)
    span = rng.integers(0, 1 << 14, 4000, dtype=np.uint64)
    hi = np.minimum(lo + span, np.uint64(hi_dom))
    res = np.asarray(f.range(state, jnp.asarray(lo, f.kdtype),
                             jnp.asarray(hi, f.kdtype)))
    truth = brute_force_range_truth(keys, lo, hi)
    assert not (truth & ~res).any()


def test_tuned_layout_with_exact_segment(rng):
    lay = FilterLayout(
        d=32, deltas=(7, 7, 4, 2), replicas=(1, 1, 1, 2),
        seg_of_layer=(2, 2, 1, 1),
        seg_bits=(1 << 12, 4096, 8192), exact_seg=0)
    n = 300
    keys = rng.integers(0, (1 << 32) - 1, n, dtype=np.uint64)
    f, state = _build(lay, keys)
    lo = rng.integers(0, (1 << 32) - 1, 3000, dtype=np.uint64)
    hi = np.minimum(lo + np.uint64(1 << 10), np.uint64((1 << 32) - 1))
    res = np.asarray(f.range(state, jnp.asarray(lo, f.kdtype),
                             jnp.asarray(hi, f.kdtype)))
    truth = brute_force_range_truth(keys, lo, hi)
    assert not (truth & ~res).any()
    fpr = (res & ~truth).mean()
    assert fpr < 0.2


def test_advisor_layout_end_to_end(rng):
    n = 50_000
    res = advise(d=64, n=n, m_bits=16 * n, R=1e6)
    f = BloomRF(res.layout)
    keys = rng.integers(0, 1 << 63, n, dtype=np.uint64)
    state = f.build_np(keys)
    lo = rng.integers(0, 1 << 63, 4000, dtype=np.uint64)
    hi = lo + np.uint64(1 << 16)
    r = np.asarray(f.range(state, jnp.asarray(lo), jnp.asarray(hi)))
    truth = brute_force_range_truth(keys, lo, hi)
    assert not (truth & ~r).any()
    fpr = (r & ~truth).sum() / max((~truth).sum(), 1)
    assert fpr < 10 * max(res.fpr_range_max, 0.01)


def test_paper_worked_example_fpr_model():
    """Paper §7: n=3, d=16, Δ=4, m=32 -> p≈0.683, fpr_15≈0.95, point≈1%."""
    lay = FilterLayout(d=16, deltas=(4,) * 4, replicas=(1,) * 4,
                       seg_of_layer=(0,) * 4, seg_bits=(32,))
    assert lay.total_bits == 32
    lm = level_fprs(lay, n=3)
    assert abs(lm.p_seg[0] - 0.683) < 0.01
    assert abs(lm.fpr[15] - 0.95) < 0.01
    assert abs(lm.fpr[0] - 0.0148) < 0.005


def test_paper_section6_space_claims():
    """§6: basic bloomRF at 17 bpk handles R=2^14 at ~1.5%; 22 bpk -> 2^21
    at ~2.5%."""
    n = 50_000_000
    assert abs(basic_range_fpr(64, n, 17 * n, 2 ** 14) - 0.015) < 0.005
    assert abs(basic_range_fpr(64, n, 22 * n, 2 ** 21) - 0.025) < 0.01


def test_advisor_matches_paper_tuning_example():
    """§7 advisor: n=50M, 16 bpk, R=1e10 -> ~0.5% point, ~3% range FPR."""
    res = advise(d=64, n=50_000_000, m_bits=16 * 50_000_000, R=1e10)
    assert res.layout.deltas[:4] == (7, 7, 7, 7)
    assert 0.002 < res.fpr_point < 0.01
    assert 0.01 < res.fpr_range_max < 0.06


def test_empirical_fpr_tracks_model(rng):
    n = 100_000
    lay = basic_layout(64, n, bits_per_key=17.0, delta=7)
    f = BloomRF(lay)
    keys = rng.integers(0, 1 << 63, n, dtype=np.uint64)
    state = f.build_np(keys)
    lo = rng.integers(0, 1 << 63, 20_000, dtype=np.uint64)
    hi = lo + np.uint64(2 ** 14 - 1)
    r = np.asarray(f.range(state, jnp.asarray(lo), jnp.asarray(hi)))
    truth = brute_force_range_truth(keys, lo, hi)
    emp = (r & ~truth).sum() / max((~truth).sum(), 1)
    model = basic_range_fpr(64, n, 17.0 * n, 2 ** 14)
    assert emp <= 2.0 * model + 0.01  # eq. (6) is an upper bound


def test_online_insert_matches_bulk(rng):
    lay = basic_layout(32, 500, bits_per_key=12.0, delta=6)
    f = BloomRF(lay)
    keys = rng.integers(0, (1 << 32) - 1, 500, dtype=np.uint64)
    bulk = f.build(jnp.asarray(keys, f.kdtype))
    online = f.insert_online(f.init_state(), jnp.asarray(keys, f.kdtype))
    assert (np.asarray(bulk) == np.asarray(online)).all()
    npb = f.build_np(keys)
    assert (np.asarray(bulk) == np.asarray(npb)).all()


def test_word_access_bounds():
    lay = basic_layout(64, 10_000, 16.0, delta=7)
    f = BloomRF(lay)
    # paper: <= 4 word accesses per layer (+ coverings), O(k) total
    assert f.word_accesses_per_range_query() <= 6 * lay.k
    assert f.word_accesses_per_point_query() == lay.k
