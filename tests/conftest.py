import os
import sys

# Filter tests need 64-bit keys; model code pins dtypes explicitly so the
# x64 flag is safe to enable process-wide for the test session.
os.environ.setdefault("JAX_ENABLE_X64", "1")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xB100F)


def brute_force_range_truth(keys, lo, hi):
    """Ground-truth range emptiness for sorted uint64 keys."""
    ks = np.sort(np.asarray(keys, np.uint64))
    lo = np.asarray(lo, np.uint64)
    hi = np.asarray(hi, np.uint64)
    idx = np.searchsorted(ks, lo)
    in_range = idx < len(ks)
    cand = ks[np.minimum(idx, len(ks) - 1)]
    return in_range & (cand <= hi)
