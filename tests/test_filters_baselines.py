"""Baseline filters: no false negatives + sane FPR/size accounting."""
import numpy as np
import pytest

from conftest import brute_force_range_truth
from repro.filters import (BloomFilter, BloomRFAdapter, CuckooFilter,
                           FencePointers, PrefixBloomFilter, Rosetta,
                           SuRFLite)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(5)
    keys = rng.integers(0, 1 << 63, 50_000, dtype=np.uint64)
    lo = rng.integers(0, 1 << 63, 5_000, dtype=np.uint64)
    hi = lo + np.uint64(2 ** 10 - 1)
    pq = np.concatenate([keys[:500],
                         rng.integers(0, 1 << 63, 2000, dtype=np.uint64)])
    return keys, lo, hi, pq


RANGE_FILTERS = [
    ("bloomrf", lambda: BloomRFAdapter(16, mode="basic")),
    ("bloomrf-tuned", lambda: BloomRFAdapter(18, mode="tuned", R=2 ** 20)),
    ("rosetta", lambda: Rosetta(18, max_range_log2=10)),
    ("surf", lambda: SuRFLite.for_budget(16)),
    ("prefix-bf", lambda: PrefixBloomFilter(16, prefix_level=10)),
    ("minmax", lambda: FencePointers(16)),
]


@pytest.mark.parametrize("name,mk", RANGE_FILTERS)
def test_range_no_false_negative(data, name, mk):
    keys, lo, hi, _ = data
    f = mk()
    f.build(keys)
    res = f.range(lo, hi)
    truth = brute_force_range_truth(keys, lo, hi)
    assert not (truth & ~res).any(), f"{name} produced range false negatives"
    fpr = (res & ~truth).sum() / max((~truth).sum(), 1)
    assert fpr <= 1.0
    assert f.size_bits() > 0


POINT_FILTERS = [
    ("bf", lambda: BloomFilter(12)),
    ("cuckoo", lambda: CuckooFilter(12)),
    ("bloomrf", lambda: BloomRFAdapter(14, mode="basic")),
    ("surf-hash", lambda: SuRFLite(suffix_bits=8, mode="hash")),
]


@pytest.mark.parametrize("name,mk", POINT_FILTERS)
def test_point_no_false_negative(data, name, mk):
    keys, _, _, pq = data
    f = mk()
    f.build(keys[:20_000])
    res = f.point(pq)
    truth = np.isin(pq, keys[:20_000])
    assert not (truth & ~res).any(), f"{name} produced point false negatives"
    fpr = (res & ~truth).sum() / max((~truth).sum(), 1)
    assert fpr < 0.25, f"{name} point FPR {fpr} unreasonable"


def test_rosetta_doubting_reduces_fpr(data):
    keys, lo, hi, _ = data
    lo16 = lo
    hi16 = lo + np.uint64(15)
    deep = Rosetta(20, max_range_log2=4)
    deep.build(keys)
    r = deep.range(lo16, hi16)
    truth = brute_force_range_truth(keys, lo16, hi16)
    fpr = (r & ~truth).sum() / max((~truth).sum(), 1)
    assert fpr < 0.05  # small ranges with budget: Rosetta's sweet spot


def test_fence_pointers_exact_on_sorted_dense():
    keys = np.arange(10_000, dtype=np.uint64) * 2
    f = FencePointers(16)
    f.build(keys)
    assert f.range(np.asarray([0]), np.asarray([5]))[0]
    # far outside the key span -> definitely negative
    assert not f.range(np.asarray([10 ** 9]), np.asarray([10 ** 9 + 5]))[0]
