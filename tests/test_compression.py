"""Elias-Fano posting lists and filter-state snapshots (dist/compression.py).
The int8 error-feedback path is covered by tests/test_train.py."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BloomRF, basic_layout
from repro.dist.compression import (elias_fano_decode, elias_fano_encode, elias_fano_size_bits,
                                    pack_filter_state, unpack_filter_state)


@pytest.mark.parametrize("n,u", [
    (0, 100), (1, 10), (1000, 1 << 20), (5000, 1 << 40), (64, 64),
    (3000, 1 << 63),
])
def test_ef_roundtrip_sorted_posting_lists(rng, n, u):
    v = np.sort(rng.integers(0, u, n, dtype=np.uint64))
    enc = elias_fano_encode(v, universe=u)
    assert np.array_equal(elias_fano_decode(enc), v)


def test_ef_roundtrip_with_duplicates(rng):
    v = np.sort(rng.integers(0, 500, 2000, dtype=np.uint64))
    enc = elias_fano_encode(v, universe=500)
    assert np.array_equal(elias_fano_decode(enc), v)


def test_ef_size_is_quasi_succinct(rng):
    """n(2 + ceil(log2(u/n))) bits, far below 64 n for dense-ish lists."""
    n, u = 10_000, 1 << 24
    v = np.sort(rng.integers(0, u, n, dtype=np.uint64))
    bits = elias_fano_size_bits(elias_fano_encode(v, universe=u))
    assert bits <= n * (2 + int(np.ceil(np.log2(u / n))) + 1)
    assert bits < 64 * n / 4


def test_ef_rejects_unsorted_and_out_of_universe():
    with pytest.raises(ValueError):
        elias_fano_encode(np.asarray([3, 1, 2], np.uint64))
    with pytest.raises(ValueError):
        elias_fano_encode(np.asarray([5], np.uint64), universe=5)


def test_filter_state_snapshot_roundtrip(rng):
    lay = basic_layout(32, 3000, 16.0, delta=6)
    f = BloomRF(lay)
    keys = rng.integers(0, 1 << 32, 3000, dtype=np.uint64).astype(np.uint32)
    state = np.asarray(f.build(jnp.asarray(keys)))
    enc = pack_filter_state(state)
    assert np.array_equal(unpack_filter_state(enc, lay.total_u32), state)
    # sparse fill curve -> snapshot beats the raw bitmap
    assert elias_fano_size_bits(enc) < 32 * lay.total_u32
