"""Pallas kernels vs pure-jnp oracles (interpret mode): bit-identical
results across layout/shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BloomRF, FilterLayout, basic_layout
from repro.kernels import (FilterOps, insert_resident,
                           point_probe_partitioned, point_probe_resident,
                           range_probe_resident)
from repro.kernels import ref as kref


def _keys(rng, d, n):
    return rng.integers(0, (1 << d) - 1, n, dtype=np.uint64).astype(np.uint32)


@pytest.mark.parametrize("d,delta,n,bpk", [
    (32, 6, 2000, 12.0),
    (32, 7, 1000, 16.0),
    (24, 4, 3000, 10.0),
    (16, 2, 500, 14.0),
])
def test_insert_kernel_bit_identical(rng, d, delta, n, bpk):
    lay = basic_layout(d, n, bpk, delta=delta)
    keys = _keys(rng, d, n)
    st_ref = kref.insert_ref(lay, BloomRF(lay).init_state(),
                             jnp.asarray(keys))
    st_k = insert_resident(lay, BloomRF(lay).init_state(), jnp.asarray(keys),
                           128, True)
    assert (np.asarray(st_ref) == np.asarray(st_k)).all()


@pytest.mark.parametrize("tile", [64, 512])
@pytest.mark.parametrize("d,delta", [(32, 6), (32, 7), (20, 3)])
def test_point_probe_resident(rng, d, delta, tile):
    lay = basic_layout(d, 2000, 12.0, delta=delta)
    keys = _keys(rng, d, 2000)
    state = BloomRF(lay).build(jnp.asarray(keys, jnp.uint32))
    qs = np.concatenate([keys[:500], _keys(rng, d, 1500)])
    want = np.asarray(kref.point_ref(lay, state, jnp.asarray(qs)))
    got = np.asarray(point_probe_resident(lay, state, jnp.asarray(qs),
                                          tile, True))
    assert (want == got).all()
    assert got[:500].all()  # no false negatives through the kernel


@pytest.mark.parametrize("block_u32", [256, 2048])
def test_point_probe_partitioned(rng, block_u32):
    lay = basic_layout(32, 5000, 14.0, delta=6)
    keys = _keys(rng, 32, 5000)
    state = BloomRF(lay).build(jnp.asarray(keys, jnp.uint32))
    qs = np.concatenate([keys[:300], _keys(rng, 32, 700)])
    want = np.asarray(kref.point_ref(lay, state, jnp.asarray(qs)))
    got = np.asarray(point_probe_partitioned(lay, state, jnp.asarray(qs),
                                             128, block_u32, True))
    assert (want == got).all()


@pytest.mark.parametrize("delta", [4, 6, 7])
def test_range_probe_kernel(rng, delta):
    lay = basic_layout(32, 2000, 14.0, delta=delta)
    keys = _keys(rng, 32, 2000)
    state = BloomRF(lay).build(jnp.asarray(keys, jnp.uint32))
    lo = _keys(rng, 32, 800)
    hi = lo + rng.integers(0, 1 << 10, 800).astype(np.uint32)
    hi = np.maximum(lo, hi)
    want = np.asarray(kref.range_ref(lay, state, jnp.asarray(lo),
                                     jnp.asarray(hi)))
    got = np.asarray(range_probe_resident(lay, state, jnp.asarray(lo),
                                          jnp.asarray(hi), 256, True))
    assert (want == got).all()


def test_filter_ops_dispatcher(rng):
    lay = basic_layout(32, 1000, 12.0, delta=6)
    ops = FilterOps(lay, interpret=True)
    keys = _keys(rng, 32, 1000)
    state = ops.insert(ops.init_state(), jnp.asarray(keys))
    assert np.asarray(ops.point(state, jnp.asarray(keys[:200]))).all()
    lo = jnp.asarray(keys[:100])
    hi = jnp.asarray(keys[:100] + np.uint32(7))
    assert np.asarray(ops.range(state, lo, hi)).all()


def test_kernel_rejects_64bit_domain():
    lay = basic_layout(64, 1000, 12.0, delta=7)
    with pytest.raises(ValueError):
        kref.check_kernel_layout(lay)


# ---------------------------------------------------------------------------
# kernel-vs-XLA parity across random layouts (multi-segment, replicas, any Δ)
# ---------------------------------------------------------------------------

def _random_kernel_layout(rng):
    """Random kernel-eligible layout: d <= 32, 2 segments, replicas, no exact."""
    d = int(rng.integers(16, 33))
    deltas, rem = [], d
    for _ in range(int(rng.integers(2, 5))):
        if rem < 1:
            break
        deltas.append(int(min(rng.integers(1, 8), rem)))
        rem -= deltas[-1]
    k = len(deltas)
    return FilterLayout(
        d=d, deltas=tuple(deltas),
        replicas=tuple(int(r) for r in rng.integers(1, 3, k)),
        seg_of_layer=tuple(int(s) for s in rng.integers(0, 2, k)),
        seg_bits=(8192, 4096), exact_seg=None,
        seed=int(rng.integers(1 << 30)))


@pytest.mark.parametrize("trial", range(6))
def test_range_kernel_parity_random_layouts(trial):
    trng = np.random.default_rng(0xC0FFEE + trial)
    lay = _random_kernel_layout(trng)
    f = BloomRF(lay)
    hi_excl = 1 << lay.d if lay.d < 64 else (1 << 63)
    keys = trng.integers(0, hi_excl, 600, dtype=np.uint64).astype(np.uint32)
    state = f.build(jnp.asarray(keys))
    lo = trng.integers(0, hi_excl, 400, dtype=np.uint64)
    hi = np.minimum(lo + trng.integers(0, 1 << min(lay.d - 1, 12), 400,
                                       dtype=np.uint64), hi_excl - 1)
    lo = lo.astype(np.uint32)
    hi = hi.astype(np.uint32)
    want = np.asarray(kref.range_ref(lay, state, jnp.asarray(lo),
                                     jnp.asarray(hi)))
    got = np.asarray(range_probe_resident(lay, state, jnp.asarray(lo),
                                          jnp.asarray(hi), 128, True))
    assert (want == got).all(), lay.describe()
    # same parity through the dispatcher: forced-XLA ops vs kernel ops
    ops_xla = FilterOps(lay, interpret=True, vmem_budget_u32=1)
    assert not ops_xla.resident
    via_xla = np.asarray(ops_xla.range(state, jnp.asarray(lo),
                                       jnp.asarray(hi)))
    assert (via_xla == got).all()


def test_exact_layout_range_kernel_raises():
    """Exact-layer layouts must be rejected by the kernel path, as documented
    in kernels/rangeprobe.py (bounded lane scan is XLA-only)."""
    from repro.core.tuning import advise

    lay = advise(16, 300, 16384, 64.0).layout
    assert lay.has_exact
    f = BloomRF(lay)
    state = f.build(jnp.asarray(np.arange(300, dtype=np.uint32)))
    lo = jnp.asarray(np.arange(10, dtype=np.uint32))
    with pytest.raises(ValueError, match="exact-layer"):
        range_probe_resident(lay, state, lo, lo, 128, True)


def test_exact_layout_ops_falls_back_to_xla(rng):
    """FilterOps.range on an exact-layer layout must silently take the XLA
    path and stay bit-identical to the core filter."""
    from repro.core.tuning import advise

    lay = advise(16, 300, 16384, 64.0).layout
    f = BloomRF(lay)
    keys = rng.integers(0, 1 << 16, 300, dtype=np.uint64).astype(np.uint32)
    ops = FilterOps(lay, interpret=True)
    state = ops.insert(ops.init_state(), jnp.asarray(keys))
    lo = rng.integers(0, 1 << 16, 500, dtype=np.uint64).astype(np.uint32)
    hi = np.minimum(lo + 64, (1 << 16) - 1).astype(np.uint32)
    got = np.asarray(ops.range(state, jnp.asarray(lo), jnp.asarray(hi)))
    want = np.asarray(f.range(state, jnp.asarray(lo), jnp.asarray(hi)))
    assert (want == got).all()
    # straddling ranges must all be positive (no false negatives)
    slo = np.maximum(keys.astype(np.int64) - 3, 0).astype(np.uint32)
    shi = np.minimum(keys.astype(np.int64) + 3, (1 << 16) - 1).astype(np.uint32)
    assert np.asarray(ops.range(state, jnp.asarray(slo),
                                jnp.asarray(shi))).all()
