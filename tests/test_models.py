"""Per-arch smoke tests (reduced configs): one train step (finite loss +
grads), prefill/decode consistency, and KV-cache head padding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import get_model
from repro.models.config import Shape


def _batch_for(model, cfg, shape, rng):
    out = {}
    for k, s in model.input_specs(shape).items():
        if s.dtype == jnp.int32 and s.shape:
            out[k] = jnp.asarray(
                rng.integers(0, max(cfg.vocab - 1, 1), s.shape), jnp.int32)
        elif not s.shape:
            out[k] = jnp.asarray(0, jnp.int32)
        else:
            out[k] = jnp.asarray(rng.normal(0, 1, s.shape), s.dtype)
    return out


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step(arch, rng):
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    shape = Shape("t", 32, 2, "train")
    batch = _batch_for(model, cfg, shape, rng)
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
    # output embedding shape sanity via prefill
    logits, _ = jax.jit(model.prefill)(
        params, {k: v for k, v in batch.items() if k != "labels"})
    assert logits.shape[-1] == cfg.vocab
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def _grow_cache(cache, plen, extra=4):
    def grow(x):
        if x.ndim >= 3 and x.shape[2] == plen:
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, extra)
            return jnp.pad(x, pad)
        return x
    return jax.tree.map(grow, cache)


_CONSISTENCY = ["qwen3-1.7b", "qwen2.5-3b", "moonshot-v1-16b-a3b",
                "mamba2-130m", "zamba2-2.7b", "whisper-base"]


@pytest.mark.parametrize("arch", _CONSISTENCY)
def test_prefill_decode_consistency(arch, rng):
    """decode(token_T | cache(prompt[:T])) == prefill(prompt[:T+1]) logits."""
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.key(1))
    B, T = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab - 1, (B, T)), jnp.int32)
    extra = ({"frames": jnp.asarray(rng.normal(0, 1, (B, cfg.enc_seq,
                                                      cfg.d_model)),
                                    jnp.dtype(cfg.dtype))}
             if cfg.family == "encdec" else {})
    full_logits, _ = jax.jit(model.prefill)(
        params, {"tokens": toks, **extra})
    _, cache = jax.jit(model.prefill)(
        params, {"tokens": toks[:, :T - 1], **extra})
    cache = _grow_cache(cache, T - 1)
    dec_logits, _ = jax.jit(model.decode)(
        params, cache, {"token": toks[:, T - 1:],
                        "pos": jnp.asarray(T - 1, jnp.int32)})
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_kv_cache_head_padding_consistency(rng):
    """Padded-KV decode (kv_cache_pad_heads) must match unpadded decode."""
    base = get_config("qwen2.5-3b", smoke=True)  # kv=2
    padded = dataclasses.replace(base, kv_cache_pad_heads=4)
    B, T = 2, 12
    toks = jnp.asarray(rng.integers(0, base.vocab - 1, (B, T)), jnp.int32)
    outs = []
    for cfg in (base, padded):
        model = get_model(cfg)
        params = model.init(jax.random.key(2))
        _, cache = jax.jit(model.prefill)(params, {"tokens": toks[:, :T - 1]})
        assert cache[0].shape[-2] == cfg.kv_cache_heads
        cache = _grow_cache(cache, T - 1)
        logits, _ = jax.jit(model.decode)(
            params, cache,
            {"token": toks[:, T - 1:], "pos": jnp.asarray(T - 1, jnp.int32)})
        outs.append(np.asarray(logits, np.float32))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-4)


def test_ssd_chunked_matches_naive(rng):
    from repro.models.mamba2 import ssd_chunked
    B, S, H, P, N = 2, 32, 3, 8, 16
    x = jnp.asarray(rng.normal(0, 1, (B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(0, 1, (B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(0, 1, (B, S, N)), jnp.float32)
    y_c, S_f = ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    Sst = np.zeros((B, H, N, P))
    y_n = np.zeros((B, S, H, P))
    for t in range(S):
        dec = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])
        Sst = dec[:, :, None, None] * Sst + np.einsum(
            "bh,bn,bhp->bhnp", np.asarray(dt[:, t]), np.asarray(Bm[:, t]),
            np.asarray(x[:, t]))
        y_n[:, t] = np.einsum("bn,bhnp->bhp", np.asarray(Cm[:, t]), Sst)
    np.testing.assert_allclose(np.asarray(y_c), y_n, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S_f), Sst, atol=1e-4)


def test_flash_attention_matches_dense(rng):
    from repro.models.layers import flash_attention
    B, S, Hkv, G, hd = 2, 24, 2, 3, 8
    q = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, G, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, hd)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block=8)
    # dense reference
    s = np.einsum("bqhgd,bkhd->bhgqk", np.asarray(q), np.asarray(k)) / \
        np.sqrt(hd)
    mask = np.tril(np.ones((S, S), bool))
    s = np.where(mask[None, None, None], s, -np.inf)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    ref = np.einsum("bhgqk,bkhd->bqhgd", p, np.asarray(v))
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-5)


def test_param_counts_are_plausible():
    from repro.models.params import count_params
    expect = {
        "qwen1.5-32b": (30e9, 40e9),
        "granite-8b": (7e9, 10e9),
        "qwen3-1.7b": (1.5e9, 2.7e9),
        "qwen2.5-3b": (2.5e9, 4e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "whisper-base": (0.05e9, 0.12e9),
        "zamba2-2.7b": (2.2e9, 3.5e9),
        "pixtral-12b": (11e9, 14e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        n = count_params(get_model(cfg).table())
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B params out of range"
