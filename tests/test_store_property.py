"""Property tests of the compaction filter-merge invariant (DESIGN.md §10):
a merged filter state admits no false negatives vs a bulk rebuild over the
union of the source runs' keys — across mixed Δ layouts, multi-segment
layouts, replicas, and tombstone-dropping merges.

The hypothesis suite explores the space; ``test_merge_invariant_seeded``
repeats the core check on seeded draws so the invariant stays exercised
even where hypothesis is not installed (it is CI-installed but optional
locally, matching test_bloomrf_property.py).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BloomRF, FilterLayout, basic_layout
from repro.store import Store, StoreConfig
from repro.store.compaction import merge_filter_state
from repro.store.run import Run

try:
    from hypothesis import given, settings, strategies as hst
except ImportError:                                     # pragma: no cover
    hst = None


def _check_union_no_fn(layout, state, union_keys):
    """Every union key (and every straddling range) probes positive."""
    f = BloomRF(layout)
    kj = jnp.asarray(union_keys, f.kdtype)
    assert np.asarray(f.point(state, kj)).all()
    ks = np.asarray(union_keys, np.uint64)
    lo = np.maximum(ks, 2) - 2
    hi = np.minimum(ks + 3, (1 << layout.d) - 1)
    assert np.asarray(f.range(state, jnp.asarray(lo, f.kdtype),
                              jnp.asarray(hi, f.kdtype))).all()


def _merge_case(layout_a, layout_b, target, keys_a, keys_b):
    """Merge two runs' filters under ``target``; verify vs bulk rebuild."""
    fa, fb = BloomRF(layout_a), BloomRF(layout_b)
    run_a = Run(np.unique(keys_a), [0] * len(np.unique(keys_a)),
                np.zeros(len(np.unique(keys_a)), bool), 0, layout_a,
                fa.build(jnp.asarray(np.unique(keys_a), fa.kdtype)))
    run_b = Run(np.unique(keys_b), [0] * len(np.unique(keys_b)),
                np.zeros(len(np.unique(keys_b)), bool), 1, layout_b,
                fb.build(jnp.asarray(np.unique(keys_b), fb.kdtype)))
    union = np.unique(np.concatenate([keys_a, keys_b]))

    def build(lay, keys):
        f = BloomRF(lay)
        return f.build(jnp.asarray(keys, f.kdtype))

    state, how = merge_filter_state([run_a, run_b], target, union, build)
    via_or = how == "or"
    assert via_or == (layout_a == target and layout_b == target)
    _check_union_no_fn(target, state, union)
    if via_or:
        # same-layout OR *is* the bulk rebuild, bit for bit
        np.testing.assert_array_equal(np.asarray(state),
                                      np.asarray(build(target, union)))
    return state


def _random_multiseg_layout(rng, d):
    """Mixed-Δ multi-segment layout (the shapes compaction can meet)."""
    deltas, rem = [], d
    for _ in range(int(rng.integers(2, 4))):
        if rem < 1:
            break
        deltas.append(int(min(rng.integers(1, 8), rem)))
        rem -= deltas[-1]
    k = len(deltas)
    return FilterLayout(
        d=d, deltas=tuple(deltas),
        replicas=tuple(int(r) for r in rng.integers(1, 3, k)),
        seg_of_layer=tuple(int(s) for s in rng.integers(0, 2, k)),
        seg_bits=(4096, 2048), seed=int(rng.integers(1 << 30)))


def _seeded_cases(seed):
    rng = np.random.default_rng(seed)
    d = int(rng.integers(16, 33))
    hi = (1 << d) - 1
    keys_a = rng.integers(0, hi, int(rng.integers(1, 400)), dtype=np.uint64)
    keys_b = rng.integers(0, hi, int(rng.integers(1, 400)), dtype=np.uint64)
    same = basic_layout(d, 256, 14.0, delta=int(rng.integers(1, 8)),
                        seed=seed)
    # same-layout OR merge
    _merge_case(same, same, same, keys_a, keys_b)
    # cross-layout rebuild into a larger class
    bigger = basic_layout(d, 2048, 14.0, delta=int(rng.integers(1, 8)),
                          seed=seed)
    _merge_case(same, same, bigger, keys_a, keys_b)
    # mixed multi-segment sources rebuilt into a multi-segment target
    la = _random_multiseg_layout(rng, d)
    lb = _random_multiseg_layout(rng, d)
    lt = _random_multiseg_layout(rng, d)
    _merge_case(la, lb, lt, keys_a, keys_b)
    _merge_case(la, la, la, keys_a, keys_b)     # multi-segment OR merge


@pytest.mark.parametrize("seed", [11, 22, 33, 44])
def test_merge_invariant_seeded(seed):
    _seeded_cases(seed)


@pytest.mark.parametrize("seed", [7, 19, 42])
def test_promote_merge_invariant(seed):
    """Promotion merges (deletable stores' in-place growth) admit no false
    negatives and distribute over OR: promote(a|b) == promote(a)|promote(b)."""
    from repro.core import promote_state, promotion_factors

    rng = np.random.default_rng(seed)
    d = int(rng.integers(16, 33))
    hi = (1 << d) - 1
    keys_a = rng.integers(0, hi, 300, dtype=np.uint64)
    keys_b = rng.integers(0, hi, 300, dtype=np.uint64)
    small = basic_layout(d, 256, 14.0, delta=6, seed=seed)
    big = basic_layout(d, 1024, 14.0, delta=6, seed=seed)
    assert promotion_factors(small, big) is not None

    def build(lay, keys):
        f = BloomRF(lay)
        return f.build(jnp.asarray(keys, f.kdtype))

    ka, kb = np.unique(keys_a), np.unique(keys_b)
    run_a = Run(ka, [0] * len(ka), np.zeros(len(ka), bool), 0, small,
                build(small, ka))
    run_b = Run(kb, [0] * len(kb), np.zeros(len(kb), bool), 1, small,
                build(small, kb))
    union = np.unique(np.concatenate([ka, kb]))
    state, how = merge_filter_state([run_a, run_b], big, union, build,
                                    allow_promote=True)
    assert how == "promote"
    _check_union_no_fn(big, state, union)
    # promotion distributes over OR — merged-then-promoted is bit-identical
    ored = jnp.bitwise_or(run_a.state, run_b.state)
    np.testing.assert_array_equal(np.asarray(state),
                                  np.asarray(promote_state(ored, small, big)))
    # without allow_promote the same inputs fall back to a rebuild
    _, how2 = merge_filter_state([run_a, run_b], big, union, build)
    assert how2 == "rebuild"


def test_store_compaction_end_to_end_no_fn(rng):
    """Drive a real store through flushes/compactions with deletes and
    re-inserts; every live key must stay reachable (point + range)."""
    st = Store(StoreConfig(d=24, memtable_limit=64, level0_runs=2,
                           fanout=3, bits_per_key=12.0))
    model = {}
    for i in range(4000):
        k = int(rng.integers(0, 1 << 24))
        if i % 11 == 0 and model:
            dk = int(rng.integers(0, 1 << 24))
            st.delete(dk)
            model.pop(dk, None)
        else:
            st.put(k, i)
            model[k] = i
    st.flush()
    assert st.stats.or_merges + st.stats.rebuild_merges > 0
    live = np.fromiter(model.keys(), np.uint64, len(model))
    assert st.get_many(live) == [model[int(k)] for k in live]
    # straddling scans find their keys
    sample = live[rng.integers(0, len(live), 100)]
    res = st.scan_many(np.maximum(sample, 2) - 2,
                       np.minimum(sample + 2, (1 << 24) - 1))
    for k, r in zip(sample, res):
        assert any(kk == int(k) for kk, _ in r)


# ---------------------------------------------------------------------------
# hypothesis exploration (optional locally, installed in CI — only these
# tests skip without it; the seeded suite above always runs)
# ---------------------------------------------------------------------------

if hst is not None:
    _settings = settings(max_examples=25, deadline=None)

    @_settings
    @given(
        d=hst.sampled_from([16, 20, 24, 32]),
        delta_a=hst.integers(1, 7),
        delta_t=hst.integers(1, 7),
        seed=hst.integers(0, 2 ** 16),
        data=hst.data(),
    )
    def test_merged_filter_never_false_negative(d, delta_a, delta_t, seed,
                                                data):
        rng = np.random.default_rng(seed)
        hi = (1 << d) - 1
        na = data.draw(hst.integers(1, 120))
        nb = data.draw(hst.integers(1, 120))
        keys_a = rng.integers(0, hi, na, dtype=np.uint64)
        keys_b = rng.integers(0, hi, nb, dtype=np.uint64)
        src = basic_layout(d, 128, 12.0, delta=delta_a, seed=seed + 1)
        _merge_case(src, src, src, keys_a, keys_b)      # OR path
        tgt = basic_layout(d, 1024, 12.0, delta=delta_t, seed=seed + 1)
        _merge_case(src, src, tgt, keys_a, keys_b)      # rebuild path

    @_settings
    @given(seed=hst.integers(0, 2 ** 16))
    def test_merged_multiseg_filters_never_false_negative(seed):
        _seeded_cases(seed)
