"""Observability plane (src/repro/obs/, DESIGN.md §15).

Covers the three layers and their two hard contracts:

* registry units — device-scalar counter accumulation, histogram
  percentile semantics, family registration/flattening, reset;
* span tracing — null singleton when off, latency histogram + JSONL
  ``bloomrf-trace/v1`` records when on;
* FPR telemetry — both invalidation modes (insert-stream and ground
  truth), the re-probe, and the workload reservoir;
* the **zero-overhead contract**: with observability ENABLED the jaxpr
  of a stacked range probe still contains exactly ONE gather, the fused
  store scan exactly ONE ``pallas_call``, and the jaxpr text is
  bit-for-bit identical to the disabled run;
* durable ``StoreStats`` round-trips through ``Store.snapshot()`` /
  ``restore()``, and the real ``gates.toml`` obs gates evaluate a
  ``bloomrf-metrics/v1`` document end to end.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks import check_gates as cg
from repro.core import basic_layout, stacked_probe
from repro.kernels.store_scan import store_scan_probe
from repro.obs import FprSampler, export_snapshot
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.store import Store, StoreConfig
from repro.store.store import StoreStats


@pytest.fixture(autouse=True)
def _fresh_obs(monkeypatch):
    """Isolated obs state: fresh registry, disabled flag, no trace sink.

    The registry and enabled flag are process globals — tests must not
    leak counters or the enabled state into each other (or into the
    rest of the suite, which pins obs-off jaxprs elsewhere)."""
    monkeypatch.setattr(obs_metrics, "_REGISTRY", obs_metrics.MetricsRegistry())
    monkeypatch.setattr(obs_metrics, "_ENABLED", False)
    yield
    obs_trace.set_trace_sink(None)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_accumulates_host_and_device_scalars():
    c = obs_metrics.registry().counter("unit/c")
    c.add(3)
    c.add(jnp.asarray(4, jnp.int32))     # device scalar: no sync until read
    c.add(jnp.asarray(5, jnp.int32))
    assert c.value() == 12
    assert isinstance(c.value(), int)


def test_gauge_set_and_read():
    g = obs_metrics.registry().gauge("unit/g")
    g.set(2.5)
    assert g.value() == 2.5
    g.set(jnp.asarray(7.0))
    assert g.value() == 7.0


def test_histogram_percentiles_are_covering_bucket_edges():
    h = obs_metrics.registry().histogram("unit/h", buckets=(1.0, 10.0, 100.0))
    h.observe(5.0)                        # lands in (1, 10]
    assert h.percentile(0.5) == 10.0
    h.observe_many(np.asarray([0.5, 50.0, 50.0, 1e6]))   # last overflows
    snap = h.snapshot_value()
    assert set(snap) == {"count", "mean", "p50", "p99"}
    assert snap["count"] == 5
    assert snap["p50"] == 100.0           # 3rd of 5 → (10, 100]
    assert snap["p99"] == 100.0           # overflow clamps to the top edge


def test_registry_rejects_kind_conflicts():
    reg = obs_metrics.registry()
    reg.counter("unit/x")
    with pytest.raises(TypeError):
        reg.gauge("unit/x")
    with pytest.raises(TypeError):
        reg.histogram("unit/x")


def test_families_flatten_suffix_and_prune():
    reg = obs_metrics.registry()
    assert reg.register_family("fam", lambda: {"a": 1, "b": 2.5}) == "fam"
    assert reg.register_family("fam", lambda: {"a": 9}) == "fam#2"
    reg.register_family("gone", lambda: None)     # dead owner → pruned
    snap = reg.snapshot()
    assert snap["fam/a"] == 1 and snap["fam/b"] == 2.5
    assert snap["fam#2/a"] == 9
    assert not any(k.startswith("gone") for k in snap)


def test_reset_zeroes_metrics_but_keeps_families():
    reg = obs_metrics.registry()
    reg.counter("unit/c").add(5)
    reg.register_family("fam", lambda: {"a": 1})
    reg.reset()
    snap = reg.snapshot()
    assert snap["unit/c"] == 0
    assert snap["fam/a"] == 1             # families survive a reset


def test_export_snapshot_schema_and_extra():
    obs_metrics.registry().counter("unit/c").add(1)
    doc = export_snapshot(extra={"obs/overhead_ratio": 1.01})
    assert doc["schema"] == "bloomrf-metrics/v1"
    assert doc["metrics"]["unit/c"] == 1
    assert doc["metrics"]["obs/overhead_ratio"] == 1.01


# ---------------------------------------------------------------------------
# span tracing
# ---------------------------------------------------------------------------

def test_span_is_null_singleton_when_disabled():
    assert obs_trace.span("unit/op") is obs_trace.NULL_SPAN
    with obs_trace.span("unit/op"):
        pass
    assert "obs/latency/unit/op" not in obs_metrics.registry().snapshot()


def test_span_feeds_latency_histogram_and_jsonl_sink(tmp_path):
    obs_metrics.enable()
    sink = tmp_path / "trace.jsonl"
    obs_trace.set_trace_sink(str(sink))
    with obs_trace.span("unit/op", runs=3):
        pass
    with obs_trace.span("unit/op"):
        pass
    obs_trace.set_trace_sink(None)
    snap = obs_metrics.registry().snapshot()
    assert snap["obs/latency/unit/op"]["count"] == 2
    recs = [json.loads(ln) for ln in sink.read_text().splitlines()]
    assert len(recs) == 2
    assert recs[0]["schema"] == "bloomrf-trace/v1"
    assert recs[0]["span"] == "unit/op"
    assert recs[0]["dur_us"] >= 0.0
    assert recs[0]["attrs"] == {"runs": 3}
    assert "attrs" not in recs[1]


# ---------------------------------------------------------------------------
# FPR telemetry
# ---------------------------------------------------------------------------

def test_fpr_sampler_rejects_bad_domain():
    with pytest.raises(ValueError):
        FprSampler(0)
    with pytest.raises(ValueError):
        FprSampler(65)


def test_fpr_insert_stream_invalidation():
    s = FprSampler(16, n_keys=64, n_ranges=64, range_len=16, seed=1)
    assert s.live_points().size == 64
    s.observe_insert(s.keys[:10])          # kill the first ten candidates
    assert s.live_points().size == 54
    # a key inside a candidate range makes that range non-absent
    s.observe_insert(np.asarray([s.lo[0]], np.uint64))
    lo, _ = s.live_ranges()
    assert s.lo[0] not in lo


def test_fpr_mark_present_replaces_insert_stream_state():
    s = FprSampler(16, n_keys=64, n_ranges=64, seed=2)
    s.observe_insert(s.keys)               # insert stream kills everything
    assert s.live_points().size == 0
    s.mark_present(np.asarray([], np.uint64))   # ground truth: store is empty
    assert s.live_points().size == 64      # replaced, not merged
    s.mark_present(s.keys[:5])
    assert s.live_points().size == 59


def test_fpr_sample_reprobes_surviving_candidates():
    s = FprSampler(16, n_keys=32, n_ranges=32, seed=3)
    out = s.sample(point_probe=lambda k: np.ones(k.size, bool),
                   range_probe=lambda lo, hi: np.zeros(lo.size, bool))
    assert out["point_candidates"] == 32 and out["point_fpr"] == 1.0
    assert out["range_candidates"] == 32 and out["range_fpr"] == 0.0
    s2 = FprSampler(16, n_keys=32, n_ranges=32, seed=3)
    s2.mark_present(s2.keys)               # nothing left to re-probe
    out2 = s2.sample(point_probe=lambda k: np.ones(k.size, bool))
    assert out2["point_candidates"] == 0 and "point_fpr" not in out2


def test_fpr_workload_reservoir_and_histogram():
    obs_metrics.enable()
    s = FprSampler(32, seed=4, reservoir_cap=8)
    lo = np.arange(20, dtype=np.uint64)
    s.observe_ranges(lo, lo + np.uint64(255))   # length 256 → log2 = 8
    assert s.workload_seen == 20
    assert len(s.workload_sample()) == 8        # capped, Algorithm R
    snap = obs_metrics.registry().snapshot()
    h = snap["obs/workload/range_log2"]
    assert h["count"] == 20 and h["p50"] == 8.0


# ---------------------------------------------------------------------------
# zero-overhead contract: obs ON must not change jaxprs
# ---------------------------------------------------------------------------

def _count_prim(jaxpr, name) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):
                n += _count_prim(v.jaxpr, name)
            elif isinstance(v, (list, tuple)):
                n += sum(_count_prim(it.jaxpr, name) for it in v
                         if hasattr(it, "jaxpr"))
    return n


def _stacked_case(rng):
    layouts = [basic_layout(32, 1000, 14.0, delta=6),
               basic_layout(32, 4000, 14.0, delta=4)]
    bases = (0, layouts[0].total_u32)
    flat = jnp.zeros(sum(lay.total_u32 for lay in layouts), jnp.uint32)
    return stacked_probe(tuple(layouts), bases), flat


def test_stacked_probe_one_gather_with_obs_enabled(rng):
    obs_metrics.enable()
    sp, flat = _stacked_case(rng)
    lo = jnp.zeros(64, jnp.uint32)
    hi = jnp.full(64, 9999, jnp.uint32)
    jaxpr = jax.make_jaxpr(sp._range_all)(flat, lo, hi)
    assert _count_prim(jaxpr.jaxpr, "gather") == 1, jaxpr.pretty_print()
    jaxpr_p = jax.make_jaxpr(sp._point_all)(flat, lo)
    assert _count_prim(jaxpr_p.jaxpr, "gather") == 1


def test_jaxpr_text_identical_obs_on_vs_off(rng):
    """jax.named_scope adds NO equations: the traces must be bit-equal."""
    sp, flat = _stacked_case(rng)
    lo = jnp.zeros(64, jnp.uint32)
    hi = jnp.full(64, 9999, jnp.uint32)
    obs_metrics.disable()
    off = str(jax.make_jaxpr(sp._range_all)(flat, lo, hi))
    obs_metrics.enable()
    on = str(jax.make_jaxpr(sp._range_all)(flat, lo, hi))
    assert on == off


def test_store_scan_one_pallas_call_with_obs_enabled(rng):
    obs_metrics.enable()
    st = Store(StoreConfig(d=32, memtable_limit=300, level0_runs=3,
                           scan_backend="kernel"))
    st.register_obs()
    for k in rng.integers(0, (1 << 32) - 1, 1200, dtype=np.uint64):
        st.put(int(k), 0)
    st.flush()
    st._refresh()
    layouts, stack, kmin_d, kmax_d, rpb = st._kernel_inputs()
    lo = jnp.zeros(64, jnp.uint32)
    hi = jnp.full(64, 1 << 20, jnp.uint32)
    jaxpr = jax.make_jaxpr(
        lambda s, a, b: store_scan_probe(layouts, s, kmin_d, kmax_d,
                                         a, b, 256, rpb, True))(stack, lo, hi)
    assert _count_prim(jaxpr.jaxpr, "pallas_call") == 1
    # the dispatch odometer ticks on the host, outside the traced fn
    st.scan_probe_device(lo, hi)
    snap = obs_metrics.registry().snapshot()
    assert snap["store/scan_probe_batches"] == 1


# ---------------------------------------------------------------------------
# StoreStats: registered family + durable round-trip
# ---------------------------------------------------------------------------

def test_store_stats_snapshot_and_reset():
    s = StoreStats()
    s.puts, s.kernel_fallbacks = 7, 2
    assert s.snapshot()["puts"] == 7
    assert s.durable_snapshot() == {
        **{name: 0 for name in StoreStats.DURABLE},
        "puts": 7, "kernel_fallbacks": 2}
    s.reset()
    assert s.puts == 0 and s.kernel_fallbacks == 0


def test_store_register_obs_family(rng):
    obs_metrics.enable()
    st = Store(StoreConfig(d=32, memtable_limit=100))
    st.register_obs()
    for k in range(5):
        st.put(k, k)
    snap = obs_metrics.registry().snapshot()
    assert snap["store/puts"] == 5


def test_durable_stats_survive_snapshot_restore(rng):
    src = Store(StoreConfig(d=32, memtable_limit=50, level0_runs=2,
                            mutability="deletable"))
    for k in rng.integers(0, 1 << 20, 400, dtype=np.uint64):
        src.put(int(k), 1)
    src.delete(int(rng.integers(1 << 20)))
    src.stats.kernel_fallbacks = 3        # process-observed, durable
    src.stats.gets = 99                   # read-path: process-local only
    snap = src.snapshot()
    dst = Store.restore(snap)
    for name in StoreStats.DURABLE:
        assert getattr(dst.stats, name) == getattr(src.stats, name), name
    assert dst.stats.gets == 0            # local counters do NOT travel


def test_restore_rejects_malformed_stats(rng):
    src = Store(StoreConfig(d=32, memtable_limit=50))
    src.put(1, 1)
    good = src.snapshot()
    for bad in ("nope", {"puts": -1}, {"not_a_counter": 1}, {"puts": "x"}):
        snap = dict(good)
        snap["stats"] = bad
        with pytest.raises(ValueError, match="stats"):
            Store.restore(snap)


def test_durable_stats_survive_checkpoint_reopen(tmp_path, rng):
    cfg = StoreConfig(d=32, memtable_limit=60, level0_runs=2,
                      durability="wal", wal_dir=str(tmp_path))
    st = Store(cfg)
    for k in rng.integers(0, 1 << 20, 150, dtype=np.uint64):
        st.put(int(k), 7)
    st.checkpoint()
    st.put(123, 9)                        # lands in the WAL tail
    puts_before = st.stats.puts
    st.close()
    re = Store.open(str(tmp_path))
    # checkpointed history + the replayed tail are both counted
    assert re.stats.puts == puts_before
    assert re.stats.wal_replayed >= 1


# ---------------------------------------------------------------------------
# gates: the committed obs gates evaluate a metrics document end to end
# ---------------------------------------------------------------------------

def _metrics_doc(**over):
    m = {"obs/fpr/observed": 0.02, "obs/fpr/model": 0.05,
         "obs/overhead_ratio": 1.01,
         "obs/latency/facade/scan": {"count": 3, "mean": 5.0,
                                     "p50": 4.0, "p99": 16.0}}
    m.update(over)
    return {"schema": "bloomrf-metrics/v1", "metrics": m}


def test_obs_gates_pass_on_healthy_metrics(tmp_path):
    path = tmp_path / "m.json"
    path.write_text(json.dumps(_metrics_doc()))
    msgs = cg.run_check(cg.load_config(), only={"obs_metrics"},
                        overrides={"obs_metrics": str(path)})
    assert len(msgs) == 3


@pytest.mark.parametrize("over", [
    {"obs/fpr/observed": 0.50},           # >2x model + slack
    {"obs/overhead_ratio": 1.20},         # obs plane entered the dispatch
    {"obs/latency/facade/scan": {"count": 0}},   # spans stopped feeding
])
def test_obs_gates_fail_on_bad_metrics(tmp_path, over):
    path = tmp_path / "m.json"
    path.write_text(json.dumps(_metrics_doc(**over)))
    with pytest.raises(cg.GateError):
        cg.run_check(cg.load_config(), only={"obs_metrics"},
                     overrides={"obs_metrics": str(path)})


def test_unknown_metrics_schema_refused(tmp_path):
    path = tmp_path / "m.json"
    doc = _metrics_doc()
    doc["schema"] = "bloomrf-metrics/v999"
    path.write_text(json.dumps(doc))
    with pytest.raises(cg.InputError):
        cg.run_check(cg.load_config(), only={"obs_metrics"},
                     overrides={"obs_metrics": str(path)})
