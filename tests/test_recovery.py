"""Crash-safety: WAL round-trips, fault-injection recovery, quarantine.

Covers DESIGN.md §14: the append-before-ack WAL contract (zero lost
acknowledged writes across injected crashes at every seam), torn-tail
tolerance through real files, checkpoint/manifest atomicity, crash-atomic
compaction, checksum-quarantined filter blocks degrading to fence-only
pruning bit-identically in the XLA and megakernel probe paths, the
runtime pallas_call dispatch fallback, malformed-snapshot hardening, and
the Supervisor's jittered exponential backoff.
"""
import copy
import dataclasses
import os
import pickle

import numpy as np
import pytest

from repro.store import (FaultPlan, InjectedCrash, Run, Store, StoreConfig,
                         Wal, fault_seed_from_env)
from repro.store.faults import flip_filter_bits, truncate_tail
from repro.store.integrity import read_manifest, write_manifest
from repro.store.wal import WAL_FILENAME

FUZZ_SEED = fault_seed_from_env(default=0xFA17)

# every crash seam the store threads FaultPlan through (kernel.dispatch is
# exercised separately — it must be absorbed, not crash)
CRASH_SEAMS = ["wal.append", "flush.after_run", "compact.before_swap",
               "snapshot.before_rename", "manifest.before_rename"]


def durable_config(wal_dir, **kw):
    kw.setdefault("d", 16)
    kw.setdefault("memtable_limit", 32)
    kw.setdefault("level0_runs", 2)
    return StoreConfig(durability="wal", wal_dir=str(wal_dir), **kw)


# ---------------------------------------------------------------------------
# WAL unit tests (real files in tmp_path)
# ---------------------------------------------------------------------------

def test_wal_roundtrip_real_file(tmp_path):
    path = str(tmp_path / WAL_FILENAME)
    wal = Wal(path).open_for_append()
    wal.append("put", 7, "seven")
    wal.append("del", 7)
    wal.append("delm", [1, 2, 3])
    wal.close()
    # replay through a FRESH handle: everything went through real bytes
    back = Wal(path).records()
    assert back == [("put", 7, "seven"), ("del", 7, None),
                    ("delm", [1, 2, 3], None)]


def test_wal_truncated_tail_tolerated(tmp_path):
    path = str(tmp_path / WAL_FILENAME)
    wal = Wal(path).open_for_append()
    for i in range(20):
        wal.append("put", i, i * 2)
    wal.close()
    rng = np.random.default_rng(1)
    torn = truncate_tail(path, rng, max_bytes=24)
    assert torn > 0
    back = Wal(path).records()
    # the tear kills at most the trailing record(s) it bit into; every
    # record before the tear point replays intact, in order
    assert 0 < len(back) <= 20
    assert back == [("put", i, i * 2) for i in range(len(back))]
    # open_for_append heals the file back to the last intact frame
    wal2 = Wal(path).open_for_append()
    assert wal2.torn_bytes > 0
    wal2.append("put", 99, "after-heal")
    wal2.close()
    assert Wal(path).records()[-1] == ("put", 99, "after-heal")


def test_wal_garbage_tail_ignored(tmp_path):
    path = str(tmp_path / WAL_FILENAME)
    wal = Wal(path).open_for_append()
    wal.append("put", 1, "a")
    wal.close()
    with open(path, "ab") as f:       # a torn in-flight frame
        f.write(b"\xff\xff\xff\xff garbage that is not a frame")
    assert Wal(path).records() == [("put", 1, "a")]


def test_wal_reset_drops_records(tmp_path):
    wal = Wal(str(tmp_path / WAL_FILENAME)).open_for_append()
    wal.append("put", 1, "a")
    wal.reset()
    wal.append("put", 2, "b")
    wal.close()
    assert Wal(wal.path).records() == [("put", 2, "b")]


# ---------------------------------------------------------------------------
# durability: open / replay / checkpoint
# ---------------------------------------------------------------------------

def test_acked_writes_survive_crash_before_flush(tmp_path):
    cfg = durable_config(tmp_path, memtable_limit=1000)
    st = Store(cfg, _warn=False)
    for k in range(50):               # all acked, none flushed
        st.put(k, k * 3)
    st.delete(10)
    assert st.n_runs == 0             # still memtable-only
    st.close()                        # "crash": no flush, no checkpoint
    rec = Store.open(str(tmp_path))
    assert rec.stats.wal_replayed == 51
    assert rec.get(7) == 21 and rec.get(10) is None
    assert rec.get_many(np.arange(50)) == \
        [None if k == 10 else k * 3 for k in range(50)]


def test_checkpoint_then_wal_tail_recovers_both(tmp_path):
    st = Store(durable_config(tmp_path), _warn=False)
    for k in range(100):
        st.put(k, k)
    st.checkpoint()
    st.put(500, "tail")               # post-checkpoint, WAL-only
    st.delete(5)
    st.close()
    rec = Store.open(str(tmp_path))
    assert rec.stats.wal_replayed == 2
    assert rec.get(500) == "tail" and rec.get(5) is None and rec.get(50) == 50


def test_checkpoint_is_idempotent_replay(tmp_path):
    """Crash between manifest rename and WAL reset: replaying records the
    snapshot already holds must change nothing (last-write-wins)."""
    st = Store(durable_config(tmp_path), _warn=False)
    for k in range(80):
        st.put(k, ("v", k))
    faults = FaultPlan(crashes={})    # no crash: build a clean checkpoint
    st.checkpoint()
    # simulate the lost WAL reset: rewrite every pre-checkpoint record
    wal = Wal(os.path.join(str(tmp_path), WAL_FILENAME)).open_for_append()
    for k in range(80):
        wal.append("put", k, ("v", k))
    wal.close()
    rec = Store.open(str(tmp_path))
    assert rec.stats.wal_replayed == 80
    assert rec.get_many(np.arange(80)) == [("v", k) for k in range(80)]
    assert faults.fired == []


@pytest.mark.parametrize("seam", ["snapshot.before_rename",
                                  "manifest.before_rename"])
def test_checkpoint_crash_leaves_recoverable_state(tmp_path, seam):
    st = Store(durable_config(tmp_path), _warn=False,
               faults=FaultPlan(crashes={seam: 1}))
    for k in range(60):
        st.put(k, k + 1)
    with pytest.raises(InjectedCrash):
        st.checkpoint()
    st.close()
    rec = Store.open(str(tmp_path))   # WAL still holds everything acked
    assert rec.get_many(np.arange(60)) == [k + 1 for k in range(60)]
    # and a later checkpoint completes normally
    rec.checkpoint()
    rec.put(1000, "post")
    rec.close()
    rec2 = Store.open(str(tmp_path))
    assert rec2.get(1000) == "post" and rec2.get(0) == 1


def test_fresh_init_refuses_existing_state(tmp_path):
    st = Store(durable_config(tmp_path), _warn=False)
    st.put(1, "a")
    st.close()
    with pytest.raises(ValueError, match="Store.open"):
        Store(durable_config(tmp_path), _warn=False)


def test_corrupt_manifest_is_actionable(tmp_path):
    st = Store(durable_config(tmp_path), _warn=False)
    st.put(1, "a")
    st.checkpoint()
    st.close()
    mpath = os.path.join(str(tmp_path), "MANIFEST.json")
    with open(mpath, "r+b") as f:     # flip a payload byte: CRC must catch
        f.seek(os.path.getsize(mpath) // 2)
        c = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([c[0] ^ 1]))
    with pytest.raises(ValueError, match="manifest"):
        Store.open(str(tmp_path))


def test_manifest_roundtrip_and_crc(tmp_path):
    write_manifest(str(tmp_path), {"snapshot": "s-1.bin", "crc32": 5,
                                   "seq": 1})
    m = read_manifest(str(tmp_path))
    assert m["snapshot"] == "s-1.bin" and m["seq"] == 1
    assert read_manifest(str(tmp_path / "nope")) is None


# ---------------------------------------------------------------------------
# crash-atomic compaction
# ---------------------------------------------------------------------------

def test_compaction_crash_leaves_old_runs_live(tmp_path):
    cfg = durable_config(tmp_path, memtable_limit=16, level0_runs=1)
    st = Store(cfg, _warn=False,
               faults=FaultPlan(crashes={"compact.before_swap": 1}))
    keys = np.arange(0, 64, dtype=np.uint64)
    with pytest.raises(InjectedCrash):
        for k in keys:
            st.put(int(k), int(k))
    # the in-memory object survived the unwound compaction: every source
    # run must still be live and every *acked* key readable
    acked = [int(k) for k in keys if st.get(int(k)) is not None]
    assert len(acked) >= 16           # at least the first flushed batch
    st.close()
    rec = Store.open(str(tmp_path))   # and the real recovery path agrees
    for k in acked:
        assert rec.get(k) == k, k


# ---------------------------------------------------------------------------
# quarantine: degraded scans stay exact and bit-identical across backends
# ---------------------------------------------------------------------------

def _filtered_store(scan_backend="xla", seed=3):
    st = Store(StoreConfig(d=20, memtable_limit=64, level0_runs=2,
                           scan_backend=scan_backend), _warn=False)
    rng = np.random.default_rng(seed)
    for k in rng.choice(1 << 20, 500, replace=False):
        st.put(int(k), int(k) ^ 0xBEEF)
    st.flush()
    return st


def _corrupt_one_filter(snap, rng):
    """Deep-copied snapshot with one run's filter bits flipped."""
    snap2 = copy.deepcopy(snap)
    encs = [e for lvl in snap2["levels"] for e in lvl if "filter" in e]
    assert encs, "fixture produced no filtered runs"
    victim = encs[rng.integers(0, len(encs))]
    bad = flip_filter_bits(victim, rng, nbits=3)
    snap2["levels"] = [[bad if e is victim else e for e in lvl]
                       for lvl in snap2["levels"]]
    return snap2


@pytest.mark.parametrize("backend", ["xla", "kernel"])
def test_quarantined_scan_bit_identical_to_control(backend):
    rng = np.random.default_rng(7)
    base = _filtered_store()
    snap = base.snapshot()
    ctrl = Store.restore(copy.deepcopy(snap))
    hurt = Store.restore(_corrupt_one_filter(snap, rng))
    assert len(hurt.quarantined_runs()) == 1
    assert ctrl.quarantined_runs() == []
    for s in (ctrl, hurt):            # kernel path runs interpret on CPU
        s.cfg = dataclasses.replace(s.cfg, scan_backend=backend)
    los = np.arange(0, 1 << 20, 1 << 12, dtype=np.uint64)
    his = los + (1 << 11)
    f_c, t_c = ctrl._touch_masks(los, his)
    f_h, t_h = hurt._touch_masks(los, his)
    np.testing.assert_array_equal(f_c, f_h)        # fences unaffected
    # the quarantined row may only ADD touches (fence-only pruning),
    # never drop one — that is the no-false-negative direction
    assert (t_h | t_c == t_h).all()
    assert hurt.scan_many(los, his) == ctrl.scan_many(los, his)
    assert hurt.stats.degraded_probes > 0
    assert ctrl.stats.degraded_probes == 0


def test_kernel_and_xla_quarantine_verdicts_match():
    rng = np.random.default_rng(11)
    snap = _filtered_store().snapshot()
    bad = _corrupt_one_filter(snap, rng)
    xla = Store.restore(copy.deepcopy(bad))
    ker = Store.restore(copy.deepcopy(bad))
    xla.cfg = dataclasses.replace(xla.cfg, scan_backend="xla")
    ker.cfg = dataclasses.replace(ker.cfg, scan_backend="kernel")
    los = np.arange(0, 1 << 20, 1 << 13, dtype=np.uint64)
    his = los + (1 << 12)
    f_x, t_x = xla._touch_masks(los, his)
    f_k, t_k = ker._touch_masks(los, his)
    np.testing.assert_array_equal(f_x, f_k)
    np.testing.assert_array_equal(t_x, t_k)


def test_scrub_quarantines_in_memory_bit_flip():
    import jax.numpy as jnp

    st = _filtered_store()
    run = next(r for r in st.live_runs() if r.state is not None)
    run.checksums()                   # build-time reference
    state = np.asarray(run.state).copy()
    state[len(state) // 2] ^= np.uint32(1 << 9)
    run.state = jnp.asarray(state)
    st._dirty = True
    report = st.scrub()
    assert report["newly_quarantined"] == 1
    assert run.quarantined
    assert report["fn_checked"] > 0   # and the no-FN assertion still held


def test_scrub_clean_store_reports_clean(tmp_path):
    st = Store(durable_config(tmp_path), _warn=False)
    for k in range(100):
        st.put(k, k)
    st.flush()
    report = st.scrub()
    assert report["quarantined"] == 0 and report["fn_checked"] > 0


# ---------------------------------------------------------------------------
# runtime kernel fallback
# ---------------------------------------------------------------------------

def test_pallas_dispatch_failure_falls_back_to_xla():
    st = _filtered_store(scan_backend="auto")
    st.faults = FaultPlan(fail_pallas=1)
    st._scan_kernel_mode = lambda: "kernel"       # force dispatch on CPU
    los = np.asarray([0, 1 << 16], np.uint64)
    his = los + (1 << 12)
    ref = _filtered_store().scan_many(los, his)
    assert st.scan_many(los, his) == ref          # batch absorbed via XLA
    assert st.stats.kernel_fallbacks == 1
    assert st.scan_many(los, his) == ref          # plan disarmed: no retry
    assert st.stats.kernel_fallbacks == 1


def test_pallas_dispatch_failure_propagates_when_pinned():
    st = _filtered_store(scan_backend="kernel")
    st.faults = FaultPlan(fail_pallas=1)
    st._scan_kernel_mode = lambda: "kernel"
    with pytest.raises(RuntimeError, match="pallas"):
        st.scan_many([0], [100])
    assert st.stats.kernel_fallbacks == 0


# ---------------------------------------------------------------------------
# snapshot semantics + malformed-input hardening
# ---------------------------------------------------------------------------

def test_snapshot_flushes_memtable_by_default():
    st = Store(StoreConfig(d=16, memtable_limit=1000), _warn=False)
    st.put(1, "unflushed")
    snap = st.snapshot()              # flush_first=True default
    assert Store.restore(snap).get(1) == "unflushed"


def test_snapshot_noflush_warns_without_wal():
    st = Store(StoreConfig(d=16, memtable_limit=1000), _warn=False)
    st.put(1, "unflushed")
    with pytest.warns(RuntimeWarning, match="unflushed"):
        snap = st.snapshot(flush_first=False)
    assert Store.restore(snap).get(1) is None     # documented loss


def test_snapshot_noflush_quiet_with_wal(tmp_path, recwarn):
    st = Store(durable_config(tmp_path, memtable_limit=1000), _warn=False)
    st.put(1, "walled")
    st.snapshot(flush_first=False)    # WAL covers the memtable: no warning
    assert not [w for w in recwarn.list
                if issubclass(w.category, RuntimeWarning)]


def _mutate_snapshot(snap, rng):
    """One random structured mutation; returns (mutated, description)."""
    snap = copy.deepcopy(snap)
    runs = [e for lvl in snap["levels"] for e in lvl]
    choice = int(rng.integers(0, 10))
    if choice == 0:
        snap["schema"] = "bloomrf-store/v99"
        return snap, "bad store schema"
    if choice == 1:
        snap["levels"] = {"not": "a list"}
        return snap, "levels not a list"
    if choice == 2:
        snap["config"] = {"filter_backend": "quantum"}
        return snap, "unknown backend"
    if choice == 3:
        snap["config"] = ["d", 16]
        return snap, "config not a dict"
    if not runs:
        snap["schema"] = None
        return snap, "no runs: bad schema"
    run = runs[rng.integers(0, len(runs))]
    if choice == 4:
        run["n"] = int(run["n"]) + 1
        return snap, "n mismatch"
    if choice == 5:
        ef = dict(run["keys"])
        plane = "low" if np.size(ef.get("low")) else "high"
        arr = np.array(ef[plane], np.uint8, copy=True)
        arr[rng.integers(0, arr.size)] ^= np.uint8(1 << rng.integers(0, 8))
        ef[plane] = arr
        run["keys"] = ef
        return snap, "key posting-list bit flip"
    if choice == 6 and run["vals"]:
        i = int(rng.integers(0, len(run["vals"])))
        run["vals"] = list(run["vals"])
        run["vals"][i] = "CORRUPTED"
        return snap, "value swapped"
    if choice == 7:
        t = np.array(run["tombs"], np.uint8, copy=True)
        if t.size:
            t[rng.integers(0, t.size)] ^= np.uint8(1 << rng.integers(0, 8))
            run["tombs"] = t
            return snap, "tombstone mask bit flip"
    if choice == 8:
        run["layout"] = {"bogus": True}
        return snap, "bad layout"
    if choice == 9 and "filter" in run:
        flipped = flip_filter_bits(run, rng)
        snap["levels"] = [[flipped if e is run else e for e in lvl]
                         for lvl in snap["levels"]]
        return snap, "filter bit flip (quarantine, not error)"
    run["schema"] = "bloomrf-run/v99"
    return snap, "bad run schema"


def test_mutated_snapshots_never_silently_misrestore():
    """Property test: every random snapshot mutation either raises an
    actionable ValueError or restores to a store whose read results are
    identical to the uncorrupted control (quarantine path)."""
    base = _filtered_store(seed=5)
    for k in range(0, 1 << 20, 1 << 13):
        base.delete(k)                # mix tombstones into the state
    snap = base.snapshot()
    ctrl = Store.restore(copy.deepcopy(snap))
    qs = np.asarray(sorted({int(r.keys[i]) for r in ctrl.live_runs()
                            for i in range(0, len(r.keys), 7)}), np.uint64)
    los = np.arange(0, 1 << 20, 1 << 14, dtype=np.uint64)
    his = los + (1 << 12)
    ctrl_gets = ctrl.get_many(qs)
    ctrl_scans = ctrl.scan_many(los, his)
    rng = np.random.default_rng(FUZZ_SEED)
    outcomes = {"raised": 0, "degraded": 0}
    for _ in range(60):
        mut, what = _mutate_snapshot(snap, rng)
        try:
            st = Store.restore(mut)
        except ValueError:
            outcomes["raised"] += 1
            continue
        # restored without error: results must match the control exactly
        # (only filter-block corruption may land here, as quarantine)
        assert st.get_many(qs) == ctrl_gets, what
        assert st.scan_many(los, his) == ctrl_scans, what
        outcomes["degraded"] += 1
    assert outcomes["raised"] > 0 and outcomes["degraded"] > 0, outcomes


def test_restore_rejects_non_dict_inputs():
    for junk in (None, 42, [], "snapshot", {"schema": "bloomrf-store/v3"}):
        with pytest.raises(ValueError):
            Store.restore(junk)
    with pytest.raises(ValueError):
        Run.unpack({"schema": "bloomrf-run/v3"})
    with pytest.raises(ValueError):
        Run.unpack([1, 2, 3])


# ---------------------------------------------------------------------------
# crash-recovery fuzz: interleave ops, crash, reopen, verify
# ---------------------------------------------------------------------------

def _fuzz_round(tmpdir, seed, n_ops, seam, countdown):
    """One armed fuzz run: returns True if the seam actually fired."""
    rng = np.random.default_rng(seed)
    cfg = durable_config(tmpdir, d=16, memtable_limit=24, level0_runs=2)
    plan = FaultPlan(seed=seed, crashes={seam: countdown})
    store = Store.open(str(tmpdir), cfg, faults=plan)
    model = {int(k): v for k, v in zip(
        *np.unique(np.asarray([], np.uint64), return_index=True))}
    # rebuild the model by replaying what the durable dir already holds
    model = {}
    crashed = False
    inflight = None                   # (kind, keys) of the op that crashed
    for _ in range(n_ops):
        kind = rng.choice(["put", "put", "put", "del", "delm", "ckpt"])
        try:
            if kind == "put":
                k, v = int(rng.integers(0, 1 << 16)), int(rng.integers(1e9))
                inflight = ("put", {k: v})
                store.put(k, v)
                model[k] = v
            elif kind == "del":
                k = int(rng.integers(0, 1 << 16))
                inflight = ("del", {k: None})
                store.delete(k)
                model.pop(k, None)
            elif kind == "delm":
                ks = [int(x) for x in rng.integers(0, 1 << 16, 5)]
                inflight = ("delm", {k: None for k in ks})
                store.delete_many(ks)
                for k in ks:
                    model.pop(k, None)
            else:
                inflight = ("ckpt", {})
                store.checkpoint()
            inflight = None
        except InjectedCrash:
            crashed = True
            break
    store.close()
    # a real process death may also tear the record being framed at crash
    # time: append garbage that replay must ignore
    wal_path = os.path.join(str(tmpdir), WAL_FILENAME)
    if crashed and os.path.exists(wal_path) and rng.random() < 0.5:
        with open(wal_path, "ab") as f:
            f.write(b"\x40\x00\x00\x00torn-in-flight-frame")
    rec = Store.open(str(tmpdir))
    # zero lost acked writes; the crashed op itself may be in either state
    allowed_either = inflight[1] if (crashed and inflight) else {}
    for k, v in model.items():
        got = rec.get(k)
        if k in allowed_either:
            assert got in (v, allowed_either[k]), (seam, k)
        else:
            assert got == v, (seam, k, got, v)
    # zero false negatives: every live model key must be readable AND a
    # scan over its neighbourhood must return it
    live = sorted(k for k in model if k not in allowed_either
                  and model[k] is not None)
    if live:
        pick = live[:: max(1, len(live) // 32)]
        lo = np.asarray(pick, np.uint64)
        scans = rec.scan_many(lo, lo)
        for k, rows in zip(pick, scans):
            assert rows == [(k, model[k])], (seam, k)
    rec.scrub(sample_keys=16)
    rec.close()
    return crashed


@pytest.mark.parametrize("seam", CRASH_SEAMS)
def test_crash_recovery_fuzz_smoke(tmp_path, seam):
    fired = False
    for countdown in (1, 3, 9):
        sub = tmp_path / f"{seam.replace('.', '_')}-{countdown}"
        sub.mkdir()
        fired |= _fuzz_round(sub, FUZZ_SEED + countdown, 400, seam,
                             countdown)
    assert fired, f"seam {seam} never fired — dead injection point"


@pytest.mark.slow
def test_crash_recovery_fuzz_slow(tmp_path):
    """The 1e5-op soak: repeated crash/reopen cycles against one durable
    directory, cycling through every seam."""
    rng = np.random.default_rng(FUZZ_SEED)
    cfg = durable_config(tmp_path, d=16, memtable_limit=64, level0_runs=2)
    model, ops_done, crashes = {}, 0, 0
    seam_i = 0
    store = Store.open(str(tmp_path), cfg)
    while ops_done < 100_000:
        if store.faults is None or not any(
                store.faults.armed(s) for s in CRASH_SEAMS):
            seam = CRASH_SEAMS[seam_i % len(CRASH_SEAMS)]
            seam_i += 1
            store.faults = FaultPlan(seed=int(rng.integers(1 << 30)),
                                     crashes={seam: int(rng.integers(1, 40))})
        kind = rng.choice(["put", "put", "put", "del", "ckpt"],
                          p=[0.3, 0.3, 0.3, 0.09, 0.01])
        inflight = None
        try:
            if kind == "put":
                k, v = int(rng.integers(0, 1 << 16)), ops_done
                inflight = (k, v)
                store.put(k, v)
                model[k] = v
            elif kind == "del":
                k = int(rng.integers(0, 1 << 16))
                inflight = (k, None)
                store.delete(k)
                model.pop(k, None)
            else:
                store.checkpoint()
        except InjectedCrash:
            crashes += 1
            store.close()
            store = Store.open(str(tmp_path))
            if inflight is not None:
                k, v = inflight
                got = store.get(k)
                assert got in (v, model.get(k)), (k, got)
                # pin the model to whatever the store durably decided
                if got is None:
                    model.pop(k, None)
                else:
                    model[k] = got
        ops_done += 1
    assert crashes >= 10, crashes
    store.close()
    rec = Store.open(str(tmp_path))
    keys = np.asarray(sorted(model), np.uint64)
    got = rec.get_many(keys)
    assert got == [model[int(k)] for k in keys]
    rec.scrub()


# ---------------------------------------------------------------------------
# serve: cold tier reopens through recovery
# ---------------------------------------------------------------------------

def test_prefix_cache_cold_tier_recovers(tmp_path):
    from repro.serve.prefix_cache import PrefixCacheIndex, pack_key

    cfg = StoreConfig(d=32, memtable_limit=64, durability="wal",
                      wal_dir=str(tmp_path))
    idx = PrefixCacheIndex(n_tenants=4,
                           backing_store=Store(cfg, _warn=False))
    idx.freeze_segment({pack_key(s, c): [s * 100 + c]
                        for s in range(8) for c in range(4)})
    idx.evict_window(6, 7)            # tombstones must survive recovery too
    idx.store.close()                 # crash before any checkpoint

    idx2 = PrefixCacheIndex(n_tenants=4)
    store = idx2.reopen_cold_tier(str(tmp_path))
    assert store.stats.wal_replayed > 0
    # no segments in the fresh index: lookups fall through to the cold tier
    assert idx2.lookup(3, 2) == [302]
    assert idx2.lookup(6, 1) is None  # evicted stays evicted
    assert idx2.stats["store_hits"] == 1


# ---------------------------------------------------------------------------
# Supervisor backoff (reusing the fault harness for injected failures)
# ---------------------------------------------------------------------------

class _FlakyTrainer:
    """Trainer stub whose run() crashes through a FaultPlan seam."""

    straggler_events: list = []
    start_step = 0

    def __init__(self, plan):
        self.plan = plan

    def run(self):
        self.plan.hit("trainer.step")
        return {"ok": True}


def test_supervisor_backoff_schedule_and_budget():
    from repro.train.fault_tolerance import Supervisor

    sleeps = []
    plan = FaultPlan(seed=1, crashes={"trainer.step": 1})

    def factory():
        # re-arm every attempt: the trainer never recovers
        plan._remaining["trainer.step"] = 1
        return _FlakyTrainer(plan)

    sup = Supervisor(factory, max_restarts=3, backoff_base=1.0,
                     backoff_cap=4.0, jitter=0.5, seed=7,
                     sleep=sleeps.append)
    with pytest.raises(RuntimeError, match="exceeded 3 restarts"):
        sup.run()
    assert len(sup.incidents) == 4    # budget + the final fatal attempt
    assert len(sleeps) == 3           # no sleep after the fatal one
    bases = [1.0, 2.0, 4.0]           # doubling, capped at 4.0
    for s, b in zip(sleeps, bases):
        assert b <= s <= b * 1.5, (s, b)
    assert [i["backoff_s"] for i in sup.incidents][:3] == sleeps


def test_supervisor_successful_recovery_resets_budget():
    from repro.train.fault_tolerance import Supervisor

    sleeps = []
    attempts = []

    def factory():
        # arm a fresh one-shot crash for the first two attempts only
        attempts.append(1)
        crashes = {"trainer.step": 1} if len(attempts) <= 2 else {}
        return _FlakyTrainer(FaultPlan(seed=2, crashes=crashes))

    sup = Supervisor(factory, max_restarts=2,
                     backoff_base=0.25, jitter=0.0, seed=0,
                     sleep=sleeps.append)
    out = sup.run()                   # crashes twice, then succeeds
    assert out["metrics"] == {"ok": True} and out["restarts"] == 2
    assert sleeps == [0.25, 0.5]
    # a fresh run() starts with a full budget (consecutive-failure reset):
    # one more crash would blow a carried-over budget of 2, but passes here
    plan2 = FaultPlan(seed=3, crashes={"trainer.step": 1})
    sup.factory = lambda: _FlakyTrainer(plan2)
    out2 = sup.run()
    assert out2["metrics"] == {"ok": True} and out2["restarts"] == 1


def test_supervisor_rejects_bad_backoff():
    from repro.train.fault_tolerance import Supervisor

    with pytest.raises(ValueError):
        Supervisor(lambda: None, jitter=2.0)


# ---------------------------------------------------------------------------
# snapshot files are real bytes (pickle) end to end
# ---------------------------------------------------------------------------

def test_snapshot_file_crc_detects_rot(tmp_path):
    st = Store(durable_config(tmp_path), _warn=False)
    for k in range(200):
        st.put(k, k)
    path = st.checkpoint()
    st.close()
    with open(path, "r+b") as f:      # rot one byte mid-file
        f.seek(os.path.getsize(path) // 3)
        c = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([c[0] ^ 0x10]))
    with pytest.raises(ValueError, match="CRC"):
        Store.open(str(tmp_path))


def test_run_pack_v3_carries_checksums():
    st = _filtered_store()
    run = st.live_runs()[0]
    enc = run.pack()
    assert enc["schema"] == "bloomrf-run/v3"
    assert set(enc["crc"]) >= {"keys", "fences", "vals", "tombs"}
    if run.state is not None:
        assert "filter" in enc["crc"]
    back = Run.unpack(pickle.loads(pickle.dumps(enc)))
    np.testing.assert_array_equal(back.keys, run.keys)
    assert not back.quarantined
