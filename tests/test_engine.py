"""Plan->gather->combine engine (core/engine.py) vs the pre-refactor
reference path: bit-identity on randomized layouts, the single-gather jaxpr
invariant, the deduped word-access model, and the lane-packed scatter.

The reference is ``BloomRF.point_reference`` / ``range_reference`` (per-key
scalar probes under vmap — the exact pre-engine implementation), so these
are cross-implementation checks, not self-comparisons.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BloomRF, FilterLayout, basic_layout


def _count_gathers(jaxpr) -> int:
    """Gather ops in a jaxpr, recursing into sub-jaxprs (pjit/while/...)."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "gather":
            n += 1
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):
                n += _count_gathers(v.jaxpr)
            elif isinstance(v, (list, tuple)):
                n += sum(_count_gathers(it.jaxpr) for it in v
                         if hasattr(it, "jaxpr"))
    return n


def _random_layout(rng, allow_exact=False):
    """Random layout: d <= 32, 2 hashed segments, replicas, Δ in 1..7."""
    d = int(rng.integers(16, 33))
    deltas, rem = [], d
    for _ in range(int(rng.integers(2, 5))):
        if rem < 1:
            break
        deltas.append(int(min(rng.integers(1, 8), rem)))
        rem -= deltas[-1]
    k = len(deltas)
    exact_seg = None
    seg_bits = (8192, 4096)
    seg_of_layer = tuple(int(s) for s in rng.integers(0, 2, k))
    if allow_exact and d - sum(deltas) >= 4 and rng.integers(2):
        exact_seg = 2
        seg_bits = (8192, 4096, 1 << (d - sum(deltas)))
    return FilterLayout(
        d=d, deltas=tuple(deltas),
        replicas=tuple(int(r) for r in rng.integers(1, 3, k)),
        seg_of_layer=seg_of_layer, seg_bits=seg_bits, exact_seg=exact_seg,
        seed=int(rng.integers(1 << 30)))


def _compare(lay, trng, n_keys=1500, n_q=20_000):
    f = BloomRF(lay)
    hi_excl = 1 << lay.d if lay.d < 64 else (1 << 63)
    keys = trng.integers(0, hi_excl, n_keys, dtype=np.uint64)
    state = f.build(jnp.asarray(keys, f.kdtype))
    lo = trng.integers(0, hi_excl, n_q, dtype=np.uint64)
    span = trng.integers(0, 1 << min(lay.d - 1, 14), n_q, dtype=np.uint64)
    hi = np.minimum(lo + span, hi_excl - 1)
    want = np.asarray(f.range_reference(state, jnp.asarray(lo, f.kdtype),
                                        jnp.asarray(hi, f.kdtype)))
    got = np.asarray(f.range(state, jnp.asarray(lo, f.kdtype),
                             jnp.asarray(hi, f.kdtype)))
    np.testing.assert_array_equal(want, got, err_msg=lay.describe())
    qs = trng.integers(0, hi_excl, n_q // 2, dtype=np.uint64)
    wp = np.asarray(f.point_reference(state, jnp.asarray(qs, f.kdtype)))
    gp = np.asarray(f.point(state, jnp.asarray(qs, f.kdtype)))
    np.testing.assert_array_equal(wp, gp, err_msg=lay.describe())


# ---------------------------------------------------------------------------
# bit-identity: engine vs pre-refactor reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("delta", [1, 2, 3, 4, 5, 6])
def test_engine_bit_identical_delta_sweep(delta):
    trng = np.random.default_rng(0xE0 + delta)
    _compare(basic_layout(24, 800, 14.0, delta=delta), trng, 800, 8000)


def test_engine_bit_identical_100k_queries():
    """Acceptance: >= 1e5 randomized queries, engine == _range_one."""
    trng = np.random.default_rng(0xE17)
    _compare(basic_layout(32, 2000, 14.0, delta=6), trng, 2000, 100_000)


def test_engine_bit_identical_100k_queries_w64_replicas():
    """Δ=7 (two-lane words) + replicas, >= 1e5 queries."""
    trng = np.random.default_rng(0xE18)
    lay = FilterLayout(d=32, deltas=(7, 7), replicas=(1, 2),
                       seg_of_layer=(0, 0), seg_bits=(16384,))
    _compare(lay, trng, 2000, 100_000)


def test_engine_bit_identical_100k_queries_exact_layout():
    """Exact-bitmap layout (fused exact covering bits + dynamic mid scan)."""
    trng = np.random.default_rng(0xE19)
    lay = FilterLayout(d=32, deltas=(7, 7, 4, 2), replicas=(1, 1, 1, 2),
                       seg_of_layer=(2, 2, 1, 1),
                       seg_bits=(1 << 12, 4096, 8192), exact_seg=0)
    _compare(lay, trng, 1000, 100_000)


@pytest.mark.parametrize("trial", range(8))
def test_engine_random_layouts_property(trial):
    """Randomized layouts: Δ in 1..7, replicas > 1, multi-segment, exact."""
    trng = np.random.default_rng(0xEA5E + trial)
    _compare(_random_layout(trng, allow_exact=True), trng)


def test_engine_64bit_domain():
    trng = np.random.default_rng(0xE64)
    _compare(basic_layout(64, 2000, 16.0, delta=7), trng, 2000, 20_000)


# ---------------------------------------------------------------------------
# plan accounting: the deduped access model and the gather width
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make", [
    lambda: basic_layout(32, 2000, 14.0, delta=6),
    lambda: basic_layout(64, 2000, 16.0, delta=7),
    lambda: FilterLayout(d=32, deltas=(7, 7), replicas=(1, 2),
                         seg_of_layer=(0, 0), seg_bits=(16384,)),
    lambda: FilterLayout(d=32, deltas=(7, 7, 4, 2), replicas=(1, 1, 1, 2),
                         seg_of_layer=(2, 2, 1, 1),
                         seg_bits=(1 << 12, 4096, 8192), exact_seg=0),
])
def test_gather_width_matches_access_model(make):
    lay = make()
    f = BloomRF(lay)
    eng = f.engine
    # the static model counts the engine's planned word loads, plus one
    # amortized lane for the exact middle scan (not a planned gather)
    scan = 1 if (lay.has_exact and lay.top_level < lay.d) else 0
    assert f.word_accesses_per_range_query() == eng.range_word_loads + scan
    # planned loads == 4 per layer per replica (covering bits deduped away)
    hashed = sum(4 * lay.replicas[i] for i in range(lay.k))
    exact = 2 if (lay.has_exact and lay.top_level < lay.d) else 0
    assert eng.range_word_loads == hashed + exact
    # the actual plan's gather width A == the static accounting
    lo = jnp.zeros(7, f.kdtype)
    hi = jnp.full(7, (1 << min(lay.d, 63)) - 1, f.kdtype)
    plan = eng.plan_range(lo, hi)
    assert plan.lanes.shape == (7, eng.range_gather_width)
    # lanes-vs-words: W=64 words take two lanes each, everything else one
    lanes = sum(4 * lay.replicas[i] * (2 if lay.word_bits(i) == 64 else 1)
                for i in range(lay.k)) + exact
    assert eng.range_gather_width == lanes


def test_point_word_accesses_unchanged():
    lay = basic_layout(64, 10_000, 16.0, delta=7)
    f = BloomRF(lay)
    assert f.word_accesses_per_point_query() == lay.k
    qs = jnp.zeros(3, f.kdtype)
    assert f.engine.plan_point(qs).lanes.shape == (3, lay.k)


# ---------------------------------------------------------------------------
# the single fused gather (jaxpr inspection)
# ---------------------------------------------------------------------------

def test_range_probe_single_gather_jaxpr():
    """The batched range probe must contain exactly ONE gather over the
    filter state per probe tile (hashed-only layouts)."""
    lay = basic_layout(32, 2000, 14.0, delta=6)
    f = BloomRF(lay)
    state = f.init_state()
    lo = jnp.zeros(512, jnp.uint32)
    hi = jnp.ones(512, jnp.uint32)
    jaxpr = jax.make_jaxpr(f.range)(state, lo, hi)
    assert _count_gathers(jaxpr.jaxpr) == 1, jaxpr.pretty_print()
    jaxpr_p = jax.make_jaxpr(f.point)(state, lo)
    assert _count_gathers(jaxpr_p.jaxpr) == 1
    # the reference path is the many-gather graph the engine replaced
    jaxpr_ref = jax.make_jaxpr(f.range_reference)(state, lo, hi)
    assert _count_gathers(jaxpr_ref.jaxpr) > 1


def test_multisegment_replicas_single_gather_jaxpr():
    lay = FilterLayout(d=32, deltas=(6, 5, 4), replicas=(2, 1, 2),
                       seg_of_layer=(0, 1, 0), seg_bits=(8192, 4096))
    f = BloomRF(lay)
    jaxpr = jax.make_jaxpr(f.range)(f.init_state(),
                                    jnp.zeros(64, jnp.uint32),
                                    jnp.ones(64, jnp.uint32))
    assert _count_gathers(jaxpr.jaxpr) == 1


# ---------------------------------------------------------------------------
# partitioned range kernel parity (resident vs partitioned vs XLA)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_u32", [256, 2048])
def test_range_probe_partitioned_parity(rng, block_u32):
    from repro.kernels import (FilterOps, range_probe_partitioned,
                               range_probe_resident)
    from repro.kernels import ref as kref

    lay = basic_layout(32, 5000, 14.0, delta=6)
    f = BloomRF(lay)
    keys = rng.integers(0, 1 << 32, 5000, dtype=np.uint64).astype(np.uint32)
    state = f.build(jnp.asarray(keys))
    lo = rng.integers(0, 1 << 32, 900, dtype=np.uint64).astype(np.uint32)
    hi = np.maximum(lo, lo + rng.integers(0, 1 << 12, 900).astype(np.uint32))
    want = np.asarray(kref.range_ref(lay, state, jnp.asarray(lo),
                                     jnp.asarray(hi)))
    part = np.asarray(range_probe_partitioned(
        lay, state, jnp.asarray(lo), jnp.asarray(hi), 128, block_u32, True))
    np.testing.assert_array_equal(want, part)
    res = np.asarray(range_probe_resident(
        lay, state, jnp.asarray(lo), jnp.asarray(hi), 256, True))
    np.testing.assert_array_equal(part, res)
    # dispatcher: forced-HBM ops must take the partitioned path and agree
    ops = FilterOps(lay, interpret=True, vmem_budget_u32=1)
    assert not ops.resident
    via_ops = np.asarray(ops.range(state, jnp.asarray(lo), jnp.asarray(hi)))
    np.testing.assert_array_equal(want, via_ops)
    # no false negatives through the kernel: straddling ranges all positive
    slo = np.maximum(keys.astype(np.int64) - 2, 0).astype(np.uint32)
    shi = np.minimum(keys.astype(np.int64) + 2, (1 << 32) - 1).astype(np.uint32)
    assert np.asarray(range_probe_partitioned(
        lay, state, jnp.asarray(slo), jnp.asarray(shi), 128, block_u32,
        True)).all()


def test_range_probe_partitioned_rejects_exact():
    from repro.core.tuning import advise
    from repro.kernels import range_probe_partitioned

    lay = advise(16, 300, 16384, 64.0).layout
    assert lay.has_exact
    f = BloomRF(lay)
    state = f.build(jnp.asarray(np.arange(300, dtype=np.uint32)))
    lo = jnp.asarray(np.arange(10, dtype=np.uint32))
    with pytest.raises(ValueError, match="exact-layer"):
        range_probe_partitioned(lay, state, lo, lo, 128, 256, True)


# ---------------------------------------------------------------------------
# lane-packed scatter_or (the O(total_bits) transient is gone)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_scatter_or_matches_bitmap_path(seed):
    trng = np.random.default_rng(0x5CA7 + seed)
    lay = basic_layout(32, 3000, 14.0, delta=6)
    f = BloomRF(lay)
    keys = jnp.asarray(trng.integers(0, 1 << 32, 3000, dtype=np.uint64),
                       f.kdtype)
    pos = jax.vmap(f._positions_one)(keys).reshape(-1)
    packed = f.scatter_or(f.init_state(), pos)
    bitmap = f.scatter_or(f.init_state(), pos, bitmap=True)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(bitmap))
    # masked variant (the sharded banks' ownership masks)
    vals = jnp.asarray(trng.integers(0, 2, pos.shape[0]).astype(bool))
    packed = f.scatter_or(f.init_state(), pos, vals)
    bitmap = f.scatter_or(f.init_state(), pos, vals, bitmap=True)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(bitmap))
    # heavy duplicates (bulk insert of one repeated key)
    dup = jnp.tile(pos[:7], 400)
    packed = f.scatter_or(f.init_state(), dup)
    bitmap = f.scatter_or(f.init_state(), dup, bitmap=True)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(bitmap))


def test_insert_has_no_total_bits_transient():
    """The bulk-insert jaxpr must not materialise an O(total_bits) bool
    temp; peak intermediate size stays O(keys * probes + total_u32)."""
    lay = basic_layout(32, 2_000_000, 16.0, delta=6)
    f = BloomRF(lay)
    keys = jnp.zeros(1024, jnp.uint32)

    def big_bool_consts(jaxpr, floor):
        out = []
        for eqn in jaxpr.eqns:
            for var in eqn.outvars:
                sz = getattr(var.aval, "size", 0)
                if var.aval.dtype == jnp.bool_ and sz >= floor:
                    out.append((eqn.primitive.name, sz))
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):
                    out += big_bool_consts(v.jaxpr, floor)
        return out

    jaxpr = jax.make_jaxpr(f.insert)(f.init_state(), keys)
    assert lay.total_bits >= 32_000_000  # the old path's transient size
    offenders = big_bool_consts(jaxpr.jaxpr, lay.total_bits)
    assert not offenders, offenders


# ---------------------------------------------------------------------------
# multi-filter stacked plan (StackedProbe): R rows, one gather
# ---------------------------------------------------------------------------

def _stack_case(rng, layouts):
    from repro.core import stacked_probe
    filts = [BloomRF(lay) for lay in layouts]
    rows = [f.build(jnp.asarray(
        rng.integers(0, 1 << f.layout.d, 1500, dtype=np.uint64), f.kdtype))
        for f in filts]
    flat = jnp.concatenate(rows)
    bases = tuple(int(b) for b in np.cumsum(
        [0] + [lay.total_u32 for lay in layouts[:-1]]))
    return stacked_probe(tuple(layouts), bases), filts, rows, flat


def test_stacked_probe_bit_identical_mixed_layouts(rng):
    layouts = [basic_layout(32, 1500, 14.0, delta=6),
               basic_layout(32, 1500, 14.0, delta=6),
               basic_layout(32, 6000, 14.0, delta=5),
               basic_layout(32, 24000, 12.0, delta=7)]
    sp, filts, rows, flat = _stack_case(rng, layouts)
    assert len(sp.spans) == 3            # two same-layout rows share a span
    lo = rng.integers(0, 1 << 32, 20000, dtype=np.uint64).astype(np.uint32)
    hi = np.minimum(lo.astype(np.uint64) + (1 << 12),
                    (1 << 32) - 1).astype(np.uint32)
    got = np.asarray(sp.range_all(flat, jnp.asarray(lo), jnp.asarray(hi)))
    for j, (f, row) in enumerate(zip(filts, rows)):
        want = np.asarray(f.range(row, jnp.asarray(lo), jnp.asarray(hi)))
        np.testing.assert_array_equal(got[:, j], want, err_msg=f"row {j}")
    qs = jnp.asarray(rng.integers(0, 1 << 32, 20000,
                                  dtype=np.uint64).astype(np.uint32))
    gp = np.asarray(sp.point_all(flat, qs))
    for j, (f, row) in enumerate(zip(filts, rows)):
        np.testing.assert_array_equal(gp[:, j], np.asarray(f.point(row, qs)))


def test_stacked_probe_per_row_bounds(rng):
    layouts = [basic_layout(32, 2000, 14.0, delta=6)] * 3
    sp, filts, rows, flat = _stack_case(rng, layouts)
    lo = rng.integers(0, 1 << 32, (4000, 3), dtype=np.uint64).astype(np.uint32)
    hi = np.minimum(lo.astype(np.uint64) + 2000,
                    (1 << 32) - 1).astype(np.uint32)
    got = np.asarray(sp.range_all(flat, jnp.asarray(lo), jnp.asarray(hi)))
    for j, (f, row) in enumerate(zip(filts, rows)):
        want = np.asarray(f.range(row, jnp.asarray(lo[:, j]),
                                  jnp.asarray(hi[:, j])))
        np.testing.assert_array_equal(got[:, j], want)


def test_stacked_probe_single_gather_jaxpr(rng):
    layouts = [basic_layout(32, 1000, 14.0, delta=6),
               basic_layout(32, 4000, 14.0, delta=4),
               basic_layout(32, 1000, 14.0, delta=6)]
    sp, _, _, flat = _stack_case(rng, layouts)
    lo = jnp.zeros(256, jnp.uint32)
    hi = jnp.full(256, 9999, jnp.uint32)
    jaxpr = jax.make_jaxpr(sp._range_all)(flat, lo, hi)
    assert _count_gathers(jaxpr.jaxpr) == 1, jaxpr.pretty_print()
    jaxpr_p = jax.make_jaxpr(sp._point_all)(flat, lo)
    assert _count_gathers(jaxpr_p.jaxpr) == 1
    # per-row bounds keep the invariant
    lo2 = jnp.zeros((256, 3), jnp.uint32)
    hi2 = jnp.full((256, 3), 9999, jnp.uint32)
    jaxpr2 = jax.make_jaxpr(sp._range_all)(flat, lo2, hi2)
    assert _count_gathers(jaxpr2.jaxpr) == 1


def test_stacked_probe_validation():
    from repro.core import StackedProbe, stacked_probe
    lay = basic_layout(32, 1000, 14.0, delta=6)
    with pytest.raises(ValueError, match="at least one"):
        StackedProbe((), ())
    with pytest.raises(ValueError, match="row bases"):
        stacked_probe((lay, lay), (0,))
    exact = FilterLayout(d=16, deltas=(7, 4), replicas=(1, 1),
                         seg_of_layer=(1, 1), seg_bits=(1 << 5, 8192),
                         exact_seg=0)
    with pytest.raises(ValueError, match="exact-bitmap"):
        stacked_probe((exact,), (0,))
    sp = stacked_probe((lay,), (0,))
    with pytest.raises(ValueError, match="bounds"):
        sp._range_all(jnp.zeros(lay.total_u32, jnp.uint32),
                      jnp.zeros((4, 7), jnp.uint32),
                      jnp.zeros((4, 7), jnp.uint32))


def test_filter_ops_stacked_dispatch_parity(rng):
    from repro.kernels import FilterOps
    lay = basic_layout(32, 2000, 14.0, delta=6)
    f = BloomRF(lay)
    rows = [f.build(jnp.asarray(
        rng.integers(0, 1 << 32, 2000, dtype=np.uint64).astype(np.uint32)))
        for _ in range(5)]
    stack = jnp.stack(rows)
    lo = rng.integers(0, 1 << 32, 600, dtype=np.uint64).astype(np.uint32)
    hi = np.maximum(lo, lo + (1 << 11)).astype(np.uint32)
    qs = jnp.asarray(rng.integers(0, 1 << 32, 600,
                                  dtype=np.uint64).astype(np.uint32))
    want_r = np.stack([np.asarray(f.range(r, jnp.asarray(lo),
                                          jnp.asarray(hi))) for r in rows],
                      axis=1)
    want_p = np.stack([np.asarray(f.point(r, qs)) for r in rows], axis=1)
    # resident Pallas kernel path vs forced XLA stacked path
    for budget in (None, 1):
        ops = FilterOps(lay, interpret=True, vmem_budget_u32=budget)
        np.testing.assert_array_equal(
            np.asarray(ops.range_stacked(stack, jnp.asarray(lo),
                                         jnp.asarray(hi))), want_r)
        np.testing.assert_array_equal(np.asarray(ops.point_stacked(stack, qs)),
                                      want_p)


def test_vmem_budget_knob():
    from repro.kernels import DEFAULT_VMEM_BUDGET_U32, FilterOps
    lay = basic_layout(32, 2000, 14.0, delta=6)
    assert FilterOps(lay).vmem_budget_u32 == DEFAULT_VMEM_BUDGET_U32
    assert FilterOps(lay).resident
    forced = FilterOps(lay, vmem_budget_u32=lay.total_u32 - 1)
    assert not forced.resident           # threshold is a real dispatch knob
    assert FilterOps(lay, vmem_budget_u32=lay.total_u32).resident


def test_insert_online_and_build_np_still_agree(rng):
    lay = basic_layout(32, 500, bits_per_key=12.0, delta=6)
    f = BloomRF(lay)
    keys = rng.integers(0, (1 << 32) - 1, 500, dtype=np.uint64)
    bulk = f.build(jnp.asarray(keys, f.kdtype))
    online = f.insert_online(f.init_state(), jnp.asarray(keys, f.kdtype))
    np.testing.assert_array_equal(np.asarray(bulk), np.asarray(online))
    np.testing.assert_array_equal(np.asarray(bulk),
                                  np.asarray(f.build_np(keys)))
