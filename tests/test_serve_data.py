"""Serving substrate + data pipeline tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import (ShardRangeIndex, StreamDeduper, SyntheticCorpus,
                        batch_iterator)
from repro.models import get_model
from repro.serve import PagedKVCache, PrefixCacheIndex, ServeLoop
from repro.serve.decode import Request
from repro.serve.prefix_cache import pack_key


def test_serve_loop_matches_manual_greedy(rng):
    cfg = get_config("qwen3-1.7b", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = rng.integers(0, cfg.vocab - 1, 12).astype(np.int32)

    loop = ServeLoop(model, params, max_seq=32, batch_slots=1)
    [req] = loop.run([Request(session=1, prompt=prompt, max_new_tokens=5)])

    # manual greedy decode
    toks = jnp.asarray(prompt[None, :])
    logits, cache = jax.jit(model.prefill)(params, {"tokens": toks})
    cache = jax.tree.map(
        lambda x: jnp.pad(x, [(0, 0)] * 2 + [(0, 32 - len(prompt))] +
                          [(0, 0)] * (x.ndim - 3))
        if x.ndim >= 3 and x.shape[2] == len(prompt) else x, cache)
    want = []
    for t in range(5):
        nxt = jnp.argmax(logits[:, -1, :], axis=-1)
        want.append(int(nxt[0]))
        logits, cache = jax.jit(model.decode)(
            params, cache, {"token": nxt[:, None].astype(jnp.int32),
                            "pos": jnp.asarray(len(prompt) + t, jnp.int32)})
    assert req.out_tokens == want


def test_paged_kv_cache_roundtrip(rng):
    pc = PagedKVCache(n_layers=2, n_pages=16, page_size=8, n_kv=2, head_dim=4)
    pc.alloc_seq(7, 20)
    k = jnp.asarray(rng.normal(0, 1, (2, 20, 2, 4)), jnp.bfloat16)
    pc.write_prefill(7, k, k)
    kc, vc = pc.gather_cache([7], max_pages=3)
    assert kc.shape == (2, 1, 24, 2, 4)
    assert (np.asarray(kc[:, 0, :20], np.float32) ==
            np.asarray(k, np.float32)).all()
    pc.write_token(7, 20, k[:, :1], k[:, :1])
    kc2, _ = pc.gather_cache([7], max_pages=3)
    assert (np.asarray(kc2[:, 0, 20], np.float32) ==
            np.asarray(k[:, 0], np.float32)).all()
    pc.free_seq(7)
    assert len(pc.free) == 16
    # page sharing for frozen prefixes keeps refcounts
    pages = pc.alloc_seq(1, 16)
    pc.share_pages(2, pages)
    pc.free_seq(1)
    assert len(pc.free) == 14  # still held by seq 2
    pc.free_seq(2)
    assert len(pc.free) == 16


def test_prefix_cache_no_false_negatives():
    idx = PrefixCacheIndex(bits_per_key=16)
    entries = {pack_key(s, c): [s * 10 + c] for s in range(6)
               for c in range(4)}
    idx.freeze_segment(entries)
    for s in range(6):
        for c in range(4):
            assert idx.lookup(s, c) == [s * 10 + c]
    assert idx.lookup(99, 0) is None
    segs = idx.session_segments(3)
    assert segs == [0]
    assert idx.eviction_candidates(0, 5) == [0]


def test_stream_dedup_never_admits_twice(rng):
    ids = rng.integers(0, 1 << 63, 500, dtype=np.uint64)
    dd = StreamDeduper(expected_docs=2000)
    keep1 = dd.admit(ids)
    keep2 = dd.admit(ids)
    assert not keep2.any(), "duplicate admitted twice (false negative!)"
    assert keep1.mean() > 0.9  # few FPs on first sight


def test_shard_range_index_no_false_negatives(rng):
    idx = ShardRangeIndex()
    stamps = {s: np.sort(rng.integers(s * 1000, (s + 1) * 1000, 50,
                                      dtype=np.uint64))
              for s in range(5)}
    for s, ts in stamps.items():
        idx.add_shard(s, ts)
    got = idx.shards_in_window(1500, 2500)
    # shards 1 and 2 definitely contain stamps in [1500, 2500]
    assert 1 in got and 2 in got


def test_batch_iterator_shapes():
    corpus = SyntheticCorpus(vocab=1000, seed=3, n_shards=4,
                             docs_per_shard=64)
    dd = StreamDeduper(expected_docs=4096)
    it = batch_iterator(corpus, batch=4, seq=64, deduper=dd)
    b = next(it)
    assert b["tokens"].shape == (4, 64)
    assert b["labels"].shape == (4, 64)
    assert int(b["tokens"].max()) < 1000
    assert dd.stats["seen"] > 0
