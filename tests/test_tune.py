"""Workload-adaptive tuner (§16): observe → fit → solve → retune.

Covers the whole loop: advisor input validation, the FprSampler workload
reservoir (exact Algorithm R — determinism and unbiasedness), the
``bloomrf-workload/v1`` model and its serde contract, the sample-driven
cost model against the engine's own probe accounting, solver hysteresis,
the AdaptiveTuner decision cache, and the store/facade wiring: retunes
fire at class-graduating compactions, the tuned store answers exactly
like its static twin (ZERO false negatives), snapshots carry the
workload model, and the one-gather / one-``pallas_call`` probe-plane
invariants survive a retuned (mixed-layout) run stack.
"""
import pickle

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import basic_layout
from repro.core.engine import _filter_for_layout
from repro.core.tuning import advise
from repro.obs.fpr import LOG2_BUCKETS, SAMPLE_FIELDS, FprSampler
from repro.store import Store, StoreConfig
from repro.tune import (AdaptiveTuner, Hysteresis, WorkloadModel,
                        candidate_layouts, cross_check, fit_workload,
                        score_layout, solve)
from repro.tune.cost import words_per_range_query
from repro.tune.workload import N_RANGE_BUCKETS, SCHEMA, range_log2_bucket

from conftest import brute_force_range_truth


# ---------------------------------------------------------------------------
# advisor input validation (satellite: core/tuning.py::advise)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kwargs,needle", [
    (dict(d=0, n=10, m_bits=1000, R=4.0), "d must be"),
    (dict(d=65, n=10, m_bits=1000, R=4.0), "d must be"),
    (dict(d=-3, n=10, m_bits=1000, R=4.0), "d must be"),
    (dict(d=32, n=0, m_bits=1000, R=4.0), "n must be"),
    (dict(d=32, n=-1, m_bits=1000, R=4.0), "n must be"),
    (dict(d=32, n=10, m_bits=0, R=4.0), "m_bits must be"),
    (dict(d=32, n=10, m_bits=-64, R=4.0), "m_bits must be"),
    (dict(d=32, n=10, m_bits=1000, R=0.5), "R must be"),
    (dict(d=32, n=10, m_bits=1000, R=float("nan")), "R must be"),
])
def test_advise_rejects_bad_inputs(kwargs, needle):
    with pytest.raises(ValueError, match=needle):
        advise(**kwargs)


def test_advise_infeasible_budget_is_a_clear_error():
    # budget too small for ANY exact level: actionable message, not a
    # StopIteration from the internal candidate sweep
    with pytest.raises(ValueError, match="no feasible"):
        advise(d=32, n=10, m_bits=1, R=16.0)
    # feasible exact level but no room for the hashed segments
    with pytest.raises(ValueError, match="no feasible"):
        advise(d=1, n=4, m_bits=64, R=2.0)


def test_advise_boundary_d1_and_d64():
    lo = advise(d=1, n=4, m_bits=4096, R=2.0)
    assert lo.layout.d == 1 and lo.exact_level == 1
    hi = advise(d=64, n=10_000, m_bits=400_000, R=2.0 ** 20)
    assert hi.layout.d == 64
    assert 0.0 <= hi.fpr_point <= hi.fpr_w and np.isfinite(hi.fpr_w)
    assert sum(hi.layout.deltas) + hi.exact_level <= 64 + hi.exact_level


# ---------------------------------------------------------------------------
# FprSampler workload reservoir: determinism + unbiasedness + schema
# ---------------------------------------------------------------------------

def _feed(sampler, lo, hi, batch):
    for s in range(0, len(lo), batch):
        sampler.observe_ranges(lo[s:s + batch], hi[s:s + batch])


def test_sampler_workload_is_deterministic_and_batch_invariant():
    """Same seed + same stream => identical sample, however batched.

    The vectorized Algorithm R draws exactly one uniform per stream item
    (the fill phase draws none), so the RNG stream position — and hence
    the reservoir — cannot depend on how callers batch their scans."""
    rng = np.random.default_rng(7)
    lo = rng.integers(0, 1 << 30, 5000, dtype=np.uint64)
    hi = lo + rng.integers(1, 1 << 12, 5000, dtype=np.uint64)
    samples = []
    for batch in (5000, 137, 1):
        s = FprSampler(32, seed=0xFEED, reservoir_cap=256)
        _feed(s, lo, hi, batch)
        samples.append((s.workload_sample(), s.workload_seen,
                        s.range_log2_counts.copy()))
    for other in samples[1:]:
        assert other[0] == samples[0][0]
        assert other[1] == samples[0][1]
        np.testing.assert_array_equal(other[2], samples[0][2])


def test_sampler_reservoir_is_unbiased_chi_square():
    """Every position of a 1e5-item stream is equally likely to survive:
    decile occupancy of the reservoir passes a chi-square test (df=9,
    alpha=1e-3 critical value 27.88). Fixed seed => deterministic."""
    n, cap = 100_000, 1024
    s = FprSampler(32, seed=0xC41, reservoir_cap=cap)
    pos = np.arange(n, dtype=np.uint64)       # lo encodes stream position
    _feed(s, pos, pos, 4096)
    assert s.workload_seen == n
    kept = np.asarray([a for a, _ in s.workload_sample()], np.int64)
    assert kept.size == cap
    obs = np.bincount(kept // (n // 10), minlength=10)
    exp = cap / 10.0
    chi2 = float(((obs - exp) ** 2 / exp).sum())
    assert chi2 < 27.88, f"reservoir decile bias: chi2={chi2:.1f}, {obs}"


def test_sampler_sample_schema_is_pinned(rng):
    """sample() keys are exactly the pinned SAMPLE_FIELDS contract that
    the workload fit consumes by name."""
    s = FprSampler(16, n_keys=64, n_ranges=64, seed=3)
    base = s.sample()
    assert set(base) == set(SAMPLE_FIELDS[:3])
    full = s.sample(point_probe=lambda ks: np.ones(len(ks), bool),
                    range_probe=lambda lo, hi: np.zeros(len(lo), bool))
    assert set(full) == set(SAMPLE_FIELDS)
    assert full["point_fpr"] == 1.0 and full["range_fpr"] == 0.0


def test_sampler_range_histogram_buckets_dyadically():
    s = FprSampler(32, seed=5)
    lo = np.zeros(3, np.uint64)
    hi = np.asarray([0, 255, 256], np.uint64)     # lengths 1, 256, 257
    s.observe_ranges(lo, hi)
    np.testing.assert_array_equal(range_log2_bucket([1, 256, 257]),
                                  [0, 8, 9])
    assert s.range_log2_counts[0] == 1
    assert s.range_log2_counts[8] == 1
    assert s.range_log2_counts[9] == 1
    assert s.range_log2_counts.sum() == 3


def test_sampler_preload_roundtrip_and_validation():
    src = FprSampler(24, seed=11, reservoir_cap=128)
    lo = np.arange(500, dtype=np.uint64)
    src.observe_ranges(lo, lo + np.uint64(31))
    dst = FprSampler(24, seed=99, reservoir_cap=128)
    dst.preload_workload(src.workload_sample(), src.workload_seen,
                         src.range_log2_counts)
    assert dst.workload_sample() == src.workload_sample()
    assert dst.workload_seen == src.workload_seen
    np.testing.assert_array_equal(dst.range_log2_counts,
                                  src.range_log2_counts)
    with pytest.raises(ValueError, match="lo > hi"):
        dst.preload_workload([(5, 2)], 1)
    with pytest.raises(ValueError, match="log2_counts"):
        dst.preload_workload([(1, 2)], 1, np.ones(7))
    with pytest.raises(ValueError, match="log2_counts"):
        dst.preload_workload([(1, 2)], 1, -np.ones(len(LOG2_BUCKETS)))


# ---------------------------------------------------------------------------
# WorkloadModel: fit, derived views, serde contract
# ---------------------------------------------------------------------------

def _sampled_workload(seed=21, n=400, length=64, d=32):
    rng = np.random.default_rng(seed)
    s = FprSampler(d, seed=seed)
    lo = rng.integers(0, 1 << 24, n, dtype=np.uint64)
    s.observe_ranges(lo, lo + np.uint64(length - 1))
    keys = rng.integers(0, 1 << d, 2000, dtype=np.uint64)
    return fit_workload(d, sampler=s, keys=keys,
                        observed={"range_fpr": 0.02}, n_points=100)


def test_workload_fit_and_derived_views():
    wl = _sampled_workload()
    assert wl.n_ranges == 400 and wl.n_points == 100
    assert wl.point_frac() == pytest.approx(0.2)
    w = wl.range_weights()
    assert w.shape == (N_RANGE_BUCKETS,) and w.sum() == pytest.approx(1.0)
    assert w[6] == pytest.approx(1.0)             # every range length 64
    # clustered keys (all in the low 2^24 of a 2^32 domain... keys here
    # are uniform over 2^32, so C stays ~1); a point mass must raise C
    assert 1.0 <= wl.c_factor <= 1.5
    spike = WorkloadModel(
        d=32, range_log2=np.zeros(N_RANGE_BUCKETS), n_ranges=0, n_points=0,
        key_density=np.eye(64)[0], observed={}, reservoir=())
    assert spike.c_factor == 1.5                  # capped, never unbounded
    # empty workload: weights collapse onto the default R budget
    w0 = spike.range_weights(default_log2=8)
    assert w0[8] == 1.0 and w0.sum() == 1.0


def test_workload_rescaled_shifts_range_lengths():
    wl = _sampled_workload(length=256)            # all mass in bucket 8
    down = wl.rescaled(-2)                        # shard-local: len / 4
    assert down.range_log2[6] == wl.range_log2[8]
    assert down.range_log2.sum() == wl.range_log2.sum()
    assert wl.rescaled(0) is wl


def test_workload_serde_roundtrip_through_real_bytes():
    wl = _sampled_workload()
    enc = pickle.loads(pickle.dumps(wl.to_dict()))
    assert enc["schema"] == SCHEMA
    back = WorkloadModel.from_dict(enc)
    assert back.d == wl.d
    assert back.n_ranges == wl.n_ranges and back.n_points == wl.n_points
    np.testing.assert_array_equal(back.range_log2, wl.range_log2)
    np.testing.assert_array_equal(back.key_density, wl.key_density)
    assert back.observed == wl.observed
    assert back.reservoir == wl.reservoir


@pytest.mark.parametrize("mutate,needle", [
    (lambda e: e.pop("schema"), "schema"),
    (lambda e: e.update(schema="bloomrf-workload/v9"), "schema"),
    (lambda e: e.update(d=0), "d must be"),
    (lambda e: e.update(d="32"), "d must be"),
    (lambda e: e.update(range_log2=[1.0] * 7), "range_log2"),
    (lambda e: e["range_log2"].__setitem__(0, -1.0), "range_log2"),
    (lambda e: e.update(key_density=None), "key_density"),
    (lambda e: e.update(n_ranges=-1), "n_ranges"),
    (lambda e: e.update(n_points=True), "n_points"),
    (lambda e: e.update(observed={"range_fpr": "high"}), "observed"),
    (lambda e: e.update(reservoir=[[5, 2]]), "reservoir"),
    (lambda e: e.update(reservoir=[[-1, 2]]), "reservoir"),
])
def test_workload_from_dict_rejects_malformed(mutate, needle):
    enc = _sampled_workload().to_dict()
    mutate(enc)
    with pytest.raises(ValueError, match=needle):
        WorkloadModel.from_dict(enc)


def test_workload_from_dict_rejects_non_dict():
    with pytest.raises(ValueError, match="dict"):
        WorkloadModel.from_dict([1, 2, 3])


# ---------------------------------------------------------------------------
# cost model: engine-true probe accounting, workload-shaped FPR
# ---------------------------------------------------------------------------

def test_cost_words_match_engine_accounting():
    for delta in (2, 4, 6):
        lay = basic_layout(24, 4000, 12.0, delta=delta)
        assert words_per_range_query(lay) == float(
            _filter_for_layout(lay).engine.range_word_loads)


def test_cost_longer_ranges_never_get_cheaper():
    """fpr_range is an integral over max(fpr[0..l]) — pushing workload
    mass to longer ranges can only raise the predicted range FPR."""
    lay = basic_layout(32, 8000, 12.0, delta=6)
    short = _sampled_workload(length=16)
    long = _sampled_workload(length=1 << 14)
    a = score_layout(lay, 8000, short)
    b = score_layout(lay, 8000, long)
    assert b.fpr_range >= a.fpr_range
    assert 0.0 <= a.fpr_point <= a.fpr_mix <= 1.0
    assert a.objective >= a.fpr_mix          # word cost is a penalty


def test_cost_rejects_bad_n_keys():
    with pytest.raises(ValueError, match="n_keys"):
        score_layout(basic_layout(24, 100, 12.0), 0, _sampled_workload())


def test_cross_check_reports_clipped_calibration():
    wl = _sampled_workload()                     # observed range_fpr 0.02
    lay = basic_layout(32, 8000, 12.0, delta=6)
    out = cross_check(lay, 8000, wl)
    assert set(out) >= {"predicted_range_fpr", "observed_range_fpr",
                        "calibration"}
    assert out["observed_range_fpr"] == 0.02
    assert 0.25 <= out["calibration"] <= 4.0
    blind = _sampled_workload()
    blind.observed.clear()
    assert cross_check(lay, 8000, blind)["calibration"] is None


# ---------------------------------------------------------------------------
# solver: equal-budget candidates, hysteresis
# ---------------------------------------------------------------------------

def test_candidates_are_hashed_single_segment_at_equal_budget():
    cur = basic_layout(32, 20_000, 14.0, delta=6)
    cands = candidate_layouts(cur, 20_000)
    assert len(cands) >= 4
    for lay in cands:
        assert lay != cur
        assert lay.d == cur.d
        assert lay.exact_seg is None             # probe-plane stackable
        assert len(lay.seg_bits) == 1
        # equal bits per key: never buys a win with more memory (only the
        # 64-bit word round-up / tiny-geometry floor may pad upward)
        assert lay.seg_bits[0] <= max(cur.seg_bits[0] + 64,
                                      2 * (1 << 6) + 64)
        assert sum(lay.deltas) <= lay.d


def test_hysteresis_validation():
    with pytest.raises(ValueError, match="min_win"):
        Hysteresis(min_win=1.0)
    with pytest.raises(ValueError, match="min_win"):
        Hysteresis(min_win=-0.1)
    with pytest.raises(ValueError):
        Hysteresis(cooldown=-1)


def test_solve_short_range_workload_shrinks_deltas():
    """A scan workload of short ranges on a coarse-δ ladder must retune
    to finer deltas (fewer wasted dyadic levels => lower predicted FPR)."""
    cur = basic_layout(32, 20_000, 14.0, delta=6)
    wl = _sampled_workload(length=8, n=500)
    dec = solve(wl, 20_000, cur)
    assert dec.changed and dec.win >= 0.10
    assert max(dec.layout.deltas) < max(cur.deltas)
    assert dec.best.objective < dec.baseline.objective
    assert "->" in dec.reason


def test_solve_hysteresis_blocks_small_wins_and_cold_workloads():
    cur = basic_layout(32, 20_000, 14.0, delta=6)
    wl = _sampled_workload(length=8, n=500)
    held = solve(wl, 20_000, cur, Hysteresis(min_win=0.9999))
    assert not held.changed and held.layout is cur
    assert "min_win" in held.reason
    cold = _sampled_workload(n=8)                # below min_ranges=64
    gate = solve(cold, 20_000, cur)
    assert not gate.changed and gate.n_candidates == 0
    assert "insufficient workload" in gate.reason


# ---------------------------------------------------------------------------
# AdaptiveTuner: decision cache, cooldown, events, serde
# ---------------------------------------------------------------------------

def _hot_tuner(length=8, n=500, d=32):
    t = AdaptiveTuner(d, hysteresis=Hysteresis(cooldown=2))
    rng = np.random.default_rng(31)
    lo = rng.integers(0, 1 << 24, n, dtype=np.uint64)
    t.observe_scan(lo, lo + np.uint64(length - 1))
    return t


def test_tuner_retune_event_and_flush_cache():
    t = _hot_tuner()
    ladder = basic_layout(32, 20_000, 14.0, delta=6)
    tuned = t.advise_layout(ladder, 20_000)
    assert tuned != ladder and t.retunes == 1
    ev = t.events[0]
    assert ev["class_deltas"] == list(ladder.deltas)
    assert ev["tuned_deltas"] == list(tuned.deltas)
    assert ev["predicted_fpr_mix"] < ev["baseline_fpr_mix"]
    # flushes get the standing decision without a solve
    assert t.cached_layout(ladder) == tuned
    # an unconsulted capacity class has no standing decision
    assert t.cached_layout(basic_layout(32, 500, 14.0, delta=6)) is None
    # a second consultation reuses the cache: no duplicate event
    assert t.advise_layout(ladder, 20_000) == tuned
    assert t.retunes == 1 and len(t.events) == 1
    rep = t.report()
    assert rep["retunes"] == 1 and rep["workload"]["schema"] == SCHEMA
    assert str(ladder.deltas) in rep["decisions"]


def test_tuner_cooldown_limits_resolves(monkeypatch):
    import repro.tune.retune as retune_mod

    t = _hot_tuner()
    ladder = basic_layout(32, 20_000, 14.0, delta=6)
    calls = []
    real = retune_mod.solve
    monkeypatch.setattr(retune_mod, "solve",
                        lambda *a, **k: calls.append(1) or real(*a, **k))
    for _ in range(5):
        t.advise_layout(ladder, 20_000)
    # cooldown=2: solve at consultation 1, cached for 2, solve again at 4
    assert len(calls) == 2


def test_tuner_min_ranges_gate_and_observed_fold():
    t = AdaptiveTuner(32)
    ladder = basic_layout(32, 20_000, 14.0, delta=6)
    assert t.advise_layout(ladder, 20_000) == ladder     # cold: no solve
    assert t.retunes == 0 and t.cached_layout(ladder) is None
    t.record_observed({"range_fpr": 0.05, "point_candidates": 3})
    t.observe_points(40)
    assert t.observed == {"range_fpr": 0.05}
    assert t.workload().n_points == 40


def test_tuner_serde_roundtrip_and_validation():
    t = _hot_tuner()
    t.observe_points(7)
    t.record_observed({"range_fpr": 0.01})
    enc = pickle.loads(pickle.dumps(t.to_dict()))
    back = AdaptiveTuner(32)
    back.load(enc)
    assert back.sampler.workload_seen == t.sampler.workload_seen
    assert back.sampler.workload_sample() == t.sampler.workload_sample()
    assert back.points_seen == 7 and back.observed == {"range_fpr": 0.01}
    with pytest.raises(ValueError, match="d=32"):
        AdaptiveTuner(24).load(enc)
    with pytest.raises(ValueError, match="schema"):
        back.load({"schema": "nope"})


# ---------------------------------------------------------------------------
# store wiring: retunes fire at compaction, twins agree, snapshots carry
# the workload (tentpole acceptance)
# ---------------------------------------------------------------------------

def _skewed_ops(seed, n_keys=12_000, n_scans=384, scan_len=256):
    """A clustered key set + short-scan workload: exactly the shape the
    coarse static ladder overprices and the tuner wins on."""
    rng = np.random.default_rng(seed)
    keys = ((rng.random(n_keys) ** 4) * (1 << 31)).astype(np.uint64)
    keys += rng.integers(0, 1 << 22, n_keys, dtype=np.uint64)
    keys = np.minimum(keys, (1 << 32) - 1)
    starts = keys[rng.integers(0, n_keys, n_scans)] + np.uint64(1)
    starts = np.minimum(starts, (1 << 32) - np.uint64(scan_len))
    return keys, starts, starts + np.uint64(scan_len - 1)


def _drive(st, keys, slo, shi):
    half = len(keys) // 2
    for i, k in enumerate(keys[:half]):
        st.put(int(k), i)
    st.flush()
    scans = []
    for s in range(0, len(slo), 64):
        scans.extend(st.scan_many(slo[s:s + 64], shi[s:s + 64]))
    for i, k in enumerate(keys[half:]):
        st.put(int(k), half + i)
    st.flush()
    return scans


def _absent_range_fpr(st, keys, seed, n=2000, length=256):
    rng = np.random.default_rng(seed)
    lo = rng.integers(1 << 30, (1 << 31), n, dtype=np.uint64)
    hi = lo + np.uint64(length - 1)
    empty = ~brute_force_range_truth(keys, lo, hi)
    fence, filt = st.probe_runs(lo[empty], hi[empty])
    return float((fence & filt).any(axis=1).mean())


def _twin_cfg(tuning):
    return StoreConfig(d=32, memtable_limit=800, level0_runs=3, fanout=4,
                       bits_per_key=14.0, tuning=tuning)


def test_store_adaptive_retunes_and_beats_static_twin():
    """The §16 acceptance loop: identical op streams through a static and
    an adaptive store — the adaptive one must (a) retune at least once
    via the compaction re-insert path, (b) answer every query identically
    (zero false negatives, identical scans), and (c) leak strictly fewer
    false positives on the short-scan workload it observed."""
    keys, slo, shi = _skewed_ops(0xA5EED)
    st_s = Store(_twin_cfg("static"))
    st_a = Store(_twin_cfg("adaptive"))
    scans_s = _drive(st_s, keys, slo, shi)
    scans_a = _drive(st_a, keys, slo, shi)
    assert st_a.stats.retunes >= 1, "compaction never landed a retune"
    assert st_a._tuner.retunes >= 1 and st_a._tuner.events
    assert st_s.stats.retunes == 0
    # zero false negatives + twin equality
    assert scans_a == scans_s
    qs = np.unique(keys)
    assert st_a.get_many(qs) == st_s.get_many(qs)
    # the live stack really holds tuner-chosen layouts, not the ladder's
    assert any(r.layout != st_a.class_layout(len(r))
               for r in st_a.live_runs())
    # strictly fewer false positives at equal bits per key
    fpr_a = _absent_range_fpr(st_a, keys, 0xF00)
    fpr_s = _absent_range_fpr(st_s, keys, 0xF00)
    assert fpr_a < fpr_s, (fpr_a, fpr_s)


def test_store_adaptive_composes_with_deletable_churn():
    """Retuning must not break the deletable lane's purge/promote
    machinery: mixed put/delete churn with live scans, zero FN."""
    rng = np.random.default_rng(0xDE1E7E)
    st = Store(StoreConfig(d=24, memtable_limit=256, level0_runs=2,
                           fanout=4, bits_per_key=14.0,
                           mutability="deletable", tuning="adaptive"))
    space = 1 << 24
    model = {}
    for i in range(12_000):
        if model and rng.random() < 0.4:
            k = int(next(iter(model)))
            st.delete(k)
            del model[k]
        else:
            k = int(rng.integers(0, space))
            st.put(k, i)
            model[k] = i
        if i % 500 == 499:                       # live scan workload
            lo = rng.integers(0, space - 64, 32, dtype=np.uint64)
            st.scan_many(lo, lo + np.uint64(63))
    st.flush()
    live = np.fromiter(model.keys(), np.uint64, len(model))
    assert st.get_many(live) == [model[int(k)] for k in live], \
        "adaptive+deletable churn produced a false negative"
    assert st.stats.promote_merges + st.stats.purge_rebuilds > 0
    assert st._tuner.sampler.workload_seen > 0
    # scans still return exactly the live surviving rows
    lo = live[:16]
    for got, k in zip(st.scan_many(lo, lo), lo):
        assert (int(k), model[int(k)]) in got


@pytest.mark.slow
def test_store_adaptive_twin_fuzz_slow_1e5():
    """Headline §16 fuzz: 1e5 mixed ops through adaptive+deletable vs
    static+deletable twins — same answers, zero FN, retunes fired."""
    def run(tuning):
        rng = np.random.default_rng(0x57EED)
        st = Store(StoreConfig(d=24, memtable_limit=1024, level0_runs=2,
                               fanout=4, bits_per_key=14.0,
                               mutability="deletable", tuning=tuning))
        model = {}
        for i in range(100_000):
            if model and rng.random() < 0.4:
                k = int(next(iter(model)))
                st.delete(k)
                del model[k]
            else:
                k = int(rng.integers(0, 1 << 24))
                st.put(k, i)
                model[k] = i
            if i % 1000 == 999:
                lo = rng.integers(0, (1 << 24) - 256, 64, dtype=np.uint64)
                st.scan_many(lo, lo + np.uint64(255))
        st.flush()
        return st, model

    st_a, model_a = run("adaptive")
    st_s, model_s = run("static")
    assert model_a == model_s                    # identical op streams
    live = np.fromiter(model_a.keys(), np.uint64, len(model_a))
    got_a, got_s = st_a.get_many(live), st_s.get_many(live)
    assert got_a == [model_a[int(k)] for k in live]
    assert got_a == got_s
    assert st_a.stats.retunes >= 1


def test_store_config_validates_tuning():
    with pytest.raises(ValueError, match="tuning"):
        StoreConfig(d=24, tuning="bogus")
    with pytest.raises(ValueError, match="adaptive"):
        StoreConfig(d=24, tuning="adaptive", filter_backend="none")


def test_store_snapshot_carries_workload_model():
    keys, slo, shi = _skewed_ops(0xBEEF, n_keys=4000, n_scans=128)
    st = Store(_twin_cfg("adaptive"))
    _drive(st, keys, slo, shi)
    snap = st.snapshot()
    assert snap["workload"]["schema"] == SCHEMA
    st2 = Store.restore(pickle.loads(pickle.dumps(snap)))
    assert st2._tuner is not None
    assert st2._tuner.sampler.workload_seen == \
        st._tuner.sampler.workload_seen
    assert st2.stats.retunes == st.stats.retunes
    qs = np.unique(keys)[:500]
    assert st2.get_many(qs) == st.get_many(qs)
    # static stores snapshot without a workload payload
    assert "workload" not in Store(_twin_cfg("static")).snapshot()
    # corrupt payloads fail loudly at restore
    bad = pickle.loads(pickle.dumps(snap))
    bad["workload"]["range_log2"] = [1.0, 2.0]
    with pytest.raises(ValueError, match="workload"):
        Store.restore(bad)


def test_retuned_stack_keeps_probe_plane_invariants():
    """The §16 acceptance invariant: a retuned (mixed-layout) run stack
    still probes as ONE fused gather and scans as ONE pallas_call."""
    from test_engine import _count_gathers
    from test_store_scan_kernel import _count_prim
    from repro.kernels.store_scan import store_scan_probe

    keys, slo, shi = _skewed_ops(0x1AB, n_keys=8000, n_scans=256)
    st = Store(StoreConfig(d=32, memtable_limit=800, level0_runs=3,
                           fanout=4, bits_per_key=14.0, tuning="adaptive",
                           scan_backend="kernel"))
    _drive(st, keys, slo, shi)
    assert st.stats.retunes >= 1
    st._refresh()
    assert any(r.layout != st.class_layout(len(r))
               for r in st.live_runs())
    # one gather through the stacked point/range probe plane
    lo = jnp.asarray(np.arange(64), jnp.uint32)
    jx = jax.make_jaxpr(
        lambda flat, a: st._probe.range_all(flat, a, a))(st._flat, lo)
    assert _count_gathers(jx.jaxpr) == 1
    # one pallas_call through the scan megakernel
    layouts, stack, kmin_d, kmax_d, rpb = st._kernel_inputs()
    jk = jax.make_jaxpr(
        lambda s, a, b: store_scan_probe(layouts, s, kmin_d, kmax_d,
                                         a, b, 256, rpb, True))(
        stack, lo, jnp.asarray(np.arange(64) + (1 << 20), jnp.uint32))
    assert _count_prim(jk.jaxpr, "pallas_call") == 1
    assert st.stats.kernel_fallbacks == 0


# ---------------------------------------------------------------------------
# facade: FilterSpec plumbing, retune_report, tenant retune-on-promote
# ---------------------------------------------------------------------------

def test_facade_adaptive_spec_validation():
    from repro.api import FilterSpec

    with pytest.raises(ValueError, match="adaptive"):
        FilterSpec(dtype="u32", tuning="adaptive")            # single
    with pytest.raises(ValueError, match="adaptive"):
        FilterSpec(dtype="u32", placement="bank", tuning="adaptive")
    FilterSpec(dtype="u32", placement="store", tuning="adaptive")
    FilterSpec(dtype="u32", placement="tenant", tenants=2,
               tuning="adaptive")


def test_facade_store_retune_report():
    from repro.api import FilterSpec, open_filter

    f = open_filter(FilterSpec(dtype="u32", placement="store",
                               tuning="adaptive", memtable_limit=500,
                               level0_runs=2))
    keys, slo, shi = _skewed_ops(0xFACADE % (1 << 31), n_keys=6000,
                                 n_scans=192)
    half = len(keys) // 2
    for i, k in enumerate(keys[:half]):
        f.put(int(k), i)
    f.flush()
    for s in range(0, len(slo), 64):
        f.scan_many(slo[s:s + 64], shi[s:s + 64])
    for i, k in enumerate(keys[half:]):
        f.put(int(k), half + i)
    f.flush()
    rep = f.retune_report()
    assert rep["tuning"] == "adaptive" and rep["retunes"] >= 1
    assert rep["events"] and rep["workload"]["schema"] == SCHEMA
    assert rep["decisions"]
    # observed_fpr feeds the model's live cross-check
    out = f.observed_fpr()
    rep2 = f.retune_report()
    if "range_fpr" in out:
        cc = rep2["cross_check"]
        assert cc["observed_range_fpr"] == out["range_fpr"]
        assert cc["calibration"] is None or 0.25 <= cc["calibration"] <= 4.0
    # zero FN through the facade after retuning
    assert all(v is not None for v in f.get_many(np.unique(keys)[:500]))
    # static stores report a stub, not an error
    g = open_filter(FilterSpec(dtype="u32", placement="store"))
    assert g.retune_report() == {"tuning": "auto", "retunes": 0,
                                 "events": []}


def test_facade_tenant_adaptive_grow_is_advised(rng):
    from repro.api import FilterSpec, open_filter

    f = open_filter(FilterSpec(dtype="u32", n=1024, placement="tenant",
                               tenants=3, shards=2, tuning="adaptive"))
    tenants = rng.integers(0, 3, 600).astype(np.uint32)
    keys = rng.integers(0, 1 << 32, 600, dtype=np.uint64)
    f.insert(tenants, keys)
    lo = rng.integers(0, (1 << 32) - 256, 200, dtype=np.uint64)
    f.range(tenants[:200], lo, lo + np.uint64(255))
    f.grow()                                     # factor advised, not fixed
    rep = f.retune_report()
    assert rep["tuning"] == "adaptive"
    assert rep["workload_seen"] == 200
    assert len(rep["promotions"]) == 1
    ev = rep["promotions"][0]
    assert ev["factor"] >= 2 and ev["reports"]
    assert rep["workload"]["schema"] == SCHEMA
    # zero FN after the advised promotion
    assert np.asarray(f.point(tenants, keys)).all()
    assert np.asarray(f.range(tenants, keys, keys)).all()


def test_facade_tenant_adaptive_composes_with_ttl(rng):
    from repro.api import FilterSpec, open_filter

    f = open_filter(FilterSpec(dtype="u32", n=512, placement="tenant",
                               tenants=2, mutability="ttl", generations=2,
                               tuning="adaptive"))
    tenants = rng.integers(0, 2, 300).astype(np.uint32)
    keys = rng.integers(0, 1 << 32, 300, dtype=np.uint64)
    f.insert(tenants, keys)
    f.range(tenants, np.maximum(keys, 8) - np.uint64(8), keys)
    f.advance_generation()
    f.grow()                                     # advised + TTL lanes
    assert np.asarray(f.point(tenants, keys)).all()
    f.advance_generation()
    f.advance_generation()
    assert np.asarray(f.point(tenants, keys)).mean() < 0.05


def test_tenant_bank_advise_promotion_scales_with_target():
    from repro.dist import TenantFilterBank

    bank = TenantFilterBank(d=32, n_tenants=2, n_shards=2,
                            n_keys_per_tenant=1024, _warn=False)
    wl = _sampled_workload(length=64, n=300)
    f_small, rep_small = bank.advise_promotion(wl, n_target=2048)
    f_big, rep_big = bank.advise_promotion(wl, n_target=8192)
    assert f_small >= 2 and f_big >= f_small
    assert 2 in rep_small and f_big in rep_big
    assert all(r.fpr_mix >= 0 for r in rep_small.values())
    # a target beyond every candidate factor is an error, not a silent
    # under-provision
    with pytest.raises(ValueError):
        bank.advise_promotion(wl, n_target=1 << 30)
    with pytest.raises(ValueError, match="n_current"):
        bank.advise_promotion(wl, n_current=0)
