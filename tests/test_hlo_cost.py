"""HLO cost-model parser: operand extraction and trip-count flop scaling
on synthetic modules (the full-model oracle check is the slow test in
test_dist_and_dryrun.py)."""
from repro.launch.hlo_cost import _operand_names, analyze_hlo

_MODULE = """\
HloModule jit_f

%body.1 (p.0: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p.0 = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[8,16]{1,0}) %p.0), index=0
  %x = f32[8,16]{1,0} get-tuple-element((s32[], f32[8,16]{1,0}) %p.0), index=1
  %w = f32[16,16]{1,0} constant({...})
  %y = f32[8,16]{1,0} dot(f32[8,16]{1,0} %x, f32[16,16]{1,0} %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %c1 = s32[] constant(1)
  %i2 = s32[] add(s32[] %i, s32[] %c1)
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(s32[] %i2, f32[8,16]{1,0} %y)
}

%cond.1 (p.1: (s32[], f32[8,16])) -> pred[] {
  %p.1 = (s32[], f32[8,16]{1,0}) parameter(0)
  %it = s32[] get-tuple-element((s32[], f32[8,16]{1,0}) %p.1), index=0
  %trips = s32[] constant(24)
  ROOT %lt = pred[] compare(s32[] %it, s32[] %trips), direction=LT
}

ENTRY %main.1 (a.0: f32[8,16]) -> (s32[], f32[8,16]) {
  %a.0 = f32[8,16]{1,0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,16]{1,0}) tuple(s32[] %z, f32[8,16]{1,0} %a.0)
  ROOT %wh = (s32[], f32[8,16]{1,0}) while((s32[], f32[8,16]{1,0}) %init), condition=%cond.1, body=%body.1
}
"""


def test_operand_names_with_type_annotations():
    rest = ("f32[8,64,64]{2,1,0} %get-tuple-element.331, "
            "f32[64,32]{1,0} %dynamic-slice_fusion.5), "
            "lhs_contracting_dims={2}, body=%region_0.1")
    assert _operand_names(rest) == ["get-tuple-element.331",
                                    "dynamic-slice_fusion.5"]


def test_operand_names_tuple_types_and_attrs_excluded():
    rest = "(f32[2]{0}, u32[]) %tuple.1), index=0, to_apply=%reducer.7"
    assert _operand_names(rest) == ["tuple.1"]


def test_operand_names_sigil_less_fallback():
    rest = "f32[8,16]{1,0} x, f32[16,16]{1,0} w.1), lhs_contracting_dims={1}"
    assert _operand_names(rest) == ["x", "w.1"]


def test_analyze_hlo_scales_dot_flops_by_trip_count():
    hc = analyze_hlo(_MODULE)
    assert hc.while_trips == {"wh": 24}
    # dot: 2 * numel(8x16) * k(16) per trip, 24 trips
    assert hc.flops >= 24 * 2 * 8 * 16 * 16
    # sigil-less print style must account identically
    hc2 = analyze_hlo(_MODULE.replace("%", ""))
    assert hc2.flops == hc.flops and hc2.while_trips == hc.while_trips
