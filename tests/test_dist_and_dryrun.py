"""Distribution layer tests that need multiple devices run as subprocesses
(device count must be fixed before jax initializes), plus the dry-run smoke
and the HLO cost-model validation."""
import json
import os
import subprocess
import sys

import pytest

_ROOT = os.path.join(os.path.dirname(__file__), "..")
_SRC = os.path.join(_ROOT, "src")


def _run(script: str, devices: int = 8, timeout=900):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=_SRC, JAX_PLATFORMS="cpu")
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)


def test_pipeline_parallel_matches_sequential():
    r = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.dist.pipeline import pipeline_apply
mesh = jax.make_mesh((4,), ("pod",))
def stage_fn(w, x): return x * w["a"] + w["b"]
params = {"a": jnp.arange(1., 5., dtype=jnp.float32),
          "b": jnp.full((4,), 0.5, jnp.float32)}
x = jnp.arange(8., dtype=jnp.float32).reshape(8, 1)
out = pipeline_apply(stage_fn, params, x, mesh, "pod", n_microbatches=4)
exp = x
for s in range(4): exp = exp * (s + 1.) + 0.5
assert np.allclose(np.asarray(out), np.asarray(exp)), (out, exp)
print("PIPELINE-OK")
""", devices=4)
    assert "PIPELINE-OK" in r.stdout, r.stdout + r.stderr


def test_make_shardings_smoke_mesh():
    r = _run("""
import jax
from repro.configs import get_config
from repro.models import get_model, SHAPES
from repro.dist.sharding import make_shardings
mesh = jax.make_mesh((2, 4), ("data", "model"))
for arch in ("qwen3-1.7b", "mamba2-130m", "zamba2-2.7b", "whisper-base"):
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    for shp in ("train_4k", "decode_32k"):
        sh = make_shardings(model, mesh, SHAPES[shp])
        assert sh.params is not None
print("SHARDINGS-OK")
""", devices=8)
    assert "SHARDINGS-OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_dryrun_smoke_cells(tmp_path):
    """Lower+compile smoke configs on the REAL production meshes (512
    placeholder devices), single and multi pod."""
    out = tmp_path / "dry.json"
    env = dict(os.environ, PYTHONPATH=_SRC)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--smoke",
         "--arch", "qwen3-1.7b,mamba2-130m,moonshot-v1-16b-a3b",
         "--shape", "train_4k,decode_32k",
         "--mesh", "both", "--out", str(out)],
        env=env, capture_output=True, text=True, timeout=1800, cwd=_ROOT)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    recs = json.loads(out.read_text())
    assert all(x["status"] == "ok" for x in recs), recs


@pytest.mark.slow
def test_hlo_cost_matches_unrolled_oracle():
    r = _run("""
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import get_model
from repro.models.config import Shape
from repro.launch.hlo_cost import analyze_hlo
from repro.models.act import unrolled_scans
for arch in ("qwen3-1.7b", "moonshot-v1-16b-a3b", "whisper-base"):
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    shape = Shape("t", 64, 8, "train")
    psds = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                        model.table(), is_leaf=lambda x: hasattr(x, "axes"))
    batch = model.input_specs(shape)
    def f(p, b): return model.loss(p, b)
    hc = analyze_hlo(jax.jit(f).lower(psds, batch).compile().as_text())
    def g(p, b): return model.loss(p, b)
    with unrolled_scans():
        c2 = jax.jit(g).lower(psds, batch).compile()
    ca = c2.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    ratio = hc.flops / max(float(ca.get("flops", 0)), 1)
    assert 0.85 < ratio < 1.15, (arch, ratio)
print("HLOCOST-OK")
""", devices=16, timeout=1200)
    assert "HLOCOST-OK" in r.stdout, r.stdout + r.stderr
