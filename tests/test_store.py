"""LSM run-store (src/repro/store): correctness of the read/write path,
compaction filter merging, the one-gather stacked probe invariant, EF run
snapshots, and the store-level pruning acceptance vs the fence baseline.
"""
import dataclasses
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import basic_layout
from repro.store import Run, Store, StoreConfig, merge_sorted_runs

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _count_gathers(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "gather":
            n += 1
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):
                n += _count_gathers(v.jaxpr)
            elif isinstance(v, (list, tuple)):
                n += sum(_count_gathers(it.jaxpr) for it in v
                         if hasattr(it, "jaxpr"))
    return n


# ---------------------------------------------------------------------------
# basic read/write semantics
# ---------------------------------------------------------------------------

def test_put_get_delete_through_flushes():
    st = Store(StoreConfig(d=32, memtable_limit=50, level0_runs=3))
    for k in range(300):
        st.put(k * 7, k)
    assert st.n_runs >= 1
    assert st.get(7 * 7) == 7
    assert st.get(7 * 7 + 1) is None
    # delete a flushed key: tombstone masks the older run
    st.delete(7 * 7)
    assert st.get(7 * 7) is None
    st.flush()                        # tombstone now lives in a run
    assert st.get(7 * 7) is None
    # overwrite: newest occurrence wins
    st.put(7 * 14, -1)
    assert st.get(7 * 14) == -1


def test_scan_merges_levels_and_masks_tombstones():
    st = Store(StoreConfig(d=32, memtable_limit=40, level0_runs=2))
    for k in range(0, 400, 2):
        st.put(k, k)
    for k in range(0, 100, 4):        # delete every other stored key < 100
        st.delete(k)
    st.put(13, 1313)                  # odd key only in the memtable
    got = st.scan(0, 99)
    want = sorted([(k, k) for k in range(0, 100, 2) if k % 4 != 0]
                  + [(13, 1313)])
    assert got == want


def test_scan_bounds_beyond_domain_clamp_not_wrap():
    """A scan hi past 2^d must clamp for the filter probe, not wrap under
    the kdtype cast (wrapping swaps the normalised interval and produced
    filter false negatives the fences don't catch)."""
    st = Store(StoreConfig(d=32, memtable_limit=100, level0_runs=3))
    for k in range(0, 4000):
        st.put(k * 1_000_000, k)         # keys up to ~4.0e9, near 2^32
    st.flush()
    got = st.scan(100, (1 << 32) + 50)   # hi would wrap to 50
    assert len(got) == 3999              # every key except 0
    assert st.scan(1 << 33, (1 << 34)) == []   # entirely above the domain
    # out-of-domain point lookups answer None (fenced off), never alias
    assert st.get_many(np.asarray([1 << 33], np.uint64)) == [None]


def test_rejects_out_of_domain_keys():
    st = Store(StoreConfig(d=16))
    with pytest.raises(ValueError, match="outside"):
        st.put(1 << 16, 0)
    with pytest.raises(ValueError):
        StoreConfig(fanout=1)
    with pytest.raises(ValueError, match="filter_backend"):
        StoreConfig(filter_backend="nope")


# ---------------------------------------------------------------------------
# compaction: both filter-merge paths, entry merge precedence
# ---------------------------------------------------------------------------

def test_compaction_exercises_or_and_rebuild_merges(rng):
    st = Store(StoreConfig(d=32, memtable_limit=200, level0_runs=2,
                           fanout=4))
    keys = rng.integers(0, 1 << 32, 3000, dtype=np.uint64)
    for i, k in enumerate(keys):
        st.put(int(k), i)
    st.flush()
    assert st.stats.compactions > 0
    # class-graduating merges re-insert; same-class merges bitwise-OR
    assert st.stats.rebuild_merges > 0
    assert st.stats.or_merges > 0
    model = {int(k): i for i, k in enumerate(keys)}
    got = st.get_many(keys[:500])
    assert got == [model[int(k)] for k in keys[:500]]


def test_merge_sorted_runs_newest_wins_and_drops_tombstones():
    lay = basic_layout(32, 10, 8.0, delta=4)
    new = Run(np.asarray([5, 10], np.uint64), ["n5", "n10"],
              np.asarray([False, True]), 0, lay, None)
    old = Run(np.asarray([5, 7, 10], np.uint64), ["o5", "o7", "o10"],
              np.asarray([False, False, False]), 1, lay, None)
    keys, vals, tombs = merge_sorted_runs([new, old])
    assert list(keys) == [5, 7, 10] and vals == ["n5", "o7", "n10"]
    assert list(tombs) == [False, False, True]
    keys, vals, tombs = merge_sorted_runs([new, old], drop_tombstones=True)
    assert list(keys) == [5, 7] and vals == ["n5", "o7"]


def test_compaction_preserves_deletes_across_levels():
    st = Store(StoreConfig(d=32, memtable_limit=30, level0_runs=2))
    for k in range(600):
        st.put(k, k)
    for k in range(0, 600, 3):
        st.delete(k)
    st.flush()
    while len(st.levels[0]) or sum(bool(lv) for lv in st.levels) > 1:
        lvl = next(lv for lv in range(len(st.levels)) if st.levels[lv])
        st.compact(lvl)               # force everything into one bottom run
        if st.n_runs <= 1:
            break
    for k in range(0, 60, 3):
        assert st.get(k) is None
    for k in range(1, 60, 3):
        assert st.get(k) == k
    # bottom-level merge garbage-collected the tombstones
    bottom = st.live_runs()[0]
    assert not bottom.tombs.any()


# ---------------------------------------------------------------------------
# the one-gather invariant over >= 8 live runs of mixed capacity classes
# ---------------------------------------------------------------------------

def _store_with_runs(rng, min_runs=9):
    st = Store(StoreConfig(d=32, memtable_limit=100, level0_runs=8,
                           fanout=4))
    i = 0
    while st.n_runs < min_runs:
        for _ in range(100):
            st.put(int(rng.integers(0, 1 << 32)), i)
            i += 1
        st.flush()
    return st


def test_scan_over_8_runs_is_one_gather(rng):
    st = _store_with_runs(rng, 9)
    runs = st.live_runs()
    assert len(runs) >= 8
    assert len({r.layout for r in runs}) >= 2   # mixed capacity classes
    lo = jnp.zeros(64, jnp.uint32)
    hi = jnp.full(64, 1 << 20, jnp.uint32)
    jaxpr = jax.make_jaxpr(st._probe._range_all)(st._flat, lo, hi)
    assert _count_gathers(jaxpr.jaxpr) == 1, jaxpr.pretty_print()
    jaxpr_p = jax.make_jaxpr(st._probe._point_all)(st._flat, lo)
    assert _count_gathers(jaxpr_p.jaxpr) == 1


def test_stacked_probe_matches_per_run_probes(rng):
    st = _store_with_runs(rng, 9)
    runs = st.live_runs()
    lo = rng.integers(0, 1 << 32, 2000, dtype=np.uint64)
    hi = np.minimum(lo + (1 << 14), (1 << 32) - 1)
    _, filt = st.probe_runs(lo, hi)
    from repro.core.engine import _filter_for_layout
    for j, r in enumerate(runs):
        f = _filter_for_layout(r.layout)
        want = np.asarray(f.range(r.state, jnp.asarray(lo, jnp.uint32),
                                  jnp.asarray(hi, jnp.uint32)))
        np.testing.assert_array_equal(filt[:, j], want)


# ---------------------------------------------------------------------------
# acceptance fuzz: 1e5 mixed ops, scans + final sweep never miss a live key
# ---------------------------------------------------------------------------

def test_fuzz_100k_ops_never_misses_a_stored_key():
    rng = np.random.default_rng(0xF022)
    st = Store(StoreConfig(d=32, memtable_limit=2000, level0_runs=4,
                           fanout=4))
    model = {}
    N_OPS = 100_000
    CHUNK = 2_000
    SCAN_B = 64                       # fixed probe batch (one compile per R)
    n_scans = 0
    for c0 in range(0, N_OPS, CHUNK):
        ops = rng.random(CHUNK)
        ks = rng.integers(0, 1 << 32, CHUNK, dtype=np.uint64)
        for op, k in zip(ops, ks):
            k = int(k)
            if op < 0.92:
                st.put(k, k ^ 0xABCD)
                model[k] = k ^ 0xABCD
            else:
                dk = int(ks[rng.integers(0, CHUNK)])
                st.delete(dk)
                model.pop(dk, None)
        # the chunk's scans, batched (and padded) to one fused probe
        lo = rng.integers(0, (1 << 32) - (1 << 16), SCAN_B, dtype=np.uint64)
        hi = lo + rng.integers(1, 1 << 16, SCAN_B, dtype=np.uint64)
        results = st.scan_many(lo, hi)
        n_scans += SCAN_B
        sorted_keys = np.sort(np.fromiter(model.keys(), np.uint64,
                                          len(model)))
        for ql, qh, res in zip(lo, hi, results):
            a, b = np.searchsorted(sorted_keys, [ql, qh + 1])
            want = [(int(k), model[int(k)]) for k in sorted_keys[a:b]]
            assert res == want, (ql, qh, len(res), len(want))
    assert st.stats.compactions > 0 and st.stats.flushes > 10
    assert n_scans + st.stats.puts + st.stats.deletes >= N_OPS
    # final sweep: every live key, batched point lookups
    live = np.fromiter(model.keys(), np.uint64, len(model))
    got = st.get_many(live)
    misses = sum(g != model[int(k)] for g, k in zip(got, live))
    assert misses == 0, f"{misses}/{len(live)} stored keys missed"


# ---------------------------------------------------------------------------
# pruning acceptance at store level: filters beat fences by >= 2x
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ["uniform", "zipf"])
def test_filter_pruning_beats_fences_by_2x(dist):
    rng = np.random.default_rng(0xACCE)
    if dist == "uniform":
        keys = rng.integers(0, 1 << 31, 8000, dtype=np.uint64)
    else:
        z = rng.zipf(1.2, 8000).astype(np.float64)
        z = z / (z.max() + 1.0)
        keys = ((z * float(1 << 31)).astype(np.uint64)
                + rng.integers(0, 1 << 22, 8000, dtype=np.uint64))
    lo = rng.integers(0, 1 << 31, 3000, dtype=np.uint64)
    hi = lo + 255
    probed = {}
    for backend in ("bloomrf", "none"):
        st = Store(StoreConfig(d=32, memtable_limit=500, level0_runs=8,
                               filter_backend=backend))
        for i, k in enumerate(keys):
            st.put(int(k), i)
        st.flush()
        assert st.n_runs >= 8
        st.scan_many(lo, hi)
        probed[backend] = st.stats.runs_probed_per_scan
    assert probed["bloomrf"] <= 0.5 * probed["none"], probed


# ---------------------------------------------------------------------------
# Elias-Fano run snapshots (dist/compression.py) round-trip bit-exactly
# ---------------------------------------------------------------------------

def test_run_snapshot_roundtrip(rng):
    st = Store(StoreConfig(d=32, memtable_limit=300, level0_runs=3))
    keys = rng.integers(0, 1 << 32, 2500, dtype=np.uint64)
    for i, k in enumerate(keys):
        st.put(int(k), i)
    st.delete(int(keys[0]))
    st.flush()
    for run in st.live_runs():
        enc = run.pack()
        back = Run.unpack(enc)
        np.testing.assert_array_equal(back.keys, run.keys)
        np.testing.assert_array_equal(back.tombs, run.tombs)
        assert back.vals == run.vals and back.layout == run.layout
        np.testing.assert_array_equal(np.asarray(back.state),
                                      np.asarray(run.state))
    # store-level snapshot: restored store answers identically
    snap = st.snapshot()
    st2 = Store.restore(snap)
    qs = rng.integers(0, 1 << 32, 1000, dtype=np.uint64)
    assert st2.get_many(np.concatenate([keys[:500], qs])) == \
        st.get_many(np.concatenate([keys[:500], qs]))
    lo = rng.integers(0, 1 << 32, 200, dtype=np.uint64)
    hi = np.minimum(lo + (1 << 12), (1 << 32) - 1)
    assert st2.scan_many(lo, hi) == st.scan_many(lo, hi)


def test_snapshot_beats_raw_dump_when_sparse(rng):
    st = Store(StoreConfig(d=32, memtable_limit=400, level0_runs=4,
                           bits_per_key=24.0))
    for k in rng.integers(0, 1 << 32, 400, dtype=np.uint64):
        st.put(int(k), 0)
    st.flush()
    run = st.live_runs()[0]
    enc = run.pack()["filter"]
    from repro.dist.compression import elias_fano_size_bits
    assert elias_fano_size_bits(enc) < run.layout.total_bits


# ---------------------------------------------------------------------------
# kernel-path filter builds (use_insert_kernels) agree with the XLA path
# ---------------------------------------------------------------------------

def test_kernel_insert_path_builds_identical_filters(rng):
    keys = rng.integers(0, 1 << 32, 1500, dtype=np.uint64)
    states = []
    for use_kernels in (False, True):
        st = Store(StoreConfig(d=32, memtable_limit=1500,
                               use_insert_kernels=use_kernels))
        for i, k in enumerate(keys):
            st.put(int(k), i)
        st.flush()
        states.append(np.asarray(st.live_runs()[0].state))
    np.testing.assert_array_equal(states[0], states[1])


# ---------------------------------------------------------------------------
# nightly YCSB-E row (slow): the benchmark acceptance at larger sizes
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_ycsb_e_row_slow():
    from benchmarks import store_bench as sb
    saved = {a: getattr(sb, a) for a in
             ("N", "OPS", "MEMTABLE", "SCAN_BATCH")}
    try:
        sb.N, sb.OPS, sb.MEMTABLE, sb.SCAN_BATCH = 60_000, 6_000, 2_000, 512
        for dist in ("uniform", "zipf"):
            rf, _, _ = sb.run_one("bloomrf", dist)
            mm, _, _ = sb.run_one("none", dist)
            r, m = (rf.stats.runs_probed_per_scan,
                    mm.stats.runs_probed_per_scan)
            assert r <= 0.5 * m, (dist, r, m)
    finally:
        for a, v in saved.items():
            setattr(sb, a, v)


def test_store_stats_dict_shape():
    s = Store(StoreConfig(d=16)).stats
    d = s.as_dict()
    assert {"runs_probed_per_scan", "scan_fp_read_rate",
            "get_fp_read_rate"} <= set(d)
    assert dataclasses.is_dataclass(s)


# ---------------------------------------------------------------------------
# config validation (d / bits_per_key / mutability boundaries)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [
    dict(d=0), dict(d=65), dict(d=-3),
    dict(d=24, bits_per_key=0.0), dict(d=24, bits_per_key=-2.0),
    dict(d=24, mutability="append_only"),
    dict(d=24, mutability="deletable", purge_dead_frac=0.0),
    dict(d=24, mutability="deletable", purge_dead_frac=1.5),
])
def test_store_config_rejects_bad_values(bad):
    with pytest.raises(ValueError):
        StoreConfig(**bad)


@pytest.mark.parametrize("d", [1, 64])
def test_store_config_domain_boundaries_work(d):
    """d=1 and d=64 are legal domains: keys round-trip through flushes."""
    st = Store(StoreConfig(d=d, memtable_limit=4, level0_runs=2))
    keys = [0, 1] if d == 1 else [0, 1, 12345, (1 << 64) - 1]
    for i, k in enumerate(keys):
        st.put(k, i)
    st.flush()
    assert st.get_many(np.asarray(keys, np.uint64)) == list(range(len(keys)))
    assert st.get(0) == 0


# ---------------------------------------------------------------------------
# bytes accounting: read + not-read must cover every considered run
# ---------------------------------------------------------------------------

def _runs_only_store(rng, n=800):
    st = Store(StoreConfig(d=24, memtable_limit=128, level0_runs=4,
                           bits_per_key=12.0))
    keys = np.unique(rng.integers(0, 1 << 23, n).astype(np.uint64))
    for i, k in enumerate(keys):
        st.put(int(k), i)
    st.flush()                          # no memtable residue
    assert len(st.live_runs()) >= 2
    return st, keys


def test_bytes_accounting_is_conserved_on_gets(rng):
    """Point path: every run is either read or credited to bytes_not_read —
    the counters partition the considered data bytes (regression: the get
    path used to never credit skipped runs, understating filter savings)."""
    st, _ = _runs_only_store(rng)
    total = sum(r.data_bytes(st.cfg.value_bytes) for r in st.live_runs())
    absent = np.arange(1 << 23, (1 << 23) + 500, dtype=np.uint64)
    r0, n0 = st.stats.bytes_read, st.stats.bytes_not_read
    st.get_many(absent)
    dr = st.stats.bytes_read - r0
    dn = st.stats.bytes_not_read - n0
    assert dr + dn == len(absent) * total
    assert dn > 0, "no filter/fence credit on the point path"


def test_bytes_accounting_is_conserved_on_scans(rng):
    st, _ = _runs_only_store(rng)
    total = sum(r.data_bytes(st.cfg.value_bytes) for r in st.live_runs())
    lo = np.arange(1 << 23, (1 << 23) + 300, dtype=np.uint64)
    r0, n0 = st.stats.bytes_read, st.stats.bytes_not_read
    st.scan_many(lo, lo + 3)
    dr = st.stats.bytes_read - r0
    dn = st.stats.bytes_not_read - n0
    assert dr + dn == len(lo) * total
    assert dn > 0


# ---------------------------------------------------------------------------
# batched deletes flush at most once per call
# ---------------------------------------------------------------------------

def test_delete_many_flushes_at_most_once():
    st = Store(StoreConfig(d=24, memtable_limit=128, level0_runs=8))
    for k in range(1000, 1600):
        st.put(k, k)
    st.flush()
    f0 = st.stats.flushes
    st.delete_many(range(1000, 1500))   # 500 tombstones >> memtable_limit
    assert st.stats.flushes - f0 <= 1
    assert st.stats.deletes >= 500
    st.flush()
    assert all(v is None for v in st.get_many(
        np.arange(1000, 1500, dtype=np.uint64)))
    assert st.get_many(np.arange(1500, 1600, dtype=np.uint64)) == \
        list(range(1500, 1600))
