"""Training substrate: optimization progress, checkpoint/restart,
fault tolerance, gradient compression, straggler detection."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.dist.compression import ef_compress, ef_init
from repro.models import get_model
from repro.train import OptConfig, TrainConfig, Trainer, make_train_step
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    restore_layer_range, save_checkpoint)
from repro.train.fault_tolerance import Supervisor, elastic_restore


def _fixed_batch(cfg, rng, B=4, S=32):
    toks = jnp.asarray(rng.integers(0, cfg.vocab - 1, (B, S + 1)), jnp.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def test_loss_decreases_on_memorization(rng):
    cfg = get_config("qwen3-1.7b", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _fixed_batch(cfg, rng)
    tr = Trainer(model, params, OptConfig(lr=3e-3, warmup_steps=5,
                                          total_steps=60),
                 TrainConfig(steps=60, log_every=1, checkpoint_every=1000),
                 itertools.repeat(batch))
    log = tr.run()
    assert log[-1]["loss"] < 0.6 * log[0]["loss"], (log[0], log[-1])


def test_checkpoint_roundtrip_and_resume(tmp_path, rng):
    cfg = get_config("qwen2.5-3b", smoke=True)
    batch = _fixed_batch(cfg, rng)

    def make(steps, ckpt_every, fail=None):
        model = get_model(cfg)
        params = model.init(jax.random.key(1))
        return Trainer(model, params,
                       OptConfig(lr=1e-3, warmup_steps=2, total_steps=20),
                       TrainConfig(steps=steps, checkpoint_every=ckpt_every,
                                   log_every=1),
                       itertools.repeat(batch), ckpt_dir=str(tmp_path),
                       fail_at_step=fail)

    straight = make(14, 7)
    straight_log = straight.run()

    import shutil
    shutil.rmtree(tmp_path)
    tmp_path.mkdir()
    sup = Supervisor(lambda: make(14, 7, fail=10 if latest_step(
        str(tmp_path)) is None else None), max_restarts=2)
    res = sup.run()
    assert res["restarts"] == 1
    # resumed run reaches the same loss (same data, deterministic CPU math)
    np.testing.assert_allclose(res["metrics"][-1]["loss"],
                               straight_log[-1]["loss"], rtol=1e-4)


def test_checkpoint_bit_exact(tmp_path, rng):
    cfg = get_config("mamba2-130m", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.key(2))
    save_checkpoint(str(tmp_path), 3, {"params": params}, n_shards=2)
    assert latest_step(str(tmp_path)) == 3
    back = restore_checkpoint(str(tmp_path), 3, {"params": params})
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back["params"])):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_layer_range_restore_prunes_shards(tmp_path, rng):
    """Realistic-scale sharded checkpoint: 64 stacked layers, 8 shards;
    restoring layers [0,7] must load ~1 shard, not all 8."""
    L = 64
    tree = {"layers": {
        "wq": jnp.asarray(rng.normal(0, 1, (L, 16, 8)), jnp.float32),
        "wk": jnp.asarray(rng.normal(0, 1, (L, 16, 4)), jnp.float32),
        "mlp": jnp.asarray(rng.normal(0, 1, (L, 32)), jnp.float32)},
        "embed": jnp.asarray(rng.normal(0, 1, (128, 16)), jnp.float32)}
    save_checkpoint(str(tmp_path), 0, tree, n_shards=8)
    part, probed, loaded = restore_layer_range(str(tmp_path), 0, 0, 7)
    assert probed == 8 and loaded <= 2, (probed, loaded)
    got = part["layers/wq"]
    assert got.shape[0] == 8
    np.testing.assert_array_equal(got,
                                  np.asarray(tree["layers"]["wq"][:8]))
    # a mid-stack stage restore
    part2, _, loaded2 = restore_layer_range(str(tmp_path), 0, 24, 31)
    np.testing.assert_array_equal(part2["layers/mlp"],
                                  np.asarray(tree["layers"]["mlp"][24:32]))
    assert loaded2 <= 2


def test_elastic_restore_placement(tmp_path, rng):
    cfg = get_config("whisper-base", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.key(4))
    save_checkpoint(str(tmp_path), 1, params, n_shards=2)
    back = elastic_restore(str(tmp_path), 1, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_grad_compression_error_feedback_converges():
    """EF-int8 SGD still converges on a quadratic (error feedback property)."""
    target = jnp.asarray([1.5, -2.0, 0.25, 7.0])
    x = {"w": jnp.zeros(4)}
    err = ef_init(x)
    for _ in range(300):
        g = {"w": 2 * (x["w"] - target)}
        cg, err = ef_compress(g, err)
        x = {"w": x["w"] - 0.05 * cg["w"]}
    np.testing.assert_allclose(np.asarray(x["w"]), np.asarray(target),
                               atol=1e-2)


def test_compressed_training_step_runs(rng):
    cfg = get_config("granite-moe-3b-a800m", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.key(5))
    step = jax.jit(make_train_step(
        model, OptConfig(lr=1e-3, total_steps=10),
        TrainConfig(steps=2, grad_compression=True, microbatches=2)))
    batch = _fixed_batch(cfg, rng)
    ef = ef_init(params)
    opt = __import__("repro.train.optimizer",
                     fromlist=["adamw_init"]).adamw_init(params)
    p2, o2, ef2, m = step(params, opt, ef, batch)
    assert np.isfinite(float(m["loss"]))


def test_straggler_detection():
    cfg = get_config("qwen3-1.7b", smoke=True)
    model = get_model(cfg)
    tr = Trainer.__new__(Trainer)
    tr.cfg = TrainConfig(straggler_zscore=3.0)
    times = [0.10 + 0.001 * i for i in range(20)]
    assert tr._detect_straggler(times) is None
    ev = tr._detect_straggler(times + [1.5])
    assert ev is not None and ev["z"] > 3.0
