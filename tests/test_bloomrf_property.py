"""Hypothesis property tests on bloomRF's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import BloomRF, basic_layout
from repro.core.codecs import (float64_to_u64, pack2x32, string_point_code,
                               string_range_bounds, u64_to_float64)

_settings = settings(max_examples=40, deadline=None)


@_settings
@given(
    d=st.sampled_from([8, 12, 16]),
    delta=st.integers(1, 7),
    bpk=st.sampled_from([8.0, 12.0, 20.0]),
    seed=st.integers(0, 2 ** 16),
    data=st.data(),
)
def test_never_false_negative(d, delta, bpk, seed, data):
    rng = np.random.default_rng(seed)
    n = data.draw(st.integers(1, 40))
    keys = rng.integers(0, (1 << d) - 1, n, dtype=np.uint64)
    lay = basic_layout(d, n, bits_per_key=bpk, delta=min(delta, d),
                       seed=seed + 1)
    f = BloomRF(lay)
    state = f.build(jnp.asarray(keys, f.kdtype))
    # every inserted key found
    assert np.asarray(f.point(state, jnp.asarray(keys, f.kdtype))).all()
    # ranges straddling inserted keys always positive
    ks = np.sort(keys)
    lo = np.maximum(ks, 3) - 3
    hi = np.minimum(ks + 5, (1 << d) - 1)
    r = np.asarray(f.range(state, jnp.asarray(lo, f.kdtype),
                           jnp.asarray(hi, f.kdtype)))
    assert r.all()


@_settings
@given(st.lists(st.floats(allow_nan=False, width=64), min_size=2,
                max_size=50))
def test_float_codec_is_monotone(xs):
    xs = np.asarray(sorted(xs), np.float64)
    codes = float64_to_u64(xs)
    assert (np.diff(codes.astype(np.float64)) >= 0).all()
    back = u64_to_float64(codes)
    assert np.array_equal(back, xs, equal_nan=True)


@_settings
@given(st.text(min_size=0, max_size=20), st.text(min_size=0, max_size=20))
def test_string_codec_order(a, b):
    lo, hi = sorted([a, b])
    clo, chi = string_range_bounds(lo, hi)
    assert clo <= chi
    p = string_point_code(lo)
    assert clo <= p  # point code of the lower bound falls inside its range


@_settings
@given(st.integers(0, 2 ** 32 - 1), st.integers(0, 2 ** 32 - 1))
def test_multiattr_pack_roundtrip(a, b):
    code = pack2x32(a, b)
    assert int(code) >> 32 == a
    assert int(code) & 0xFFFFFFFF == b


@_settings
@given(seed=st.integers(0, 1000), data=st.data())
def test_range_query_superset_of_point(seed, data):
    """range(x, x) must imply >= point(x) positives (same DI, coarser)."""
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, (1 << 16) - 1, 30, dtype=np.uint64)
    lay = basic_layout(16, 30, 12.0, delta=4, seed=seed)
    f = BloomRF(lay)
    state = f.build(jnp.asarray(keys, f.kdtype))
    qs = rng.integers(0, (1 << 16) - 1, 200, dtype=np.uint64)
    p = np.asarray(f.point(state, jnp.asarray(qs, f.kdtype)))
    r = np.asarray(f.range(state, jnp.asarray(qs, f.kdtype),
                           jnp.asarray(qs, f.kdtype)))
    assert not (p & ~r).any()
