"""Hypothesis property tests on bloomRF's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import BloomRF, basic_layout
from repro.core.codecs import (float32_to_u32, float64_to_u64,
                               multiattr_range_for_a_eq_b_range, pack2x32,
                               string_point_code, string_range_bounds,
                               u32_to_float32, u64_to_float64, unpack2x32)

_settings = settings(max_examples=40, deadline=None)


@_settings
@given(
    d=st.sampled_from([8, 12, 16]),
    delta=st.integers(1, 7),
    bpk=st.sampled_from([8.0, 12.0, 20.0]),
    seed=st.integers(0, 2 ** 16),
    data=st.data(),
)
def test_never_false_negative(d, delta, bpk, seed, data):
    rng = np.random.default_rng(seed)
    n = data.draw(st.integers(1, 40))
    keys = rng.integers(0, (1 << d) - 1, n, dtype=np.uint64)
    lay = basic_layout(d, n, bits_per_key=bpk, delta=min(delta, d),
                       seed=seed + 1)
    f = BloomRF(lay)
    state = f.build(jnp.asarray(keys, f.kdtype))
    # every inserted key found
    assert np.asarray(f.point(state, jnp.asarray(keys, f.kdtype))).all()
    # ranges straddling inserted keys always positive
    ks = np.sort(keys)
    lo = np.maximum(ks, 3) - 3
    hi = np.minimum(ks + 5, (1 << d) - 1)
    r = np.asarray(f.range(state, jnp.asarray(lo, f.kdtype),
                           jnp.asarray(hi, f.kdtype)))
    assert r.all()


@_settings
@given(st.lists(st.floats(allow_nan=False, width=64), min_size=2,
                max_size=50))
def test_float_codec_is_monotone(xs):
    xs = np.asarray(sorted(xs), np.float64)
    codes = float64_to_u64(xs)
    assert (np.diff(codes.astype(np.float64)) >= 0).all()
    back = u64_to_float64(codes)
    assert np.array_equal(back, xs, equal_nan=True)


@_settings
@given(st.lists(st.floats(allow_nan=False, width=32), min_size=2,
                max_size=50))
def test_float32_codec_is_monotone(xs):
    """float32_to_u32 is the φ map at 32 bits: order-preserving and
    bijective (u32_to_float32 inverts it exactly)."""
    xs = np.asarray(sorted(xs), np.float32)
    codes = float32_to_u32(xs)
    assert (np.diff(codes.astype(np.float64)) >= 0).all()
    back = u32_to_float32(codes)
    assert np.array_equal(back, xs, equal_nan=True)


@_settings
@given(st.text(min_size=0, max_size=20), st.text(min_size=0, max_size=20))
def test_string_codec_order(a, b):
    lo, hi = sorted([a, b])
    clo, chi = string_range_bounds(lo, hi)
    assert clo <= chi
    p = string_point_code(lo)
    assert clo <= p  # point code of the lower bound falls inside its range


@_settings
@given(st.text(min_size=0, max_size=24), st.text(min_size=0, max_size=24),
       st.text(min_size=0, max_size=24))
def test_string_point_inside_range_bounds(a, b, c):
    """point/range consistency: for every lo <= s <= hi (string order),
    string_point_code(s) lies inside string_range_bounds(lo, hi) — a
    string range probe can never miss an inserted string."""
    lo, s, hi = sorted([a, b, c])
    clo, chi = string_range_bounds(lo, hi)
    assert clo <= string_point_code(s) <= chi


@_settings
@given(st.integers(0, 2 ** 32 - 1), st.integers(0, 2 ** 32 - 1))
def test_multiattr_pack_roundtrip(a, b):
    code = pack2x32(a, b)
    assert int(code) >> 32 == a
    assert int(code) & 0xFFFFFFFF == b
    ra, rb = unpack2x32(code)
    assert (int(ra), int(rb)) == (a, b)


def test_multiattr_conjunctive_never_false_negative():
    """1e5 random conjunctive predicates ``A == a AND B in [b_lo, b_hi]``:
    the <A,B> code interval from multiattr_range_for_a_eq_b_range must
    contain the code of every matching inserted pair (FN-freedom of the
    paper's §8 dual-concatenation scheme, checked against brute force)."""
    rng = np.random.default_rng(0xA77B)
    Q = 100_000
    n = 5_000
    a = rng.integers(0, 1 << 10, n, dtype=np.uint64)   # dense A: many matches
    b = rng.integers(0, 1 << 32, n, dtype=np.uint64)
    codes = np.sort(pack2x32(a, b))
    qa = rng.integers(0, 1 << 10, Q, dtype=np.uint64)
    qlo = rng.integers(0, 1 << 32, Q, dtype=np.uint64)
    qhi = np.minimum(qlo + rng.integers(0, 1 << 30, Q, dtype=np.uint64),
                     np.uint64((1 << 32) - 1))
    lo, hi = multiattr_range_for_a_eq_b_range(qa, qlo, qhi)
    # brute-force truth: does any inserted pair match the predicate?
    idx = np.searchsorted(codes, lo)
    in_set = idx < n
    cand = codes[np.minimum(idx, n - 1)]
    code_hit = in_set & (cand <= hi)
    # exact truth via (a, b) comparison on the nearest candidate is
    # subsumed: the code interval [pack(a,qlo), pack(a,qhi)] contains
    # exactly the codes of pairs with A == a and B in [qlo, qhi] (pack2x32
    # is a lexicographic bijection), so "code in interval" IS the truth.
    ca, cb = unpack2x32(cand)
    true_hit = in_set & (ca == qa) & (cb >= qlo) & (cb <= qhi)
    fn = true_hit & ~code_hit
    assert not fn.any(), f"{int(fn.sum())} conjunctive false negatives"
    assert int(true_hit.sum()) > 0  # the workload actually had matches


@_settings
@given(seed=st.integers(0, 1000), data=st.data())
def test_range_query_superset_of_point(seed, data):
    """range(x, x) must imply >= point(x) positives (same DI, coarser)."""
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, (1 << 16) - 1, 30, dtype=np.uint64)
    lay = basic_layout(16, 30, 12.0, delta=4, seed=seed)
    f = BloomRF(lay)
    state = f.build(jnp.asarray(keys, f.kdtype))
    qs = rng.integers(0, (1 << 16) - 1, 200, dtype=np.uint64)
    p = np.asarray(f.point(state, jnp.asarray(qs, f.kdtype)))
    r = np.asarray(f.range(state, jnp.asarray(qs, f.kdtype),
                           jnp.asarray(qs, f.kdtype)))
    assert not (p & ~r).any()
