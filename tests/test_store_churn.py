"""Churn fuzz for dynamic filters (ISSUE: deletable/expiring lanes).

The contract under churn: after any interleaving of inserts, deletes and
generation expiry, (a) every live key is still readable — ZERO false
negatives — and (b) the filters' false-positive rate on absent keys stays
bounded instead of drifting upward as dead keys' bits accumulate.  The
deletable store's purge/promote compaction is what prevents the drift;
the insert-only store run on the identical op sequence is the control.

``test_churn_fuzz_smoke`` is the tier-1 gate; the 1e6-op headline run is
``test_churn_fuzz_slow_1e6`` (``-m slow``, the nightly lane).
"""
import pickle

import numpy as np
import pytest

from repro.store import Store, StoreConfig
from repro.store.memtable import TOMBSTONE
from repro.store.run import Run


def _churn(store, rng, n_ops, key_space, delete_frac=0.4):
    """Random put/delete mix; returns the surviving model dict."""
    model = {}
    for i in range(n_ops):
        if model and rng.random() < delete_frac:
            # delete a key that actually exists (the supported contract)
            k = int(next(iter(model)))
            store.delete(k)
            del model[k]
        else:
            k = int(rng.integers(0, key_space))
            store.put(k, i)
            model[k] = i
    return model


def _filter_positive_rate(store, keys):
    """Fraction of ``keys`` some run's (fence AND filter) lets through."""
    fence, filt = store.probe_runs(keys, keys, point=True)
    return float((fence & filt).any(axis=1).mean())


def _run_churn_fuzz(rng, n_ops, memtable_limit):
    space = 1 << 24
    cfgs = {
        "deletable": StoreConfig(d=24, memtable_limit=memtable_limit,
                                 level0_runs=2, fanout=4, bits_per_key=14.0,
                                 mutability="deletable"),
        "insert_only": StoreConfig(d=24, memtable_limit=memtable_limit,
                                   level0_runs=2, fanout=4,
                                   bits_per_key=14.0),
    }
    fpr = {}
    for name, cfg in cfgs.items():
        st = Store(cfg)
        model = _churn(st, np.random.default_rng(rng.integers(1 << 31)),
                       n_ops, space)
        st.flush()
        # zero false negatives: every surviving key reads its last value
        live = np.fromiter(model.keys(), np.uint64, len(model))
        got = st.get_many(live)
        assert got == [model[int(k)] for k in live], \
            f"{name}: churn produced a false negative"
        # FPR on definitely-absent keys (outside every inserted key)
        absent = rng.integers(space, 2 * space, 20_000, dtype=np.uint64)
        absent = np.minimum(absent, (1 << 24) - 1)
        absent = absent[~np.isin(absent, live)]
        fpr[name] = _filter_positive_rate(st, absent)
        if name == "deletable":
            assert st.stats.promote_merges + st.stats.purge_rebuilds > 0, \
                "deletable churn never exercised promote/purge"
    # bounded drift: churn with ~40% deletes must not saturate the filters,
    # and washing dead bits out must not do *worse* than keeping them
    assert fpr["deletable"] < 0.30, fpr
    assert fpr["deletable"] <= fpr["insert_only"] + 0.02, fpr
    return fpr


def test_churn_fuzz_smoke(rng):
    _run_churn_fuzz(rng, n_ops=12_000, memtable_limit=256)


@pytest.mark.slow
def test_churn_fuzz_slow_1e6(rng):
    """Headline acceptance: 1e6 mixed ops, zero FN, bounded FPR drift."""
    _run_churn_fuzz(rng, n_ops=1_000_000, memtable_limit=4096)


# ---------------------------------------------------------------------------
# TTL / generation expiry fuzz
# ---------------------------------------------------------------------------

def test_ttl_generation_fuzz(rng):
    """Zero FN for keys inside the TTL window; expired keys decay to the
    background FPR instead of accumulating."""
    from repro.api import FilterSpec, open_filter

    G = 3
    f = open_filter(FilterSpec(dtype="u32", n=4096, mutability="ttl",
                               generations=G))
    batches = []          # batches[i] inserted right after advance #i
    for epoch in range(8):
        keys = rng.integers(0, 1 << 32, 500, dtype=np.uint64)
        f.insert(keys)
        batches.append(keys)
        # live window: current generation plus the G-1 younger survivors
        live = np.concatenate(batches[max(0, epoch - (G - 1)):])
        assert np.asarray(f.point(live)).all(), \
            f"epoch {epoch}: FN inside the TTL window"
        if epoch >= G:
            expired = np.concatenate(batches[: epoch - (G - 1)])
            assert np.asarray(f.point(expired)).mean() < 0.05, \
                f"epoch {epoch}: expired keys did not decay"
        absent = rng.integers(0, 1 << 32, 5000, dtype=np.uint64)
        assert np.asarray(f.point(absent)).mean() < 0.05
        f.advance_generation()
    # fully drained: everything expired, state collapses to empty
    for _ in range(G):
        f.advance_generation()
    assert not np.asarray(f.state).any()


def test_aging_tenant_bank_fuzz(rng):
    from repro.dist import AgingTenantBank, TenantFilterBank

    bank = TenantFilterBank(d=32, n_tenants=4, n_shards=2,
                            n_keys_per_tenant=2048, _warn=False)
    aging = AgingTenantBank(bank, n_generations=2)
    t1 = rng.integers(0, 4, 400).astype(np.uint32)
    k1 = rng.integers(0, 1 << 32, 400, dtype=np.uint64)
    aging.insert(t1, k1)
    aging.advance()
    t2 = rng.integers(0, 4, 400).astype(np.uint32)
    k2 = rng.integers(0, 1 << 32, 400, dtype=np.uint64)
    aging.insert(t2, k2)
    assert np.asarray(aging.point(t1, k1)).all()      # still in window
    assert np.asarray(aging.point(t2, k2)).all()
    aging.advance()                                   # k1's generation dies
    assert np.asarray(aging.point(t2, k2)).all()
    assert np.asarray(aging.point(t1, k1)).mean() < 0.05
    # growth preserves the window contents
    grown = aging.promoted(factor=4)
    assert np.asarray(grown.point(t2, k2)).all()


# ---------------------------------------------------------------------------
# snapshots through real bytes (satellite 1 + 5)
# ---------------------------------------------------------------------------

def _store_with_tombstones(rng):
    # level0_runs high enough that the tombstoned flush is NOT immediately
    # bottom-compacted away (bottom merges drop tombstone entries)
    st = Store(StoreConfig(d=24, memtable_limit=512, level0_runs=4,
                           fanout=3, bits_per_key=12.0))
    keys = rng.integers(0, 1 << 24, 600, dtype=np.uint64)
    for i, k in enumerate(keys):
        st.put(int(k), i)
    for k in keys[:150]:
        st.delete(int(k))
    st.flush()
    return st, keys


def test_run_pack_has_no_inprocess_sentinel(rng):
    st, _ = _store_with_tombstones(rng)
    runs = [r for r in st.live_runs() if r.tombs.any()]
    assert runs, "fixture produced no tombstoned runs"
    for run in runs:
        enc = run.pack()
        assert enc["schema"] == "bloomrf-run/v3"
        assert not any(isinstance(v, type(TOMBSTONE)) for v in enc["vals"])
        back = Run.unpack(enc)
        for v, t in zip(back.vals, back.tombs):
            assert (v is TOMBSTONE) == bool(t)   # identity, not a copy
        np.testing.assert_array_equal(back.keys, run.keys)
        np.testing.assert_array_equal(back.tombs, run.tombs)


def test_run_unpack_accepts_v1_and_heals_identity(rng):
    """A v1 snapshot that went through pickle carries *copies* of the
    sentinel; unpack must restore the canonical object from the mask."""
    st, _ = _store_with_tombstones(rng)
    run = next(r for r in st.live_runs() if r.tombs.any())
    enc = run.pack()
    enc["schema"] = "bloomrf-run/v1"
    stale = pickle.loads(pickle.dumps(TOMBSTONE))     # identity-broken copy
    assert stale is not TOMBSTONE
    enc["vals"] = [stale if t else v
                   for v, t in zip(run.vals, run.tombs)]
    back = Run.unpack(enc)
    assert all((v is TOMBSTONE) == bool(t)
               for v, t in zip(back.vals, back.tombs))


def test_store_snapshot_pickle_roundtrip(rng):
    st, keys = _store_with_tombstones(rng)
    snap = st.snapshot()
    assert snap["schema"] == "bloomrf-store/v3"
    blob = pickle.dumps(snap)                         # REAL bytes
    st2 = Store.restore(pickle.loads(blob))
    qs = np.unique(keys)
    assert st2.get_many(qs) == st.get_many(qs)
    # deleted keys stay deleted after the round-trip
    assert all(st2.get(int(k)) is None for k in keys[:150])
    # and the restored tombstones keep sentinel identity
    for run in st2.live_runs():
        for v, t in zip(run.vals, run.tombs):
            assert (v is TOMBSTONE) == bool(t)
