"""Quickstart: build a bloomRF, run point + range queries, compare the
empirical FPR against the paper's model, and let the tuning advisor pick a
layout for large ranges.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np
import jax.numpy as jnp

from repro.core import BloomRF, basic_layout
from repro.core.model import basic_range_fpr
from repro.core.tuning import advise

rng = np.random.default_rng(42)

# --- basic bloomRF: tuning-free, good to ranges ~2^14 --------------------
n = 100_000
keys = rng.integers(0, 1 << 63, n, dtype=np.uint64)
layout = basic_layout(d=64, n_keys=n, bits_per_key=17.0, delta=7)
filt = BloomRF(layout)
state = filt.build_np(keys)
print(layout.describe())

# point membership: never a false negative
assert bool(filt.point(state, jnp.asarray(keys[0], filt.kdtype)))
print("point(inserted key) ->", bool(filt.point(state, jnp.asarray(keys[0]))))

# range query: "any key in [lo, hi]?"
lo, hi = np.uint64(keys[0] - 5), np.uint64(keys[0] + 5)
print(f"range[{lo}, {hi}] ->", bool(filt.range(state, jnp.uint64(lo),
                                               jnp.uint64(hi))))

# empirical vs model FPR for ranges of 2^14
Q = 20_000
qlo = rng.integers(0, 1 << 63, Q, dtype=np.uint64)
qhi = qlo + np.uint64(2 ** 14 - 1)
res = np.asarray(filt.range(state, jnp.asarray(qlo), jnp.asarray(qhi)))
ks = np.sort(keys)
idx = np.searchsorted(ks, qlo)
truth = (idx < n) & (ks[np.minimum(idx, n - 1)] <= qhi)
emp = (res & ~truth).sum() / max((~truth).sum(), 1)
print(f"range 2^14 FPR: empirical {emp:.4f} vs model bound "
      f"{basic_range_fpr(64, n, 17.0 * n, 2**14):.4f}")

# --- tuned bloomRF for big ranges (paper §7) ------------------------------
res = advise(d=64, n=n, m_bits=16 * n, R=1e9)
print(f"\nadvisor for R=1e9: exact level {res.exact_level}, "
      f"deltas {res.layout.deltas}, predicted point FPR {res.fpr_point:.4f}, "
      f"range FPR {res.fpr_range_max:.4f}")
tuned = BloomRF(res.layout)
tstate = tuned.build_np(keys)
big_lo = rng.integers(0, 1 << 63, 5000, dtype=np.uint64)
big_hi = big_lo + np.uint64(int(1e9))
r = np.asarray(tuned.range(tstate, jnp.asarray(big_lo), jnp.asarray(big_hi)))
idx = np.searchsorted(ks, big_lo)
truth = (idx < n) & (ks[np.minimum(idx, n - 1)] <= big_hi)
assert not (truth & ~r).any(), "false negative!"
print(f"tuned filter, |R|=1e9: FPR "
      f"{(r & ~truth).sum() / max((~truth).sum(), 1):.4f} "
      f"(no false negatives on {int(truth.sum())} non-empty ranges)")
