"""Quickstart: open a bloomRF through the typed façade, run point + range
queries, compare the empirical FPR against the paper's model, and let the
spec's tuning budget pick an advisor layout for large ranges — then do the
same with float keys, which the façade encodes through the order-preserving
φ codec (paper §8).  The observability plane (DESIGN.md §15) is switched on
for the session, so the run ends with a one-screen metrics summary: probe
counts, live observed FPR from the known-absent reservoir, and p50/p99
facade latency.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np

from repro import FilterSpec, open_filter
from repro import obs
from repro.core.model import basic_range_fpr

obs.enable()
rng = np.random.default_rng(42)

# --- basic bloomRF: tuning-free, good to ranges ~2^14 --------------------
n = 100_000
keys = rng.integers(0, 1 << 63, n, dtype=np.uint64)
f = open_filter(FilterSpec(dtype="u64", n=n, bits_per_key=17.0,
                           range_log2=14))
f.insert(keys)
print(f.describe())
print(f.layout.describe())

# point membership: never a false negative
print("point(inserted key) ->", bool(f.point(keys[:1])[0]))

# range query: "any key in [lo, hi]?"
lo, hi = np.uint64(keys[0] - 5), np.uint64(keys[0] + 5)
print(f"range[{lo}, {hi}] ->", bool(f.range([lo], [hi])[0]))

# empirical vs model FPR for ranges of 2^14
Q = 20_000
qlo = rng.integers(0, 1 << 63, Q, dtype=np.uint64)
qhi = qlo + np.uint64(2 ** 14 - 1)
res = f.range(qlo, qhi)
ks = np.sort(keys)
idx = np.searchsorted(ks, qlo)
truth = (idx < n) & (ks[np.minimum(idx, n - 1)] <= qhi)
emp = (res & ~truth).sum() / max((~truth).sum(), 1)
print(f"range 2^14 FPR: empirical {emp:.4f} vs model bound "
      f"{basic_range_fpr(64, n, 17.0 * n, 2**14):.4f}")

# --- tuned bloomRF for big ranges (paper §7): range_log2=30 -> advisor ----
tuned = open_filter(FilterSpec(dtype="u64", n=n, bits_per_key=16.0,
                               range_log2=30, backend="xla"))
tuned.insert(keys)
print(f"\n{tuned.describe()}: tuning={tuned.tuning}, "
      f"exact_level={tuned.layout.exact_level}, deltas={tuned.layout.deltas}")
big_lo = rng.integers(0, 1 << 63, 5000, dtype=np.uint64)
big_hi = big_lo + np.uint64(int(1e9))
r = tuned.range(big_lo, big_hi)
idx = np.searchsorted(ks, big_lo)
truth = (idx < n) & (ks[np.minimum(idx, n - 1)] <= big_hi)
assert not (truth & ~r).any(), "false negative!"
print(f"tuned filter, |R|=1e9: FPR "
      f"{(r & ~truth).sum() / max((~truth).sum(), 1):.4f} "
      f"(no false negatives on {int(truth.sum())} non-empty ranges)")

# --- typed keys: float64 through the φ codec ------------------------------
temps = rng.normal(20.0, 15.0, 50_000)
ff = open_filter(FilterSpec(dtype="f64", n=len(temps), bits_per_key=16.0))
ff.insert(temps)
assert ff.point(temps[:100]).all()
hot = ff.range(np.full(1, 35.0), np.full(1, 1000.0))
print(f"\nfloat keys: any reading in [35C, 1000C]? -> {bool(hot[0])} "
      f"(truth: {bool((temps >= 35.0).any())})")

# --- observability: what did this session actually do? --------------------
# The registry accumulated everything above; observed_fpr() re-probes each
# filter's known-absent reservoir — any positive is a certain false
# positive, so the rate IS the live FPR (no truth set needed).
live = f.observed_fpr()
snap = obs.export_snapshot()["metrics"]
print("\n--- metrics summary (repro.obs) ---")
print(f"basic filter live FPR: point {live.get('point_fpr', 0.0):.4f}, "
      f"range {live.get('range_fpr', 0.0):.4f} "
      f"({live['range_candidates']} known-absent candidates re-probed)")
for name in sorted(snap):
    if name.startswith("obs/latency/"):
        h = snap[name]
        print(f"{name[len('obs/latency/'):]:>16}: n={h['count']:<6} "
              f"p50={h['p50']:>9.0f}us p99={h['p99']:>9.0f}us")
wl = snap.get("obs/workload/range_log2")
if wl:
    print(f"query range length: median ~2^{wl['p50']:.0f} "
          f"({wl['count']} ranges observed)")
