"""Dual-attribute bloomRF (paper §8 + Fig. 12.F): conjunctive predicates
``Run < 300 AND ObjectID = x`` answered by ONE filter over concatenated
attributes, vs two single-attribute filters combined conjunctively.

The façade's ``multiattr`` dtype inserts both the <A,B> and <B,A>
concatenations; ``range((a, b_lo), (a, b_hi))`` probes the <A,B> codes and
``range_where_b`` the mirrored <B,A> codes — no hand-rolled packing.

    PYTHONPATH=src python examples/multi_attribute.py
"""
import os
os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np

from repro import FilterSpec, open_filter
from repro.core import pack2x32

rng = np.random.default_rng(16)
N, Q = 200_000, 10_000

# SDSS-like columns
run = np.abs(rng.normal(400, 150, N)).astype(np.uint64)
obj = rng.integers(0, 1 << 31, N, dtype=np.uint64)

dual = open_filter(FilterSpec(dtype="multiattr", n=N, bits_per_key=16.0,
                              range_log2=32, backend="xla"))
dual.insert((obj, run))                         # sets <Obj,Run> and <Run,Obj>

sep_obj = open_filter(FilterSpec(dtype="u64", n=N, bits_per_key=16.0))
sep_obj.insert(obj)

qs = rng.integers(0, 1 << 31, Q, dtype=np.uint64)
zeros = np.zeros(Q, np.uint64)
caps = np.full(Q, 299, np.uint64)

res_dual = dual.range((qs, zeros), (qs, caps))  # Obj == x AND Run in [0,299]
res_sep = sep_obj.point(qs)   # the Run<300 single filter is ~always true

ks = np.sort(pack2x32(obj, run))
lo = pack2x32(qs, zeros)
hi = pack2x32(qs, caps)
idx = np.searchsorted(ks, lo)
truth = (idx < len(ks)) & (ks[np.minimum(idx, len(ks) - 1)] <= hi)
for name, res in (("dual-attribute", res_dual), ("two separate", res_sep)):
    assert not (truth & ~res).any()
    fpr = (res & ~truth).sum() / max((~truth).sum(), 1)
    print(f"{name:16s} FPR for 'Run<300 AND ObjectID=x': {fpr:.4f}")
