"""Dual-attribute bloomRF (paper §8 + Fig. 12.F): conjunctive predicates
``Run < 300 AND ObjectID = x`` answered by ONE filter over concatenated
attributes, vs two single-attribute filters combined conjunctively.

    PYTHONPATH=src python examples/multi_attribute.py
"""
import os
os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np

from repro.core.codecs import (multiattr_insert_codes,
                               multiattr_range_for_a_eq_b_range)
from repro.filters import BloomRFAdapter

rng = np.random.default_rng(16)
N, Q = 200_000, 10_000

# SDSS-like columns
run = np.abs(rng.normal(400, 150, N)).astype(np.uint64)
obj = rng.integers(0, 1 << 31, N, dtype=np.uint64)

ab, ba = multiattr_insert_codes(obj, run)       # <Obj,Run> and <Run,Obj>
dual = BloomRFAdapter(16, mode="tuned", R=2.0 ** 32)
dual.build(np.concatenate([ab, ba]))

sep_obj = BloomRFAdapter(16, mode="basic")
sep_obj.build(obj)

qs = rng.integers(0, 1 << 31, Q, dtype=np.uint64)
lo, hi = multiattr_range_for_a_eq_b_range(qs, np.uint64(0), np.uint64(299))

res_dual = dual.range(lo, hi)
res_sep = sep_obj.point(qs)   # the Run<300 single filter is ~always true

ks = np.sort(ab)
idx = np.searchsorted(ks, lo)
truth = (idx < len(ks)) & (ks[np.minimum(idx, len(ks) - 1)] <= hi)
for name, res in (("dual-attribute", res_dual), ("two separate", res_sep)):
    assert not (truth & ~res).any()
    fpr = (res & ~truth).sum() / max((~truth).sum(), 1)
    print(f"{name:16s} FPR for 'Run<300 AND ObjectID=x': {fpr:.4f}")
