"""End-to-end training driver: a small qwen3-family LM trained on the
synthetic corpus with bloomRF online dedup + shard range-admission, sharded
checkpoints (with bloomRF layer-range indexes), fault-injected restart, and
straggler monitoring.

    PYTHONPATH=src python examples/train_lm_dedup.py [--steps 60]
"""
import os
os.environ.setdefault("JAX_ENABLE_X64", "1")

import argparse
import tempfile

import jax

from repro.configs import get_config
from repro.data import StreamDeduper, SyntheticCorpus, batch_iterator
from repro.models import get_model
from repro.train import OptConfig, TrainConfig, Trainer
from repro.train.checkpoint import latest_step, restore_layer_range
from repro.train.fault_tolerance import Supervisor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config("qwen3-1.7b", smoke=True)
    ckpt_dir = tempfile.mkdtemp(prefix="bloomrf_train_")
    dedup = StreamDeduper(expected_docs=1 << 14)

    def data():
        corpus = SyntheticCorpus(vocab=cfg.vocab, seed=1, dup_rate=0.3)
        return batch_iterator(corpus, args.batch, args.seq, deduper=dedup,
                              window=(0, 10_000))

    def factory():
        model = get_model(cfg)
        params = model.init(jax.random.key(0))
        return Trainer(
            model, params,
            OptConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps),
            TrainConfig(steps=args.steps, checkpoint_every=20, log_every=10,
                        grad_compression=True),
            data(), ckpt_dir=ckpt_dir,
            fail_at_step=args.steps // 2
            if latest_step(ckpt_dir) is None else None)

    sup = Supervisor(factory, max_restarts=2)
    res = sup.run()
    print(f"\ntrained {args.steps} steps with {res['restarts']} restart(s)")
    for rec in res["metrics"]:
        print(f"  step {rec['step']:4d} loss {rec['loss']:.4f} "
              f"lr {rec['lr']:.2e} {rec['step_time_s']*1e3:.0f} ms")
    print("dedup stats:", dedup.stats)
    print("straggler events:", res["stragglers"])

    # elastic partial restore: a 'pipeline stage' pulling layers [0, 0]
    step = latest_step(ckpt_dir)
    part, probed, loaded = restore_layer_range(ckpt_dir, step, 0, 0)
    print(f"layer-range restore via bloomRF index: {loaded}/{probed} shards "
          f"loaded, {len(part)} leaf slices")


if __name__ == "__main__":
    main()
