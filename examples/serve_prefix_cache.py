"""Batched serving with the bloomRF prefix-cache index: requests stream
through fixed batch slots; frozen prompt chunks are indexed per segment by a
bloomRF, and follow-up requests from the same session probe the filters
before touching any segment map (point queries) while session sweeps use
range queries.

    PYTHONPATH=src python examples/serve_prefix_cache.py
"""
import os
os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np
import jax

from repro.configs import get_config
from repro.models import get_model
from repro.serve import ServeLoop
from repro.serve.decode import Request


def main():
    rng = np.random.default_rng(3)
    cfg = get_config("qwen2.5-3b", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    loop = ServeLoop(model, params, max_seq=96, batch_slots=2,
                     prefix_chunk=16)

    # two waves of requests; sessions 0/1 return in wave 2 (prefix reuse)
    wave1 = [Request(session=s, prompt=rng.integers(
        0, cfg.vocab - 1, 48).astype(np.int32), max_new_tokens=8)
        for s in range(4)]
    wave2 = [Request(session=s, prompt=rng.integers(
        0, cfg.vocab - 1, 48).astype(np.int32), max_new_tokens=8)
        for s in (0, 1, 7)]

    done = loop.run(wave1) + loop.run(wave2)
    for r in done:
        print(f"session {r.session}: generated {r.out_tokens}")
    s = loop.index.stats
    print(f"\nprefix index: {len(loop.index.segments)} segments, "
          f"{s['filter_probes']} filter probes, {s['filter_hits']} hits, "
          f"{s['map_hits']} confirmed, "
          f"measured FP rate {loop.index.false_positive_rate():.3f}")
    print("segments holding session 0:", loop.index.session_segments(0))
    print("eviction sweep sessions [4, 9]:",
          loop.index.eviction_candidates(4, 9))


if __name__ == "__main__":
    main()
